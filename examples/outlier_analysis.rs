//! Outlier-suppression analysis on real model activations (Figures 1/3/4):
//! per-token mass concentration δ, the Prop 3.2 normalized bound across
//! block sizes, empirical suppression ratios, and the Gaussian/Laplacian
//! distribution-fit comparison. Writes CSVs next to the binary for
//! plotting and prints summaries.
//!
//!     cargo run --release --example outlier_analysis [model]

use perq::calib::capture;
use perq::hadamard::BlockRotator;
use perq::model::transform;
use perq::prelude::*;
use perq::stats::{self, distfit};
use perq::tensor::Mat;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(|s| s.as_str()).unwrap_or("llama_tiny");
    let ctx = RepoContext::discover()?;
    let engine = Engine::new(&ctx)?;
    let bundle = ModelBundle::load_with_engine(&ctx, &engine, model)?;
    let cfg = bundle.cfg.clone();

    let mut ws = bundle.weights.clone();
    transform::fold_norms(&mut ws, &cfg);
    let seqs = capture::calibration_batches(&cfg, Source::Wiki, 8, 42);
    let caps = capture::run_capture(&engine, model, &cfg, &ws, &seqs)?;
    let layer = cfg.n_layers.saturating_sub(1).min(2); // "third down projection layer"
    let down = &caps.down_in[layer];
    println!("{model}: {} tokens at down-proj layer {layer} (d_ffn {})",
             down.rows, cfg.d_ffn);

    // --- Fig 1: activation range under rotation structures -----------------
    let range = |m: &Mat| -> f64 {
        m.data.iter().fold(0.0f64, |a, &v| a.max(v.abs() as f64))
    };
    println!("\nFig 1 — max |activation| by rotation structure:");
    println!("  original      {:8.3}", range(down));
    for b in [32usize, 128, cfg.d_ffn] {
        if cfg.d_ffn % b != 0 {
            continue;
        }
        let rot = BlockRotator::hadamard(b)?;
        let mut r = down.clone();
        rot.apply_mat(&mut r);
        let label = if b == cfg.d_ffn { "full".to_string() } else { format!("b={b}") };
        println!("  {label:<12} {:8.3}", range(&r));
    }

    // --- Fig 3: delta vs suppression ratio + distribution fits -------------
    let full_rot = BlockRotator::hadamard(cfg.d_ffn)?;
    let n_tokens = down.rows.min(1024);
    let mut csv = String::from("delta,suppression,delta_gauss,delta_laplace\n");
    let mut below_thresh = 0usize;
    let mut suppressed = 0usize;
    let mut rng = perq::data::rng::Rng::new(0xF16_3);
    for r in 0..n_tokens {
        let row = down.row(r);
        let d = stats::delta(row);
        let mut rot = Mat::from_vec(1, row.len(), row.to_vec());
        full_rot.apply_mat(&mut rot);
        let ratio = stats::suppression_ratio(row, &rot.data);
        if d < 1.0 / (row.len() as f64).sqrt() {
            below_thresh += 1;
        }
        if ratio < 1.0 {
            suppressed += 1;
        }
        let (gm, gs) = distfit::fit_gaussian(row);
        let g = distfit::sample_gaussian(gm, gs, row.len(), &mut rng);
        let (lm, lsc) = distfit::fit_laplacian(row);
        let l = distfit::sample_laplacian(lm, lsc, row.len(), &mut rng);
        csv.push_str(&format!(
            "{d:.6},{ratio:.6},{:.6},{:.6}\n",
            stats::delta(&g),
            stats::delta(&l)
        ));
    }
    std::fs::write("outlier_fig3.csv", &csv)?;
    println!(
        "\nFig 3 — of {n_tokens} tokens: {below_thresh} below the 1/sqrt(d) sufficient \
         threshold, {suppressed} actually suppressed (paper: suppression is \
         consistent even above the threshold). CSV -> outlier_fig3.csv"
    );

    // --- Fig 4: normalized bound vs block size -----------------------------
    println!("\nFig 4 — mean normalized bound max_j delta_j|X_j|inf/|X|inf vs b:");
    let mut csv4 = String::from("b,mean,std,sqrt_thresh,lower\n");
    let mut b = 16usize;
    while b <= cfg.d_ffn {
        if cfg.d_ffn % b == 0 {
            let vals: Vec<f64> = (0..n_tokens)
                .map(|r| stats::normalized_bound(down.row(r), b))
                .collect();
            let mean = vals.iter().sum::<f64>() / vals.len() as f64;
            let var = vals.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / vals.len() as f64;
            println!(
                "  b={b:<5} mean {mean:.4} (std {:.4})   1/sqrt(b)={:.4}  1/b={:.4}",
                var.sqrt(),
                1.0 / (b as f64).sqrt(),
                1.0 / b as f64
            );
            csv4.push_str(&format!(
                "{b},{mean:.6},{:.6},{:.6},{:.6}\n",
                var.sqrt(),
                1.0 / (b as f64).sqrt(),
                1.0 / b as f64
            ));
        }
        b *= 2;
    }
    std::fs::write("outlier_fig4.csv", &csv4)?;
    println!("CSV -> outlier_fig4.csv");
    Ok(())
}
