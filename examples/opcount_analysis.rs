//! Rotation compute analysis (the paper's Appendix A): the analytic op
//! model behind Tables 3-4 (exact reproduction), the *measured* op count of
//! our generalized non-power-of-2 fast transform, and wall-clock timings of
//! the rust transform implementations.
//!
//!     cargo run --release --example opcount_analysis

use perq::hadamard::nonpow2::NonPow2Plan;
use perq::hadamard::{opcount, BlockRotator};
use perq::tensor::Mat;
use perq::util::bench::{fmt_count, print_table, time};

fn main() -> anyhow::Result<()> {
    // Tables 3 and 4 — analytic, matches the paper digit-for-digit.
    let rows3: Vec<(String, Vec<String>)> = opcount::table3()
        .into_iter()
        .map(|r| {
            (
                format!("{} {} d={}", r.model, r.size, r.d),
                vec![
                    fmt_count(r.b32),
                    fmt_count(r.b128),
                    fmt_count(r.b512),
                    fmt_count(r.full),
                ],
            )
        })
        .collect();
    print_table("Table 3 (analytic)", &["b=32", "b=128", "b=512", "Full"], &rows3);

    let rows4: Vec<(String, Vec<String>)> = opcount::table4()
        .into_iter()
        .map(|r| {
            (
                r.model.to_string(),
                vec![
                    fmt_count(r.matmul),
                    fmt_count(r.butterfly_matmul),
                    fmt_count(r.ours),
                ],
            )
        })
        .collect();
    print_table("Table 4 (analytic)", &["Matmul", "Bfly+MM", "Ours"], &rows4);

    // Measured ops of the generalized implementation vs the paper model.
    println!("\nmeasured non-pow-2 plan ops vs model d(k'+t+2):");
    for d in [448usize, 1792, 3072, 6144, 14336] {
        if let Ok(plan) = NonPow2Plan::new(d) {
            let model = opcount::ours_ops(d);
            let meas = plan.measured_ops();
            println!(
                "  d={d:<6} model {:<9} measured {:<9} ratio {:.2}",
                fmt_count(model),
                fmt_count(meas),
                meas as f64 / model as f64
            );
        }
    }

    // Wall-clock of the actual rust transforms (per 4096-token batch).
    println!("\nwall-clock, 4096 tokens/batch:");
    for (d, b) in [(1024usize, 32usize), (1024, 1024), (448, 448), (14336, 14336)] {
        let rot = BlockRotator::hadamard(b)?;
        let mut m = Mat::from_fn(4096, d, |i, j| ((i * 31 + j) as f32 * 0.01).sin());
        let t = time(&format!("d={d} b={b}"), 3, 300, || {
            rot.apply_mat(&mut m);
        });
        let gbps = (4096.0 * d as f64 * 4.0) / (t.mean_ns) ; // bytes/ns = GB/s
        println!("  d={d:<6} b={b:<6} {:8.2} ms/batch  ({gbps:.2} GB/s)", t.mean_ms());
    }
    Ok(())
}
