//! Quickstart: quantize one model with PeRQ*, compare against the
//! full-precision baseline, then round-trip the quantized model through a
//! versioned `.perq` deployment artifact (quantize once, serve many).
//!
//!     cargo run --release --example quickstart [-- --backend native|pjrt|auto]
//!
//! With artifacts (`make artifacts`) the trained tiny models are used and
//! the backend defaults to pjrt when compiled in. Without artifacts the
//! example still runs: native backend, synthetic weights — useful to see
//! the pipeline shape, though a random-init model has near-uniform ppl.

use perq::prelude::*;
use perq::util::cli;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cli::parse(&argv);
    let (engine, bundle) = match RepoContext::discover() {
        Ok(ctx) => {
            let kind = BackendKind::resolve(args.backend(), &ctx)?;
            let engine = Engine::with_backend(&ctx, kind)?;
            let bundle = match ModelBundle::load(&ctx, "llama_np2") {
                Ok(b) => b,
                Err(e) if kind == BackendKind::Native => {
                    println!("note: {e:#}\n      — falling back to synthetic weights");
                    ModelBundle::synthetic("llama_np2")?
                }
                Err(e) => return Err(e),
            };
            (engine, bundle)
        }
        Err(_) => {
            anyhow::ensure!(
                !matches!(args.backend(), Some("pjrt")),
                "--backend pjrt requires an artifacts/ tree (run `make artifacts`)"
            );
            println!("no artifacts/ tree found — native backend, synthetic weights");
            (Engine::native_ephemeral(), ModelBundle::synthetic("llama_np2")?)
        }
    };
    println!(
        "model {} — {} layers, d_model {}, d_ffn {}, {} params (backend: {})",
        bundle.name,
        bundle.cfg.n_layers,
        bundle.cfg.d_model,
        bundle.cfg.d_ffn,
        bundle.weights.param_count(),
        engine.backend().name()
    );

    // full-precision reference
    let (fp, _) = baseline_eval(&bundle, &engine, 4096, None)?;
    println!("BF16-analog baseline ppl: {:.3}", fp.perplexity);

    // PeRQ*: MassDiff permutation + QuaRot rotations + block-32 online
    // Hadamard at the down projection + Qronos rounding, INT4 W4A4.
    // Quantize ONCE (the offline stages), then evaluate the result — the
    // same QuantizedModel is exported below.
    let qm = Pipeline::new(presets::perq_star(32, Format::Int4))
        .quantize_with_engine(&bundle, &engine)?;
    let perq_eval = perq::eval::perplexity::evaluate_stream(
        &engine, &qm.model, &qm.cfg, &qm.ws, &qm.graph, Source::Wiki, 8192,
    )?;
    println!("PeRQ* (INT4, b=32) ppl:   {:.3}", perq_eval.perplexity);

    // the same pipeline without the permutation — the paper's ablation
    let report_np = Pipeline::new(presets::no_permute(32, Format::Int4))
        .run_with_engine(&bundle, &engine)?;
    println!("No-Permute (b=32) ppl:    {:.3}", report_np.perplexity);

    println!(
        "\npermutation recovers {:.0}% of the quantization gap",
        100.0 * (report_np.perplexity - perq_eval.perplexity)
            / (report_np.perplexity - fp.perplexity).max(1e-9)
    );

    // quantize once, serve many: export the already-quantized model as a
    // versioned .perq deployment artifact, reload it, and evaluate without
    // touching any calibration code — the loaded copy scores
    // bit-identically on the native backend.
    let path = std::env::temp_dir().join("perq_quickstart.perq");
    qm.save(&path)?;
    let dm = DeployedModel::load(&path)?;
    let eval = dm.evaluate(Source::Wiki, 8192)?;
    println!(
        "reloaded {} from {} ({:.1} KiB on disk): ppl {:.3}",
        dm.label,
        path.display(),
        std::fs::metadata(&path)?.len() as f64 / 1024.0,
        eval.perplexity
    );
    Ok(())
}
