//! Quickstart: quantize one model with PeRQ* and compare against the
//! full-precision baseline.
//!
//!     cargo run --release --example quickstart
//!
//! Requires `make artifacts` (builds the tiny models + AOT graphs once).

use perq::prelude::*;

fn main() -> anyhow::Result<()> {
    let ctx = RepoContext::discover()?;
    let engine = Engine::new(&ctx)?;
    let bundle = ModelBundle::load_with_engine(&ctx, &engine, "llama_np2")?;
    println!(
        "model {} — {} layers, d_model {}, d_ffn {}, {} params",
        bundle.name,
        bundle.cfg.n_layers,
        bundle.cfg.d_model,
        bundle.cfg.d_ffn,
        bundle.weights.param_count()
    );

    // full-precision reference
    let (fp, _) = baseline_eval(&bundle, &engine, 4096, None)?;
    println!("BF16-analog baseline ppl: {:.3}", fp.perplexity);

    // PeRQ*: MassDiff permutation + QuaRot rotations + block-32 online
    // Hadamard at the down projection + Qronos rounding, INT4 W4A4.
    let spec = presets::perq_star(32, Format::Int4);
    let report = Pipeline::new(spec).run_with_engine(&bundle, &engine)?;
    println!("PeRQ* (INT4, b=32) ppl:   {:.3}", report.perplexity);

    // the same pipeline without the permutation — the paper's ablation
    let report_np = Pipeline::new(presets::no_permute(32, Format::Int4))
        .run_with_engine(&bundle, &engine)?;
    println!("No-Permute (b=32) ppl:    {:.3}", report_np.perplexity);

    println!(
        "\npermutation recovers {:.0}% of the quantization gap",
        100.0 * (report_np.perplexity - report.perplexity)
            / (report_np.perplexity - fp.perplexity).max(1e-9)
    );
    Ok(())
}
