//! Pipeline-composition study (the paper's Table 9): cross Stage-1
//! transforms (MassDiff+QuaRot vs MassDiff+Spin) with Stage-2 rounding
//! (RTN / GPTQ / Qronos) on one model, INT4 b=32.
//!
//!     cargo run --release --example pipeline_composition [model]

use perq::coordinator::spec::RotationSpec;
use perq::prelude::*;
use perq::util::bench::{fmt_ppl, print_table};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(|s| s.as_str()).unwrap_or("llama_np2");
    let ctx = RepoContext::discover()?;
    let engine = Engine::new(&ctx)?;
    let bundle = ModelBundle::load_with_engine(&ctx, &engine, model)?;

    let stage1 = [
        ("MassDiff+QuaRot", RotationSpec::quarot(32)),
        ("MassDiff+Spin", RotationSpec::spin(32)),
    ];
    let stage2 = [Rounding::Rtn, Rounding::Gptq, Rounding::Qronos];

    let mut rows = Vec::new();
    for (s1_name, rot) in stage1 {
        for rounding in stage2 {
            let mut spec = PipelineSpec::default();
            spec.permutation = PermKind::MassDiff;
            spec.rotation = rot;
            spec.rounding = rounding;
            spec.format = Format::Int4;
            spec.eval_tokens = 4096;
            let rep = Pipeline::new(spec).run_with_engine(&bundle, &engine)?;
            println!("{s1_name:<18} + {:<7} ppl {:.3}", rounding.name(), rep.perplexity);
            rows.push((
                format!("{s1_name} + {}", rounding.name()),
                vec![fmt_ppl(rep.perplexity)],
            ));
        }
    }
    print_table(&format!("Table 9 shape — {model} INT4 b=32"), &["ppl"], &rows);
    println!("\n(PeRQ* = MassDiff+QuaRot+Qronos; PeRQ† = MassDiff+Spin+RTN)");
    Ok(())
}
