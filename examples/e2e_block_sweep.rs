//! End-to-end driver (DESIGN.md §headline): run the full PeRQ system —
//! calibration capture through PJRT artifacts, MassDiff permutation
//! calibration, offline rotation/permutation merging, Qronos rounding,
//! and perplexity evaluation on the held-out synthetic corpus — across
//! every exported block size, with and without permutations.
//!
//! This regenerates the *shape* of the paper's Table 1 on the substitute
//! model and reports the headline metric: the fraction of the full-vector
//! rotation gap that permutations recover at each block size.
//!
//!     cargo run --release --example e2e_block_sweep [model] [eval_tokens]

use perq::prelude::*;
use perq::util::bench::{fmt_ppl, print_table};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(|s| s.as_str()).unwrap_or("llama_tiny");
    let eval_tokens: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(8192);

    let ctx = RepoContext::discover()?;
    let engine = Engine::new(&ctx)?;
    let bundle = ModelBundle::load_with_engine(&ctx, &engine, model)?;
    let blocks = bundle.cfg.block_sizes.clone();
    let full = *blocks.iter().max().unwrap();

    let (fp, _) = baseline_eval(&bundle, &engine, eval_tokens, None)?;
    println!("{model}: BF16-analog ppl {:.3} | blocks {blocks:?}", fp.perplexity);

    let mut rows = Vec::new();
    let mut np_ppl = Vec::new();
    let mut pq_ppl = Vec::new();
    for &b in &blocks {
        if b == 1 {
            continue; // b=1 is the no-rotation arm, not part of Table 1
        }
        let mut spec_np = presets::no_permute(b, Format::Int4);
        spec_np.eval_tokens = eval_tokens;
        let r_np = Pipeline::new(spec_np).run_with_engine(&bundle, &engine)?;
        let mut spec_pq = presets::perq_star(b, Format::Int4);
        spec_pq.eval_tokens = eval_tokens;
        let r_pq = Pipeline::new(spec_pq).run_with_engine(&bundle, &engine)?;
        println!(
            "  b={b:<5} no-permute {:>7.3}   PeRQ* {:>7.3}   (mass balance {:.2}x -> {:.2}x)",
            r_np.perplexity, r_pq.perplexity, r_np.mass_balance, r_pq.mass_balance
        );
        np_ppl.push(r_np.perplexity);
        pq_ppl.push(r_pq.perplexity);
        rows.push((
            format!("b={b}"),
            vec![fmt_ppl(r_np.perplexity), fmt_ppl(r_pq.perplexity)],
        ));
    }
    print_table(
        &format!("Table 1 shape — {model} INT4 W4A4 (Qronos)"),
        &["No Permute", "PeRQ*"],
        &rows,
    );

    // headline: recovery of the full-vector gap at the smallest block
    let full_np = *np_ppl.last().unwrap(); // largest block ≈ full-vector
    let small_np = np_ppl[0];
    let small_pq = pq_ppl[0];
    let recovery = 100.0 * (small_np - small_pq) / (small_np - full_np).max(1e-9);
    println!(
        "\nheadline: at the smallest block, PeRQ recovers {recovery:.0}% of the \
         full-vector rotation gap (paper reports up to 90% for Llama3 1B b=16; \
         full-vector ppl here {:.3}, fp {:.3})",
        full_np, fp.perplexity
    );
    let _ = full;
    Ok(())
}
