//! Greedy token generation through the stateful execution model: quantize
//! a model with PeRQ* once, export it as a `.perq` deployment artifact,
//! reload it, and drive **prefill → decode** sessions — the decode-time
//! workload (per-token R̃3 rotation, packed-int8 KV cache) the paper's
//! Appendix A compute argument is about.
//!
//!     cargo run --release --example generate [model] \
//!         [--prompt-tokens 1,2,3] [--max-new N] [--workers W]
//!
//! Two paths are exercised and must agree token-for-token:
//!   * the direct API (`DeployedModel::generate` — one session, one slot);
//!   * the continuous-batching server (`submit_generate` — requests join a
//!     live replica batch at step granularity).
//!
//! `PERQ_KV={int8,f32}` switches the KV-cache storage mode (packed u8
//! codes by default).

use anyhow::Result;
use perq::coordinator::presets;
use perq::coordinator::server::{resolve_max_wait, ServeOptions};
use perq::data::corpus::{token_stream, Split};
use perq::prelude::*;
use perq::util::cli;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cli::parse(&argv);
    let model = args
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "llama_np2".to_string());
    let workers = args.get_usize("workers", 2).max(1);

    // offline: quantize once (synthetic weights stand in on a bare
    // checkout), export, reload — generation runs from the artifact alone
    let bundle = match RepoContext::discover()
        .ok()
        .and_then(|ctx| ModelBundle::load(&ctx, &model).ok())
    {
        Some(b) => b,
        None => {
            println!("(no trained weights found — synthetic {model})");
            ModelBundle::synthetic(&model)?
        }
    };
    let engine = Engine::native_ephemeral();
    // largest standard block that divides this model's d_ffn
    let block = [32usize, 16, 8, 4, 2, 1]
        .into_iter()
        .find(|b| bundle.cfg.d_ffn % b == 0)
        .unwrap_or(1);
    let mut spec = presets::perq_star(block, Format::Int4);
    spec.calib_seqs = 2;
    let qm = Pipeline::new(spec).quantize_with_engine(&bundle, &engine)?;
    let path = std::env::temp_dir().join(format!("generate_{model}.perq"));
    qm.save(&path)?;
    let dm = DeployedModel::load(&path)?;
    let t = dm.cfg.seq_len;
    println!(
        "{} {} — seq_len {t}, KV cache: {}\n",
        dm.model,
        dm.label,
        perq::tensor::KvMode::from_env().name()
    );

    let max_new = args.get_usize("max-new", (t / 2).clamp(1, 16));
    let prompt: Vec<i32> = match args.get("prompt-tokens") {
        Some(s) => s.split(',').filter_map(|x| x.trim().parse().ok()).collect(),
        None => {
            let plen = (t / 4).clamp(1, 8);
            token_stream(Source::Wiki, Split::Test, plen + 1)[..plen]
                .iter()
                .map(|&x| x as i32)
                .collect()
        }
    };
    anyhow::ensure!(
        !prompt.is_empty() && prompt.len() + max_new <= t,
        "prompt ({}) + max_new ({max_new}) must fit in seq_len ({t})",
        prompt.len()
    );

    // path 1: direct single-session generation
    let direct = dm.generate(&prompt, max_new)?;
    let toks: Vec<String> = direct.tokens.iter().map(|t| t.to_string()).collect();
    println!("direct    : {}", toks.join(" "));
    println!(
        "            prefill {:.2}ms | decode {:.2}ms = {:.0} tok/s",
        direct.prefill_s * 1e3,
        direct.decode_s * 1e3,
        direct.decode_tok_per_s()
    );

    // path 2: the continuous-batching server — several concurrent
    // requests (the shared prompt plus varied peers) ride one live batch
    let server = dm.serve(ServeOptions::new(resolve_max_wait(None), workers))?;
    let rx_main = server.submit_generate(prompt.clone(), max_new)?;
    let peers: Vec<_> = (0..3usize)
        .filter_map(|i| {
            let plen = (i % 3) + 1; // 1..=3 token prompts
            let peer: Vec<i32> = (0..plen as i32)
                .map(|x| (x * 3 + i as i32) % dm.cfg.vocab as i32)
                .collect();
            if plen + max_new <= t {
                server.submit_generate(peer, max_new).ok()
            } else {
                None
            }
        })
        .collect();
    // double unwrap: channel intact AND the request actually completed
    // (no cap/deadline configured, so nothing may be rejected here)
    let served = rx_main.recv()??;
    for rx in peers {
        let _ = rx.recv();
    }
    let toks: Vec<String> = served.tokens.iter().map(|t| t.to_string()).collect();
    println!("served    : {}", toks.join(" "));
    println!(
        "            prefill-phase {:.2}ms | decode-phase {:.2}ms",
        served.prefill_latency.as_secs_f64() * 1e3,
        served.decode_latency.as_secs_f64() * 1e3
    );
    anyhow::ensure!(
        served.tokens == direct.tokens,
        "continuous batching must not change greedy tokens"
    );
    let snap = server.snapshot();
    println!(
        "\nserver: {} generations | {} steps (occupancy {:.2}) | decode {:.0} tok/s \
         (prefill {:.3}s / decode {:.3}s)",
        snap.generated, snap.batches, snap.mean_occupancy, snap.decode_tok_per_s,
        snap.prefill_s, snap.decode_s
    );
    server.shutdown();
    println!("\n(co-batched peers and replica count never change greedy output — \
              scoring and sampling are per-slot independent)");
    Ok(())
}
