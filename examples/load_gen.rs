//! Load generator for the HTTP front door: closed- and open-loop request
//! streams against `perq serve --http`, with exponential backoff that
//! honors `Retry-After` on 429/503, exact client-side latency percentiles
//! (sorted samples, not histogram buckets), and a goodput summary appended
//! to `BENCH_serve.json`.
//!
//!     cargo run --release --example load_gen [--addr HOST:PORT] \
//!         [--mode closed|open] [--conns N] [--qps Q] [--duration-ms MS] \
//!         [--seq-len T] [--vocab V] [--workers W] [--queue-cap N] \
//!         [--out FILE]
//!
//! Without `--addr` a tiny synthetic model is served in-process on a free
//! port (so the harness runs anywhere, CI included); `--seq-len`/`--vocab`
//! must match the target model when pointing at an external server, since
//! score requests carry exactly `seq_len + 1` token ids.
//!
//! Closed loop: `--conns` threads each keep one request in flight —
//! throughput finds its own level. Open loop: the same threads pace
//! arrivals at `--qps` regardless of completions — the harness that shows
//! queueing collapse and back-pressure (429/503) instead of hiding them.

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use perq::backend::ForwardGraph;
use perq::coordinator::http::{HttpOptions, HttpServer};
use perq::coordinator::net::client;
use perq::coordinator::server::{InferenceServer, ServeOptions};
use perq::model::bundle::synthetic_weights;
use perq::model::config::ModelConfig;
use perq::quant::{Format, WeightCodec};
use perq::tensor::QuantMat;
use perq::util::bench::TrajectoryRow;
use perq::util::{cli, json};

const CLIENT_TIMEOUT: Duration = Duration::from_secs(30);
const MAX_BACKOFF_MS: u64 = 500;

/// One worker's view of the run.
#[derive(Default)]
struct Tally {
    /// latencies of successful attempts, milliseconds
    lats_ms: Vec<f64>,
    ok: u64,
    /// 429/503 responses (each one backed off and retried)
    backpressure: u64,
    /// non-back-pressure failures: 4xx/5xx or transport errors
    errors: u64,
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cli::parse(&argv);
    let mode = args.get_or("mode", "closed");
    anyhow::ensure!(
        mode == "closed" || mode == "open",
        "--mode must be `closed` or `open`, got {mode:?}"
    );
    let conns = flag_u64(&args, "conns", 4).max(1) as usize;
    let qps = flag_u64(&args, "qps", 50).max(1);
    let duration = Duration::from_millis(flag_u64(&args, "duration-ms", 2_000).max(1));
    let seq_len = flag_u64(&args, "seq-len", 12).max(2) as usize;
    let vocab = flag_u64(&args, "vocab", 8).max(2) as usize;
    let out = args.get_or("out", "BENCH_serve.json");

    // target: an external front door, or an in-process synthetic one
    let mut local: Option<(HttpServer, Arc<InferenceServer>)> = None;
    let addr = match args.get("addr") {
        Some(a) => a.to_string(),
        None => {
            let (http, server, addr) = start_local(&args, seq_len, vocab)?;
            println!("no --addr: serving a synthetic model in-process on {addr}");
            local = Some((http, server));
            addr
        }
    };

    // one request body per worker, varied by worker index (the engine cost
    // is shape-bound, not value-bound, so this is purely cosmetic)
    let bodies: Vec<Vec<u8>> = (0..conns)
        .map(|w| {
            let tokens: Vec<i32> =
                (0..seq_len + 1).map(|i| ((3 * w + i) % vocab) as i32).collect();
            format!("{{\"tokens\":{tokens:?}}}").into_bytes()
        })
        .collect();

    println!(
        "load_gen: mode={mode} conns={conns}{} duration={:.1}s target={addr}",
        if mode == "open" { format!(" qps={qps}") } else { String::new() },
        duration.as_secs_f64()
    );
    let t0 = Instant::now();
    let deadline = t0 + duration;
    let mut handles = Vec::new();
    for (w, body) in bodies.into_iter().enumerate() {
        let addr = addr.clone();
        let mode = mode.clone();
        // each worker paces its share of the open-loop arrival rate
        let gap = Duration::from_secs_f64(conns as f64 / qps as f64);
        handles.push(std::thread::spawn(move || {
            run_worker(&addr, &body, &mode, gap, deadline, w)
        }));
    }
    let mut all = Tally::default();
    for h in handles {
        let t = h.join().expect("worker panicked");
        all.lats_ms.extend(t.lats_ms);
        all.ok += t.ok;
        all.backpressure += t.backpressure;
        all.errors += t.errors;
    }
    let wall = t0.elapsed().as_secs_f64();

    all.lats_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |q: f64| -> f64 {
        if all.lats_ms.is_empty() {
            return 0.0;
        }
        all.lats_ms[((all.lats_ms.len() - 1) as f64 * q) as usize]
    };
    let (p50, p95, p99) = (pct(0.50), pct(0.95), pct(0.99));
    let goodput = all.ok as f64 / wall;
    println!(
        "done in {wall:.2}s: {} ok, {} back-pressured, {} errors | \
         goodput {goodput:.1} req/s | lat p50 {p50:.1}ms p95 {p95:.1}ms p99 {p99:.1}ms",
        all.ok, all.backpressure, all.errors
    );

    // the server's own view, when we own the server
    if let Some((http, _server)) = local {
        let snap = http.stats().snapshot();
        println!(
            "server counters: submitted={} served={} rejected={} \
             deadline_exceeded={} failed={}",
            snap.submitted, snap.served, snap.rejected, snap.deadline_exceeded,
            snap.failed
        );
        http.shutdown();
    }

    TrajectoryRow::new("serve")
        .str_field("mode", &mode)
        .num_field("conns", conns as f64)
        .num_field("target_qps", if mode == "open" { qps as f64 } else { 0.0 })
        .num_field("duration_s", wall)
        .num_field("ok", all.ok as f64)
        .num_field("backpressure", all.backpressure as f64)
        .num_field("errors", all.errors as f64)
        .num_field("goodput_rps", goodput)
        .num_field("p50_ms", p50)
        .num_field("p95_ms", p95)
        .num_field("p99_ms", p99)
        .append_to(Path::new(&out))?;
    println!("appended the run to {out}");
    Ok(())
}

/// One worker: closed loop keeps a single request in flight; open loop
/// paces arrivals on a fixed clock no matter how the last request fared.
fn run_worker(addr: &str, body: &[u8], mode: &str, gap: Duration,
              deadline: Instant, w: usize) -> Tally {
    let mut t = Tally::default();
    let mut next_arrival = Instant::now() + gap.mul_f64((w % 7) as f64 / 7.0);
    while Instant::now() < deadline {
        if mode == "open" {
            let now = Instant::now();
            if now < next_arrival {
                std::thread::sleep(next_arrival - now);
            }
            // fixed schedule: late workers skip sleeping, never re-anchor
            next_arrival += gap;
        }
        let mut backoff = Duration::from_millis(5);
        // one logical request: retry through back-pressure until it lands
        // or the run ends
        loop {
            let attempt = Instant::now();
            if attempt >= deadline {
                break;
            }
            match client::request(addr, "POST", "/v1/score", &[], body, CLIENT_TIMEOUT) {
                Ok(resp) if resp.status == 200 => {
                    t.ok += 1;
                    t.lats_ms.push(attempt.elapsed().as_secs_f64() * 1e3);
                    break;
                }
                Ok(resp) if resp.status == 429 || resp.status == 503 => {
                    t.backpressure += 1;
                    // honor Retry-After when present, otherwise double up
                    let wait = resp
                        .header("retry-after")
                        .and_then(|v| v.parse::<u64>().ok())
                        .map(|s| Duration::from_secs(s).min(Duration::from_millis(MAX_BACKOFF_MS)))
                        .unwrap_or(backoff);
                    std::thread::sleep(wait.min(deadline.saturating_duration_since(Instant::now())));
                    backoff = (backoff * 2).min(Duration::from_millis(MAX_BACKOFF_MS));
                }
                Ok(_) | Err(_) => {
                    t.errors += 1;
                    break;
                }
            }
        }
    }
    t
}

/// Spin up the in-process target: a tiny INT4-packed synthetic model
/// behind the HTTP front door on `127.0.0.1:0`.
fn start_local(args: &cli::Args, seq_len: usize, vocab: usize)
               -> Result<(HttpServer, Arc<InferenceServer>, String)> {
    let j = json::parse(&format!(
        r#"{{"config": {{"name": "load_gen", "n_layers": 1, "d_model": 16,
            "n_heads": 2, "d_ffn": 32, "vocab": {vocab}, "seq_len": {seq_len},
            "batch": 3, "block_sizes": [1, 8]}}}}"#,
    ))?;
    let cfg = ModelConfig::from_meta(&j)?;
    let mut ws = synthetic_weights(&cfg, 21);
    for site in cfg.linear_sites() {
        let w = ws.get(&site.name).clone();
        let codec = WeightCodec::fit(Format::Int4, &w);
        let q = codec.quantize_mat(&w);
        let packed = QuantMat::from_codec(&q, &codec)?;
        ws.set(&site.name, q);
        ws.set_packed(&site.name, packed);
    }
    let graph = ForwardGraph::Merged { r3_block: 8, format: Format::Int4 };
    let mut opts = ServeOptions::new(
        Duration::from_millis(1),
        flag_u64(args, "workers", 1).max(1) as usize,
    );
    let queue_cap = flag_u64(args, "queue-cap", 8) as usize;
    if queue_cap > 0 {
        opts = opts.with_queue_cap(queue_cap);
    }
    let server = Arc::new(InferenceServer::start_native(&cfg, &ws, &graph, opts)?);
    let http = HttpServer::start(Arc::clone(&server), "127.0.0.1:0",
                                 HttpOptions::default())?;
    let addr = http.local_addr().to_string();
    Ok((http, server, addr))
}

/// A `--flag N` that warns on garbage instead of silently using the
/// default (the repo-wide warned-knob pattern).
fn flag_u64(args: &cli::Args, name: &str, default: u64) -> u64 {
    match args.get(name) {
        None => default,
        Some(raw) => match raw.parse::<u64>() {
            Ok(v) => v,
            Err(_) => {
                perq::log_warn!(
                    "--{name} {raw:?} is not a number — using default {default}"
                );
                default
            }
        },
    }
}
