//! Serving driver: quantize a model with PeRQ* **once**, export it as a
//! versioned `.perq` deployment artifact, then stand up the dynamic-
//! batching inference server from the *loaded artifact* (no calibration
//! state crosses the boundary), fire a stream of scoring requests with
//! random arrival gaps, and report latency / throughput per block size —
//! the runtime side of the paper's Appendix A compute argument, plus the
//! analytic rotation op counts for context.
//!
//!     cargo run --release --example serve_requests [model] [n_requests] \
//!         [--backend native|pjrt|auto] [--threads N] [--workers N]
//!
//! With `--backend native` (the default when no HLO artifact tree is
//! found) the whole path — calibration capture, PTQ, serving — runs in
//! pure Rust with zero PJRT/XLA or Python-artifact dependency; if even the
//! trained weights are missing, deterministic synthetic weights are used
//! so the serving path can be exercised anywhere.
//!
//! `--threads N` (or `PERQ_THREADS`) sizes the kernel worker pool;
//! `--workers N` (or `PERQ_SERVER_WORKERS`, default 1) runs that many
//! backend replicas on the shared request queue — NLLs are identical
//! regardless of the replica count (per-slot-independent scoring);
//! `--max-wait-ms MS` (or `PERQ_MAX_WAIT_MS`) bounds the batch-forming
//! wait of idle replicas; `PERQ_SIMD={auto,avx2,neon,scalar}` overrides
//! kernel dispatch. Requests join each replica's live batch at step
//! granularity (continuous batching) — partial steps run fewer rows, so
//! there is no padding anywhere.

use std::time::{Duration, Instant};

use anyhow::Result;
use perq::coordinator::pipeline::{Pipeline, QuantizedModel};
use perq::coordinator::presets;
use perq::coordinator::server::{InferenceServer, ServeOptions};
use perq::data::corpus::{token_stream, Split};
use perq::data::rng::Rng;
use perq::hadamard::opcount;
use perq::prelude::*;
use perq::util::cli;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cli::parse(&argv);
    let model = args
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "llama_np2".to_string());
    let n_requests: usize = args
        .positional
        .get(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(64);
    // pool sizing must precede the first kernel call (lazy global spawn)
    if let Some(raw) = args.get("threads") {
        match raw.parse::<usize>() {
            Ok(n) => perq::util::pool::set_default_parallelism(n),
            Err(_) => perq::log_warn!(
                "--threads {raw:?} is not a lane count — using the default pool size"
            ),
        }
    }
    let num_workers = parse_count(args.get("workers"), "--workers")
        .or_else(|| {
            let env = std::env::var("PERQ_SERVER_WORKERS").ok();
            parse_count(env.as_deref(), "PERQ_SERVER_WORKERS")
        })
        .unwrap_or(1)
        .max(1);

    // Resolve artifacts + backend. Native serving needs neither the XLA
    // toolchain nor `make artifacts`; pjrt needs both.
    let discovered = RepoContext::discover().ok();
    let (engine, bundle) = match &discovered {
        Some(ctx) => {
            let kind = BackendKind::resolve(args.backend(), ctx)?;
            let engine = Engine::with_backend(ctx, kind)?;
            match ModelBundle::load(ctx, &model) {
                Ok(b) => (engine, b),
                Err(e) if kind == BackendKind::Native => {
                    println!("note: {e:#}\n      — falling back to synthetic weights");
                    (engine, ModelBundle::synthetic(&model)?)
                }
                Err(e) => return Err(e),
            }
        }
        None => {
            anyhow::ensure!(
                !matches!(args.backend(), Some("pjrt")),
                "--backend pjrt requires an artifacts/ tree (run `make artifacts`)"
            );
            println!("no artifacts/ tree found — native backend, synthetic weights");
            (Engine::native_ephemeral(), ModelBundle::synthetic(&model)?)
        }
    };
    let cfg = bundle.cfg.clone();
    let t = cfg.seq_len;
    println!("backend: {}  model: {model}\n", engine.backend().name());

    for block in [16usize, 32, cfg.d_ffn] {
        if cfg.d_ffn % block != 0 {
            continue;
        }
        if engine.backend() == BackendKind::Pjrt
            && !bundle.has_artifact(&format!("fwd_quant_b{block}"))
        {
            continue;
        }
        // offline PTQ (PeRQ*, INT4) — capture + rounding on the same backend
        let mut spec = presets::perq_star(block, Format::Int4);
        spec.calib_seqs = 4;
        let qm = Pipeline::new(spec).quantize_with_engine(&bundle, &engine)?;
        // rotation-quality telemetry recorded during calibration — the
        // report `perq export` writes beside the artifact
        println!("    {}", qm.telemetry.summary());

        // bring up the server (one backend replica per worker thread;
        // pjrt keeps device-resident weights, native keeps pooled scratch)
        // --max-wait-ms > PERQ_MAX_WAIT_MS > shared default
        let wait = perq::coordinator::server::resolve_max_wait(
            args.get("max-wait-ms").and_then(|s| match s.parse::<u64>() {
                Ok(v) => Some(v),
                Err(_) => {
                    perq::log_warn!(
                        "--max-wait-ms {s:?} is not a millisecond count — \
                         using PERQ_MAX_WAIT_MS / the default"
                    );
                    None
                }
            }),
        );
        let server =
            start_server(&engine, &bundle, &qm, ServeOptions::new(wait, num_workers))?;

        // request stream: random windows of the test split, random gaps
        let toks = token_stream(Source::Wiki, Split::Test, 1 << 15);
        let mut rng = Rng::new(0x5E44);
        let t0 = Instant::now();
        let mut rxs = Vec::new();
        for _ in 0..n_requests {
            let start = rng.next_below((toks.len() - t - 1) as u64) as usize;
            let window: Vec<i32> = toks[start..start + t + 1].iter().map(|&x| x as i32).collect();
            rxs.push(server.submit(window)?);
            if rng.next_f64() < 0.3 {
                std::thread::sleep(Duration::from_millis(rng.next_below(4)));
            }
        }
        let mut lats: Vec<f64> = Vec::new();
        let mut nll = 0.0;
        for rx in rxs {
            // outer ? = channel intact; inner ? = request actually served
            // (no admission cap or deadline is set here, so every request
            // must complete)
            let resp = rx.recv()??;
            lats.push(resp.latency.as_secs_f64() * 1e3);
            nll += resp.nll;
        }
        let wall = t0.elapsed().as_secs_f64();
        lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p = |q: f64| lats[((lats.len() - 1) as f64 * q) as usize];
        let (_served, batches, exec_s) = server.stats();
        let snap = server.snapshot();
        // server-side histogram percentiles (fixed √2 buckets, atomics)
        let (sp50, sp95, sp99) = server.latency_percentiles();
        let label = if block == cfg.d_ffn { "full".to_string() } else { format!("b={block}") };
        println!(
            "{model} {label:<6} | {n_requests} reqs in {wall:.2}s = {:.0} tok/s | \
             lat p50 {:.0}ms p95 {:.0}ms | hist p50/p95/p99 {sp50:.1}/{sp95:.1}/{sp99:.1}ms | \
             {batches} steps (occupancy {:.2}) | \
             exec {:.2}s | ppl {:.2} | rot ops/token {}",
            n_requests as f64 * t as f64 / wall,
            p(0.5),
            p(0.95),
            snap.mean_occupancy,
            exec_s,
            (nll / n_requests as f64).exp(),
            perq::util::bench::fmt_count(opcount::block_ops(cfg.d_ffn, block)),
        );
        if server.num_workers() > 1 {
            for (w, (ws, wb, wx)) in server.per_worker_stats().into_iter().enumerate() {
                println!("    worker {w}: {ws} served / {wb} batches / exec {wx:.2}s");
            }
        }
        // request-lifecycle traces from the server's ring buffer — the
        // per-request spans `perq serve --metrics-out` dumps as JSON
        let traces = server.recent_traces();
        if let Some(slowest) = traces.iter().max_by(|a, b| a.total_ms.total_cmp(&b.total_ms)) {
            println!(
                "    traces: {} in ring | slowest {} #{}: queued {:.1}ms + \
                 prefill {:.1}ms + decode {:.1}ms = {:.1}ms total",
                traces.len(),
                slowest.kind,
                slowest.id,
                slowest.queued_ms,
                slowest.prefill_ms,
                slowest.decode_ms,
                slowest.total_ms,
            );
        }
        server.shutdown();
    }
    println!(
        "\n(the rotation op-count column is the paper's Appendix A argument: \
         smaller b cuts online rotation compute; at this model scale the \
         end-to-end latency is dominated by the matmuls, as in the paper's \
         2% end-to-end observation)"
    );
    Ok(())
}

/// Parse a worker count, warning (instead of silently ignoring) when the
/// value does not parse — a mistyped `--workers` should not quietly serve
/// on one replica.
fn parse_count(raw: Option<&str>, what: &str) -> Option<usize> {
    let raw = raw?;
    match raw.parse::<usize>() {
        Ok(n) => Some(n),
        Err(_) => {
            perq::log_warn!("{what}={raw:?} is not a worker count — ignoring it");
            None
        }
    }
}

fn start_server(engine: &Engine, bundle: &ModelBundle, qm: &QuantizedModel,
                opts: ServeOptions) -> Result<InferenceServer> {
    match engine.backend() {
        BackendKind::Native => {
            // quantize-once / serve-many: round-trip through the versioned
            // .perq deployment artifact and serve the *loaded* copy — the
            // replicas come up from the file alone, in milliseconds.
            let path = std::env::temp_dir()
                .join(format!("serve_requests_{}_{}.perq", bundle.name, qm.graph.tag()));
            qm.save(&path)?;
            let t0 = Instant::now();
            let dm = perq::deploy::DeployedModel::load(&path)?;
            let num_workers = opts.num_workers;
            let server = InferenceServer::start_deployed(&dm, opts)?;
            println!(
                "    .perq artifact: {:.1} KiB, load + {num_workers} replica(s) \
                 ready in {:.1}ms (no calibration)",
                std::fs::metadata(&path)?.len() as f64 / 1024.0,
                t0.elapsed().as_secs_f64() * 1e3
            );
            Ok(server)
        }
        BackendKind::Pjrt => start_pjrt_server(engine, bundle, qm, opts),
    }
}

#[cfg(feature = "pjrt")]
fn start_pjrt_server(engine: &Engine, bundle: &ModelBundle, qm: &QuantizedModel,
                     opts: ServeOptions) -> Result<InferenceServer> {
    let artifact = engine
        .ctx()
        .model_dir(&bundle.name)
        .join(format!("{}.hlo.txt", qm.eval_tag));
    InferenceServer::start(artifact, &bundle.cfg, &qm.ws, qm.extras.clone(), opts)
}

#[cfg(not(feature = "pjrt"))]
fn start_pjrt_server(_engine: &Engine, _bundle: &ModelBundle, _qm: &QuantizedModel,
                     _opts: ServeOptions) -> Result<InferenceServer> {
    anyhow::bail!("the pjrt backend is not compiled in (rebuild with `--features pjrt`)")
}
