//! Minimal leveled logging facade (the `log` crate is unavailable
//! offline): `PERQ_LOG={error,warn,info,debug}` selects the maximum level
//! (default `info`; setting the legacy `PERQ_TRACE` variable without
//! `PERQ_LOG` promotes to `debug`, preserving the old pipeline tracing
//! switch). Messages go to stderr as `[perq LEVEL] ...`, keeping stdout
//! clean for CLI results and JSON.
//!
//! Use through the crate-root macros — the level gate runs *before* the
//! format arguments are evaluated, so disabled sites cost one relaxed
//! enum compare:
//!
//! ```ignore
//! crate::log_warn!("server: score prefill failed: {e:#}");
//! crate::log_debug!("[{stage}] {ms:.1} ms");
//! ```

use std::sync::OnceLock;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" | "trace" => Some(Level::Debug),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
        }
    }
}

/// The active maximum level, resolved from the environment once per
/// process (first use wins; later env changes are not observed).
pub fn max_level() -> Level {
    static MAX: OnceLock<Level> = OnceLock::new();
    *MAX.get_or_init(|| match std::env::var("PERQ_LOG") {
        Ok(s) => Level::parse(&s).unwrap_or(Level::Info),
        Err(_) if std::env::var("PERQ_TRACE").is_ok() => Level::Debug,
        Err(_) => Level::Info,
    })
}

pub fn enabled(level: Level) -> bool {
    level <= max_level()
}

/// Emit one line. Callers go through the `log_*!` macros, which gate on
/// [`enabled`] first.
pub fn emit(level: Level, args: std::fmt::Arguments<'_>) {
    eprintln!("[perq {}] {args}", level.tag());
}

#[macro_export]
macro_rules! log_error {
    ($($a:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Error) {
            $crate::obs::log::emit($crate::obs::log::Level::Error, format_args!($($a)*));
        }
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($a:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Warn) {
            $crate::obs::log::emit($crate::obs::log::Level::Warn, format_args!($($a)*));
        }
    };
}

#[macro_export]
macro_rules! log_info {
    ($($a:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Info) {
            $crate::obs::log::emit($crate::obs::log::Level::Info, format_args!($($a)*));
        }
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($a:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Debug) {
            $crate::obs::log::emit($crate::obs::log::Level::Debug, format_args!($($a)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing_and_ordering() {
        assert_eq!(Level::parse("error"), Some(Level::Error));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse(" info "), Some(Level::Info));
        assert_eq!(Level::parse("trace"), Some(Level::Debug));
        assert_eq!(Level::parse("verbose"), None);
        assert!(Level::Error < Level::Debug, "lower levels are more severe");
    }

    #[test]
    fn macros_expand_without_panicking() {
        // max_level() is process-cached, so this only checks the plumbing
        crate::log_error!("test error {}", 1);
        crate::log_debug!("test debug {}", 2);
        assert!(enabled(Level::Error), "error is never filtered out");
    }
}
