//! Unified observability layer: metrics, request tracing, rotation-quality
//! telemetry, and leveled logging.
//!
//!   * [`metrics`] — named atomic counters/gauges/√2-bucket histograms in
//!     a [`metrics::Registry`], with a Prometheus text renderer and a JSON
//!     snapshot. Handles are resolved once and recorded through relaxed
//!     atomics, so the decode hot loop stays lock- and allocation-free.
//!   * [`trace`] — per-request lifecycle spans (enqueue → admit → prefill
//!     → decode → complete) in a lock-light ring buffer.
//!   * [`telemetry`] — the calibration-time rotation-quality report
//!     (blockwise ℓ1 mass imbalance pre/post permutation, post-rotation
//!     max|x| and kurtosis, per-site quantization MSE).
//!   * [`log`] — `PERQ_LOG`-leveled stderr logging behind the crate-root
//!     `log_error!`/`log_warn!`/`log_info!`/`log_debug!` macros.
//!
//! Every consumer-facing surface renders through one pair of methods —
//! `ServerStats::render_prometheus_full` (server registry + process-wide
//! engine registry in one exposition) and its JSON twin
//! `snapshot_json_full`. `GET /metrics`, the periodic `--metrics-out`
//! writer, and the exit-time flush guard all call those two, so scrape
//! and dump output can never drift apart.

pub mod log;
pub mod metrics;
pub mod telemetry;
pub mod trace;
