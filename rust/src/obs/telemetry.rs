//! Rotation-quality telemetry: the calibration-time statistics the paper's
//! argument actually rests on, recorded instead of discarded.
//!
//! Three families, gathered by `Pipeline::quantize_with_engine`:
//!   * per-layer blockwise ℓ1 **mass imbalance** before vs after the
//!     MassDiff permutation — `max_block_mass / mass_lower_bound`, the
//!     quantity the greedy mass-diffusion pass equalizes (1.0 = perfectly
//!     balanced blocks);
//!   * per-layer post-rotation **outlier shape** — max|x| and kurtosis of
//!     the rotated calibration activations (kurtosis 3 = Gaussian; block
//!     rotations should pull heavy-tailed activations toward it);
//!   * per-site weight **quantization MSE** — mean squared error between
//!     each quantized site and its float reference.
//!
//! The assembled [`RotationReport`] rides on `QuantizedModel`, is written
//! beside the `.perq` artifact by `perq export` (see
//! `deploy::telemetry_path`), and is printed by `perq models` /
//! `perq inspect`.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{self, Json};

/// Rotation/permutation quality for one layer's down-projection input.
#[derive(Clone, Debug)]
pub struct LayerRotationStats {
    pub layer: usize,
    /// max blockwise ℓ1 mass under the identity ordering
    pub pre_max_block_mass: f64,
    /// max blockwise ℓ1 mass under the calibrated permutation
    pub post_max_block_mass: f64,
    /// ideal (perfectly balanced) blockwise mass — the LPT lower bound
    pub mass_lower_bound: f64,
    /// max |x| of the calibration activations after the R̃3 rotation
    pub post_rot_absmax: f64,
    /// kurtosis (m4/m2², Gaussian = 3) after the R̃3 rotation
    pub post_rot_kurtosis: f64,
}

impl LayerRotationStats {
    /// Imbalance ratio before permutation (≥ 1.0; 1.0 = balanced).
    pub fn pre_imbalance(&self) -> f64 {
        ratio(self.pre_max_block_mass, self.mass_lower_bound)
    }

    /// Imbalance ratio after permutation. MassDiff should pull this at or
    /// below [`LayerRotationStats::pre_imbalance`], toward 1.0.
    pub fn post_imbalance(&self) -> f64 {
        ratio(self.post_max_block_mass, self.mass_lower_bound)
    }
}

fn ratio(num: f64, den: f64) -> f64 {
    if den > 0.0 { num / den } else { 1.0 }
}

/// Quantization error for one weight site.
#[derive(Clone, Debug)]
pub struct SiteQuantStats {
    pub name: String,
    /// mean((w - quantize(w))²) over the site's elements
    pub mse: f64,
}

/// The structured calibration-telemetry report.
#[derive(Clone, Debug, Default)]
pub struct RotationReport {
    pub model: String,
    pub label: String,
    pub r3_block: usize,
    pub calib_tokens: usize,
    pub layers: Vec<LayerRotationStats>,
    pub sites: Vec<SiteQuantStats>,
}

impl RotationReport {
    /// Mean pre/post imbalance ratio across layers: > 1.0 means the
    /// permutation reduced the worst block's ℓ1 mass by that factor.
    pub fn mean_mass_improvement(&self) -> f64 {
        if self.layers.is_empty() {
            return 1.0;
        }
        let s: f64 = self
            .layers
            .iter()
            .map(|l| ratio(l.pre_imbalance(), l.post_imbalance()))
            .sum();
        s / self.layers.len() as f64
    }

    pub fn mean_site_mse(&self) -> f64 {
        if self.sites.is_empty() {
            return 0.0;
        }
        self.sites.iter().map(|s| s.mse).sum::<f64>() / self.sites.len() as f64
    }

    /// One-line summary for `perq models`.
    pub fn summary(&self) -> String {
        format!(
            "telemetry: {} layers, mass imbalance {:.3}→{:.3} ({:.2}x), {} sites, mean mse {:.3e}",
            self.layers.len(),
            mean(self.layers.iter().map(|l| l.pre_imbalance())),
            mean(self.layers.iter().map(|l| l.post_imbalance())),
            self.mean_mass_improvement(),
            self.sites.len(),
            self.mean_site_mse(),
        )
    }

    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("model".to_string(), Json::Str(self.model.clone()));
        o.insert("label".to_string(), Json::Str(self.label.clone()));
        o.insert("r3_block".to_string(), Json::Num(self.r3_block as f64));
        o.insert("calib_tokens".to_string(), Json::Num(self.calib_tokens as f64));
        let layers = self
            .layers
            .iter()
            .map(|l| {
                let mut m = BTreeMap::new();
                m.insert("layer".to_string(), Json::Num(l.layer as f64));
                m.insert("pre_max_block_mass".to_string(), Json::Num(l.pre_max_block_mass));
                m.insert("post_max_block_mass".to_string(), Json::Num(l.post_max_block_mass));
                m.insert("mass_lower_bound".to_string(), Json::Num(l.mass_lower_bound));
                m.insert("pre_imbalance".to_string(), Json::Num(l.pre_imbalance()));
                m.insert("post_imbalance".to_string(), Json::Num(l.post_imbalance()));
                m.insert("post_rot_absmax".to_string(), Json::Num(l.post_rot_absmax));
                m.insert("post_rot_kurtosis".to_string(), Json::Num(l.post_rot_kurtosis));
                Json::Obj(m)
            })
            .collect();
        o.insert("layers".to_string(), Json::Arr(layers));
        let sites = self
            .sites
            .iter()
            .map(|s| {
                let mut m = BTreeMap::new();
                m.insert("name".to_string(), Json::Str(s.name.clone()));
                m.insert("mse".to_string(), Json::Num(s.mse));
                Json::Obj(m)
            })
            .collect();
        o.insert("sites".to_string(), Json::Arr(sites));
        o.insert(
            "mean_mass_improvement".to_string(),
            Json::Num(self.mean_mass_improvement()),
        );
        Json::Obj(o)
    }

    pub fn from_json(j: &Json) -> Result<RotationReport> {
        let str_of = |k: &str| -> String {
            j.get(k).and_then(|v| v.as_str()).unwrap_or_default().to_string()
        };
        let num_of = |k: &str| j.get(k).and_then(|v| v.as_usize()).unwrap_or(0);
        let mut layers = Vec::new();
        for l in j.get("layers").and_then(|v| v.as_arr()).unwrap_or(&[]) {
            let f = |k: &str| l.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
            layers.push(LayerRotationStats {
                layer: l.get("layer").and_then(|v| v.as_usize()).unwrap_or(0),
                pre_max_block_mass: f("pre_max_block_mass"),
                post_max_block_mass: f("post_max_block_mass"),
                mass_lower_bound: f("mass_lower_bound"),
                post_rot_absmax: f("post_rot_absmax"),
                post_rot_kurtosis: f("post_rot_kurtosis"),
            });
        }
        let mut sites = Vec::new();
        for s in j.get("sites").and_then(|v| v.as_arr()).unwrap_or(&[]) {
            sites.push(SiteQuantStats {
                name: s.get("name").and_then(|v| v.as_str()).unwrap_or_default().to_string(),
                mse: s.get("mse").and_then(|v| v.as_f64()).unwrap_or(0.0),
            });
        }
        Ok(RotationReport {
            model: str_of("model"),
            label: str_of("label"),
            r3_block: num_of("r3_block"),
            calib_tokens: num_of("calib_tokens"),
            layers,
            sites,
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, json::dump(&self.to_json()))
            .with_context(|| format!("writing telemetry report {path:?}"))
    }

    pub fn load(path: &Path) -> Result<RotationReport> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading telemetry report {path:?}"))?;
        RotationReport::from_json(&json::parse(&text)?)
    }
}

fn mean(it: impl Iterator<Item = f64>) -> f64 {
    let (mut s, mut n) = (0.0f64, 0usize);
    for x in it {
        s += x;
        n += 1;
    }
    if n > 0 { s / n as f64 } else { 0.0 }
}

/// max|x| and kurtosis (m4/m2², Gaussian = 3) of a sample. Kurtosis is
/// 0.0 for degenerate samples (fewer than 2 values or zero variance).
pub fn absmax_and_kurtosis(xs: &[f32]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let n = xs.len() as f64;
    let mut absmax = 0.0f64;
    let mut sum = 0.0f64;
    for &x in xs {
        absmax = absmax.max((x as f64).abs());
        sum += x as f64;
    }
    let mu = sum / n;
    let (mut m2, mut m4) = (0.0f64, 0.0f64);
    for &x in xs {
        let d = x as f64 - mu;
        let d2 = d * d;
        m2 += d2;
        m4 += d2 * d2;
    }
    m2 /= n;
    m4 /= n;
    let kurt = if xs.len() >= 2 && m2 > 0.0 { m4 / (m2 * m2) } else { 0.0 };
    (absmax, kurt)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> RotationReport {
        RotationReport {
            model: "m".to_string(),
            label: "massdiff+r3".to_string(),
            r3_block: 16,
            calib_tokens: 128,
            layers: vec![LayerRotationStats {
                layer: 0,
                pre_max_block_mass: 2.0,
                post_max_block_mass: 1.2,
                mass_lower_bound: 1.0,
                post_rot_absmax: 0.7,
                post_rot_kurtosis: 3.1,
            }],
            sites: vec![SiteQuantStats { name: "l0.down".to_string(), mse: 1.5e-4 }],
        }
    }

    #[test]
    fn imbalance_ratios_and_improvement() {
        let r = report();
        let l = &r.layers[0];
        assert!((l.pre_imbalance() - 2.0).abs() < 1e-12);
        assert!((l.post_imbalance() - 1.2).abs() < 1e-12);
        assert!((r.mean_mass_improvement() - 2.0 / 1.2).abs() < 1e-12);
        assert!(r.summary().contains("1 layers"), "{}", r.summary());
    }

    #[test]
    fn json_round_trip() {
        let r = report();
        let dumped = json::dump(&r.to_json());
        let back = RotationReport::from_json(&json::parse(&dumped).unwrap()).unwrap();
        assert_eq!(back.model, "m");
        assert_eq!(back.r3_block, 16);
        assert_eq!(back.layers.len(), 1);
        assert!((back.layers[0].post_rot_kurtosis - 3.1).abs() < 1e-12);
        assert!((back.sites[0].mse - 1.5e-4).abs() < 1e-18);
        // derived fields are recomputed, not trusted from the file
        assert!((back.mean_mass_improvement() - r.mean_mass_improvement()).abs() < 1e-12);
    }

    #[test]
    fn kurtosis_of_known_shapes() {
        // constant sample: zero variance → 0.0 sentinel
        assert_eq!(absmax_and_kurtosis(&[2.0; 8]).1, 0.0);
        // symmetric two-point mass {-1, +1}: kurtosis = 1 (sub-Gaussian)
        let (amax, k) = absmax_and_kurtosis(&[1.0, -1.0, 1.0, -1.0]);
        assert_eq!(amax, 1.0);
        assert!((k - 1.0).abs() < 1e-12, "{k}");
        // one huge outlier among small values → heavy-tailed, k >> 3
        let mut xs = vec![0.01f32; 63];
        xs.push(10.0);
        assert!(absmax_and_kurtosis(&xs).1 > 10.0);
    }
}
