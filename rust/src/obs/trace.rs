//! Request-lifecycle tracing: per-request IDs with span timings through
//! enqueue → admit → prefill → per-token decode → complete.
//!
//! A request's span stamps travel *with* the request (plain `Instant`
//! fields on the queue entry — no shared state while the request is in
//! flight), and the finished [`RequestTrace`] is pushed into a lock-light
//! ring buffer: an atomic cursor picks the slot, and each slot has its own
//! mutex, so concurrent completions from different replicas contend only
//! when they hash to the same slot. [`Tracer::recent_traces`] drains a
//! coherent copy for `perq serve --metrics-out` and the examples.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::json::Json;

/// The completed lifecycle of one request, in span durations. Per-token
/// decode timing is not stored per request (that would allocate in the
/// hot loop) — `decode_steps` plus the server's decode-step histogram
/// recover the per-token distribution.
#[derive(Clone, Debug)]
pub struct RequestTrace {
    /// Monotone per-server request ID, assigned at submit time.
    pub id: u64,
    /// "score" or "generate".
    pub kind: &'static str,
    /// enqueue → admitted by a replica
    pub queued_ms: f64,
    /// admitted → prefill complete (first token sampled, for generate)
    pub prefill_ms: f64,
    /// prefill complete → generation complete (0 for score requests)
    pub decode_ms: f64,
    /// enqueue → response sent
    pub total_ms: f64,
    /// decode steps this request rode (tokens after the first)
    pub decode_steps: u64,
    /// false when the request did not complete (see `outcome`)
    pub ok: bool,
    /// terminal state: "completed", "queue_full", "shed",
    /// "deadline_exceeded", "worker_failed", or "shutting_down" —
    /// mirrors the `ServeError` kind the client received
    pub outcome: &'static str,
}

impl RequestTrace {
    pub fn to_json(&self) -> Json {
        let mut o = std::collections::BTreeMap::new();
        o.insert("id".to_string(), Json::Num(self.id as f64));
        o.insert("kind".to_string(), Json::Str(self.kind.to_string()));
        o.insert("queued_ms".to_string(), Json::Num(self.queued_ms));
        o.insert("prefill_ms".to_string(), Json::Num(self.prefill_ms));
        o.insert("decode_ms".to_string(), Json::Num(self.decode_ms));
        o.insert("total_ms".to_string(), Json::Num(self.total_ms));
        o.insert("decode_steps".to_string(), Json::Num(self.decode_steps as f64));
        o.insert("ok".to_string(), Json::Bool(self.ok));
        o.insert("outcome".to_string(), Json::Str(self.outcome.to_string()));
        Json::Obj(o)
    }
}

/// Fixed-capacity ring of completed request traces.
pub struct Tracer {
    next_id: AtomicU64,
    cursor: AtomicU64,
    slots: Vec<Mutex<Option<RequestTrace>>>,
}

impl Tracer {
    pub fn new(capacity: usize) -> Tracer {
        let capacity = capacity.max(1);
        Tracer {
            next_id: AtomicU64::new(1),
            cursor: AtomicU64::new(0),
            slots: (0..capacity).map(|_| Mutex::new(None)).collect(),
        }
    }

    /// Allocate the next request ID (1-based, monotone per tracer).
    pub fn next_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Push a completed trace, evicting the oldest once full.
    pub fn record(&self, trace: RequestTrace) {
        let i = (self.cursor.fetch_add(1, Ordering::Relaxed) % self.slots.len() as u64) as usize;
        *self.slots[i].lock().unwrap() = Some(trace);
    }

    /// Completed traces currently in the ring, oldest first (by request
    /// ID — completion order and ID order can differ under batching).
    pub fn recent_traces(&self) -> Vec<RequestTrace> {
        let mut out: Vec<RequestTrace> = self
            .slots
            .iter()
            .filter_map(|s| s.lock().unwrap().clone())
            .collect();
        out.sort_by_key(|t| t.id);
        out
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(self.recent_traces().iter().map(|t| t.to_json()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(id: u64) -> RequestTrace {
        RequestTrace {
            id,
            kind: "score",
            queued_ms: 0.1,
            prefill_ms: 0.2,
            decode_ms: 0.0,
            total_ms: 0.3,
            decode_steps: 0,
            ok: true,
            outcome: "completed",
        }
    }

    #[test]
    fn ring_keeps_newest_and_orders_by_id() {
        let tr = Tracer::new(4);
        for id in [3u64, 1, 2, 5, 4, 6] {
            tr.record(t(id));
        }
        let got: Vec<u64> = tr.recent_traces().iter().map(|x| x.id).collect();
        // capacity 4: the first two records (ids 3, 1) were evicted
        assert_eq!(got, vec![2, 4, 5, 6]);
    }

    #[test]
    fn ids_are_monotone_and_json_dumps() {
        let tr = Tracer::new(2);
        assert_eq!(tr.next_id(), 1);
        assert_eq!(tr.next_id(), 2);
        tr.record(t(1));
        let j = crate::util::json::dump(&tr.to_json());
        assert!(j.contains("\"kind\":\"score\""), "{j}");
        assert!(j.contains("\"ok\":true"), "{j}");
        assert!(j.contains("\"outcome\":\"completed\""), "{j}");
    }
}
