//! Metrics registry: named atomic counters, gauges, and √2-bucket
//! histograms, cheap enough for the decode hot loop.
//!
//! Handles ([`Counter`], [`Gauge`], [`Hist`]) are plain atomics behind an
//! `Arc` — callers resolve them from a [`Registry`] **once** (at server or
//! backend construction) and then record through the handle with a single
//! relaxed atomic op: no locks, no lookups, no allocation on the hot path.
//! The registry itself is only locked at registration and render time.
//!
//! Two rendering surfaces:
//!   * [`Registry::render_prometheus`] — Prometheus text exposition format
//!     (`# HELP`/`# TYPE` + samples; histograms as cumulative `le` buckets
//!     with `_sum`/`_count`), for `perq serve --metrics-out`;
//!   * [`Registry::snapshot_json`] — a deterministic [`Json`] object
//!     (BTreeMap key order) for machine-readable dumps.
//!
//! Per-process engine metrics (the native backend's decode/prefill
//! counters) live in the [`global`] registry; each [`InferenceServer`]
//! owns its own registry so concurrently running servers (tests spin up
//! many) never mix counts.
//!
//! [`InferenceServer`]: crate::coordinator::server::InferenceServer

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

use crate::util::json::Json;

/// Number of √2-spaced histogram buckets: 1 µs · 2^(i/2) spans 1 µs to
/// ≈ 35 min, far beyond any request this server can see.
pub const HIST_BUCKETS: usize = 64;

/// Geometric midpoint multiplier of a √2-wide bucket: 2^(1/4).
const GEO_MID: f64 = 1.189_207_115_002_721_1;

/// Monotone named counter. One relaxed `fetch_add` per record.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous signed value (queue depth, active slots).
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Fixed-bucket latency histogram over atomics — recordable from every
/// worker thread without locks, readable while the server runs. Buckets
/// are √2-spaced in microseconds, so a reported percentile is within ~19%
/// of the true value (the geometric-mid representative). Out-of-range
/// samples clamp into the edge buckets (so `count` always equals the
/// number of records); clamps past the top are additionally tallied in a
/// saturation counter instead of disappearing silently, and a percentile
/// that lands among saturated samples reports the top bucket's *lower
/// bound* (the tightest claim the histogram can actually support) rather
/// than a midpoint it has no evidence for.
#[derive(Debug)]
pub struct Hist {
    buckets: Vec<AtomicU64>,
    saturated: AtomicU64,
    sum_ns: AtomicU64,
}

impl Default for Hist {
    fn default() -> Self {
        Hist {
            buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            saturated: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }
}

impl Hist {
    /// Raw (unclamped) bucket index of a nanosecond latency.
    fn bucket(ns: u64) -> usize {
        let us = (ns / 1_000).max(1);
        let l = 63 - us.leading_zeros() as u64; // floor(log2 µs)
        let half = if l > 0 && (us & (1 << (l - 1))) != 0 { 1 } else { 0 };
        (2 * l + half) as usize
    }

    /// Lower bound of bucket `i` in microseconds: 2^l · (1 + h/2) for
    /// i = 2l + h. `bucket_lower_us(HIST_BUCKETS)` is the top bucket's
    /// nominal upper edge.
    pub fn bucket_lower_us(i: usize) -> f64 {
        let l = (i / 2) as f64;
        let half = (i % 2) as f64;
        (2.0f64).powf(l) * (1.0 + 0.5 * half)
    }

    /// Record one duration. Samples past the top bucket land in the last
    /// bucket *and* bump the saturation counter.
    pub fn record(&self, lat: Duration) {
        self.record_ns(lat.as_nanos() as u64);
    }

    /// Record one latency in nanoseconds (the hot-loop entry point: two
    /// relaxed `fetch_add`s and integer bit-math, nothing else).
    pub fn record_ns(&self, ns: u64) {
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        let idx = Hist::bucket(ns);
        if idx >= HIST_BUCKETS {
            self.saturated.fetch_add(1, Ordering::Relaxed);
            self.buckets[HIST_BUCKETS - 1].fetch_add(1, Ordering::Relaxed);
        } else {
            self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Total recorded samples (clamped records included).
    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Records that overflowed the top bucket and were clamped into it.
    pub fn saturated(&self) -> u64 {
        self.saturated.load(Ordering::Relaxed)
    }

    /// Sum of recorded durations in seconds.
    pub fn sum_s(&self) -> f64 {
        self.sum_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// The q-quantile (0 < q ≤ 1) in milliseconds, or 0.0 with no samples.
    pub fn percentile(&self, q: f64) -> f64 {
        let counts: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        percentile_ms(&counts, self.saturated(), q)
    }

    /// One coherent copy of the bucket counts (each bucket is read once;
    /// concurrent records may straddle the read, as with any lock-free
    /// snapshot).
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            saturated: self.saturated(),
            sum_ns: self.sum_ns.load(Ordering::Relaxed),
        }
    }
}

/// The q-quantile of a √2-bucket count vector in milliseconds. Returns the
/// geometric midpoint of the bucket holding the rank — except at the top
/// bucket when saturation occurred, where the midpoint would fabricate
/// precision for samples that only clamped there: the bucket **lower
/// bound** is reported instead (a floor the data actually supports).
fn percentile_ms(counts: &[u64], saturated: u64, q: f64) -> f64 {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
    let mut seen = 0u64;
    for (i, &c) in counts.iter().enumerate() {
        seen += c;
        if seen >= rank {
            let lower_us = Hist::bucket_lower_us(i);
            if i == HIST_BUCKETS - 1 && saturated > 0 {
                return lower_us / 1_000.0;
            }
            return lower_us * GEO_MID / 1_000.0;
        }
    }
    0.0
}

/// An owned, mergeable copy of a [`Hist`]'s state. Merging is exact bucket
/// addition, so it is associative and commutative — per-shard histograms
/// can be combined in any order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistSnapshot {
    pub buckets: Vec<u64>,
    pub saturated: u64,
    pub sum_ns: u64,
}

impl HistSnapshot {
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    pub fn percentile(&self, q: f64) -> f64 {
        percentile_ms(&self.buckets, self.saturated, q)
    }

    /// Elementwise sum of two snapshots.
    pub fn merge(&self, other: &HistSnapshot) -> HistSnapshot {
        HistSnapshot {
            buckets: self
                .buckets
                .iter()
                .zip(&other.buckets)
                .map(|(a, b)| a + b)
                .collect(),
            saturated: self.saturated + other.saturated,
            sum_ns: self.sum_ns + other.sum_ns,
        }
    }
}

/// A named metrics registry. Registration is get-or-create (re-registering
/// a name returns the existing handle); rendering walks the sorted name
/// maps, so output is deterministic for a given state.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, (String, Arc<Counter>)>>,
    gauges: Mutex<BTreeMap<String, (String, Arc<Gauge>)>>,
    hists: Mutex<BTreeMap<String, (String, Arc<Hist>)>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get-or-create the counter `name`. The handle stays valid (and keeps
    /// feeding this registry) for as long as the caller holds it.
    pub fn counter(&self, name: &str, help: &str) -> Arc<Counter> {
        let mut m = self.counters.lock().unwrap();
        Arc::clone(
            &m.entry(name.to_string())
                .or_insert_with(|| (help.to_string(), Arc::new(Counter::default())))
                .1,
        )
    }

    pub fn gauge(&self, name: &str, help: &str) -> Arc<Gauge> {
        let mut m = self.gauges.lock().unwrap();
        Arc::clone(
            &m.entry(name.to_string())
                .or_insert_with(|| (help.to_string(), Arc::new(Gauge::default())))
                .1,
        )
    }

    pub fn hist(&self, name: &str, help: &str) -> Arc<Hist> {
        let mut m = self.hists.lock().unwrap();
        Arc::clone(
            &m.entry(name.to_string())
                .or_insert_with(|| (help.to_string(), Arc::new(Hist::default())))
                .1,
        )
    }

    /// Prometheus text exposition format: `# HELP`/`# TYPE` per metric,
    /// histograms as cumulative `le` buckets (upper edges in seconds) plus
    /// `_sum`/`_count`, and the saturation tally as a companion counter.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        for (name, (help, c)) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} counter\n"));
            out.push_str(&format!("{name} {}\n", c.get()));
        }
        for (name, (help, g)) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} gauge\n"));
            out.push_str(&format!("{name} {}\n", g.get()));
        }
        for (name, (help, h)) in self.hists.lock().unwrap().iter() {
            let snap = h.snapshot();
            out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} histogram\n"));
            let mut cum = 0u64;
            for (i, &c) in snap.buckets.iter().enumerate() {
                cum += c;
                // skip interior empty buckets to keep the dump readable;
                // cumulative counts stay exact because `cum` carries on
                if c == 0 && i + 1 < HIST_BUCKETS {
                    continue;
                }
                if i + 1 < HIST_BUCKETS {
                    let le = Hist::bucket_lower_us(i + 1) * 1e-6;
                    out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {cum}\n"));
                }
            }
            out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {cum}\n"));
            out.push_str(&format!("{name}_sum {}\n", snap.sum_ns as f64 / 1e9));
            out.push_str(&format!("{name}_count {cum}\n"));
            out.push_str(&format!(
                "# HELP {name}_saturated_total samples clamped into the top bucket\n\
                 # TYPE {name}_saturated_total counter\n\
                 {name}_saturated_total {}\n",
                snap.saturated
            ));
        }
        out
    }

    /// Deterministic JSON snapshot:
    /// `{"counters": {..}, "gauges": {..}, "hists": {name: {count,
    /// saturated, sum_ms, p50_ms, p95_ms, p99_ms}}}`.
    pub fn snapshot_json(&self) -> Json {
        let mut counters = BTreeMap::new();
        for (name, (_, c)) in self.counters.lock().unwrap().iter() {
            counters.insert(name.clone(), Json::Num(c.get() as f64));
        }
        let mut gauges = BTreeMap::new();
        for (name, (_, g)) in self.gauges.lock().unwrap().iter() {
            gauges.insert(name.clone(), Json::Num(g.get() as f64));
        }
        let mut hists = BTreeMap::new();
        for (name, (_, h)) in self.hists.lock().unwrap().iter() {
            let snap = h.snapshot();
            let mut o = BTreeMap::new();
            o.insert("count".to_string(), Json::Num(snap.count() as f64));
            o.insert("saturated".to_string(), Json::Num(snap.saturated as f64));
            o.insert("sum_ms".to_string(), Json::Num(snap.sum_ns as f64 / 1e6));
            o.insert("p50_ms".to_string(), Json::Num(snap.percentile(0.50)));
            o.insert("p95_ms".to_string(), Json::Num(snap.percentile(0.95)));
            o.insert("p99_ms".to_string(), Json::Num(snap.percentile(0.99)));
            hists.insert(name.clone(), Json::Obj(o));
        }
        let mut top = BTreeMap::new();
        top.insert("counters".to_string(), Json::Obj(counters));
        top.insert("gauges".to_string(), Json::Obj(gauges));
        top.insert("hists".to_string(), Json::Obj(hists));
        Json::Obj(top)
    }
}

/// The process-wide registry: engine-level metrics (native backend decode
/// and prefill counters) that are not tied to one server instance.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    //! Concurrency, bucket-boundary, merge, and determinism coverage lives
    //! in rust/tests/obs_props.rs (its own binary, so it can also own a
    //! counting global allocator for the zero-alloc decode assertion).
    //! These are shape checks only.

    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let r = Registry::new();
        let c = r.counter("x_total", "a counter");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // re-registering the same name returns the same handle
        assert_eq!(r.counter("x_total", "a counter").get(), 5);
        let g = r.gauge("depth", "a gauge");
        g.set(7);
        g.add(-3);
        assert_eq!(g.get(), 4);
    }

    #[test]
    fn prometheus_render_contains_all_families() {
        let r = Registry::new();
        r.counter("served_total", "requests").add(3);
        r.gauge("queue_depth", "pending").set(2);
        r.hist("lat_seconds", "latency").record(Duration::from_micros(250));
        let text = r.render_prometheus();
        assert!(text.contains("# TYPE served_total counter"), "{text}");
        assert!(text.contains("served_total 3"), "{text}");
        assert!(text.contains("# TYPE queue_depth gauge"), "{text}");
        assert!(text.contains("# TYPE lat_seconds histogram"), "{text}");
        assert!(text.contains("lat_seconds_bucket{le=\"+Inf\"} 1"), "{text}");
        assert!(text.contains("lat_seconds_count 1"), "{text}");
    }

    #[test]
    fn hist_snapshot_round_trip() {
        let h = Hist::default();
        h.record(Duration::from_micros(10));
        h.record(Duration::from_micros(10_000));
        let snap = h.snapshot();
        assert_eq!(snap.count(), 2);
        assert_eq!(snap.saturated, 0);
        assert!((snap.percentile(0.5) - h.percentile(0.5)).abs() < 1e-12);
    }
}
