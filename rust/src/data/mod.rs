//! Data substrate: the deterministic synthetic corpus (rust twin of
//! python/compile/corpus.py, bit-identical by construction and enforced by
//! the `corpus_golden.bin` cross-test) plus the byte-level tokenizer.

pub mod corpus;
pub mod rng;
