//! xorshift64* — twin of `corpus.Rng` in python/compile/corpus.py.
//! Both twins use only u64 integer ops and the (x >> 11) * 2^-53 float
//! derivation, so streams are bit-identical across languages.

#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let s = seed ^ 0x9E37_79B9_7F4A_7C15;
        Rng { state: if s == 0 { 0xDEAD_BEEF_CAFE_F00D } else { s } }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in [0, 1) with 53 bits — IEEE-exact across the twins.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }

    /// Standard normal via Box-Muller (rust-side use only — never in the
    /// cross-language corpus path).
    pub fn next_normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(12345);
        let mut b = Rng::new(12345);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn floats_in_range_and_centered() {
        let mut r = Rng::new(99);
        let fs: Vec<f64> = (0..1000).map(|_| r.next_f64()).collect();
        assert!(fs.iter().all(|&f| (0.0..1.0).contains(&f)));
        let mean = fs.iter().sum::<f64>() / fs.len() as f64;
        assert!((0.4..0.6).contains(&mean));
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let xs: Vec<f64> = (0..20000).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
