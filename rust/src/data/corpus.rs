//! Synthetic corpus generator — bit-identical rust twin of
//! python/compile/corpus.py (see that file for the determinism rules).
//! Sources `wiki`/`c4`/`fineweb` stand in for WikiText2/C4/FineWeb
//! (DESIGN.md §3); identity with the python stream is enforced against
//! `artifacts/corpus_golden.bin` in the integration tests.

use super::rng::Rng;

pub const CHARSET: &[u8] = b"abcdefghijklmnopqrstuvwxyz .,\n";
pub const VOCAB_SIZE: usize = 32;
pub const NUM_WORDS: usize = 512;
const TRAIN_CHARS: usize = 1 << 18;

const SYLLABLES: [&str; 50] = [
    "ba", "be", "bi", "bo", "bu", "da", "de", "di", "do", "du",
    "ka", "ke", "ki", "ko", "ku", "la", "le", "li", "lo", "lu",
    "ma", "me", "mi", "mo", "mu", "na", "ne", "ni", "no", "nu",
    "ra", "re", "ri", "ro", "ru", "sa", "se", "si", "so", "su",
    "ta", "te", "ti", "to", "tu", "va", "ve", "vi", "vo", "vu",
];

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Source {
    Wiki,
    C4,
    Fineweb,
}

impl Source {
    pub fn all() -> [Source; 3] {
        [Source::Wiki, Source::C4, Source::Fineweb]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Source::Wiki => "wiki",
            Source::C4 => "c4",
            Source::Fineweb => "fineweb",
        }
    }

    pub fn parse(s: &str) -> Option<Source> {
        match s {
            "wiki" => Some(Source::Wiki),
            "c4" => Some(Source::C4),
            "fineweb" => Some(Source::Fineweb),
            _ => None,
        }
    }

    fn spec(&self) -> SourceSpec {
        match self {
            Source::Wiki => SourceSpec {
                seed: 0x00C0_FFEE,
                bigram_weight: 0.5,
                min_sentence: 4,
                max_sentence: 12,
                comma_prob: 0.10,
            },
            Source::C4 => SourceSpec {
                seed: 0x00BE_EF01,
                bigram_weight: 0.3,
                min_sentence: 3,
                max_sentence: 9,
                comma_prob: 0.05,
            },
            Source::Fineweb => SourceSpec {
                seed: 0x00FA_CADE,
                bigram_weight: 0.7,
                min_sentence: 5,
                max_sentence: 15,
                comma_prob: 0.15,
            },
        }
    }
}

#[derive(Clone, Copy, Debug)]
struct SourceSpec {
    seed: u64,
    bigram_weight: f64,
    min_sentence: u64,
    max_sentence: u64,
    comma_prob: f64,
}

pub fn build_vocabulary() -> Vec<String> {
    let mut rng = Rng::new(0x5EED_0001);
    let mut words = Vec::with_capacity(NUM_WORDS);
    for _ in 0..NUM_WORDS {
        let n_syll = 1 + rng.next_below(3);
        let mut w = String::new();
        for _ in 0..n_syll {
            w.push_str(SYLLABLES[rng.next_below(SYLLABLES.len() as u64) as usize]);
        }
        words.push(w);
    }
    words
}

pub struct CorpusGenerator {
    spec: SourceSpec,
    rng: Rng,
    words: Vec<String>,
    cum: Vec<f64>,
    total: f64,
    prev: usize,
}

impl CorpusGenerator {
    pub fn new(source: Source) -> Self {
        let spec = source.spec();
        let mut cum = Vec::with_capacity(NUM_WORDS);
        let mut total = 0.0f64;
        for r in 0..NUM_WORDS {
            total += 1.0 / (r + 1) as f64;
            cum.push(total);
        }
        CorpusGenerator {
            spec,
            rng: Rng::new(spec.seed),
            words: build_vocabulary(),
            cum,
            total,
            prev: 0,
        }
    }

    fn zipf_word(&mut self) -> usize {
        let u = self.rng.next_f64() * self.total;
        let (mut lo, mut hi) = (0usize, NUM_WORDS - 1);
        while lo < hi {
            let mid = (lo + hi) / 2;
            if self.cum[mid] < u {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        lo
    }

    fn next_word(&mut self) -> usize {
        let w = if self.rng.next_f64() < self.spec.bigram_weight {
            (self.prev * 31 + 17) % NUM_WORDS
        } else {
            self.zipf_word()
        };
        self.prev = w;
        w
    }

    fn sentence(&mut self) -> String {
        let spec = self.spec;
        let n = spec.min_sentence
            + self.rng.next_below(spec.max_sentence - spec.min_sentence + 1);
        let mut parts: Vec<String> = Vec::new();
        for i in 0..n {
            let w = self.next_word();
            parts.push(self.words[w].clone());
            if i + 1 < n && self.rng.next_f64() < spec.comma_prob {
                parts.push(",".to_string());
            }
        }
        let mut s = parts.join(" ").replace(" ,", ",");
        s.push('.');
        s
    }

    pub fn text(&mut self, n_chars: usize) -> String {
        let mut out = String::with_capacity(n_chars + 64);
        let mut sent_in_par = 0;
        while out.len() < n_chars {
            let s = self.sentence();
            out.push_str(&s);
            sent_in_par += 1;
            if sent_in_par == 5 {
                out.push('\n');
                sent_in_par = 0;
            } else {
                out.push(' ');
            }
        }
        out.truncate(n_chars);
        out
    }
}

pub fn char_to_id(c: u8) -> Option<u16> {
    CHARSET.iter().position(|&x| x == c).map(|p| p as u16)
}

pub fn tokenize(text: &str) -> Vec<u16> {
    text.bytes()
        .map(|c| char_to_id(c).unwrap_or_else(|| panic!("untokenizable byte {c}")))
        .collect()
}

pub fn detokenize(ids: &[u16]) -> String {
    ids.iter().map(|&i| CHARSET[i as usize] as char).collect()
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Test,
}

/// Token ids for a (source, split); twin of `corpus.token_stream`.
pub fn token_stream(source: Source, split: Split, n_tokens: usize) -> Vec<u16> {
    let mut gen = CorpusGenerator::new(source);
    match split {
        Split::Train => tokenize(&gen.text(n_tokens)),
        Split::Test => {
            let _ = gen.text(TRAIN_CHARS); // advance past the train region
            tokenize(&gen.text(n_tokens))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let a = token_stream(Source::Wiki, Split::Train, 1024);
        let b = token_stream(Source::Wiki, Split::Train, 1024);
        assert_eq!(a, b);
    }

    #[test]
    fn sources_and_splits_differ() {
        let w = token_stream(Source::Wiki, Split::Train, 512);
        let c = token_stream(Source::C4, Split::Train, 512);
        let t = token_stream(Source::Wiki, Split::Test, 512);
        assert_ne!(w, c);
        assert_ne!(w, t);
    }

    #[test]
    fn tokens_in_range() {
        let toks = token_stream(Source::Fineweb, Split::Train, 4096);
        assert!(toks.iter().all(|&t| (t as usize) < VOCAB_SIZE));
    }

    #[test]
    fn tokenize_roundtrip() {
        let s = "hello world, this is a test.\n";
        assert_eq!(detokenize(&tokenize(s)), s);
    }

    #[test]
    fn vocabulary_is_stable() {
        let v1 = build_vocabulary();
        let v2 = build_vocabulary();
        assert_eq!(v1, v2);
        assert_eq!(v1.len(), NUM_WORDS);
    }

    #[test]
    fn prefix_property() {
        // a longer stream extends a shorter one (same generator state path)
        let short = token_stream(Source::Wiki, Split::Train, 256);
        let long = token_stream(Source::Wiki, Split::Train, 1024);
        assert_eq!(&long[..256], &short[..]);
    }

    #[test]
    fn char_distribution_nonuniform() {
        let toks = token_stream(Source::Wiki, Split::Train, 1 << 15);
        let mut counts = [0usize; VOCAB_SIZE];
        for &t in &toks {
            counts[t as usize] += 1;
        }
        let n = toks.len() as f64;
        let entropy: f64 = counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / n;
                -p * p.ln()
            })
            .sum();
        assert!(entropy < (VOCAB_SIZE as f64).ln() * 0.95);
    }
}
