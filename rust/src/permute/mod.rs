//! Permutation substrate — the paper's Section 4.
//!
//! * `massdiff` — Algorithm 1: greedy mass diffusion equalizing the expected
//!   per-block ℓ1 norm over a calibration set (the PeRQ permutation).
//! * `baselines` — Identity / Random / Absmax / ZigZag (Lin et al. 2024a),
//!   the alternatives of Table 6.
//! * Permutations are `Vec<usize>` in "gather" convention:
//!   `y[j] = x[perm[j]]`, matching `Mat::permute_cols`.

pub mod baselines;
pub mod massdiff;

pub use baselines::{absmax_perm, identity_perm, random_perm, zigzag_perm};
pub use massdiff::massdiff_perm;


/// Permutation strategies evaluated in the paper (Table 6).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PermKind {
    Identity,
    Random,
    Absmax,
    ZigZag,
    MassDiff,
}

impl PermKind {
    pub fn name(&self) -> &'static str {
        match self {
            PermKind::Identity => "identity",
            PermKind::Random => "random",
            PermKind::Absmax => "absmax",
            PermKind::ZigZag => "zigzag",
            PermKind::MassDiff => "massdiff",
        }
    }

    pub fn parse(s: &str) -> Option<PermKind> {
        match s {
            "identity" | "none" => Some(PermKind::Identity),
            "random" => Some(PermKind::Random),
            "absmax" => Some(PermKind::Absmax),
            "zigzag" => Some(PermKind::ZigZag),
            "massdiff" => Some(PermKind::MassDiff),
            _ => None,
        }
    }

    /// Calibrate a permutation of dimension d for block size b from
    /// per-coordinate calibration statistics (see `CalibStats`).
    pub fn calibrate(&self, stats: &CalibStats, b: usize, seed: u64) -> Vec<usize> {
        match self {
            PermKind::Identity => identity_perm(stats.d),
            PermKind::Random => random_perm(stats.d, seed),
            PermKind::Absmax => absmax_perm(&stats.absmax),
            PermKind::ZigZag => zigzag_perm(&stats.absmax, b),
            PermKind::MassDiff => massdiff_perm(&stats.mean_abs, b),
        }
    }
}

/// Per-coordinate calibration statistics consumed by the permutation
/// calibrators: E|X_i| (MassDiff's objective) and max|X_i| (Absmax/ZigZag).
#[derive(Clone, Debug)]
pub struct CalibStats {
    pub d: usize,
    /// (1/m) Σ_k |X_i^{(k)}| per coordinate.
    pub mean_abs: Vec<f64>,
    /// max_k |X_i^{(k)}| per coordinate.
    pub absmax: Vec<f64>,
}

impl CalibStats {
    pub fn from_activations(rows: &[&[f32]]) -> CalibStats {
        assert!(!rows.is_empty());
        let d = rows[0].len();
        let mut mean_abs = vec![0.0f64; d];
        let mut absmax = vec![0.0f64; d];
        for row in rows {
            assert_eq!(row.len(), d);
            for (i, &v) in row.iter().enumerate() {
                let a = v.abs() as f64;
                mean_abs[i] += a;
                if a > absmax[i] {
                    absmax[i] = a;
                }
            }
        }
        let m = rows.len() as f64;
        for v in &mut mean_abs {
            *v /= m;
        }
        CalibStats { d, mean_abs, absmax }
    }

    pub fn from_mat(m: &crate::tensor::Mat) -> CalibStats {
        let rows: Vec<&[f32]> = (0..m.rows).map(|i| m.row(i)).collect();
        CalibStats::from_activations(&rows)
    }
}

/// Verify `perm` is a valid permutation of 0..d.
pub fn is_permutation(perm: &[usize]) -> bool {
    let d = perm.len();
    let mut seen = vec![false; d];
    for &p in perm {
        if p >= d || seen[p] {
            return false;
        }
        seen[p] = true;
    }
    true
}

/// Inverse permutation: if y = x[perm], then x = y[inv].
pub fn invert(perm: &[usize]) -> Vec<usize> {
    let mut inv = vec![0usize; perm.len()];
    for (j, &p) in perm.iter().enumerate() {
        inv[p] = j;
    }
    inv
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn invert_roundtrip() {
        let perm = vec![3usize, 0, 4, 1, 2];
        let inv = invert(&perm);
        let x: Vec<i32> = vec![10, 11, 12, 13, 14];
        let y: Vec<i32> = perm.iter().map(|&p| x[p]).collect();
        let back: Vec<i32> = inv.iter().map(|&p| y[p]).collect();
        assert_eq!(back, x);
    }

    #[test]
    fn is_permutation_detects_dupes() {
        assert!(is_permutation(&[2, 0, 1]));
        assert!(!is_permutation(&[2, 0, 2]));
        assert!(!is_permutation(&[3, 0, 1]));
    }

    #[test]
    fn calib_stats_basic() {
        let a: Vec<f32> = vec![1.0, -2.0, 0.0];
        let b: Vec<f32> = vec![-3.0, 2.0, 1.0];
        let s = CalibStats::from_activations(&[&a, &b]);
        assert_eq!(s.mean_abs, vec![2.0, 2.0, 0.5]);
        assert_eq!(s.absmax, vec![3.0, 2.0, 1.0]);
    }

    #[test]
    fn all_kinds_produce_valid_perms() {
        let mut rng = crate::data::rng::Rng::new(1);
        let rows: Vec<Vec<f32>> = (0..8)
            .map(|_| (0..64).map(|_| rng.next_normal() as f32).collect())
            .collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let stats = CalibStats::from_activations(&refs);
        for kind in [
            PermKind::Identity,
            PermKind::Random,
            PermKind::Absmax,
            PermKind::ZigZag,
            PermKind::MassDiff,
        ] {
            let p = kind.calibrate(&stats, 16, 7);
            assert!(is_permutation(&p), "{kind:?}");
        }
    }
}
