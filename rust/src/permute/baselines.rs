//! Baseline permutation strategies (paper Table 6): Identity, Random,
//! Absmax (descending max-magnitude order), and ZigZag (Lin et al. 2024a,
//! DuQuant) — boustrophedon assignment of magnitude-sorted coordinates.

use crate::data::rng::Rng;

pub fn identity_perm(d: usize) -> Vec<usize> {
    (0..d).collect()
}

pub fn random_perm(d: usize, seed: u64) -> Vec<usize> {
    let mut rng = Rng::new(seed);
    let mut p: Vec<usize> = (0..d).collect();
    // Fisher-Yates
    for i in (1..d).rev() {
        let j = rng.next_below((i + 1) as u64) as usize;
        p.swap(i, j);
    }
    p
}

fn argsort_desc(vals: &[f64]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..vals.len()).collect();
    idx.sort_by(|&a, &b| {
        vals[b]
            .partial_cmp(&vals[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    idx
}

/// Absmax: coordinates in descending order of max |X_i| over calibration.
pub fn absmax_perm(absmax: &[f64]) -> Vec<usize> {
    argsort_desc(absmax)
}

/// ZigZag (DuQuant): sort by descending magnitude, then deal coordinates to
/// blocks in a serpentine pattern (block 0..n-1, then n-1..0, ...) so each
/// block receives an alternating mix of large and small coordinates.
pub fn zigzag_perm(absmax: &[f64], b: usize) -> Vec<usize> {
    let d = absmax.len();
    assert!(d % b == 0, "block {b} must divide dim {d}");
    let n = d / b;
    let order = argsort_desc(absmax);
    let mut blocks: Vec<Vec<usize>> = vec![Vec::with_capacity(b); n];
    let mut fwd = true;
    let mut pos = 0usize;
    for &i in &order {
        blocks[pos].push(i);
        if fwd {
            if pos + 1 == n {
                fwd = false;
            } else {
                pos += 1;
            }
        } else if pos == 0 {
            fwd = true;
        } else {
            pos -= 1;
        }
    }
    blocks.into_iter().flatten().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::permute::is_permutation;

    #[test]
    fn identity_is_identity() {
        assert_eq!(identity_perm(5), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn random_valid_and_seeded() {
        let a = random_perm(100, 1);
        let b = random_perm(100, 1);
        let c = random_perm(100, 2);
        assert!(is_permutation(&a));
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn absmax_sorts_descending() {
        let vals = vec![1.0, 5.0, 3.0, 2.0];
        assert_eq!(absmax_perm(&vals), vec![1, 2, 3, 0]);
    }

    #[test]
    fn zigzag_valid() {
        let mut rng = crate::data::rng::Rng::new(9);
        let vals: Vec<f64> = (0..96).map(|_| rng.next_f64()).collect();
        let p = zigzag_perm(&vals, 16);
        assert!(is_permutation(&p));
    }

    #[test]
    fn zigzag_spreads_top_coordinates() {
        // top-n coordinates land in n distinct blocks (first forward sweep)
        let d = 64;
        let b = 16;
        let n = d / b;
        let vals: Vec<f64> = (0..d).map(|i| (d - i) as f64).collect();
        let p = zigzag_perm(&vals, b);
        let mut block_of = vec![0usize; d];
        for (pos, &i) in p.iter().enumerate() {
            block_of[i] = pos / b;
        }
        let mut first: Vec<usize> = (0..n).map(|i| block_of[i]).collect();
        first.sort_unstable();
        assert_eq!(first, (0..n).collect::<Vec<_>>());
    }

    #[test]
    fn zigzag_serpentine_second_sweep_reverses() {
        let d = 8;
        let b = 2; // 4 blocks
        let vals: Vec<f64> = (0..d).map(|i| (d - i) as f64).collect();
        // sorted order = 0,1,2,...; sweep: blocks 0,1,2,3 then 3,2,1,0
        let p = zigzag_perm(&vals, b);
        let mut block_of = vec![0usize; d];
        for (pos, &i) in p.iter().enumerate() {
            block_of[i] = pos / b;
        }
        assert_eq!(&block_of[..4], &[0, 1, 2, 3]);
        assert_eq!(&block_of[4..], &[3, 2, 1, 0]);
    }
}
