//! MassDiff (Algorithm 1): greedy mass diffusion.
//!
//! Sort coordinates by descending average magnitude; assign each to the
//! block whose running average ℓ1 mass is smallest; close blocks when full.
//! The result minimizes (greedily) E[max_j ‖X_{B_j}‖₁] — exactly the bound
//! of Proposition 3.2 that governs worst-case post-rotation outliers.
//!
//! Complexity: O(d log d) for the sort + O(d log n) for the block selection
//! via a binary heap — well under the paper's "two minutes for Llama3 8B"
//! budget (sub-millisecond at d = 14336; see benches/perf_hotpaths.rs).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Heap entry: (mass, block). BinaryHeap is a max-heap, so order is
/// reversed to pop the *least-loaded* block first.
struct BlockLoad {
    mass: f64,
    block: usize,
    filled: usize,
}

impl PartialEq for BlockLoad {
    fn eq(&self, other: &Self) -> bool {
        self.mass == other.mass && self.block == other.block
    }
}
impl Eq for BlockLoad {}
impl PartialOrd for BlockLoad {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for BlockLoad {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: smallest mass first; tie-break on block index for
        // determinism (python twin uses argmin which picks the lowest index)
        other
            .mass
            .partial_cmp(&self.mass)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.block.cmp(&self.block))
    }
}

/// Algorithm 1. `mean_abs[i]` = (1/m) Σ_k |X_i^{(k)}| over the calibration
/// set; `b` = block size. Returns the gather permutation: output coordinate
/// j reads input coordinate perm[j], blocks laid out contiguously.
pub fn massdiff_perm(mean_abs: &[f64], b: usize) -> Vec<usize> {
    let d = mean_abs.len();
    assert!(d % b == 0, "block {b} must divide dim {d}");
    let n = d / b;
    // argsort by descending mean |X_i| (stable: ties by index)
    let mut order: Vec<usize> = (0..d).collect();
    order.sort_by(|&a, &c| {
        mean_abs[c]
            .partial_cmp(&mean_abs[a])
            .unwrap_or(Ordering::Equal)
            .then(a.cmp(&c))
    });
    let mut heap: BinaryHeap<BlockLoad> = (0..n)
        .map(|j| BlockLoad { mass: 0.0, block: j, filled: 0 })
        .collect();
    let mut blocks: Vec<Vec<usize>> = vec![Vec::with_capacity(b); n];
    for &i in &order {
        let mut top = heap.pop().expect("a block is always open");
        blocks[top.block].push(i);
        top.mass += mean_abs[i];
        top.filled += 1;
        if top.filled < b {
            heap.push(top);
        }
    }
    blocks.into_iter().flatten().collect()
}

/// The objective MassDiff minimizes: max_j Σ_{i ∈ B_j} mean_abs[i] for the
/// blocking induced by `perm` (contiguous b-blocks of the permuted order).
pub fn max_block_mass(mean_abs: &[f64], perm: &[usize], b: usize) -> f64 {
    perm.chunks(b)
        .map(|blk| blk.iter().map(|&i| mean_abs[i]).sum::<f64>())
        .fold(0.0, f64::max)
}

/// The theoretical lower bound on max-block-mass: total mass / n blocks.
pub fn mass_lower_bound(mean_abs: &[f64], b: usize) -> f64 {
    let n = mean_abs.len() / b;
    mean_abs.iter().sum::<f64>() / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::permute::{identity_perm, is_permutation};

    fn rand_masses(d: usize, seed: u64) -> Vec<f64> {
        let mut rng = crate::data::rng::Rng::new(seed);
        (0..d).map(|_| rng.next_f64() + 0.01).collect()
    }

    #[test]
    fn produces_valid_permutation() {
        let m = rand_masses(128, 1);
        let p = massdiff_perm(&m, 16);
        assert!(is_permutation(&p));
    }

    #[test]
    fn improves_over_identity_on_sorted_mass() {
        // adversarial input: mass concentrated in the first block
        let mut m = vec![0.01f64; 64];
        for i in 0..8 {
            m[i] = 10.0;
        }
        let p = massdiff_perm(&m, 8);
        let ident = identity_perm(64);
        assert!(
            max_block_mass(&m, &p, 8) < max_block_mass(&m, &ident, 8) / 4.0
        );
    }

    #[test]
    fn near_lower_bound_on_random_input() {
        // the paper: MassDiff drives 77-100% of tokens within 1% of the limit
        let m = rand_masses(1024, 2);
        let p = massdiff_perm(&m, 32);
        let got = max_block_mass(&m, &p, 32);
        let lb = mass_lower_bound(&m, 32);
        assert!(got <= lb * 1.02, "got {got} vs lb {lb}");
    }

    #[test]
    fn exact_on_uniform_mass() {
        let m = vec![1.0f64; 96];
        let p = massdiff_perm(&m, 12);
        let got = max_block_mass(&m, &p, 12);
        assert!((got - 12.0).abs() < 1e-9);
    }

    #[test]
    fn block_size_d_is_identity_objective() {
        // one block: any permutation has the same mass; must still be valid
        let m = rand_masses(64, 3);
        let p = massdiff_perm(&m, 64);
        assert!(is_permutation(&p));
        assert!((max_block_mass(&m, &p, 64) - m.iter().sum::<f64>()).abs() < 1e-9);
    }

    #[test]
    fn deterministic() {
        let m = rand_masses(256, 4);
        assert_eq!(massdiff_perm(&m, 16), massdiff_perm(&m, 16));
    }

    #[test]
    fn largest_coordinates_spread_across_blocks() {
        let mut m = vec![0.1f64; 64];
        m[0] = 5.0;
        m[1] = 5.0;
        m[2] = 5.0;
        m[3] = 5.0;
        let p = massdiff_perm(&m, 16);
        // the 4 heavy coordinates must land in 4 distinct blocks
        let block_of: Vec<usize> = {
            let mut v = vec![0usize; 64];
            for (pos, &i) in p.iter().enumerate() {
                v[i] = pos / 16;
            }
            v
        };
        let mut blocks = [block_of[0], block_of[1], block_of[2], block_of[3]];
        blocks.sort_unstable();
        assert_eq!(blocks, [0, 1, 2, 3]);
    }
}
