//! Qronos-style rounding (Zhang et al. 2026) — documented substitution.
//!
//! The original Qronos "corrects the past by shaping the future"; its exact
//! update rules are specified in a concurrent paper we reproduce only via
//! the host paper's Appendix B: (i) damping λ = α·σ₁ with α = 1e-3, (ii)
//! descending-diagonal order, (iii) it consistently improves on GPTQ.
//!
//! Our implementation honors all three: a GPTQ pass (with the Qronos
//! damping rule) followed by K sweeps of exact coordinate-descent
//! re-optimization on the quantized solution — for each coordinate i the
//! grid point minimizing the quadratic proxy loss given all *current*
//! other coordinates ("correcting the past") is re-selected, which
//! monotonically decreases tr((W−Q)ᵀH(W−Q)). See DESIGN.md §3.

use crate::quant::WeightCodec;
use crate::tensor::linalg::SymMat;
use crate::tensor::Mat;

use super::gptq::gptq_ordered;
use super::{desc_diag_order, permute_sym};

const CD_SWEEPS: usize = 3;

/// Damping per Appendix B: λ = 1e-3 · σ₁(H).
pub fn damp_qronos(h: &mut SymMat) {
    let sigma1 = h.max_eigenvalue(60);
    h.add_diag((1e-3 * sigma1).max(1e-10));
}

/// Incremental coordinate-descent state: per output channel (transposed
/// layout), the error e = w − q and its image He are maintained across
/// sweeps, so each coordinate visit is O(1) and each *accepted* change is
/// O(n) — vs the naive O(n) per visit (§Perf: ~2.5× on the wd sites).
struct CdState {
    n: usize,
    e_t: Vec<f64>,  // (cols, n)
    he_t: Vec<f64>, // (cols, n): He per channel
}

impl CdState {
    fn new(w: &Mat, q: &Mat, h: &SymMat) -> CdState {
        let n = w.rows;
        let cols = w.cols;
        let mut e_t = vec![0.0f64; cols * n];
        for i in 0..n {
            for c in 0..cols {
                e_t[c * n + i] = (w.at(i, c) - q.at(i, c)) as f64;
            }
        }
        let mut he_t = vec![0.0f64; cols * n];
        for c in 0..cols {
            let e = &e_t[c * n..(c + 1) * n];
            let he = &mut he_t[c * n..(c + 1) * n];
            for i in 0..n {
                let ei = e[i];
                if ei == 0.0 {
                    continue;
                }
                let hrow = &h.data[i * n..(i + 1) * n];
                for j in 0..n {
                    he[j] += hrow[j] * ei;
                }
            }
        }
        CdState { n, e_t, he_t }
    }
}

/// One coordinate-descent sweep over all coordinates (ordered space).
/// Returns the number of coordinates whose quantized value changed.
fn cd_sweep(w: &Mat, q: &mut Mat, codec: &WeightCodec, h: &SymMat,
            order: &[usize], state: &mut CdState) -> usize {
    let n = w.rows;
    let cols = w.cols;
    let mut changed = 0usize;
    for i in 0..n {
        let hii = h.at(i, i);
        if hii <= 0.0 {
            continue;
        }
        let hrow = &h.data[i * n..(i + 1) * n];
        let orig_row = order[i];
        for c in 0..cols {
            let he_i = state.he_t[c * n + i];
            // exact 1-D minimizer over the continuous line, then snap to grid:
            // q_i* = Q( q_i + (He)_i / H_ii )
            let target = q.at(i, c) as f64 + he_i / hii;
            let new_q = codec.quantize_entry(orig_row, c, target as f32);
            let old_q = q.at(i, c);
            if (new_q - old_q).abs() > 1e-12 {
                // accept only if the quadratic strictly decreases:
                // Δ = H_ii/2·δ² + (He)_i·δ with δ = old_q − new_q
                let delta = (old_q - new_q) as f64; // e_i increases by delta
                let obj_change = hii * delta * delta / 2.0 + he_i * delta;
                if obj_change < -1e-15 {
                    *q.at_mut(i, c) = new_q;
                    state.e_t[c * n + i] += delta;
                    let he = &mut state.he_t[c * n..(c + 1) * n];
                    for j in 0..n {
                        he[j] += hrow[j] * delta;
                    }
                    changed += 1;
                }
            }
        }
    }
    let _ = state.n;
    changed
}

/// Full Qronos-style solve.
pub fn qronos(w: &Mat, codec: &WeightCodec, gram: &SymMat) -> Mat {
    assert_eq!(w.rows, gram.n);
    let mut h = gram.clone();
    damp_qronos(&mut h);
    let order = desc_diag_order(&h);
    let hp = permute_sym(&h, &order);
    let u = super::gptq::solve_factor(&hp);
    let w_ord = w.permute_rows(&order);
    // pass 1: GPTQ with Qronos damping
    let mut q_ord = gptq_ordered(&w_ord, codec, &u, &order);
    // pass 2: coordinate-descent correction sweeps against the *undamped*
    // Gram (the objective that matters); acceptance is strict-decrease, so
    // this pass is monotone in the true proxy loss.
    let gram_ord = permute_sym(gram, &order);
    let mut state = CdState::new(&w_ord, &q_ord, &gram_ord);
    for _ in 0..CD_SWEEPS {
        let changed = cd_sweep(&w_ord, &mut q_ord, codec, &gram_ord, &order, &mut state);
        if changed == 0 {
            break;
        }
    }
    let inv = crate::permute::invert(&order);
    q_ord.permute_rows(&inv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Format;
    use crate::rounding::proxy_loss;

    fn correlated_problem(d: usize, t: usize, seed: u64) -> (Mat, SymMat) {
        let mut rng = crate::data::rng::Rng::new(seed);
        let w = Mat::from_fn(d, 8, |_, _| rng.next_normal() as f32 * 0.2);
        let mut h = SymMat::zeros(d);
        let mut x = vec![0.0f32; t * d];
        for r in 0..t {
            let c0 = rng.next_normal() as f32;
            for j in 0..d {
                x[r * d + j] = rng.next_normal() as f32 + 0.8 * c0;
            }
        }
        h.accumulate_gram(&x, t);
        (w, h)
    }

    #[test]
    fn cd_sweeps_monotone() {
        let (w, h) = correlated_problem(32, 128, 1);
        let codec = WeightCodec::fit(Format::Int4, &w);
        let mut hd = h.clone();
        damp_qronos(&mut hd);
        let order = desc_diag_order(&hd);
        let hp = permute_sym(&hd, &order);
        let w_ord = w.permute_rows(&order);
        let mut q = codec.quantize_mat(&w_ord);
        let mut state = CdState::new(&w_ord, &q, &hp);
        let mut prev = proxy_loss(&w_ord, &q, &hp);
        for _ in 0..4 {
            cd_sweep(&w_ord, &mut q, &codec, &hp, &order, &mut state);
            let cur = proxy_loss(&w_ord, &q, &hp);
            assert!(cur <= prev + 1e-9);
            prev = cur;
        }
    }

    #[test]
    fn qronos_on_grid() {
        let (w, h) = correlated_problem(24, 96, 2);
        let codec = WeightCodec::fit(Format::Int4, &w);
        let q = qronos(&w, &codec, &h);
        let q2 = codec.quantize_mat(&q);
        for (a, b) in q.data.iter().zip(&q2.data) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn damping_uses_sigma1() {
        let (_, h) = correlated_problem(16, 64, 3);
        let sigma1 = h.max_eigenvalue(100);
        let mut hd = h.clone();
        damp_qronos(&mut hd);
        for i in 0..16 {
            let added = hd.at(i, i) - h.at(i, i);
            assert!((added - 1e-3 * sigma1).abs() / added < 0.05);
        }
    }
}
