//! Stage-2 rounding algorithms (Fig 2): RTN, GPTQ (OPTQ, Frantar et al.
//! 2023) and a Qronos-style corrector (Zhang et al. 2026).
//!
//! All solvers minimize the layerwise proxy loss
//!     tr( (W − Q)ᵀ H (W − Q) ),  H = X̃ᵀX̃ + λI,
//! where X̃ are the *transformed* (permuted, rotated, fake-quantized)
//! calibration activations — matching Appendix B, including the damping
//! rules (GPTQ: λ = 1% of mean diag; Qronos: λ = 1e-3·σ₁) and the
//! descending-diagonal processing order.

pub mod gptq;
pub mod qronos;


use crate::quant::WeightCodec;
use crate::tensor::linalg::SymMat;
use crate::tensor::Mat;

/// Rounding algorithm selector (paper Tables 1-2, 9).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Rounding {
    Rtn,
    Gptq,
    Qronos,
}

impl Rounding {
    pub fn name(&self) -> &'static str {
        match self {
            Rounding::Rtn => "rtn",
            Rounding::Gptq => "gptq",
            Rounding::Qronos => "qronos",
        }
    }

    pub fn parse(s: &str) -> Option<Rounding> {
        match s {
            "rtn" => Some(Rounding::Rtn),
            "gptq" => Some(Rounding::Gptq),
            "qronos" => Some(Rounding::Qronos),
            _ => None,
        }
    }

    /// Round weight matrix `w` (d_in × d_out) through `codec`, using the
    /// Gram matrix `gram` = X̃ᵀX̃ accumulated from calibration activations
    /// (ignored for RTN).
    pub fn round(&self, w: &Mat, codec: &WeightCodec, gram: Option<&SymMat>) -> Mat {
        match self {
            Rounding::Rtn => codec.quantize_mat(w),
            Rounding::Gptq => match gram {
                Some(h) => gptq::gptq(w, codec, h),
                None => codec.quantize_mat(w),
            },
            Rounding::Qronos => match gram {
                Some(h) => qronos::qronos(w, codec, h),
                None => codec.quantize_mat(w),
            },
        }
    }
}

/// The layerwise proxy loss tr((W−Q)ᵀH(W−Q)) all solvers minimize.
pub fn proxy_loss(w: &Mat, q: &Mat, h: &SymMat) -> f64 {
    let d = w.rows;
    assert_eq!(h.n, d);
    let e = w.sub(q); // (d_in, d_out)
    let mut acc = 0.0f64;
    for c in 0..e.cols {
        // eᵀ H e per output column
        for i in 0..d {
            let ei = e.at(i, c) as f64;
            if ei == 0.0 {
                continue;
            }
            let hrow = &h.data[i * d..(i + 1) * d];
            let mut s = 0.0;
            for j in 0..d {
                s += hrow[j] * e.at(j, c) as f64;
            }
            acc += ei * s;
        }
    }
    acc
}

/// Descending order of the Gram diagonal — the processing order shared by
/// GPTQ and Qronos (Appendix B; provably helps, Zhang et al. 2025).
pub fn desc_diag_order(h: &SymMat) -> Vec<usize> {
    let diag = h.diag();
    let mut idx: Vec<usize> = (0..h.n).collect();
    idx.sort_by(|&a, &b| {
        diag[b]
            .partial_cmp(&diag[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    idx
}

/// Reorder H rows+cols by `order`.
pub fn permute_sym(h: &SymMat, order: &[usize]) -> SymMat {
    let n = h.n;
    let mut out = SymMat::zeros(n);
    for (i, &oi) in order.iter().enumerate() {
        for (j, &oj) in order.iter().enumerate() {
            *out.at_mut(i, j) = h.at(oi, oj);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Format;

    fn rand_w(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = crate::data::rng::Rng::new(seed);
        Mat::from_fn(r, c, |_, _| rng.next_normal() as f32 * 0.2)
    }

    fn rand_gram(d: usize, t: usize, seed: u64) -> SymMat {
        let mut rng = crate::data::rng::Rng::new(seed);
        // correlated activations: x = z + common component
        let mut h = SymMat::zeros(d);
        let mut x = vec![0.0f32; t * d];
        for r in 0..t {
            let common = rng.next_normal() as f32;
            for j in 0..d {
                x[r * d + j] = rng.next_normal() as f32 + 0.7 * common;
            }
        }
        h.accumulate_gram(&x, t);
        h.add_diag(0.01 * h.mean_diag());
        h
    }

    #[test]
    fn rtn_equals_codec() {
        let w = rand_w(32, 8, 1);
        let codec = WeightCodec::fit(Format::Int4, &w);
        let q = Rounding::Rtn.round(&w, &codec, None);
        assert_eq!(q.data, codec.quantize_mat(&w).data);
    }

    #[test]
    fn gptq_beats_rtn_on_correlated_hessian() {
        let w = rand_w(64, 16, 2);
        let h = rand_gram(64, 256, 3);
        let codec = WeightCodec::fit(Format::Int4, &w);
        let q_rtn = Rounding::Rtn.round(&w, &codec, Some(&h));
        let q_gptq = Rounding::Gptq.round(&w, &codec, Some(&h));
        let l_rtn = proxy_loss(&w, &q_rtn, &h);
        let l_gptq = proxy_loss(&w, &q_gptq, &h);
        assert!(l_gptq < l_rtn, "gptq {l_gptq} vs rtn {l_rtn}");
    }

    #[test]
    fn qronos_beats_gptq_in_aggregate() {
        // Qronos and GPTQ start from differently-damped solves, so strict
        // per-instance dominance is not guaranteed — the paper's claim (and
        // this test) is aggregate improvement.
        let (mut sum_g, mut sum_q) = (0.0, 0.0);
        for seed in 0..8 {
            let w = rand_w(48, 12, 10 + seed);
            let h = rand_gram(48, 200, 20 + seed);
            let codec = WeightCodec::fit(Format::Int4, &w);
            let q_g = Rounding::Gptq.round(&w, &codec, Some(&h));
            let q_q = Rounding::Qronos.round(&w, &codec, Some(&h));
            sum_g += proxy_loss(&w, &q_g, &h);
            sum_q += proxy_loss(&w, &q_q, &h);
        }
        assert!(sum_q < sum_g, "qronos {sum_q} vs gptq {sum_g}");
    }

    #[test]
    fn qronos_never_worse_than_its_own_rtn_start() {
        for seed in 0..5 {
            let w = rand_w(40, 8, 30 + seed);
            let h = rand_gram(40, 160, 40 + seed);
            let codec = WeightCodec::fit(Format::Int4, &w);
            let q_q = Rounding::Qronos.round(&w, &codec, Some(&h));
            let rtn = codec.quantize_mat(&w);
            assert!(proxy_loss(&w, &q_q, &h) <= proxy_loss(&w, &rtn, &h) * 1.0001);
        }
    }

    #[test]
    fn desc_diag_order_sorts() {
        let mut h = SymMat::zeros(4);
        for (i, v) in [2.0, 9.0, 1.0, 5.0].iter().enumerate() {
            *h.at_mut(i, i) = *v;
        }
        assert_eq!(desc_diag_order(&h), vec![1, 3, 0, 2]);
    }

    #[test]
    fn permute_sym_preserves_diag_multiset() {
        let h = rand_gram(8, 32, 5);
        let order = desc_diag_order(&h);
        let hp = permute_sym(&h, &order);
        let mut a = h.diag();
        let mut b = hp.diag();
        a.sort_by(|x, y| x.partial_cmp(y).unwrap());
        b.sort_by(|x, y| x.partial_cmp(y).unwrap());
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn proxy_loss_zero_for_exact() {
        let w = rand_w(16, 4, 7);
        let h = rand_gram(16, 64, 8);
        assert!(proxy_loss(&w, &w, &h).abs() < 1e-9);
    }
}
