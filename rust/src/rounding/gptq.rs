//! GPTQ / OPTQ (Frantar et al. 2023) with the Cholesky reformulation.
//!
//! Given W (d_in × d_out), codec Q, and Gram H₀ = X̃ᵀX̃:
//!   1. damp: H = H₀ + λI, λ = 1% of mean diag (Appendix B);
//!   2. order coordinates by descending diag(H);
//!   3. factor H = LLᵀ, U = L⁻ᵀ (upper), so that for the sequential
//!      solve the optimal correction of the not-yet-quantized coordinates
//!      after quantizing i is  w_j ← w_j − e·U[i,j]/U[i,i];
//!   4. walk coordinates in order, quantize through the codec, propagate.

use crate::quant::WeightCodec;
use crate::tensor::linalg::{invert_lower, SymMat};
use crate::tensor::Mat;

use super::{desc_diag_order, permute_sym};

/// Damping per Appendix B: λ = 1% of the average diagonal.
pub fn damp_gptq(h: &mut SymMat) {
    let lambda = 0.01 * h.mean_diag();
    h.add_diag(lambda.max(1e-10));
}

/// Core solver on a *pre-ordered* problem; returns Q in the same order.
/// `u` is the solve factor stored row-major (upper triangular), n = d_in.
///
/// Hot-path layout (§Perf): the running weights are kept *transposed*
/// (cols × n) so the per-coordinate correction `w_j -= err·u[i,j]` walks
/// both `work` and `u` contiguously — ~3× over the naive row-major walk.
pub(crate) fn gptq_ordered(w: &Mat, codec: &WeightCodec, u: &[f64],
                           order: &[usize]) -> Mat {
    let n = w.rows;
    let cols = w.cols;
    let mut work_t = w.transpose(); // (cols, n): row c is output channel c
    let mut q_t = Mat::zeros(cols, n);
    for i in 0..n {
        let uii = u[i * n + i];
        let urow = &u[i * n..(i + 1) * n];
        let orig_row = order[i];
        for c in 0..cols {
            let wrow = &mut work_t.data[c * n..(c + 1) * n];
            let v = wrow[i];
            let qv = codec.quantize_entry(orig_row, c, v);
            q_t.data[c * n + i] = qv;
            let err = ((v - qv) as f64) / uii;
            if err != 0.0 {
                for j in (i + 1)..n {
                    wrow[j] -= (err * urow[j]) as f32;
                }
            }
        }
    }
    q_t.transpose()
}

/// The sequential-solve factor: U = R⁻¹ (upper) where H = R·Rᵀ with R
/// *upper* triangular (the "reverse Cholesky", whose trailing blocks nest
/// with the trailing submatrices H_{≥i,≥i} the solve needs). Equivalent to
/// torch's `cholesky(H⁻¹, upper=True)` in the reference OPTQ code, since
/// H⁻¹ = UᵀU. Computed via the exchange trick: J·H·J = L·Lᵀ ⇒ R = J·L·J
/// ⇒ U = J·L⁻¹·J.
pub(crate) fn solve_factor(h: &SymMat) -> Vec<f64> {
    let n = h.n;
    // reverse both dims
    let mut hr = SymMat::zeros(n);
    for i in 0..n {
        for j in 0..n {
            *hr.at_mut(i, j) = h.at(n - 1 - i, n - 1 - j);
        }
    }
    let l = match hr.cholesky() {
        Some(l) => l,
        None => {
            // pathological Hessian: fall back to heavier damping
            let mut h2 = hr.clone();
            h2.add_diag(h2.mean_diag().max(1e-8));
            h2.cholesky().expect("Hessian not PD even after damping")
        }
    };
    let linv = invert_lower(&l, n);
    // U = J·L⁻¹·J: u[i][j] = linv[n-1-i][n-1-j] (upper triangular)
    let mut u = vec![0.0f64; n * n];
    for i in 0..n {
        for j in i..n {
            u[i * n + j] = linv[(n - 1 - i) * n + (n - 1 - j)];
        }
    }
    u
}

/// Full GPTQ: damping + ordering + reverse-Cholesky + sequential solve.
pub fn gptq(w: &Mat, codec: &WeightCodec, gram: &SymMat) -> Mat {
    assert_eq!(w.rows, gram.n, "Hessian dim must match d_in");
    let mut h = gram.clone();
    damp_gptq(&mut h);
    let order = desc_diag_order(&h);
    let hp = permute_sym(&h, &order);
    let u = solve_factor(&hp);
    let w_ord = w.permute_rows(&order);
    let q_ord = gptq_ordered(&w_ord, codec, &u, &order);
    // un-permute rows
    let inv = crate::permute::invert(&order);
    q_ord.permute_rows(&inv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Format;
    use crate::rounding::proxy_loss;

    fn rand_w(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = crate::data::rng::Rng::new(seed);
        Mat::from_fn(r, c, |_, _| rng.next_normal() as f32 * 0.2)
    }

    #[test]
    fn diagonal_hessian_reduces_to_rtn() {
        // with H = I there is no cross-coordinate interaction: GPTQ == RTN
        let w = rand_w(32, 8, 1);
        let mut h = SymMat::zeros(32);
        h.add_diag(1.0);
        let codec = WeightCodec::fit(Format::Int4, &w);
        let q = gptq(&w, &codec, &h);
        let rtn = codec.quantize_mat(&w);
        for (a, b) in q.data.iter().zip(&rtn.data) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn never_worse_than_rtn_in_proxy_loss() {
        for seed in 0..8 {
            let d = 40;
            let w = rand_w(d, 10, seed);
            let mut rng = crate::data::rng::Rng::new(100 + seed);
            let mut h = SymMat::zeros(d);
            let t = 160;
            let mut x = vec![0.0f32; t * d];
            for r in 0..t {
                let c0 = rng.next_normal() as f32;
                for j in 0..d {
                    x[r * d + j] = rng.next_normal() as f32 + c0;
                }
            }
            h.accumulate_gram(&x, t);
            h.add_diag(0.01 * h.mean_diag());
            let codec = WeightCodec::fit(Format::Int4, &w);
            let q = gptq(&w, &codec, &h);
            let rtn = codec.quantize_mat(&w);
            let lg = proxy_loss(&w, &q, &h);
            let lr = proxy_loss(&w, &rtn, &h);
            assert!(lg <= lr * 1.001, "seed {seed}: {lg} vs {lr}");
        }
    }

    #[test]
    fn output_is_on_grid() {
        let w = rand_w(24, 6, 5);
        let mut h = SymMat::zeros(24);
        let mut rng = crate::data::rng::Rng::new(77);
        let mut x = vec![0.0f32; 96 * 24];
        for v in x.iter_mut() {
            *v = rng.next_normal() as f32;
        }
        h.accumulate_gram(&x, 96);
        let codec = WeightCodec::fit(Format::Int4, &w);
        let q = gptq(&w, &codec, &h);
        // every output must be a codec fixed point
        let q2 = codec.quantize_mat(&q);
        for (a, b) in q.data.iter().zip(&q2.data) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn works_for_all_formats() {
        let w = rand_w(64, 4, 6);
        let mut h = SymMat::zeros(64);
        h.add_diag(2.0);
        for f in [Format::Int4, Format::Fp4, Format::Mxfp4] {
            let codec = WeightCodec::fit(f, &w);
            let q = gptq(&w, &codec, &h);
            assert!(q.data.iter().all(|v| v.is_finite()), "{f:?}");
        }
    }
}
