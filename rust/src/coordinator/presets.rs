//! Named pipeline presets matching the paper's method rows (Tables 1-2).

use super::spec::{GraphKind, PipelineSpec, RotationSpec};
use crate::permute::PermKind;
use crate::quant::Format;
use crate::rounding::Rounding;

/// PeRQ* — MassDiff + QuaRot rotations + Qronos (Fig 7 merged graph).
pub fn perq_star(block: usize, format: Format) -> PipelineSpec {
    PipelineSpec {
        permutation: PermKind::MassDiff,
        rotation: RotationSpec::quarot(block),
        rounding: Rounding::Qronos,
        format,
        ..Default::default()
    }
}

/// PeRQ† — MassDiff + learned (SpinQuant-style) R1 + RTN.
pub fn perq_dagger(block: usize, format: Format) -> PipelineSpec {
    PipelineSpec {
        permutation: PermKind::MassDiff,
        rotation: RotationSpec::spin(block),
        rounding: Rounding::Rtn,
        format,
        ..Default::default()
    }
}

/// "No Permute" arm of Table 1: QuaRot rotations + Qronos, identity P3.
pub fn no_permute(block: usize, format: Format) -> PipelineSpec {
    PipelineSpec {
        permutation: PermKind::Identity,
        rotation: RotationSpec::quarot(block),
        rounding: Rounding::Qronos,
        format,
        ..Default::default()
    }
}

/// MR-RTN / MR-GPTQ(=BRQ) / MR-Qronos: merged block rotations, identity P3.
pub fn mr(block: usize, rounding: Rounding, format: Format) -> PipelineSpec {
    PipelineSpec {
        permutation: PermKind::Identity,
        rotation: RotationSpec::mr(block),
        rounding,
        format,
        ..Default::default()
    }
}

/// BRQ-Spin: learned block rotations at R1, GPTQ rounding.
pub fn brq_spin(block: usize, format: Format) -> PipelineSpec {
    PipelineSpec {
        permutation: PermKind::Identity,
        rotation: RotationSpec::brq_spin(block),
        rounding: Rounding::Gptq,
        format,
        ..Default::default()
    }
}

/// The online-graph variant of a spec (Fig 9 / Table 11).
pub fn online(mut spec: PipelineSpec) -> PipelineSpec {
    spec.graph = GraphKind::Online;
    spec
}

/// All Table 2 method rows for a given format, in paper order.
pub fn table2_methods(format: Format) -> Vec<(&'static str, PipelineSpec)> {
    vec![
        ("MR-RTN", mr(32, Rounding::Rtn, format)),
        ("MR-GPTQ/BRQ", mr(32, Rounding::Gptq, format)),
        ("MR-Qronos", mr(32, Rounding::Qronos, format)),
        ("BRQ-Spin", brq_spin(32, format)),
        ("PeRQ*", perq_star(32, format)),
        ("PeRQ+", perq_dagger(32, format)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_compose() {
        let s = perq_star(32, Format::Int4);
        assert_eq!(s.label(), "massdiff+quarot(b32)+qronos@int4");
        let d = perq_dagger(32, Format::Int4);
        assert_eq!(d.label(), "massdiff+spin(b32)+rtn@int4");
        let m = mr(32, Rounding::Gptq, Format::Mxfp4);
        assert_eq!(m.label(), "identity+mr32(b32)+gptq@mxfp4");
    }

    #[test]
    fn table2_has_six_methods() {
        assert_eq!(table2_methods(Format::Int4).len(), 6);
    }
}
