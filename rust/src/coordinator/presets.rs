//! Named pipeline presets matching the paper's method rows (Tables 1-2).

use super::spec::{GraphKind, PipelineSpec, RotationSpec};
use crate::permute::PermKind;
use crate::quant::Format;
use crate::rounding::Rounding;

/// PeRQ* — MassDiff + QuaRot rotations + Qronos (Fig 7 merged graph).
pub fn perq_star(block: usize, format: Format) -> PipelineSpec {
    PipelineSpec {
        permutation: PermKind::MassDiff,
        rotation: RotationSpec::quarot(block),
        rounding: Rounding::Qronos,
        format,
        ..Default::default()
    }
}

/// PeRQ† — MassDiff + learned (SpinQuant-style) R1 + RTN.
pub fn perq_dagger(block: usize, format: Format) -> PipelineSpec {
    PipelineSpec {
        permutation: PermKind::MassDiff,
        rotation: RotationSpec::spin(block),
        rounding: Rounding::Rtn,
        format,
        ..Default::default()
    }
}

/// "No Permute" arm of Table 1: QuaRot rotations + Qronos, identity P3.
pub fn no_permute(block: usize, format: Format) -> PipelineSpec {
    PipelineSpec {
        permutation: PermKind::Identity,
        rotation: RotationSpec::quarot(block),
        rounding: Rounding::Qronos,
        format,
        ..Default::default()
    }
}

/// MR-RTN / MR-GPTQ(=BRQ) / MR-Qronos: merged block rotations, identity P3.
pub fn mr(block: usize, rounding: Rounding, format: Format) -> PipelineSpec {
    PipelineSpec {
        permutation: PermKind::Identity,
        rotation: RotationSpec::mr(block),
        rounding,
        format,
        ..Default::default()
    }
}

/// BRQ-Spin: learned block rotations at R1, GPTQ rounding.
pub fn brq_spin(block: usize, format: Format) -> PipelineSpec {
    PipelineSpec {
        permutation: PermKind::Identity,
        rotation: RotationSpec::brq_spin(block),
        rounding: Rounding::Gptq,
        format,
        ..Default::default()
    }
}

/// The online-graph variant of a spec (Fig 9 / Table 11).
pub fn online(mut spec: PipelineSpec) -> PipelineSpec {
    spec.graph = GraphKind::Online;
    spec
}

/// CLI-facing preset names, in help-text order. [`parse`] accepts exactly
/// these — the single registry both the `perq` dispatch and its help text
/// share, so they cannot drift.
pub fn names() -> &'static [&'static str] {
    &["perq_star", "perq_dagger", "no_permute", "mr_rtn", "mr_gptq", "mr_qronos", "brq_spin"]
}

/// Resolve a preset by CLI name at the given block size and format.
/// Returns `None` for unknown names (see [`names`]).
pub fn parse(name: &str, block: usize, format: Format) -> Option<PipelineSpec> {
    Some(match name {
        "perq_star" => perq_star(block, format),
        "perq_dagger" => perq_dagger(block, format),
        "no_permute" => no_permute(block, format),
        "mr_rtn" => mr(block, Rounding::Rtn, format),
        "mr_gptq" => mr(block, Rounding::Gptq, format),
        "mr_qronos" => mr(block, Rounding::Qronos, format),
        "brq_spin" => brq_spin(block, format),
        _ => return None,
    })
}

/// All Table 2 method rows for a given format, in paper order.
pub fn table2_methods(format: Format) -> Vec<(&'static str, PipelineSpec)> {
    vec![
        ("MR-RTN", mr(32, Rounding::Rtn, format)),
        ("MR-GPTQ/BRQ", mr(32, Rounding::Gptq, format)),
        ("MR-Qronos", mr(32, Rounding::Qronos, format)),
        ("BRQ-Spin", brq_spin(32, format)),
        ("PeRQ*", perq_star(32, format)),
        ("PeRQ+", perq_dagger(32, format)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_compose() {
        let s = perq_star(32, Format::Int4);
        assert_eq!(s.label(), "massdiff+quarot(b32)+qronos@int4");
        let d = perq_dagger(32, Format::Int4);
        assert_eq!(d.label(), "massdiff+spin(b32)+rtn@int4");
        let m = mr(32, Rounding::Gptq, Format::Mxfp4);
        assert_eq!(m.label(), "identity+mr32(b32)+gptq@mxfp4");
    }

    #[test]
    fn table2_has_six_methods() {
        assert_eq!(table2_methods(Format::Int4).len(), 6);
    }

    #[test]
    fn every_registered_name_parses() {
        for name in names() {
            let spec = parse(name, 32, Format::Int4)
                .unwrap_or_else(|| panic!("registered preset {name} must parse"));
            assert_eq!(spec.rotation.r3_block, 32);
        }
        assert!(parse("perq_nope", 32, Format::Int4).is_none());
    }

    #[test]
    fn parse_matches_direct_constructors() {
        assert_eq!(
            parse("mr_gptq", 16, Format::Mxfp4).unwrap().label(),
            mr(16, Rounding::Gptq, Format::Mxfp4).label()
        );
        assert_eq!(
            parse("perq_star", 32, Format::Int8).unwrap().label(),
            perq_star(32, Format::Int8).label()
        );
    }
}
