//! The L3 coordinator — PeRQ's pipeline engine (Fig 2): compose Stage 1
//! (permute × rotate) with Stage 2 (round), run calibration and the offline
//! weight transforms, schedule per-linear rounding jobs across worker
//! threads, and evaluate the quantized model through the AOT artifacts.

pub mod pipeline;
pub mod presets;
pub mod spec;

pub use pipeline::{Pipeline, PipelineReport};
pub use spec::PipelineSpec;
pub mod http;
pub mod net;
pub mod server;
