//! Pipeline specification: which permutation, rotations, rounding, format,
//! and graph architecture compose a run (the paper's Fig 2 "pipeline" vs
//! Fig 7/9 "graph" distinction).

pub use crate::permute::PermKind;
pub use crate::quant::Format;
pub use crate::rounding::Rounding;

use crate::data::corpus::Source;

/// Rotation choice at a given site.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RotKind {
    /// No rotation.
    None,
    /// Full-vector normalized Hadamard.
    Hadamard,
    /// Block-diagonal Hadamard with the given block size.
    HadamardBlock(usize),
    /// Learned full-vector rotation (rotopt_r1.npy — the SpinQuant arm).
    Learned,
    /// Learned block rotation (rotopt_r1_b32.npy — the BRQ-Spin arm).
    LearnedBlock(usize),
}

/// Where rotations go (Fig 7): R1 on the residual stream, R2 per-head on
/// v→o, R̃3 online at the down-projection input with block size `r3_block`
/// (1 = no rotation, d_ffn = full-vector).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RotationSpec {
    pub r1: RotKind,
    pub r2: RotKind,
    pub r3_block: usize,
}

impl RotationSpec {
    /// QuaRot-style: full-vector Hadamard R1/R2, block Hadamard R̃3.
    pub fn quarot(r3_block: usize) -> Self {
        RotationSpec { r1: RotKind::Hadamard, r2: RotKind::Hadamard, r3_block }
    }

    /// MR-GPTQ/BRQ-style: merged *block* rotations at R1/R2 too.
    pub fn mr(block: usize) -> Self {
        RotationSpec {
            r1: RotKind::HadamardBlock(block),
            r2: RotKind::Hadamard,
            r3_block: block,
        }
    }

    /// SpinQuant-style: learned full-vector R1, Hadamard R2.
    pub fn spin(r3_block: usize) -> Self {
        RotationSpec { r1: RotKind::Learned, r2: RotKind::Hadamard, r3_block }
    }

    /// BRQ-Spin: learned block rotations at R1, Hadamard R2, block R̃3.
    pub fn brq_spin(block: usize) -> Self {
        RotationSpec {
            r1: RotKind::LearnedBlock(block),
            r2: RotKind::Hadamard,
            r3_block: block,
        }
    }

    /// No rotations anywhere.
    pub fn none() -> Self {
        RotationSpec { r1: RotKind::None, r2: RotKind::None, r3_block: 1 }
    }
}

/// Graph architecture (Table 11): merged (Fig 7) vs fully online (Fig 9).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GraphKind {
    Merged,
    Online,
}

#[derive(Clone, Debug)]
pub struct PipelineSpec {
    pub permutation: PermKind,
    pub rotation: RotationSpec,
    pub rounding: Rounding,
    pub format: Format,
    pub graph: GraphKind,
    /// capture/Hessian calibration sequences (paper: 128 × 2048 tokens)
    pub calib_seqs: usize,
    /// permutation-calibration sequences (paper default: 1)
    pub perm_calib_seqs: usize,
    pub calib_source: Source,
    pub eval_source: Source,
    pub eval_tokens: usize,
    pub zeroshot_tokens: usize,
    pub seed: u64,
    pub workers: usize,
    /// also run the zero-shot probe suite (slower)
    pub run_zeroshot: bool,
}

impl Default for PipelineSpec {
    fn default() -> Self {
        PipelineSpec {
            permutation: PermKind::MassDiff,
            rotation: RotationSpec::quarot(32),
            rounding: Rounding::Qronos,
            format: Format::Int4,
            graph: GraphKind::Merged,
            calib_seqs: 16,
            perm_calib_seqs: 2,
            calib_source: Source::Wiki,
            eval_source: Source::Wiki,
            eval_tokens: 8192,
            zeroshot_tokens: 2048,
            seed: 7,
            workers: crate::util::pool::default_workers(),
            run_zeroshot: false,
        }
    }
}

impl PipelineSpec {
    /// Short human label, e.g. "massdiff+quarot(b32)+qronos@int4".
    pub fn label(&self) -> String {
        let rot = match self.rotation.r1 {
            RotKind::None => "norot".to_string(),
            RotKind::Hadamard => "quarot".to_string(),
            RotKind::HadamardBlock(b) => format!("mr{b}"),
            RotKind::Learned => "spin".to_string(),
            RotKind::LearnedBlock(b) => format!("brqspin{b}"),
        };
        format!(
            "{}+{}(b{})+{}@{}{}",
            self.permutation.name(),
            rot,
            self.rotation.r3_block,
            self.rounding.name(),
            self.format.name(),
            if self.graph == GraphKind::Online { "+online" } else { "" },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_perq_star_shape() {
        let s = PipelineSpec::default();
        assert_eq!(s.permutation, PermKind::MassDiff);
        assert_eq!(s.rounding, Rounding::Qronos);
        assert_eq!(s.rotation.r3_block, 32);
        assert_eq!(s.graph, GraphKind::Merged);
    }

    #[test]
    fn labels_are_distinct() {
        let a = PipelineSpec::default().label();
        let mut s = PipelineSpec::default();
        s.rounding = Rounding::Rtn;
        assert_ne!(a, s.label());
    }
}
