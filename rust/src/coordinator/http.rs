//! The network front door: a dependency-free HTTP/1.1 server over
//! `std::net::TcpListener` exposing an [`InferenceServer`] to real
//! clients.
//!
//! Endpoints:
//!
//! | endpoint            | method | body / response                           |
//! |---------------------|--------|-------------------------------------------|
//! | `/v1/score`         | POST   | `{"tokens":[...]}` → `{"nll",...}`        |
//! | `/v1/generate`      | POST   | `{"prompt":[...],"max_new_tokens":N}` → NDJSON token chunks (or one JSON object with `"stream":false`) |
//! | `/healthz`          | GET    | liveness — 200 while the process runs     |
//! | `/readyz`           | GET    | readiness — 503 the instant drain begins  |
//! | `/metrics`          | GET    | Prometheus text (server + engine registries) |
//! | `/traces`           | GET    | recent per-request traces as JSON         |
//!
//! The robustness layer is the point, not the parsing. Admission is
//! bounded end to end: over `--max-conns` concurrent connections get an
//! immediate 503 + `Retry-After`; reads and writes carry socket timeouts
//! so a slowloris costs one 408, never a wedged worker thread; request
//! bodies are capped (413). `ServeError` maps exactly onto status codes
//! ([`status_for`]) so the PR 7 completion contract
//! (`submitted == served + rejected + deadline_exceeded + failed`) is
//! observable from the client side. A `Perq-Deadline-Ms` header becomes a
//! [`SubmitOpts`] deadline; a client that disconnects mid-stream flips the
//! request's cancel flag and the worker frees the decode slot at its next
//! sweep. SIGTERM triggers graceful drain: `/readyz` goes 503 immediately,
//! new work is refused, in-flight requests get `--drain-timeout-ms` to
//! finish before the server aborts them.
//!
//! Connection-level failures are deterministic under test via the
//! `PERQ_NET_FAULT` harness in [`crate::coordinator::net::fault`].

use crate::coordinator::net::{self, Conn, HttpRequest, ReadOutcome};
use crate::coordinator::server::{
    GenerateResponse, InferenceServer, ServeError, ServeResult, ServerStats, SubmitOpts,
};
use crate::obs::metrics::{Counter, Gauge, Registry};
use crate::util::json::{self, Json};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

/// Connection-level policy for the front door.
#[derive(Clone, Copy, Debug)]
pub struct HttpOptions {
    /// concurrent-connection cap; the accept loop answers 503 +
    /// `Retry-After` beyond it without spawning a handler
    pub max_conns: usize,
    /// per-connection socket read timeout (slowloris bound → 408)
    pub read_timeout: Duration,
    /// per-connection socket write timeout
    pub write_timeout: Duration,
    /// request-body cap in bytes (413 beyond)
    pub max_body: usize,
    /// how long in-flight requests get to finish once drain begins
    pub drain_timeout: Duration,
}

impl Default for HttpOptions {
    fn default() -> HttpOptions {
        HttpOptions {
            max_conns: 64,
            read_timeout: Duration::from_secs(5),
            write_timeout: Duration::from_secs(5),
            max_body: 1 << 20,
            drain_timeout: Duration::from_secs(5),
        }
    }
}

/// The exact `ServeError` → HTTP status mapping. Exhaustive on purpose:
/// adding a `ServeError` variant without deciding its client-visible
/// status fails to compile.
pub fn status_for(err: ServeError) -> u16 {
    match err {
        ServeError::QueueFull => 429,
        ServeError::Shed => 429,
        // unlike the back-pressure 429s this is not retryable: the
        // request's token span can never fit the KV page pool
        ServeError::Rejected => 400,
        ServeError::DeadlineExceeded => 504,
        ServeError::WorkerFailed => 500,
        ServeError::ShuttingDown => 503,
        ServeError::Cancelled => 499,
    }
}

/// Front-door counters, registered in the *server's* registry so one
/// `/metrics` scrape (and the `--metrics-out` dump) sees request
/// accounting and connection accounting side by side.
struct HttpMetrics {
    registry: Arc<Registry>,
    conns: Arc<Counter>,
    conns_rejected: Arc<Counter>,
    active: Arc<Gauge>,
    requests: Arc<Counter>,
    bad_requests: Arc<Counter>,
    disconnects: Arc<Counter>,
}

impl HttpMetrics {
    fn new(registry: Arc<Registry>) -> HttpMetrics {
        let conns = registry.counter("perq_http_connections_total",
                                     "TCP connections accepted");
        let conns_rejected = registry.counter(
            "perq_http_connections_rejected_total",
            "connections answered 503 at accept (over --max-conns)");
        let active = registry.gauge("perq_http_active_connections",
                                    "connections currently being handled");
        let requests = registry.counter("perq_http_requests_total",
                                        "HTTP requests parsed off the wire");
        let bad_requests = registry.counter(
            "perq_http_bad_requests_total",
            "requests refused before reaching the server (4xx/5xx parse class)");
        let disconnects = registry.counter(
            "perq_http_client_disconnects_total",
            "clients that vanished mid-response (write failed)");
        HttpMetrics { registry, conns, conns_rejected, active, requests,
                      bad_requests, disconnects }
    }

    /// Per-status response counter, created on first use.
    fn count_status(&self, status: u16) {
        self.registry
            .counter(&format!("perq_http_status_{status}_total"),
                     "HTTP responses by status code")
            .inc();
    }
}

/// State shared by the accept loop and every connection handler.
struct Shared {
    server: Arc<InferenceServer>,
    stats: Arc<ServerStats>,
    opts: HttpOptions,
    /// drain begun: `/readyz` → 503, POSTs → 503, responses close
    draining: AtomicBool,
    /// accept loop must exit
    stopped: AtomicBool,
    active_conns: AtomicUsize,
    handlers: Mutex<Vec<JoinHandle<()>>>,
    m: HttpMetrics,
}

/// A running HTTP front door. Dropping it (or calling [`shutdown`])
/// drains gracefully: in-flight work gets [`HttpOptions::drain_timeout`]
/// to finish, then the engine aborts the rest so the process never hangs.
///
/// [`shutdown`]: HttpServer::shutdown
pub struct HttpServer {
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    local: SocketAddr,
}

impl HttpServer {
    /// Bind `addr` (e.g. `127.0.0.1:8080`; port 0 picks a free port) and
    /// start accepting. The inference server keeps running until the
    /// front door drains.
    pub fn start(server: Arc<InferenceServer>, addr: &str,
                 opts: HttpOptions) -> Result<HttpServer> {
        net::fault::load_env_once();
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding http listener on {addr}"))?;
        let local = listener.local_addr().context("listener local_addr")?;
        // nonblocking so the accept loop can notice `stopped` promptly;
        // accepted sockets do NOT inherit this and go back to blocking
        // reads bounded by the socket timeouts.
        listener.set_nonblocking(true).context("set_nonblocking")?;
        let stats = server.shared_stats();
        let m = HttpMetrics::new(server.registry());
        let shared = Arc::new(Shared {
            server,
            stats,
            opts,
            draining: AtomicBool::new(false),
            stopped: AtomicBool::new(false),
            active_conns: AtomicUsize::new(0),
            handlers: Mutex::new(Vec::new()),
            m,
        });
        let accept_shared = Arc::clone(&shared);
        let accept = thread::Builder::new()
            .name("perq-http-accept".into())
            .spawn(move || accept_loop(&listener, &accept_shared))
            .context("spawning accept thread")?;
        Ok(HttpServer { shared, accept: Some(accept), local })
    }

    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// Begin graceful drain *without blocking*: `/readyz` flips to 503 and
    /// new POSTs are refused immediately; the engine stops admitting and
    /// finishes what it holds. Idempotent.
    pub fn begin_drain(&self) {
        if !self.shared.draining.swap(true, Ordering::SeqCst) {
            self.shared.server.begin_shutdown();
        }
    }

    pub fn is_draining(&self) -> bool {
        self.shared.draining.load(Ordering::SeqCst)
    }

    /// The server-side stats this front door reports through.
    pub fn stats(&self) -> Arc<ServerStats> {
        Arc::clone(&self.shared.stats)
    }

    /// Drain and stop: [`begin_drain`], wait up to
    /// [`HttpOptions::drain_timeout`] for in-flight connections, abort
    /// whatever is still running, then join the accept and handler
    /// threads.
    ///
    /// [`begin_drain`]: HttpServer::begin_drain
    pub fn shutdown(mut self) {
        self.drain_impl();
    }

    fn drain_impl(&mut self) {
        self.begin_drain();
        let deadline = Instant::now() + self.shared.opts.drain_timeout;
        while self.shared.active_conns.load(Ordering::SeqCst) > 0
            && Instant::now() < deadline
        {
            thread::sleep(Duration::from_millis(5));
        }
        if self.shared.active_conns.load(Ordering::SeqCst) > 0 {
            // drain timeout: fail the stragglers (their handlers observe
            // ShuttingDown and answer 503) rather than hang the process
            self.shared.server.abort_in_flight();
        }
        self.shared.stopped.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let handlers = std::mem::take(&mut *self.shared.handlers.lock().unwrap());
        for h in handlers {
            let _ = h.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        if self.accept.is_some() {
            self.drain_impl();
        }
    }
}

fn accept_loop(listener: &TcpListener, shared: &Arc<Shared>) {
    loop {
        if shared.stopped.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let id = net::fault::next_conn_id();
                shared.m.conns.inc();
                if net::fault::accept_close(id) {
                    // injected: the client vanished between accept and read
                    drop(stream);
                    continue;
                }
                if shared.active_conns.load(Ordering::SeqCst) >= shared.opts.max_conns {
                    reject_over_limit(shared, stream);
                    continue;
                }
                shared.active_conns.fetch_add(1, Ordering::SeqCst);
                shared.m.active.add(1);
                let conn = match Conn::new(stream, id, shared.opts.read_timeout,
                                           shared.opts.write_timeout) {
                    Ok(c) => c,
                    Err(_) => {
                        shared.active_conns.fetch_sub(1, Ordering::SeqCst);
                        shared.m.active.add(-1);
                        continue;
                    }
                };
                let handler_shared = Arc::clone(shared);
                let spawned = thread::Builder::new()
                    .name(format!("perq-http-{id}"))
                    .spawn(move || {
                        handle_conn(&handler_shared, conn);
                        handler_shared.active_conns.fetch_sub(1, Ordering::SeqCst);
                        handler_shared.m.active.add(-1);
                    });
                match spawned {
                    Ok(h) => reap_and_track(shared, h),
                    Err(_) => {
                        shared.active_conns.fetch_sub(1, Ordering::SeqCst);
                        shared.m.active.add(-1);
                    }
                }
            }
            // nonblocking listener: nothing pending — nap and re-check
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(5));
            }
            Err(_) => thread::sleep(Duration::from_millis(5)),
        }
    }
}

/// Best-effort 503 + `Retry-After` for a connection over `--max-conns`,
/// written straight on the raw stream (no handler thread is spent on it).
fn reject_over_limit(shared: &Arc<Shared>, stream: std::net::TcpStream) {
    use std::io::Write;
    shared.m.conns_rejected.inc();
    shared.m.count_status(503);
    let body = error_body("over_capacity", "connection limit reached");
    let bytes = net::response_bytes(503, "application/json",
                                    &[("Retry-After", "1")], &body, true);
    let _ = stream.set_write_timeout(Some(Duration::from_millis(250)));
    let mut stream = stream;
    let _ = stream.write_all(&bytes);
}

/// Join any finished handler threads, then track the new one.
fn reap_and_track(shared: &Arc<Shared>, h: JoinHandle<()>) {
    let mut handlers = shared.handlers.lock().unwrap();
    let mut i = 0;
    while i < handlers.len() {
        if handlers[i].is_finished() {
            let _ = handlers.swap_remove(i).join();
        } else {
            i += 1;
        }
    }
    handlers.push(h);
}

/// `{"error":...,"message":...}` with proper JSON escaping.
fn error_body(error: &str, message: &str) -> Vec<u8> {
    let mut obj = BTreeMap::new();
    obj.insert("error".to_string(), Json::Str(error.to_string()));
    obj.insert("message".to_string(), Json::Str(message.to_string()));
    json::dump(&Json::Obj(obj)).into_bytes()
}

/// `Retry-After` rides on every back-pressure status.
fn extra_for(status: u16) -> &'static [(&'static str, &'static str)] {
    match status {
        429 | 503 => &[("Retry-After", "1")],
        405 => &[],
        _ => &[],
    }
}

/// Write a fixed response, counting the status and a vanished client.
/// Returns whether the connection may keep serving requests.
fn respond(shared: &Arc<Shared>, conn: &mut Conn, status: u16,
           content_type: &str, extra: &[(&str, &str)], body: &[u8],
           close: bool) -> bool {
    shared.m.count_status(status);
    match conn.write_response(status, content_type, extra, body, close) {
        Ok(()) => !close,
        Err(_) => {
            shared.m.disconnects.inc();
            false
        }
    }
}

fn respond_error(shared: &Arc<Shared>, conn: &mut Conn, status: u16,
                 error: &str, message: &str, close: bool) -> bool {
    let body = error_body(error, message);
    respond(shared, conn, status, "application/json", extra_for(status), &body, close)
}

/// Serve one connection: keep-alive request loop until the client closes,
/// a parse error closes it, or drain begins.
fn handle_conn(shared: &Arc<Shared>, mut conn: Conn) {
    loop {
        match conn.read_request(shared.opts.max_body) {
            ReadOutcome::Closed => break,
            ReadOutcome::Bad { status, reason } => {
                shared.m.bad_requests.inc();
                shared.m.count_status(status);
                let body = error_body("bad_request", reason);
                let _ = conn.write_response(status, "application/json",
                                            extra_for(status), &body, true);
                break;
            }
            ReadOutcome::Request(req) => {
                shared.m.requests.inc();
                // during drain every response closes, so handler threads
                // quiesce as soon as their current request resolves
                let close = req.wants_close()
                    || shared.draining.load(Ordering::SeqCst);
                if !route(shared, &mut conn, &req, close) {
                    break;
                }
            }
        }
    }
}

/// Dispatch one request. Returns whether the connection stays open.
fn route(shared: &Arc<Shared>, conn: &mut Conn, req: &HttpRequest,
         close: bool) -> bool {
    let path = req.path();
    match (req.method.as_str(), path) {
        ("GET", "/healthz") => {
            respond(shared, conn, 200, "application/json", &[],
                    b"{\"ok\":true}", close)
        }
        ("GET", "/readyz") => {
            if shared.draining.load(Ordering::SeqCst) {
                respond(shared, conn, 503, "application/json",
                        extra_for(503), b"{\"ready\":false,\"draining\":true}",
                        close)
            } else {
                respond(shared, conn, 200, "application/json", &[],
                        b"{\"ready\":true}", close)
            }
        }
        ("GET", "/metrics") => {
            let body = shared.stats.render_prometheus_full();
            respond(shared, conn, 200, "text/plain; version=0.0.4", &[],
                    body.as_bytes(), close)
        }
        ("GET", "/traces") => {
            let traces: Vec<Json> = shared
                .server
                .recent_traces()
                .iter()
                .map(|t| t.to_json())
                .collect();
            let body = json::dump(&Json::Arr(traces));
            respond(shared, conn, 200, "application/json", &[],
                    body.as_bytes(), close)
        }
        ("POST", "/v1/score") => handle_score(shared, conn, req, close),
        ("POST", "/v1/generate") => handle_generate(shared, conn, req, close),
        (_, "/healthz" | "/readyz" | "/metrics" | "/traces") => {
            respond(shared, conn, 405, "application/json",
                    &[("Allow", "GET")], &error_body("method_not_allowed",
                                                     "use GET"), close)
        }
        (_, "/v1/score" | "/v1/generate") => {
            respond(shared, conn, 405, "application/json",
                    &[("Allow", "POST")], &error_body("method_not_allowed",
                                                      "use POST"), close)
        }
        _ => {
            respond(shared, conn, 404, "application/json", &[],
                    &error_body("not_found", "unknown endpoint"), close)
        }
    }
}

/// Build [`SubmitOpts`] from the `Perq-Deadline-Ms` / `Perq-Priority`
/// headers. A header that is present but unparsable is a client bug —
/// refuse it rather than silently serving without the deadline the
/// client thinks it set.
fn opts_from_headers(req: &HttpRequest) -> std::result::Result<SubmitOpts, String> {
    let mut opts = SubmitOpts::default();
    if let Some(v) = req.header("perq-deadline-ms") {
        let ms: u64 = v
            .parse()
            .map_err(|_| format!("bad Perq-Deadline-Ms {v:?} (want milliseconds)"))?;
        opts.deadline = Some(Instant::now() + Duration::from_millis(ms));
    }
    if let Some(v) = req.header("perq-priority") {
        opts.priority = v
            .parse()
            .map_err(|_| format!("bad Perq-Priority {v:?} (want 0-255)"))?;
    }
    Ok(opts)
}

/// Pull an i32 token array out of a parsed JSON body field.
fn tokens_field(body: &Json, key: &str) -> std::result::Result<Vec<i32>, String> {
    let arr = body
        .get(key)
        .and_then(|v| v.as_arr())
        .ok_or_else(|| format!("body must carry a {key:?} array of token ids"))?;
    arr.iter()
        .map(|v| {
            v.as_f64()
                .map(|n| n as i32)
                .ok_or_else(|| format!("{key:?} must contain only numbers"))
        })
        .collect()
}

/// During drain new work is refused up front with the same 503 the
/// engine would answer, so clients see one consistent signal.
fn refuse_if_draining(shared: &Arc<Shared>, conn: &mut Conn, close: bool) -> Option<bool> {
    if shared.draining.load(Ordering::SeqCst) {
        return Some(respond_error(shared, conn, 503, "shutting_down",
                                  "server is draining", close));
    }
    None
}

fn handle_score(shared: &Arc<Shared>, conn: &mut Conn, req: &HttpRequest,
                close: bool) -> bool {
    if let Some(keep) = refuse_if_draining(shared, conn, close) {
        return keep;
    }
    let parsed = match std::str::from_utf8(&req.body)
        .map_err(|_| "body is not UTF-8".to_string())
        .and_then(|s| json::parse(s).map_err(|e| e.to_string()))
    {
        Ok(j) => j,
        Err(e) => return respond_error(shared, conn, 400, "bad_request", &e, close),
    };
    let tokens = match tokens_field(&parsed, "tokens") {
        Ok(t) => t,
        Err(e) => return respond_error(shared, conn, 400, "bad_request", &e, close),
    };
    let opts = match opts_from_headers(req) {
        Ok(o) => o,
        Err(e) => return respond_error(shared, conn, 400, "bad_request", &e, close),
    };
    let rx = match shared.server.submit_with(tokens, opts) {
        Ok(rx) => rx,
        // submit-side validation (wrong window length, vocab range) — a
        // client error, not a server failure
        Err(e) => {
            return respond_error(shared, conn, 400, "bad_request",
                                 &format!("{e:#}"), close)
        }
    };
    match recv_result(&rx) {
        Ok(resp) => {
            let mut obj = BTreeMap::new();
            // nll goes through the shortest-round-trip f64 path, so the
            // client-decoded value is bit-identical to the engine's
            obj.insert("nll".to_string(), Json::Num(resp.nll));
            obj.insert("latency_ms".to_string(),
                       Json::Num(resp.latency.as_secs_f64() * 1e3));
            obj.insert("batch_occupancy".to_string(),
                       Json::Num(resp.batch_occupancy as f64));
            let body = json::dump(&Json::Obj(obj));
            respond(shared, conn, 200, "application/json", &[],
                    body.as_bytes(), close)
        }
        Err(err) => {
            let status = status_for(err);
            respond_error(shared, conn, status, err.as_str(),
                          &err.to_string(), close)
        }
    }
}

/// Wait for the engine's verdict; a dropped response channel can only
/// mean the server tore down around the request.
fn recv_result<T>(rx: &Receiver<ServeResult<T>>) -> ServeResult<T> {
    match rx.recv() {
        Ok(r) => r,
        Err(_) => Err(ServeError::ShuttingDown),
    }
}

fn handle_generate(shared: &Arc<Shared>, conn: &mut Conn, req: &HttpRequest,
                   close: bool) -> bool {
    if let Some(keep) = refuse_if_draining(shared, conn, close) {
        return keep;
    }
    let parsed = match std::str::from_utf8(&req.body)
        .map_err(|_| "body is not UTF-8".to_string())
        .and_then(|s| json::parse(s).map_err(|e| e.to_string()))
    {
        Ok(j) => j,
        Err(e) => return respond_error(shared, conn, 400, "bad_request", &e, close),
    };
    let prompt = match tokens_field(&parsed, "prompt") {
        Ok(t) => t,
        Err(e) => return respond_error(shared, conn, 400, "bad_request", &e, close),
    };
    let max_new = parsed
        .get("max_new_tokens")
        .and_then(|v| v.as_usize())
        .unwrap_or(16);
    let stream = !matches!(parsed.get("stream"), Some(Json::Bool(false)));
    let opts = match opts_from_headers(req) {
        Ok(o) => o,
        Err(e) => return respond_error(shared, conn, 400, "bad_request", &e, close),
    };

    if !stream {
        let rx = match shared.server.submit_generate_with(prompt, max_new, opts) {
            Ok(rx) => rx,
            Err(e) => {
                return respond_error(shared, conn, 400, "bad_request",
                                     &format!("{e:#}"), close)
            }
        };
        return match recv_result(&rx) {
            Ok(resp) => {
                let body = json::dump(&generate_json(&resp));
                respond(shared, conn, 200, "application/json", &[],
                        body.as_bytes(), close)
            }
            Err(err) => {
                let status = status_for(err);
                respond_error(shared, conn, status, err.as_str(),
                              &err.to_string(), close)
            }
        };
    }

    // streaming: one NDJSON chunk per sampled token, then a final summary
    // object. The head and the first token go out in a single write so
    // even a mid-response drop delivers a well-formed stream prefix.
    let (token_tx, token_rx) = std::sync::mpsc::channel::<i32>();
    let cancel = Arc::new(AtomicBool::new(false));
    let rx = match shared.server.submit_generate_stream(
        prompt, max_new, opts, Some(token_tx), Some(Arc::clone(&cancel))) {
        Ok(rx) => rx,
        Err(e) => {
            return respond_error(shared, conn, 400, "bad_request",
                                 &format!("{e:#}"), close)
        }
    };
    let first = match token_rx.recv() {
        Ok(tok) => tok,
        // resolved before the first token: the terminal error (or a
        // response that never streamed) goes out as a plain response
        Err(_) => {
            return match recv_result(&rx) {
                Ok(resp) => {
                    let body = json::dump(&generate_json(&resp));
                    respond(shared, conn, 200, "application/json", &[],
                            body.as_bytes(), close)
                }
                Err(err) => {
                    let status = status_for(err);
                    respond_error(shared, conn, status, err.as_str(),
                                  &err.to_string(), close)
                }
            };
        }
    };
    shared.m.count_status(200);
    if conn
        .write_chunked_head(200, "application/x-ndjson", &[],
                            token_line(first).as_bytes(), close)
        .is_err()
    {
        return client_vanished(shared, &cancel);
    }
    loop {
        match token_rx.recv() {
            Ok(tok) => {
                if conn.write_chunk(token_line(tok).as_bytes()).is_err() {
                    return client_vanished(shared, &cancel);
                }
            }
            // the worker dropped its sender: generation resolved
            Err(_) => break,
        }
    }
    let last = match recv_result(&rx) {
        Ok(resp) => {
            let mut j = generate_json(&resp);
            if let Json::Obj(ref mut o) = j {
                o.insert("done".to_string(), Json::Bool(true));
            }
            json::dump(&j) + "\n"
        }
        Err(err) => {
            let mut o = BTreeMap::new();
            o.insert("error".to_string(), Json::Str(err.as_str().to_string()));
            o.insert("message".to_string(), Json::Str(err.to_string()));
            o.insert("status".to_string(), Json::Num(f64::from(status_for(err))));
            json::dump(&Json::Obj(o)) + "\n"
        }
    };
    if conn.finish_chunks(last.as_bytes()).is_err() {
        return client_vanished(shared, &cancel);
    }
    !close
}

/// The client disconnected mid-stream: flip the request's cancel flag so
/// the worker frees the decode slot at its next sweep, and close.
fn client_vanished(shared: &Arc<Shared>, cancel: &Arc<AtomicBool>) -> bool {
    cancel.store(true, Ordering::SeqCst);
    shared.m.disconnects.inc();
    false
}

fn token_line(tok: i32) -> String {
    format!("{{\"token\":{tok}}}\n")
}

fn generate_json(resp: &GenerateResponse) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("tokens".to_string(),
               Json::Arr(resp.tokens.iter().map(|&t| Json::Num(f64::from(t))).collect()));
    obj.insert("prefill_ms".to_string(),
               Json::Num(resp.prefill_latency.as_secs_f64() * 1e3));
    obj.insert("decode_ms".to_string(),
               Json::Num(resp.decode_latency.as_secs_f64() * 1e3));
    obj.insert("latency_ms".to_string(),
               Json::Num(resp.latency.as_secs_f64() * 1e3));
    Json::Obj(obj)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Exhaustive by construction: `status_for` has no wildcard arm, so a
    /// new `ServeError` variant breaks the build until it gets a status;
    /// this test pins the mapping itself.
    #[test]
    fn serve_error_status_mapping_is_exact() {
        assert_eq!(status_for(ServeError::QueueFull), 429);
        assert_eq!(status_for(ServeError::Shed), 429);
        assert_eq!(status_for(ServeError::Rejected), 400);
        assert_eq!(status_for(ServeError::DeadlineExceeded), 504);
        assert_eq!(status_for(ServeError::WorkerFailed), 500);
        assert_eq!(status_for(ServeError::ShuttingDown), 503);
        assert_eq!(status_for(ServeError::Cancelled), 499);
    }

    #[test]
    fn error_body_escapes() {
        let b = String::from_utf8(error_body("bad_request", "a \"quoted\" msg")).unwrap();
        assert_eq!(b, "{\"error\":\"bad_request\",\"message\":\"a \\\"quoted\\\" msg\"}");
    }

    #[test]
    fn backpressure_statuses_carry_retry_after() {
        assert_eq!(extra_for(429), &[("Retry-After", "1")]);
        assert_eq!(extra_for(503), &[("Retry-After", "1")]);
        assert!(extra_for(200).is_empty());
        assert!(extra_for(404).is_empty());
    }
}
