//! Batched inference server — the serving-side L3 coordinator.
//!
//! The paper's case for block rotations is a *serving* argument (App A:
//! online rotation overhead, "1.5× lower rotation cost, 2% end-to-end
//! latency for Llama2 7B at b=32"). This module provides the runtime that
//! argument lives in: a request router + dynamic batcher in front of any
//! [`ExecBackend`] — the device-resident PJRT artifact executor or the
//! pure-Rust `NativeBackend`.
//!
//! Design (vLLM-router-like, scaled to this testbed):
//!   * clients submit `ScoreRequest`s (token windows) and receive logits
//!     scores through a oneshot channel;
//!   * a batcher thread drains the queue into fixed-size backend batches
//!     (the forward graph has static (B, T)), padding the tail with the
//!     first request and waiting at most `max_wait` for a full batch;
//!     padded slots are *execution filler only* — they are excluded from
//!     `ServerStats.served`, from per-request NLL, and from the reported
//!     batch occupancy, and counted separately in `ServerStats.padded`;
//!   * the backend is constructed *on the batcher thread* via a `Send`
//!     factory, because PJRT handles are `Rc`-based and thread-confined;
//!     weights live as device buffers there (uploaded once), so the
//!     request path copies only tokens — the §Perf win over literal
//!     re-upload on every call. The native backend reuses pooled scratch
//!     the same way.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::backend::ExecBackend;
use crate::model::config::ModelConfig;

pub use crate::backend::ExtraInput;

/// Constructs the backend on the batcher thread (PJRT handles are not
/// `Send`; only the factory crosses threads).
pub type BackendFactory = Box<dyn FnOnce() -> Result<Box<dyn ExecBackend>> + Send + 'static>;

pub struct ScoreRequest {
    /// seq_len token window to score
    pub tokens: Vec<i32>,
    pub submitted: Instant,
    respond: Sender<ScoreResponse>,
}

#[derive(Debug)]
pub struct ScoreResponse {
    /// mean next-token NLL over the window (nats)
    pub nll: f64,
    /// queueing + batching + execution latency
    pub latency: Duration,
    /// how many *real* requests shared the batch (padding excluded)
    pub batch_occupancy: usize,
}

struct Queue {
    pending: VecDeque<ScoreRequest>,
    shutdown: bool,
}

/// Server statistics (atomics; read while running).
#[derive(Default)]
pub struct ServerStats {
    /// real requests served (padded slots never count)
    pub served: AtomicU64,
    pub batches: AtomicU64,
    /// batch slots filled with padding (tail duplication)
    pub padded: AtomicU64,
    pub exec_ns: AtomicU64,
}

pub struct InferenceServer {
    queue: Arc<(Mutex<Queue>, Condvar)>,
    stats: Arc<ServerStats>,
    worker: Option<std::thread::JoinHandle<()>>,
    running: Arc<AtomicBool>,
    cfg: ModelConfig,
}

impl InferenceServer {
    /// Spin up a server whose batcher thread owns the backend produced by
    /// `factory`. Construction errors surface here, not on first request.
    pub fn start_backend(factory: BackendFactory, cfg: &ModelConfig,
                         max_wait: Duration) -> Result<InferenceServer> {
        let queue = Arc::new((
            Mutex::new(Queue { pending: VecDeque::new(), shutdown: false }),
            Condvar::new(),
        ));
        let stats = Arc::new(ServerStats::default());
        let running = Arc::new(AtomicBool::new(true));
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let worker = {
            let queue = queue.clone();
            let stats = stats.clone();
            let running = running.clone();
            std::thread::spawn(move || {
                let backend = match factory() {
                    Ok(b) => {
                        let _ = ready_tx.send(Ok(()));
                        b
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                batcher_loop(backend, queue, stats, running, max_wait)
            })
        };
        ready_rx
            .recv()
            .map_err(|_| anyhow!("server thread died during startup"))??;
        Ok(InferenceServer {
            queue,
            stats,
            worker: Some(worker),
            running,
            cfg: cfg.clone(),
        })
    }

    /// Serve through the device-resident PJRT artifact at `artifact` (an
    /// .hlo.txt path) over (already transformed + quantized) weights;
    /// `extras` are the rotation/format inputs.
    #[cfg(feature = "pjrt")]
    pub fn start(artifact: std::path::PathBuf, cfg: &ModelConfig,
                 ws: &crate::model::weights::WeightSet, extras: Vec<ExtraInput>,
                 max_wait: Duration) -> Result<InferenceServer> {
        let graph = graph_from_extras(&extras)?;
        // native-only formats (fmt id > 3) must not reach the artifact's
        // lax.switch — it would clamp them to the wrong quantizer
        crate::backend::ensure_artifact_format(&graph)?;
        let cfg2 = cfg.clone();
        let ws2 = ws.clone();
        let factory: BackendFactory = Box::new(move || {
            Ok(Box::new(crate::backend::pjrt::PjrtBackend::load(
                &artifact, &cfg2, &ws2, &graph,
            )?) as Box<dyn ExecBackend>)
        });
        InferenceServer::start_backend(factory, cfg, max_wait)
    }

    /// Serve through the pure-Rust native backend — no PJRT, no artifacts.
    pub fn start_native(cfg: &ModelConfig, ws: &crate::model::weights::WeightSet,
                        graph: &crate::backend::ForwardGraph,
                        max_wait: Duration) -> Result<InferenceServer> {
        let cfg2 = cfg.clone();
        let ws2 = ws.clone();
        let graph = graph.clone();
        let factory: BackendFactory = Box::new(move || {
            Ok(Box::new(crate::backend::NativeBackend::new(cfg2, ws2, graph)?)
                as Box<dyn ExecBackend>)
        });
        InferenceServer::start_backend(factory, cfg, max_wait)
    }

    /// Submit a scoring request; returns a receiver for the response.
    pub fn submit(&self, tokens: Vec<i32>) -> Result<std::sync::mpsc::Receiver<ScoreResponse>> {
        anyhow::ensure!(tokens.len() == self.cfg.seq_len + 1,
                        "requests carry seq_len+1 tokens (window + next-token target)");
        let (tx, rx) = channel();
        let (lock, cv) = &*self.queue;
        let mut q = lock.lock().unwrap();
        anyhow::ensure!(!q.shutdown, "server is shut down");
        q.pending.push_back(ScoreRequest {
            tokens,
            submitted: Instant::now(),
            respond: tx,
        });
        cv.notify_one();
        Ok(rx)
    }

    /// (served, batches, exec seconds) — `served` counts real requests
    /// only; padded slots are tracked by [`InferenceServer::padded_slots`].
    pub fn stats(&self) -> (u64, u64, f64) {
        let served = self.stats.served.load(Ordering::Relaxed);
        let batches = self.stats.batches.load(Ordering::Relaxed);
        let exec_s = self.stats.exec_ns.load(Ordering::Relaxed) as f64 / 1e9;
        (served, batches, exec_s)
    }

    /// Batch slots that were filled with tail padding (never billed as
    /// served requests).
    pub fn padded_slots(&self) -> u64 {
        self.stats.padded.load(Ordering::Relaxed)
    }

    pub fn shutdown(mut self) {
        self.running.store(false, Ordering::Relaxed);
        {
            let (lock, cv) = &*self.queue;
            lock.lock().unwrap().shutdown = true;
            cv.notify_all();
        }
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        self.running.store(false, Ordering::Relaxed);
        let (lock, cv) = &*self.queue;
        if let Ok(mut q) = lock.lock() {
            q.shutdown = true;
        }
        cv.notify_all();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Recover the graph description from legacy (matrix.., fmt) extras — the
/// shape the pjrt `start` entry point and the integration suite still use.
#[cfg(feature = "pjrt")]
fn graph_from_extras(extras: &[ExtraInput]) -> Result<crate::backend::ForwardGraph> {
    use crate::backend::ForwardGraph;
    use crate::quant::Format;
    let fmt = extras
        .iter()
        .find_map(|e| match e {
            ExtraInput::ScalarI32(v) => Some(*v),
            _ => None,
        })
        .unwrap_or(0);
    let format = match fmt {
        1 => Format::Int4,
        2 => Format::Fp4,
        3 => Format::Mxfp4,
        4 => Format::Int8,
        _ => Format::None,
    };
    let mats = extras
        .iter()
        .filter(|e| matches!(e, ExtraInput::Matrix(_)))
        .count();
    if mats >= 2 {
        return Ok(ForwardGraph::Online { format });
    }
    let b = extras
        .iter()
        .find_map(|e| match e {
            ExtraInput::Matrix(m) => Some(m.rows),
            _ => None,
        })
        .unwrap_or(1);
    Ok(ForwardGraph::Merged { r3_block: b, format })
}

fn batcher_loop(mut backend: Box<dyn ExecBackend>, queue: Arc<(Mutex<Queue>, Condvar)>,
                stats: Arc<ServerStats>, running: Arc<AtomicBool>,
                max_wait: Duration) {
    let b = backend.cfg().batch;
    let t = backend.cfg().seq_len;
    let v = backend.cfg().vocab;
    while running.load(Ordering::Relaxed) {
        // drain up to a full batch, waiting at most max_wait after the
        // first request arrives
        let batch: Vec<ScoreRequest> = {
            let (lock, cv) = &*queue;
            let mut q = lock.lock().unwrap();
            while q.pending.is_empty() && !q.shutdown {
                q = cv.wait(q).unwrap();
            }
            if q.shutdown && q.pending.is_empty() {
                return;
            }
            let deadline = Instant::now() + max_wait;
            while q.pending.len() < b && !q.shutdown {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (qq, timeout) = cv.wait_timeout(q, deadline - now).unwrap();
                q = qq;
                if timeout.timed_out() {
                    break;
                }
            }
            let take = q.pending.len().min(b);
            q.pending.drain(..take).collect()
        };
        if batch.is_empty() {
            continue;
        }
        let real = batch.len();
        // assemble the token batch; tail slots are padded with the first
        // request purely to satisfy the static (B, T) graph shape
        let mut tokens = Vec::with_capacity(b * t);
        for i in 0..b {
            let req = batch.get(i).unwrap_or(&batch[0]);
            tokens.extend_from_slice(&req.tokens[..t]);
        }
        let t_exec = Instant::now();
        let result = backend.score(&tokens);
        let exec_ns = t_exec.elapsed().as_nanos() as u64;
        stats.exec_ns.fetch_add(exec_ns, Ordering::Relaxed);
        stats.batches.fetch_add(1, Ordering::Relaxed);
        stats.padded.fetch_add((b - real) as u64, Ordering::Relaxed);
        match result {
            Ok(logits) => {
                // only the `real` leading slots correspond to requests;
                // padded tail logits are dropped without scoring
                for (i, req) in batch.into_iter().enumerate() {
                    // mean NLL of targets tokens[1..=t] under logits[0..t)
                    let base = i * t * v;
                    let mut nll = 0.0f64;
                    for j in 0..t {
                        let row = &logits[base + j * v..base + (j + 1) * v];
                        let mx = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x)) as f64;
                        let lse: f64 = row.iter().map(|&x| ((x as f64) - mx).exp()).sum();
                        let tgt = req.tokens[j + 1] as usize;
                        nll += mx + lse.ln() - row[tgt] as f64;
                    }
                    stats.served.fetch_add(1, Ordering::Relaxed);
                    let _ = req.respond.send(ScoreResponse {
                        nll: nll / t as f64,
                        latency: req.submitted.elapsed(),
                        batch_occupancy: real,
                    });
                }
            }
            Err(e) => {
                eprintln!("server: batch execution failed: {e:#}");
                // drop senders → clients observe disconnection
            }
        }
    }
}

#[cfg(test)]
mod tests {
    //! Queue/batcher logic tests that don't need a real model live in
    //! rust/tests/coordinator_props.rs; full server round-trips are
    //! exercised natively in rust/tests/backend_parity.rs and
    //! examples/serve_requests.rs, and against PJRT in the integration
    //! suite.

    use super::*;
    use crate::backend::ForwardGraph;
    use crate::model::bundle;
    use crate::util::json;

    #[test]
    fn stats_default_zero() {
        let s = ServerStats::default();
        assert_eq!(s.served.load(std::sync::atomic::Ordering::Relaxed), 0);
        assert_eq!(s.padded.load(std::sync::atomic::Ordering::Relaxed), 0);
    }

    #[test]
    fn native_server_round_trip_counts_padding() {
        let j = json::parse(
            r#"{"config": {"name": "t", "n_layers": 1, "d_model": 16,
                "n_heads": 2, "d_ffn": 32, "vocab": 8, "seq_len": 8,
                "batch": 4, "block_sizes": [1, 8]}}"#,
        )
        .unwrap();
        let cfg = crate::model::config::ModelConfig::from_meta(&j).unwrap();
        let ws = bundle::synthetic_weights(&cfg, 11);
        let graph = ForwardGraph::Merged { r3_block: 8, format: crate::quant::Format::Int4 };
        let server =
            InferenceServer::start_native(&cfg, &ws, &graph, Duration::from_millis(1)).unwrap();
        // 3 requests into a batch-of-4 server → at least one padded slot
        let mk = |s: usize| -> Vec<i32> {
            (0..cfg.seq_len + 1).map(|i| ((s + i) % cfg.vocab) as i32).collect()
        };
        let rxs: Vec<_> = (0..3).map(|s| server.submit(mk(s)).unwrap()).collect();
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert!(resp.nll.is_finite() && resp.nll > 0.0);
            assert!(resp.batch_occupancy <= 3, "padding must not inflate occupancy");
        }
        let (served, batches, _) = server.stats();
        assert_eq!(served, 3, "padded slots must not count as served");
        assert!(batches >= 1);
        assert!(server.padded_slots() >= 1, "tail padding should be recorded");
        // identical windows score identically (deterministic native path)
        let a = server.submit(mk(0)).unwrap().recv().unwrap().nll;
        let b = server.submit(mk(0)).unwrap().recv().unwrap().nll;
        assert!((a - b).abs() < 1e-12);
        server.shutdown();
    }

    #[test]
    fn submit_rejects_bad_window() {
        let j = json::parse(
            r#"{"config": {"name": "t", "n_layers": 1, "d_model": 16,
                "n_heads": 2, "d_ffn": 32, "vocab": 8, "seq_len": 8,
                "batch": 2, "block_sizes": [1]}}"#,
        )
        .unwrap();
        let cfg = crate::model::config::ModelConfig::from_meta(&j).unwrap();
        let ws = bundle::synthetic_weights(&cfg, 12);
        let server = InferenceServer::start_native(
            &cfg, &ws, &ForwardGraph::Fp, Duration::from_millis(1),
        )
        .unwrap();
        assert!(server.submit(vec![0i32; 3]).is_err());
        server.shutdown();
    }
}
