//! Batched inference server — the serving-side L3 coordinator.
//!
//! The paper's case for block rotations is a *serving* argument (App A:
//! online rotation overhead, "1.5× lower rotation cost, 2% end-to-end
//! latency for Llama2 7B at b=32"). This module provides the runtime that
//! argument lives in: a request router + dynamic batcher in front of the
//! quantized AOT artifact.
//!
//! Design (vLLM-router-like, scaled to this testbed):
//!   * clients submit `ScoreRequest`s (token windows) and receive logits
//!     scores through a oneshot channel;
//!   * a batcher thread drains the queue into fixed-size artifact batches
//!     (the AOT graph has static (B, T)), padding the tail with the first
//!     request and waiting at most `max_wait` for a full batch;
//!   * weights live as *device buffers* (uploaded once via
//!     `buffer_from_host_literal`), so the request path copies only tokens
//!     and the small rotation/format extras — the §Perf win over literal
//!     re-upload on every call.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};
use xla::{PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use crate::model::config::ModelConfig;
use crate::model::weights::WeightSet;
use crate::runtime::engine;
use crate::tensor::Mat;

/// Extra artifact inputs after (weights, tokens), in a `Send` form —
/// PJRT handles are `Rc`-based and thread-confined, so the batcher thread
/// materializes literals itself.
#[derive(Clone)]
pub enum ExtraInput {
    Matrix(Mat),
    ScalarI32(i32),
}

pub struct ScoreRequest {
    /// seq_len token window to score
    pub tokens: Vec<i32>,
    pub submitted: Instant,
    respond: Sender<ScoreResponse>,
}

#[derive(Debug)]
pub struct ScoreResponse {
    /// mean next-token NLL over the window (nats)
    pub nll: f64,
    /// queueing + batching + execution latency
    pub latency: Duration,
    /// how many requests shared the batch
    pub batch_occupancy: usize,
}

struct Queue {
    pending: VecDeque<ScoreRequest>,
    shutdown: bool,
}

/// Server statistics (atomics; read while running).
#[derive(Default)]
pub struct ServerStats {
    pub served: AtomicU64,
    pub batches: AtomicU64,
    pub exec_ns: AtomicU64,
}

pub struct InferenceServer {
    queue: Arc<(Mutex<Queue>, Condvar)>,
    stats: Arc<ServerStats>,
    worker: Option<std::thread::JoinHandle<()>>,
    running: Arc<AtomicBool>,
    cfg: ModelConfig,
}

/// Device-resident model state, built and owned by the batcher thread
/// (PJRT handles are not `Send`; the whole client is thread-confined).
struct DeviceState {
    exe: PjRtLoadedExecutable,
    weight_bufs: Vec<PjRtBuffer>,
    extra_bufs: Vec<PjRtBuffer>,
    /// Host literals backing the device buffers. `buffer_from_host_literal`
    /// copies asynchronously on the CPU client, so the source literals must
    /// outlive the buffers (dropping them early is a use-after-free that
    /// manifests as a fatal size-check in abstract_tfrt_cpu_buffer.cc).
    _host_literals: Vec<xla::Literal>,
    cfg: ModelConfig,
    vocab: usize,
}

fn build_device_state(artifact: &std::path::Path, cfg: &ModelConfig,
                      ws: &WeightSet, extras: &[ExtraInput]) -> Result<DeviceState> {
    let client = PjRtClient::cpu().map_err(|e| anyhow!("pjrt client: {e:?}"))?;
    let proto = xla::HloModuleProto::from_text_file(
        artifact.to_str().ok_or_else(|| anyhow!("bad path"))?,
    )
    .map_err(|e| anyhow!("loading {artifact:?}: {e:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp).map_err(|e| anyhow!("compile: {e:?}"))?;
    let devices = client.addressable_devices();
    let device = &devices[0];
    // one-time weight upload (the §Perf point of this server)
    let mut host_literals = engine::weight_literals(ws)?;
    for e in extras {
        host_literals.push(match e {
            ExtraInput::Matrix(m) => engine::mat_literal(m)?,
            ExtraInput::ScalarI32(v) => engine::scalar_i32(*v),
        });
    }
    let n_weights = ws.names.len();
    let mut weight_bufs = Vec::new();
    let mut extra_bufs = Vec::new();
    for (i, lit) in host_literals.iter().enumerate() {
        let buf = client
            .buffer_from_host_literal(Some(device), lit)
            .map_err(|e| anyhow!("uploading input {i}: {e:?}"))?;
        if i < n_weights {
            weight_bufs.push(buf);
        } else {
            extra_bufs.push(buf);
        }
    }
    Ok(DeviceState {
        exe,
        weight_bufs,
        extra_bufs,
        _host_literals: host_literals,
        cfg: cfg.clone(),
        vocab: cfg.vocab,
    })
}

impl DeviceState {
    fn execute(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let cfg = &self.cfg;
        let tok_lit = engine::tokens_literal(tokens, cfg.batch, cfg.seq_len)?;
        let client = self.exe.client();
        let devices = client.addressable_devices();
    let device = &devices[0];
        let tok_buf = client
            .buffer_from_host_literal(Some(device), &tok_lit)
            .map_err(|e| anyhow!("uploading tokens: {e:?}"))?;
        let mut inputs: Vec<&PjRtBuffer> = self.weight_bufs.iter().collect();
        inputs.push(&tok_buf);
        for b in &self.extra_bufs {
            inputs.push(b);
        }
        let out = self
            .exe
            .execute_b(&inputs)
            .map_err(|e| anyhow!("execute_b: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e:?}"))?;
        let tuple = lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        engine::literal_to_vec_f32(&tuple[0])
    }
}

impl InferenceServer {
    /// Spin up a server over (already transformed + quantized) weights and
    /// the artifact at `artifact` (an .hlo.txt path); `extras` are the
    /// rotation/format inputs. The batcher thread owns its own PJRT client
    /// and compiles the artifact on startup.
    pub fn start(artifact: std::path::PathBuf, cfg: &ModelConfig, ws: &WeightSet,
                 extras: Vec<ExtraInput>, max_wait: Duration) -> Result<InferenceServer> {
        let queue = Arc::new((
            Mutex::new(Queue { pending: VecDeque::new(), shutdown: false }),
            Condvar::new(),
        ));
        let stats = Arc::new(ServerStats::default());
        let running = Arc::new(AtomicBool::new(true));
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let worker = {
            let queue = queue.clone();
            let stats = stats.clone();
            let running = running.clone();
            let cfg2 = cfg.clone();
            let ws2 = ws.clone();
            std::thread::spawn(move || {
                let state = match build_device_state(&artifact, &cfg2, &ws2, &extras) {
                    Ok(s) => {
                        let _ = ready_tx.send(Ok(()));
                        s
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                batcher_loop(state, queue, stats, running, max_wait)
            })
        };
        ready_rx
            .recv()
            .map_err(|_| anyhow!("server thread died during startup"))??;
        Ok(InferenceServer {
            queue,
            stats,
            worker: Some(worker),
            running,
            cfg: cfg.clone(),
        })
    }

    /// Submit a scoring request; returns a receiver for the response.
    pub fn submit(&self, tokens: Vec<i32>) -> Result<std::sync::mpsc::Receiver<ScoreResponse>> {
        anyhow::ensure!(tokens.len() == self.cfg.seq_len + 1,
                        "requests carry seq_len+1 tokens (window + next-token target)");
        let (tx, rx) = channel();
        let (lock, cv) = &*self.queue;
        let mut q = lock.lock().unwrap();
        anyhow::ensure!(!q.shutdown, "server is shut down");
        q.pending.push_back(ScoreRequest {
            tokens,
            submitted: Instant::now(),
            respond: tx,
        });
        cv.notify_one();
        Ok(rx)
    }

    pub fn stats(&self) -> (u64, u64, f64) {
        let served = self.stats.served.load(Ordering::Relaxed);
        let batches = self.stats.batches.load(Ordering::Relaxed);
        let exec_s = self.stats.exec_ns.load(Ordering::Relaxed) as f64 / 1e9;
        (served, batches, exec_s)
    }

    pub fn shutdown(mut self) {
        self.running.store(false, Ordering::Relaxed);
        {
            let (lock, cv) = &*self.queue;
            lock.lock().unwrap().shutdown = true;
            cv.notify_all();
        }
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        self.running.store(false, Ordering::Relaxed);
        let (lock, cv) = &*self.queue;
        if let Ok(mut q) = lock.lock() {
            q.shutdown = true;
        }
        cv.notify_all();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn batcher_loop(state: DeviceState, queue: Arc<(Mutex<Queue>, Condvar)>,
                stats: Arc<ServerStats>, running: Arc<AtomicBool>,
                max_wait: Duration) {
    let b = state.cfg.batch;
    let t = state.cfg.seq_len;
    while running.load(Ordering::Relaxed) {
        // drain up to a full batch, waiting at most max_wait after the
        // first request arrives
        let batch: Vec<ScoreRequest> = {
            let (lock, cv) = &*queue;
            let mut q = lock.lock().unwrap();
            while q.pending.is_empty() && !q.shutdown {
                q = cv.wait(q).unwrap();
            }
            if q.shutdown && q.pending.is_empty() {
                return;
            }
            let deadline = Instant::now() + max_wait;
            while q.pending.len() < b && !q.shutdown {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (qq, timeout) = cv.wait_timeout(q, deadline - now).unwrap();
                q = qq;
                if timeout.timed_out() {
                    break;
                }
            }
            let take = q.pending.len().min(b);
            q.pending.drain(..take).collect()
        };
        if batch.is_empty() {
            continue;
        }
        // assemble the padded token batch
        let mut tokens = Vec::with_capacity(b * t);
        for i in 0..b {
            let req = batch.get(i).unwrap_or(&batch[0]);
            tokens.extend_from_slice(&req.tokens[..t]);
        }
        let t_exec = Instant::now();
        let result = state.execute(&tokens);
        let exec_ns = t_exec.elapsed().as_nanos() as u64;
        stats.exec_ns.fetch_add(exec_ns, Ordering::Relaxed);
        stats.batches.fetch_add(1, Ordering::Relaxed);
        match result {
            Ok(logits) => {
                let v = state.vocab;
                for (i, req) in batch.into_iter().enumerate() {
                    // mean NLL of targets tokens[1..=t] under logits[0..t)
                    let base = i * t * v;
                    let mut nll = 0.0f64;
                    for j in 0..t {
                        let row = &logits[base + j * v..base + (j + 1) * v];
                        let mx = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x)) as f64;
                        let lse: f64 = row.iter().map(|&x| ((x as f64) - mx).exp()).sum();
                        let tgt = req.tokens[j + 1] as usize;
                        nll += mx + lse.ln() - row[tgt] as f64;
                    }
                    stats.served.fetch_add(1, Ordering::Relaxed);
                    let _ = req.respond.send(ScoreResponse {
                        nll: nll / t as f64,
                        latency: req.submitted.elapsed(),
                        batch_occupancy: b.min(i + 1),
                    });
                }
            }
            Err(e) => {
                eprintln!("server: batch execution failed: {e:#}");
                // drop senders → clients observe disconnection
            }
        }
    }
}

#[cfg(test)]
mod tests {
    //! Queue/batcher logic tests that don't need PJRT live in
    //! rust/tests/coordinator_props.rs (prop_batching_pads_consistently);
    //! full server round-trips are exercised in examples/serve_requests.rs
    //! and the integration suite.

    #[test]
    fn stats_default_zero() {
        let s = super::ServerStats::default();
        assert_eq!(s.served.load(std::sync::atomic::Ordering::Relaxed), 0);
    }
}
