//! Continuous-batching inference server — the serving-side L3 coordinator.
//!
//! The paper's case for block rotations is a *serving* argument, and a
//! *decode-time* one (App A: the online R̃3 rotation is paid per generated
//! token). This module provides the runtime that argument lives in: a
//! request router + slot-based continuous batcher in front of any
//! [`ExecBackend`] session.
//!
//! Design (vLLM-style, scaled to this testbed):
//!   * clients submit [`ScoreRequest`]s (token windows → NLL) or
//!     [`GenerateRequest`]s (prompt + `max_new_tokens` → greedy tokens)
//!     and receive `ServeResult` responses through oneshot channels;
//!   * each of the `num_workers` replicas owns a backend *session* with
//!     `cfg.batch` attention-state slots. Requests join and leave the live
//!     batch at **step granularity**: score windows prefill free slots and
//!     release them immediately; generation requests prefill their prompt
//!     into a slot and then ride the shared `decode_step` until done,
//!     while new arrivals backfill freed slots between steps;
//!   * each worker constructs its own backend *on its replica thread* via
//!     a shared `Send + Sync` factory (PJRT handles are `Rc`-based and
//!     thread-confined). Scoring and sampling are per-slot independent,
//!     so NLLs and generated tokens are identical regardless of arrival
//!     order, co-batched requests, or replica count — asserted by
//!     rust/tests/decode_parity.rs;
//!   * [`ServerStats`] tracks request counts, per-phase execution time and
//!     token throughput, step occupancy, and fixed-bucket atomic latency
//!     histograms. Every field is a handle registered in a per-server
//!     [`Registry`] (`obs::metrics`), so the [`StatsSnapshot`], the
//!     Prometheus dump, and the JSON snapshot are views over the same
//!     atomics. Completed requests leave a [`RequestTrace`] (with a
//!     terminal `outcome`) in a ring readable via
//!     [`InferenceServer::recent_traces`].
//!
//! # Failure model (the fail-safe layer)
//!
//! Every request accepted by a `submit*` call resolves to **exactly one**
//! terminal state, delivered as a `ServeResult` on its channel and
//! mirrored in the trace ring + metric counters:
//!
//!   * `Ok(response)` — completed (`perq_requests_served_total`);
//!   * `Err(QueueFull | Shed | Rejected | ShuttingDown)` — rejected by
//!     admission control (`perq_server_rejected_total`; sheds also count
//!     in `perq_server_shed_total`; `Rejected` means the request's token
//!     span exceeds the KV page pool and could never be served);
//!   * `Err(DeadlineExceeded)` — expired at batch-forming time or between
//!     decode steps (`perq_server_deadline_exceeded_total`);
//!   * `Err(WorkerFailed)` — lost to a backend error or replica panic
//!     (`perq_request_failures_total`).
//!
//! Replica threads run every engine step under `catch_unwind`: a panic
//! poisons only that replica's sessions, fails only the in-flight slots,
//! and the worker respawns a fresh backend from the factory
//! (`perq_server_worker_failures_total`). Score requests get a bounded
//! automatic retry (`score_retries`, `perq_server_retries_total`);
//! partially-generated requests are never retried. [`ServeOptions`]
//! bounds the intake queue (`queue_cap`, with priority shedding), sets a
//! default deadline, and caps the graceful drain (`drain_timeout`) —
//! after which in-flight steps are aborted through each backend's
//! cooperative step interrupt.
//!
//! When the backend's KV cache is paged (`PERQ_KV_PAGE`) and the page
//! pool oversubscribes, decode steps can fail with a typed
//! [`OutOfPages`] — always *before* any cache write. The scheduler then
//! preempts the lowest-priority active generation: its cache rows are
//! swapped out to host memory (`perq_kv_preemptions_total`), the step
//! re-runs bit-identically for the survivors, and the preempted request
//! resumes — restored page-for-page — before any new work is admitted.
//! A preempted-and-resumed request still completes exactly once, so the
//! completion contract above is unchanged.
//!
//! The batch-forming wait is configurable: `--max-wait-ms` on the CLIs,
//! `PERQ_MAX_WAIT_MS` in the environment, else [`DEFAULT_MAX_WAIT_MS`]
//! (see [`resolve_max_wait`]). It only delays *idle* workers to let a
//! fuller prefill form; a worker with active decode slots never waits.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Result};

use crate::backend::{ExecBackend, SessionId};
use crate::model::config::ModelConfig;
use crate::obs::metrics::{Counter, Gauge, Hist, Registry};
use crate::obs::trace::{RequestTrace, Tracer};
use crate::tensor::{KvSwap, OutOfPages, PagedConfig};
use crate::util::json::Json;

pub use crate::backend::ExtraInput;

/// Constructs one backend per worker thread, on that thread (PJRT handles
/// are not `Send`; only the factory crosses threads). Called once per
/// replica *plus once per respawn after a panic*, so it must be `Fn`.
pub type BackendFactory = Box<dyn Fn() -> Result<Box<dyn ExecBackend>> + Send + Sync + 'static>;

/// Default batch-forming wait for idle workers, in milliseconds.
pub const DEFAULT_MAX_WAIT_MS: u64 = 5;

/// Resolve the batch-forming wait: CLI `--max-wait-ms` wins, then the
/// `PERQ_MAX_WAIT_MS` environment variable, then [`DEFAULT_MAX_WAIT_MS`].
/// An unparsable environment value is *reported*, not silently ignored.
pub fn resolve_max_wait(cli_ms: Option<u64>) -> Duration {
    let ms = cli_ms
        .or_else(|| {
            let raw = std::env::var("PERQ_MAX_WAIT_MS").ok()?;
            match raw.trim().parse::<u64>() {
                Ok(v) => Some(v),
                Err(_) => {
                    crate::log_warn!(
                        "PERQ_MAX_WAIT_MS={raw:?} is not a millisecond count — using \
                         default {DEFAULT_MAX_WAIT_MS} ms"
                    );
                    None
                }
            }
        })
        .unwrap_or(DEFAULT_MAX_WAIT_MS);
    Duration::from_millis(ms)
}

/// Terminal non-success states of an accepted request (see the module's
/// failure model). Delivered through the response channel, so a client
/// always learns its request's fate — no silent drops.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// rejected at submit: the intake queue was at capacity
    QueueFull,
    /// evicted from the queue by a higher-priority arrival
    Shed,
    /// rejected at submit: the request can never be served on this
    /// configuration — its token span exceeds the KV page pool, so
    /// admitting it would only waste work before an inevitable failure
    Rejected,
    /// expired before completion (batch-forming or between decode steps)
    DeadlineExceeded,
    /// lost to a backend error or replica panic (retries exhausted)
    WorkerFailed,
    /// the server drained before this request could run
    ShuttingDown,
    /// the client abandoned the request (e.g. disconnected mid-stream);
    /// the slot is freed at the worker's next sweep
    Cancelled,
}

impl ServeError {
    /// Stable lowercase kind, used as the trace `outcome` label.
    pub fn as_str(&self) -> &'static str {
        match self {
            ServeError::QueueFull => "queue_full",
            ServeError::Shed => "shed",
            ServeError::Rejected => "rejected",
            ServeError::DeadlineExceeded => "deadline_exceeded",
            ServeError::WorkerFailed => "worker_failed",
            ServeError::ShuttingDown => "shutting_down",
            ServeError::Cancelled => "cancelled",
        }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let what = match self {
            ServeError::QueueFull => "request rejected: intake queue full",
            ServeError::Shed => "request shed for a higher-priority arrival",
            ServeError::Rejected => "request rejected: token span exceeds the KV cache capacity",
            ServeError::DeadlineExceeded => "request deadline exceeded",
            ServeError::WorkerFailed => "request lost to a worker failure",
            ServeError::ShuttingDown => "request dropped: server shutting down",
            ServeError::Cancelled => "request cancelled: client disconnected",
        };
        f.write_str(what)
    }
}

impl std::error::Error for ServeError {}

/// What a response channel carries: the response, or the terminal
/// [`ServeError`] the request resolved to instead.
pub type ServeResult<T> = std::result::Result<T, ServeError>;

/// Per-request submission options: admission priority and deadline.
#[derive(Clone, Copy, Debug, Default)]
pub struct SubmitOpts {
    /// admission priority — higher wins queue slots under pressure;
    /// equal priorities keep FIFO order (default 0)
    pub priority: u8,
    /// absolute deadline; `None` inherits the server's default deadline
    pub deadline: Option<Instant>,
}

/// Server-wide serving policy, shared by every `start_*` entry point.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ServeOptions {
    /// backend replicas (session-owning threads); min 1
    pub num_workers: usize,
    /// batch-forming wait for idle workers (see [`resolve_max_wait`])
    pub max_wait: Duration,
    /// intake-queue capacity; `None` = unbounded (the pre-fail-safe
    /// behavior). Oversubscription rejects with `QueueFull` or sheds the
    /// lowest-priority queued request.
    pub queue_cap: Option<usize>,
    /// default per-request deadline, measured from submit
    pub deadline: Option<Duration>,
    /// graceful-drain budget for `shutdown()`/`Drop`: queued + in-flight
    /// work gets this long to finish before in-flight steps are aborted
    pub drain_timeout: Duration,
    /// automatic retries for score requests lost to a worker failure
    /// (generation requests are never retried: partially-generated
    /// output must not be silently recomputed)
    pub score_retries: u32,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            num_workers: 1,
            max_wait: Duration::from_millis(DEFAULT_MAX_WAIT_MS),
            queue_cap: None,
            deadline: None,
            drain_timeout: Duration::from_secs(5),
            score_retries: 1,
        }
    }
}

impl ServeOptions {
    /// The historical `(max_wait, num_workers)` constructor shape.
    pub fn new(max_wait: Duration, num_workers: usize) -> ServeOptions {
        ServeOptions { num_workers, max_wait, ..ServeOptions::default() }
    }

    pub fn with_queue_cap(mut self, cap: usize) -> Self {
        self.queue_cap = Some(cap);
        self
    }

    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    pub fn with_drain_timeout(mut self, timeout: Duration) -> Self {
        self.drain_timeout = timeout;
        self
    }

    pub fn with_score_retries(mut self, retries: u32) -> Self {
        self.score_retries = retries;
        self
    }
}

pub struct ScoreRequest {
    /// seq_len + 1 tokens: the window to score plus the next-token target
    pub tokens: Vec<i32>,
    pub submitted: Instant,
    /// lifecycle-trace ID, assigned at submit time
    pub trace_id: u64,
    /// admission priority (higher wins under queue pressure)
    pub priority: u8,
    /// absolute deadline, resolved at submit time
    pub deadline: Option<Instant>,
    /// worker-failure retries consumed so far
    attempts: u32,
    respond: Sender<ServeResult<ScoreResponse>>,
}

#[derive(Debug)]
pub struct ScoreResponse {
    /// mean next-token NLL over the window (nats)
    pub nll: f64,
    /// queueing + batching + execution latency
    pub latency: Duration,
    /// score windows that shared this request's prefill step
    pub batch_occupancy: usize,
}

pub struct GenerateRequest {
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub submitted: Instant,
    /// lifecycle-trace ID, assigned at submit time
    pub trace_id: u64,
    /// admission priority (higher wins under queue pressure)
    pub priority: u8,
    /// absolute deadline, resolved at submit time
    pub deadline: Option<Instant>,
    /// per-token streaming sink: the worker sends each sampled token the
    /// moment it exists (first token after prefill, then one per decode
    /// step). Best-effort — a dropped receiver never fails the request.
    stream: Option<Sender<i32>>,
    /// cooperative cancellation (client disconnected): checked at the
    /// batch-forming sweep and between decode steps, where deadlines are
    /// checked, so a cancelled request frees its slot within one step
    cancel: Option<Arc<AtomicBool>>,
    respond: Sender<ServeResult<GenerateResponse>>,
}

#[derive(Debug)]
pub struct GenerateResponse {
    /// greedily sampled tokens (prompt excluded)
    pub tokens: Vec<i32>,
    /// submit → prompt prefilled + first token sampled
    pub prefill_latency: Duration,
    /// first token → generation complete
    pub decode_latency: Duration,
    /// end-to-end (prefill + decode phases)
    pub latency: Duration,
}

enum Request {
    Score(ScoreRequest),
    Generate(GenerateRequest),
}

impl Request {
    fn priority(&self) -> u8 {
        match self {
            Request::Score(r) => r.priority,
            Request::Generate(r) => r.priority,
        }
    }

    fn deadline(&self) -> Option<Instant> {
        match self {
            Request::Score(r) => r.deadline,
            Request::Generate(r) => r.deadline,
        }
    }

    fn is_expired(&self, now: Instant) -> bool {
        self.deadline().map_or(false, |d| now >= d)
    }

    /// Cancelled by the client while still queued (generate-only: score
    /// responses are a single write, so a vanished scorer is undetectable
    /// until then and simply gets its send dropped).
    fn is_cancelled(&self) -> bool {
        match self {
            Request::Score(_) => false,
            Request::Generate(r) => {
                r.cancel.as_ref().map_or(false, |c| c.load(Ordering::Relaxed))
            }
        }
    }
}

struct Queue {
    pending: VecDeque<Request>,
    shutdown: bool,
}

/// Insert keeping the queue sorted by priority (descending), FIFO within
/// equal priorities. All-default (0) priorities degrade to `push_back`,
/// so the scan from the back is O(1) for the common case.
fn insert_by_priority(pending: &mut VecDeque<Request>, req: Request) {
    let p = req.priority();
    let mut idx = pending.len();
    while idx > 0 && pending[idx - 1].priority() < p {
        idx -= 1;
    }
    pending.insert(idx, req);
}

/// Admit `req` under `cap` (None = unbounded). At capacity, a request
/// that outranks the lowest-priority queued entry sheds it; otherwise
/// the arrival itself is rejected. Returns the request to resolve with
/// its rejection kind — resolution happens *after* the lock drops.
fn admit_locked(pending: &mut VecDeque<Request>, cap: Option<usize>,
                req: Request) -> Option<(Request, ServeError)> {
    if let Some(cap) = cap {
        if pending.len() >= cap {
            let outranks = pending.back().map_or(false, |back| back.priority() < req.priority());
            if outranks {
                let victim = pending.pop_back().expect("back checked above");
                insert_by_priority(pending, req);
                return Some((victim, ServeError::Shed));
            }
            return Some((req, ServeError::QueueFull));
        }
    }
    insert_by_priority(pending, req);
    None
}

/// The request-latency histogram, generalized into `obs::metrics` (PR 6)
/// and re-exported under its historical serving-layer name.
pub use crate::obs::metrics::Hist as LatencyHist;

/// Completed-trace ring capacity per server (see [`Tracer`]).
const TRACE_RING: usize = 256;

/// Milliseconds of a span, for trace records.
fn ms(d: Duration) -> f64 {
    d.as_secs_f64() * 1e3
}

/// Per-worker counters; the aggregate [`ServerStats`] sums across replicas.
#[derive(Default)]
pub struct WorkerStats {
    /// requests completed on this replica (score + generate)
    pub served: AtomicU64,
    /// engine steps (prefill calls + decode calls)
    pub batches: AtomicU64,
    pub exec_ns: AtomicU64,
}

/// Server statistics (atomics; read while running). Every field is a
/// handle registered in `registry` under a stable `perq_*` metric name
/// (see the README metrics table), so the legacy [`StatsSnapshot`],
/// `registry.render_prometheus()`, and `registry.snapshot_json()` read
/// the very same atomics. Each server owns its own registry.
pub struct ServerStats {
    /// the registry every handle below is registered in
    pub registry: Arc<Registry>,
    /// requests accepted by a `submit*` call (each resolves to exactly
    /// one terminal state: served/rejected/deadline_exceeded/failed)
    pub submitted: Arc<Counter>,
    /// requests completed (score + generate)
    pub served: Arc<Counter>,
    /// generate requests completed (subset of `served`)
    pub generated: Arc<Counter>,
    /// engine steps executed (prefill calls + decode calls)
    pub batches: Arc<Counter>,
    pub exec_ns: Arc<Counter>,
    /// execution time spent in prefill steps
    pub prefill_ns: Arc<Counter>,
    /// execution time spent in decode steps
    pub decode_ns: Arc<Counter>,
    /// prompt/window tokens pushed through prefill
    pub prefill_tokens: Arc<Counter>,
    /// tokens produced by decode steps
    pub decode_tokens: Arc<Counter>,
    /// Σ active requests over engine steps (mean = occupancy_sum/batches)
    pub occupancy_sum: Arc<Counter>,
    /// requests lost to backend errors or replica panics (WorkerFailed)
    pub failures: Arc<Counter>,
    /// requests rejected by admission control (QueueFull + Shed +
    /// ShuttingDown)
    pub rejected: Arc<Counter>,
    /// queued requests evicted for higher-priority arrivals (⊂ rejected)
    pub shed: Arc<Counter>,
    /// requests abandoned by their client, e.g. a mid-stream disconnect
    /// (⊂ rejected — the completion contract is unchanged)
    pub cancelled: Arc<Counter>,
    /// requests expired before completion
    pub deadline_exceeded: Arc<Counter>,
    /// replica poisonings (panic → session quarantined → respawn)
    pub worker_failures: Arc<Counter>,
    /// score requests requeued after a worker failure
    pub retries: Arc<Counter>,
    /// decoding requests swapped out of their slot to relieve KV page
    /// pressure (each later resumes and still completes exactly once)
    pub preemptions: Arc<Counter>,
    /// requests waiting for admission (sampled at queue transitions)
    pub queue_depth: Arc<Gauge>,
    /// end-to-end request latency histogram
    pub latency: Arc<Hist>,
    /// submit → prefill-complete latency (generate requests)
    pub prefill_lat: Arc<Hist>,
    /// decode-phase latency (generate requests)
    pub decode_lat: Arc<Hist>,
    /// single decode engine-step execution time (per-token span source)
    pub decode_step: Arc<Hist>,
    /// completed request-lifecycle traces (fixed ring)
    pub traces: Tracer,
}

impl Default for ServerStats {
    fn default() -> Self {
        let registry = Arc::new(Registry::new());
        ServerStats {
            submitted: registry.counter(
                "perq_requests_submitted_total",
                "requests accepted into the intake queue",
            ),
            served: registry
                .counter("perq_requests_served_total", "requests completed (score + generate)"),
            generated: registry
                .counter("perq_generate_requests_total", "generate requests completed"),
            batches: registry
                .counter("perq_engine_steps_total", "engine steps (prefill + decode calls)"),
            exec_ns: registry
                .counter("perq_exec_ns_total", "execution time across engine steps (ns)"),
            prefill_ns: registry
                .counter("perq_prefill_ns_total", "execution time in prefill steps (ns)"),
            decode_ns: registry
                .counter("perq_decode_ns_total", "execution time in decode steps (ns)"),
            prefill_tokens: registry
                .counter("perq_prefill_tokens_total", "prompt/window tokens through prefill"),
            decode_tokens: registry
                .counter("perq_decode_tokens_total", "tokens produced by decode steps"),
            occupancy_sum: registry
                .counter("perq_step_occupancy_total", "sum of active requests over engine steps"),
            failures: registry
                .counter("perq_request_failures_total", "requests lost to worker failures"),
            rejected: registry.counter(
                "perq_server_rejected_total",
                "requests rejected by admission control",
            ),
            shed: registry.counter(
                "perq_server_shed_total",
                "queued requests shed for higher-priority arrivals",
            ),
            cancelled: registry.counter(
                "perq_server_cancelled_total",
                "requests cancelled by client disconnect (subset of rejected)",
            ),
            deadline_exceeded: registry.counter(
                "perq_server_deadline_exceeded_total",
                "requests expired before completion",
            ),
            worker_failures: registry.counter(
                "perq_server_worker_failures_total",
                "replica poisonings (panic, session quarantined, respawn)",
            ),
            retries: registry.counter(
                "perq_server_retries_total",
                "score requests requeued after a worker failure",
            ),
            preemptions: registry.counter(
                "perq_kv_preemptions_total",
                "decoding requests swapped out to relieve KV page pressure",
            ),
            queue_depth: registry.gauge("perq_queue_depth", "requests waiting for admission"),
            latency: registry
                .hist("perq_request_latency_seconds", "end-to-end request latency"),
            prefill_lat: registry.hist(
                "perq_prefill_latency_seconds",
                "submit to prefill-complete latency (generate requests)",
            ),
            decode_lat: registry
                .hist("perq_decode_latency_seconds", "decode-phase latency (generate requests)"),
            decode_step: registry
                .hist("perq_decode_step_seconds", "single decode engine-step execution time"),
            traces: Tracer::new(TRACE_RING),
            registry,
        }
    }
}

/// One coherent read of [`ServerStats`] — the `perq serve` JSON record.
#[derive(Clone, Debug)]
pub struct StatsSnapshot {
    pub served: u64,
    pub generated: u64,
    pub batches: u64,
    pub exec_s: f64,
    pub prefill_s: f64,
    pub decode_s: f64,
    pub prefill_tokens: u64,
    pub decode_tokens: u64,
    /// decode tokens per second of decode execution time
    pub decode_tok_per_s: f64,
    /// mean active requests per engine step
    pub mean_occupancy: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub prefill_p50_ms: f64,
    pub prefill_p95_ms: f64,
    pub prefill_p99_ms: f64,
    pub decode_p50_ms: f64,
    pub decode_p95_ms: f64,
    pub decode_p99_ms: f64,
    /// latency records clamped into the top histogram bucket
    pub hist_saturated: u64,
    /// requests accepted by submit (completion-contract denominator)
    pub submitted: u64,
    /// rejected by admission control (queue full / shed / shutdown)
    pub rejected: u64,
    /// subset of `rejected`: evicted for higher-priority arrivals
    pub shed: u64,
    /// subset of `rejected`: abandoned by the client (disconnects)
    pub cancelled: u64,
    /// expired before completion
    pub deadline_exceeded: u64,
    /// lost to worker failures (terminal, retries exhausted)
    pub failed: u64,
    /// replica poisonings (panic → respawn)
    pub worker_failures: u64,
    /// score-request retries after worker failures
    pub retries: u64,
    /// decode preemptions (slot swapped out under KV page pressure; a
    /// preempted-and-resumed request still counts once in `served`)
    pub preemptions: u64,
    /// prompt tokens served from the shared KV prefix cache
    /// (process-wide engine counter — additive across servers)
    pub kv_prefix_hits: u64,
    /// private page copies triggered by writes into shared KV pages
    /// (process-wide engine counter)
    pub kv_cow_copies: u64,
    /// KV pages currently off the free list (process-wide engine gauge)
    pub kv_pages_in_use: i64,
    /// KV page pool size of the most recent paged session (engine gauge)
    pub kv_pages_total: i64,
}

impl ServerStats {
    /// The legacy `perq serve` statistics view, read straight off the
    /// registry-registered handles (see [`ServerStats`]).
    pub fn snapshot(&self) -> StatsSnapshot {
        let batches = self.batches.get();
        let decode_s = self.decode_ns.get() as f64 / 1e9;
        let decode_tokens = self.decode_tokens.get();
        // KV paging counters live in the process-wide engine registry
        // (they are engine-session state, not per-server state); the
        // snapshot reads the same handles the backends write through
        let g = crate::obs::metrics::global();
        StatsSnapshot {
            served: self.served.get(),
            generated: self.generated.get(),
            batches,
            exec_s: self.exec_ns.get() as f64 / 1e9,
            prefill_s: self.prefill_ns.get() as f64 / 1e9,
            decode_s,
            prefill_tokens: self.prefill_tokens.get(),
            decode_tokens,
            decode_tok_per_s: if decode_s > 0.0 { decode_tokens as f64 / decode_s } else { 0.0 },
            mean_occupancy: if batches > 0 {
                self.occupancy_sum.get() as f64 / batches as f64
            } else {
                0.0
            },
            p50_ms: self.latency.percentile(0.50),
            p95_ms: self.latency.percentile(0.95),
            p99_ms: self.latency.percentile(0.99),
            prefill_p50_ms: self.prefill_lat.percentile(0.50),
            prefill_p95_ms: self.prefill_lat.percentile(0.95),
            prefill_p99_ms: self.prefill_lat.percentile(0.99),
            decode_p50_ms: self.decode_lat.percentile(0.50),
            decode_p95_ms: self.decode_lat.percentile(0.95),
            decode_p99_ms: self.decode_lat.percentile(0.99),
            hist_saturated: self.latency.saturated()
                + self.prefill_lat.saturated()
                + self.decode_lat.saturated(),
            submitted: self.submitted.get(),
            rejected: self.rejected.get(),
            shed: self.shed.get(),
            cancelled: self.cancelled.get(),
            deadline_exceeded: self.deadline_exceeded.get(),
            failed: self.failures.get(),
            worker_failures: self.worker_failures.get(),
            retries: self.retries.get(),
            preemptions: self.preemptions.get(),
            kv_prefix_hits: g
                .counter("perq_kv_prefix_hits_total",
                         "prompt tokens served from the shared KV prefix cache")
                .get(),
            kv_cow_copies: g
                .counter("perq_kv_cow_copies_total",
                         "private page copies triggered by writes into shared KV pages")
                .get(),
            kv_pages_in_use: g
                .gauge("perq_kv_pages_in_use",
                       "KV pages off the free list (live slots + prefix cache)")
                .get(),
            kv_pages_total: g
                .gauge("perq_kv_pages_total",
                       "KV page pool size of the most recent paged session")
                .get(),
        }
    }

    /// Prometheus text exposition for everything this process serves:
    /// this server's registry followed by the process-wide engine
    /// registry (the name sets are disjoint). This is the ONE render
    /// path behind `GET /metrics`, the periodic `--metrics-out` writer,
    /// and the exit-time flush guard, so scrape consumers can never see
    /// divergent formats.
    pub fn render_prometheus_full(&self) -> String {
        let mut text = self.registry.render_prometheus();
        text.push_str(&crate::obs::metrics::global().render_prometheus());
        text
    }

    /// The JSON twin of [`render_prometheus_full`]: the legacy snapshot
    /// fields flat at the top level (bit-compatible with the
    /// pre-registry shape), plus the full server registry, the
    /// process-wide engine registry, and the recent request traces.
    ///
    /// [`render_prometheus_full`]: ServerStats::render_prometheus_full
    pub fn snapshot_json_full(&self) -> Json {
        let mut o = match self.snapshot().to_json() {
            Json::Obj(m) => m,
            _ => BTreeMap::new(),
        };
        o.insert("registry".to_string(), self.registry.snapshot_json());
        o.insert("engine".to_string(), crate::obs::metrics::global().snapshot_json());
        o.insert("traces".to_string(), self.traces.to_json());
        Json::Obj(o)
    }
}

impl StatsSnapshot {
    /// The `perq serve` JSON record: the PR 5 field set, field for field,
    /// plus the additive failure-model fields. Consumers of the legacy
    /// record must keep seeing exactly the original keys.
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("served".to_string(), Json::Num(self.served as f64));
        o.insert("generated".to_string(), Json::Num(self.generated as f64));
        o.insert("batches".to_string(), Json::Num(self.batches as f64));
        o.insert("exec_s".to_string(), Json::Num(self.exec_s));
        o.insert("prefill_s".to_string(), Json::Num(self.prefill_s));
        o.insert("decode_s".to_string(), Json::Num(self.decode_s));
        o.insert("prefill_tokens".to_string(), Json::Num(self.prefill_tokens as f64));
        o.insert("decode_tokens".to_string(), Json::Num(self.decode_tokens as f64));
        o.insert("decode_tok_per_s".to_string(), Json::Num(self.decode_tok_per_s));
        o.insert("mean_occupancy".to_string(), Json::Num(self.mean_occupancy));
        o.insert("p50_ms".to_string(), Json::Num(self.p50_ms));
        o.insert("p95_ms".to_string(), Json::Num(self.p95_ms));
        o.insert("p99_ms".to_string(), Json::Num(self.p99_ms));
        o.insert("prefill_p50_ms".to_string(), Json::Num(self.prefill_p50_ms));
        o.insert("prefill_p95_ms".to_string(), Json::Num(self.prefill_p95_ms));
        o.insert("prefill_p99_ms".to_string(), Json::Num(self.prefill_p99_ms));
        o.insert("decode_p50_ms".to_string(), Json::Num(self.decode_p50_ms));
        o.insert("decode_p95_ms".to_string(), Json::Num(self.decode_p95_ms));
        o.insert("decode_p99_ms".to_string(), Json::Num(self.decode_p99_ms));
        o.insert("hist_saturated".to_string(), Json::Num(self.hist_saturated as f64));
        o.insert("submitted".to_string(), Json::Num(self.submitted as f64));
        o.insert("rejected".to_string(), Json::Num(self.rejected as f64));
        o.insert("shed".to_string(), Json::Num(self.shed as f64));
        o.insert("cancelled".to_string(), Json::Num(self.cancelled as f64));
        o.insert("deadline_exceeded".to_string(), Json::Num(self.deadline_exceeded as f64));
        o.insert("failed".to_string(), Json::Num(self.failed as f64));
        o.insert("worker_failures".to_string(), Json::Num(self.worker_failures as f64));
        o.insert("retries".to_string(), Json::Num(self.retries as f64));
        o.insert("preemptions".to_string(), Json::Num(self.preemptions as f64));
        o.insert("kv_prefix_hits".to_string(), Json::Num(self.kv_prefix_hits as f64));
        o.insert("kv_cow_copies".to_string(), Json::Num(self.kv_cow_copies as f64));
        o.insert("kv_pages_in_use".to_string(), Json::Num(self.kv_pages_in_use as f64));
        o.insert("kv_pages_total".to_string(), Json::Num(self.kv_pages_total as f64));
        Json::Obj(o)
    }
}

pub struct InferenceServer {
    queue: Arc<(Mutex<Queue>, Condvar)>,
    stats: Arc<ServerStats>,
    worker_stats: Vec<Arc<WorkerStats>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    running: Arc<AtomicBool>,
    /// drain-timeout escalation: stop cooperating, abandon in-flight work
    /// (doubles as every backend's step-interrupt probe)
    abort: Arc<AtomicBool>,
    cfg: ModelConfig,
    /// false when the backend cannot decode incrementally (pjrt AOT
    /// graphs) — generation requests are rejected at submit time
    supports_generate: bool,
    /// the most positions one request can ever hold: `seq_len`, further
    /// capped by the KV page pool when paging is on with an explicit
    /// pool size. A request over this bound resolves `Err(Rejected)` at
    /// submit — it could only ever fail after burning prefill work.
    kv_request_cap: usize,
    opts: ServeOptions,
}

impl InferenceServer {
    /// Spin up `opts.num_workers` backend replicas (one session-owning
    /// thread each, each owning a backend produced by `factory` on that
    /// thread) over a shared request queue. Construction errors from
    /// *any* replica surface here, not on first request.
    pub fn start_backend(factory: BackendFactory, cfg: &ModelConfig,
                         opts: ServeOptions) -> Result<InferenceServer> {
        let num_workers = opts.num_workers.max(1);
        let factory: Arc<BackendFactory> = Arc::new(factory);
        let queue = Arc::new((
            Mutex::new(Queue { pending: VecDeque::new(), shutdown: false }),
            Condvar::new(),
        ));
        let stats = Arc::new(ServerStats::default());
        let running = Arc::new(AtomicBool::new(true));
        let abort = Arc::new(AtomicBool::new(false));
        // live-replica count: the last one out fails whatever is still
        // queued so no client blocks on a dead server
        let alive = Arc::new(AtomicUsize::new(num_workers));
        // each replica reports readiness plus whether its backend can
        // decode incrementally (pjrt cannot)
        let (ready_tx, ready_rx) = channel::<Result<bool>>();
        let mut workers = Vec::with_capacity(num_workers);
        let mut worker_stats = Vec::with_capacity(num_workers);
        for w in 0..num_workers {
            let per = Arc::new(WorkerStats::default());
            worker_stats.push(Arc::clone(&per));
            let ctx = WorkerCtx {
                queue: queue.clone(),
                stats: stats.clone(),
                running: running.clone(),
                abort: abort.clone(),
                alive: alive.clone(),
                max_wait: opts.max_wait,
                score_retries: opts.score_retries,
            };
            let t_factory = Arc::clone(&factory);
            let t_ready = ready_tx.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("perq-serve-{w}"))
                .spawn(move || run_worker(t_factory, ctx, per, t_ready));
            match spawned {
                Ok(handle) => workers.push(handle),
                Err(e) => {
                    // wind down the replicas that did start before bailing
                    {
                        let (lock, cv) = &*queue;
                        if let Ok(mut q) = lock.lock() {
                            q.shutdown = true;
                        }
                        cv.notify_all();
                    }
                    for h in workers {
                        let _ = h.join();
                    }
                    return Err(anyhow!("spawning server worker: {e}"));
                }
            }
        }
        drop(ready_tx);
        // replicas read the same env-resolved paging config the backends
        // do, so the submit-time bound matches what sessions can hold
        let pcfg = PagedConfig::from_env();
        let kv_request_cap = if pcfg.is_paged() && pcfg.pages > 0 {
            cfg.seq_len.min(pcfg.pages * pcfg.page)
        } else {
            cfg.seq_len
        };
        let mut server = InferenceServer {
            queue,
            stats,
            worker_stats,
            workers,
            running: running.clone(),
            abort,
            cfg: cfg.clone(),
            supports_generate: true,
            kv_request_cap,
            opts,
        };
        // every replica must come up; a single failure shuts the rest down
        for _ in 0..num_workers {
            match ready_rx.recv() {
                Ok(Ok(can_decode)) => {
                    server.supports_generate &= can_decode;
                }
                Ok(Err(e)) => {
                    server.shutdown();
                    return Err(e);
                }
                Err(_) => {
                    server.shutdown();
                    return Err(anyhow!("server thread died during startup"));
                }
            }
        }
        Ok(server)
    }

    /// Serve through the device-resident PJRT artifact at `artifact` (an
    /// .hlo.txt path) over (already transformed + quantized) weights;
    /// `extras` are the rotation/format inputs.
    #[cfg(feature = "pjrt")]
    pub fn start(artifact: std::path::PathBuf, cfg: &ModelConfig,
                 ws: &crate::model::weights::WeightSet, extras: Vec<ExtraInput>,
                 opts: ServeOptions) -> Result<InferenceServer> {
        let graph = graph_from_extras(&extras)?;
        // native-only formats (fmt id > 3) must not reach the artifact's
        // lax.switch — it would clamp them to the wrong quantizer
        crate::backend::ensure_artifact_format(&graph)?;
        let cfg2 = cfg.clone();
        let ws2 = ws.clone();
        let factory: BackendFactory = Box::new(move || {
            Ok(Box::new(crate::backend::pjrt::PjrtBackend::load(
                &artifact, &cfg2, &ws2, &graph,
            )?) as Box<dyn ExecBackend>)
        });
        InferenceServer::start_backend(factory, cfg, opts)
    }

    /// Serve through the pure-Rust native backend — no PJRT, no artifacts.
    /// Each replica clones the weight set (packed low-bit twins keep that
    /// cheap for INT4/INT8 graphs).
    pub fn start_native(cfg: &ModelConfig, ws: &crate::model::weights::WeightSet,
                        graph: &crate::backend::ForwardGraph,
                        opts: ServeOptions) -> Result<InferenceServer> {
        let cfg2 = cfg.clone();
        let ws2 = ws.clone();
        let graph = graph.clone();
        let factory: BackendFactory = Box::new(move || {
            Ok(Box::new(crate::backend::NativeBackend::new(
                cfg2.clone(),
                ws2.clone(),
                graph.clone(),
            )?) as Box<dyn ExecBackend>)
        });
        InferenceServer::start_backend(factory, cfg, opts)
    }

    /// Serve a loaded `.perq` deployment artifact — the serve-many half of
    /// quantize-once / serve-many. Native backend only: deployment
    /// artifacts carry no AOT HLO graphs.
    pub fn start_deployed(dm: &crate::deploy::DeployedModel,
                          opts: ServeOptions) -> Result<InferenceServer> {
        InferenceServer::start_native(&dm.cfg, &dm.ws, &dm.graph, opts)
    }

    /// Submit a scoring request with default priority and the server's
    /// default deadline; returns a receiver for the terminal result.
    pub fn submit(&self, tokens: Vec<i32>) -> Result<Receiver<ServeResult<ScoreResponse>>> {
        self.submit_with(tokens, SubmitOpts::default())
    }

    /// Submit a scoring request with explicit priority/deadline.
    pub fn submit_with(&self, tokens: Vec<i32>, opts: SubmitOpts)
                       -> Result<Receiver<ServeResult<ScoreResponse>>> {
        ensure!(tokens.len() == self.cfg.seq_len + 1,
                "requests carry seq_len+1 tokens (window + next-token target)");
        // validate every token here — including the final next-token
        // target, which never flows through prefill's own check; an
        // out-of-vocab target must fail the submit, not panic a worker
        self.check_tokens(&tokens)?;
        let (tx, rx) = channel();
        self.push(Request::Score(ScoreRequest {
            tokens,
            submitted: Instant::now(),
            trace_id: self.stats.traces.next_id(),
            priority: opts.priority,
            deadline: self.effective_deadline(opts),
            attempts: 0,
            respond: tx,
        }))?;
        Ok(rx)
    }

    /// Submit many score windows under ONE queue lock, so capacity
    /// admission is deterministic with respect to this batch's order: with
    /// `queue_cap = C` and an idle server, exactly the first `C` windows
    /// are admitted and the rest resolve `Err(QueueFull)` — regardless of
    /// replica scheduling.
    pub fn submit_batch(&self, windows: Vec<Vec<i32>>, opts: SubmitOpts)
                        -> Result<Vec<Receiver<ServeResult<ScoreResponse>>>> {
        for tokens in &windows {
            ensure!(tokens.len() == self.cfg.seq_len + 1,
                    "requests carry seq_len+1 tokens (window + next-token target)");
            self.check_tokens(tokens)?;
        }
        let deadline = self.effective_deadline(opts);
        let mut rxs = Vec::with_capacity(windows.len());
        let mut rejects = Vec::new();
        {
            let (lock, cv) = &*self.queue;
            let mut q = lock.lock().unwrap();
            ensure!(!q.shutdown, "server is shut down");
            for tokens in windows {
                let (tx, rx) = channel();
                rxs.push(rx);
                self.stats.submitted.inc();
                let req = Request::Score(ScoreRequest {
                    tokens,
                    submitted: Instant::now(),
                    trace_id: self.stats.traces.next_id(),
                    priority: opts.priority,
                    deadline,
                    attempts: 0,
                    respond: tx,
                });
                if let Some(reject) = admit_locked(&mut q.pending, self.opts.queue_cap, req) {
                    rejects.push(reject);
                }
            }
            self.stats.queue_depth.set(q.pending.len() as i64);
            cv.notify_all();
        }
        for (victim, err) in rejects {
            resolve_unserved(&self.stats, victim, err);
        }
        Ok(rxs)
    }

    /// Submit a generation request (greedy sampling) with default
    /// priority/deadline; returns a receiver for the terminal result. The
    /// request joins a replica's live batch at the next step boundary and
    /// holds one slot until `max_new_tokens` are produced.
    pub fn submit_generate(&self, prompt: Vec<i32>, max_new_tokens: usize)
                           -> Result<Receiver<ServeResult<GenerateResponse>>> {
        self.submit_generate_with(prompt, max_new_tokens, SubmitOpts::default())
    }

    /// Submit a generation request with explicit priority/deadline.
    pub fn submit_generate_with(&self, prompt: Vec<i32>, max_new_tokens: usize,
                                opts: SubmitOpts)
                                -> Result<Receiver<ServeResult<GenerateResponse>>> {
        self.submit_generate_stream(prompt, max_new_tokens, opts, None, None)
    }

    /// Submit a generation request with per-token streaming and/or
    /// cooperative cancellation — the network front door's entry point.
    ///
    /// Each sampled token is sent into `stream` the moment it exists (the
    /// first right after prompt prefill, then one per decode step); the
    /// final [`GenerateResponse`] still arrives on the returned receiver.
    /// Setting `cancel` resolves the request `Err(Cancelled)` and frees
    /// its slot at the worker's next sweep — the disconnect path.
    pub fn submit_generate_stream(&self, prompt: Vec<i32>, max_new_tokens: usize,
                                  opts: SubmitOpts, stream: Option<Sender<i32>>,
                                  cancel: Option<Arc<AtomicBool>>)
                                  -> Result<Receiver<ServeResult<GenerateResponse>>> {
        ensure!(
            self.supports_generate,
            "this server's backend cannot decode incrementally (fixed-shape AOT \
             graphs) — generation requires the native backend"
        );
        ensure!(!prompt.is_empty(), "generation needs a non-empty prompt");
        ensure!(max_new_tokens >= 1, "generation needs max_new_tokens >= 1");
        ensure!(
            prompt.len() + max_new_tokens <= self.cfg.seq_len,
            "prompt ({}) + max_new_tokens ({max_new_tokens}) exceeds the model's \
             seq_len ({})",
            prompt.len(),
            self.cfg.seq_len
        );
        self.check_tokens(&prompt)?;
        let (tx, rx) = channel();
        let req = GenerateRequest {
            prompt,
            max_new_tokens,
            submitted: Instant::now(),
            trace_id: self.stats.traces.next_id(),
            priority: opts.priority,
            deadline: self.effective_deadline(opts),
            stream,
            cancel,
            respond: tx,
        };
        // within seq_len but beyond the KV page pool: no replica could
        // ever hold this request, so it resolves through the channel as
        // a typed terminal rejection (HTTP 400, counted in `rejected` so
        // the completion contract still balances) instead of queueing up
        // work that must fail
        if req.prompt.len() + max_new_tokens > self.kv_request_cap {
            self.stats.submitted.inc();
            resolve_unserved(&self.stats, Request::Generate(req), ServeError::Rejected);
            return Ok(rx);
        }
        self.push(Request::Generate(req))?;
        Ok(rx)
    }

    /// Per-request deadline wins; otherwise the server default (if any)
    /// starts counting at submit time.
    fn effective_deadline(&self, opts: SubmitOpts) -> Option<Instant> {
        opts.deadline.or_else(|| self.opts.deadline.map(|d| Instant::now() + d))
    }

    fn check_tokens(&self, tokens: &[i32]) -> Result<()> {
        for &t in tokens {
            ensure!(
                t >= 0 && (t as usize) < self.cfg.vocab,
                "token {t} outside the model's vocab (0..{})",
                self.cfg.vocab
            );
        }
        Ok(())
    }

    fn push(&self, req: Request) -> Result<()> {
        let reject = {
            let (lock, cv) = &*self.queue;
            let mut q = lock.lock().unwrap();
            ensure!(!q.shutdown, "server is shut down");
            self.stats.submitted.inc();
            let reject = admit_locked(&mut q.pending, self.opts.queue_cap, req);
            self.stats.queue_depth.set(q.pending.len() as i64);
            cv.notify_one();
            reject
        };
        // rejections resolve outside the lock (channel send + trace)
        if let Some((victim, err)) = reject {
            resolve_unserved(&self.stats, victim, err);
        }
        Ok(())
    }

    /// (served, batches, exec seconds) — the legacy aggregate triple
    /// (`served` counts completed requests of both kinds).
    pub fn stats(&self) -> (u64, u64, f64) {
        let served = self.stats.served.get();
        let batches = self.stats.batches.get();
        let exec_s = self.stats.exec_ns.get() as f64 / 1e9;
        (served, batches, exec_s)
    }

    /// A full coherent statistics read: request counts, per-phase
    /// execution/throughput, occupancy, percentiles, failure counters.
    pub fn snapshot(&self) -> StatsSnapshot {
        self.stats.snapshot()
    }

    /// Per-replica (served, batches, exec seconds) snapshots, in worker
    /// order. Sums match the aggregate [`InferenceServer::stats`].
    pub fn per_worker_stats(&self) -> Vec<(u64, u64, f64)> {
        self.worker_stats
            .iter()
            .map(|w| {
                (
                    w.served.load(Ordering::Relaxed),
                    w.batches.load(Ordering::Relaxed),
                    w.exec_ns.load(Ordering::Relaxed) as f64 / 1e9,
                )
            })
            .collect()
    }

    /// Backend replica count.
    pub fn num_workers(&self) -> usize {
        self.worker_stats.len()
    }

    /// The serving policy this server was started with.
    pub fn options(&self) -> &ServeOptions {
        &self.opts
    }

    /// Server-side request-latency percentiles (p50, p95, p99) in ms from
    /// the fixed-bucket histogram (~19% bucket resolution).
    pub fn latency_percentiles(&self) -> (f64, f64, f64) {
        let h = &self.stats.latency;
        (h.percentile(0.50), h.percentile(0.95), h.percentile(0.99))
    }

    /// The metrics registry behind this server's statistics. Render with
    /// `render_prometheus()` (text exposition format) or `snapshot_json()`;
    /// both read the same atomics [`InferenceServer::snapshot`] does.
    pub fn registry(&self) -> Arc<Registry> {
        Arc::clone(&self.stats.registry)
    }

    /// Shared handle to the live statistics — for periodic metric dumps
    /// that outlive a `&self` borrow (e.g. the `--metrics-out` writer
    /// thread and its exit-time flush guard).
    pub fn shared_stats(&self) -> Arc<ServerStats> {
        Arc::clone(&self.stats)
    }

    /// Completed request-lifecycle traces currently in the ring buffer,
    /// oldest first.
    pub fn recent_traces(&self) -> Vec<RequestTrace> {
        self.stats.traces.recent_traces()
    }

    fn signal_shutdown(&self) {
        self.running.store(false, Ordering::Relaxed);
        let (lock, cv) = &*self.queue;
        if let Ok(mut q) = lock.lock() {
            q.shutdown = true;
        }
        cv.notify_all();
    }

    /// Begin graceful drain through a shared handle (`&self`, unlike
    /// [`shutdown`]): admission stops (new submits fail), replicas finish
    /// queued + in-flight work and then exit. The network front door
    /// calls this the moment drain begins; the replicas are joined later
    /// when the last owner drops. Idempotent.
    ///
    /// [`shutdown`]: InferenceServer::shutdown
    pub fn begin_shutdown(&self) {
        self.signal_shutdown();
    }

    /// Drain-timeout escalation through a shared handle: abandon whatever
    /// is still queued or mid-step (the abort flag doubles as every
    /// backend's step interrupt) so a drain can never hang on a stuck
    /// request. Still-unserved requests resolve `Err(ShuttingDown)`.
    pub fn abort_in_flight(&self) {
        self.abort.store(true, Ordering::Relaxed);
        let (_, cv) = &*self.queue;
        cv.notify_all();
    }

    /// Graceful drain: stop admission, let replicas finish queued and
    /// in-flight work, then — once `timeout` expires — abort whatever is
    /// still running (the abort flag is every backend's step interrupt,
    /// so even a mid-step replica unwinds at its next cancellation point).
    fn drain(&mut self, timeout: Duration) {
        if self.workers.is_empty() {
            return;
        }
        self.signal_shutdown();
        let deadline = Instant::now() + timeout;
        while self.workers.iter().any(|w| !w.is_finished()) {
            if Instant::now() >= deadline {
                crate::log_warn!(
                    "server: drain timeout ({} ms) expired — aborting in-flight work",
                    timeout.as_millis()
                );
                self.abort.store(true, Ordering::Relaxed);
                let (_, cv) = &*self.queue;
                cv.notify_all();
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    /// Drain with the configured `drain_timeout` and join the replicas.
    /// Every still-unserved request resolves to `Err(ShuttingDown)`.
    pub fn shutdown(mut self) {
        let timeout = self.opts.drain_timeout;
        self.drain(timeout);
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        let timeout = self.opts.drain_timeout;
        self.drain(timeout);
    }
}

/// Recover the graph description from legacy (matrix.., fmt) extras — the
/// shape the pjrt `start` entry point and the integration suite still use.
#[cfg(feature = "pjrt")]
fn graph_from_extras(extras: &[ExtraInput]) -> Result<crate::backend::ForwardGraph> {
    use crate::backend::ForwardGraph;
    use crate::quant::Format;
    let fmt = extras
        .iter()
        .find_map(|e| match e {
            ExtraInput::ScalarI32(v) => Some(*v),
            _ => None,
        })
        .unwrap_or(0);
    let format = match fmt {
        1 => Format::Int4,
        2 => Format::Fp4,
        3 => Format::Mxfp4,
        4 => Format::Int8,
        _ => Format::None,
    };
    let mats = extras
        .iter()
        .filter(|e| matches!(e, ExtraInput::Matrix(_)))
        .count();
    if mats >= 2 {
        return Ok(ForwardGraph::Online { format });
    }
    let b = extras
        .iter()
        .find_map(|e| match e {
            ExtraInput::Matrix(m) => Some(m.rows),
            _ => None,
        })
        .unwrap_or(1);
    Ok(ForwardGraph::Merged { r3_block: b, format })
}

/// A generation request currently occupying a session slot.
struct ActiveGen {
    req: GenerateRequest,
    generated: Vec<i32>,
    /// when a replica pulled the request off the queue
    admitted: Instant,
    /// when the prompt prefill (+ first token) completed
    prefilled: Instant,
}

/// A generation swapped out of its slot under KV page pressure: the raw
/// cache rows ride in host memory until pages free up, then `swap_in`
/// restores them bit-identically and decode resumes where it stopped.
struct PreemptedGen {
    active: ActiveGen,
    swap: KvSwap,
    /// the token to feed the next decode step after resume
    last_token: i32,
}

/// Preemption victim: the lowest-priority active generation; the most
/// recently admitted breaks ties (it has the least sunk decode work).
fn pick_victim(gen_slots: &[Option<ActiveGen>]) -> Option<usize> {
    (0..gen_slots.len())
        .filter(|&s| gen_slots[s].is_some())
        .min_by_key(|&s| {
            let a = gen_slots[s].as_ref().expect("filtered above");
            // min_by_key keeps the FIRST minimum, so invert the admit
            // order: later admission must compare smaller
            (a.req.priority, std::cmp::Reverse(a.admitted))
        })
}

use crate::backend::greedy_argmax as argmax;

/// Mean next-token NLL of one scored window from its prefill logits.
fn window_nll(logits: &[f32], tokens: &[i32], t: usize, v: usize) -> f64 {
    let mut nll = 0.0f64;
    for j in 0..t {
        let row = &logits[j * v..(j + 1) * v];
        let mx = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x)) as f64;
        let lse: f64 = row.iter().map(|&x| ((x as f64) - mx).exp()).sum();
        let tgt = tokens[j + 1] as usize;
        nll += mx + lse.ln() - row[tgt] as f64;
    }
    nll / t as f64
}

/// Run one engine step under `catch_unwind`: `Ok(result)` is the
/// backend's own result; `Err(msg)` means the step panicked and the
/// replica's sessions must be treated as poisoned. `AssertUnwindSafe` is
/// sound here because a panicking backend is *discarded*, never reused.
fn guard<T>(f: impl FnOnce() -> Result<T>) -> std::result::Result<Result<T>, String> {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)) {
        Ok(result) => Ok(result),
        Err(payload) => Err(panic_message(payload)),
    }
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic (non-string payload)".to_string()
    }
}

/// Tally one terminal failure in the counters the completion contract is
/// audited against (served + rejected + deadline_exceeded + failed ==
/// submitted; shed is a sub-count of rejected).
fn count_failure(stats: &ServerStats, err: ServeError) {
    match err {
        ServeError::QueueFull | ServeError::Rejected | ServeError::ShuttingDown => {
            stats.rejected.inc()
        }
        ServeError::Shed => {
            stats.shed.inc();
            stats.rejected.inc();
        }
        ServeError::DeadlineExceeded => stats.deadline_exceeded.inc(),
        ServeError::WorkerFailed => stats.failures.inc(),
        ServeError::Cancelled => {
            stats.cancelled.inc();
            stats.rejected.inc();
        }
    }
}

/// Resolve a request that never reached an engine step: count it, leave
/// its trace (all queue time), and deliver the error to the client.
fn resolve_unserved(stats: &ServerStats, req: Request, err: ServeError) {
    count_failure(stats, err);
    let (id, kind, submitted) = match &req {
        Request::Score(r) => (r.trace_id, "score", r.submitted),
        Request::Generate(r) => (r.trace_id, "generate", r.submitted),
    };
    let total_ms = ms(submitted.elapsed());
    stats.traces.record(RequestTrace {
        id,
        kind,
        queued_ms: total_ms,
        prefill_ms: 0.0,
        decode_ms: 0.0,
        total_ms,
        decode_steps: 0,
        ok: false,
        outcome: err.as_str(),
    });
    match req {
        Request::Score(r) => {
            let _ = r.respond.send(Err(err));
        }
        Request::Generate(r) => {
            let _ = r.respond.send(Err(err));
        }
    }
}

/// Resolve an in-flight generation (slot already held, spans real): count
/// it, trace it with its actual phase timings, deliver the error.
fn fail_active(stats: &ServerStats, active: ActiveGen, err: ServeError) {
    count_failure(stats, err);
    stats.traces.record(RequestTrace {
        id: active.req.trace_id,
        kind: "generate",
        queued_ms: ms(active.admitted - active.req.submitted),
        prefill_ms: ms(active.prefilled - active.admitted),
        decode_ms: ms(active.prefilled.elapsed()),
        total_ms: ms(active.req.submitted.elapsed()),
        decode_steps: (active.generated.len() as u64).saturating_sub(1),
        ok: false,
        outcome: err.as_str(),
    });
    let _ = active.req.respond.send(Err(err));
}

/// Resolve a generation whose prompt prefill failed or panicked.
fn fail_gen_prefill(stats: &ServerStats, req: GenerateRequest, admitted: Instant,
                    exec_ns: u64, err: ServeError) {
    count_failure(stats, err);
    stats.traces.record(RequestTrace {
        id: req.trace_id,
        kind: "generate",
        queued_ms: ms(admitted - req.submitted),
        prefill_ms: exec_ns as f64 / 1e6,
        decode_ms: 0.0,
        total_ms: ms(req.submitted.elapsed()),
        decode_steps: 0,
        ok: false,
        outcome: err.as_str(),
    });
    let _ = req.respond.send(Err(err));
}

/// Everything a replica thread needs besides its backend — shared
/// handles cloned once at spawn, reused across respawns.
struct WorkerCtx {
    queue: Arc<(Mutex<Queue>, Condvar)>,
    stats: Arc<ServerStats>,
    running: Arc<AtomicBool>,
    abort: Arc<AtomicBool>,
    /// live-replica count (see `worker_epilogue`)
    alive: Arc<AtomicUsize>,
    max_wait: Duration,
    score_retries: u32,
}

/// Why `run_replica` returned.
enum ReplicaExit {
    /// drain complete or abort requested — the worker thread exits
    Clean,
    /// an engine step panicked: sessions are quarantined, the worker
    /// respawns a fresh backend from the factory
    Poisoned,
    /// the backend could not even open its sessions — don't respawn,
    /// it would fail the same way
    Fatal,
}

/// Worker thread body: construct the backend, report readiness, then run
/// replica incarnations until drain — respawning after each poisoning.
fn run_worker(factory: Arc<BackendFactory>, ctx: WorkerCtx, mine: Arc<WorkerStats>,
              ready: Sender<Result<bool>>) {
    let mut backend = match (*factory)() {
        Ok(b) => {
            let _ = ready.send(Ok(b.supports_decode()));
            b
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            worker_epilogue(&ctx);
            return;
        }
    };
    drop(ready);
    backend.set_step_interrupt(Some(ctx.abort.clone()));
    loop {
        match run_replica(backend, &ctx, &mine) {
            ReplicaExit::Clean | ReplicaExit::Fatal => break,
            ReplicaExit::Poisoned => {
                ctx.stats.worker_failures.inc();
                if !ctx.running.load(Ordering::Relaxed) || ctx.abort.load(Ordering::Relaxed) {
                    break;
                }
                match (*factory)() {
                    Ok(mut b) => {
                        b.set_step_interrupt(Some(ctx.abort.clone()));
                        crate::log_warn!(
                            "server: replica poisoned by a panic — respawned a fresh backend"
                        );
                        backend = b;
                    }
                    Err(e) => {
                        crate::log_error!("server: respawning replica failed: {e:#}");
                        break;
                    }
                }
            }
        }
    }
    worker_epilogue(&ctx);
}

/// The last replica out resolves whatever is still queued (requeued
/// retries, work admitted during a crash cascade) as `ShuttingDown`, and
/// closes the queue so later submits fail fast — no client ever blocks
/// on a server with no workers left.
fn worker_epilogue(ctx: &WorkerCtx) {
    if ctx.alive.fetch_sub(1, Ordering::AcqRel) != 1 {
        return;
    }
    let pending: Vec<Request> = {
        let (lock, cv) = &*ctx.queue;
        let mut q = lock.lock().unwrap();
        q.shutdown = true;
        let pending = q.pending.drain(..).collect();
        ctx.stats.queue_depth.set(0);
        cv.notify_all();
        pending
    };
    for req in pending {
        resolve_unserved(&ctx.stats, req, ServeError::ShuttingDown);
    }
}

/// One replica incarnation: a backend session with `cfg.batch` slots,
/// driven at step granularity until drain (`Clean`), a session-opening
/// failure (`Fatal`), or a panic in an engine step (`Poisoned` — every
/// in-flight or untouched request is resolved or requeued first).
fn run_replica(mut backend: Box<dyn ExecBackend>, ctx: &WorkerCtx,
               mine: &Arc<WorkerStats>) -> ReplicaExit {
    let b = backend.cfg().batch;
    let t = backend.cfg().seq_len;
    let v = backend.cfg().vocab;
    // two sessions per replica: generation rides the backend's default
    // KV mode (quantized cache); score requests run in an *exact* scoring
    // session so served NLLs match the eval/`score` path bit-for-bit
    let sid: SessionId = match backend.begin(b) {
        Ok(s) => s,
        Err(e) => {
            crate::log_error!("server: opening execution session failed: {e:#}");
            return ReplicaExit::Fatal;
        }
    };
    let sid_score: SessionId = match backend.begin_scoring(b) {
        Ok(s) => s,
        Err(e) => {
            crate::log_error!("server: opening scoring session failed: {e:#}");
            return ReplicaExit::Fatal;
        }
    };
    let mut gen_slots: Vec<Option<ActiveGen>> = (0..b).map(|_| None).collect();
    let mut last_tokens: Vec<i32> = vec![-1; b];
    let mut logits_buf: Vec<f32> = Vec::new();
    // generations swapped out of their slots under KV page pressure,
    // oldest first — resumed (swap_in, bit-identical) before new work is
    // admitted so a preempted request can never be starved by arrivals
    let mut preempted: VecDeque<PreemptedGen> = VecDeque::new();

    loop {
        // drain-timeout escalation: abandon in-flight generations and exit
        if ctx.abort.load(Ordering::Relaxed) {
            for slot in gen_slots.iter_mut() {
                if let Some(active) = slot.take() {
                    fail_active(&ctx.stats, active, ServeError::ShuttingDown);
                }
            }
            for p in preempted.drain(..) {
                fail_active(&ctx.stats, p.active, ServeError::ShuttingDown);
            }
            return ReplicaExit::Clean;
        }
        // -- resume pass: swapped-out generations re-enter first ----------
        while let Some(p) = preempted.pop_front() {
            let Some(slot) = (0..b).find(|&s| gen_slots[s].is_none()) else {
                preempted.push_front(p);
                break;
            };
            match guard(|| backend.swap_in_slot(sid, slot, &p.swap)) {
                Ok(Ok(())) => {
                    last_tokens[slot] = p.last_token;
                    gen_slots[slot] = Some(p.active);
                }
                Ok(Err(e)) if e.downcast_ref::<OutOfPages>().is_some() => {
                    // pages still pinned — try again next iteration, after
                    // decode progress (completions) frees some
                    preempted.push_front(p);
                    break;
                }
                Ok(Err(e)) => {
                    crate::log_error!("server: resuming preempted request failed: {e:#}");
                    let _ = backend.reset_slot(sid, slot);
                    fail_active(&ctx.stats, p.active, ServeError::WorkerFailed);
                }
                Err(panic_msg) => {
                    crate::log_error!("server: swap-in panicked: {panic_msg}");
                    fail_active(&ctx.stats, p.active, ServeError::WorkerFailed);
                    poison_cleanup(ctx, &mut gen_slots, &mut preempted, Vec::new());
                    return ReplicaExit::Poisoned;
                }
            }
        }
        let n_active = gen_slots.iter().filter(|s| s.is_some()).count();
        // requests that died while queued (deadline expired, or the
        // client abandoned them), resolved after the lock drops
        let mut swept: Vec<(Request, ServeError)> = Vec::new();
        // -- pull work: block only when fully idle ------------------------
        let (score_reqs, gen_reqs): (Vec<ScoreRequest>, Vec<GenerateRequest>) = {
            let (lock, cv) = &*ctx.queue;
            let mut q = lock.lock().unwrap();
            let mut draining = q.shutdown || !ctx.running.load(Ordering::Relaxed);
            if n_active == 0 && preempted.is_empty() && !draining {
                while q.pending.is_empty()
                    && !q.shutdown
                    && ctx.running.load(Ordering::Relaxed)
                    && !ctx.abort.load(Ordering::Relaxed)
                {
                    q = cv.wait(q).unwrap();
                }
                draining = q.shutdown || !ctx.running.load(Ordering::Relaxed);
                // batch-forming wait: give peers up to max_wait to arrive
                // so the prefill runs fuller (idle workers only — a worker
                // with live decode slots never stalls here)
                if !draining && !ctx.abort.load(Ordering::Relaxed) {
                    let deadline = Instant::now() + ctx.max_wait;
                    while q.pending.len() < b && !q.shutdown {
                        let now = Instant::now();
                        if now >= deadline {
                            break;
                        }
                        let (qq, timeout) = cv.wait_timeout(q, deadline - now).unwrap();
                        q = qq;
                        if timeout.timed_out() {
                            break;
                        }
                    }
                    draining = q.shutdown || !ctx.running.load(Ordering::Relaxed);
                }
            }
            if draining && q.pending.is_empty() && n_active == 0 && preempted.is_empty() {
                return ReplicaExit::Clean;
            }
            // FIFO admission: scores fill the scoring session (up to b),
            // generations fill the free generation slots; stop at the
            // first request that doesn't fit so nothing is overtaken.
            // Dead-on-arrival requests (deadline already behind us) are
            // pulled out without consuming admission capacity.
            // slots held back for swapped-out generations: new arrivals
            // must not occupy every slot a preempted request needs back
            let free_gen = (b - n_active).saturating_sub(preempted.len());
            let mut scores = Vec::new();
            let mut gens = Vec::new();
            let now = Instant::now();
            loop {
                if q.pending.front().map_or(false, |r| r.is_cancelled()) {
                    swept.push((q.pending.pop_front().expect("front checked above"),
                                ServeError::Cancelled));
                    continue;
                }
                if q.pending.front().map_or(false, |r| r.is_expired(now)) {
                    swept.push((q.pending.pop_front().expect("front checked above"),
                                ServeError::DeadlineExceeded));
                    continue;
                }
                let fits = match q.pending.front() {
                    Some(Request::Score(_)) => scores.len() < b,
                    Some(Request::Generate(_)) => gens.len() < free_gen,
                    None => false,
                };
                if !fits {
                    break;
                }
                match q.pending.pop_front().expect("front checked above") {
                    Request::Score(s) => scores.push(s),
                    Request::Generate(g) => gens.push(g),
                }
            }
            ctx.stats.queue_depth.set(q.pending.len() as i64);
            (scores, gens)
        };
        for (req, err) in swept {
            resolve_unserved(&ctx.stats, req, err);
        }
        // admission stamp for everything pulled this round (trace span:
        // enqueue → admit)
        let admitted = Instant::now();

        // -- score admissions: one batched prefill (exact session) --------
        if !score_reqs.is_empty() {
            // occupancy of THIS engine step: the score windows it runs
            let occupancy = score_reqs.len();
            let slots: Vec<usize> = (0..score_reqs.len()).collect();
            let mut tokens = Vec::with_capacity(slots.len() * t);
            for req in &score_reqs {
                tokens.extend_from_slice(&req.tokens[..t]);
            }
            let t_exec = Instant::now();
            let result = guard(|| backend.prefill_slots(sid_score, &slots, &tokens));
            let exec_ns = t_exec.elapsed().as_nanos() as u64;
            record_step(&ctx.stats, mine, exec_ns, true, (slots.len() * t) as u64,
                        occupancy as u64);
            match result {
                Ok(Ok(logits)) => {
                    // respond before releasing slots: the logits are
                    // already extracted, so nothing can lose these
                    for (i, req) in score_reqs.into_iter().enumerate() {
                        let nll = window_nll(&logits[i * t * v..(i + 1) * t * v],
                                             &req.tokens, t, v);
                        let latency = req.submitted.elapsed();
                        ctx.stats.served.inc();
                        mine.served.fetch_add(1, Ordering::Relaxed);
                        ctx.stats.latency.record(latency);
                        ctx.stats.traces.record(RequestTrace {
                            id: req.trace_id,
                            kind: "score",
                            queued_ms: ms(admitted - req.submitted),
                            prefill_ms: exec_ns as f64 / 1e6,
                            decode_ms: 0.0,
                            total_ms: ms(latency),
                            decode_steps: 0,
                            ok: true,
                            outcome: "completed",
                        });
                        let _ = req.respond.send(Ok(ScoreResponse {
                            nll,
                            latency,
                            batch_occupancy: occupancy,
                        }));
                    }
                    for &slot in &slots {
                        if let Err(e) = backend.reset_slot(sid_score, slot) {
                            crate::log_warn!("server: releasing score slot {slot} failed: {e:#}");
                        }
                    }
                }
                Ok(Err(e)) => {
                    crate::log_error!("server: score prefill failed: {e:#}");
                    for &slot in &slots {
                        let _ = backend.reset_slot(sid_score, slot);
                    }
                    retry_or_fail_scores(ctx, score_reqs);
                }
                Err(panic_msg) => {
                    crate::log_error!("server: score prefill panicked: {panic_msg}");
                    retry_or_fail_scores(ctx, score_reqs);
                    poison_cleanup(ctx, &mut gen_slots, &mut preempted, Vec::new());
                    return ReplicaExit::Poisoned;
                }
            }
        }

        // -- generation admissions: prefill prompts into free slots -------
        let mut gen_iter = gen_reqs.into_iter();
        while let Some(req) = gen_iter.next() {
            let Some(slot) = (0..b).find(|&s| gen_slots[s].is_none()) else {
                crate::log_warn!("server: admission raced past capacity — requeueing");
                let rest: Vec<GenerateRequest> = std::iter::once(req).chain(gen_iter).collect();
                let (lock, cv) = &*ctx.queue;
                if let Ok(mut q) = lock.lock() {
                    for r in rest.into_iter().rev() {
                        q.pending.push_front(Request::Generate(r));
                    }
                    ctx.stats.queue_depth.set(q.pending.len() as i64);
                }
                cv.notify_one();
                break;
            };
            let t_exec = Instant::now();
            // prefix-aware prefill: tokens shared with an earlier prompt
            // come out of the KV prefix cache; only the suffix is computed
            let result = guard(|| backend.prefill_prefixed(sid, slot, &req.prompt));
            let exec_ns = t_exec.elapsed().as_nanos() as u64;
            match result {
                Ok(Ok((logits, matched))) => {
                    // a prompt prefill is its own engine step, running 1
                    // request over the un-shared suffix
                    let suffix = req.prompt.len() - matched;
                    record_step(&ctx.stats, mine, exec_ns, true, suffix as u64, 1);
                    // greedy first token from the last prompt position —
                    // always the last row of the suffix logits (matched is
                    // capped below the full prompt length)
                    let first = argmax(&logits[(suffix - 1) * v..suffix * v]);
                    let prefilled = Instant::now();
                    ctx.stats.prefill_lat.record(prefilled - req.submitted);
                    if let Some(tx) = &req.stream {
                        // best-effort: a vanished stream consumer shows up
                        // as a cancel, never as a serving error
                        let _ = tx.send(first);
                    }
                    let active =
                        ActiveGen { req, generated: vec![first], admitted, prefilled };
                    if active.generated.len() >= active.req.max_new_tokens {
                        finish_generation(&ctx.stats, mine, active);
                        let _ = backend.reset_slot(sid, slot);
                    } else {
                        last_tokens[slot] = first;
                        gen_slots[slot] = Some(active);
                    }
                }
                Ok(Err(e)) => {
                    record_step(&ctx.stats, mine, exec_ns, true, req.prompt.len() as u64, 1);
                    let _ = backend.reset_slot(sid, slot);
                    if e.downcast_ref::<OutOfPages>().is_some()
                        && !ctx.abort.load(Ordering::Relaxed)
                    {
                        // the page pool can't hold this prompt *right
                        // now*. The typed error fires before any cache
                        // write, so the request is untouched: with work
                        // in flight, completions will free pages —
                        // requeue this admission round at the front and
                        // retry. With nothing running it can never fit.
                        let n_live = gen_slots.iter().filter(|s| s.is_some()).count();
                        if n_live > 0 || !preempted.is_empty() {
                            crate::log_warn!(
                                "server: KV pages exhausted at prefill — requeueing \
                                 request {} until decode work completes",
                                req.trace_id
                            );
                            let rest: Vec<GenerateRequest> =
                                std::iter::once(req).chain(gen_iter).collect();
                            let (lock, cv) = &*ctx.queue;
                            if let Ok(mut q) = lock.lock() {
                                for r in rest.into_iter().rev() {
                                    q.pending.push_front(Request::Generate(r));
                                }
                                ctx.stats.queue_depth.set(q.pending.len() as i64);
                            }
                            cv.notify_one();
                            break;
                        }
                    }
                    crate::log_error!("server: prompt prefill failed: {e:#}");
                    let err = if ctx.abort.load(Ordering::Relaxed) {
                        ServeError::ShuttingDown
                    } else {
                        ServeError::WorkerFailed
                    };
                    fail_gen_prefill(&ctx.stats, req, admitted, exec_ns, err);
                }
                Err(panic_msg) => {
                    record_step(&ctx.stats, mine, exec_ns, true, req.prompt.len() as u64, 1);
                    crate::log_error!("server: prompt prefill panicked: {panic_msg}");
                    fail_gen_prefill(&ctx.stats, req, admitted, exec_ns,
                                     ServeError::WorkerFailed);
                    // the rest of this admission round never touched the
                    // backend — requeue it untouched (not a retry)
                    poison_cleanup(ctx, &mut gen_slots, &mut preempted, gen_iter.collect());
                    return ReplicaExit::Poisoned;
                }
            }
        }

        // -- one decode step over every active slot -----------------------
        let n_active = gen_slots.iter().filter(|s| s.is_some()).count();
        if n_active == 0 {
            continue;
        }
        // cancel + deadline sweep between decode steps: a request whose
        // client vanished or whose deadline passed frees its slot instead
        // of burning further decode work
        let now = Instant::now();
        for slot in 0..b {
            let verdict = gen_slots[slot].as_ref().and_then(|a| {
                let cancelled =
                    a.req.cancel.as_ref().map_or(false, |c| c.load(Ordering::Relaxed));
                if cancelled {
                    Some(ServeError::Cancelled)
                } else if a.req.deadline.map_or(false, |d| now >= d) {
                    Some(ServeError::DeadlineExceeded)
                } else {
                    None
                }
            });
            if let Some(err) = verdict {
                let active = gen_slots[slot].take().expect("checked above");
                fail_active(&ctx.stats, active, err);
                last_tokens[slot] = -1;
                let _ = backend.reset_slot(sid, slot);
            }
        }
        // the same sweep over swapped-out requests: an expired or
        // abandoned preemptee must not wait for a free slot to resolve
        let mut i = 0;
        while i < preempted.len() {
            let a = &preempted[i].active;
            let cancelled =
                a.req.cancel.as_ref().map_or(false, |c| c.load(Ordering::Relaxed));
            let expired = a.req.deadline.map_or(false, |d| now >= d);
            if cancelled || expired {
                let p = preempted.remove(i).expect("index bounded above");
                let err = if cancelled {
                    ServeError::Cancelled
                } else {
                    ServeError::DeadlineExceeded
                };
                fail_active(&ctx.stats, p.active, err);
            } else {
                i += 1;
            }
        }
        // -- the decode step, with page-pressure preemption: an
        // OutOfPages step fails *before any cache write*, so after
        // swapping the lowest-priority generation out to host memory the
        // same step re-runs bit-identically for the survivors
        'decode: loop {
            let n_active = gen_slots.iter().filter(|s| s.is_some()).count();
            if n_active == 0 {
                break 'decode;
            }
            let t_exec = Instant::now();
            let result =
                guard(|| backend.decode_step_into(sid, &last_tokens, &mut logits_buf));
            let exec_ns = t_exec.elapsed().as_nanos() as u64;
            record_step(&ctx.stats, mine, exec_ns, false, n_active as u64, n_active as u64);
            match result {
                Ok(Ok(())) => {
                    // tokens count only for steps that actually produced them
                    ctx.stats.decode_tokens.add(n_active as u64);
                    for slot in 0..b {
                        if gen_slots[slot].is_none() {
                            continue;
                        }
                        let tok = argmax(&logits_buf[slot * v..(slot + 1) * v]);
                        let done = {
                            let active = gen_slots[slot].as_mut().expect("checked above");
                            active.generated.push(tok);
                            if let Some(tx) = &active.req.stream {
                                let _ = tx.send(tok);
                            }
                            active.generated.len() >= active.req.max_new_tokens
                        };
                        if done {
                            let finished = gen_slots[slot].take().expect("checked above");
                            finish_generation(&ctx.stats, mine, finished);
                            last_tokens[slot] = -1;
                            let _ = backend.reset_slot(sid, slot);
                        } else {
                            last_tokens[slot] = tok;
                        }
                    }
                    break 'decode;
                }
                Ok(Err(e))
                    if e.downcast_ref::<OutOfPages>().is_some()
                        && n_active > 1
                        && !ctx.abort.load(Ordering::Relaxed) =>
                {
                    let victim = pick_victim(&gen_slots).expect("n_active > 1");
                    match guard(|| backend.swap_out_slot(sid, victim)) {
                        Ok(Ok(Some(swap))) => {
                            let active = gen_slots[victim].take().expect("picked above");
                            crate::log_warn!(
                                "server: KV pages exhausted — preempting request {} \
                                 ({} cached positions swapped out)",
                                active.req.trace_id,
                                swap.len()
                            );
                            preempted.push_back(PreemptedGen {
                                active,
                                swap,
                                last_token: last_tokens[victim],
                            });
                            last_tokens[victim] = -1;
                            ctx.stats.preemptions.inc();
                        }
                        Ok(Ok(None)) | Ok(Err(_)) => {
                            // a backend that cannot swap this slot out
                            // cannot relieve the pressure either — fail
                            // the victim and retry with the survivors
                            if let Some(active) = gen_slots[victim].take() {
                                fail_active(&ctx.stats, active, ServeError::WorkerFailed);
                            }
                            last_tokens[victim] = -1;
                            let _ = backend.reset_slot(sid, victim);
                        }
                        Err(panic_msg) => {
                            crate::log_error!("server: swap-out panicked: {panic_msg}");
                            poison_cleanup(ctx, &mut gen_slots, &mut preempted, Vec::new());
                            return ReplicaExit::Poisoned;
                        }
                    }
                }
                Ok(Err(e)) => {
                    // an abort-interrupted step is shutdown, not a failure
                    let err = if ctx.abort.load(Ordering::Relaxed) {
                        ServeError::ShuttingDown
                    } else {
                        ServeError::WorkerFailed
                    };
                    crate::log_error!("server: decode step failed: {e:#}");
                    for slot in 0..b {
                        if let Some(active) = gen_slots[slot].take() {
                            fail_active(&ctx.stats, active, err);
                            last_tokens[slot] = -1;
                            let _ = backend.reset_slot(sid, slot);
                        }
                    }
                    break 'decode;
                }
                Err(panic_msg) => {
                    crate::log_error!("server: decode step panicked: {panic_msg}");
                    poison_cleanup(ctx, &mut gen_slots, &mut preempted, Vec::new());
                    return ReplicaExit::Poisoned;
                }
            }
        }
    }
}

/// Score requests lost to a worker failure: requeue those with retry
/// budget left (front of the queue, original order), resolve the rest.
/// Generation requests never come through here — partially-generated
/// output is never silently recomputed.
fn retry_or_fail_scores(ctx: &WorkerCtx, reqs: Vec<ScoreRequest>) {
    let aborting = ctx.abort.load(Ordering::Relaxed);
    let mut requeue: Vec<ScoreRequest> = Vec::new();
    for mut req in reqs {
        if !aborting && req.attempts < ctx.score_retries {
            req.attempts += 1;
            ctx.stats.retries.inc();
            crate::log_warn!(
                "server: score request {} retrying after worker failure (attempt {} of {})",
                req.trace_id,
                req.attempts + 1,
                ctx.score_retries + 1
            );
            requeue.push(req);
        } else {
            let err = if aborting { ServeError::ShuttingDown } else { ServeError::WorkerFailed };
            resolve_unserved(&ctx.stats, Request::Score(req), err);
        }
    }
    if !requeue.is_empty() {
        let (lock, cv) = &*ctx.queue;
        let mut q = lock.lock().unwrap();
        for req in requeue.into_iter().rev() {
            q.pending.push_front(Request::Score(req));
        }
        ctx.stats.queue_depth.set(q.pending.len() as i64);
        drop(q);
        cv.notify_all();
    }
}

/// A replica just poisoned itself: fail every in-flight generation —
/// slot-resident or swapped out — with `WorkerFailed` and put
/// never-attempted generation admissions back at the queue front (they
/// are untouched work, not retries).
fn poison_cleanup(ctx: &WorkerCtx, gen_slots: &mut [Option<ActiveGen>],
                  preempted: &mut VecDeque<PreemptedGen>,
                  untouched: Vec<GenerateRequest>) {
    for slot in gen_slots.iter_mut() {
        if let Some(active) = slot.take() {
            fail_active(&ctx.stats, active, ServeError::WorkerFailed);
        }
    }
    for p in preempted.drain(..) {
        fail_active(&ctx.stats, p.active, ServeError::WorkerFailed);
    }
    if !untouched.is_empty() {
        let (lock, cv) = &*ctx.queue;
        let mut q = lock.lock().unwrap();
        for req in untouched.into_iter().rev() {
            q.pending.push_front(Request::Generate(req));
        }
        ctx.stats.queue_depth.set(q.pending.len() as i64);
        drop(q);
        cv.notify_all();
    }
}

/// Account one engine step (prefill or decode) in the aggregate and
/// per-worker counters.
fn record_step(stats: &ServerStats, mine: &WorkerStats, exec_ns: u64, is_prefill: bool,
               tokens: u64, occupancy: u64) {
    stats.exec_ns.add(exec_ns);
    stats.batches.inc();
    stats.occupancy_sum.add(occupancy);
    if is_prefill {
        stats.prefill_ns.add(exec_ns);
        stats.prefill_tokens.add(tokens);
    } else {
        stats.decode_ns.add(exec_ns);
        // the per-token span source: every decode engine step's execution
        // time (all handles pre-resolved — atomics only on this path)
        stats.decode_step.record_ns(exec_ns);
    }
    mine.exec_ns.fetch_add(exec_ns, Ordering::Relaxed);
    mine.batches.fetch_add(1, Ordering::Relaxed);
}

/// Complete a generation request: respond, account it, and leave its
/// lifecycle trace.
fn finish_generation(stats: &ServerStats, mine: &WorkerStats, active: ActiveGen) {
    let now = Instant::now();
    let latency = now - active.req.submitted;
    let decode_latency = now - active.prefilled;
    stats.served.inc();
    stats.generated.inc();
    mine.served.fetch_add(1, Ordering::Relaxed);
    stats.latency.record(latency);
    stats.decode_lat.record(decode_latency);
    stats.traces.record(RequestTrace {
        id: active.req.trace_id,
        kind: "generate",
        queued_ms: ms(active.admitted - active.req.submitted),
        prefill_ms: ms(active.prefilled - active.admitted),
        decode_ms: ms(decode_latency),
        total_ms: ms(latency),
        decode_steps: (active.generated.len() as u64).saturating_sub(1),
        ok: true,
        outcome: "completed",
    });
    let _ = active.req.respond.send(Ok(GenerateResponse {
        tokens: active.generated,
        prefill_latency: active.prefilled - active.req.submitted,
        decode_latency,
        latency,
    }));
}

#[cfg(test)]
mod tests {
    //! Queue/scheduler logic tests that don't need a real model live in
    //! rust/tests/coordinator_props.rs; full server round-trips are
    //! exercised natively below and in examples/serve_requests.rs,
    //! multi-worker determinism in rust/tests/simd_props.rs and
    //! rust/tests/decode_parity.rs, fault injection in
    //! rust/tests/failsafe.rs, and PJRT in the integration suite.

    use super::*;
    use crate::backend::ForwardGraph;
    use crate::model::bundle;
    use crate::util::json;

    #[test]
    fn stats_default_zero() {
        let s = ServerStats::default();
        assert_eq!(s.served.get(), 0);
        assert_eq!(s.generated.get(), 0);
        assert_eq!(s.latency.count(), 0);
        assert_eq!(s.latency.percentile(0.5), 0.0);
        let snap = s.snapshot();
        assert_eq!(snap.decode_tokens, 0);
        assert_eq!(snap.decode_tok_per_s, 0.0);
        assert_eq!(snap.mean_occupancy, 0.0);
        assert_eq!(snap.hist_saturated, 0);
        assert_eq!(snap.submitted, 0);
        assert_eq!(snap.rejected, 0);
        assert_eq!(snap.shed, 0);
        assert_eq!(snap.cancelled, 0);
        assert_eq!(snap.deadline_exceeded, 0);
        assert_eq!(snap.failed, 0);
        assert_eq!(snap.worker_failures, 0);
        assert_eq!(snap.retries, 0);
        assert_eq!(snap.preemptions, 0);
        assert!(s.traces.recent_traces().is_empty());
    }

    #[test]
    fn stats_are_a_view_over_the_registry() {
        // the snapshot and the registry render must read the same atomics
        let s = ServerStats::default();
        s.served.add(4);
        s.latency.record(Duration::from_micros(300));
        assert_eq!(s.snapshot().served, 4);
        let prom = s.registry.render_prometheus();
        assert!(prom.contains("perq_requests_served_total 4"), "{prom}");
        assert!(prom.contains("perq_request_latency_seconds_count 1"), "{prom}");
        let j = s.registry.snapshot_json();
        assert_eq!(
            j.get("counters").and_then(|c| c.get("perq_requests_served_total"))
                .and_then(|v| v.as_usize()),
            Some(4)
        );
        // the failure-model counters live in the same registry
        s.rejected.inc();
        s.worker_failures.inc();
        let prom = s.registry.render_prometheus();
        assert!(prom.contains("perq_server_rejected_total 1"), "{prom}");
        assert!(prom.contains("perq_server_worker_failures_total 1"), "{prom}");
        assert!(prom.contains("perq_requests_submitted_total 0"), "{prom}");
        // the legacy JSON view carries the exact PR 5 field set
        let legacy = s.snapshot().to_json();
        for key in ["served", "generated", "batches", "exec_s", "prefill_s", "decode_s",
                    "prefill_tokens", "decode_tokens", "decode_tok_per_s", "mean_occupancy",
                    "p50_ms", "p95_ms", "p99_ms", "prefill_p50_ms", "prefill_p95_ms",
                    "prefill_p99_ms", "decode_p50_ms", "decode_p95_ms", "decode_p99_ms",
                    "hist_saturated"] {
            assert!(legacy.get(key).is_some(), "legacy snapshot lost key {key}");
        }
        // plus the additive failure-model keys
        for key in ["submitted", "rejected", "shed", "cancelled", "deadline_exceeded",
                    "failed", "worker_failures", "retries"] {
            assert!(legacy.get(key).is_some(), "snapshot missing failure key {key}");
        }
        // plus the additive KV-paging keys
        for key in ["preemptions", "kv_prefix_hits", "kv_cow_copies", "kv_pages_in_use",
                    "kv_pages_total"] {
            assert!(legacy.get(key).is_some(), "snapshot missing kv key {key}");
        }
        let prom = s.registry.render_prometheus();
        assert!(prom.contains("perq_kv_preemptions_total 0"), "{prom}");
    }

    #[test]
    fn full_renders_are_single_sourced() {
        // `/metrics`, the periodic --metrics-out dump, and the exit flush
        // all call these two methods — pin their shape here once
        let s = ServerStats::default();
        s.served.add(2);
        s.cancelled.inc();
        let marker = crate::obs::metrics::global()
            .counter("perq_render_test_marker_total", "render-path test marker");
        marker.inc();
        let prom = s.render_prometheus_full();
        assert!(prom.contains("perq_requests_served_total 2"), "{prom}");
        assert!(prom.contains("perq_server_cancelled_total 1"), "{prom}");
        // the process-wide engine registry rides along in one exposition
        assert!(prom.contains("perq_render_test_marker_total"), "{prom}");
        let j = s.snapshot_json_full();
        assert_eq!(j.get("served").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(j.get("cancelled").and_then(|v| v.as_usize()), Some(1));
        for key in ["registry", "engine", "traces"] {
            assert!(j.get(key).is_some(), "snapshot_json_full missing {key}");
        }
    }

    #[test]
    fn serve_error_kinds_are_stable() {
        let all = [ServeError::QueueFull, ServeError::Shed, ServeError::Rejected,
                   ServeError::DeadlineExceeded, ServeError::WorkerFailed,
                   ServeError::ShuttingDown, ServeError::Cancelled];
        let kinds: Vec<&str> = all.iter().map(|e| e.as_str()).collect();
        assert_eq!(kinds, vec!["queue_full", "shed", "rejected", "deadline_exceeded",
                               "worker_failed", "shutting_down", "cancelled"]);
        // Display is human-readable and distinct per kind
        let shown: std::collections::BTreeSet<String> =
            all.iter().map(|e| e.to_string()).collect();
        assert_eq!(shown.len(), all.len());
        // it is a std error, so `rx.recv()??` works under anyhow
        let e: Box<dyn std::error::Error> = Box::new(ServeError::QueueFull);
        assert!(e.to_string().contains("queue full"));
    }

    #[test]
    fn serve_options_defaults_and_builders() {
        let o = ServeOptions::default();
        assert_eq!(o.num_workers, 1);
        assert_eq!(o.max_wait, Duration::from_millis(DEFAULT_MAX_WAIT_MS));
        assert_eq!(o.queue_cap, None);
        assert_eq!(o.deadline, None);
        assert_eq!(o.drain_timeout, Duration::from_secs(5));
        assert_eq!(o.score_retries, 1);
        let o = ServeOptions::new(Duration::from_millis(2), 3)
            .with_queue_cap(8)
            .with_deadline(Duration::from_millis(50))
            .with_drain_timeout(Duration::from_millis(200))
            .with_score_retries(0);
        assert_eq!(o.num_workers, 3);
        assert_eq!(o.max_wait, Duration::from_millis(2));
        assert_eq!(o.queue_cap, Some(8));
        assert_eq!(o.deadline, Some(Duration::from_millis(50)));
        assert_eq!(o.drain_timeout, Duration::from_millis(200));
        assert_eq!(o.score_retries, 0);
    }

    /// A throwaway score request for queue-logic tests (receiver dropped —
    /// sends are ignored).
    fn qreq(priority: u8, trace_id: u64) -> Request {
        let (tx, _rx) = channel();
        Request::Score(ScoreRequest {
            tokens: vec![],
            submitted: Instant::now(),
            trace_id,
            priority,
            deadline: None,
            attempts: 0,
            respond: tx,
        })
    }

    fn id_of(r: &Request) -> u64 {
        match r {
            Request::Score(s) => s.trace_id,
            Request::Generate(g) => g.trace_id,
        }
    }

    fn queue_ids(q: &VecDeque<Request>) -> Vec<u64> {
        q.iter().map(id_of).collect()
    }

    #[test]
    fn priority_insert_is_ordered_and_fifo_within_ties() {
        let mut q = VecDeque::new();
        for (p, id) in [(0u8, 1u64), (2, 2), (1, 3), (2, 4), (0, 5)] {
            insert_by_priority(&mut q, qreq(p, id));
        }
        // descending priority; equal priorities keep submit order
        assert_eq!(queue_ids(&q), vec![2, 4, 3, 1, 5]);
        // all-default priorities degrade to plain FIFO
        let mut q = VecDeque::new();
        for id in 1..=4u64 {
            insert_by_priority(&mut q, qreq(0, id));
        }
        assert_eq!(queue_ids(&q), vec![1, 2, 3, 4]);
    }

    #[test]
    fn admit_locked_caps_and_sheds_by_priority() {
        // unbounded: everything is admitted
        let mut q = VecDeque::new();
        assert!(admit_locked(&mut q, None, qreq(0, 1)).is_none());
        // cap 2, all equal priority: third arrival is rejected, queue keeps
        // the first two
        let mut q = VecDeque::new();
        assert!(admit_locked(&mut q, Some(2), qreq(0, 1)).is_none());
        assert!(admit_locked(&mut q, Some(2), qreq(0, 2)).is_none());
        let (victim, err) = admit_locked(&mut q, Some(2), qreq(0, 3)).expect("rejected");
        assert_eq!(err, ServeError::QueueFull);
        assert_eq!(id_of(&victim), 3);
        assert_eq!(queue_ids(&q), vec![1, 2]);
        // a higher-priority arrival sheds the lowest-priority queued entry
        let (victim, err) = admit_locked(&mut q, Some(2), qreq(5, 4)).expect("shed");
        assert_eq!(err, ServeError::Shed);
        assert_eq!(id_of(&victim), 2);
        assert_eq!(queue_ids(&q), vec![4, 1], "priority 5 jumps the survivor");
        // an equal-priority arrival cannot shed (no livelock of peers)
        let (_, err) = admit_locked(&mut q, Some(2), qreq(5, 5)).expect("rejected");
        assert_eq!(err, ServeError::QueueFull);
    }

    #[test]
    fn latency_hist_buckets_monotonic() {
        let h = LatencyHist::default();
        for us in [5u64, 50, 500, 5_000, 50_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        let p50 = h.percentile(0.5);
        let p95 = h.percentile(0.95);
        let p99 = h.percentile(0.99);
        assert!(p50 > 0.0 && p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        // p50 of {5,50,500,5000,50000}µs sits in the 500µs bucket: within
        // bucket resolution of 0.5 ms
        assert!((0.3..1.0).contains(&p50), "p50 {p50} ms");
    }

    #[test]
    fn latency_hist_extremes_clamp_with_saturation() {
        let h = LatencyHist::default();
        h.record(Duration::from_nanos(1)); // below 1 µs → first bucket
        assert_eq!(h.saturated(), 0, "low clamp is not saturation");
        h.record(Duration::from_secs(7200)); // beyond range → last bucket
        h.record(Duration::from_secs(9000));
        assert_eq!(h.count(), 3, "clamped records still count");
        assert_eq!(h.saturated(), 2, "top-bucket clamps are tallied");
        assert!(h.percentile(1.0) > h.percentile(0.1));
        // saturation clamp: a rank landing among saturated samples reports
        // the top bucket's lower bound, not its geometric midpoint
        let top_lower_ms =
            LatencyHist::bucket_lower_us(crate::obs::metrics::HIST_BUCKETS - 1) / 1_000.0;
        assert_eq!(h.percentile(1.0), top_lower_ms, "no midpoint beyond the data");
    }

    #[test]
    fn resolve_max_wait_precedence() {
        // CLI value wins outright (env consultation skipped)
        assert_eq!(resolve_max_wait(Some(25)), Duration::from_millis(25));
        assert_eq!(resolve_max_wait(Some(0)), Duration::from_millis(0));
        // no CLI and no env (assuming a clean test environment) → default
        if std::env::var("PERQ_MAX_WAIT_MS").is_err() {
            assert_eq!(resolve_max_wait(None), Duration::from_millis(DEFAULT_MAX_WAIT_MS));
        }
    }

    fn tiny_parts(seq_len: usize, batch: usize)
                  -> (crate::model::config::ModelConfig,
                      crate::model::weights::WeightSet,
                      ForwardGraph) {
        let j = json::parse(&format!(
            r#"{{"config": {{"name": "t", "n_layers": 1, "d_model": 16,
                "n_heads": 2, "d_ffn": 32, "vocab": 8, "seq_len": {seq_len},
                "batch": {batch}, "block_sizes": [1, 8]}}}}"#,
        ))
        .unwrap();
        let cfg = crate::model::config::ModelConfig::from_meta(&j).unwrap();
        let ws = bundle::synthetic_weights(&cfg, 11);
        let graph = ForwardGraph::Merged { r3_block: 8, format: crate::quant::Format::Int4 };
        (cfg, ws, graph)
    }

    fn tiny_server(seq_len: usize, batch: usize, workers: usize) -> InferenceServer {
        let (cfg, ws, graph) = tiny_parts(seq_len, batch);
        InferenceServer::start_native(&cfg, &ws, &graph,
                                      ServeOptions::new(Duration::from_millis(1), workers))
            .unwrap()
    }

    #[test]
    fn native_score_round_trip_partial_batch() {
        let server = tiny_server(8, 4, 1);
        assert_eq!(server.num_workers(), 1);
        // 3 requests into a 4-slot server: a partial step, no filler
        let mk = |s: usize| -> Vec<i32> { (0..9).map(|i| ((s + i) % 8) as i32).collect() };
        let rxs: Vec<_> = (0..3).map(|s| server.submit(mk(s)).unwrap()).collect();
        for rx in rxs {
            let resp = rx.recv().unwrap().unwrap();
            assert!(resp.nll.is_finite() && resp.nll > 0.0);
            assert!(resp.batch_occupancy <= 3, "occupancy counts real requests only");
        }
        let (served, batches, _) = server.stats();
        assert_eq!(served, 3);
        assert!(batches >= 1);
        assert_eq!(server.stats.latency.count(), 3, "every request records a latency");
        let snap = server.snapshot();
        assert_eq!(snap.served, 3);
        assert_eq!(snap.generated, 0);
        assert_eq!(snap.submitted, 3, "accepted submits are counted");
        assert_eq!(snap.rejected + snap.deadline_exceeded + snap.failed, 0);
        assert!(snap.prefill_tokens >= 3 * 8, "score windows flow through prefill");
        assert!(snap.mean_occupancy > 0.0);
        // per-worker counters merge into the aggregate
        let per = server.per_worker_stats();
        assert_eq!(per.iter().map(|p| p.0).sum::<u64>(), served);
        assert_eq!(per.iter().map(|p| p.1).sum::<u64>(), batches);
        // identical windows score identically (deterministic native path)
        let a = server.submit(mk(0)).unwrap().recv().unwrap().unwrap().nll;
        let b = server.submit(mk(0)).unwrap().recv().unwrap().unwrap().nll;
        assert!((a - b).abs() < 1e-12);
        server.shutdown();
    }

    #[test]
    fn generate_round_trip_greedy_and_deterministic() {
        let server = tiny_server(16, 2, 1);
        let prompt = vec![1i32, 5, 2, 7];
        let a = server.submit_generate(prompt.clone(), 6).unwrap().recv().unwrap().unwrap();
        assert_eq!(a.tokens.len(), 6);
        assert!(a.tokens.iter().all(|&t| (0..8).contains(&t)), "tokens in vocab");
        assert!(a.latency >= a.prefill_latency);
        // greedy sampling is deterministic: same prompt → same tokens
        let b = server.submit_generate(prompt.clone(), 6).unwrap().recv().unwrap().unwrap();
        assert_eq!(a.tokens, b.tokens);
        // interleave a score request with generation traffic
        let win: Vec<i32> = (0..17).map(|i| (i % 8) as i32).collect();
        let rx_g = server.submit_generate(prompt, 8).unwrap();
        let rx_s = server.submit(win).unwrap();
        assert_eq!(rx_g.recv().unwrap().unwrap().tokens.len(), 8);
        assert!(rx_s.recv().unwrap().unwrap().nll.is_finite());
        let snap = server.snapshot();
        assert_eq!(snap.generated, 3);
        assert_eq!(snap.served, 4, "served counts score + generate");
        assert_eq!(snap.submitted, 4);
        // 3 generations × (n-1) decode steps each produced decode tokens
        assert!(snap.decode_tokens >= 5 + 5 + 7, "decode tokens {}", snap.decode_tokens);
        assert!(snap.decode_s > 0.0 && snap.decode_tok_per_s > 0.0);
        assert!(snap.batches > 3, "prefill + decode steps both count");
        server.shutdown();
    }

    #[test]
    fn request_traces_cover_both_submit_paths() {
        let server = tiny_server(16, 2, 1);
        let win: Vec<i32> = (0..17).map(|i| (i % 8) as i32).collect();
        server.submit(win).unwrap().recv().unwrap().unwrap();
        server.submit_generate(vec![1, 5, 2], 4).unwrap().recv().unwrap().unwrap();
        let traces = server.recent_traces();
        assert_eq!(traces.len(), 2, "every completed request leaves a trace");
        assert!(traces[0].id < traces[1].id, "IDs are monotone with submit order");
        assert!(traces.iter().any(|t| t.kind == "score"));
        assert!(traces.iter().all(|t| t.outcome == "completed"));
        let g = traces.iter().find(|t| t.kind == "generate").expect("generate trace");
        assert!(g.ok);
        assert_eq!(g.decode_steps, 3, "4 tokens = prefill's first + 3 decode steps");
        assert!(g.decode_ms <= g.total_ms && g.prefill_ms <= g.total_ms);
        // the registry saw the same traffic the snapshot did
        let prom = server.registry().render_prometheus();
        assert!(prom.contains("perq_requests_served_total 2"), "{prom}");
        assert!(prom.contains("perq_generate_requests_total 1"), "{prom}");
        assert!(prom.contains("perq_requests_submitted_total 2"), "{prom}");
        server.shutdown();
    }

    #[test]
    fn served_nll_is_exact_regardless_of_kv_mode() {
        // the server scores through an exact (f32-KV) scoring session,
        // so served NLLs must equal a direct exact-session rescore
        // bit-for-bit even though generation sessions default to the
        // quantized cache
        let (cfg, ws, graph) = tiny_parts(8, 4);
        let server = InferenceServer::start_native(
            &cfg, &ws, &graph, ServeOptions::new(Duration::from_millis(1), 1),
        )
        .unwrap();
        let win: Vec<i32> = (0..9).map(|i| ((i * 3 + 1) % 8) as i32).collect();
        let served = server.submit(win.clone()).unwrap().recv().unwrap().unwrap().nll;
        server.shutdown();
        use crate::backend::NativeBackend;
        use crate::tensor::KvMode;
        let mut be = NativeBackend::new(cfg, ws, graph).unwrap();
        let sid = be.begin_with_mode(1, KvMode::F32).unwrap();
        let logits = be.prefill_slots(sid, &[0], &win[..8]).unwrap();
        let direct = window_nll(&logits, &win, 8, 8);
        assert_eq!(served.to_bits(), direct.to_bits(),
                   "served NLL must match the exact rescore ({served} vs {direct})");
    }

    #[test]
    fn submit_rejects_out_of_vocab_tokens() {
        let server = tiny_server(8, 2, 1);
        // out-of-vocab *target* token (the final entry never reaches
        // prefill's own validation) must fail at submit, not panic a
        // worker thread
        let mut win: Vec<i32> = (0..9).map(|i| (i % 8) as i32).collect();
        win[8] = 99;
        assert!(server.submit(win).is_err());
        let mut win2: Vec<i32> = (0..9).map(|i| (i % 8) as i32).collect();
        win2[3] = -2;
        assert!(server.submit(win2).is_err());
        assert!(server.submit_generate(vec![1, 99], 2).is_err());
        // validation failures happen before admission: not "submitted"
        assert_eq!(server.snapshot().submitted, 0);
        // the server is still alive and serving after the rejections
        let ok: Vec<i32> = (0..9).map(|i| (i % 8) as i32).collect();
        assert!(server.submit(ok).unwrap().recv().unwrap().unwrap().nll.is_finite());
        server.shutdown();
    }

    #[test]
    fn generate_rejects_oversized_requests() {
        let server = tiny_server(8, 2, 1);
        assert!(server.submit_generate(vec![], 3).is_err());
        assert!(server.submit_generate(vec![1, 2, 3], 0).is_err());
        assert!(server.submit_generate(vec![1; 6], 3).is_err(), "6 + 3 > seq_len 8");
        assert!(server.submit_generate(vec![1; 4], 4).is_ok());
        server.shutdown();
    }

    #[test]
    fn submit_rejects_bad_window() {
        let j = json::parse(
            r#"{"config": {"name": "t", "n_layers": 1, "d_model": 16,
                "n_heads": 2, "d_ffn": 32, "vocab": 8, "seq_len": 8,
                "batch": 2, "block_sizes": [1]}}"#,
        )
        .unwrap();
        let cfg = crate::model::config::ModelConfig::from_meta(&j).unwrap();
        let ws = bundle::synthetic_weights(&cfg, 12);
        let server = InferenceServer::start_native(
            &cfg, &ws, &ForwardGraph::Fp, ServeOptions::new(Duration::from_millis(1), 2),
        )
        .unwrap();
        assert_eq!(server.num_workers(), 2);
        assert!(server.submit(vec![0i32; 3]).is_err());
        server.shutdown();
    }

    #[test]
    fn expired_deadline_resolves_without_engine_work() {
        let server = tiny_server(8, 2, 1);
        let win: Vec<i32> = (0..9).map(|i| (i % 8) as i32).collect();
        // a deadline already behind us: the request must resolve
        // DeadlineExceeded at batch-forming time, never touching a slot
        let opts = SubmitOpts {
            priority: 0,
            deadline: Some(Instant::now() - Duration::from_millis(1)),
        };
        let rx = server.submit_with(win.clone(), opts).unwrap();
        assert!(matches!(rx.recv().unwrap(), Err(ServeError::DeadlineExceeded)));
        let snap = server.snapshot();
        assert_eq!(snap.deadline_exceeded, 1);
        assert_eq!(snap.submitted, 1);
        let trace = server.recent_traces().pop().expect("expired request left a trace");
        assert!(!trace.ok);
        assert_eq!(trace.outcome, "deadline_exceeded");
        // the server keeps serving afterwards
        assert!(server.submit(win).unwrap().recv().unwrap().unwrap().nll.is_finite());
        server.shutdown();
    }

    #[test]
    fn shutdown_resolves_queued_requests_and_closes_submits() {
        let server = tiny_server(8, 2, 1);
        let win: Vec<i32> = (0..9).map(|i| (i % 8) as i32).collect();
        let rx = server.submit(win.clone()).unwrap();
        let snap_stats = server.shared_stats();
        server.shutdown();
        // the in-flight request resolved one way or the other — never hangs
        let outcome = rx.recv().unwrap();
        match outcome {
            Ok(resp) => assert!(resp.nll.is_finite()),
            Err(e) => assert_eq!(e, ServeError::ShuttingDown),
        }
        // terminal accounting is complete: one submit, one terminal state
        let snap = snap_stats.snapshot();
        assert_eq!(snap.submitted, 1);
        assert_eq!(snap.served + snap.rejected + snap.deadline_exceeded + snap.failed, 1);
    }
}
