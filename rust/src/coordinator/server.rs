//! Batched inference server — the serving-side L3 coordinator.
//!
//! The paper's case for block rotations is a *serving* argument (App A:
//! online rotation overhead, "1.5× lower rotation cost, 2% end-to-end
//! latency for Llama2 7B at b=32"). This module provides the runtime that
//! argument lives in: a request router + dynamic batcher in front of any
//! [`ExecBackend`] — the device-resident PJRT artifact executor or the
//! pure-Rust `NativeBackend`.
//!
//! Design (vLLM-router-like, scaled to this testbed):
//!   * clients submit `ScoreRequest`s (token windows) and receive logits
//!     scores through a oneshot channel;
//!   * `num_workers` batcher threads (replicas) drain a shared queue into
//!     fixed-size backend batches (the forward graph has static (B, T)),
//!     padding the tail with the first request and waiting at most
//!     `max_wait` for a full batch; padded slots are *execution filler
//!     only* — they are excluded from `ServerStats.served`, from
//!     per-request NLL, and from the reported batch occupancy, and counted
//!     separately in `ServerStats.padded`;
//!   * each worker constructs its own backend *on its batcher thread* via
//!     a shared `Send + Sync` factory, because PJRT handles are `Rc`-based
//!     and thread-confined; weights live as device buffers there (uploaded
//!     once), so the request path copies only tokens — the §Perf win over
//!     literal re-upload on every call. The native backend reuses pooled
//!     scratch the same way. Scoring is per-slot independent (per-token
//!     quantization, per-sequence attention), so NLLs are identical
//!     regardless of `num_workers` or batch composition — asserted by
//!     rust/tests/simd_props.rs;
//!   * per-worker counters merge into the aggregate [`ServerStats`], and a
//!     fixed-bucket atomic histogram tracks request latency for
//!     p50/p95/p99 reporting (`latency_percentiles`).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::backend::ExecBackend;
use crate::model::config::ModelConfig;

pub use crate::backend::ExtraInput;

/// Constructs one backend per worker thread, on that thread (PJRT handles
/// are not `Send`; only the factory crosses threads). Called once per
/// replica, so it must be `Fn`, not `FnOnce`.
pub type BackendFactory = Box<dyn Fn() -> Result<Box<dyn ExecBackend>> + Send + Sync + 'static>;

pub struct ScoreRequest {
    /// seq_len token window to score
    pub tokens: Vec<i32>,
    pub submitted: Instant,
    respond: Sender<ScoreResponse>,
}

#[derive(Debug)]
pub struct ScoreResponse {
    /// mean next-token NLL over the window (nats)
    pub nll: f64,
    /// queueing + batching + execution latency
    pub latency: Duration,
    /// how many *real* requests shared the batch (padding excluded)
    pub batch_occupancy: usize,
}

struct Queue {
    pending: VecDeque<ScoreRequest>,
    shutdown: bool,
}

/// Number of √2-spaced latency buckets: 1 µs · 2^(i/2) spans 1 µs to
/// ≈ 35 min, far beyond any request this server can see.
const LAT_BUCKETS: usize = 64;

/// Fixed-bucket request-latency histogram over atomics — recordable from
/// every worker thread without locks, readable while the server runs.
/// Buckets are √2-spaced in microseconds, so a reported percentile is
/// within ~19% of the true value (the geometric-mid representative).
pub struct LatencyHist {
    buckets: Vec<AtomicU64>,
}

impl Default for LatencyHist {
    fn default() -> Self {
        LatencyHist { buckets: (0..LAT_BUCKETS).map(|_| AtomicU64::new(0)).collect() }
    }
}

impl LatencyHist {
    fn bucket(ns: u64) -> usize {
        let us = (ns / 1_000).max(1);
        let l = 63 - us.leading_zeros() as u64; // floor(log2 µs)
        let half = if l > 0 && (us & (1 << (l - 1))) != 0 { 1 } else { 0 };
        ((2 * l + half) as usize).min(LAT_BUCKETS - 1)
    }

    /// Record one request latency.
    pub fn record(&self, lat: Duration) {
        let idx = LatencyHist::bucket(lat.as_nanos() as u64);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Total recorded samples.
    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// The q-quantile (0 < q ≤ 1) in milliseconds, or 0.0 with no samples.
    /// Returns the geometric midpoint of the bucket holding the rank.
    pub fn percentile(&self, q: f64) -> f64 {
        let counts: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                // bucket i covers [2^(l)·(1 + h/2), …) µs for i = 2l + h
                let l = (i / 2) as f64;
                let half = (i % 2) as f64;
                let lower_us = (2.0f64).powf(l) * (1.0 + 0.5 * half);
                // geometric mid of a √2-wide interval
                return lower_us * (2.0f64).powf(0.25) / 1_000.0;
            }
        }
        0.0
    }
}

/// Per-worker counters; the aggregate [`ServerStats`] sums across replicas.
#[derive(Default)]
pub struct WorkerStats {
    pub served: AtomicU64,
    pub batches: AtomicU64,
    pub exec_ns: AtomicU64,
}

/// Server statistics (atomics; read while running). The aggregate counters
/// are the merge of every worker's [`WorkerStats`].
#[derive(Default)]
pub struct ServerStats {
    /// real requests served (padded slots never count)
    pub served: AtomicU64,
    pub batches: AtomicU64,
    /// batch slots filled with padding (tail duplication)
    pub padded: AtomicU64,
    pub exec_ns: AtomicU64,
    /// request latency (queue + batch + exec) histogram
    pub latency: LatencyHist,
}

pub struct InferenceServer {
    queue: Arc<(Mutex<Queue>, Condvar)>,
    stats: Arc<ServerStats>,
    worker_stats: Vec<Arc<WorkerStats>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    running: Arc<AtomicBool>,
    cfg: ModelConfig,
}

impl InferenceServer {
    /// Spin up `num_workers` backend replicas (one batcher thread each,
    /// each owning a backend produced by `factory` on that thread) over a
    /// shared request queue. Construction errors from *any* replica
    /// surface here, not on first request.
    pub fn start_backend(factory: BackendFactory, cfg: &ModelConfig, max_wait: Duration,
                         num_workers: usize) -> Result<InferenceServer> {
        let num_workers = num_workers.max(1);
        let factory: Arc<BackendFactory> = Arc::new(factory);
        let queue = Arc::new((
            Mutex::new(Queue { pending: VecDeque::new(), shutdown: false }),
            Condvar::new(),
        ));
        let stats = Arc::new(ServerStats::default());
        let running = Arc::new(AtomicBool::new(true));
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let mut workers = Vec::with_capacity(num_workers);
        let mut worker_stats = Vec::with_capacity(num_workers);
        for w in 0..num_workers {
            let per = Arc::new(WorkerStats::default());
            worker_stats.push(Arc::clone(&per));
            let t_factory = Arc::clone(&factory);
            let t_queue = queue.clone();
            let t_stats = stats.clone();
            let t_running = running.clone();
            let t_ready = ready_tx.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("perq-serve-{w}"))
                .spawn(move || {
                    let backend = match (*t_factory)() {
                        Ok(b) => {
                            let _ = t_ready.send(Ok(()));
                            b
                        }
                        Err(e) => {
                            let _ = t_ready.send(Err(e));
                            return;
                        }
                    };
                    drop(t_ready);
                    batcher_loop(backend, t_queue, t_stats, per, t_running, max_wait)
                });
            match spawned {
                Ok(handle) => workers.push(handle),
                Err(e) => {
                    // wind down the replicas that did start before bailing
                    {
                        let (lock, cv) = &*queue;
                        if let Ok(mut q) = lock.lock() {
                            q.shutdown = true;
                        }
                        cv.notify_all();
                    }
                    for h in workers {
                        let _ = h.join();
                    }
                    return Err(anyhow!("spawning server worker: {e}"));
                }
            }
        }
        drop(ready_tx);
        let server = InferenceServer {
            queue,
            stats,
            worker_stats,
            workers,
            running: running.clone(),
            cfg: cfg.clone(),
        };
        // every replica must come up; a single failure shuts the rest down
        for _ in 0..num_workers {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    server.shutdown();
                    return Err(e);
                }
                Err(_) => {
                    server.shutdown();
                    return Err(anyhow!("server thread died during startup"));
                }
            }
        }
        Ok(server)
    }

    /// Serve through the device-resident PJRT artifact at `artifact` (an
    /// .hlo.txt path) over (already transformed + quantized) weights;
    /// `extras` are the rotation/format inputs.
    #[cfg(feature = "pjrt")]
    pub fn start(artifact: std::path::PathBuf, cfg: &ModelConfig,
                 ws: &crate::model::weights::WeightSet, extras: Vec<ExtraInput>,
                 max_wait: Duration, num_workers: usize) -> Result<InferenceServer> {
        let graph = graph_from_extras(&extras)?;
        // native-only formats (fmt id > 3) must not reach the artifact's
        // lax.switch — it would clamp them to the wrong quantizer
        crate::backend::ensure_artifact_format(&graph)?;
        let cfg2 = cfg.clone();
        let ws2 = ws.clone();
        let factory: BackendFactory = Box::new(move || {
            Ok(Box::new(crate::backend::pjrt::PjrtBackend::load(
                &artifact, &cfg2, &ws2, &graph,
            )?) as Box<dyn ExecBackend>)
        });
        InferenceServer::start_backend(factory, cfg, max_wait, num_workers)
    }

    /// Serve through the pure-Rust native backend — no PJRT, no artifacts.
    /// Each of the `num_workers` replicas clones the weight set (packed
    /// low-bit twins keep that cheap for INT4/INT8 graphs).
    pub fn start_native(cfg: &ModelConfig, ws: &crate::model::weights::WeightSet,
                        graph: &crate::backend::ForwardGraph, max_wait: Duration,
                        num_workers: usize) -> Result<InferenceServer> {
        let cfg2 = cfg.clone();
        let ws2 = ws.clone();
        let graph = graph.clone();
        let factory: BackendFactory = Box::new(move || {
            Ok(Box::new(crate::backend::NativeBackend::new(
                cfg2.clone(),
                ws2.clone(),
                graph.clone(),
            )?) as Box<dyn ExecBackend>)
        });
        InferenceServer::start_backend(factory, cfg, max_wait, num_workers)
    }

    /// Serve a loaded `.perq` deployment artifact — the serve-many half of
    /// quantize-once / serve-many. Replicas come up from the artifact
    /// weights alone (packed low-bit or merged dense); no calibration,
    /// permutation search, or rounding code runs. Native backend only:
    /// deployment artifacts carry no AOT HLO graphs.
    pub fn start_deployed(dm: &crate::deploy::DeployedModel, max_wait: Duration,
                          num_workers: usize) -> Result<InferenceServer> {
        InferenceServer::start_native(&dm.cfg, &dm.ws, &dm.graph, max_wait, num_workers)
    }

    /// Submit a scoring request; returns a receiver for the response.
    pub fn submit(&self, tokens: Vec<i32>) -> Result<std::sync::mpsc::Receiver<ScoreResponse>> {
        anyhow::ensure!(tokens.len() == self.cfg.seq_len + 1,
                        "requests carry seq_len+1 tokens (window + next-token target)");
        let (tx, rx) = channel();
        let (lock, cv) = &*self.queue;
        let mut q = lock.lock().unwrap();
        anyhow::ensure!(!q.shutdown, "server is shut down");
        q.pending.push_back(ScoreRequest {
            tokens,
            submitted: Instant::now(),
            respond: tx,
        });
        cv.notify_one();
        Ok(rx)
    }

    /// (served, batches, exec seconds) — `served` counts real requests
    /// only; padded slots are tracked by [`InferenceServer::padded_slots`].
    pub fn stats(&self) -> (u64, u64, f64) {
        let served = self.stats.served.load(Ordering::Relaxed);
        let batches = self.stats.batches.load(Ordering::Relaxed);
        let exec_s = self.stats.exec_ns.load(Ordering::Relaxed) as f64 / 1e9;
        (served, batches, exec_s)
    }

    /// Per-replica (served, batches, exec seconds) snapshots, in worker
    /// order. Sums match the aggregate [`InferenceServer::stats`].
    pub fn per_worker_stats(&self) -> Vec<(u64, u64, f64)> {
        self.worker_stats
            .iter()
            .map(|w| {
                (
                    w.served.load(Ordering::Relaxed),
                    w.batches.load(Ordering::Relaxed),
                    w.exec_ns.load(Ordering::Relaxed) as f64 / 1e9,
                )
            })
            .collect()
    }

    /// Backend replica count.
    pub fn num_workers(&self) -> usize {
        self.worker_stats.len()
    }

    /// Server-side request-latency percentiles (p50, p95, p99) in ms from
    /// the fixed-bucket histogram (~19% bucket resolution).
    pub fn latency_percentiles(&self) -> (f64, f64, f64) {
        let h = &self.stats.latency;
        (h.percentile(0.50), h.percentile(0.95), h.percentile(0.99))
    }

    /// Batch slots that were filled with tail padding (never billed as
    /// served requests).
    pub fn padded_slots(&self) -> u64 {
        self.stats.padded.load(Ordering::Relaxed)
    }

    fn signal_shutdown(&self) {
        self.running.store(false, Ordering::Relaxed);
        let (lock, cv) = &*self.queue;
        if let Ok(mut q) = lock.lock() {
            q.shutdown = true;
        }
        cv.notify_all();
    }

    pub fn shutdown(mut self) {
        self.signal_shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        self.signal_shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Recover the graph description from legacy (matrix.., fmt) extras — the
/// shape the pjrt `start` entry point and the integration suite still use.
#[cfg(feature = "pjrt")]
fn graph_from_extras(extras: &[ExtraInput]) -> Result<crate::backend::ForwardGraph> {
    use crate::backend::ForwardGraph;
    use crate::quant::Format;
    let fmt = extras
        .iter()
        .find_map(|e| match e {
            ExtraInput::ScalarI32(v) => Some(*v),
            _ => None,
        })
        .unwrap_or(0);
    let format = match fmt {
        1 => Format::Int4,
        2 => Format::Fp4,
        3 => Format::Mxfp4,
        4 => Format::Int8,
        _ => Format::None,
    };
    let mats = extras
        .iter()
        .filter(|e| matches!(e, ExtraInput::Matrix(_)))
        .count();
    if mats >= 2 {
        return Ok(ForwardGraph::Online { format });
    }
    let b = extras
        .iter()
        .find_map(|e| match e {
            ExtraInput::Matrix(m) => Some(m.rows),
            _ => None,
        })
        .unwrap_or(1);
    Ok(ForwardGraph::Merged { r3_block: b, format })
}

fn batcher_loop(mut backend: Box<dyn ExecBackend>, queue: Arc<(Mutex<Queue>, Condvar)>,
                stats: Arc<ServerStats>, mine: Arc<WorkerStats>, running: Arc<AtomicBool>,
                max_wait: Duration) {
    let b = backend.cfg().batch;
    let t = backend.cfg().seq_len;
    let v = backend.cfg().vocab;
    while running.load(Ordering::Relaxed) {
        // drain up to a full batch, waiting at most max_wait after the
        // first request arrives
        let batch: Vec<ScoreRequest> = {
            let (lock, cv) = &*queue;
            let mut q = lock.lock().unwrap();
            while q.pending.is_empty() && !q.shutdown {
                q = cv.wait(q).unwrap();
            }
            if q.shutdown && q.pending.is_empty() {
                return;
            }
            let deadline = Instant::now() + max_wait;
            while q.pending.len() < b && !q.shutdown {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (qq, timeout) = cv.wait_timeout(q, deadline - now).unwrap();
                q = qq;
                if timeout.timed_out() {
                    break;
                }
            }
            let take = q.pending.len().min(b);
            q.pending.drain(..take).collect()
        };
        if batch.is_empty() {
            continue;
        }
        let real = batch.len();
        // assemble the token batch; tail slots are padded with the first
        // request purely to satisfy the static (B, T) graph shape
        let mut tokens = Vec::with_capacity(b * t);
        for i in 0..b {
            let req = batch.get(i).unwrap_or(&batch[0]);
            tokens.extend_from_slice(&req.tokens[..t]);
        }
        let t_exec = Instant::now();
        let result = backend.score(&tokens);
        let exec_ns = t_exec.elapsed().as_nanos() as u64;
        stats.exec_ns.fetch_add(exec_ns, Ordering::Relaxed);
        stats.batches.fetch_add(1, Ordering::Relaxed);
        stats.padded.fetch_add((b - real) as u64, Ordering::Relaxed);
        mine.exec_ns.fetch_add(exec_ns, Ordering::Relaxed);
        mine.batches.fetch_add(1, Ordering::Relaxed);
        match result {
            Ok(logits) => {
                // only the `real` leading slots correspond to requests;
                // padded tail logits are dropped without scoring
                for (i, req) in batch.into_iter().enumerate() {
                    // mean NLL of targets tokens[1..=t] under logits[0..t)
                    let base = i * t * v;
                    let mut nll = 0.0f64;
                    for j in 0..t {
                        let row = &logits[base + j * v..base + (j + 1) * v];
                        let mx = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x)) as f64;
                        let lse: f64 = row.iter().map(|&x| ((x as f64) - mx).exp()).sum();
                        let tgt = req.tokens[j + 1] as usize;
                        nll += mx + lse.ln() - row[tgt] as f64;
                    }
                    stats.served.fetch_add(1, Ordering::Relaxed);
                    mine.served.fetch_add(1, Ordering::Relaxed);
                    let latency = req.submitted.elapsed();
                    stats.latency.record(latency);
                    let _ = req.respond.send(ScoreResponse {
                        nll: nll / t as f64,
                        latency,
                        batch_occupancy: real,
                    });
                }
            }
            Err(e) => {
                eprintln!("server: batch execution failed: {e:#}");
                // drop senders → clients observe disconnection
            }
        }
    }
}

#[cfg(test)]
mod tests {
    //! Queue/batcher logic tests that don't need a real model live in
    //! rust/tests/coordinator_props.rs; full server round-trips are
    //! exercised natively in rust/tests/backend_parity.rs and
    //! examples/serve_requests.rs, multi-worker determinism in
    //! rust/tests/simd_props.rs, and PJRT in the integration suite.

    use super::*;
    use crate::backend::ForwardGraph;
    use crate::model::bundle;
    use crate::util::json;

    #[test]
    fn stats_default_zero() {
        let s = ServerStats::default();
        assert_eq!(s.served.load(std::sync::atomic::Ordering::Relaxed), 0);
        assert_eq!(s.padded.load(std::sync::atomic::Ordering::Relaxed), 0);
        assert_eq!(s.latency.count(), 0);
        assert_eq!(s.latency.percentile(0.5), 0.0);
    }

    #[test]
    fn latency_hist_buckets_monotonic() {
        let h = LatencyHist::default();
        for us in [5u64, 50, 500, 5_000, 50_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        let p50 = h.percentile(0.5);
        let p95 = h.percentile(0.95);
        let p99 = h.percentile(0.99);
        assert!(p50 > 0.0 && p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        // p50 of {5,50,500,5000,50000}µs sits in the 500µs bucket: within
        // bucket resolution of 0.5 ms
        assert!((0.3..1.0).contains(&p50), "p50 {p50} ms");
    }

    #[test]
    fn latency_hist_extremes_clamp() {
        let h = LatencyHist::default();
        h.record(Duration::from_nanos(1)); // below 1 µs → first bucket
        h.record(Duration::from_secs(7200)); // beyond range → last bucket
        assert_eq!(h.count(), 2);
        assert!(h.percentile(1.0) > h.percentile(0.1));
    }

    #[test]
    fn native_server_round_trip_counts_padding() {
        let j = json::parse(
            r#"{"config": {"name": "t", "n_layers": 1, "d_model": 16,
                "n_heads": 2, "d_ffn": 32, "vocab": 8, "seq_len": 8,
                "batch": 4, "block_sizes": [1, 8]}}"#,
        )
        .unwrap();
        let cfg = crate::model::config::ModelConfig::from_meta(&j).unwrap();
        let ws = bundle::synthetic_weights(&cfg, 11);
        let graph = ForwardGraph::Merged { r3_block: 8, format: crate::quant::Format::Int4 };
        let server =
            InferenceServer::start_native(&cfg, &ws, &graph, Duration::from_millis(1), 1).unwrap();
        assert_eq!(server.num_workers(), 1);
        // 3 requests into a batch-of-4 server → at least one padded slot
        let mk = |s: usize| -> Vec<i32> {
            (0..cfg.seq_len + 1).map(|i| ((s + i) % cfg.vocab) as i32).collect()
        };
        let rxs: Vec<_> = (0..3).map(|s| server.submit(mk(s)).unwrap()).collect();
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert!(resp.nll.is_finite() && resp.nll > 0.0);
            assert!(resp.batch_occupancy <= 3, "padding must not inflate occupancy");
        }
        let (served, batches, _) = server.stats();
        assert_eq!(served, 3, "padded slots must not count as served");
        assert!(batches >= 1);
        assert!(server.padded_slots() >= 1, "tail padding should be recorded");
        assert_eq!(server.stats.latency.count(), 3, "every request records a latency");
        // per-worker counters merge into the aggregate
        let per = server.per_worker_stats();
        assert_eq!(per.iter().map(|p| p.0).sum::<u64>(), served);
        assert_eq!(per.iter().map(|p| p.1).sum::<u64>(), batches);
        // identical windows score identically (deterministic native path)
        let a = server.submit(mk(0)).unwrap().recv().unwrap().nll;
        let b = server.submit(mk(0)).unwrap().recv().unwrap().nll;
        assert!((a - b).abs() < 1e-12);
        server.shutdown();
    }

    #[test]
    fn submit_rejects_bad_window() {
        let j = json::parse(
            r#"{"config": {"name": "t", "n_layers": 1, "d_model": 16,
                "n_heads": 2, "d_ffn": 32, "vocab": 8, "seq_len": 8,
                "batch": 2, "block_sizes": [1]}}"#,
        )
        .unwrap();
        let cfg = crate::model::config::ModelConfig::from_meta(&j).unwrap();
        let ws = bundle::synthetic_weights(&cfg, 12);
        let server = InferenceServer::start_native(
            &cfg, &ws, &ForwardGraph::Fp, Duration::from_millis(1), 2,
        )
        .unwrap();
        assert_eq!(server.num_workers(), 2);
        assert!(server.submit(vec![0i32; 3]).is_err());
        server.shutdown();
    }
}
