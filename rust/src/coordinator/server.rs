//! Continuous-batching inference server — the serving-side L3 coordinator.
//!
//! The paper's case for block rotations is a *serving* argument, and a
//! *decode-time* one (App A: the online R̃3 rotation is paid per generated
//! token). This module provides the runtime that argument lives in: a
//! request router + slot-based continuous batcher in front of any
//! [`ExecBackend`] session.
//!
//! Design (vLLM-style, scaled to this testbed):
//!   * clients submit [`ScoreRequest`]s (token windows → NLL) or
//!     [`GenerateRequest`]s (prompt + `max_new_tokens` → greedy tokens)
//!     and receive responses through oneshot channels;
//!   * each of the `num_workers` replicas owns a backend *session* with
//!     `cfg.batch` attention-state slots. Requests join and leave the live
//!     batch at **step granularity**: score windows prefill free slots and
//!     release them immediately; generation requests prefill their prompt
//!     into a slot and then ride the shared `decode_step` until done,
//!     while new arrivals backfill freed slots between steps. There is no
//!     fixed-size batch assembly and no tail-padding filler — a partial
//!     step simply runs fewer rows (the pjrt adapter hides its static
//!     graph shape internally);
//!   * each worker constructs its own backend *on its replica thread* via
//!     a shared `Send + Sync` factory (PJRT handles are `Rc`-based and
//!     thread-confined; the native backend keeps pooled scratch + session
//!     arenas warm the same way). Scoring and sampling are per-slot
//!     independent (per-token quantization, per-slot attention state), so
//!     NLLs and generated tokens are identical regardless of arrival
//!     order, co-batched requests, or replica count — asserted by
//!     rust/tests/decode_parity.rs;
//!   * [`ServerStats`] tracks request counts, per-phase (prefill/decode)
//!     execution time and token throughput, step occupancy, and three
//!     fixed-bucket atomic latency histograms (end-to-end, prefill phase,
//!     decode phase) with explicit saturation counting. Every field is a
//!     handle registered in a per-server [`Registry`] (`obs::metrics`), so
//!     the coherent [`StatsSnapshot`] that feeds the `perq serve` JSON
//!     output, the Prometheus text dump (`--metrics-out`), and the JSON
//!     metrics snapshot are all views over the same atomics. Completed
//!     requests additionally leave a [`RequestTrace`] (enqueue → admit →
//!     prefill → decode → complete spans) in a ring buffer readable via
//!     [`InferenceServer::recent_traces`].
//!
//! The batch-forming wait is configurable: `--max-wait-ms` on the CLIs,
//! `PERQ_MAX_WAIT_MS` in the environment, else [`DEFAULT_MAX_WAIT_MS`]
//! (see [`resolve_max_wait`]). It only delays *idle* workers to let a
//! fuller prefill form; a worker with active decode slots never waits.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Result};

use crate::backend::{ExecBackend, SessionId};
use crate::model::config::ModelConfig;
use crate::obs::metrics::{Counter, Gauge, Hist, Registry};
use crate::obs::trace::{RequestTrace, Tracer};
use crate::util::json::Json;

pub use crate::backend::ExtraInput;

/// Constructs one backend per worker thread, on that thread (PJRT handles
/// are not `Send`; only the factory crosses threads). Called once per
/// replica, so it must be `Fn`, not `FnOnce`.
pub type BackendFactory = Box<dyn Fn() -> Result<Box<dyn ExecBackend>> + Send + Sync + 'static>;

/// Default batch-forming wait for idle workers, in milliseconds.
pub const DEFAULT_MAX_WAIT_MS: u64 = 5;

/// Resolve the batch-forming wait: CLI `--max-wait-ms` wins, then the
/// `PERQ_MAX_WAIT_MS` environment variable, then [`DEFAULT_MAX_WAIT_MS`].
pub fn resolve_max_wait(cli_ms: Option<u64>) -> Duration {
    let ms = cli_ms
        .or_else(|| {
            std::env::var("PERQ_MAX_WAIT_MS")
                .ok()
                .and_then(|s| s.trim().parse::<u64>().ok())
        })
        .unwrap_or(DEFAULT_MAX_WAIT_MS);
    Duration::from_millis(ms)
}

pub struct ScoreRequest {
    /// seq_len + 1 tokens: the window to score plus the next-token target
    pub tokens: Vec<i32>,
    pub submitted: Instant,
    /// lifecycle-trace ID, assigned at submit time
    pub trace_id: u64,
    respond: Sender<ScoreResponse>,
}

#[derive(Debug)]
pub struct ScoreResponse {
    /// mean next-token NLL over the window (nats)
    pub nll: f64,
    /// queueing + batching + execution latency
    pub latency: Duration,
    /// score windows that shared this request's prefill step
    pub batch_occupancy: usize,
}

pub struct GenerateRequest {
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub submitted: Instant,
    /// lifecycle-trace ID, assigned at submit time
    pub trace_id: u64,
    respond: Sender<GenerateResponse>,
}

#[derive(Debug)]
pub struct GenerateResponse {
    /// greedily sampled tokens (prompt excluded)
    pub tokens: Vec<i32>,
    /// submit → prompt prefilled + first token sampled
    pub prefill_latency: Duration,
    /// first token → generation complete
    pub decode_latency: Duration,
    /// end-to-end (prefill + decode phases)
    pub latency: Duration,
}

enum Request {
    Score(ScoreRequest),
    Generate(GenerateRequest),
}

struct Queue {
    pending: VecDeque<Request>,
    shutdown: bool,
}

/// The request-latency histogram, generalized into `obs::metrics` (PR 6)
/// and re-exported under its historical serving-layer name: √2-spaced
/// microsecond buckets, atomic recording, explicit saturation counting,
/// and the percentile saturation clamp (a rank landing among saturated
/// samples reports the top bucket's lower bound, not a midpoint).
pub use crate::obs::metrics::Hist as LatencyHist;

/// Completed-trace ring capacity per server (see [`Tracer`]).
const TRACE_RING: usize = 256;

/// Milliseconds of a span, for trace records.
fn ms(d: Duration) -> f64 {
    d.as_secs_f64() * 1e3
}

/// Per-worker counters; the aggregate [`ServerStats`] sums across replicas.
#[derive(Default)]
pub struct WorkerStats {
    /// requests completed on this replica (score + generate)
    pub served: AtomicU64,
    /// engine steps (prefill calls + decode calls)
    pub batches: AtomicU64,
    pub exec_ns: AtomicU64,
}

/// Server statistics (atomics; read while running). The aggregate counters
/// are the merge of every worker's [`WorkerStats`]; the phase split and
/// the histograms are aggregate-only.
///
/// Every field is a handle registered in `registry` under a stable
/// `perq_*` metric name (see the README metrics table), so the legacy
/// [`StatsSnapshot`], `registry.render_prometheus()`, and
/// `registry.snapshot_json()` read the very same atomics — the snapshot is
/// a *view over the registry*, not a second accounting path. Each server
/// owns its own registry so concurrent servers in one process never mix
/// counts; process-wide engine metrics live in `obs::metrics::global()`.
pub struct ServerStats {
    /// the registry every handle below is registered in
    pub registry: Arc<Registry>,
    /// requests completed (score + generate)
    pub served: Arc<Counter>,
    /// generate requests completed (subset of `served`)
    pub generated: Arc<Counter>,
    /// engine steps executed (prefill calls + decode calls)
    pub batches: Arc<Counter>,
    pub exec_ns: Arc<Counter>,
    /// execution time spent in prefill steps
    pub prefill_ns: Arc<Counter>,
    /// execution time spent in decode steps
    pub decode_ns: Arc<Counter>,
    /// prompt/window tokens pushed through prefill
    pub prefill_tokens: Arc<Counter>,
    /// tokens produced by decode steps
    pub decode_tokens: Arc<Counter>,
    /// Σ active requests over engine steps (mean = occupancy_sum/batches)
    pub occupancy_sum: Arc<Counter>,
    /// requests dropped because a backend call failed
    pub failures: Arc<Counter>,
    /// requests waiting for admission (sampled at queue transitions)
    pub queue_depth: Arc<Gauge>,
    /// end-to-end request latency histogram
    pub latency: Arc<Hist>,
    /// submit → prefill-complete latency (generate requests)
    pub prefill_lat: Arc<Hist>,
    /// decode-phase latency (generate requests)
    pub decode_lat: Arc<Hist>,
    /// single decode engine-step execution time (per-token span source)
    pub decode_step: Arc<Hist>,
    /// completed request-lifecycle traces (fixed ring)
    pub traces: Tracer,
}

impl Default for ServerStats {
    fn default() -> Self {
        let registry = Arc::new(Registry::new());
        ServerStats {
            served: registry
                .counter("perq_requests_served_total", "requests completed (score + generate)"),
            generated: registry
                .counter("perq_generate_requests_total", "generate requests completed"),
            batches: registry
                .counter("perq_engine_steps_total", "engine steps (prefill + decode calls)"),
            exec_ns: registry
                .counter("perq_exec_ns_total", "execution time across engine steps (ns)"),
            prefill_ns: registry
                .counter("perq_prefill_ns_total", "execution time in prefill steps (ns)"),
            decode_ns: registry
                .counter("perq_decode_ns_total", "execution time in decode steps (ns)"),
            prefill_tokens: registry
                .counter("perq_prefill_tokens_total", "prompt/window tokens through prefill"),
            decode_tokens: registry
                .counter("perq_decode_tokens_total", "tokens produced by decode steps"),
            occupancy_sum: registry
                .counter("perq_step_occupancy_total", "sum of active requests over engine steps"),
            failures: registry
                .counter("perq_request_failures_total", "requests dropped by backend errors"),
            queue_depth: registry.gauge("perq_queue_depth", "requests waiting for admission"),
            latency: registry
                .hist("perq_request_latency_seconds", "end-to-end request latency"),
            prefill_lat: registry.hist(
                "perq_prefill_latency_seconds",
                "submit to prefill-complete latency (generate requests)",
            ),
            decode_lat: registry
                .hist("perq_decode_latency_seconds", "decode-phase latency (generate requests)"),
            decode_step: registry
                .hist("perq_decode_step_seconds", "single decode engine-step execution time"),
            traces: Tracer::new(TRACE_RING),
            registry,
        }
    }
}

/// One coherent read of [`ServerStats`] — the `perq serve` JSON record.
#[derive(Clone, Debug)]
pub struct StatsSnapshot {
    pub served: u64,
    pub generated: u64,
    pub batches: u64,
    pub exec_s: f64,
    pub prefill_s: f64,
    pub decode_s: f64,
    pub prefill_tokens: u64,
    pub decode_tokens: u64,
    /// decode tokens per second of decode execution time
    pub decode_tok_per_s: f64,
    /// mean active requests per engine step
    pub mean_occupancy: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub prefill_p50_ms: f64,
    pub prefill_p95_ms: f64,
    pub prefill_p99_ms: f64,
    pub decode_p50_ms: f64,
    pub decode_p95_ms: f64,
    pub decode_p99_ms: f64,
    /// latency records clamped into the top histogram bucket
    pub hist_saturated: u64,
}

impl ServerStats {
    /// The legacy `perq serve` statistics view, read straight off the
    /// registry-registered handles (see [`ServerStats`]).
    pub fn snapshot(&self) -> StatsSnapshot {
        let batches = self.batches.get();
        let decode_s = self.decode_ns.get() as f64 / 1e9;
        let decode_tokens = self.decode_tokens.get();
        StatsSnapshot {
            served: self.served.get(),
            generated: self.generated.get(),
            batches,
            exec_s: self.exec_ns.get() as f64 / 1e9,
            prefill_s: self.prefill_ns.get() as f64 / 1e9,
            decode_s,
            prefill_tokens: self.prefill_tokens.get(),
            decode_tokens,
            decode_tok_per_s: if decode_s > 0.0 { decode_tokens as f64 / decode_s } else { 0.0 },
            mean_occupancy: if batches > 0 {
                self.occupancy_sum.get() as f64 / batches as f64
            } else {
                0.0
            },
            p50_ms: self.latency.percentile(0.50),
            p95_ms: self.latency.percentile(0.95),
            p99_ms: self.latency.percentile(0.99),
            prefill_p50_ms: self.prefill_lat.percentile(0.50),
            prefill_p95_ms: self.prefill_lat.percentile(0.95),
            prefill_p99_ms: self.prefill_lat.percentile(0.99),
            decode_p50_ms: self.decode_lat.percentile(0.50),
            decode_p95_ms: self.decode_lat.percentile(0.95),
            decode_p99_ms: self.decode_lat.percentile(0.99),
            hist_saturated: self.latency.saturated()
                + self.prefill_lat.saturated()
                + self.decode_lat.saturated(),
        }
    }
}

impl StatsSnapshot {
    /// The PR 5 `perq serve` JSON shape, field for field — consumers of
    /// the legacy record (BENCH_deploy.json rows, the `--metrics-out`
    /// snapshot) must keep seeing exactly these keys.
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("served".to_string(), Json::Num(self.served as f64));
        o.insert("generated".to_string(), Json::Num(self.generated as f64));
        o.insert("batches".to_string(), Json::Num(self.batches as f64));
        o.insert("exec_s".to_string(), Json::Num(self.exec_s));
        o.insert("prefill_s".to_string(), Json::Num(self.prefill_s));
        o.insert("decode_s".to_string(), Json::Num(self.decode_s));
        o.insert("prefill_tokens".to_string(), Json::Num(self.prefill_tokens as f64));
        o.insert("decode_tokens".to_string(), Json::Num(self.decode_tokens as f64));
        o.insert("decode_tok_per_s".to_string(), Json::Num(self.decode_tok_per_s));
        o.insert("mean_occupancy".to_string(), Json::Num(self.mean_occupancy));
        o.insert("p50_ms".to_string(), Json::Num(self.p50_ms));
        o.insert("p95_ms".to_string(), Json::Num(self.p95_ms));
        o.insert("p99_ms".to_string(), Json::Num(self.p99_ms));
        o.insert("prefill_p50_ms".to_string(), Json::Num(self.prefill_p50_ms));
        o.insert("prefill_p95_ms".to_string(), Json::Num(self.prefill_p95_ms));
        o.insert("prefill_p99_ms".to_string(), Json::Num(self.prefill_p99_ms));
        o.insert("decode_p50_ms".to_string(), Json::Num(self.decode_p50_ms));
        o.insert("decode_p95_ms".to_string(), Json::Num(self.decode_p95_ms));
        o.insert("decode_p99_ms".to_string(), Json::Num(self.decode_p99_ms));
        o.insert("hist_saturated".to_string(), Json::Num(self.hist_saturated as f64));
        Json::Obj(o)
    }
}

pub struct InferenceServer {
    queue: Arc<(Mutex<Queue>, Condvar)>,
    stats: Arc<ServerStats>,
    worker_stats: Vec<Arc<WorkerStats>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    running: Arc<AtomicBool>,
    cfg: ModelConfig,
    /// false when the backend cannot decode incrementally (pjrt AOT
    /// graphs) — generation requests are rejected at submit time
    supports_generate: bool,
}

impl InferenceServer {
    /// Spin up `num_workers` backend replicas (one session-owning thread
    /// each, each owning a backend produced by `factory` on that thread)
    /// over a shared request queue. Construction errors from *any* replica
    /// surface here, not on first request.
    pub fn start_backend(factory: BackendFactory, cfg: &ModelConfig, max_wait: Duration,
                         num_workers: usize) -> Result<InferenceServer> {
        let num_workers = num_workers.max(1);
        let factory: Arc<BackendFactory> = Arc::new(factory);
        let queue = Arc::new((
            Mutex::new(Queue { pending: VecDeque::new(), shutdown: false }),
            Condvar::new(),
        ));
        let stats = Arc::new(ServerStats::default());
        let running = Arc::new(AtomicBool::new(true));
        // each replica reports readiness plus whether its backend can
        // decode incrementally (pjrt cannot)
        let (ready_tx, ready_rx) = channel::<Result<bool>>();
        let mut workers = Vec::with_capacity(num_workers);
        let mut worker_stats = Vec::with_capacity(num_workers);
        for w in 0..num_workers {
            let per = Arc::new(WorkerStats::default());
            worker_stats.push(Arc::clone(&per));
            let t_factory = Arc::clone(&factory);
            let t_queue = queue.clone();
            let t_stats = stats.clone();
            let t_running = running.clone();
            let t_ready = ready_tx.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("perq-serve-{w}"))
                .spawn(move || {
                    let backend = match (*t_factory)() {
                        Ok(b) => {
                            let _ = t_ready.send(Ok(b.supports_decode()));
                            b
                        }
                        Err(e) => {
                            let _ = t_ready.send(Err(e));
                            return;
                        }
                    };
                    drop(t_ready);
                    worker_loop(backend, t_queue, t_stats, per, t_running, max_wait)
                });
            match spawned {
                Ok(handle) => workers.push(handle),
                Err(e) => {
                    // wind down the replicas that did start before bailing
                    {
                        let (lock, cv) = &*queue;
                        if let Ok(mut q) = lock.lock() {
                            q.shutdown = true;
                        }
                        cv.notify_all();
                    }
                    for h in workers {
                        let _ = h.join();
                    }
                    return Err(anyhow!("spawning server worker: {e}"));
                }
            }
        }
        drop(ready_tx);
        let mut server = InferenceServer {
            queue,
            stats,
            worker_stats,
            workers,
            running: running.clone(),
            cfg: cfg.clone(),
            supports_generate: true,
        };
        // every replica must come up; a single failure shuts the rest down
        for _ in 0..num_workers {
            match ready_rx.recv() {
                Ok(Ok(can_decode)) => {
                    server.supports_generate &= can_decode;
                }
                Ok(Err(e)) => {
                    server.shutdown();
                    return Err(e);
                }
                Err(_) => {
                    server.shutdown();
                    return Err(anyhow!("server thread died during startup"));
                }
            }
        }
        Ok(server)
    }

    /// Serve through the device-resident PJRT artifact at `artifact` (an
    /// .hlo.txt path) over (already transformed + quantized) weights;
    /// `extras` are the rotation/format inputs.
    #[cfg(feature = "pjrt")]
    pub fn start(artifact: std::path::PathBuf, cfg: &ModelConfig,
                 ws: &crate::model::weights::WeightSet, extras: Vec<ExtraInput>,
                 max_wait: Duration, num_workers: usize) -> Result<InferenceServer> {
        let graph = graph_from_extras(&extras)?;
        // native-only formats (fmt id > 3) must not reach the artifact's
        // lax.switch — it would clamp them to the wrong quantizer
        crate::backend::ensure_artifact_format(&graph)?;
        let cfg2 = cfg.clone();
        let ws2 = ws.clone();
        let factory: BackendFactory = Box::new(move || {
            Ok(Box::new(crate::backend::pjrt::PjrtBackend::load(
                &artifact, &cfg2, &ws2, &graph,
            )?) as Box<dyn ExecBackend>)
        });
        InferenceServer::start_backend(factory, cfg, max_wait, num_workers)
    }

    /// Serve through the pure-Rust native backend — no PJRT, no artifacts.
    /// Each of the `num_workers` replicas clones the weight set (packed
    /// low-bit twins keep that cheap for INT4/INT8 graphs).
    pub fn start_native(cfg: &ModelConfig, ws: &crate::model::weights::WeightSet,
                        graph: &crate::backend::ForwardGraph, max_wait: Duration,
                        num_workers: usize) -> Result<InferenceServer> {
        let cfg2 = cfg.clone();
        let ws2 = ws.clone();
        let graph = graph.clone();
        let factory: BackendFactory = Box::new(move || {
            Ok(Box::new(crate::backend::NativeBackend::new(
                cfg2.clone(),
                ws2.clone(),
                graph.clone(),
            )?) as Box<dyn ExecBackend>)
        });
        InferenceServer::start_backend(factory, cfg, max_wait, num_workers)
    }

    /// Serve a loaded `.perq` deployment artifact — the serve-many half of
    /// quantize-once / serve-many. Replicas come up from the artifact
    /// weights alone (packed low-bit or merged dense); no calibration,
    /// permutation search, or rounding code runs. Native backend only:
    /// deployment artifacts carry no AOT HLO graphs.
    pub fn start_deployed(dm: &crate::deploy::DeployedModel, max_wait: Duration,
                          num_workers: usize) -> Result<InferenceServer> {
        InferenceServer::start_native(&dm.cfg, &dm.ws, &dm.graph, max_wait, num_workers)
    }

    /// Submit a scoring request; returns a receiver for the response.
    pub fn submit(&self, tokens: Vec<i32>) -> Result<Receiver<ScoreResponse>> {
        ensure!(tokens.len() == self.cfg.seq_len + 1,
                "requests carry seq_len+1 tokens (window + next-token target)");
        // validate every token here — including the final next-token
        // target, which never flows through prefill's own check; an
        // out-of-vocab target must fail the submit, not panic a worker
        self.check_tokens(&tokens)?;
        let (tx, rx) = channel();
        self.push(Request::Score(ScoreRequest {
            tokens,
            submitted: Instant::now(),
            trace_id: self.stats.traces.next_id(),
            respond: tx,
        }))?;
        Ok(rx)
    }

    /// Submit a generation request (greedy sampling); returns a receiver
    /// for the response. The request joins a replica's live batch at the
    /// next step boundary and holds one slot until `max_new_tokens` are
    /// produced.
    pub fn submit_generate(&self, prompt: Vec<i32>, max_new_tokens: usize)
                           -> Result<Receiver<GenerateResponse>> {
        ensure!(
            self.supports_generate,
            "this server's backend cannot decode incrementally (fixed-shape AOT \
             graphs) — generation requires the native backend"
        );
        ensure!(!prompt.is_empty(), "generation needs a non-empty prompt");
        ensure!(max_new_tokens >= 1, "generation needs max_new_tokens >= 1");
        ensure!(
            prompt.len() + max_new_tokens <= self.cfg.seq_len,
            "prompt ({}) + max_new_tokens ({max_new_tokens}) exceeds the model's \
             seq_len ({})",
            prompt.len(),
            self.cfg.seq_len
        );
        self.check_tokens(&prompt)?;
        let (tx, rx) = channel();
        self.push(Request::Generate(GenerateRequest {
            prompt,
            max_new_tokens,
            submitted: Instant::now(),
            trace_id: self.stats.traces.next_id(),
            respond: tx,
        }))?;
        Ok(rx)
    }

    fn check_tokens(&self, tokens: &[i32]) -> Result<()> {
        for &t in tokens {
            ensure!(
                t >= 0 && (t as usize) < self.cfg.vocab,
                "token {t} outside the model's vocab (0..{})",
                self.cfg.vocab
            );
        }
        Ok(())
    }

    fn push(&self, req: Request) -> Result<()> {
        let (lock, cv) = &*self.queue;
        let mut q = lock.lock().unwrap();
        ensure!(!q.shutdown, "server is shut down");
        q.pending.push_back(req);
        self.stats.queue_depth.set(q.pending.len() as i64);
        cv.notify_one();
        Ok(())
    }

    /// (served, batches, exec seconds) — the legacy aggregate triple
    /// (`served` counts completed requests of both kinds).
    pub fn stats(&self) -> (u64, u64, f64) {
        let served = self.stats.served.get();
        let batches = self.stats.batches.get();
        let exec_s = self.stats.exec_ns.get() as f64 / 1e9;
        (served, batches, exec_s)
    }

    /// A full coherent statistics read: request counts, per-phase
    /// execution/throughput, occupancy, percentiles, saturation.
    pub fn snapshot(&self) -> StatsSnapshot {
        self.stats.snapshot()
    }

    /// Per-replica (served, batches, exec seconds) snapshots, in worker
    /// order. Sums match the aggregate [`InferenceServer::stats`].
    pub fn per_worker_stats(&self) -> Vec<(u64, u64, f64)> {
        self.worker_stats
            .iter()
            .map(|w| {
                (
                    w.served.load(Ordering::Relaxed),
                    w.batches.load(Ordering::Relaxed),
                    w.exec_ns.load(Ordering::Relaxed) as f64 / 1e9,
                )
            })
            .collect()
    }

    /// Backend replica count.
    pub fn num_workers(&self) -> usize {
        self.worker_stats.len()
    }

    /// Server-side request-latency percentiles (p50, p95, p99) in ms from
    /// the fixed-bucket histogram (~19% bucket resolution).
    pub fn latency_percentiles(&self) -> (f64, f64, f64) {
        let h = &self.stats.latency;
        (h.percentile(0.50), h.percentile(0.95), h.percentile(0.99))
    }

    /// The metrics registry behind this server's statistics. Render with
    /// `render_prometheus()` (text exposition format) or `snapshot_json()`;
    /// both read the same atomics [`InferenceServer::snapshot`] does.
    pub fn registry(&self) -> Arc<Registry> {
        Arc::clone(&self.stats.registry)
    }

    /// Shared handle to the live statistics — for periodic metric dumps
    /// that outlive a `&self` borrow (e.g. the `--metrics-out` writer
    /// thread).
    pub fn shared_stats(&self) -> Arc<ServerStats> {
        Arc::clone(&self.stats)
    }

    /// Completed request-lifecycle traces currently in the ring buffer,
    /// oldest first.
    pub fn recent_traces(&self) -> Vec<RequestTrace> {
        self.stats.traces.recent_traces()
    }

    fn signal_shutdown(&self) {
        self.running.store(false, Ordering::Relaxed);
        let (lock, cv) = &*self.queue;
        if let Ok(mut q) = lock.lock() {
            q.shutdown = true;
        }
        cv.notify_all();
    }

    pub fn shutdown(mut self) {
        self.signal_shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        self.signal_shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Recover the graph description from legacy (matrix.., fmt) extras — the
/// shape the pjrt `start` entry point and the integration suite still use.
#[cfg(feature = "pjrt")]
fn graph_from_extras(extras: &[ExtraInput]) -> Result<crate::backend::ForwardGraph> {
    use crate::backend::ForwardGraph;
    use crate::quant::Format;
    let fmt = extras
        .iter()
        .find_map(|e| match e {
            ExtraInput::ScalarI32(v) => Some(*v),
            _ => None,
        })
        .unwrap_or(0);
    let format = match fmt {
        1 => Format::Int4,
        2 => Format::Fp4,
        3 => Format::Mxfp4,
        4 => Format::Int8,
        _ => Format::None,
    };
    let mats = extras
        .iter()
        .filter(|e| matches!(e, ExtraInput::Matrix(_)))
        .count();
    if mats >= 2 {
        return Ok(ForwardGraph::Online { format });
    }
    let b = extras
        .iter()
        .find_map(|e| match e {
            ExtraInput::Matrix(m) => Some(m.rows),
            _ => None,
        })
        .unwrap_or(1);
    Ok(ForwardGraph::Merged { r3_block: b, format })
}

/// A generation request currently occupying a session slot.
struct ActiveGen {
    req: GenerateRequest,
    generated: Vec<i32>,
    /// when a replica pulled the request off the queue
    admitted: Instant,
    /// when the prompt prefill (+ first token) completed
    prefilled: Instant,
}

use crate::backend::greedy_argmax as argmax;

/// Mean next-token NLL of one scored window from its prefill logits.
fn window_nll(logits: &[f32], tokens: &[i32], t: usize, v: usize) -> f64 {
    let mut nll = 0.0f64;
    for j in 0..t {
        let row = &logits[j * v..(j + 1) * v];
        let mx = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x)) as f64;
        let lse: f64 = row.iter().map(|&x| ((x as f64) - mx).exp()).sum();
        let tgt = tokens[j + 1] as usize;
        nll += mx + lse.ln() - row[tgt] as f64;
    }
    nll / t as f64
}

/// One replica: a backend session with `cfg.batch` slots, driven at step
/// granularity. Score requests prefill free slots and release them in the
/// same step; generation requests hold a slot across decode steps, with
/// new arrivals backfilling freed slots between steps.
fn worker_loop(mut backend: Box<dyn ExecBackend>, queue: Arc<(Mutex<Queue>, Condvar)>,
               stats: Arc<ServerStats>, mine: Arc<WorkerStats>, running: Arc<AtomicBool>,
               max_wait: Duration) {
    let b = backend.cfg().batch;
    let t = backend.cfg().seq_len;
    let v = backend.cfg().vocab;
    // two sessions per replica: generation rides the backend's default
    // KV mode (quantized cache); score requests run in an *exact* scoring
    // session so served NLLs match the eval/`score` path bit-for-bit
    let sid: SessionId = match backend.begin(b) {
        Ok(s) => s,
        Err(e) => {
            crate::log_error!("server: opening execution session failed: {e:#}");
            return;
        }
    };
    let sid_score: SessionId = match backend.begin_scoring(b) {
        Ok(s) => s,
        Err(e) => {
            crate::log_error!("server: opening scoring session failed: {e:#}");
            return;
        }
    };
    let mut gen_slots: Vec<Option<ActiveGen>> = (0..b).map(|_| None).collect();
    let mut last_tokens: Vec<i32> = vec![-1; b];
    let mut logits_buf: Vec<f32> = Vec::new();

    while running.load(Ordering::Relaxed) {
        let n_active = gen_slots.iter().filter(|s| s.is_some()).count();
        // -- pull work: block only when fully idle ------------------------
        let (score_reqs, gen_reqs): (Vec<ScoreRequest>, Vec<GenerateRequest>) = {
            let (lock, cv) = &*queue;
            let mut q = lock.lock().unwrap();
            if n_active == 0 {
                while q.pending.is_empty() && !q.shutdown {
                    q = cv.wait(q).unwrap();
                }
                if q.shutdown && q.pending.is_empty() {
                    return;
                }
                // batch-forming wait: give peers up to max_wait to arrive
                // so the prefill runs fuller (idle workers only — a worker
                // with live decode slots never stalls here)
                let deadline = Instant::now() + max_wait;
                while q.pending.len() < b && !q.shutdown {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    let (qq, timeout) = cv.wait_timeout(q, deadline - now).unwrap();
                    q = qq;
                    if timeout.timed_out() {
                        break;
                    }
                }
            }
            // FIFO admission: scores fill the scoring session (up to b),
            // generations fill the free generation slots; stop at the
            // first request that doesn't fit so nothing is overtaken
            let free_gen = b - n_active;
            let mut scores = Vec::new();
            let mut gens = Vec::new();
            loop {
                let fits = match q.pending.front() {
                    Some(Request::Score(_)) => scores.len() < b,
                    Some(Request::Generate(_)) => gens.len() < free_gen,
                    None => false,
                };
                if !fits {
                    break;
                }
                match q.pending.pop_front().expect("front checked above") {
                    Request::Score(s) => scores.push(s),
                    Request::Generate(g) => gens.push(g),
                }
            }
            stats.queue_depth.set(q.pending.len() as i64);
            (scores, gens)
        };
        // admission stamp for everything pulled this round (trace span:
        // enqueue → admit)
        let admitted = Instant::now();

        // -- score admissions: one batched prefill (exact session) --------
        if !score_reqs.is_empty() {
            // occupancy of THIS engine step: the score windows it runs
            let occupancy = score_reqs.len();
            let slots: Vec<usize> = (0..score_reqs.len()).collect();
            let mut tokens = Vec::with_capacity(slots.len() * t);
            for req in &score_reqs {
                tokens.extend_from_slice(&req.tokens[..t]);
            }
            let t_exec = Instant::now();
            let result = backend.prefill_slots(sid_score, &slots, &tokens);
            let exec_ns = t_exec.elapsed().as_nanos() as u64;
            record_step(&stats, &mine, exec_ns, true, (slots.len() * t) as u64,
                        occupancy as u64);
            for &slot in &slots {
                if let Err(e) = backend.reset_slot(sid_score, slot) {
                    crate::log_warn!("server: releasing score slot {slot} failed: {e:#}");
                }
            }
            match result {
                Ok(logits) => {
                    for (i, req) in score_reqs.into_iter().enumerate() {
                        let nll = window_nll(&logits[i * t * v..(i + 1) * t * v],
                                             &req.tokens, t, v);
                        let latency = req.submitted.elapsed();
                        stats.served.inc();
                        mine.served.fetch_add(1, Ordering::Relaxed);
                        stats.latency.record(latency);
                        stats.traces.record(RequestTrace {
                            id: req.trace_id,
                            kind: "score",
                            queued_ms: ms(admitted - req.submitted),
                            prefill_ms: exec_ns as f64 / 1e6,
                            decode_ms: 0.0,
                            total_ms: ms(latency),
                            decode_steps: 0,
                            ok: true,
                        });
                        let _ = req.respond.send(ScoreResponse {
                            nll,
                            latency,
                            batch_occupancy: occupancy,
                        });
                    }
                }
                Err(e) => {
                    crate::log_error!("server: score prefill failed: {e:#}");
                    // drop senders → clients observe disconnection
                    for req in score_reqs {
                        stats.failures.inc();
                        stats.traces.record(RequestTrace {
                            id: req.trace_id,
                            kind: "score",
                            queued_ms: ms(admitted - req.submitted),
                            prefill_ms: exec_ns as f64 / 1e6,
                            decode_ms: 0.0,
                            total_ms: ms(req.submitted.elapsed()),
                            decode_steps: 0,
                            ok: false,
                        });
                    }
                }
            }
        }

        // -- generation admissions: prefill prompts into free slots -------
        for req in gen_reqs {
            let Some(slot) = (0..b).find(|&s| gen_slots[s].is_none()) else {
                crate::log_warn!("server: admission raced past capacity — requeueing");
                let (lock, cv) = &*queue;
                if let Ok(mut q) = lock.lock() {
                    q.pending.push_front(Request::Generate(req));
                    stats.queue_depth.set(q.pending.len() as i64);
                }
                cv.notify_one();
                break;
            };
            let t_exec = Instant::now();
            let result = backend.prefill_slots(sid, &[slot], &req.prompt);
            let exec_ns = t_exec.elapsed().as_nanos() as u64;
            // a prompt prefill is its own engine step, running 1 request
            record_step(&stats, &mine, exec_ns, true, req.prompt.len() as u64, 1);
            match result {
                Ok(logits) => {
                    // greedy first token from the last prompt position
                    let first = argmax(&logits[(req.prompt.len() - 1) * v..req.prompt.len() * v]);
                    let prefilled = Instant::now();
                    stats.prefill_lat.record(prefilled - req.submitted);
                    let active =
                        ActiveGen { req, generated: vec![first], admitted, prefilled };
                    if active.generated.len() >= active.req.max_new_tokens {
                        finish_generation(&stats, &mine, active);
                        let _ = backend.reset_slot(sid, slot);
                    } else {
                        last_tokens[slot] = first;
                        gen_slots[slot] = Some(active);
                    }
                }
                Err(e) => {
                    crate::log_error!("server: prompt prefill failed: {e:#}");
                    let _ = backend.reset_slot(sid, slot);
                    // drop sender → client observes disconnection
                    stats.failures.inc();
                    stats.traces.record(RequestTrace {
                        id: req.trace_id,
                        kind: "generate",
                        queued_ms: ms(admitted - req.submitted),
                        prefill_ms: exec_ns as f64 / 1e6,
                        decode_ms: 0.0,
                        total_ms: ms(req.submitted.elapsed()),
                        decode_steps: 0,
                        ok: false,
                    });
                }
            }
        }

        // -- one decode step over every active slot -----------------------
        let n_active = gen_slots.iter().filter(|s| s.is_some()).count();
        if n_active == 0 {
            continue;
        }
        let t_exec = Instant::now();
        let result = backend.decode_step_into(sid, &last_tokens, &mut logits_buf);
        let exec_ns = t_exec.elapsed().as_nanos() as u64;
        record_step(&stats, &mine, exec_ns, false, n_active as u64, n_active as u64);
        match result {
            Ok(()) => {
                // tokens count only for steps that actually produced them
                stats.decode_tokens.add(n_active as u64);
                for slot in 0..b {
                    if gen_slots[slot].is_none() {
                        continue;
                    }
                    let tok = argmax(&logits_buf[slot * v..(slot + 1) * v]);
                    let done = {
                        let active = gen_slots[slot].as_mut().expect("checked above");
                        active.generated.push(tok);
                        active.generated.len() >= active.req.max_new_tokens
                    };
                    if done {
                        let finished = gen_slots[slot].take().expect("checked above");
                        finish_generation(&stats, &mine, finished);
                        last_tokens[slot] = -1;
                        let _ = backend.reset_slot(sid, slot);
                    } else {
                        last_tokens[slot] = tok;
                    }
                }
            }
            Err(e) => {
                crate::log_error!("server: decode step failed: {e:#}");
                // abandon the active generations (senders drop) and
                // release their slots so the replica can keep serving
                for slot in 0..b {
                    if let Some(active) = gen_slots[slot].take() {
                        stats.failures.inc();
                        stats.traces.record(RequestTrace {
                            id: active.req.trace_id,
                            kind: "generate",
                            queued_ms: ms(active.admitted - active.req.submitted),
                            prefill_ms: ms(active.prefilled - active.admitted),
                            decode_ms: ms(active.prefilled.elapsed()),
                            total_ms: ms(active.req.submitted.elapsed()),
                            decode_steps: (active.generated.len() as u64).saturating_sub(1),
                            ok: false,
                        });
                        last_tokens[slot] = -1;
                        let _ = backend.reset_slot(sid, slot);
                    }
                }
            }
        }
    }
}

/// Account one engine step (prefill or decode) in the aggregate and
/// per-worker counters.
fn record_step(stats: &ServerStats, mine: &WorkerStats, exec_ns: u64, is_prefill: bool,
               tokens: u64, occupancy: u64) {
    stats.exec_ns.add(exec_ns);
    stats.batches.inc();
    stats.occupancy_sum.add(occupancy);
    if is_prefill {
        stats.prefill_ns.add(exec_ns);
        stats.prefill_tokens.add(tokens);
    } else {
        stats.decode_ns.add(exec_ns);
        // the per-token span source: every decode engine step's execution
        // time (all handles pre-resolved — atomics only on this path)
        stats.decode_step.record_ns(exec_ns);
    }
    mine.exec_ns.fetch_add(exec_ns, Ordering::Relaxed);
    mine.batches.fetch_add(1, Ordering::Relaxed);
}

/// Complete a generation request: respond, account it, and leave its
/// lifecycle trace.
fn finish_generation(stats: &ServerStats, mine: &WorkerStats, active: ActiveGen) {
    let now = Instant::now();
    let latency = now - active.req.submitted;
    let decode_latency = now - active.prefilled;
    stats.served.inc();
    stats.generated.inc();
    mine.served.fetch_add(1, Ordering::Relaxed);
    stats.latency.record(latency);
    stats.decode_lat.record(decode_latency);
    stats.traces.record(RequestTrace {
        id: active.req.trace_id,
        kind: "generate",
        queued_ms: ms(active.admitted - active.req.submitted),
        prefill_ms: ms(active.prefilled - active.admitted),
        decode_ms: ms(decode_latency),
        total_ms: ms(latency),
        decode_steps: (active.generated.len() as u64).saturating_sub(1),
        ok: true,
    });
    let _ = active.req.respond.send(GenerateResponse {
        tokens: active.generated,
        prefill_latency: active.prefilled - active.req.submitted,
        decode_latency,
        latency,
    });
}

#[cfg(test)]
mod tests {
    //! Queue/scheduler logic tests that don't need a real model live in
    //! rust/tests/coordinator_props.rs; full server round-trips are
    //! exercised natively below and in examples/serve_requests.rs,
    //! multi-worker determinism in rust/tests/simd_props.rs and
    //! rust/tests/decode_parity.rs, and PJRT in the integration suite.

    use super::*;
    use crate::backend::ForwardGraph;
    use crate::model::bundle;
    use crate::util::json;

    #[test]
    fn stats_default_zero() {
        let s = ServerStats::default();
        assert_eq!(s.served.get(), 0);
        assert_eq!(s.generated.get(), 0);
        assert_eq!(s.latency.count(), 0);
        assert_eq!(s.latency.percentile(0.5), 0.0);
        let snap = s.snapshot();
        assert_eq!(snap.decode_tokens, 0);
        assert_eq!(snap.decode_tok_per_s, 0.0);
        assert_eq!(snap.mean_occupancy, 0.0);
        assert_eq!(snap.hist_saturated, 0);
        assert!(s.traces.recent_traces().is_empty());
    }

    #[test]
    fn stats_are_a_view_over_the_registry() {
        // the snapshot and the registry render must read the same atomics
        let s = ServerStats::default();
        s.served.add(4);
        s.latency.record(Duration::from_micros(300));
        assert_eq!(s.snapshot().served, 4);
        let prom = s.registry.render_prometheus();
        assert!(prom.contains("perq_requests_served_total 4"), "{prom}");
        assert!(prom.contains("perq_request_latency_seconds_count 1"), "{prom}");
        let j = s.registry.snapshot_json();
        assert_eq!(
            j.get("counters").and_then(|c| c.get("perq_requests_served_total"))
                .and_then(|v| v.as_usize()),
            Some(4)
        );
        // the legacy JSON view carries the exact PR 5 field set
        let legacy = s.snapshot().to_json();
        for key in ["served", "generated", "batches", "exec_s", "prefill_s", "decode_s",
                    "prefill_tokens", "decode_tokens", "decode_tok_per_s", "mean_occupancy",
                    "p50_ms", "p95_ms", "p99_ms", "prefill_p50_ms", "prefill_p95_ms",
                    "prefill_p99_ms", "decode_p50_ms", "decode_p95_ms", "decode_p99_ms",
                    "hist_saturated"] {
            assert!(legacy.get(key).is_some(), "legacy snapshot lost key {key}");
        }
    }

    #[test]
    fn latency_hist_buckets_monotonic() {
        let h = LatencyHist::default();
        for us in [5u64, 50, 500, 5_000, 50_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        let p50 = h.percentile(0.5);
        let p95 = h.percentile(0.95);
        let p99 = h.percentile(0.99);
        assert!(p50 > 0.0 && p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        // p50 of {5,50,500,5000,50000}µs sits in the 500µs bucket: within
        // bucket resolution of 0.5 ms
        assert!((0.3..1.0).contains(&p50), "p50 {p50} ms");
    }

    #[test]
    fn latency_hist_extremes_clamp_with_saturation() {
        let h = LatencyHist::default();
        h.record(Duration::from_nanos(1)); // below 1 µs → first bucket
        assert_eq!(h.saturated(), 0, "low clamp is not saturation");
        h.record(Duration::from_secs(7200)); // beyond range → last bucket
        h.record(Duration::from_secs(9000));
        assert_eq!(h.count(), 3, "clamped records still count");
        assert_eq!(h.saturated(), 2, "top-bucket clamps are tallied");
        assert!(h.percentile(1.0) > h.percentile(0.1));
        // saturation clamp: a rank landing among saturated samples reports
        // the top bucket's lower bound, not its geometric midpoint
        let top_lower_ms =
            LatencyHist::bucket_lower_us(crate::obs::metrics::HIST_BUCKETS - 1) / 1_000.0;
        assert_eq!(h.percentile(1.0), top_lower_ms, "no midpoint beyond the data");
    }

    #[test]
    fn resolve_max_wait_precedence() {
        // CLI value wins outright (env consultation skipped)
        assert_eq!(resolve_max_wait(Some(25)), Duration::from_millis(25));
        assert_eq!(resolve_max_wait(Some(0)), Duration::from_millis(0));
        // no CLI and no env (assuming a clean test environment) → default
        if std::env::var("PERQ_MAX_WAIT_MS").is_err() {
            assert_eq!(resolve_max_wait(None), Duration::from_millis(DEFAULT_MAX_WAIT_MS));
        }
    }

    fn tiny_parts(seq_len: usize, batch: usize)
                  -> (crate::model::config::ModelConfig,
                      crate::model::weights::WeightSet,
                      ForwardGraph) {
        let j = json::parse(&format!(
            r#"{{"config": {{"name": "t", "n_layers": 1, "d_model": 16,
                "n_heads": 2, "d_ffn": 32, "vocab": 8, "seq_len": {seq_len},
                "batch": {batch}, "block_sizes": [1, 8]}}}}"#,
        ))
        .unwrap();
        let cfg = crate::model::config::ModelConfig::from_meta(&j).unwrap();
        let ws = bundle::synthetic_weights(&cfg, 11);
        let graph = ForwardGraph::Merged { r3_block: 8, format: crate::quant::Format::Int4 };
        (cfg, ws, graph)
    }

    fn tiny_server(seq_len: usize, batch: usize, workers: usize) -> InferenceServer {
        let (cfg, ws, graph) = tiny_parts(seq_len, batch);
        InferenceServer::start_native(&cfg, &ws, &graph, Duration::from_millis(1), workers)
            .unwrap()
    }

    #[test]
    fn native_score_round_trip_partial_batch() {
        let server = tiny_server(8, 4, 1);
        assert_eq!(server.num_workers(), 1);
        // 3 requests into a 4-slot server: a partial step, no filler
        let mk = |s: usize| -> Vec<i32> { (0..9).map(|i| ((s + i) % 8) as i32).collect() };
        let rxs: Vec<_> = (0..3).map(|s| server.submit(mk(s)).unwrap()).collect();
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert!(resp.nll.is_finite() && resp.nll > 0.0);
            assert!(resp.batch_occupancy <= 3, "occupancy counts real requests only");
        }
        let (served, batches, _) = server.stats();
        assert_eq!(served, 3);
        assert!(batches >= 1);
        assert_eq!(server.stats.latency.count(), 3, "every request records a latency");
        let snap = server.snapshot();
        assert_eq!(snap.served, 3);
        assert_eq!(snap.generated, 0);
        assert!(snap.prefill_tokens >= 3 * 8, "score windows flow through prefill");
        assert!(snap.mean_occupancy > 0.0);
        // per-worker counters merge into the aggregate
        let per = server.per_worker_stats();
        assert_eq!(per.iter().map(|p| p.0).sum::<u64>(), served);
        assert_eq!(per.iter().map(|p| p.1).sum::<u64>(), batches);
        // identical windows score identically (deterministic native path)
        let a = server.submit(mk(0)).unwrap().recv().unwrap().nll;
        let b = server.submit(mk(0)).unwrap().recv().unwrap().nll;
        assert!((a - b).abs() < 1e-12);
        server.shutdown();
    }

    #[test]
    fn generate_round_trip_greedy_and_deterministic() {
        let server = tiny_server(16, 2, 1);
        let prompt = vec![1i32, 5, 2, 7];
        let a = server.submit_generate(prompt.clone(), 6).unwrap().recv().unwrap();
        assert_eq!(a.tokens.len(), 6);
        assert!(a.tokens.iter().all(|&t| (0..8).contains(&t)), "tokens in vocab");
        assert!(a.latency >= a.prefill_latency);
        // greedy sampling is deterministic: same prompt → same tokens
        let b = server.submit_generate(prompt.clone(), 6).unwrap().recv().unwrap();
        assert_eq!(a.tokens, b.tokens);
        // interleave a score request with generation traffic
        let win: Vec<i32> = (0..17).map(|i| (i % 8) as i32).collect();
        let rx_g = server.submit_generate(prompt, 8).unwrap();
        let rx_s = server.submit(win).unwrap();
        assert_eq!(rx_g.recv().unwrap().tokens.len(), 8);
        assert!(rx_s.recv().unwrap().nll.is_finite());
        let snap = server.snapshot();
        assert_eq!(snap.generated, 3);
        assert_eq!(snap.served, 4, "served counts score + generate");
        // 3 generations × (n-1) decode steps each produced decode tokens
        assert!(snap.decode_tokens >= 5 + 5 + 7, "decode tokens {}", snap.decode_tokens);
        assert!(snap.decode_s > 0.0 && snap.decode_tok_per_s > 0.0);
        assert!(snap.batches > 3, "prefill + decode steps both count");
        server.shutdown();
    }

    #[test]
    fn request_traces_cover_both_submit_paths() {
        let server = tiny_server(16, 2, 1);
        let win: Vec<i32> = (0..17).map(|i| (i % 8) as i32).collect();
        server.submit(win).unwrap().recv().unwrap();
        server.submit_generate(vec![1, 5, 2], 4).unwrap().recv().unwrap();
        let traces = server.recent_traces();
        assert_eq!(traces.len(), 2, "every completed request leaves a trace");
        assert!(traces[0].id < traces[1].id, "IDs are monotone with submit order");
        assert!(traces.iter().any(|t| t.kind == "score"));
        let g = traces.iter().find(|t| t.kind == "generate").expect("generate trace");
        assert!(g.ok);
        assert_eq!(g.decode_steps, 3, "4 tokens = prefill's first + 3 decode steps");
        assert!(g.decode_ms <= g.total_ms && g.prefill_ms <= g.total_ms);
        // the registry saw the same traffic the snapshot did
        let prom = server.registry().render_prometheus();
        assert!(prom.contains("perq_requests_served_total 2"), "{prom}");
        assert!(prom.contains("perq_generate_requests_total 1"), "{prom}");
        server.shutdown();
    }

    #[test]
    fn served_nll_is_exact_regardless_of_kv_mode() {
        // the server scores through an exact (f32-KV) scoring session,
        // so served NLLs must equal a direct exact-session rescore
        // bit-for-bit even though generation sessions default to the
        // quantized cache
        let (cfg, ws, graph) = tiny_parts(8, 4);
        let server = InferenceServer::start_native(
            &cfg, &ws, &graph, Duration::from_millis(1), 1,
        )
        .unwrap();
        let win: Vec<i32> = (0..9).map(|i| ((i * 3 + 1) % 8) as i32).collect();
        let served = server.submit(win.clone()).unwrap().recv().unwrap().nll;
        server.shutdown();
        use crate::backend::NativeBackend;
        use crate::tensor::KvMode;
        let mut be = NativeBackend::new(cfg, ws, graph).unwrap();
        let sid = be.begin_with_mode(1, KvMode::F32).unwrap();
        let logits = be.prefill_slots(sid, &[0], &win[..8]).unwrap();
        let direct = window_nll(&logits, &win, 8, 8);
        assert_eq!(served.to_bits(), direct.to_bits(),
                   "served NLL must match the exact rescore ({served} vs {direct})");
    }

    #[test]
    fn submit_rejects_out_of_vocab_tokens() {
        let server = tiny_server(8, 2, 1);
        // out-of-vocab *target* token (the final entry never reaches
        // prefill's own validation) must fail at submit, not panic a
        // worker thread
        let mut win: Vec<i32> = (0..9).map(|i| (i % 8) as i32).collect();
        win[8] = 99;
        assert!(server.submit(win).is_err());
        let mut win2: Vec<i32> = (0..9).map(|i| (i % 8) as i32).collect();
        win2[3] = -2;
        assert!(server.submit(win2).is_err());
        assert!(server.submit_generate(vec![1, 99], 2).is_err());
        // the server is still alive and serving after the rejections
        let ok: Vec<i32> = (0..9).map(|i| (i % 8) as i32).collect();
        assert!(server.submit(ok).unwrap().recv().unwrap().nll.is_finite());
        server.shutdown();
    }

    #[test]
    fn generate_rejects_oversized_requests() {
        let server = tiny_server(8, 2, 1);
        assert!(server.submit_generate(vec![], 3).is_err());
        assert!(server.submit_generate(vec![1, 2, 3], 0).is_err());
        assert!(server.submit_generate(vec![1; 6], 3).is_err(), "6 + 3 > seq_len 8");
        assert!(server.submit_generate(vec![1; 4], 4).is_ok());
        server.shutdown();
    }

    #[test]
    fn submit_rejects_bad_window() {
        let j = json::parse(
            r#"{"config": {"name": "t", "n_layers": 1, "d_model": 16,
                "n_heads": 2, "d_ffn": 32, "vocab": 8, "seq_len": 8,
                "batch": 2, "block_sizes": [1]}}"#,
        )
        .unwrap();
        let cfg = crate::model::config::ModelConfig::from_meta(&j).unwrap();
        let ws = bundle::synthetic_weights(&cfg, 12);
        let server = InferenceServer::start_native(
            &cfg, &ws, &ForwardGraph::Fp, Duration::from_millis(1), 2,
        )
        .unwrap();
        assert_eq!(server.num_workers(), 2);
        assert!(server.submit(vec![0i32; 3]).is_err());
        server.shutdown();
    }
}
