//! HTTP/1.1 wire plumbing for the network front door — no dependencies
//! beyond `std::net`.
//!
//! This module owns everything connection-shaped so `coordinator::http`
//! can stay about *serving policy* (routing, admission, drain):
//!
//!   * [`Conn`] — a buffered `TcpStream` wrapper with read/write timeouts,
//!     keep-alive/pipelining leftovers, and the [`fault`] hooks. Reading a
//!     request yields a [`ReadOutcome`]: a parsed [`HttpRequest`], a clean
//!     close at a request boundary, or a protocol/resource violation with
//!     the exact status to answer before closing (400/405-class parse
//!     errors, 408 slowloris timeout, 411 missing length, 413 oversized
//!     body, 431 oversized head, 505 bad version);
//!   * response writers — fixed-length ([`Conn::write_response`]) and
//!     chunked streaming ([`Conn::write_chunked_head`] /
//!     [`Conn::write_chunk`] / [`Conn::finish_chunks`]); a fixed response
//!     is a **single** socket write, so the `drop_mid_response` fault has
//!     deterministic first-write-delivered semantics;
//!   * [`fault`] — the `PERQ_NET_FAULT` deterministic connection-fault
//!     harness, the network twin of the engine-step `PERQ_FAULT` module
//!     (`backend::native::fault`);
//!   * [`client`] — a minimal blocking HTTP/1.1 client (one request per
//!     connection) shared by the integration tests and the load generator;
//!   * [`install_shutdown_signals`] / [`shutdown_signaled`] — an
//!     async-signal-safe SIGTERM/SIGINT latch for `perq serve --http`.

use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

/// Cap on the request line + headers, before the body starts (431 beyond).
pub const MAX_HEAD_BYTES: usize = 8 * 1024;
/// Cap on the header count (431 beyond) — no header-bomb allocations.
pub const MAX_HEADERS: usize = 100;

/// Deterministic fault injection for the connection path — the harness
/// behind `PERQ_NET_FAULT` that rust/tests/http_front.rs drives so
/// connection-level failures are testable without flaky sockets.
///
/// Spec grammar (comma-separated clauses, unknown clauses are warned and
/// ignored):
///   * `accept_close:N`       — close the N-th accepted connection
///                              immediately (client vanished after accept)
///   * `stall_read:N:MS`      — the N-th connection's reads sleep MS ms
///                              and then time out (slowloris)
///   * `drop_mid_response:N`  — on the N-th connection, every write after
///                              the first fails with `BrokenPipe` (client
///                              disconnected mid-response)
///
/// Connections are counted process-wide from the moment the plan is armed
/// ([`arm`] resets the counter), which keeps injection deterministic for
/// single-listener tests. When disarmed — the normal state — every hook
/// is a single relaxed atomic load.
pub mod fault {
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::sync::{Mutex, Once};

    /// One armed injection plan (see the module docs for the grammar).
    #[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
    pub struct NetFaultPlan {
        /// close exactly this (1-based) accepted connection
        pub accept_close: Option<u64>,
        /// (conn, ms): this connection's reads sleep `ms` then time out
        pub stall_read: Option<(u64, u64)>,
        /// on this connection, writes after the first return `BrokenPipe`
        pub drop_mid_response: Option<u64>,
    }

    impl NetFaultPlan {
        pub fn is_empty(&self) -> bool {
            *self == NetFaultPlan::default()
        }
    }

    static ACTIVE: AtomicBool = AtomicBool::new(false);
    static CONN: AtomicU64 = AtomicU64::new(0);
    static PLAN: Mutex<NetFaultPlan> = Mutex::new(NetFaultPlan {
        accept_close: None,
        stall_read: None,
        drop_mid_response: None,
    });
    static ENV_ONCE: Once = Once::new();

    /// Parse a `PERQ_NET_FAULT` spec. Returns the plan plus every clause
    /// that failed to parse (callers log those — a typo must not silently
    /// disable an intended fault).
    pub fn parse(spec: &str) -> (NetFaultPlan, Vec<String>) {
        let mut plan = NetFaultPlan::default();
        let mut rejected = Vec::new();
        for clause in spec.split(',').map(str::trim).filter(|c| !c.is_empty()) {
            let mut parts = clause.split(':');
            let parsed = match parts.next() {
                Some("accept_close") => {
                    match (parts.next().and_then(|n| n.parse::<u64>().ok()), parts.next()) {
                        (Some(n), None) if n >= 1 => {
                            plan.accept_close = Some(n);
                            true
                        }
                        _ => false,
                    }
                }
                Some("stall_read") => {
                    let conn = parts.next().and_then(|n| n.parse::<u64>().ok());
                    let ms = parts.next().and_then(|n| n.parse::<u64>().ok());
                    match (conn, ms, parts.next()) {
                        (Some(conn), Some(ms), None) if conn >= 1 => {
                            plan.stall_read = Some((conn, ms));
                            true
                        }
                        _ => false,
                    }
                }
                Some("drop_mid_response") => {
                    match (parts.next().and_then(|n| n.parse::<u64>().ok()), parts.next()) {
                        (Some(n), None) if n >= 1 => {
                            plan.drop_mid_response = Some(n);
                            true
                        }
                        _ => false,
                    }
                }
                _ => false,
            };
            if !parsed {
                rejected.push(clause.to_string());
            }
        }
        (plan, rejected)
    }

    /// Arm `plan`, resetting the connection counter. Process-global: tests
    /// that arm faults must serialize against each other.
    pub fn arm(plan: NetFaultPlan) {
        *PLAN.lock().unwrap() = plan;
        CONN.store(0, Ordering::SeqCst);
        ACTIVE.store(!plan.is_empty(), Ordering::SeqCst);
    }

    /// Disarm injection (every hook returns to one relaxed load).
    pub fn disarm() {
        ACTIVE.store(false, Ordering::SeqCst);
        *PLAN.lock().unwrap() = NetFaultPlan::default();
    }

    /// Arm from `PERQ_NET_FAULT` once per process (the HTTP front end
    /// calls this at start; explicit [`arm`] in tests takes precedence
    /// afterwards).
    pub fn load_env_once() {
        ENV_ONCE.call_once(|| {
            if let Ok(spec) = std::env::var("PERQ_NET_FAULT") {
                let (plan, rejected) = parse(&spec);
                for clause in rejected {
                    crate::log_warn!(
                        "PERQ_NET_FAULT: ignoring unparsable clause {clause:?} \
                         (grammar: accept_close:N, stall_read:N:MS, drop_mid_response:N)"
                    );
                }
                if !plan.is_empty() {
                    crate::log_warn!("PERQ_NET_FAULT armed: {plan:?}");
                    arm(plan);
                }
            }
        });
    }

    /// Stamp the next accepted connection (1-based since the last [`arm`]).
    pub fn next_conn_id() -> u64 {
        CONN.fetch_add(1, Ordering::SeqCst) + 1
    }

    /// Should the accept loop drop connection `conn` on the floor?
    #[inline]
    pub fn accept_close(conn: u64) -> bool {
        if !ACTIVE.load(Ordering::Relaxed) {
            return false;
        }
        PLAN.lock().unwrap().accept_close == Some(conn)
    }

    /// Milliseconds connection `conn`'s reads stall before timing out.
    #[inline]
    pub fn stall_read(conn: u64) -> Option<u64> {
        if !ACTIVE.load(Ordering::Relaxed) {
            return None;
        }
        match PLAN.lock().unwrap().stall_read {
            Some((c, ms)) if c == conn => Some(ms),
            _ => None,
        }
    }

    /// Do writes after the first on connection `conn` break?
    #[inline]
    pub fn drop_mid_response(conn: u64) -> bool {
        if !ACTIVE.load(Ordering::Relaxed) {
            return false;
        }
        PLAN.lock().unwrap().drop_mid_response == Some(conn)
    }
}

/// One parsed HTTP/1.1 request. Header names are lowercased at parse time
/// (HTTP header names are case-insensitive); values keep their bytes.
#[derive(Debug)]
pub struct HttpRequest {
    pub method: String,
    /// the raw request target (path + optional query string)
    pub target: String,
    /// `HTTP/1.0` or `HTTP/1.1` (anything else never parses — 505)
    pub version: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// Case-insensitive header lookup (names are stored lowercased).
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(n, _)| *n == name).map(|(_, v)| v.as_str())
    }

    /// The request path with any query string stripped.
    pub fn path(&self) -> &str {
        self.target.split('?').next().unwrap_or("")
    }

    /// Does the client ask for the connection to close after the response?
    pub fn wants_close(&self) -> bool {
        self.version == "HTTP/1.0"
            || self
                .header("connection")
                .map_or(false, |v| v.eq_ignore_ascii_case("close"))
    }
}

/// What reading one request off a connection produced.
pub enum ReadOutcome {
    /// a complete, well-framed request
    Request(HttpRequest),
    /// clean EOF at a request boundary (keep-alive end) — close silently
    Closed,
    /// protocol violation or resource-cap hit: answer `status` (with
    /// `reason` as the body) and close the connection
    Bad { status: u16, reason: &'static str },
}

/// A buffered server-side connection: socket timeouts applied, leftover
/// bytes preserved across keep-alive requests, [`fault`] hooks consulted
/// on every read and write.
pub struct Conn {
    stream: TcpStream,
    /// process-wide accept ordinal (see [`fault::next_conn_id`])
    pub id: u64,
    /// bytes read but not yet consumed (pipelined/next requests)
    buf: Vec<u8>,
    /// completed socket writes — the `drop_mid_response` fault breaks
    /// every write after the first
    writes: u64,
}

impl Conn {
    /// Wrap an accepted stream: disable Nagle (token chunks must flush per
    /// step, not per segment) and bound every read/write.
    pub fn new(stream: TcpStream, id: u64, read_timeout: Duration,
               write_timeout: Duration) -> io::Result<Conn> {
        let _ = stream.set_nodelay(true);
        stream.set_read_timeout(Some(read_timeout.max(Duration::from_millis(1))))?;
        stream.set_write_timeout(Some(write_timeout.max(Duration::from_millis(1))))?;
        Ok(Conn { stream, id, buf: Vec::new(), writes: 0 })
    }

    /// Pull more bytes off the socket into the leftover buffer. `Ok(0)`
    /// is EOF. The `stall_read` fault turns this into a slowloris read:
    /// sleep, then surface the timeout the real socket would.
    fn fill(&mut self) -> io::Result<usize> {
        if let Some(ms) = fault::stall_read(self.id) {
            std::thread::sleep(Duration::from_millis(ms));
            return Err(io::Error::new(io::ErrorKind::TimedOut,
                                      "PERQ_NET_FAULT: injected read stall"));
        }
        let mut chunk = [0u8; 4096];
        let n = self.stream.read(&mut chunk)?;
        self.buf.extend_from_slice(&chunk[..n]);
        Ok(n)
    }

    /// Read one request, honoring the head/header/body caps. `max_body`
    /// bounds the declared `Content-Length` (413 beyond).
    pub fn read_request(&mut self, max_body: usize) -> ReadOutcome {
        // -- head: read until the blank line, within MAX_HEAD_BYTES -------
        let head_end = loop {
            if let Some(pos) = find_subslice(&self.buf, b"\r\n\r\n") {
                break pos;
            }
            if self.buf.len() > MAX_HEAD_BYTES {
                return ReadOutcome::Bad { status: 431, reason: "request head too large" };
            }
            match self.fill() {
                Ok(0) if self.buf.is_empty() => return ReadOutcome::Closed,
                Ok(0) => {
                    return ReadOutcome::Bad { status: 400, reason: "truncated request" }
                }
                Ok(_) => {}
                Err(e) => return read_err(e),
            }
        };
        if head_end > MAX_HEAD_BYTES {
            return ReadOutcome::Bad { status: 431, reason: "request head too large" };
        }
        let body_start = head_end + 4;
        let head = match parse_request_head(&self.buf[..head_end], max_body) {
            Ok(h) => h,
            Err((status, reason)) => return ReadOutcome::Bad { status, reason },
        };

        // -- body fill ------------------------------------------------------
        let body_end = body_start + head.body_len;
        while self.buf.len() < body_end {
            match self.fill() {
                Ok(0) => {
                    return ReadOutcome::Bad { status: 400, reason: "truncated request body" }
                }
                Ok(_) => {}
                Err(e) => return read_err(e),
            }
        }
        let body = self.buf[body_start..body_end].to_vec();
        self.buf.drain(..body_end);
        ReadOutcome::Request(HttpRequest {
            method: head.method,
            target: head.target,
            version: head.version,
            headers: head.headers,
            body,
        })
    }

    /// One socket write, with the `drop_mid_response` fault applied: on an
    /// armed connection, every write after the first breaks like a vanished
    /// client's RST would — deterministically.
    pub fn write_all(&mut self, bytes: &[u8]) -> io::Result<()> {
        if self.writes >= 1 && fault::drop_mid_response(self.id) {
            return Err(io::Error::new(io::ErrorKind::BrokenPipe,
                                      "PERQ_NET_FAULT: injected mid-response disconnect"));
        }
        self.stream.write_all(bytes)?;
        self.writes += 1;
        Ok(())
    }

    /// Write a complete fixed-length response as ONE socket write.
    pub fn write_response(&mut self, status: u16, content_type: &str,
                          extra: &[(&str, &str)], body: &[u8],
                          close: bool) -> io::Result<()> {
        let bytes = response_bytes(status, content_type, extra, body, close);
        self.write_all(&bytes)
    }

    /// Start a chunked (streaming) response: status line, headers, and the
    /// first chunk in one write, so even a `drop_mid_response` client sees
    /// the stream begin.
    pub fn write_chunked_head(&mut self, status: u16, content_type: &str,
                              extra: &[(&str, &str)], first_chunk: &[u8],
                              close: bool) -> io::Result<()> {
        let mut head = format!("HTTP/1.1 {} {}\r\n", status, status_reason(status));
        head.push_str(&format!("Content-Type: {content_type}\r\n"));
        head.push_str("Transfer-Encoding: chunked\r\n");
        for (k, v) in extra {
            head.push_str(&format!("{k}: {v}\r\n"));
        }
        if close {
            head.push_str("Connection: close\r\n");
        }
        head.push_str("\r\n");
        let mut bytes = head.into_bytes();
        encode_chunk(&mut bytes, first_chunk);
        self.write_all(&bytes)
    }

    /// Stream one more chunk (skipped for empty data — a zero-length chunk
    /// would terminate the stream).
    pub fn write_chunk(&mut self, data: &[u8]) -> io::Result<()> {
        if data.is_empty() {
            return Ok(());
        }
        let mut bytes = Vec::with_capacity(data.len() + 16);
        encode_chunk(&mut bytes, data);
        self.write_all(&bytes)
    }

    /// Terminate a chunked response (optionally with a final data chunk).
    pub fn finish_chunks(&mut self, last: &[u8]) -> io::Result<()> {
        let mut bytes = Vec::with_capacity(last.len() + 24);
        encode_chunk(&mut bytes, last);
        bytes.extend_from_slice(b"0\r\n\r\n");
        self.write_all(&bytes)
    }
}

/// A parsed request head: the request line, headers (names lowercased),
/// and the declared body length, already validated against the caller's
/// body cap.
pub struct RequestHead {
    pub method: String,
    pub target: String,
    pub version: String,
    pub headers: Vec<(String, String)>,
    pub body_len: usize,
}

/// Parse the bytes of one request head — everything before the blank
/// line, exclusive of the `\r\n\r\n` itself — into a [`RequestHead`], or
/// the `(status, reason)` to answer before closing.
///
/// Pure (no socket, no state): this is the function the byte-mutation
/// fuzzer in rust/verify/http.rs hammers with arbitrary inputs, so every
/// rejection must come back as `Err`, never a panic. [`Conn::read_request`]
/// layers the socket framing (head accumulation, 431 cap, body fill) on
/// top.
pub fn parse_request_head(
    head: &[u8],
    max_body: usize,
) -> Result<RequestHead, (u16, &'static str)> {
    let head = match std::str::from_utf8(head) {
        Ok(s) => s,
        Err(_) => return Err((400, "request head is not UTF-8")),
    };

    // -- request line -----------------------------------------------------
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let parts: Vec<&str> = request_line.split(' ').collect();
    if parts.len() != 3 || parts[0].is_empty() || parts[1].is_empty() {
        return Err((400, "malformed request line"));
    }
    let (method, target, version) = (parts[0], parts[1], parts[2]);
    if version != "HTTP/1.1" && version != "HTTP/1.0" {
        return Err((505, "unsupported HTTP version"));
    }

    // -- headers ------------------------------------------------------------
    let mut headers: Vec<(String, String)> = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        if headers.len() >= MAX_HEADERS {
            return Err((431, "too many headers"));
        }
        let Some(colon) = line.find(':') else {
            return Err((400, "malformed header line"));
        };
        let name = line[..colon].trim().to_ascii_lowercase();
        if name.is_empty() {
            return Err((400, "malformed header line"));
        }
        headers.push((name, line[colon + 1..].trim().to_string()));
    }

    // -- body framing -------------------------------------------------------
    let te = headers.iter().any(|(n, _)| n == "transfer-encoding");
    if te {
        return Err((501, "chunked request bodies are not supported"));
    }
    let content_length = match headers.iter().find(|(n, _)| n == "content-length") {
        Some((_, v)) => match v.parse::<u64>() {
            Ok(n) => Some(n as usize),
            Err(_) => return Err((400, "bad Content-Length")),
        },
        None => None,
    };
    let body_len = match (method, content_length) {
        // requests that carry payloads must declare their framing
        ("POST" | "PUT" | "PATCH", None) => return Err((411, "missing Content-Length")),
        (_, Some(n)) if n > max_body => return Err((413, "request body too large")),
        (_, Some(n)) => n,
        (_, None) => 0,
    };
    Ok(RequestHead {
        method: method.to_string(),
        target: target.to_string(),
        version: version.to_string(),
        headers,
        body_len,
    })
}

/// Map a read error to the status it must answer: timeouts are the
/// slowloris 408, anything else is a generic 400 before closing.
fn read_err(e: io::Error) -> ReadOutcome {
    match e.kind() {
        io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut => {
            ReadOutcome::Bad { status: 408, reason: "read timeout" }
        }
        _ => ReadOutcome::Bad { status: 400, reason: "connection error" },
    }
}

/// Append one chunked-transfer-encoded chunk (no-op for empty data).
fn encode_chunk(out: &mut Vec<u8>, data: &[u8]) {
    if data.is_empty() {
        return;
    }
    out.extend_from_slice(format!("{:x}\r\n", data.len()).as_bytes());
    out.extend_from_slice(data);
    out.extend_from_slice(b"\r\n");
}

/// Reason phrase for every status the front door can answer.
pub fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        411 => "Length Required",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        499 => "Client Closed Request",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        505 => "HTTP Version Not Supported",
        _ => "Unknown",
    }
}

/// Serialize a complete fixed-length response.
pub fn response_bytes(status: u16, content_type: &str, extra: &[(&str, &str)],
                      body: &[u8], close: bool) -> Vec<u8> {
    let mut head = format!("HTTP/1.1 {} {}\r\n", status, status_reason(status));
    head.push_str(&format!("Content-Type: {content_type}\r\n"));
    head.push_str(&format!("Content-Length: {}\r\n", body.len()));
    for (k, v) in extra {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    if close {
        head.push_str("Connection: close\r\n");
    }
    head.push_str("\r\n");
    let mut bytes = head.into_bytes();
    bytes.extend_from_slice(body);
    bytes
}

/// Minimal blocking HTTP/1.1 client — one request per connection
/// (`Connection: close`), fixed-length and chunked responses decoded.
/// Shared by rust/tests/http_front.rs and examples/load_gen.rs; not a
/// general-purpose client.
pub mod client {
    use super::find_subslice;
    use std::io::{Read, Write};
    use std::net::TcpStream;
    use std::time::Duration;

    /// One decoded response: status, lowercased header names, full body
    /// (chunked transfer encoding already stripped).
    #[derive(Debug)]
    pub struct Response {
        pub status: u16,
        pub headers: Vec<(String, String)>,
        pub body: Vec<u8>,
    }

    impl Response {
        pub fn header(&self, name: &str) -> Option<&str> {
            let name = name.to_ascii_lowercase();
            self.headers.iter().find(|(n, _)| *n == name).map(|(_, v)| v.as_str())
        }

        pub fn body_str(&self) -> String {
            String::from_utf8_lossy(&self.body).into_owned()
        }
    }

    fn bad(msg: &str) -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::InvalidData, msg.to_string())
    }

    /// Fire one request and decode the response. The server closes after
    /// responding (we send `Connection: close`), so the read loop runs to
    /// EOF; `timeout` bounds every socket read/write.
    pub fn request(addr: &str, method: &str, path: &str,
                   headers: &[(&str, &str)], body: &[u8],
                   timeout: Duration) -> std::io::Result<Response> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(timeout.max(Duration::from_millis(1))))?;
        stream.set_write_timeout(Some(timeout.max(Duration::from_millis(1))))?;
        let mut stream = stream;
        let mut req = format!("{method} {path} HTTP/1.1\r\n");
        req.push_str("Host: perq\r\n");
        req.push_str("Connection: close\r\n");
        for (k, v) in headers {
            req.push_str(&format!("{k}: {v}\r\n"));
        }
        if !body.is_empty() || method == "POST" || method == "PUT" {
            req.push_str(&format!("Content-Length: {}\r\n", body.len()));
        }
        req.push_str("\r\n");
        stream.write_all(req.as_bytes())?;
        stream.write_all(body)?;
        let mut raw = Vec::new();
        let mut chunk = [0u8; 4096];
        loop {
            match stream.read(&mut chunk) {
                Ok(0) => break,
                Ok(n) => raw.extend_from_slice(&chunk[..n]),
                Err(e) => return Err(e),
            }
        }
        parse_response(&raw)
    }

    /// Decode a raw response byte stream (head + framed body).
    pub fn parse_response(raw: &[u8]) -> std::io::Result<Response> {
        let head_end = find_subslice(raw, b"\r\n\r\n")
            .ok_or_else(|| bad("response head never completed"))?;
        let head = std::str::from_utf8(&raw[..head_end])
            .map_err(|_| bad("response head is not UTF-8"))?;
        let mut lines = head.split("\r\n");
        let status_line = lines.next().unwrap_or("");
        let mut parts = status_line.split(' ');
        let _version = parts.next().unwrap_or("");
        let status: u16 = parts
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| bad("malformed status line"))?;
        let mut headers = Vec::new();
        for line in lines {
            if line.is_empty() {
                continue;
            }
            let Some(colon) = line.find(':') else {
                return Err(bad("malformed response header"));
            };
            headers.push((
                line[..colon].trim().to_ascii_lowercase(),
                line[colon + 1..].trim().to_string(),
            ));
        }
        let rest = &raw[head_end + 4..];
        let chunked = headers
            .iter()
            .any(|(n, v)| n == "transfer-encoding" && v.eq_ignore_ascii_case("chunked"));
        let body = if chunked {
            decode_chunked(rest)?
        } else {
            match headers.iter().find(|(n, _)| n == "content-length") {
                Some((_, v)) => {
                    let n: usize =
                        v.parse().map_err(|_| bad("bad response Content-Length"))?;
                    if rest.len() < n {
                        return Err(bad("truncated response body"));
                    }
                    rest[..n].to_vec()
                }
                None => rest.to_vec(),
            }
        };
        Ok(Response { status, headers, body })
    }

    /// Strip chunked transfer encoding. Errors on truncation — a stream a
    /// fault (or a real disconnect) cut short is detectable, not silent.
    pub fn decode_chunked(mut rest: &[u8]) -> std::io::Result<Vec<u8>> {
        let mut body = Vec::new();
        loop {
            let line_end =
                find_subslice(rest, b"\r\n").ok_or_else(|| bad("truncated chunk size"))?;
            let size_str = std::str::from_utf8(&rest[..line_end])
                .map_err(|_| bad("chunk size is not UTF-8"))?;
            // chunk extensions (";...") are legal — ignore them
            let size_str = size_str.split(';').next().unwrap_or("").trim();
            let size = usize::from_str_radix(size_str, 16)
                .map_err(|_| bad("bad chunk size"))?;
            rest = &rest[line_end + 2..];
            if size == 0 {
                return Ok(body);
            }
            if rest.len() < size + 2 {
                return Err(bad("truncated chunk data"));
            }
            body.extend_from_slice(&rest[..size]);
            rest = &rest[size + 2..];
        }
    }
}

/// First offset of `needle` in `hay`, if any.
pub(crate) fn find_subslice(hay: &[u8], needle: &[u8]) -> Option<usize> {
    if needle.is_empty() || hay.len() < needle.len() {
        return None;
    }
    hay.windows(needle.len()).position(|w| w == needle)
}

// -- shutdown signals -----------------------------------------------------

use std::sync::atomic::{AtomicBool, Ordering};

/// Latched by the SIGTERM/SIGINT handler — polled by `perq serve --http`.
static SIGNALED: AtomicBool = AtomicBool::new(false);

/// Has a shutdown signal arrived since [`install_shutdown_signals`]?
pub fn shutdown_signaled() -> bool {
    SIGNALED.load(Ordering::Relaxed)
}

/// Test hook: latch the same flag a real SIGTERM would.
pub fn simulate_shutdown_signal() {
    SIGNALED.store(true, Ordering::SeqCst);
}

/// Install SIGTERM + SIGINT handlers that latch [`shutdown_signaled`].
/// The handler body is one atomic store — async-signal-safe — and `std`
/// already links libc, so `signal(2)` is declared here directly instead
/// of pulling in a crate.
#[cfg(unix)]
pub fn install_shutdown_signals() {
    extern "C" fn on_signal(_sig: i32) {
        SIGNALED.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    // SAFETY: `signal(2)` is only unsafe through its handler contract, and
    // `on_signal` honors it: an `extern "C" fn(i32)` (the exact type
    // `signal` expects, passed as its address) whose body is a single
    // atomic store — async-signal-safe, no allocation, no locks, no Rust
    // unwinding across the FFI boundary.
    unsafe {
        signal(SIGTERM, on_signal as usize);
        signal(SIGINT, on_signal as usize);
    }
}

/// Non-unix builds poll the latch only (set via CLI backstops or tests).
#[cfg(not(unix))]
pub fn install_shutdown_signals() {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_spec_grammar() {
        let (plan, rejected) = fault::parse("accept_close:2,stall_read:1:50,drop_mid_response:3");
        assert_eq!(plan.accept_close, Some(2));
        assert_eq!(plan.stall_read, Some((1, 50)));
        assert_eq!(plan.drop_mid_response, Some(3));
        assert!(rejected.is_empty());
        // junk clauses are reported, never silently dropped
        let (plan, rejected) = fault::parse("accept_close:0,stall_read:1,typo:4,stall_read:2:5:9");
        assert!(plan.is_empty(), "{plan:?}");
        assert_eq!(rejected.len(), 4);
        // empty/whitespace specs are fine
        let (plan, rejected) = fault::parse("  ");
        assert!(plan.is_empty() && rejected.is_empty());
    }

    #[test]
    fn response_bytes_shape() {
        let b = response_bytes(429, "application/json", &[("Retry-After", "1")],
                               b"{\"error\":\"queue_full\"}", true);
        let s = String::from_utf8(b).unwrap();
        assert!(s.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{s}");
        assert!(s.contains("Content-Length: 22\r\n"), "{s}");
        assert!(s.contains("Retry-After: 1\r\n"), "{s}");
        assert!(s.contains("Connection: close\r\n"), "{s}");
        assert!(s.ends_with("\r\n\r\n{\"error\":\"queue_full\"}"), "{s}");
    }

    #[test]
    fn chunk_roundtrip() {
        let mut wire = Vec::new();
        encode_chunk(&mut wire, b"{\"token\":3}\n");
        encode_chunk(&mut wire, b"");
        encode_chunk(&mut wire, b"{\"done\":true}\n");
        wire.extend_from_slice(b"0\r\n\r\n");
        let body = client::decode_chunked(&wire).unwrap();
        assert_eq!(body, b"{\"token\":3}\n{\"done\":true}\n");
        // truncation is an error, not a silent prefix
        assert!(client::decode_chunked(&wire[..wire.len() - 5]).is_err());
        assert!(client::decode_chunked(b"zz\r\n").is_err());
    }

    #[test]
    fn client_parses_fixed_and_chunked_responses() {
        let raw = response_bytes(200, "application/json", &[], b"{\"nll\":1.5}", false);
        let r = client::parse_response(&raw).unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(r.header("content-type"), Some("application/json"));
        assert_eq!(r.body, b"{\"nll\":1.5}");
        let mut raw = b"HTTP/1.1 200 OK\r\nTransfer-Encoding: chunked\r\n\r\n".to_vec();
        encode_chunk(&mut raw, b"abc");
        raw.extend_from_slice(b"0\r\n\r\n");
        let r = client::parse_response(&raw).unwrap();
        assert_eq!(r.body, b"abc");
        assert!(client::parse_response(b"junk").is_err());
    }

    #[test]
    fn status_reasons_are_stable() {
        for (code, reason) in [(200, "OK"), (408, "Request Timeout"),
                               (413, "Payload Too Large"), (429, "Too Many Requests"),
                               (499, "Client Closed Request"), (503, "Service Unavailable"),
                               (504, "Gateway Timeout")] {
            assert_eq!(status_reason(code), reason);
        }
    }

    #[test]
    fn find_subslice_edges() {
        assert_eq!(find_subslice(b"abcd", b"cd"), Some(2));
        assert_eq!(find_subslice(b"abcd", b"x"), None);
        assert_eq!(find_subslice(b"ab", b"abcd"), None);
        assert_eq!(find_subslice(b"abcd", b""), None);
    }
}
