//! The PeRQ pipeline engine (Fig 2, executed on the Fig 7 or Fig 9 graph):
//!
//!   1. fold norm scales, merge R1/R2 into the weights (merged graph);
//!   2. run the capture artifact → per-site calibration activations;
//!   3. calibrate P3 per layer (MassDiff / baselines) on the down-proj
//!      inputs and merge it through the SwiGLU equivariant region;
//!   4. fold R̃3ᵀ into wd (merged graph);
//!   5. round every linear through the chosen Stage-2 solver, with
//!      per-site Hessians built from the transformed, fake-quantized
//!      calibration activations (Appendix B) — one job per linear,
//!      scheduled across worker threads;
//!   6. evaluate perplexity (and optionally the zero-shot probes) through
//!      the selected execution backend — the matching AOT artifact on
//!      pjrt, or the pure-Rust `backend::NativeBackend` otherwise.
//!
//! Python never runs here; with the native backend it never ran at all.

use std::time::Instant;

use anyhow::{ensure, Context, Result};

use super::spec::{GraphKind, PipelineSpec, RotKind};
use crate::calib::capture::{self, Captures};
use crate::eval::perplexity::{evaluate_with, EvalResult};
use crate::eval::zeroshot::{evaluate_zeroshot_with, ZeroShotResult};
use crate::hadamard::{self, BlockRotator};
use crate::model::bundle::ModelBundle;
use crate::model::config::CaptureKind;
use crate::model::transform;
use crate::model::weights::WeightSet;
use crate::backend::{BackendKind, ExtraInput, ForwardGraph};
use crate::obs::telemetry::{self, LayerRotationStats, RotationReport, SiteQuantStats};
use crate::permute::{self, CalibStats};
use crate::quant::{act, Format, WeightCodec};
use crate::runtime::Engine;
use crate::tensor::linalg::SymMat;
use crate::tensor::{Mat, QuantMat};
use crate::util::pool;

pub struct Pipeline {
    pub spec: PipelineSpec,
}

/// The output of the offline PTQ stages: transformed + quantized weights
/// plus everything needed to execute the matching artifact (eval, the
/// `coordinator::server` path, or a `.perq` deployment artifact via
/// [`QuantizedModel::save`]).
pub struct QuantizedModel {
    /// the bundle name this model was quantized from
    pub model: String,
    /// the pipeline label (`PipelineSpec::label`)
    pub label: String,
    pub cfg: crate::model::ModelConfig,
    pub ws: WeightSet,
    /// backend-neutral description of the matching forward graph
    pub graph: ForwardGraph,
    /// the graph's AOT artifact tag (pjrt backend)
    pub eval_tag: String,
    /// extra graph inputs after (weights, tokens), in host form
    pub extras: Vec<ExtraInput>,
    pub mass_balance: f64,
    pub calib_tokens: usize,
    /// pipeline seed (provenance)
    pub seed: u64,
    /// fused per-layer P3 permutations — already merged into `ws`
    /// (Remark 4.2); retained for artifact provenance
    pub perms: Vec<Vec<u32>>,
    /// rotation-quality telemetry gathered during calibration (per-layer
    /// mass imbalance pre/post permutation, post-rotation outlier shape,
    /// per-site quantization MSE); `perq export` writes it beside the
    /// artifact (see `deploy::telemetry_path`)
    pub telemetry: RotationReport,
}

impl QuantizedModel {
    fn provenance(&self) -> crate::deploy::Provenance {
        crate::deploy::Provenance {
            seed: self.seed,
            spec: self.label.clone(),
            writer: format!("perq {}", env!("CARGO_PKG_VERSION")),
            mass_balance: self.mass_balance,
            calib_tokens: self.calib_tokens,
        }
    }

    /// Write this model as a versioned `.perq` deployment artifact —
    /// the quantize-once half of quantize-once / serve-many. The file
    /// round-trips bit-exactly: serving the loaded artifact scores
    /// bit-identically to serving this in-process model.
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        crate::deploy::write_model(
            path, &self.model, &self.label, &self.cfg, &self.ws, &self.graph,
            &self.perms, &self.provenance(),
        )
    }

    /// Load a `.perq` artifact (convenience alias for
    /// [`crate::deploy::DeployedModel::load`]).
    pub fn load(path: &std::path::Path) -> Result<crate::deploy::DeployedModel> {
        crate::deploy::DeployedModel::load(path)
    }

    /// The in-memory deployment view of this model (no disk round-trip) —
    /// what [`QuantizedModel::save`] + `DeployedModel::load` produce.
    pub fn deploy(&self) -> crate::deploy::DeployedModel {
        crate::deploy::DeployedModel {
            model: self.model.clone(),
            label: self.label.clone(),
            cfg: self.cfg.clone(),
            ws: self.ws.clone(),
            graph: self.graph.clone(),
            perms: self.perms.clone(),
            provenance: self.provenance(),
            version: crate::deploy::artifact::FORMAT_VERSION,
        }
    }
}

#[derive(Clone, Debug)]
pub struct PipelineReport {
    pub label: String,
    pub model: String,
    pub perplexity: f64,
    pub nll: f64,
    pub zeroshot: Option<ZeroShotResult>,
    /// mean per-linear proxy-loss improvement of rounding vs RTN (diag)
    pub calib_tokens: usize,
    pub wall_ms: f64,
    /// max per-block l1 mass ratio achieved by the permutation (diagnostic,
    /// 1.0 = theoretical optimum) averaged over layers
    pub mass_balance: f64,
}

impl Pipeline {
    pub fn new(spec: PipelineSpec) -> Pipeline {
        Pipeline { spec }
    }

    /// Build the R1 rotation matrix for this spec (d_model space).
    fn r1_matrix(&self, bundle: &ModelBundle) -> Result<Option<Mat>> {
        let d = bundle.cfg.d_model;
        Ok(match self.spec.rotation.r1 {
            RotKind::None => None,
            RotKind::Hadamard => Some(hadamard::normalized_hadamard(d)?),
            RotKind::HadamardBlock(b) => {
                Some(hadamard::construct::block_hadamard_dense(d, b.min(d))?)
            }
            RotKind::Learned => Some(
                bundle
                    .learned_r1
                    .clone()
                    .unwrap_or(hadamard::normalized_hadamard(d)?),
            ),
            RotKind::LearnedBlock(b) => {
                let blk = match &bundle.learned_r1_block {
                    Some((bb, m)) if *bb == b => m.clone(),
                    _ => hadamard::normalized_hadamard(b)?,
                };
                // expand to block-diagonal d×d
                let mut out = Mat::zeros(d, d);
                for g in 0..d / b {
                    for i in 0..b {
                        for j in 0..b {
                            *out.at_mut(g * b + i, g * b + j) = blk.at(i, j);
                        }
                    }
                }
                Some(out)
            }
        })
    }

    fn r2_matrix(&self, bundle: &ModelBundle) -> Result<Option<Mat>> {
        let hd = bundle.cfg.head_dim();
        Ok(match self.spec.rotation.r2 {
            RotKind::None => None,
            RotKind::Hadamard | RotKind::Learned => {
                Some(hadamard::normalized_hadamard(hd)?)
            }
            RotKind::HadamardBlock(b) | RotKind::LearnedBlock(b) => {
                Some(hadamard::construct::block_hadamard_dense(hd, b.min(hd))?)
            }
        })
    }

    /// Run the full pipeline on a model bundle.
    pub fn run(&self, bundle: &ModelBundle) -> Result<PipelineReport> {
        let engine = Engine::new(&bundle.ctx)?;
        self.run_with_engine(bundle, &engine)
    }

    /// Offline stages only (transform -> capture -> permute -> rotate ->
    /// round); returns the quantized model without evaluating it.
    pub fn quantize_with_engine(&self, bundle: &ModelBundle, engine: &Engine) -> Result<QuantizedModel> {
        // stage timings go through the leveled log facade: visible with
        // PERQ_LOG=debug (or the legacy PERQ_TRACE switch)
        let mut t_stage = Instant::now();
        let mut stage = |name: &str| {
            crate::log_debug!("[perq-trace] {name}: {:.1} ms",
                              t_stage.elapsed().as_secs_f64() * 1e3);
            t_stage = Instant::now();
        };
        let t0 = Instant::now();
        let spec = &self.spec;
        let cfg = &bundle.cfg;
        let b3 = spec.rotation.r3_block;
        ensure!(
            cfg.d_ffn % b3 == 0,
            "R3 block {} must divide d_ffn {}",
            b3,
            cfg.d_ffn
        );
        let merged = spec.graph == GraphKind::Merged;
        if !merged {
            // the Fig 9 artifact is lowered with b = 32 at every online site
            ensure!(b3 == 32, "online graph artifacts use block size 32");
            ensure!(
                engine.backend() == BackendKind::Pjrt,
                "the fully-online graph (Fig 9) is only lowered for the pjrt backend"
            );
        }
        let graph = if merged {
            ForwardGraph::Merged { r3_block: b3, format: spec.format }
        } else {
            ForwardGraph::Online { format: spec.format }
        };
        let eval_tag = graph.tag();
        if engine.backend() == BackendKind::Pjrt {
            crate::backend::ensure_artifact_format(&graph)?;
            ensure!(
                bundle.has_artifact(&eval_tag),
                "missing artifact {eval_tag} for {}",
                bundle.name
            );
        }

        // ---- stage 0: offline transforms (norm folds + merged rotations) --
        let mut ws = bundle.weights.clone();
        transform::fold_norms(&mut ws, cfg);
        if merged {
            if let Some(r1) = self.r1_matrix(bundle)? {
                transform::merge_r1(&mut ws, cfg, &r1);
            }
            if let Some(r2) = self.r2_matrix(bundle)? {
                transform::merge_r2(&mut ws, cfg, &r2);
            }
        }

        stage("transform");
        // ---- stage 1: calibration captures (in the transformed space) ----
        let seqs = capture::calibration_batches(cfg, spec.calib_source, spec.calib_seqs, spec.seed);
        let mut caps = capture::run_capture(engine, &bundle.name, cfg, &ws, &seqs)
            .context("running calibration capture")?;

        stage("capture");
        // ---- stage 2: permutation calibration + merge (Alg 1 / Rmk 4.2) --
        let perm_tokens = (spec.perm_calib_seqs * cfg.seq_len).min(caps.n_tokens);
        let mut mass_balance = 0.0f64;
        let mut perms: Vec<Vec<u32>> = Vec::with_capacity(cfg.n_layers);
        let mut layer_stats: Vec<LayerRotationStats> = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let down = &caps.down_in[l];
            let sub_rows: Vec<&[f32]> = (0..perm_tokens.min(down.rows)).map(|r| down.row(r)).collect();
            let stats = CalibStats::from_activations(&sub_rows);
            let perm = spec.permutation.calibrate(&stats, b3, spec.seed + l as u64);
            // diagnostic: how balanced is the result vs the theoretical LB,
            // and vs the identity ordering it started from (`down` is still
            // pre-permutation here) — the pre/post pair is the telemetry
            // report's mass-diffusion evidence
            let full_stats = CalibStats::from_mat(down);
            let pre = permute::massdiff::max_block_mass(
                &full_stats.mean_abs, &permute::identity_perm(full_stats.d), b3,
            );
            let got = permute::massdiff::max_block_mass(&full_stats.mean_abs, &perm, b3);
            let lb = permute::massdiff::mass_lower_bound(&full_stats.mean_abs, b3);
            mass_balance += if lb > 0.0 { got / lb } else { 1.0 };
            layer_stats.push(LayerRotationStats {
                layer: l,
                pre_max_block_mass: pre,
                post_max_block_mass: got,
                mass_lower_bound: lb,
                // filled in after the R̃3 rotation below
                post_rot_absmax: 0.0,
                post_rot_kurtosis: 0.0,
            });
            transform::merge_p3_layer(&mut ws, l, &perm);
            caps.down_in[l] = caps.down_in[l].permute_cols(&perm);
            perms.push(perm.iter().map(|&i| i as u32).collect());
        }
        mass_balance /= cfg.n_layers as f64;

        stage("permute");
        // ---- stage 3: R3 rotation handling -------------------------------
        let rot3 = BlockRotator::hadamard(b3)?;
        if merged {
            transform::merge_r3_inv(&mut ws, cfg, &rot3)?;
        }
        // Hessian inputs for wd see the *rotated* activations. The rotated
        // (not yet fake-quantized) matrix is also the right place to read
        // the outlier shape the rotation leaves behind: max|x| and
        // kurtosis of what the quantizer will actually see.
        for l in 0..cfg.n_layers {
            rot3.apply_mat(&mut caps.down_in[l]);
            let (amax, kurt) = telemetry::absmax_and_kurtosis(&caps.down_in[l].data);
            layer_stats[l].post_rot_absmax = amax;
            layer_stats[l].post_rot_kurtosis = kurt;
        }
        // Online graph: d_model-space sites are rotated in-graph too.
        let rot_online = if merged { None } else { Some(BlockRotator::hadamard(32)?) };
        if let Some(rot) = &rot_online {
            for l in 0..cfg.n_layers {
                rot.apply_mat(&mut caps.attn_in[l]);
                rot.apply_mat(&mut caps.o_in[l]);
                rot.apply_mat(&mut caps.ffn_in[l]);
            }
        }
        // X̃ is rotated *and quantized* (Appendix B).
        if spec.format != Format::None {
            for l in 0..cfg.n_layers {
                act::act_quant_mat(&mut caps.attn_in[l], spec.format);
                act::act_quant_mat(&mut caps.o_in[l], spec.format);
                act::act_quant_mat(&mut caps.ffn_in[l], spec.format);
                act::act_quant_mat(&mut caps.down_in[l], spec.format);
            }
        }

        stage("rotate+actquant");
        // ---- stage 4: per-linear rounding jobs ----------------------------
        // Packing is only useful to the native backend's qgemm path; pjrt
        // feeds dense weights into the artifacts, so skip the pack work
        // (and the retained payloads) there.
        let pack = engine.backend() == BackendKind::Native;
        let site_stats = self.round_all(cfg, &mut ws, &caps, rot_online.as_ref(), pack)?;

        stage("rounding");
        // Native engines serve packed sites straight from the integer
        // payloads, so drop their dense f32 copies here — the 4–8× weight
        // memory reduction then holds for the whole QuantizedModel, not
        // just inside each backend's private clone. Skipped when the
        // PERQ_PACKED escape hatch disables packed serving (the f32
        // fallback needs the dense copies); pjrt feeds dense weights into
        // the artifacts and must keep them regardless.
        if engine.backend() == BackendKind::Native
            && crate::backend::native::packed_serving_enabled()
        {
            let packed_names: Vec<String> = ws.packed.keys().cloned().collect();
            for name in &packed_names {
                ws.drop_dense(name);
            }
        }
        let _ = t0;
        let telemetry = RotationReport {
            model: bundle.name.clone(),
            label: spec.label(),
            r3_block: b3,
            calib_tokens: caps.n_tokens,
            layers: layer_stats,
            sites: site_stats,
        };
        Ok(QuantizedModel {
            model: bundle.name.clone(),
            label: spec.label(),
            cfg: cfg.clone(),
            ws,
            extras: graph.extras()?,
            eval_tag,
            graph,
            mass_balance,
            calib_tokens: caps.n_tokens,
            seed: spec.seed,
            perms,
            telemetry,
        })
    }

    pub fn run_with_engine(&self, bundle: &ModelBundle, engine: &Engine) -> Result<PipelineReport> {
        let t0 = Instant::now();
        let spec = &self.spec;
        let qm = self.quantize_with_engine(bundle, engine)?;
        let mut t_stage = Instant::now();
        let mut stage = |name: &str| {
            crate::log_debug!("[perq-trace] {name}: {:.1} ms",
                              t_stage.elapsed().as_secs_f64() * 1e3);
            t_stage = Instant::now();
        };
        // ---- stage 5: evaluation ------------------------------------------
        // one scorer serves both eval passes (a native scorer owns a copy
        // of the quantized weights — no point building it twice)
        let mut score = crate::backend::scorer(engine, &bundle.name, &bundle.cfg, &qm.ws, &qm.graph)?;
        let eval = evaluate_with(&mut *score, &bundle.cfg, spec.eval_source, spec.eval_tokens)?;
        let zeroshot = if spec.run_zeroshot {
            Some(evaluate_zeroshot_with(&mut *score, &bundle.cfg, spec.zeroshot_tokens)?)
        } else {
            None
        };

        stage("eval");
        Ok(PipelineReport {
            label: spec.label(),
            model: bundle.name.clone(),
            perplexity: eval.perplexity,
            nll: eval.nll,
            zeroshot,
            calib_tokens: qm.calib_tokens,
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            mass_balance: qm.mass_balance,
        })
    }

    /// Round every linear site in parallel worker threads. With `pack`,
    /// each rounded site also gets a packed integer twin for the native
    /// backend's qgemm path (integer formats only). Returns per-site
    /// quantization MSE (rounded vs float reference) for the telemetry
    /// report.
    fn round_all(&self, cfg: &crate::model::ModelConfig, ws: &mut WeightSet,
                 caps: &Captures, rot_online: Option<&BlockRotator>, pack: bool)
                 -> Result<Vec<SiteQuantStats>> {
        let spec = &self.spec;
        if spec.format == Format::None {
            return Ok(Vec::new());
        }
        let sites = cfg.linear_sites();
        let needs_gram = spec.rounding != crate::rounding::Rounding::Rtn;
        // snapshot of the weights each job reads (transformed, fp)
        let w_in: Vec<Mat> = sites
            .iter()
            .map(|s| {
                let w = ws.get(&s.name).clone();
                // online graph: the in-graph weight rotation means the
                // effective weight is R̃ᵀw; quantize in that space and
                // pre-compensate afterwards.
                match (rot_online, s.capture) {
                    (Some(rot), CaptureKind::AttnIn | CaptureKind::OIn | CaptureKind::FfnIn) => {
                        rot.merge_into_weight_rows(&w).expect("rotating weight")
                    }
                    (Some(_), CaptureKind::DownIn) => {
                        let rot3 = BlockRotator::hadamard(spec.rotation.r3_block).unwrap();
                        rot3.merge_into_weight_rows(&w).expect("rotating weight")
                    }
                    _ => w,
                }
            })
            .collect();
        let quantized: Vec<(Mat, Option<QuantMat>, f64)> =
            pool::parallel_map(sites.len(), spec.workers, |i| {
                let site = &sites[i];
                let w = &w_in[i];
                let codec = WeightCodec::fit(spec.format, w);
                let gram = if needs_gram {
                    let x = caps.site(site.capture, site.layer);
                    let mut h = SymMat::zeros(w.rows);
                    h.accumulate_gram(&x.data, x.rows);
                    Some(h)
                } else {
                    None
                };
                let rounded = spec.rounding.round(w, &codec, gram.as_ref());
                // telemetry: mean squared rounding error vs the float
                // reference, in the space the site is quantized in
                let mut err = 0.0f64;
                for (a, b) in w.data.iter().zip(&rounded.data) {
                    let d = (*a - *b) as f64;
                    err += d * d;
                }
                let mse = err / w.data.len().max(1) as f64;
                // Merged graphs serve the rounded weight as-is: pack its
                // integer codes once here so the native backend can run the
                // low-bit qgemm path and drop the dequantized f32 copy.
                // (Online graphs re-rotate the weights below, which leaves
                // nothing integer-exact to pack — pjrt executes those.)
                let packed = if pack && rot_online.is_none() {
                    QuantMat::from_codec(&rounded, &codec)
                } else {
                    None
                };
                (rounded, packed, mse)
            });
        let mut site_stats = Vec::with_capacity(sites.len());
        for (site, (mut q, packed, mse)) in sites.iter().zip(quantized) {
            site_stats.push(SiteQuantStats { name: site.name.clone(), mse });
            // online graph: pre-compensate the in-graph rotation so the
            // graph's R̃ᵀ(w_feed) equals the quantized rotated weight.
            if let Some(rot) = rot_online {
                let r = match site.capture {
                    CaptureKind::DownIn => BlockRotator::hadamard(spec.rotation.r3_block)?,
                    _ => BlockRotator::hadamard(rot.b)?,
                };
                q = r.rotate_weight_rows_fwd(&q)?;
            }
            ws.set(&site.name, q);
            if let Some(p) = packed {
                ws.set_packed(&site.name, p);
            }
        }
        Ok(())
    }
}

/// Evaluate the full-precision (BF16-analog) baseline of a bundle.
pub fn baseline_eval(bundle: &ModelBundle, engine: &Engine, eval_tokens: usize,
                     zeroshot_tokens: Option<usize>) -> Result<(EvalResult, Option<ZeroShotResult>)> {
    let mut score =
        crate::backend::scorer(engine, &bundle.name, &bundle.cfg, &bundle.weights, &ForwardGraph::Fp)?;
    let eval = evaluate_with(
        &mut *score, &bundle.cfg, crate::data::corpus::Source::Wiki, eval_tokens,
    )?;
    let z = match zeroshot_tokens {
        Some(n) => Some(evaluate_zeroshot_with(&mut *score, &bundle.cfg, n)?),
        None => None,
    };
    Ok((eval, z))
}
