//! `perq` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   quantize   run a full PTQ pipeline and report perplexity / 0-shot
//!   export     quantize once and write a versioned .perq deployment
//!              artifact (no evaluation)
//!   serve      load a .perq artifact and serve scoring requests — no
//!              calibration; start-to-ready lands in BENCH_deploy.json
//!   baseline   evaluate the full-precision model
//!   sweep      block-size sweep (Table 1 style) for one method
//!   opcounts   print the analytic rotation op-count tables (Tables 3-4)
//!   stats      mass-concentration statistics on real activations (Fig 3-4)
//!   models     list model bundles and exported .perq artifacts
//!   inspect    summarize one .perq artifact + its telemetry sidecar
//!
//! Network front door: `perq serve --artifact m.perq --http ADDR` serves
//! over real sockets (POST /v1/score, streaming POST /v1/generate, GET
//! /healthz /readyz /metrics /traces) until SIGTERM/SIGINT triggers a
//! graceful drain. `PERQ_NET_FAULT=accept_close:N,...` injects
//! deterministic connection faults for testing.
//!
//! Observability: `perq serve --metrics-out FILE` dumps the server's
//! metrics registry periodically and at shutdown — Prometheus text
//! exposition to FILE, a JSON snapshot (legacy ServerStats shape +
//! registry + request traces) to FILE.json. `perq export` writes the
//! rotation-quality telemetry report beside the artifact
//! (`<artifact>.telemetry.json`). `PERQ_LOG={error,warn,info,debug}`
//! levels the CLI/server stderr logging.
//!
//! Examples:
//!   perq quantize --model llama_tiny --preset perq_star --block 32
//!   perq export --model llama_np2 --preset perq_star --block 32 --out m.perq
//!   perq serve --artifact m.perq --requests 64 --workers 4
//!   perq sweep --model llama_tiny --blocks 16,32,64 --format int4
//!   perq baseline --model qwen_tiny

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use perq::backend::BackendKind;
use perq::calib::capture;
use perq::coordinator::presets;
use perq::coordinator::server::{ServeOptions, ServerStats};
use perq::coordinator::spec::{GraphKind, PipelineSpec, RotationSpec};
use perq::data::corpus::{token_stream, Split};
use perq::deploy;
use perq::hadamard::opcount;
use perq::model::transform;
use perq::prelude::*;
use perq::stats;
use perq::util::bench::{fmt_count, fmt_ppl, print_table, TrajectoryRow};
use perq::util::cli;
use perq::util::json;

fn main() {
    // `-n N` is the conventional short form for `--max-new N` (the tiny
    // parser only understands `--` options)
    let argv: Vec<String> = std::env::args()
        .skip(1)
        .map(|a| if a == "-n" { "--max-new".to_string() } else { a })
        .collect();
    let args = cli::parse(&argv);
    // `--threads N` (or PERQ_THREADS) sizes the worker pool; it must be
    // applied before any kernel work because the global pool spawns
    // lazily on first use.
    if let Some(raw) = args.get("threads") {
        match raw.parse::<usize>() {
            Ok(n) => perq::util::pool::set_default_parallelism(n),
            Err(_) => perq::log_warn!(
                "--threads {raw:?} is not a lane count — using the \
                 PERQ_THREADS / core-count default"
            ),
        }
    }
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "quantize" => cmd_quantize(&args),
        "export" => cmd_export(&args),
        "serve" => cmd_serve(&args),
        "generate" => cmd_generate(&args),
        "baseline" => cmd_baseline(&args),
        "sweep" => cmd_sweep(&args),
        "opcounts" => cmd_opcounts(),
        "stats" => cmd_stats(&args),
        "models" => cmd_models(),
        "inspect" => cmd_inspect(&args),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        perq::log_error!("{e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "perq — Permute, Rotate, then Quantize (PTQ coordinator)\n\
         \n\
         USAGE: perq <command> [options]\n\
         \n\
         COMMANDS:\n\
         \x20 quantize   --model M [--preset P | --perm/--rounding/--format/--block ...]\n\
         \x20 export     --model M [--preset P ...] --out m.perq\n\
         \x20            (quantize once, write a versioned deployment artifact)\n\
         \x20 serve      --artifact m.perq [--requests N] [--workers W]\n\
         \x20            [--max-wait-ms MS | PERQ_MAX_WAIT_MS] (load + serve, no\n\
         \x20            calibration; full stats snapshot → BENCH_deploy.json)\n\
         \x20            [--queue-cap N] (bounded admission: reject/shed when the\n\
         \x20            intake queue is full)  [--deadline-ms MS] (per-request\n\
         \x20            deadline)  [--drain-timeout-ms MS] (graceful-drain cap\n\
         \x20            at shutdown)  PERQ_FAULT=panic_step:N,... (deterministic\n\
         \x20            fault injection in the engine step path)\n\
         \x20            [--metrics-out FILE] (periodic + final registry dump:\n\
         \x20            Prometheus text → FILE, JSON snapshot → FILE.json;\n\
         \x20            writes are atomic temp-file + rename)\n\
         \x20            [--http ADDR] (HTTP/1.1 front door on ADDR, e.g.\n\
         \x20            127.0.0.1:8080 — POST /v1/score, streaming POST\n\
         \x20            /v1/generate, GET /healthz /readyz /metrics /traces;\n\
         \x20            serves until SIGTERM/SIGINT, then drains gracefully)\n\
         \x20            [--max-conns N] (connection cap, over-limit → 503 +\n\
         \x20            Retry-After; default 64)  [--read-timeout-ms MS |\n\
         \x20            --write-timeout-ms MS] (per-connection socket caps,\n\
         \x20            default 5000)  [--max-body-bytes N] (request-body cap,\n\
         \x20            default 1 MiB)  [--max-secs S] (exit after S seconds —\n\
         \x20            smoke runs)  PERQ_NET_FAULT=accept_close:N,\n\
         \x20            stall_read:N:MS,drop_mid_response:N (deterministic\n\
         \x20            connection-fault injection)\n\
         \x20            [--kv-page P] (paged KV cache: P positions per page;\n\
         \x20            identical prompt prefixes share pages copy-on-write)\n\
         \x20            [--kv-pages N] (page-pool size per replica; smaller\n\
         \x20            than the batch needs = oversubscription — requests\n\
         \x20            that can never fit are rejected at submit, decode\n\
         \x20            overflow preempts + resumes the lowest-priority slot;\n\
         \x20            env twins PERQ_KV_PAGE / PERQ_KV_PAGES)\n\
         \x20 generate   --artifact m.perq [--prompt-tokens 1,2,3] [--max-new N | -n N]\n\
         \x20            (stateful prefill+decode generation: quantized KV cache,\n\
         \x20            PERQ_KV={{int8,f32}}; appends BENCH_decode.json)\n\
         \x20 baseline   --model M [--eval-tokens N]\n\
         \x20 sweep      --model M --blocks 16,32,64 [--perm massdiff]\n\
         \x20 opcounts   (analytic Tables 3-4)\n\
         \x20 stats      --model M [--block B]\n\
         \x20 models     (bundles + exported .perq artifacts + telemetry)\n\
         \x20 inspect    --artifact m.perq (header summary + rotation-quality\n\
         \x20            telemetry report, if exported)\n\
         \n\
         PRESETS: {}\n\
         OPTIONS: --perm identity|random|absmax|zigzag|massdiff\n\
         \x20        --rounding rtn|gptq|qronos   --format int4|int8|fp4|mxfp4\n\
         \x20        --block N   --online   --zeroshot   --eval-tokens N\n\
         \x20        --calib-seqs N   --source wiki|c4|fineweb (calib + eval)\n\
         \x20        --eval-source wiki|c4|fineweb (override eval split only)\n\
         \x20        --backend native|pjrt|auto (native = pure-Rust forward,\n\
         \x20                  no PJRT/XLA or HLO artifacts required)\n\
         \x20        --threads N  worker-pool lanes (default: PERQ_THREADS\n\
         \x20                  env, else core count; PERQ_SIMD={{auto,avx2,\n\
         \x20                  neon,scalar}} overrides kernel dispatch)\n\
         \x20        PERQ_LOG=error|warn|info|debug  stderr log level",
        presets::names().join(" ")
    );
}

fn spec_from_args(args: &cli::Args) -> Result<PipelineSpec> {
    let block = flag_usize(args, "block", 32);
    let format = Format::parse(&args.get_or("format", "int4"))
        .ok_or_else(|| anyhow!("bad --format"))?;
    let mut spec = if let Some(preset) = args.get("preset") {
        presets::parse(preset, block, format).ok_or_else(|| {
            anyhow!("unknown preset {preset} (expected one of: {})", presets::names().join(" "))
        })?
    } else {
        let mut s = PipelineSpec::default();
        s.rotation = RotationSpec::quarot(block);
        s.format = format;
        if let Some(p) = args.get("perm") {
            s.permutation = PermKind::parse(p).ok_or_else(|| anyhow!("bad --perm"))?;
        }
        if let Some(r) = args.get("rounding") {
            s.rounding = Rounding::parse(r).ok_or_else(|| anyhow!("bad --rounding"))?;
        }
        s
    };
    if args.has_flag("online") {
        spec.graph = GraphKind::Online;
    }
    if args.has_flag("zeroshot") {
        spec.run_zeroshot = true;
    }
    spec.eval_tokens = flag_usize(args, "eval-tokens", spec.eval_tokens);
    spec.calib_seqs = flag_usize(args, "calib-seqs", spec.calib_seqs);
    if let Some(src) = args.get("source") {
        let s = Source::parse(src).ok_or_else(|| anyhow!("bad --source"))?;
        // --source selects the corpus for the whole run: calibration AND
        // evaluation (previously only calibration was switched, silently
        // evaluating on the default split). --eval-source overrides below.
        spec.calib_source = s;
        spec.eval_source = s;
    }
    if let Some(src) = args.get("eval-source") {
        spec.eval_source = Source::parse(src).ok_or_else(|| anyhow!("bad --eval-source"))?;
    }
    Ok(spec)
}

/// Shared engine construction honoring `--backend {native,pjrt,auto}`.
fn engine_from_args(args: &cli::Args, ctx: &RepoContext) -> Result<Engine> {
    let kind = BackendKind::resolve(args.get("backend"), ctx)?;
    Engine::with_backend(ctx, kind)
}

/// Engine + bundle resolution with the synthetic fallback: no artifacts
/// tree (or no trained weights) still yields a runnable native setup, so
/// `perq export` works from a bare checkout — the CI smoke path.
fn engine_and_bundle(args: &cli::Args, model: &str) -> Result<(Engine, ModelBundle)> {
    match RepoContext::discover() {
        Ok(ctx) => {
            let kind = BackendKind::resolve(args.get("backend"), &ctx)?;
            let engine = Engine::with_backend(&ctx, kind)?;
            match ModelBundle::load(&ctx, model) {
                Ok(b) => Ok((engine, b)),
                Err(e) if kind == BackendKind::Native => {
                    perq::log_warn!("{e:#} — falling back to synthetic weights");
                    Ok((engine, ModelBundle::synthetic(model)?))
                }
                Err(e) => Err(e),
            }
        }
        Err(_) => {
            anyhow::ensure!(
                !matches!(args.get("backend"), Some("pjrt")),
                "--backend pjrt requires an artifacts/ tree (run `make artifacts`)"
            );
            Ok((Engine::native_ephemeral(), ModelBundle::synthetic(model)?))
        }
    }
}

/// `perq export`: run the offline PTQ stages once and write the result as
/// a versioned `.perq` deployment artifact — no evaluation, no serving.
fn cmd_export(args: &cli::Args) -> Result<()> {
    let model = args.get_or("model", "llama_tiny");
    let out = args.get_or("out", &format!("{model}.perq"));
    let (engine, bundle) = engine_and_bundle(args, &model)?;
    let spec = spec_from_args(args)?;
    println!("pipeline: {}", spec.label());
    println!("backend:  {}", engine.backend().name());
    println!("model:    {} ({} params)", model, bundle.weights.param_count());
    let t0 = Instant::now();
    let qm = Pipeline::new(spec).quantize_with_engine(&bundle, &engine)?;
    let quantize_s = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    qm.save(Path::new(&out))?;
    let write_ms = t1.elapsed().as_secs_f64() * 1e3;
    let bytes = std::fs::metadata(&out)?.len();
    println!(
        "exported {out}: {} — {} packed / {} dense sites, {:.1} KiB \
         ({quantize_s:.1}s quantize + {write_ms:.0}ms write)",
        qm.label,
        qm.ws.packed.len(),
        qm.ws.tensors.len(),
        bytes as f64 / 1024.0,
    );
    // rotation-quality telemetry rides beside the artifact so the serving
    // fleet can answer "how well did the permutation/rotation do?" without
    // the pipeline that built it
    let tpath = deploy::telemetry_path(Path::new(&out));
    qm.telemetry.save(&tpath)?;
    println!("telemetry: {} — {}", tpath.display(), qm.telemetry.summary());
    Ok(())
}

/// `perq serve`: load a `.perq` artifact, bring up the batching server
/// (no calibration), fire a deterministic request stream, and append the
/// start-to-ready / latency numbers to BENCH_deploy.json.
fn cmd_serve(args: &cli::Args) -> Result<()> {
    let artifact = args.get("artifact").ok_or_else(|| {
        anyhow!("serve needs --artifact model.perq (create one with `perq export`)")
    })?;
    let n_requests = flag_usize(args, "requests", 32).max(1);
    let workers = flag_usize(args, "workers", 1).max(1);
    // --max-wait-ms > PERQ_MAX_WAIT_MS > default
    let max_wait =
        perq::coordinator::server::resolve_max_wait(flag_u64(args, "max-wait-ms"));
    // fail-safe knobs: all off/unbounded unless asked for, so the default
    // serve path behaves exactly as before
    let mut opts = ServeOptions::new(max_wait, workers);
    if let Some(cap) = flag_u64(args, "queue-cap") {
        opts = opts.with_queue_cap((cap as usize).max(1));
    }
    if let Some(ms) = flag_u64(args, "deadline-ms") {
        opts = opts.with_deadline(Duration::from_millis(ms));
    }
    if let Some(ms) = flag_u64(args, "drain-timeout-ms") {
        opts = opts.with_drain_timeout(Duration::from_millis(ms));
    }
    // --kv-page/--kv-pages: paged KV cache with prefix sharing and
    // preemption. The flags are the CLI face of PERQ_KV_PAGE /
    // PERQ_KV_PAGES — setting the env here (before any backend exists)
    // routes them through the same PagedConfig::from_env() the server
    // uses for its admission cap, so flag and env can never disagree.
    if let Some(p) = flag_u64(args, "kv-page") {
        std::env::set_var("PERQ_KV_PAGE", p.to_string());
    }
    if let Some(n) = flag_u64(args, "kv-pages") {
        std::env::set_var("PERQ_KV_PAGES", n.to_string());
    }

    // quantize-once / serve-many: everything below is artifact load +
    // server bring-up — the offline pipeline never runs here
    let t0 = Instant::now();
    let dm = DeployedModel::load(Path::new(artifact))?;
    let load_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t1 = Instant::now();
    let server = dm.serve(opts)?;
    let ready_ms = t1.elapsed().as_secs_f64() * 1e3;
    println!(
        "{artifact}: {} {} (format v{}) — loaded in {load_ms:.1}ms, \
         {workers} replica(s) ready in {ready_ms:.1}ms, start-to-ready {:.1}ms",
        dm.model,
        dm.label,
        dm.version,
        load_ms + ready_ms,
    );

    // --metrics-out FILE: dump the metrics registry periodically while the
    // server runs (Prometheus text → FILE, JSON snapshot → FILE.json) and
    // once more at shutdown, so a scraper or a post-mortem always sees a
    // current view
    let metrics_out = args.get("metrics-out").map(PathBuf::from);
    // one last dump on EVERY exit path — normal return, early `?`, or a
    // panic unwinding through this frame — so a post-mortem always finds
    // the terminal counters on disk
    let _final_flush = metrics_out.clone().map(|path| MetricsFlushGuard {
        path,
        stats: server.shared_stats(),
    });
    let metrics_stop = Arc::new(AtomicBool::new(false));
    let metrics_writer = metrics_out.clone().map(|path| {
        let shared = server.shared_stats();
        let stop = metrics_stop.clone();
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(Duration::from_millis(500));
                if let Err(e) = write_metrics_files(&path, &shared) {
                    perq::log_warn!("metrics dump failed: {e:#}");
                }
            }
        })
    });

    // --http ADDR: real network front door — serve requests off the wire
    // until SIGTERM/SIGINT (or --max-secs, for smoke runs) instead of
    // self-generating traffic
    if let Some(addr) = args.get("http") {
        let mut hopts = perq::coordinator::http::HttpOptions::default();
        if let Some(n) = flag_u64(args, "max-conns") {
            hopts.max_conns = (n as usize).max(1);
        }
        if let Some(ms) = flag_u64(args, "read-timeout-ms") {
            hopts.read_timeout = Duration::from_millis(ms.max(1));
        }
        if let Some(ms) = flag_u64(args, "write-timeout-ms") {
            hopts.write_timeout = Duration::from_millis(ms.max(1));
        }
        if let Some(n) = flag_u64(args, "max-body-bytes") {
            hopts.max_body = (n as usize).max(1);
        }
        hopts.drain_timeout = opts.drain_timeout;
        let shared = server.shared_stats();
        let http =
            perq::coordinator::http::HttpServer::start(Arc::new(server), addr, hopts)?;
        perq::coordinator::net::install_shutdown_signals();
        println!(
            "http: listening on {} — POST /v1/score /v1/generate, GET /healthz \
             /readyz /metrics /traces (SIGTERM/SIGINT drains and exits)",
            http.local_addr()
        );
        let max_secs = flag_u64(args, "max-secs");
        let started = Instant::now();
        while !perq::coordinator::net::shutdown_signaled() {
            if max_secs.map_or(false, |s| started.elapsed() >= Duration::from_secs(s)) {
                break;
            }
            std::thread::sleep(Duration::from_millis(50));
        }
        println!("http: draining ({} ms budget)", hopts.drain_timeout.as_millis());
        http.shutdown();
        metrics_stop.store(true, Ordering::Relaxed);
        if let Some(h) = metrics_writer {
            let _ = h.join();
        }
        let snap = shared.snapshot();
        println!(
            "outcomes: {} submitted = {} served + {} rejected ({} shed, {} cancelled) \
             + {} deadline-exceeded + {} failed | {} worker failure(s), {} retries",
            snap.submitted,
            snap.served,
            snap.rejected,
            snap.shed,
            snap.cancelled,
            snap.deadline_exceeded,
            snap.failed,
            snap.worker_failures,
            snap.retries,
        );
        print_kv_line(&snap);
        if let Some(path) = &metrics_out {
            write_metrics_files(path, &shared)?;
            println!(
                "metrics: {} (Prometheus text) + {} (JSON snapshot)",
                path.display(),
                metrics_json_path(path).display(),
            );
        }
        return Ok(());
    }

    // deterministic request stream over the held-out split
    let t = dm.cfg.seq_len;
    let toks = token_stream(Source::Wiki, Split::Test, (n_requests + 2) * t);
    let t2 = Instant::now();
    let mut rxs = Vec::with_capacity(n_requests);
    for i in 0..n_requests {
        let start = (i * t) % (toks.len() - t - 1);
        let window: Vec<i32> = toks[start..start + t + 1].iter().map(|&x| x as i32).collect();
        rxs.push(server.submit(window)?);
    }
    // every submitted request resolves exactly once: either a response or
    // a terminal ServeError (rejected / deadline-exceeded / failed) —
    // tally the unserved kinds instead of aborting the run on the first
    let mut unserved: std::collections::BTreeMap<&'static str, usize> =
        std::collections::BTreeMap::new();
    let mut nll = 0.0f64;
    let mut scored = 0usize;
    for rx in rxs {
        match rx.recv() {
            Ok(Ok(r)) => {
                nll += r.nll;
                scored += 1;
            }
            Ok(Err(e)) => *unserved.entry(e.as_str()).or_insert(0) += 1,
            Err(_) => *unserved.entry("worker_failed").or_insert(0) += 1,
        }
    }
    nll /= scored.max(1) as f64;
    // score-phase wall only — the generation slice below gets its own
    // clock so the throughput line and the JSON record stay coherent
    let score_wall = t2.elapsed().as_secs_f64();
    // a slice of generation traffic so the decode-phase stats are live
    let n_gen = flag_usize(args, "gen-requests", 4);
    if n_gen > 0 && t >= 4 {
        let plen = (t / 2).clamp(1, 8);
        let max_new = (t - plen).min(8).max(1);
        let gen_rxs: Vec<_> = (0..n_gen)
            .map(|i| {
                let start = (i * plen) % (toks.len() - plen - 1);
                let prompt: Vec<i32> =
                    toks[start..start + plen].iter().map(|&x| x as i32).collect();
                server.submit_generate(prompt, max_new)
            })
            .collect::<Result<_>>()?;
        for rx in gen_rxs {
            match rx.recv() {
                Ok(Ok(_)) => {}
                Ok(Err(e)) => *unserved.entry(e.as_str()).or_insert(0) += 1,
                Err(_) => *unserved.entry("worker_failed").or_insert(0) += 1,
            }
        }
    }
    let wall = t2.elapsed().as_secs_f64(); // score + generation phases
    let snap = server.snapshot();
    println!(
        "{} requests ({} generate) in {wall:.2}s — score phase {score_wall:.2}s = \
         {:.0} tok/s | mean nll {nll:.6} (ppl {:.2}) | \
         {} steps (occupancy {:.2}) | exec {:.2}s (prefill {:.2}s / decode {:.2}s)",
        snap.served,
        snap.generated,
        scored as f64 * t as f64 / score_wall.max(1e-9),
        nll.exp(),
        snap.batches,
        snap.mean_occupancy,
        snap.exec_s,
        snap.prefill_s,
        snap.decode_s,
    );
    println!(
        "decode {:.0} tok/s | latency p50/p95/p99 {:.1}/{:.1}/{:.1}ms | \
         prefill-phase p50 {:.1}ms | decode-phase p50 {:.1}ms | hist saturated {}",
        snap.decode_tok_per_s,
        snap.p50_ms,
        snap.p95_ms,
        snap.p99_ms,
        snap.prefill_p50_ms,
        snap.decode_p50_ms,
        snap.hist_saturated,
    );
    // the completion contract, checkable from stdout alone:
    // submitted == served + rejected + deadline-exceeded + failed
    println!(
        "outcomes: {} submitted = {} served + {} rejected ({} shed) + \
         {} deadline-exceeded + {} failed | {} worker failure(s), {} retries",
        snap.submitted,
        snap.served,
        snap.rejected,
        snap.shed,
        snap.deadline_exceeded,
        snap.failed,
        snap.worker_failures,
        snap.retries,
    );
    print_kv_line(&snap);
    if !unserved.is_empty() {
        let parts: Vec<String> =
            unserved.iter().map(|(k, n)| format!("{n} {k}")).collect();
        println!("unserved: {}", parts.join(", "));
    }

    // stop the periodic writer, then drain the server so the final dump
    // carries the terminal counters (ShuttingDown resolutions included)
    metrics_stop.store(true, Ordering::Relaxed);
    if let Some(h) = metrics_writer {
        let _ = h.join();
    }
    let shared = server.shared_stats();
    server.shutdown();
    if let Some(path) = &metrics_out {
        write_metrics_files(path, &shared)?;
        println!(
            "metrics: {} (Prometheus text) + {} (JSON snapshot)",
            path.display(),
            metrics_json_path(path).display(),
        );
    }

    // the trajectory row rides the shared JSON serializer so paths/labels
    // with quotes or backslashes stay valid; the full ServerStats snapshot
    // comes along (percentiles, occupancy, decode tok/s)
    let bench_path = args.get_or("bench-out", "BENCH_deploy.json");
    let mut row = TrajectoryRow::new("deploy")
        .str_field("artifact", artifact)
        .str_field("model", &dm.model)
        .str_field("label", &dm.label);
    for (k, v) in [
        ("workers", workers as f64),
        ("requests", n_requests as f64),
        ("load_ms", load_ms),
        ("ready_ms", ready_ms),
        ("start_to_ready_ms", load_ms + ready_ms),
        ("nll", nll),
        ("wall_s", wall),
        ("score_wall_s", score_wall),
        ("served", snap.served as f64),
        ("generated", snap.generated as f64),
        ("steps", snap.batches as f64),
        ("mean_occupancy", snap.mean_occupancy),
        ("exec_s", snap.exec_s),
        ("prefill_s", snap.prefill_s),
        ("decode_s", snap.decode_s),
        ("prefill_tokens", snap.prefill_tokens as f64),
        ("decode_tokens", snap.decode_tokens as f64),
        ("decode_tok_per_s", snap.decode_tok_per_s),
        ("p50_ms", snap.p50_ms),
        ("p95_ms", snap.p95_ms),
        ("p99_ms", snap.p99_ms),
        ("prefill_p50_ms", snap.prefill_p50_ms),
        ("prefill_p95_ms", snap.prefill_p95_ms),
        ("prefill_p99_ms", snap.prefill_p99_ms),
        ("decode_p50_ms", snap.decode_p50_ms),
        ("decode_p95_ms", snap.decode_p95_ms),
        ("decode_p99_ms", snap.decode_p99_ms),
        ("hist_saturated", snap.hist_saturated as f64),
        ("submitted", snap.submitted as f64),
        ("rejected", snap.rejected as f64),
        ("shed", snap.shed as f64),
        ("deadline_exceeded", snap.deadline_exceeded as f64),
        ("failed", snap.failed as f64),
        ("worker_failures", snap.worker_failures as f64),
        ("retries", snap.retries as f64),
        ("preemptions", snap.preemptions as f64),
        ("kv_prefix_hits", snap.kv_prefix_hits as f64),
        ("kv_cow_copies", snap.kv_cow_copies as f64),
        ("kv_pages_in_use", snap.kv_pages_in_use as f64),
        ("kv_pages_total", snap.kv_pages_total as f64),
    ] {
        row = row.num_field(k, v);
    }
    row.append_to(Path::new(&bench_path))?;
    println!("appended {bench_path}");
    Ok(())
}

/// Paged-KV accounting line, printed beside the completion contract so an
/// oversubscribed run shows its paging story (pool usage, prefix sharing,
/// copy-on-write splits, preemptions) on stdout alone. Dense runs with no
/// paging activity stay silent — there is nothing to report.
fn print_kv_line(snap: &perq::coordinator::server::StatsSnapshot) {
    if snap.kv_pages_total == 0 && snap.preemptions == 0 && snap.kv_prefix_hits == 0 {
        return;
    }
    println!(
        "kv: {} page(s) in use of {} | {} prefix-hit token(s), {} cow copy(ies), \
         {} preemption(s)",
        snap.kv_pages_in_use,
        snap.kv_pages_total,
        snap.kv_prefix_hits,
        snap.kv_cow_copies,
        snap.preemptions,
    );
}

/// Parse an optional numeric flag, warning (instead of silently ignoring)
/// when the value does not parse — a mistyped `--queue-cap` or
/// `--deadline-ms` must not quietly disable admission control.
fn flag_u64(args: &cli::Args, name: &str) -> Option<u64> {
    let raw = args.get(name)?;
    match raw.parse::<u64>() {
        Ok(v) => Some(v),
        Err(_) => {
            perq::log_warn!("--{name} {raw:?} is not a number — ignoring the flag");
            None
        }
    }
}

/// [`flag_u64`] with a default — the warned replacement for the silent
/// `get_usize` coercion (a mistyped `--requests` or `--block` must say so
/// instead of quietly running with the default).
fn flag_usize(args: &cli::Args, name: &str, default: usize) -> usize {
    match args.get(name) {
        None => default,
        Some(raw) => match raw.parse::<usize>() {
            Ok(v) => v,
            Err(_) => {
                perq::log_warn!(
                    "--{name} {raw:?} is not a number — using default {default}"
                );
                default
            }
        },
    }
}

/// Drop guard for `--metrics-out`: writes one final registry dump when the
/// serve command exits by any path, including a panic unwinding through
/// `cmd_serve`, so the on-disk snapshot always reflects the end of the run.
struct MetricsFlushGuard {
    path: PathBuf,
    stats: Arc<ServerStats>,
}

impl Drop for MetricsFlushGuard {
    fn drop(&mut self) {
        if let Err(e) = write_metrics_files(&self.path, &self.stats) {
            perq::log_warn!("final metrics dump failed: {e:#}");
        }
    }
}

/// Sibling path for the JSON half of a `--metrics-out` dump: `FILE.json`.
fn metrics_json_path(prom: &Path) -> PathBuf {
    let mut s = prom.as_os_str().to_os_string();
    s.push(".json");
    PathBuf::from(s)
}

/// Write `contents` to `path` atomically: a scraper reading mid-dump sees
/// either the previous complete file or the new one, never a torn write.
fn write_atomic(path: &Path, contents: &str) -> Result<()> {
    let mut tmp = path.as_os_str().to_os_string();
    tmp.push(".tmp");
    let tmp = PathBuf::from(tmp);
    std::fs::write(&tmp, contents)?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Dump the server's metrics registry: Prometheus text exposition to
/// `prom` (server registry + the process-wide `perq_native_*` engine
/// counters; the name sets are disjoint), and a JSON snapshot to
/// `prom`.json — the legacy `ServerStats` fields flat at the top level
/// (bit-compatible with the pre-registry shape), plus the full registry,
/// the engine registry, and the recent request traces.
fn write_metrics_files(prom: &Path, stats: &ServerStats) -> Result<()> {
    // single-sourced with `GET /metrics`: both halves come from the same
    // ServerStats render methods, so the dump and the scrape endpoint can
    // never drift apart
    write_atomic(prom, &stats.render_prometheus_full())?;
    write_atomic(&metrics_json_path(prom), &json::dump(&stats.snapshot_json_full()))?;
    Ok(())
}

/// `perq generate`: load a `.perq` artifact and run greedy token
/// generation through the stateful prefill/decode session path — the
/// decode-time workload (quantized KV cache, per-token R̃3 rotation) the
/// paper's Appendix A argument is about. Appends decode throughput to
/// BENCH_decode.json.
fn cmd_generate(args: &cli::Args) -> Result<()> {
    let artifact = args.get("artifact").ok_or_else(|| {
        anyhow!("generate needs --artifact model.perq (create one with `perq export`)")
    })?;
    let dm = DeployedModel::load(Path::new(artifact))?;
    let t = dm.cfg.seq_len;
    let max_new = flag_usize(args, "max-new", 16).max(1);
    let prompt: Vec<i32> = match args.get("prompt-tokens") {
        Some(s) => s
            .split(',')
            .map(|x| {
                x.trim()
                    .parse::<i32>()
                    .map_err(|_| anyhow!("bad --prompt-tokens entry {x:?}"))
            })
            .collect::<Result<_>>()?,
        None => {
            // deterministic default prompt from the held-out split
            let plen = (t / 4).clamp(1, 8);
            token_stream(Source::Wiki, Split::Test, plen + 1)[..plen]
                .iter()
                .map(|&x| x as i32)
                .collect()
        }
    };
    anyhow::ensure!(
        prompt.len() + max_new <= t,
        "prompt ({}) + --max-new ({max_new}) exceeds the model's seq_len ({t})",
        prompt.len()
    );
    println!(
        "{artifact}: {} {} (format v{}) — prompt {} tokens, generating {max_new} \
         (KV cache: {})",
        dm.model,
        dm.label,
        dm.version,
        prompt.len(),
        perq::tensor::KvMode::from_env().name(),
    );
    let r = dm.generate(&prompt, max_new)?;
    let toks: Vec<String> = r.tokens.iter().map(|t| t.to_string()).collect();
    println!("tokens: {}", toks.join(" "));
    println!(
        "prefill {:.1}ms | decode {} tokens in {:.1}ms = {:.0} tok/s",
        r.prefill_s * 1e3,
        r.tokens.len().saturating_sub(1),
        r.decode_s * 1e3,
        r.decode_tok_per_s(),
    );
    let bench_path = args.get_or("bench-out", "BENCH_decode.json");
    TrajectoryRow::new("generate")
        .str_field("artifact", artifact)
        .str_field("model", &dm.model)
        .str_field("label", &dm.label)
        .str_field("kv_mode", perq::tensor::KvMode::from_env().name())
        .num_field("prompt_tokens", prompt.len() as f64)
        .num_field("max_new", max_new as f64)
        .num_field("prefill_ms", r.prefill_s * 1e3)
        .num_field("decode_ms", r.decode_s * 1e3)
        .num_field("decode_tok_per_s", r.decode_tok_per_s())
        .append_to(Path::new(&bench_path))?;
    println!("appended {bench_path}");
    Ok(())
}

fn cmd_quantize(args: &cli::Args) -> Result<()> {
    let model = args.get_or("model", "llama_tiny");
    let ctx = RepoContext::discover()?;
    let engine = engine_from_args(args, &ctx)?;
    let bundle = ModelBundle::load(&ctx, &model)?;
    let spec = spec_from_args(args)?;
    println!("pipeline: {}", spec.label());
    println!("backend:  {}", engine.backend().name());
    println!("model:    {} ({} params)", model, bundle.weights.param_count());
    let report = Pipeline::new(spec).run_with_engine(&bundle, &engine)?;
    println!("perplexity:   {:.3} ({})", report.perplexity, fmt_ppl(report.perplexity));
    println!("nll:          {:.4} nats/token", report.nll);
    println!("mass balance: {:.3}x of optimum", report.mass_balance);
    println!("calib tokens: {}", report.calib_tokens);
    if let Some(z) = &report.zeroshot {
        for (name, acc) in z.task_names.iter().zip(&z.accuracies) {
            println!("  0-shot {name:<14} {:.1}%", acc * 100.0);
        }
        println!("  0-shot average       {:.1}%", z.average());
    }
    println!("wall: {:.1}s", report.wall_ms / 1e3);
    Ok(())
}

fn cmd_baseline(args: &cli::Args) -> Result<()> {
    let model = args.get_or("model", "llama_tiny");
    let ctx = RepoContext::discover()?;
    let engine = engine_from_args(args, &ctx)?;
    let bundle = ModelBundle::load_with_engine(&ctx, &engine, &model)?;
    let n = flag_usize(args, "eval-tokens", 8192);
    let z = args.has_flag("zeroshot").then_some(2048);
    let (eval, zres) = baseline_eval(&bundle, &engine, n, z)?;
    println!("{model} BF16-analog baseline: ppl {:.3} over {} predictions",
             eval.perplexity, eval.n_predictions);
    if let Some(z) = zres {
        println!("  0-shot average {:.1}%", z.average());
    }
    Ok(())
}

fn cmd_sweep(args: &cli::Args) -> Result<()> {
    let model = args.get_or("model", "llama_tiny");
    let blocks: Vec<usize> = args
        .get_or("blocks", "16,32,64,128")
        .split(',')
        .filter_map(|s| s.parse().ok())
        .collect();
    let ctx = RepoContext::discover()?;
    let engine = engine_from_args(args, &ctx)?;
    let bundle = ModelBundle::load_with_engine(&ctx, &engine, &model)?;
    let mut rows = Vec::new();
    for &b in &blocks {
        let mut spec = spec_from_args(args)?;
        spec.rotation = RotationSpec::quarot(b);
        let rep = Pipeline::new(spec).run_with_engine(&bundle, &engine)?;
        println!("  b={b}: ppl {:.2}", rep.perplexity);
        rows.push((format!("b={b}"), vec![fmt_ppl(rep.perplexity)]));
    }
    print_table(&format!("{model} block-size sweep"), &["ppl"], &rows);
    Ok(())
}

fn cmd_opcounts() -> Result<()> {
    let rows3: Vec<(String, Vec<String>)> = opcount::table3()
        .into_iter()
        .map(|r| {
            let pct = |ops: usize| format!("{} ({:.0}%)", fmt_count(ops),
                                           100.0 * ops as f64 / r.full as f64);
            (
                format!("{} {} d={}", r.model, r.size, r.d),
                vec![pct(r.b32), pct(r.b128), pct(r.b512), fmt_count(r.full)],
            )
        })
        .collect();
    print_table("Table 3: rotation op counts", &["b=32", "b=128", "b=512", "Full"], &rows3);
    let rows4: Vec<(String, Vec<String>)> = opcount::table4()
        .into_iter()
        .map(|r| {
            (
                format!("{} d={} (2^{}x{})", r.model, r.d, r.kp, r.base),
                vec![
                    fmt_count(r.matmul),
                    fmt_count(r.butterfly_matmul),
                    fmt_count(r.ours),
                ],
            )
        })
        .collect();
    print_table("Table 4: non-power-of-2 methods", &["Matmul", "Bfly+MM", "Ours"], &rows4);
    Ok(())
}

fn cmd_stats(args: &cli::Args) -> Result<()> {
    let model = args.get_or("model", "llama_tiny");
    let block = flag_usize(args, "block", 32);
    let ctx = RepoContext::discover()?;
    let engine = engine_from_args(args, &ctx)?;
    let bundle = ModelBundle::load_with_engine(&ctx, &engine, &model)?;
    let cfg = &bundle.cfg;
    let mut ws = bundle.weights.clone();
    transform::fold_norms(&mut ws, cfg);
    let seqs = capture::calibration_batches(cfg, Source::Wiki, 8, 3);
    let caps = capture::run_capture(&engine, &model, cfg, &ws, &seqs)?;
    println!("mass concentration at down-projection inputs ({model}, {} tokens):",
             caps.n_tokens);
    for l in 0..cfg.n_layers {
        let down = &caps.down_in[l];
        let mut deltas = Vec::new();
        let mut bounds = Vec::new();
        for r in 0..down.rows.min(512) {
            let row = down.row(r);
            deltas.push(stats::delta(row));
            bounds.push(stats::normalized_bound(row, block));
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        println!(
            "  layer {l}: mean delta {:.4}  mean bound(b={block}) {:.4}  1/sqrt(b)={:.4}  1/b={:.4}",
            mean(&deltas), mean(&bounds),
            1.0 / (block as f64).sqrt(), 1.0 / block as f64
        );
    }
    Ok(())
}

fn cmd_models() -> Result<()> {
    // HLO model bundles (meta.json directories) — only with an artifacts
    // tree; exported .perq artifacts list fine without one.
    let ctx = RepoContext::discover().ok();
    let mut any = false;
    if let Some(ctx) = &ctx {
        if let Ok(entries) = std::fs::read_dir(&ctx.artifacts) {
            let mut names: Vec<String> = entries
                .flatten()
                .filter(|e| e.path().join("meta.json").exists())
                .map(|e| e.file_name().to_string_lossy().to_string())
                .collect();
            names.sort();
            for name in names {
                println!("{name}  (HLO bundle)");
                any = true;
            }
        }
    }
    // exported .perq deployment artifacts: cwd + the artifacts tree,
    // summarized from the header alone (format/block/label, no payload IO)
    let mut dirs = vec![PathBuf::from(".")];
    if let Some(ctx) = &ctx {
        dirs.push(ctx.artifacts.clone());
    }
    for dir in dirs {
        let Ok(entries) = std::fs::read_dir(&dir) else { continue };
        let mut paths: Vec<PathBuf> = entries
            .flatten()
            .map(|e| e.path())
            .filter(|p| p.extension().map_or(false, |e| e == "perq"))
            .collect();
        paths.sort();
        for p in paths {
            match deploy::inspect(&p) {
                // sizing columns (seq_len / layers / packed bytes) come
                // from the header + footer alone — no payload is loaded
                Ok(info) => println!(
                    "{}  (.perq v{}: {} {} {} b={} | seq_len {} | {} layers | \
                     packed {:.1} KiB + dense {:.1} KiB — {})",
                    p.display(),
                    info.version,
                    info.model,
                    info.graph_kind,
                    info.format,
                    info.r3_block,
                    info.seq_len,
                    info.n_layers,
                    info.packed_bytes as f64 / 1024.0,
                    info.dense_bytes as f64 / 1024.0,
                    info.label
                ),
                Err(e) => println!("{}  (unreadable .perq: {e:#})", p.display()),
            }
            if let Some(report) = deploy::load_telemetry(&p) {
                println!("    {}", report.summary());
            }
            any = true;
        }
    }
    if !any {
        println!("no model bundles or .perq artifacts found (run `make artifacts` or `perq export`)");
    }
    Ok(())
}

/// `perq inspect`: summarize one `.perq` artifact from its header/footer
/// alone, then print the full rotation-quality telemetry report if the
/// export wrote one beside it.
fn cmd_inspect(args: &cli::Args) -> Result<()> {
    let artifact = args.get("artifact").ok_or_else(|| {
        anyhow!("inspect needs --artifact model.perq (create one with `perq export`)")
    })?;
    let path = Path::new(artifact);
    let info = deploy::inspect(path)?;
    println!(
        "{artifact}: {} {} (.perq v{}) — {} b={} | seq_len {} | {} layers | \
         packed {:.1} KiB + dense {:.1} KiB",
        info.model,
        info.graph_kind,
        info.version,
        info.format,
        info.r3_block,
        info.seq_len,
        info.n_layers,
        info.packed_bytes as f64 / 1024.0,
        info.dense_bytes as f64 / 1024.0,
    );
    println!("label: {}", info.label);
    match deploy::load_telemetry(path) {
        None => println!(
            "no telemetry sidecar ({}) — re-export to record rotation quality",
            deploy::telemetry_path(path).display()
        ),
        Some(report) => {
            println!("{}", report.summary());
            println!(
                "  {:>5}  {:>9} {:>9} {:>9}  {:>9} {:>9}",
                "layer", "pre_imb", "post_imb", "improve", "absmax", "kurtosis"
            );
            for l in &report.layers {
                println!(
                    "  {:>5}  {:>9.3} {:>9.3} {:>8.2}x  {:>9.3} {:>9.2}",
                    l.layer,
                    l.pre_imbalance(),
                    l.post_imbalance(),
                    if l.post_imbalance() > 0.0 {
                        l.pre_imbalance() / l.post_imbalance()
                    } else {
                        1.0
                    },
                    l.post_rot_absmax,
                    l.post_rot_kurtosis,
                );
            }
            if !report.sites.is_empty() {
                // worst rounding errors first — the sites to look at when
                // perplexity regresses
                let mut sites = report.sites.clone();
                sites.sort_by(|a, b| b.mse.total_cmp(&a.mse));
                println!("  worst-mse sites:");
                for s in sites.iter().take(8) {
                    println!("    {:<16} mse {:.3e}", s.name, s.mse);
                }
            }
        }
    }
    Ok(())
}
