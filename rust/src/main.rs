//! `perq` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   quantize   run a full PTQ pipeline and report perplexity / 0-shot
//!   export     quantize once and write a versioned .perq deployment
//!              artifact (no evaluation)
//!   serve      load a .perq artifact and serve scoring requests — no
//!              calibration; start-to-ready lands in BENCH_deploy.json
//!   baseline   evaluate the full-precision model
//!   sweep      block-size sweep (Table 1 style) for one method
//!   opcounts   print the analytic rotation op-count tables (Tables 3-4)
//!   stats      mass-concentration statistics on real activations (Fig 3-4)
//!   models     list model bundles and exported .perq artifacts
//!
//! Examples:
//!   perq quantize --model llama_tiny --preset perq_star --block 32
//!   perq export --model llama_np2 --preset perq_star --block 32 --out m.perq
//!   perq serve --artifact m.perq --requests 64 --workers 4
//!   perq sweep --model llama_tiny --blocks 16,32,64 --format int4
//!   perq baseline --model qwen_tiny

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use perq::backend::BackendKind;
use perq::calib::capture;
use perq::coordinator::presets;
use perq::coordinator::spec::{GraphKind, PipelineSpec, RotationSpec};
use perq::data::corpus::{token_stream, Split};
use perq::deploy;
use perq::hadamard::opcount;
use perq::model::transform;
use perq::prelude::*;
use perq::stats;
use perq::util::bench::{append_trajectory, fmt_count, fmt_ppl, print_table};
use perq::util::cli;
use perq::util::json::{self, Json};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cli::parse(&argv);
    // `--threads N` (or PERQ_THREADS) sizes the worker pool; it must be
    // applied before any kernel work because the global pool spawns
    // lazily on first use.
    if let Some(n) = args.get("threads").and_then(|s| s.parse::<usize>().ok()) {
        perq::util::pool::set_default_parallelism(n);
    }
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "quantize" => cmd_quantize(&args),
        "export" => cmd_export(&args),
        "serve" => cmd_serve(&args),
        "baseline" => cmd_baseline(&args),
        "sweep" => cmd_sweep(&args),
        "opcounts" => cmd_opcounts(),
        "stats" => cmd_stats(&args),
        "models" => cmd_models(),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "perq — Permute, Rotate, then Quantize (PTQ coordinator)\n\
         \n\
         USAGE: perq <command> [options]\n\
         \n\
         COMMANDS:\n\
         \x20 quantize   --model M [--preset P | --perm/--rounding/--format/--block ...]\n\
         \x20 export     --model M [--preset P ...] --out m.perq\n\
         \x20            (quantize once, write a versioned deployment artifact)\n\
         \x20 serve      --artifact m.perq [--requests N] [--workers W]\n\
         \x20            (load + serve, no calibration; appends BENCH_deploy.json)\n\
         \x20 baseline   --model M [--eval-tokens N]\n\
         \x20 sweep      --model M --blocks 16,32,64 [--perm massdiff]\n\
         \x20 opcounts   (analytic Tables 3-4)\n\
         \x20 stats      --model M [--block B]\n\
         \x20 models     (bundles + exported .perq artifacts)\n\
         \n\
         PRESETS: {}\n\
         OPTIONS: --perm identity|random|absmax|zigzag|massdiff\n\
         \x20        --rounding rtn|gptq|qronos   --format int4|int8|fp4|mxfp4\n\
         \x20        --block N   --online   --zeroshot   --eval-tokens N\n\
         \x20        --calib-seqs N   --source wiki|c4|fineweb (calib + eval)\n\
         \x20        --eval-source wiki|c4|fineweb (override eval split only)\n\
         \x20        --backend native|pjrt|auto (native = pure-Rust forward,\n\
         \x20                  no PJRT/XLA or HLO artifacts required)\n\
         \x20        --threads N  worker-pool lanes (default: PERQ_THREADS\n\
         \x20                  env, else core count; PERQ_SIMD={{auto,avx2,\n\
         \x20                  neon,scalar}} overrides kernel dispatch)",
        presets::names().join(" ")
    );
}

fn spec_from_args(args: &cli::Args) -> Result<PipelineSpec> {
    let block = args.get_usize("block", 32);
    let format = Format::parse(&args.get_or("format", "int4"))
        .ok_or_else(|| anyhow!("bad --format"))?;
    let mut spec = if let Some(preset) = args.get("preset") {
        presets::parse(preset, block, format).ok_or_else(|| {
            anyhow!("unknown preset {preset} (expected one of: {})", presets::names().join(" "))
        })?
    } else {
        let mut s = PipelineSpec::default();
        s.rotation = RotationSpec::quarot(block);
        s.format = format;
        if let Some(p) = args.get("perm") {
            s.permutation = PermKind::parse(p).ok_or_else(|| anyhow!("bad --perm"))?;
        }
        if let Some(r) = args.get("rounding") {
            s.rounding = Rounding::parse(r).ok_or_else(|| anyhow!("bad --rounding"))?;
        }
        s
    };
    if args.has_flag("online") {
        spec.graph = GraphKind::Online;
    }
    if args.has_flag("zeroshot") {
        spec.run_zeroshot = true;
    }
    spec.eval_tokens = args.get_usize("eval-tokens", spec.eval_tokens);
    spec.calib_seqs = args.get_usize("calib-seqs", spec.calib_seqs);
    if let Some(src) = args.get("source") {
        let s = Source::parse(src).ok_or_else(|| anyhow!("bad --source"))?;
        // --source selects the corpus for the whole run: calibration AND
        // evaluation (previously only calibration was switched, silently
        // evaluating on the default split). --eval-source overrides below.
        spec.calib_source = s;
        spec.eval_source = s;
    }
    if let Some(src) = args.get("eval-source") {
        spec.eval_source = Source::parse(src).ok_or_else(|| anyhow!("bad --eval-source"))?;
    }
    Ok(spec)
}

/// Shared engine construction honoring `--backend {native,pjrt,auto}`.
fn engine_from_args(args: &cli::Args, ctx: &RepoContext) -> Result<Engine> {
    let kind = BackendKind::resolve(args.get("backend"), ctx)?;
    Engine::with_backend(ctx, kind)
}

/// Engine + bundle resolution with the synthetic fallback: no artifacts
/// tree (or no trained weights) still yields a runnable native setup, so
/// `perq export` works from a bare checkout — the CI smoke path.
fn engine_and_bundle(args: &cli::Args, model: &str) -> Result<(Engine, ModelBundle)> {
    match RepoContext::discover() {
        Ok(ctx) => {
            let kind = BackendKind::resolve(args.get("backend"), &ctx)?;
            let engine = Engine::with_backend(&ctx, kind)?;
            match ModelBundle::load(&ctx, model) {
                Ok(b) => Ok((engine, b)),
                Err(e) if kind == BackendKind::Native => {
                    eprintln!("note: {e:#}\n      — falling back to synthetic weights");
                    Ok((engine, ModelBundle::synthetic(model)?))
                }
                Err(e) => Err(e),
            }
        }
        Err(_) => {
            anyhow::ensure!(
                !matches!(args.get("backend"), Some("pjrt")),
                "--backend pjrt requires an artifacts/ tree (run `make artifacts`)"
            );
            Ok((Engine::native_ephemeral(), ModelBundle::synthetic(model)?))
        }
    }
}

/// `perq export`: run the offline PTQ stages once and write the result as
/// a versioned `.perq` deployment artifact — no evaluation, no serving.
fn cmd_export(args: &cli::Args) -> Result<()> {
    let model = args.get_or("model", "llama_tiny");
    let out = args.get_or("out", &format!("{model}.perq"));
    let (engine, bundle) = engine_and_bundle(args, &model)?;
    let spec = spec_from_args(args)?;
    println!("pipeline: {}", spec.label());
    println!("backend:  {}", engine.backend().name());
    println!("model:    {} ({} params)", model, bundle.weights.param_count());
    let t0 = Instant::now();
    let qm = Pipeline::new(spec).quantize_with_engine(&bundle, &engine)?;
    let quantize_s = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    qm.save(Path::new(&out))?;
    let write_ms = t1.elapsed().as_secs_f64() * 1e3;
    let bytes = std::fs::metadata(&out)?.len();
    println!(
        "exported {out}: {} — {} packed / {} dense sites, {:.1} KiB \
         ({quantize_s:.1}s quantize + {write_ms:.0}ms write)",
        qm.label,
        qm.ws.packed.len(),
        qm.ws.tensors.len(),
        bytes as f64 / 1024.0,
    );
    Ok(())
}

/// `perq serve`: load a `.perq` artifact, bring up the batching server
/// (no calibration), fire a deterministic request stream, and append the
/// start-to-ready / latency numbers to BENCH_deploy.json.
fn cmd_serve(args: &cli::Args) -> Result<()> {
    let artifact = args.get("artifact").ok_or_else(|| {
        anyhow!("serve needs --artifact model.perq (create one with `perq export`)")
    })?;
    let n_requests = args.get_usize("requests", 32).max(1);
    let workers = args.get_usize("workers", 1).max(1);
    let max_wait = Duration::from_millis(args.get_usize("max-wait-ms", 5) as u64);

    // quantize-once / serve-many: everything below is artifact load +
    // server bring-up — the offline pipeline never runs here
    let t0 = Instant::now();
    let dm = DeployedModel::load(Path::new(artifact))?;
    let load_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t1 = Instant::now();
    let server = dm.serve(max_wait, workers)?;
    let ready_ms = t1.elapsed().as_secs_f64() * 1e3;
    println!(
        "{artifact}: {} {} (format v{}) — loaded in {load_ms:.1}ms, \
         {workers} replica(s) ready in {ready_ms:.1}ms, start-to-ready {:.1}ms",
        dm.model,
        dm.label,
        dm.version,
        load_ms + ready_ms,
    );

    // deterministic request stream over the held-out split
    let t = dm.cfg.seq_len;
    let toks = token_stream(Source::Wiki, Split::Test, (n_requests + 2) * t);
    let t2 = Instant::now();
    let mut rxs = Vec::with_capacity(n_requests);
    for i in 0..n_requests {
        let start = (i * t) % (toks.len() - t - 1);
        let window: Vec<i32> = toks[start..start + t + 1].iter().map(|&x| x as i32).collect();
        rxs.push(server.submit(window)?);
    }
    let mut nll = 0.0f64;
    for rx in rxs {
        nll += rx.recv()?.nll;
    }
    nll /= n_requests as f64;
    let wall = t2.elapsed().as_secs_f64();
    let (served, batches, exec_s) = server.stats();
    let (p50, p95, p99) = server.latency_percentiles();
    println!(
        "{served} requests in {wall:.2}s = {:.0} tok/s | mean nll {nll:.6} (ppl {:.2}) | \
         {batches} batches | exec {exec_s:.2}s | hist p50/p95/p99 {p50:.1}/{p95:.1}/{p99:.1}ms",
        served as f64 * t as f64 / wall.max(1e-9),
        nll.exp(),
    );
    server.shutdown();

    // build the record through the JSON serializer so paths/labels with
    // quotes or backslashes stay valid JSON
    let bench_path = args.get_or("bench-out", "BENCH_deploy.json");
    let mut o = std::collections::BTreeMap::new();
    for (k, v) in [
        ("bench", "deploy".to_string()),
        ("artifact", artifact.to_string()),
        ("model", dm.model.clone()),
        ("label", dm.label.clone()),
    ] {
        o.insert(k.to_string(), Json::Str(v));
    }
    for (k, v) in [
        ("workers", workers as f64),
        ("requests", n_requests as f64),
        ("load_ms", load_ms),
        ("ready_ms", ready_ms),
        ("start_to_ready_ms", load_ms + ready_ms),
        ("nll", nll),
        ("wall_s", wall),
        ("p50_ms", p50),
        ("p95_ms", p95),
        ("p99_ms", p99),
    ] {
        o.insert(k.to_string(), Json::Num(v));
    }
    append_trajectory(Path::new(&bench_path), &json::dump(&Json::Obj(o)))?;
    println!("appended {bench_path}");
    Ok(())
}

fn cmd_quantize(args: &cli::Args) -> Result<()> {
    let model = args.get_or("model", "llama_tiny");
    let ctx = RepoContext::discover()?;
    let engine = engine_from_args(args, &ctx)?;
    let bundle = ModelBundle::load(&ctx, &model)?;
    let spec = spec_from_args(args)?;
    println!("pipeline: {}", spec.label());
    println!("backend:  {}", engine.backend().name());
    println!("model:    {} ({} params)", model, bundle.weights.param_count());
    let report = Pipeline::new(spec).run_with_engine(&bundle, &engine)?;
    println!("perplexity:   {:.3} ({})", report.perplexity, fmt_ppl(report.perplexity));
    println!("nll:          {:.4} nats/token", report.nll);
    println!("mass balance: {:.3}x of optimum", report.mass_balance);
    println!("calib tokens: {}", report.calib_tokens);
    if let Some(z) = &report.zeroshot {
        for (name, acc) in z.task_names.iter().zip(&z.accuracies) {
            println!("  0-shot {name:<14} {:.1}%", acc * 100.0);
        }
        println!("  0-shot average       {:.1}%", z.average());
    }
    println!("wall: {:.1}s", report.wall_ms / 1e3);
    Ok(())
}

fn cmd_baseline(args: &cli::Args) -> Result<()> {
    let model = args.get_or("model", "llama_tiny");
    let ctx = RepoContext::discover()?;
    let engine = engine_from_args(args, &ctx)?;
    let bundle = ModelBundle::load_with_engine(&ctx, &engine, &model)?;
    let n = args.get_usize("eval-tokens", 8192);
    let z = args.has_flag("zeroshot").then_some(2048);
    let (eval, zres) = baseline_eval(&bundle, &engine, n, z)?;
    println!("{model} BF16-analog baseline: ppl {:.3} over {} predictions",
             eval.perplexity, eval.n_predictions);
    if let Some(z) = zres {
        println!("  0-shot average {:.1}%", z.average());
    }
    Ok(())
}

fn cmd_sweep(args: &cli::Args) -> Result<()> {
    let model = args.get_or("model", "llama_tiny");
    let blocks: Vec<usize> = args
        .get_or("blocks", "16,32,64,128")
        .split(',')
        .filter_map(|s| s.parse().ok())
        .collect();
    let ctx = RepoContext::discover()?;
    let engine = engine_from_args(args, &ctx)?;
    let bundle = ModelBundle::load_with_engine(&ctx, &engine, &model)?;
    let mut rows = Vec::new();
    for &b in &blocks {
        let mut spec = spec_from_args(args)?;
        spec.rotation = RotationSpec::quarot(b);
        let rep = Pipeline::new(spec).run_with_engine(&bundle, &engine)?;
        println!("  b={b}: ppl {:.2}", rep.perplexity);
        rows.push((format!("b={b}"), vec![fmt_ppl(rep.perplexity)]));
    }
    print_table(&format!("{model} block-size sweep"), &["ppl"], &rows);
    Ok(())
}

fn cmd_opcounts() -> Result<()> {
    let rows3: Vec<(String, Vec<String>)> = opcount::table3()
        .into_iter()
        .map(|r| {
            let pct = |ops: usize| format!("{} ({:.0}%)", fmt_count(ops),
                                           100.0 * ops as f64 / r.full as f64);
            (
                format!("{} {} d={}", r.model, r.size, r.d),
                vec![pct(r.b32), pct(r.b128), pct(r.b512), fmt_count(r.full)],
            )
        })
        .collect();
    print_table("Table 3: rotation op counts", &["b=32", "b=128", "b=512", "Full"], &rows3);
    let rows4: Vec<(String, Vec<String>)> = opcount::table4()
        .into_iter()
        .map(|r| {
            (
                format!("{} d={} (2^{}x{})", r.model, r.d, r.kp, r.base),
                vec![
                    fmt_count(r.matmul),
                    fmt_count(r.butterfly_matmul),
                    fmt_count(r.ours),
                ],
            )
        })
        .collect();
    print_table("Table 4: non-power-of-2 methods", &["Matmul", "Bfly+MM", "Ours"], &rows4);
    Ok(())
}

fn cmd_stats(args: &cli::Args) -> Result<()> {
    let model = args.get_or("model", "llama_tiny");
    let block = args.get_usize("block", 32);
    let ctx = RepoContext::discover()?;
    let engine = engine_from_args(args, &ctx)?;
    let bundle = ModelBundle::load_with_engine(&ctx, &engine, &model)?;
    let cfg = &bundle.cfg;
    let mut ws = bundle.weights.clone();
    transform::fold_norms(&mut ws, cfg);
    let seqs = capture::calibration_batches(cfg, Source::Wiki, 8, 3);
    let caps = capture::run_capture(&engine, &model, cfg, &ws, &seqs)?;
    println!("mass concentration at down-projection inputs ({model}, {} tokens):",
             caps.n_tokens);
    for l in 0..cfg.n_layers {
        let down = &caps.down_in[l];
        let mut deltas = Vec::new();
        let mut bounds = Vec::new();
        for r in 0..down.rows.min(512) {
            let row = down.row(r);
            deltas.push(stats::delta(row));
            bounds.push(stats::normalized_bound(row, block));
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        println!(
            "  layer {l}: mean delta {:.4}  mean bound(b={block}) {:.4}  1/sqrt(b)={:.4}  1/b={:.4}",
            mean(&deltas), mean(&bounds),
            1.0 / (block as f64).sqrt(), 1.0 / block as f64
        );
    }
    Ok(())
}

fn cmd_models() -> Result<()> {
    // HLO model bundles (meta.json directories) — only with an artifacts
    // tree; exported .perq artifacts list fine without one.
    let ctx = RepoContext::discover().ok();
    let mut any = false;
    if let Some(ctx) = &ctx {
        if let Ok(entries) = std::fs::read_dir(&ctx.artifacts) {
            let mut names: Vec<String> = entries
                .flatten()
                .filter(|e| e.path().join("meta.json").exists())
                .map(|e| e.file_name().to_string_lossy().to_string())
                .collect();
            names.sort();
            for name in names {
                println!("{name}  (HLO bundle)");
                any = true;
            }
        }
    }
    // exported .perq deployment artifacts: cwd + the artifacts tree,
    // summarized from the header alone (format/block/label, no payload IO)
    let mut dirs = vec![PathBuf::from(".")];
    if let Some(ctx) = &ctx {
        dirs.push(ctx.artifacts.clone());
    }
    for dir in dirs {
        let Ok(entries) = std::fs::read_dir(&dir) else { continue };
        let mut paths: Vec<PathBuf> = entries
            .flatten()
            .map(|e| e.path())
            .filter(|p| p.extension().map_or(false, |e| e == "perq"))
            .collect();
        paths.sort();
        for p in paths {
            match deploy::inspect(&p) {
                Ok(info) => println!(
                    "{}  (.perq v{}: {} {} {} b={} — {})",
                    p.display(),
                    info.version,
                    info.model,
                    info.graph_kind,
                    info.format,
                    info.r3_block,
                    info.label
                ),
                Err(e) => println!("{}  (unreadable .perq: {e:#})", p.display()),
            }
            any = true;
        }
    }
    if !any {
        println!("no model bundles or .perq artifacts found (run `make artifacts` or `perq export`)");
    }
    Ok(())
}
