//! `perq` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   quantize   run a full PTQ pipeline and report perplexity / 0-shot
//!   baseline   evaluate the full-precision model
//!   sweep      block-size sweep (Table 1 style) for one method
//!   opcounts   print the analytic rotation op-count tables (Tables 3-4)
//!   stats      mass-concentration statistics on real activations (Fig 3-4)
//!   models     list available model bundles
//!
//! Examples:
//!   perq quantize --model llama_tiny --preset perq_star --block 32
//!   perq quantize --model llama_tiny --perm zigzag --rounding gptq --format fp4
//!   perq sweep --model llama_tiny --blocks 16,32,64 --format int4
//!   perq baseline --model qwen_tiny

use anyhow::{anyhow, bail, Result};

use perq::backend::BackendKind;
use perq::calib::capture;
use perq::coordinator::presets;
use perq::coordinator::spec::{GraphKind, PipelineSpec, RotationSpec};
use perq::hadamard::opcount;
use perq::model::transform;
use perq::prelude::*;
use perq::stats;
use perq::util::bench::{fmt_count, fmt_ppl, print_table};
use perq::util::cli;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cli::parse(&argv);
    // `--threads N` (or PERQ_THREADS) sizes the worker pool; it must be
    // applied before any kernel work because the global pool spawns
    // lazily on first use.
    if let Some(n) = args.get("threads").and_then(|s| s.parse::<usize>().ok()) {
        perq::util::pool::set_default_parallelism(n);
    }
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "quantize" => cmd_quantize(&args),
        "baseline" => cmd_baseline(&args),
        "sweep" => cmd_sweep(&args),
        "opcounts" => cmd_opcounts(),
        "stats" => cmd_stats(&args),
        "models" => cmd_models(),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "perq — Permute, Rotate, then Quantize (PTQ coordinator)\n\
         \n\
         USAGE: perq <command> [options]\n\
         \n\
         COMMANDS:\n\
         \x20 quantize   --model M [--preset P | --perm/--rounding/--format/--block ...]\n\
         \x20 baseline   --model M [--eval-tokens N]\n\
         \x20 sweep      --model M --blocks 16,32,64 [--perm massdiff]\n\
         \x20 opcounts   (analytic Tables 3-4)\n\
         \x20 stats      --model M [--block B]\n\
         \x20 models\n\
         \n\
         PRESETS: perq_star perq_dagger no_permute mr_rtn mr_gptq mr_qronos brq_spin\n\
         OPTIONS: --perm identity|random|absmax|zigzag|massdiff\n\
         \x20        --rounding rtn|gptq|qronos   --format int4|fp4|mxfp4\n\
         \x20        --block N   --online   --zeroshot   --eval-tokens N\n\
         \x20        --calib-seqs N   --source wiki|c4|fineweb\n\
         \x20        --backend native|pjrt|auto (native = pure-Rust forward,\n\
         \x20                  no PJRT/XLA or HLO artifacts required)\n\
         \x20        --threads N  worker-pool lanes (default: PERQ_THREADS\n\
         \x20                  env, else core count; PERQ_SIMD={{auto,avx2,\n\
         \x20                  neon,scalar}} overrides kernel dispatch)"
    );
}

fn spec_from_args(args: &cli::Args) -> Result<PipelineSpec> {
    let block = args.get_usize("block", 32);
    let format = Format::parse(&args.get_or("format", "int4"))
        .ok_or_else(|| anyhow!("bad --format"))?;
    let mut spec = if let Some(preset) = args.get("preset") {
        match preset {
            "perq_star" => presets::perq_star(block, format),
            "perq_dagger" => presets::perq_dagger(block, format),
            "no_permute" => presets::no_permute(block, format),
            "mr_rtn" => presets::mr(block, Rounding::Rtn, format),
            "mr_gptq" => presets::mr(block, Rounding::Gptq, format),
            "mr_qronos" => presets::mr(block, Rounding::Qronos, format),
            "brq_spin" => presets::brq_spin(block, format),
            p => bail!("unknown preset {p}"),
        }
    } else {
        let mut s = PipelineSpec::default();
        s.rotation = RotationSpec::quarot(block);
        s.format = format;
        if let Some(p) = args.get("perm") {
            s.permutation = PermKind::parse(p).ok_or_else(|| anyhow!("bad --perm"))?;
        }
        if let Some(r) = args.get("rounding") {
            s.rounding = Rounding::parse(r).ok_or_else(|| anyhow!("bad --rounding"))?;
        }
        s
    };
    if args.has_flag("online") {
        spec.graph = GraphKind::Online;
    }
    if args.has_flag("zeroshot") {
        spec.run_zeroshot = true;
    }
    spec.eval_tokens = args.get_usize("eval-tokens", spec.eval_tokens);
    spec.calib_seqs = args.get_usize("calib-seqs", spec.calib_seqs);
    if let Some(src) = args.get("source") {
        let s = Source::parse(src).ok_or_else(|| anyhow!("bad --source"))?;
        spec.calib_source = s;
    }
    Ok(spec)
}

/// Shared engine construction honoring `--backend {native,pjrt,auto}`.
fn engine_from_args(args: &cli::Args, ctx: &RepoContext) -> Result<Engine> {
    let kind = BackendKind::resolve(args.get("backend"), ctx)?;
    Engine::with_backend(ctx, kind)
}

fn cmd_quantize(args: &cli::Args) -> Result<()> {
    let model = args.get_or("model", "llama_tiny");
    let ctx = RepoContext::discover()?;
    let engine = engine_from_args(args, &ctx)?;
    let bundle = ModelBundle::load(&ctx, &model)?;
    let spec = spec_from_args(args)?;
    println!("pipeline: {}", spec.label());
    println!("backend:  {}", engine.backend().name());
    println!("model:    {} ({} params)", model, bundle.weights.param_count());
    let report = Pipeline::new(spec).run_with_engine(&bundle, &engine)?;
    println!("perplexity:   {:.3} ({})", report.perplexity, fmt_ppl(report.perplexity));
    println!("nll:          {:.4} nats/token", report.nll);
    println!("mass balance: {:.3}x of optimum", report.mass_balance);
    println!("calib tokens: {}", report.calib_tokens);
    if let Some(z) = &report.zeroshot {
        for (name, acc) in z.task_names.iter().zip(&z.accuracies) {
            println!("  0-shot {name:<14} {:.1}%", acc * 100.0);
        }
        println!("  0-shot average       {:.1}%", z.average());
    }
    println!("wall: {:.1}s", report.wall_ms / 1e3);
    Ok(())
}

fn cmd_baseline(args: &cli::Args) -> Result<()> {
    let model = args.get_or("model", "llama_tiny");
    let ctx = RepoContext::discover()?;
    let engine = engine_from_args(args, &ctx)?;
    let bundle = ModelBundle::load_with_engine(&ctx, &engine, &model)?;
    let n = args.get_usize("eval-tokens", 8192);
    let z = args.has_flag("zeroshot").then_some(2048);
    let (eval, zres) = baseline_eval(&bundle, &engine, n, z)?;
    println!("{model} BF16-analog baseline: ppl {:.3} over {} predictions",
             eval.perplexity, eval.n_predictions);
    if let Some(z) = zres {
        println!("  0-shot average {:.1}%", z.average());
    }
    Ok(())
}

fn cmd_sweep(args: &cli::Args) -> Result<()> {
    let model = args.get_or("model", "llama_tiny");
    let blocks: Vec<usize> = args
        .get_or("blocks", "16,32,64,128")
        .split(',')
        .filter_map(|s| s.parse().ok())
        .collect();
    let ctx = RepoContext::discover()?;
    let engine = engine_from_args(args, &ctx)?;
    let bundle = ModelBundle::load_with_engine(&ctx, &engine, &model)?;
    let mut rows = Vec::new();
    for &b in &blocks {
        let mut spec = spec_from_args(args)?;
        spec.rotation = RotationSpec::quarot(b);
        let rep = Pipeline::new(spec).run_with_engine(&bundle, &engine)?;
        println!("  b={b}: ppl {:.2}", rep.perplexity);
        rows.push((format!("b={b}"), vec![fmt_ppl(rep.perplexity)]));
    }
    print_table(&format!("{model} block-size sweep"), &["ppl"], &rows);
    Ok(())
}

fn cmd_opcounts() -> Result<()> {
    let rows3: Vec<(String, Vec<String>)> = opcount::table3()
        .into_iter()
        .map(|r| {
            let pct = |ops: usize| format!("{} ({:.0}%)", fmt_count(ops),
                                           100.0 * ops as f64 / r.full as f64);
            (
                format!("{} {} d={}", r.model, r.size, r.d),
                vec![pct(r.b32), pct(r.b128), pct(r.b512), fmt_count(r.full)],
            )
        })
        .collect();
    print_table("Table 3: rotation op counts", &["b=32", "b=128", "b=512", "Full"], &rows3);
    let rows4: Vec<(String, Vec<String>)> = opcount::table4()
        .into_iter()
        .map(|r| {
            (
                format!("{} d={} (2^{}x{})", r.model, r.d, r.kp, r.base),
                vec![
                    fmt_count(r.matmul),
                    fmt_count(r.butterfly_matmul),
                    fmt_count(r.ours),
                ],
            )
        })
        .collect();
    print_table("Table 4: non-power-of-2 methods", &["Matmul", "Bfly+MM", "Ours"], &rows4);
    Ok(())
}

fn cmd_stats(args: &cli::Args) -> Result<()> {
    let model = args.get_or("model", "llama_tiny");
    let block = args.get_usize("block", 32);
    let ctx = RepoContext::discover()?;
    let engine = engine_from_args(args, &ctx)?;
    let bundle = ModelBundle::load_with_engine(&ctx, &engine, &model)?;
    let cfg = &bundle.cfg;
    let mut ws = bundle.weights.clone();
    transform::fold_norms(&mut ws, cfg);
    let seqs = capture::calibration_batches(cfg, Source::Wiki, 8, 3);
    let caps = capture::run_capture(&engine, &model, cfg, &ws, &seqs)?;
    println!("mass concentration at down-projection inputs ({model}, {} tokens):",
             caps.n_tokens);
    for l in 0..cfg.n_layers {
        let down = &caps.down_in[l];
        let mut deltas = Vec::new();
        let mut bounds = Vec::new();
        for r in 0..down.rows.min(512) {
            let row = down.row(r);
            deltas.push(stats::delta(row));
            bounds.push(stats::normalized_bound(row, block));
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        println!(
            "  layer {l}: mean delta {:.4}  mean bound(b={block}) {:.4}  1/sqrt(b)={:.4}  1/b={:.4}",
            mean(&deltas), mean(&bounds),
            1.0 / (block as f64).sqrt(), 1.0 / block as f64
        );
    }
    Ok(())
}

fn cmd_models() -> Result<()> {
    let ctx = RepoContext::discover()?;
    for entry in std::fs::read_dir(&ctx.artifacts)? {
        let entry = entry?;
        if entry.path().join("meta.json").exists() {
            let name = entry.file_name().to_string_lossy().to_string();
            println!("{name}");
        }
    }
    Ok(())
}
