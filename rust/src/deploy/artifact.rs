//! The versioned `.perq` container format — the byte-level half of the
//! deploy subsystem (see `deploy::mod` for the model-level schema).
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! ┌────────────────────────────────────────────────────────────┐
//! │ magic  "PERQARTF"                                  8 bytes │
//! │ format version (u32)                               4 bytes │
//! │ header length H (u32)                              4 bytes │
//! │ header CRC32 (u32)                                 4 bytes │
//! │ header JSON (schema: deploy::mod)                  H bytes │
//! ├── aligned to 64 ───────────────────────────────────────────┤
//! │ section 0 payload                                          │
//! ├── aligned to 64 ───────────────────────────────────────────┤
//! │ section 1 payload …                                        │
//! ├── aligned to 64 ───────────────────────────────────────────┤
//! │ footer JSON: the section table                     F bytes │
//! │   {"sections": [{name, kind, dims, bits,                   │
//! │                  offset, len, crc}, …]}                    │
//! │ footer length F (u32) │ footer CRC32 (u32) │ magic 8 bytes │
//! └────────────────────────────────────────────────────────────┘
//! ```
//!
//! Design notes:
//! * the header (label, config, provenance, weight names/shapes) is known
//!   before any payload, so it streams out first; the section table needs
//!   offsets and checksums, so it lands in a footer — the writer is fully
//!   streaming (one pass, `Write`-generic, no payload buffering);
//! * sections are 64-byte aligned, so a reader that maps the file can
//!   hand out payload slices directly (the in-tree reader loads the file
//!   into one buffer and borrows sections from it — zero-copy-friendly,
//!   one copy total);
//! * every region is independently checksummed (CRC32/IEEE): header,
//!   footer, and each section. Truncation is caught by the trailing
//!   magic, corruption by the covering CRC;
//! * versioning: readers accept `version <= FORMAT_VERSION` and must
//!   reject anything newer — forward compatibility is explicit re-export,
//!   never silent reinterpretation. Additive changes (new section kinds,
//!   new header fields) do not bump the version; layout changes do.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;
use std::sync::OnceLock;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::util::json::{self, Json};

/// File magic, present at both ends (head: format id; tail: truncation
/// sentinel).
pub const MAGIC: &[u8; 8] = b"PERQARTF";

/// Current container format version. Readers reject anything newer.
pub const FORMAT_VERSION: u32 = 1;

/// Section payload alignment (bytes) — mmap/zero-copy friendly.
pub const ALIGN: usize = 64;

/// Fixed head: magic + version + header length + header crc.
const HEAD_LEN: usize = 20;

/// Fixed trailer: footer length + footer crc + magic.
const TRAILER_LEN: usize = 16;

/// Pure, overflow-checked extent arithmetic for the container framing.
/// Offsets and lengths come from the (attacker-controllable) head,
/// trailer, and section table, so every bound computation must be total:
/// each function here returns `None` instead of wrapping, and the Kani
/// harness in rust/verify/artifact.rs proves them panic- and
/// overflow-free for *all* `usize` inputs.
pub mod extent {
    use super::{HEAD_LEN, TRAILER_LEN};

    /// Minimum file length able to hold a header of `hlen` bytes plus the
    /// fixed framing: `HEAD_LEN + hlen + TRAILER_LEN`, checked.
    pub fn min_file_len(hlen: usize) -> Option<usize> {
        HEAD_LEN.checked_add(hlen)?.checked_add(TRAILER_LEN)
    }

    /// Start offset of a footer of `flen` bytes in a file of `n` bytes
    /// whose header is `hlen` bytes: `Some(n - TRAILER_LEN - flen)` iff
    /// the footer + trailer fit in the file *and* start at or after the
    /// end of the header region. Replaces the unchecked
    /// `flen + TRAILER_LEN <= n && n - TRAILER_LEN - flen >= HEAD_LEN + hlen`.
    pub fn footer_start(n: usize, hlen: usize, flen: usize) -> Option<usize> {
        let head_end = HEAD_LEN.checked_add(hlen)?;
        let tail = flen.checked_add(TRAILER_LEN)?;
        let fstart = n.checked_sub(tail)?;
        if fstart >= head_end {
            Some(fstart)
        } else {
            None
        }
    }

    /// One-past-the-end byte of a section payload, checked.
    pub fn section_end(offset: usize, len: usize) -> Option<usize> {
        offset.checked_add(len)
    }
}

// ---------------------------------------------------------------- crc32

fn crc32_table() -> &'static [u32; 256] {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, slot) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *slot = c;
        }
        t
    })
}

/// Incremental CRC32 (IEEE 802.3 polynomial) state.
pub struct Crc32(u32);

impl Default for Crc32 {
    fn default() -> Self {
        Crc32::new()
    }
}

impl Crc32 {
    pub fn new() -> Crc32 {
        Crc32(0xFFFF_FFFF)
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let table = crc32_table();
        let mut c = self.0;
        for &b in bytes {
            c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.0 = c;
    }

    pub fn finish(&self) -> u32 {
        self.0 ^ 0xFFFF_FFFF
    }
}

/// One-shot CRC32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

// ------------------------------------------------------------- sections

/// A section table entry: where a payload lives and how to validate it.
/// `dims`/`bits` carry the shape metadata the model-level reader needs to
/// reconstruct matrices without re-deriving it from the header.
#[derive(Clone, Debug)]
pub struct SectionDesc {
    pub name: String,
    /// payload kind tag: "f32", "qmat", "u32", …
    pub kind: String,
    pub dims: Vec<usize>,
    /// integer code width for "qmat" sections (0 otherwise)
    pub bits: u32,
    /// absolute byte offset of the payload in the file
    pub offset: usize,
    pub len: usize,
    pub crc: u32,
}

fn sections_to_json(sections: &[SectionDesc]) -> Json {
    let arr = sections
        .iter()
        .map(|s| {
            let mut m = BTreeMap::new();
            m.insert("name".to_string(), Json::Str(s.name.clone()));
            m.insert("kind".to_string(), Json::Str(s.kind.clone()));
            m.insert(
                "dims".to_string(),
                Json::Arr(s.dims.iter().map(|&d| Json::Num(d as f64)).collect()),
            );
            m.insert("bits".to_string(), Json::Num(s.bits as f64));
            m.insert("offset".to_string(), Json::Num(s.offset as f64));
            m.insert("len".to_string(), Json::Num(s.len as f64));
            m.insert("crc".to_string(), Json::Num(s.crc as f64));
            Json::Obj(m)
        })
        .collect();
    let mut root = BTreeMap::new();
    root.insert("sections".to_string(), Json::Arr(arr));
    Json::Obj(root)
}

fn sections_from_json(footer: &Json) -> Result<Vec<SectionDesc>> {
    let arr = footer
        .get("sections")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("artifact footer carries no section table"))?;
    arr.iter()
        .map(|s| {
            let str_field = |k: &str| -> Result<String> {
                Ok(s.get(k)
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow!("section entry missing {k}"))?
                    .to_string())
            };
            let num_field = |k: &str| -> Result<usize> {
                s.get(k)
                    .and_then(|v| v.as_usize())
                    .ok_or_else(|| anyhow!("section entry missing {k}"))
            };
            Ok(SectionDesc {
                name: str_field("name")?,
                kind: str_field("kind")?,
                dims: s
                    .get("dims")
                    .and_then(|v| v.as_arr())
                    .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                    .unwrap_or_default(),
                bits: num_field("bits")? as u32,
                offset: num_field("offset")?,
                len: num_field("len")?,
                crc: s
                    .get("crc")
                    .and_then(|v| v.as_f64())
                    .ok_or_else(|| anyhow!("section entry missing crc"))? as u32,
            })
        })
        .collect()
}

// --------------------------------------------------------------- writer

/// Streaming `.perq` writer: header up front, sections appended one at a
/// time (length + CRC accumulated on the fly), section table in the
/// footer. Payloads are never buffered, so writing a model costs O(1)
/// extra memory over the weights it serializes.
pub struct ArtifactWriter<W: Write> {
    out: W,
    pos: usize,
    sections: Vec<SectionDesc>,
    cur: Option<Crc32>,
}

impl<W: Write> ArtifactWriter<W> {
    /// Write the fixed head + header JSON and return a writer positioned
    /// for the first section.
    pub fn new(mut out: W, header: &Json) -> Result<ArtifactWriter<W>> {
        let hjson = json::dump(header);
        let hbytes = hjson.as_bytes();
        out.write_all(MAGIC)?;
        out.write_all(&FORMAT_VERSION.to_le_bytes())?;
        out.write_all(&(hbytes.len() as u32).to_le_bytes())?;
        out.write_all(&crc32(hbytes).to_le_bytes())?;
        out.write_all(hbytes)?;
        Ok(ArtifactWriter {
            out,
            pos: HEAD_LEN + hbytes.len(),
            sections: Vec::new(),
            cur: None,
        })
    }

    /// Open a new section (pads to [`ALIGN`] first).
    pub fn begin_section(&mut self, name: &str, kind: &str, dims: &[usize], bits: u32) -> Result<()> {
        ensure!(self.cur.is_none(), "previous section was not ended");
        self.pad_file(ALIGN)?;
        self.sections.push(SectionDesc {
            name: name.to_string(),
            kind: kind.to_string(),
            dims: dims.to_vec(),
            bits,
            offset: self.pos,
            len: 0,
            crc: 0,
        });
        self.cur = Some(Crc32::new());
        Ok(())
    }

    /// Append raw bytes to the open section.
    pub fn write_bytes(&mut self, bytes: &[u8]) -> Result<()> {
        let crc = self
            .cur
            .as_mut()
            .ok_or_else(|| anyhow!("write outside an open section"))?;
        crc.update(bytes);
        self.out.write_all(bytes)?;
        self.pos += bytes.len();
        self.sections.last_mut().expect("open section").len += bytes.len();
        Ok(())
    }

    /// Append f32 values (little-endian), chunked to bound scratch.
    pub fn write_f32s(&mut self, values: &[f32]) -> Result<()> {
        let mut buf = Vec::with_capacity(values.len().min(16_384) * 4);
        for chunk in values.chunks(16_384) {
            buf.clear();
            for x in chunk {
                buf.extend_from_slice(&x.to_le_bytes());
            }
            self.write_bytes(&buf)?;
        }
        Ok(())
    }

    /// Append i32 values (little-endian).
    pub fn write_i32s(&mut self, values: &[i32]) -> Result<()> {
        let mut buf = Vec::with_capacity(values.len().min(16_384) * 4);
        for chunk in values.chunks(16_384) {
            buf.clear();
            for x in chunk {
                buf.extend_from_slice(&x.to_le_bytes());
            }
            self.write_bytes(&buf)?;
        }
        Ok(())
    }

    /// Append u32 values (little-endian).
    pub fn write_u32s(&mut self, values: &[u32]) -> Result<()> {
        let mut buf = Vec::with_capacity(values.len().min(16_384) * 4);
        for chunk in values.chunks(16_384) {
            buf.clear();
            for x in chunk {
                buf.extend_from_slice(&x.to_le_bytes());
            }
            self.write_bytes(&buf)?;
        }
        Ok(())
    }

    /// Zero-pad *inside* the open section to the given alignment of the
    /// section-relative position (padding counts toward len and CRC).
    pub fn pad_section(&mut self, align: usize) -> Result<()> {
        ensure!(self.cur.is_some(), "pad_section outside an open section");
        let sec_pos = self.sections.last().expect("open section").len;
        let rem = sec_pos % align;
        if rem != 0 {
            self.write_bytes(&vec![0u8; align - rem])?;
        }
        Ok(())
    }

    /// Close the open section, sealing its CRC.
    pub fn end_section(&mut self) -> Result<()> {
        let crc = self
            .cur
            .take()
            .ok_or_else(|| anyhow!("end_section without an open section"))?;
        self.sections.last_mut().expect("open section").crc = crc.finish();
        Ok(())
    }

    /// Zero-pad the file position to `align` (between sections only).
    fn pad_file(&mut self, align: usize) -> Result<()> {
        let rem = self.pos % align;
        if rem != 0 {
            let pad = align - rem;
            self.out.write_all(&vec![0u8; pad])?;
            self.pos += pad;
        }
        Ok(())
    }

    /// Write the footer section table + trailer and flush.
    pub fn finish(mut self) -> Result<()> {
        ensure!(self.cur.is_none(), "finish with an unfinished section");
        self.pad_file(ALIGN)?;
        let fjson = json::dump(&sections_to_json(&self.sections));
        let fbytes = fjson.as_bytes();
        self.out.write_all(fbytes)?;
        self.out.write_all(&(fbytes.len() as u32).to_le_bytes())?;
        self.out.write_all(&crc32(fbytes).to_le_bytes())?;
        self.out.write_all(MAGIC)?;
        self.out.flush()?;
        Ok(())
    }
}

// --------------------------------------------------------------- reader

/// A fully-validated `.perq` file: header parsed, section table located,
/// every CRC checked, all bounds verified. Section payloads are borrowed
/// slices of the single file buffer.
pub struct ArtifactReader {
    pub version: u32,
    pub header: Json,
    data: Vec<u8>,
    sections: Vec<SectionDesc>,
    by_name: BTreeMap<String, usize>,
}

fn u32_at(data: &[u8], at: usize) -> u32 {
    u32::from_le_bytes([data[at], data[at + 1], data[at + 2], data[at + 3]])
}

impl ArtifactReader {
    pub fn open(path: &Path) -> Result<ArtifactReader> {
        let data =
            std::fs::read(path).with_context(|| format!("reading artifact {path:?}"))?;
        ArtifactReader::from_bytes(data)
            .with_context(|| format!("loading artifact {path:?}"))
    }

    pub fn from_bytes(data: Vec<u8>) -> Result<ArtifactReader> {
        ensure!(
            data.len() >= HEAD_LEN + TRAILER_LEN,
            "artifact truncated ({} bytes — smaller than the fixed framing)",
            data.len()
        );
        let (version, hlen) = parse_head(&data)?;
        ensure!(
            extent::min_file_len(hlen).is_some_and(|min| min <= data.len()),
            "artifact truncated inside the header"
        );
        let hbytes = &data[HEAD_LEN..HEAD_LEN + hlen];
        let hcrc = u32_at(&data, 16);
        ensure!(
            crc32(hbytes) == hcrc,
            "header checksum mismatch — corrupted artifact"
        );
        let header = json::parse(
            std::str::from_utf8(hbytes).context("artifact header is not UTF-8")?,
        )
        .context("parsing artifact header JSON")?;

        // trailer: the truncation sentinel, then the footer section table
        let n = data.len();
        ensure!(
            &data[n - 8..] == MAGIC,
            "trailing magic missing — truncated artifact"
        );
        let flen = u32_at(&data, n - TRAILER_LEN) as usize;
        let fcrc = u32_at(&data, n - TRAILER_LEN + 4);
        let fstart = extent::footer_start(n, hlen, flen)
            .ok_or_else(|| anyhow!("artifact truncated before the section table"))?;
        // fstart + flen == n - TRAILER_LEN by construction of footer_start
        let fbytes = &data[fstart..n - TRAILER_LEN];
        ensure!(
            crc32(fbytes) == fcrc,
            "section-table checksum mismatch — corrupted artifact"
        );
        let footer = json::parse(
            std::str::from_utf8(fbytes).context("section table is not UTF-8")?,
        )
        .context("parsing artifact section table")?;
        let sections = sections_from_json(&footer)?;

        let mut by_name = BTreeMap::new();
        for (i, s) in sections.iter().enumerate() {
            // offsets/lens come from the (attacker-controllable) section
            // table, so the bound check must not itself overflow
            let end = extent::section_end(s.offset, s.len)
                .ok_or_else(|| anyhow!("section {} extent overflows", s.name))?;
            ensure!(
                s.offset >= HEAD_LEN + hlen && end <= fstart,
                "section {} points outside the payload area",
                s.name
            );
            ensure!(
                crc32(&data[s.offset..end]) == s.crc,
                "section {} checksum mismatch — corrupted artifact",
                s.name
            );
            ensure!(
                by_name.insert(s.name.clone(), i).is_none(),
                "duplicate section {}",
                s.name
            );
        }
        Ok(ArtifactReader { version, header, data, sections, by_name })
    }

    pub fn sections(&self) -> &[SectionDesc] {
        &self.sections
    }

    pub fn section(&self, name: &str) -> Option<&SectionDesc> {
        self.by_name.get(name).map(|&i| &self.sections[i])
    }

    /// Borrow a section's (already CRC-verified) payload bytes. Descs
    /// handed out by this reader were extent-checked in `from_bytes`; a
    /// caller-forged desc fails the checked extent or the slice bounds
    /// check (a clean panic, never a wrapped index).
    pub fn bytes(&self, s: &SectionDesc) -> &[u8] {
        let end = extent::section_end(s.offset, s.len).expect("section extent overflows");
        &self.data[s.offset..end]
    }

    pub fn f32s(&self, s: &SectionDesc) -> Result<Vec<f32>> {
        le_f32s(self.bytes(s))
    }

    pub fn u32s(&self, s: &SectionDesc) -> Result<Vec<u32>> {
        let b = self.bytes(s);
        ensure!(b.len() % 4 == 0, "section {} is not u32-aligned", s.name);
        Ok(b.chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// Decode little-endian f32s from raw bytes.
pub fn le_f32s(b: &[u8]) -> Result<Vec<f32>> {
    ensure!(b.len() % 4 == 0, "f32 payload length {} is not a multiple of 4", b.len());
    Ok(b.chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Decode little-endian i32s from raw bytes.
pub fn le_i32s(b: &[u8]) -> Result<Vec<i32>> {
    ensure!(b.len() % 4 == 0, "i32 payload length {} is not a multiple of 4", b.len());
    Ok(b.chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Validate the fixed head (magic + version) and return
/// `(version, header_len)`. Shared by the full reader and the cheap
/// header-only path. Public so the verification harness
/// (rust/verify/artifact.rs) can prove it total — it never panics or
/// reads out of bounds for *any* input slice.
pub fn parse_head(head: &[u8]) -> Result<(u32, usize)> {
    ensure!(head.len() >= HEAD_LEN, "artifact shorter than the fixed head");
    ensure!(
        &head[0..8] == MAGIC,
        "bad magic — not a .perq deployment artifact"
    );
    let version = u32_at(head, 8);
    ensure!(version >= 1, "bad artifact format version 0");
    if version > FORMAT_VERSION {
        bail!(
            "artifact format version {version} is newer than this build supports \
             (max {FORMAT_VERSION}) — upgrade perq or re-export the artifact"
        );
    }
    Ok((version, u32_at(head, 12) as usize))
}

/// Read and validate only the fixed head plus the footer section table —
/// the cheap path for listings that need payload *sizes* (packed weight
/// bytes, section inventory) without reading any payload: two small reads
/// at the ends of the file, every byte read is CRC-covered.
pub fn read_section_table(path: &Path) -> Result<(u32, Vec<SectionDesc>)> {
    use std::io::{Seek, SeekFrom};
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening artifact {path:?}"))?;
    let mut head = [0u8; HEAD_LEN];
    f.read_exact(&mut head)
        .with_context(|| format!("reading artifact head of {path:?}"))?;
    let (version, hlen) = parse_head(&head)?;
    let n = f
        .seek(SeekFrom::End(0))
        .with_context(|| format!("sizing artifact {path:?}"))? as usize;
    ensure!(
        extent::min_file_len(hlen).is_some_and(|min| n >= min),
        "artifact {path:?} truncated ({n} bytes)"
    );
    let mut trailer = [0u8; TRAILER_LEN];
    f.seek(SeekFrom::End(-(TRAILER_LEN as i64)))?;
    f.read_exact(&mut trailer)
        .with_context(|| format!("reading artifact trailer of {path:?}"))?;
    ensure!(
        &trailer[8..] == MAGIC,
        "trailing magic missing — truncated artifact {path:?}"
    );
    let flen = u32_at(&trailer, 0) as usize;
    let fcrc = u32_at(&trailer, 4);
    ensure!(
        extent::footer_start(n, hlen, flen).is_some(),
        "artifact {path:?} truncated before the section table"
    );
    // flen <= u32::MAX and fits in the file (checked above), so the
    // seek offset cannot overflow i64
    f.seek(SeekFrom::End(-((TRAILER_LEN + flen) as i64)))?;
    let mut fbytes = vec![0u8; flen];
    f.read_exact(&mut fbytes)
        .with_context(|| format!("reading artifact section table of {path:?}"))?;
    ensure!(
        crc32(&fbytes) == fcrc,
        "section-table checksum mismatch — corrupted artifact {path:?}"
    );
    let footer = json::parse(
        std::str::from_utf8(&fbytes).context("section table is not UTF-8")?,
    )
    .with_context(|| format!("parsing artifact section table of {path:?}"))?;
    Ok((version, sections_from_json(&footer)?))
}

/// Read and validate only the head + header JSON — the cheap path for
/// listings (`perq models`) that must not load payloads.
pub fn read_header(path: &Path) -> Result<(u32, Json)> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening artifact {path:?}"))?;
    let mut head = [0u8; HEAD_LEN];
    f.read_exact(&mut head)
        .with_context(|| format!("reading artifact head of {path:?}"))?;
    let (version, hlen) = parse_head(&head)?;
    let hcrc = u32_at(&head, 16);
    let mut hbytes = vec![0u8; hlen];
    f.read_exact(&mut hbytes)
        .with_context(|| format!("reading artifact header of {path:?}"))?;
    ensure!(
        crc32(&hbytes) == hcrc,
        "header checksum mismatch — corrupted artifact {path:?}"
    );
    let header = json::parse(std::str::from_utf8(&hbytes).context("header is not UTF-8")?)
        .with_context(|| format!("parsing artifact header of {path:?}"))?;
    Ok((version, header))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn header() -> Json {
        let mut m = BTreeMap::new();
        m.insert("model".to_string(), Json::Str("t".to_string()));
        Json::Obj(m)
    }

    fn sample() -> Vec<u8> {
        let mut buf = Vec::new();
        {
            let mut w = ArtifactWriter::new(&mut buf, &header()).unwrap();
            w.begin_section("a", "f32", &[2, 3], 0).unwrap();
            w.write_f32s(&[1.0, -2.5, 3.0, 0.0, 7.0, -0.125]).unwrap();
            w.end_section().unwrap();
            w.begin_section("b", "u32", &[3], 0).unwrap();
            w.write_u32s(&[5, 0, 9]).unwrap();
            w.end_section().unwrap();
            w.begin_section("c", "qmat", &[4, 2], 4).unwrap();
            w.write_bytes(&[0xAB, 0xCD, 0x01]).unwrap();
            w.pad_section(4).unwrap();
            w.write_i32s(&[-7, 7]).unwrap();
            w.end_section().unwrap();
            w.finish().unwrap();
        }
        buf
    }

    #[test]
    fn round_trip_sections() {
        let r = ArtifactReader::from_bytes(sample()).unwrap();
        assert_eq!(r.version, FORMAT_VERSION);
        assert_eq!(r.header.get("model").and_then(|v| v.as_str()), Some("t"));
        assert_eq!(r.sections().len(), 3);
        let a = r.section("a").unwrap();
        assert_eq!((a.kind.as_str(), a.dims.as_slice()), ("f32", &[2usize, 3][..]));
        assert_eq!(a.offset % ALIGN, 0, "sections are aligned");
        assert_eq!(r.f32s(a).unwrap(), vec![1.0, -2.5, 3.0, 0.0, 7.0, -0.125]);
        let b = r.section("b").unwrap();
        assert_eq!(r.u32s(b).unwrap(), vec![5, 0, 9]);
        let c = r.section("c").unwrap();
        assert_eq!(c.bits, 4);
        // 3 payload bytes padded to 4, then two i32s
        assert_eq!(c.len, 4 + 8);
        assert_eq!(&r.bytes(c)[..4], &[0xAB, 0xCD, 0x01, 0x00]);
        assert_eq!(le_i32s(&r.bytes(c)[4..]).unwrap(), vec![-7, 7]);
        assert!(r.section("missing").is_none());
    }

    #[test]
    fn rejects_bad_magic_and_future_version() {
        let good = sample();
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        assert!(ArtifactReader::from_bytes(bad).is_err());
        let mut newer = good.clone();
        newer[8..12].copy_from_slice(&(FORMAT_VERSION + 1).to_le_bytes());
        let err = ArtifactReader::from_bytes(newer).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn rejects_corruption_everywhere() {
        let good = sample();
        // header byte
        let mut b = good.clone();
        b[HEAD_LEN + 2] ^= 0x01;
        assert!(ArtifactReader::from_bytes(b).is_err());
        // a payload byte inside section "a"
        let r = ArtifactReader::from_bytes(good.clone()).unwrap();
        let off = r.section("a").unwrap().offset;
        let mut b = good.clone();
        b[off + 1] ^= 0x40;
        let err = ArtifactReader::from_bytes(b).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
        // truncation
        let b = good[..good.len() - 5].to_vec();
        assert!(ArtifactReader::from_bytes(b).is_err());
        // empty file
        assert!(ArtifactReader::from_bytes(Vec::new()).is_err());
    }

    #[test]
    fn section_table_reads_from_file_ends_only() {
        let path = std::env::temp_dir().join("perq_secs_test.perq");
        std::fs::write(&path, sample()).unwrap();
        let (v, secs) = read_section_table(&path).unwrap();
        assert_eq!(v, FORMAT_VERSION);
        assert_eq!(secs.len(), 3);
        let c = secs.iter().find(|s| s.name == "c").unwrap();
        assert_eq!((c.kind.as_str(), c.bits, c.len), ("qmat", 4, 12));
        // a truncated file is rejected by the trailing magic / bounds
        let full = sample();
        std::fs::write(&path, &full[..full.len() - 3]).unwrap();
        assert!(read_section_table(&path).is_err());
    }

    #[test]
    fn crc32_known_vector() {
        // the canonical IEEE test vector
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    /// Rebuild `sample()` with its footer JSON replaced, recomputing the
    /// trailer (flen + fcrc + magic) so only the forged fields can fail
    /// validation — this exercises the extent checks, not the CRCs.
    fn with_forged_footer(edit: impl Fn(&mut SectionDesc)) -> Vec<u8> {
        let good = sample();
        let r = ArtifactReader::from_bytes(good.clone()).unwrap();
        let mut secs = r.sections().to_vec();
        for s in &mut secs {
            edit(s);
        }
        let n = good.len();
        let old_flen = u32_at(&good, n - TRAILER_LEN) as usize;
        let fstart = n - TRAILER_LEN - old_flen;
        let mut forged = good[..fstart].to_vec();
        let fjson = json::dump(&sections_to_json(&secs));
        forged.extend_from_slice(fjson.as_bytes());
        forged.extend_from_slice(&(fjson.len() as u32).to_le_bytes());
        forged.extend_from_slice(&crc32(fjson.as_bytes()).to_le_bytes());
        forged.extend_from_slice(MAGIC);
        forged
    }

    #[test]
    fn rejects_maximal_section_extents() {
        // JSON numbers travel as f64, so use exactly-representable
        // near-maximal values: 2^63 survives the round-trip bit-exactly.
        const HUGE: usize = 1usize << 63;
        // offset + len wraps usize without checked_add
        let b = with_forged_footer(|s| {
            s.offset = HUGE;
            s.len = HUGE;
        });
        let err = ArtifactReader::from_bytes(b).unwrap_err().to_string();
        assert!(err.contains("extent overflows"), "{err}");
        // huge offset alone: no wrap, but far outside the payload area
        let b = with_forged_footer(|s| s.offset = HUGE);
        let err = ArtifactReader::from_bytes(b).unwrap_err().to_string();
        assert!(err.contains("outside the payload area"), "{err}");
        // huge len alone: end lands past the footer
        let b = with_forged_footer(|s| s.len = HUGE);
        let err = ArtifactReader::from_bytes(b).unwrap_err().to_string();
        assert!(err.contains("outside the payload area"), "{err}");
    }

    #[test]
    fn extent_arithmetic_rejects_wraparound() {
        // the pure helpers the reader is built on — the Kani harness
        // proves these total; this pins the boundary behavior in tier-1
        assert_eq!(extent::min_file_len(0), Some(HEAD_LEN + TRAILER_LEN));
        assert_eq!(extent::min_file_len(usize::MAX), None);
        assert_eq!(extent::section_end(usize::MAX, 1), None);
        assert_eq!(extent::section_end(7, 9), Some(16));
        // footer exactly filling the payload area is accepted…
        assert_eq!(extent::footer_start(100, 10, 100 - TRAILER_LEN - HEAD_LEN - 10), Some(30));
        // …one byte more is not, and wraparound inputs are rejected
        assert_eq!(extent::footer_start(100, 10, 100 - TRAILER_LEN - HEAD_LEN - 9), None);
        assert_eq!(extent::footer_start(100, usize::MAX, 4), None);
        assert_eq!(extent::footer_start(100, 4, usize::MAX), None);
        assert_eq!(extent::footer_start(10, 0, 0), None); // file smaller than framing
    }
}
