//! Deployment artifacts — the quantize-once / serve-many half of the
//! public API.
//!
//! The paper's deployment story (Remark 4.2 / Fig 7) is that permutations
//! and rotations are merged into the weights *offline*, so inference
//! carries zero extra cost. This module makes that offline product a
//! first-class on-disk object: a versioned binary `.perq` artifact holding
//! everything a serving fleet needs to come up in milliseconds — packed
//! INT4/INT8 weights (`tensor::qmat::QuantMat` payloads + per-channel
//! scales + column sums), merged f32 weights for the unpacked sites, the
//! R̃3 rotation plan, the fused per-layer permutations (provenance), the
//! model config, and the pipeline provenance (spec label, seed, calibration
//! size) — and *no* calibration state. Calibration, permutation search,
//! and rounding stay behind `coordinator::Pipeline`; serving and eval
//! accept a loaded [`DeployedModel`] and never touch them.
//!
//! ```no_run
//! use std::path::Path;
//! use perq::prelude::*;
//!
//! // offline, once:
//! let bundle = ModelBundle::synthetic("llama_np2").unwrap();
//! let engine = Engine::native_ephemeral();
//! let spec = perq::coordinator::presets::perq_star(32, Format::Int4);
//! let qm = Pipeline::new(spec).quantize_with_engine(&bundle, &engine).unwrap();
//! qm.save(Path::new("llama_np2.perq")).unwrap();
//!
//! // serving fleet, many times (no calibration, ~ms startup):
//! let dm = DeployedModel::load(Path::new("llama_np2.perq")).unwrap();
//! let opts = perq::coordinator::server::ServeOptions::new(
//!     std::time::Duration::from_millis(5), 4);
//! let server = dm.serve(opts).unwrap();
//! # drop(server);
//! ```
//!
//! Header schema (JSON, see `artifact` for the container layout):
//! `model`, `label`, `config` (the `meta.json` config shape —
//! `ModelConfig::from_meta` parses it directly), `graph`
//! (kind/r3_block/format), `names` (canonical weight order), `shapes`
//! (original npy shapes), `provenance` (spec label, seed, writer version,
//! mass balance, calibration tokens). Sections: `w:<name>` dense f32
//! tensors, `q:<name>` packed integer twins, `rot3` the R̃3 plan matrix,
//! `perm:l<i>` fused per-layer permutations.
//!
//! Guarantees: payloads round-trip bit-exactly (raw little-endian f32 /
//! integer bytes), so a loaded model scores bit-identically to the
//! in-process `QuantizedModel` it was saved from — asserted end to end by
//! rust/tests/deploy_roundtrip.rs.

pub mod artifact;

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Duration;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::backend::{ExecBackend, ForwardGraph, NativeBackend};
use crate::coordinator::server::{InferenceServer, ServeOptions};
use crate::data::corpus::Source;
use crate::eval::perplexity::{evaluate_with, EvalResult};
use crate::hadamard::BlockRotator;
use crate::model::config::ModelConfig;
use crate::model::weights::WeightSet;
use crate::quant::Format;
use crate::tensor::{Mat, QuantMat};
use crate::util::json::Json;

use self::artifact::{ArtifactReader, ArtifactWriter};

/// Where an artifact came from — carried verbatim in the header so a
/// server fleet can answer "what exactly is this file?" without the
/// pipeline that built it.
#[derive(Clone, Debug)]
pub struct Provenance {
    /// pipeline seed (calibration batches + permutation search)
    pub seed: u64,
    /// the `PipelineSpec` label that produced the weights
    pub spec: String,
    /// writer identification, e.g. "perq 0.2.0"
    pub writer: String,
    /// permutation mass-balance diagnostic at quantize time
    pub mass_balance: f64,
    /// calibration tokens consumed by the offline stages
    pub calib_tokens: usize,
}

/// A model loaded from (or destined for) a `.perq` artifact: everything
/// serving needs, nothing calibration needs. Accepted directly by
/// [`NativeBackend::from_deployed`], [`InferenceServer::start_deployed`],
/// and `eval::perplexity::evaluate_deployed`.
pub struct DeployedModel {
    pub model: String,
    /// the pipeline label, e.g. "massdiff+quarot(b32)+qronos@int4"
    pub label: String,
    pub cfg: ModelConfig,
    pub ws: WeightSet,
    pub graph: ForwardGraph,
    /// fused per-layer P3 permutations (already merged into `ws`;
    /// provenance and re-export only)
    pub perms: Vec<Vec<u32>>,
    pub provenance: Provenance,
    /// container format version the artifact was read with
    pub version: u32,
}

impl DeployedModel {
    /// Load and fully validate a `.perq` artifact (checksums, version,
    /// shapes). Rejects artifacts written by a newer format version.
    pub fn load(path: &Path) -> Result<DeployedModel> {
        load_model(path)
    }

    /// A pure-Rust execution backend over the deployed weights.
    pub fn backend(&self) -> Result<NativeBackend> {
        NativeBackend::from_deployed(self)
    }

    /// Stand up the batching inference server on this model —
    /// `opts.num_workers` native replicas under `opts`' serving policy
    /// (queue capacity, deadlines, drain timeout), zero calibration work.
    pub fn serve(&self, opts: ServeOptions) -> Result<InferenceServer> {
        InferenceServer::start_deployed(self, opts)
    }

    /// Perplexity over the held-out split of `source`, served from the
    /// artifact weights as-is.
    pub fn evaluate(&self, source: Source, n_tokens: usize) -> Result<EvalResult> {
        let mut be = self.backend()?;
        let mut score = move |tokens: &[i32]| be.score(tokens);
        evaluate_with(&mut score, &self.cfg, source, n_tokens)
    }

    /// Greedy token generation straight from the deployed weights: prefill
    /// the prompt into a one-slot execution session, then `decode_step`
    /// until `max_new_tokens` are produced — the stateful serving workload
    /// (quantized KV cache, per-token R̃3 rotation) behind `perq generate`.
    pub fn generate(&self, prompt: &[i32], max_new_tokens: usize) -> Result<GenerateResult> {
        use std::time::Instant;
        ensure!(!prompt.is_empty(), "generation needs a non-empty prompt");
        ensure!(max_new_tokens >= 1, "generation needs max_new_tokens >= 1");
        ensure!(
            prompt.len() + max_new_tokens <= self.cfg.seq_len,
            "prompt ({}) + max_new_tokens ({max_new_tokens}) exceeds seq_len ({})",
            prompt.len(),
            self.cfg.seq_len
        );
        let v = self.cfg.vocab;
        let mut be = self.backend()?;
        let sid = be.begin(1)?;
        let t0 = Instant::now();
        let logits = be.prefill_slots(sid, &[0], prompt)?;
        let prefill_s = t0.elapsed().as_secs_f64();
        let mut tokens = vec![crate::backend::greedy_argmax(
            &logits[(prompt.len() - 1) * v..prompt.len() * v],
        )];
        let t1 = Instant::now();
        let mut step = Vec::new();
        while tokens.len() < max_new_tokens {
            let last = *tokens.last().expect("seeded above");
            be.decode_step_into(sid, &[last], &mut step)?;
            tokens.push(crate::backend::greedy_argmax(&step[..v]));
        }
        let decode_s = t1.elapsed().as_secs_f64();
        be.end(sid)?;
        Ok(GenerateResult { tokens, prefill_s, decode_s })
    }

    /// Bytes held by the deployed weights (packed + dense).
    pub fn weight_bytes(&self) -> usize {
        self.ws.weight_bytes()
    }
}

/// The output of [`DeployedModel::generate`].
#[derive(Clone, Debug)]
pub struct GenerateResult {
    /// greedily sampled tokens (prompt excluded)
    pub tokens: Vec<i32>,
    /// prompt prefill wall time (seconds)
    pub prefill_s: f64,
    /// decode-loop wall time (seconds)
    pub decode_s: f64,
}

impl GenerateResult {
    /// Decode throughput: tokens produced by the decode loop per second
    /// (the first token comes from prefill, so it is excluded).
    pub fn decode_tok_per_s(&self) -> f64 {
        let decode_tokens = self.tokens.len().saturating_sub(1);
        if self.decode_s > 0.0 {
            decode_tokens as f64 / self.decode_s
        } else {
            0.0
        }
    }
}

/// Cheap summary of a `.perq` file — read without touching any payload
/// section (the `perq models` listing path): the header JSON plus the
/// footer section table, so operators can size replicas (sequence budget,
/// layer count, resident weight bytes) without loading the artifact.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub model: String,
    pub label: String,
    /// quantization format name ("int4", "int8", "fp4", …)
    pub format: String,
    /// forward-graph kind ("merged", "online", "fp")
    pub graph_kind: String,
    pub r3_block: usize,
    pub version: u32,
    /// maximum positions per sequence slot (KV-cache capacity)
    pub seq_len: usize,
    pub n_layers: usize,
    /// bytes of packed low-bit weight sections (`q:*` payloads)
    pub packed_bytes: u64,
    /// bytes of dense f32 weight sections (`w:*` payloads)
    pub dense_bytes: u64,
}

/// Where `perq export` writes the rotation-quality telemetry report for an
/// artifact: `<artifact>.telemetry.json` beside the `.perq` file.
pub fn telemetry_path(artifact: &Path) -> std::path::PathBuf {
    let mut s = artifact.as_os_str().to_os_string();
    s.push(".telemetry.json");
    std::path::PathBuf::from(s)
}

/// Load the telemetry sidecar written beside an artifact, if present and
/// parseable. `None` covers artifacts exported before telemetry existed.
pub fn load_telemetry(artifact: &Path) -> Option<crate::obs::telemetry::RotationReport> {
    let p = telemetry_path(artifact);
    if !p.exists() {
        return None;
    }
    crate::obs::telemetry::RotationReport::load(&p).ok()
}

/// Read only the header and footer of a `.perq` artifact and summarize it.
pub fn inspect(path: &Path) -> Result<ArtifactInfo> {
    let (version, header) = artifact::read_header(path)?;
    let graph = graph_from_json(
        header
            .get("graph")
            .ok_or_else(|| anyhow!("artifact header carries no graph description"))?,
    )?;
    let (graph_kind, r3_block) = match &graph {
        ForwardGraph::Fp => ("fp", 0),
        ForwardGraph::Merged { r3_block, .. } => ("merged", *r3_block),
        ForwardGraph::Online { .. } => ("online", 32),
    };
    let cfg = ModelConfig::from_meta(&header).context("parsing artifact model config")?;
    // payload sizes come from the footer table (two end-of-file reads, no
    // payload IO); sum the packed vs dense weight sections
    let (_, sections) = artifact::read_section_table(path)?;
    let mut packed_bytes = 0u64;
    let mut dense_bytes = 0u64;
    for s in &sections {
        if s.name.starts_with("q:") {
            packed_bytes += s.len as u64;
        } else if s.name.starts_with("w:") {
            dense_bytes += s.len as u64;
        }
    }
    let str_field = |k: &str| -> String {
        header
            .get(k)
            .and_then(|v| v.as_str())
            .unwrap_or("?")
            .to_string()
    };
    Ok(ArtifactInfo {
        model: str_field("model"),
        label: str_field("label"),
        format: graph.format().name().to_string(),
        graph_kind: graph_kind.to_string(),
        r3_block,
        version,
        seq_len: cfg.seq_len,
        n_layers: cfg.n_layers,
        packed_bytes,
        dense_bytes,
    })
}

// ------------------------------------------------------------ write path

/// Serialize a quantized model as a `.perq` deployment artifact.
/// (`QuantizedModel::save` is the usual entry point; this free function
/// exists so tests and tools can write hand-built weight sets.)
pub fn write_model(path: &Path, model: &str, label: &str, cfg: &ModelConfig,
                   ws: &WeightSet, graph: &ForwardGraph, perms: &[Vec<u32>],
                   prov: &Provenance) -> Result<()> {
    for key in ws.tensors.keys().chain(ws.packed.keys()) {
        ensure!(
            ws.names.iter().any(|n| n == key),
            "weight {key} is not in the canonical name order — cannot serialize"
        );
    }
    for name in &ws.names {
        ensure!(
            ws.tensors.contains_key(name) || ws.packed.contains_key(name),
            "weight {name} has neither a dense nor a packed payload — cannot serialize"
        );
    }
    let header = header_json(model, label, cfg, ws, graph, prov)?;
    let file = std::fs::File::create(path)
        .with_context(|| format!("creating artifact {path:?}"))?;
    let mut w = ArtifactWriter::new(std::io::BufWriter::new(file), &header)?;
    for name in &ws.names {
        if let Some(m) = ws.tensors.get(name) {
            w.begin_section(&format!("w:{name}"), "f32", &[m.rows, m.cols], 0)?;
            w.write_f32s(&m.data)?;
            w.end_section()?;
        }
        if let Some(q) = ws.packed.get(name) {
            w.begin_section(&format!("q:{name}"), "qmat", &[q.rows, q.cols], q.bits)?;
            w.write_bytes(q.payload_bytes())?;
            w.pad_section(4)?;
            w.write_f32s(&q.scales)?;
            w.write_i32s(q.colsums())?;
            w.end_section()?;
        }
    }
    if let ForwardGraph::Merged { r3_block, .. } = graph {
        if *r3_block > 1 {
            let m = BlockRotator::hadamard(*r3_block)?.matrix()?;
            w.begin_section("rot3", "f32", &[m.rows, m.cols], 0)?;
            w.write_f32s(&m.data)?;
            w.end_section()?;
        }
    }
    for (l, p) in perms.iter().enumerate() {
        w.begin_section(&format!("perm:l{l}"), "u32", &[p.len()], 0)?;
        w.write_u32s(p)?;
        w.end_section()?;
    }
    w.finish()
        .with_context(|| format!("finalizing artifact {path:?}"))
}

fn header_json(model: &str, label: &str, cfg: &ModelConfig, ws: &WeightSet,
               graph: &ForwardGraph, prov: &Provenance) -> Result<Json> {
    let mut h = BTreeMap::new();
    h.insert("artifact".to_string(), Json::Str("perq deployed model".to_string()));
    h.insert("model".to_string(), Json::Str(model.to_string()));
    h.insert("label".to_string(), Json::Str(label.to_string()));
    h.insert("config".to_string(), config_json(cfg));
    h.insert("graph".to_string(), graph_to_json(graph));
    h.insert(
        "names".to_string(),
        Json::Arr(ws.names.iter().map(|n| Json::Str(n.clone())).collect()),
    );
    let mut shapes = BTreeMap::new();
    for name in &ws.names {
        shapes.insert(
            name.clone(),
            Json::Arr(ws.shape(name).iter().map(|&d| Json::Num(d as f64)).collect()),
        );
    }
    h.insert("shapes".to_string(), Json::Obj(shapes));
    let mut p = BTreeMap::new();
    p.insert("seed".to_string(), Json::Num(prov.seed as f64));
    p.insert("spec".to_string(), Json::Str(prov.spec.clone()));
    p.insert("writer".to_string(), Json::Str(prov.writer.clone()));
    p.insert("mass_balance".to_string(), Json::Num(prov.mass_balance));
    p.insert("calib_tokens".to_string(), Json::Num(prov.calib_tokens as f64));
    h.insert("provenance".to_string(), Json::Obj(p));
    Ok(Json::Obj(h))
}

fn config_json(cfg: &ModelConfig) -> Json {
    let mut c = BTreeMap::new();
    c.insert("name".to_string(), Json::Str(cfg.name.clone()));
    c.insert("n_layers".to_string(), Json::Num(cfg.n_layers as f64));
    c.insert("d_model".to_string(), Json::Num(cfg.d_model as f64));
    c.insert("n_heads".to_string(), Json::Num(cfg.n_heads as f64));
    c.insert("d_ffn".to_string(), Json::Num(cfg.d_ffn as f64));
    c.insert("vocab".to_string(), Json::Num(cfg.vocab as f64));
    c.insert("seq_len".to_string(), Json::Num(cfg.seq_len as f64));
    c.insert("batch".to_string(), Json::Num(cfg.batch as f64));
    c.insert(
        "block_sizes".to_string(),
        Json::Arr(cfg.block_sizes.iter().map(|&b| Json::Num(b as f64)).collect()),
    );
    Json::Obj(c)
}

fn graph_to_json(graph: &ForwardGraph) -> Json {
    let mut g = BTreeMap::new();
    match graph {
        ForwardGraph::Fp => {
            g.insert("kind".to_string(), Json::Str("fp".to_string()));
        }
        ForwardGraph::Merged { r3_block, format } => {
            g.insert("kind".to_string(), Json::Str("merged".to_string()));
            g.insert("r3_block".to_string(), Json::Num(*r3_block as f64));
            g.insert("format".to_string(), Json::Str(format.name().to_string()));
        }
        ForwardGraph::Online { format } => {
            g.insert("kind".to_string(), Json::Str("online".to_string()));
            g.insert("format".to_string(), Json::Str(format.name().to_string()));
        }
    }
    Json::Obj(g)
}

fn graph_from_json(j: &Json) -> Result<ForwardGraph> {
    let kind = j
        .get("kind")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("graph description missing kind"))?;
    let format = || -> Result<Format> {
        let name = j
            .get("format")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("graph description missing format"))?;
        Format::parse(name).ok_or_else(|| anyhow!("unknown graph format {name:?}"))
    };
    match kind {
        "fp" => Ok(ForwardGraph::Fp),
        "merged" => Ok(ForwardGraph::Merged {
            r3_block: j
                .get("r3_block")
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("merged graph missing r3_block"))?,
            format: format()?,
        }),
        "online" => Ok(ForwardGraph::Online { format: format()? }),
        k => bail!("unknown graph kind {k:?}"),
    }
}

// ------------------------------------------------------------- load path

/// Load a `.perq` artifact into a [`DeployedModel`]. Every section CRC,
/// the format version, and all shape/length invariants are validated
/// before any weight is handed to a backend.
pub fn load_model(path: &Path) -> Result<DeployedModel> {
    let r = ArtifactReader::open(path)?;
    model_from_reader(&r).with_context(|| format!("decoding artifact {path:?}"))
}

fn model_from_reader(r: &ArtifactReader) -> Result<DeployedModel> {
    let h = &r.header;
    let model = h
        .get("model")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("artifact header missing model"))?
        .to_string();
    let label = h
        .get("label")
        .and_then(|v| v.as_str())
        .unwrap_or("")
        .to_string();
    let cfg = ModelConfig::from_meta(h).context("parsing artifact model config")?;
    let graph = graph_from_json(
        h.get("graph")
            .ok_or_else(|| anyhow!("artifact header missing graph"))?,
    )?;
    let names: Vec<String> = h
        .get("names")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("artifact header missing weight names"))?
        .iter()
        .filter_map(|v| v.as_str().map(|s| s.to_string()))
        .collect();
    ensure!(!names.is_empty(), "artifact header lists no weights");
    let shapes_j = h
        .get("shapes")
        .and_then(|v| v.as_obj())
        .ok_or_else(|| anyhow!("artifact header missing weight shapes"))?;

    let mut tensors = BTreeMap::new();
    let mut shapes = BTreeMap::new();
    let mut packed = BTreeMap::new();
    for name in &names {
        let shape: Vec<usize> = shapes_j
            .get(name)
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
            .ok_or_else(|| anyhow!("artifact header missing shape for {name}"))?;
        let (rows, cols) = match shape.as_slice() {
            [n] => (1usize, *n),
            [r, c] => (*r, *c),
            _ => bail!("weight {name}: unsupported rank {}", shape.len()),
        };
        shapes.insert(name.clone(), shape);
        let mut have = false;
        if let Some(s) = r.section(&format!("w:{name}")) {
            ensure!(s.kind == "f32", "weight {name}: unexpected section kind {}", s.kind);
            ensure!(
                s.dims == [rows, cols],
                "weight {name}: section dims {:?} disagree with header shape ({rows}x{cols})",
                s.dims
            );
            // header shapes are untrusted: checked product, never a wrap
            let want = rows
                .checked_mul(cols)
                .ok_or_else(|| anyhow!("weight {name}: shape {rows}x{cols} overflows"))?;
            let data = r.f32s(s)?;
            ensure!(
                data.len() == want,
                "weight {name}: payload holds {} values, shape needs {want}",
                data.len()
            );
            tensors.insert(name.clone(), Mat::from_vec(rows, cols, data));
            have = true;
        }
        if let Some(s) = r.section(&format!("q:{name}")) {
            ensure!(s.kind == "qmat", "weight {name}: unexpected section kind {}", s.kind);
            ensure!(
                s.dims == [rows, cols],
                "packed weight {name}: section dims {:?} disagree with header shape ({rows}x{cols})",
                s.dims
            );
            let bytes = r.bytes(s);
            let plen = QuantMat::payload_len(rows, cols, s.bits)?;
            // payload padded to f32 alignment, then scales + colsums;
            // all arithmetic checked — the shape is untrusted input
            let want = plen
                .checked_add(3)
                .map(|v| v / 4 * 4)
                .and_then(|spos| cols.checked_mul(8).and_then(|m| spos.checked_add(m)))
                .ok_or_else(|| {
                    anyhow!("packed weight {name}: {rows}x{cols} section size overflows")
                })?;
            let spos = want - 8 * cols;
            ensure!(
                s.len == want,
                "packed weight {name}: section length {} disagrees with {rows}x{cols} int{}",
                s.len,
                s.bits
            );
            let payload = bytes[..plen].to_vec();
            let scales = artifact::le_f32s(&bytes[spos..spos + 4 * cols])?;
            let colsum = artifact::le_i32s(&bytes[spos + 4 * cols..])?;
            packed.insert(
                name.clone(),
                QuantMat::from_parts(rows, cols, s.bits, payload, scales, colsum)?,
            );
            have = true;
        }
        ensure!(have, "artifact carries no payload for weight {name}");
    }
    let ws = WeightSet { names, tensors, shapes, packed };

    if let ForwardGraph::Merged { r3_block, .. } = &graph {
        ensure!(
            *r3_block >= 1 && cfg.d_ffn % r3_block == 0,
            "artifact R3 block {} must divide d_ffn {}",
            r3_block,
            cfg.d_ffn
        );
        if *r3_block > 1 {
            if let Some(s) = r.section("rot3") {
                // the plan is reconstructed deterministically from the block
                // size; the stored matrix is an integrity cross-check
                let got = r.f32s(s)?;
                let want = BlockRotator::hadamard(*r3_block)?.matrix()?;
                ensure!(
                    got == want.data,
                    "artifact R3 rotation plan disagrees with block size {r3_block}"
                );
            }
        }
    }

    let mut perms = Vec::new();
    for l in 0..cfg.n_layers {
        match r.section(&format!("perm:l{l}")) {
            Some(s) => {
                let p = r.u32s(s)?;
                ensure!(
                    p.len() == cfg.d_ffn,
                    "fused permutation for layer {l} has {} entries, d_ffn is {}",
                    p.len(),
                    cfg.d_ffn
                );
                perms.push(p);
            }
            None => break,
        }
    }

    let prov = h.get("provenance");
    let p_str = |k: &str| -> String {
        prov.and_then(|p| p.get(k))
            .and_then(|v| v.as_str())
            .unwrap_or("")
            .to_string()
    };
    let p_num = |k: &str| -> f64 {
        prov.and_then(|p| p.get(k)).and_then(|v| v.as_f64()).unwrap_or(0.0)
    };
    let provenance = Provenance {
        seed: p_num("seed") as u64,
        spec: p_str("spec"),
        writer: p_str("writer"),
        mass_balance: p_num("mass_balance"),
        calib_tokens: p_num("calib_tokens") as usize,
    };

    Ok(DeployedModel {
        model,
        label,
        cfg,
        ws,
        graph,
        perms,
        provenance,
        version: r.version,
    })
}

#[cfg(test)]
mod tests {
    //! Unit coverage of the JSON schema helpers; end-to-end save→load→serve
    //! bit-identity lives in rust/tests/deploy_roundtrip.rs.

    use super::*;

    #[test]
    fn graph_json_round_trips() {
        for g in [
            ForwardGraph::Fp,
            ForwardGraph::Merged { r3_block: 32, format: Format::Int4 },
            ForwardGraph::Merged { r3_block: 16, format: Format::Int8 },
            ForwardGraph::Online { format: Format::Fp4 },
        ] {
            let j = graph_to_json(&g);
            assert_eq!(graph_from_json(&j).unwrap(), g);
        }
        assert!(graph_from_json(&Json::Obj(Default::default())).is_err());
    }

    #[test]
    fn telemetry_sidecar_path_and_absence() {
        let p = telemetry_path(Path::new("/tmp/m.perq"));
        assert_eq!(p, Path::new("/tmp/m.perq.telemetry.json"));
        assert!(load_telemetry(Path::new("/tmp/does_not_exist.perq")).is_none());
    }

    #[test]
    fn config_json_parses_back() {
        let cfg = crate::model::bundle::synthetic_config("llama_np2").unwrap();
        let mut h = BTreeMap::new();
        h.insert("config".to_string(), config_json(&cfg));
        let back = ModelConfig::from_meta(&Json::Obj(h)).unwrap();
        assert_eq!(back.name, cfg.name);
        assert_eq!(back.d_ffn, cfg.d_ffn);
        assert_eq!(back.block_sizes, cfg.block_sizes);
    }
}
