//! Zero-shot probe suite — the substitution for the paper's LightEval
//! reasoning tasks (ARC-C/E, PIQA, Winogrande, HellaSwag; see DESIGN.md §3).
//!
//! Five probes measure next-token top-1 accuracy under distinct conditions,
//! standing in for "downstream accuracy that is not perplexity":
//!   wiki-next     — in-distribution next-char accuracy
//!   c4-next       — cross-source generalization (calibrated on wiki)
//!   fineweb-next  — cross-source, heavier bigram structure
//!   word-start    — accuracy on positions right after a space (hard:
//!                   requires word-level context, the "reasoning" analog)
//!   word-body     — accuracy inside words (easy, syllable structure)
//! The reported average plays the role of the paper's 0-shot column.
//!
//! Like perplexity, the probe loop is backend-agnostic: it drives the
//! scoring closure from `backend::scorer` (AOT artifact or NativeBackend).

use anyhow::Result;

use crate::backend::{self, ForwardGraph};
use crate::data::corpus::{self, Source, Split};
use crate::model::config::ModelConfig;
use crate::model::weights::WeightSet;
use crate::runtime::Engine;

#[derive(Clone, Debug)]
pub struct ZeroShotResult {
    pub task_names: Vec<&'static str>,
    pub accuracies: Vec<f64>,
}

impl ZeroShotResult {
    pub fn average(&self) -> f64 {
        100.0 * self.accuracies.iter().sum::<f64>() / self.accuracies.len() as f64
    }
}

struct ProbeAcc {
    correct: usize,
    total: usize,
}

/// Evaluate the probe suite for graph `graph` on the engine's backend.
pub fn evaluate_zeroshot(engine: &Engine, model: &str, cfg: &ModelConfig,
                         ws: &WeightSet, graph: &ForwardGraph,
                         n_tokens: usize) -> Result<ZeroShotResult> {
    let mut score = backend::scorer(engine, model, cfg, ws, graph)?;
    evaluate_zeroshot_with(&mut *score, cfg, n_tokens)
}

/// Backend-agnostic probe core; `score` takes `batch * seq_len` tokens.
pub fn evaluate_zeroshot_with(score: &mut dyn FnMut(&[i32]) -> Result<Vec<f32>>,
                              cfg: &ModelConfig,
                              n_tokens: usize) -> Result<ZeroShotResult> {
    let (b, t, v) = (cfg.batch, cfg.seq_len, cfg.vocab);
    let space_id = corpus::char_to_id(b' ').unwrap();
    let mut accs: Vec<ProbeAcc> = (0..5).map(|_| ProbeAcc { correct: 0, total: 0 }).collect();

    for (src_idx, source) in [Source::Wiki, Source::C4, Source::Fineweb].iter().enumerate() {
        let toks = corpus::token_stream(*source, Split::Test, n_tokens.max(b * t + 1));
        let n_windows = ((toks.len() - 1) / t).min(n_tokens / t);
        let mut window = 0usize;
        while window < n_windows {
            let real = (n_windows - window).min(b);
            let mut tokens: Vec<i32> = Vec::with_capacity(b * t);
            for i in 0..b {
                let w = window + i.min(real - 1);
                tokens.extend(toks[w * t..(w + 1) * t].iter().map(|&x| x as i32));
            }
            let data = score(&tokens)?;
            anyhow::ensure!(data.len() == b * t * v, "logit shape mismatch");
            for i in 0..real {
                let w = window + i;
                for j in 0..t {
                    let row = &data[(i * t + j) * v..(i * t + j + 1) * v];
                    let pred = row
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .unwrap()
                        .0 as u16;
                    let tgt = toks[w * t + j + 1];
                    let prev = toks[w * t + j];
                    let hit = (pred == tgt) as usize;
                    // probes 0-2: per-source next-token accuracy
                    accs[src_idx].correct += hit;
                    accs[src_idx].total += 1;
                    if *source == Source::Wiki {
                        if prev == space_id {
                            accs[3].correct += hit; // word-start (hard)
                            accs[3].total += 1;
                        } else {
                            accs[4].correct += hit; // word-body (easy)
                            accs[4].total += 1;
                        }
                    }
                }
            }
            window += real;
        }
    }
    Ok(ZeroShotResult {
        task_names: vec!["wiki-next", "c4-next", "fineweb-next", "word-start", "word-body"],
        accuracies: accs
            .iter()
            .map(|a| if a.total == 0 { 0.0 } else { a.correct as f64 / a.total as f64 })
            .collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn average_is_percentage() {
        let r = ZeroShotResult {
            task_names: vec!["a", "b"],
            accuracies: vec![0.5, 0.7],
        };
        assert!((r.average() - 60.0).abs() < 1e-9);
    }

    #[test]
    fn uniform_scorer_scores_all_probes() {
        let j = crate::util::json::parse(
            r#"{"config": {"name": "m", "n_layers": 1, "d_model": 8,
                "n_heads": 1, "d_ffn": 16, "vocab": 32, "seq_len": 16,
                "batch": 2, "block_sizes": [1]}}"#,
        )
        .unwrap();
        let cfg = ModelConfig::from_meta(&j).unwrap();
        let mut score = |_tokens: &[i32]| -> Result<Vec<f32>> { Ok(vec![0.0f32; 2 * 16 * 32]) };
        let r = evaluate_zeroshot_with(&mut score, &cfg, 128).unwrap();
        assert_eq!(r.task_names.len(), 5);
        // argmax of uniform logits is token 0 — accuracy small but defined
        assert!(r.accuracies.iter().all(|&a| (0.0..=1.0).contains(&a)));
        assert!(r.accuracies[0] >= 0.0);
    }
}
