//! Zero-shot probe suite — the substitution for the paper's LightEval
//! reasoning tasks (ARC-C/E, PIQA, Winogrande, HellaSwag; see DESIGN.md §3).
//!
//! Five probes measure next-token top-1 accuracy under distinct conditions,
//! standing in for "downstream accuracy that is not perplexity":
//!   wiki-next     — in-distribution next-char accuracy
//!   c4-next       — cross-source generalization (calibrated on wiki)
//!   fineweb-next  — cross-source, heavier bigram structure
//!   word-start    — accuracy on positions right after a space (hard:
//!                   requires word-level context, the "reasoning" analog)
//!   word-body     — accuracy inside words (easy, syllable structure)
//! The reported average plays the role of the paper's 0-shot column.

use anyhow::Result;

use crate::data::corpus::{self, Source, Split};
use crate::model::config::ModelConfig;
use crate::model::weights::WeightSet;
use crate::runtime::engine::{self, Engine};

#[derive(Clone, Debug)]
pub struct ZeroShotResult {
    pub task_names: Vec<&'static str>,
    pub accuracies: Vec<f64>,
}

impl ZeroShotResult {
    pub fn average(&self) -> f64 {
        100.0 * self.accuracies.iter().sum::<f64>() / self.accuracies.len() as f64
    }
}

struct ProbeAcc {
    correct: usize,
    total: usize,
}

/// Evaluate the probe suite through artifact `tag` with the given extras.
pub fn evaluate_zeroshot(engine: &Engine, model: &str, cfg: &ModelConfig,
                         ws: &WeightSet, tag: &str,
                         extras: &super::perplexity::ExtraInputs,
                         n_tokens: usize) -> Result<ZeroShotResult> {
    let (b, t, v) = (cfg.batch, cfg.seq_len, cfg.vocab);
    let w_lits = engine::weight_literals(ws)?;
    let space_id = corpus::char_to_id(b' ').unwrap();
    let mut accs: Vec<ProbeAcc> = (0..5).map(|_| ProbeAcc { correct: 0, total: 0 }).collect();

    for (src_idx, source) in [Source::Wiki, Source::C4, Source::Fineweb].iter().enumerate() {
        let toks = corpus::token_stream(*source, Split::Test, n_tokens.max(b * t + 1));
        let n_windows = ((toks.len() - 1) / t).min(n_tokens / t);
        let mut window = 0usize;
        while window < n_windows {
            let real = (n_windows - window).min(b);
            let mut tokens: Vec<i32> = Vec::with_capacity(b * t);
            for i in 0..b {
                let w = window + i.min(real - 1);
                tokens.extend(toks[w * t..(w + 1) * t].iter().map(|&x| x as i32));
            }
            let mut inputs = w_lits.clone();
            inputs.push(engine::tokens_literal(&tokens, b, t)?);
            for e in extras {
                inputs.push(super::perplexity::clone_literal_pub(e)?);
            }
            let outs = engine.run(model, tag, &inputs)?;
            let data = engine::literal_to_vec_f32(&outs[0])?;
            for i in 0..real {
                let w = window + i;
                for j in 0..t {
                    let row = &data[(i * t + j) * v..(i * t + j + 1) * v];
                    let pred = row
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .unwrap()
                        .0 as u16;
                    let tgt = toks[w * t + j + 1];
                    let prev = toks[w * t + j];
                    let hit = (pred == tgt) as usize;
                    // probes 0-2: per-source next-token accuracy
                    accs[src_idx].correct += hit;
                    accs[src_idx].total += 1;
                    if *source == Source::Wiki {
                        if prev == space_id {
                            accs[3].correct += hit; // word-start (hard)
                            accs[3].total += 1;
                        } else {
                            accs[4].correct += hit; // word-body (easy)
                            accs[4].total += 1;
                        }
                    }
                }
            }
            window += real;
        }
    }
    Ok(ZeroShotResult {
        task_names: vec!["wiki-next", "c4-next", "fineweb-next", "word-start", "word-body"],
        accuracies: accs
            .iter()
            .map(|a| if a.total == 0 { 0.0 } else { a.correct as f64 / a.total as f64 })
            .collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn average_is_percentage() {
        let r = ZeroShotResult {
            task_names: vec!["a", "b"],
            accuracies: vec![0.5, 0.7],
        };
        assert!((r.average() - 60.0).abs() < 1e-9);
    }
}
