//! Evaluation: perplexity over the held-out synthetic corpus and the
//! zero-shot probe suite (the substitution for LightEval's reasoning
//! tasks — see DESIGN.md §3).

pub mod perplexity;
pub mod zeroshot;

pub use perplexity::{perplexity_from_logits, EvalResult};
