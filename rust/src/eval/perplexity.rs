//! Perplexity evaluation over the held-out test split, streamed through a
//! forward backend in (batch, seq) chunks. The windowing/NLL core is
//! backend-agnostic: it drives a scoring closure built by
//! `backend::scorer`, which executes either the AOT artifact (pjrt) or the
//! pure-Rust `NativeBackend`.

use anyhow::{ensure, Result};

use crate::backend::{self, ForwardGraph};
use crate::data::corpus::{self, Source, Split};
use crate::model::config::ModelConfig;
use crate::model::weights::WeightSet;
use crate::runtime::Engine;
use crate::tensor::Mat;

#[derive(Clone, Debug)]
pub struct EvalResult {
    pub perplexity: f64,
    pub nll: f64,
    pub n_predictions: usize,
}

/// Cross-entropy of next-token predictions from logits (rows = positions
/// of one sequence; evaluates positions 0..t-1 predicting 1..t).
pub fn perplexity_from_logits(logits: &Mat, targets: &[u16]) -> (f64, usize) {
    let t = targets.len();
    debug_assert!(logits.rows >= t);
    let v = logits.cols;
    let mut nll = 0.0f64;
    for (i, &tgt) in targets.iter().enumerate() {
        let row = logits.row(i);
        let mx = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x)) as f64;
        let mut lse = 0.0f64;
        for j in 0..v {
            lse += ((row[j] as f64) - mx).exp();
        }
        let lse = mx + lse.ln();
        nll += lse - row[tgt as usize] as f64;
    }
    (nll, t)
}

/// Stream `n_tokens` of (source, test) through the engine's backend for
/// graph `graph` and compute perplexity.
pub fn evaluate_stream(engine: &Engine, model: &str, cfg: &ModelConfig,
                       ws: &WeightSet, graph: &ForwardGraph,
                       source: Source, n_tokens: usize) -> Result<EvalResult> {
    let mut score = backend::scorer(engine, model, cfg, ws, graph)?;
    evaluate_with(&mut *score, cfg, source, n_tokens)
}

/// Evaluate a loaded `.perq` deployment artifact through the engine's
/// backend — no calibration or quantization code runs; the artifact
/// weights are served as-is. (For the engine-free native path, see
/// `deploy::DeployedModel::evaluate`.)
pub fn evaluate_deployed(engine: &Engine, dm: &crate::deploy::DeployedModel,
                         source: Source, n_tokens: usize) -> Result<EvalResult> {
    evaluate_stream(engine, &dm.model, &dm.cfg, &dm.ws, &dm.graph, source, n_tokens)
}

/// The backend-agnostic streaming core: non-overlapping windows, batched,
/// tail batches padded with the last real window (padding excluded from
/// the NLL). `score` takes `batch * seq_len` tokens → flat logits.
pub fn evaluate_with(score: &mut dyn FnMut(&[i32]) -> Result<Vec<f32>>,
                     cfg: &ModelConfig, source: Source,
                     n_tokens: usize) -> Result<EvalResult> {
    let (b, t, v) = (cfg.batch, cfg.seq_len, cfg.vocab);
    let toks = corpus::token_stream(source, Split::Test, n_tokens.max(b * t + 1));
    let mut total_nll = 0.0f64;
    let mut total_n = 0usize;
    let n_windows = (toks.len() - 1) / t;
    let mut window = 0usize;
    while window < n_windows {
        let real = (n_windows - window).min(b);
        let mut tokens: Vec<i32> = Vec::with_capacity(b * t);
        for i in 0..b {
            let w = window + i.min(real - 1); // pad with last real window
            tokens.extend(toks[w * t..(w + 1) * t].iter().map(|&x| x as i32));
        }
        let data = score(&tokens)?;
        ensure!(data.len() == b * t * v, "logit shape mismatch");
        for i in 0..real {
            let w = window + i;
            // position j of window w predicts token w*t + j + 1; the final
            // target (w*t + t) exists because n_windows = (len-1)/t.
            let logits = Mat::from_vec(t, v, data[i * t * v..(i + 1) * t * v].to_vec());
            let targets: Vec<u16> = toks[w * t + 1..w * t + t + 1].to_vec();
            let (nll, n) = perplexity_from_logits(&logits, &targets);
            total_nll += nll;
            total_n += n;
        }
        window += real;
    }
    let nll = total_nll / total_n as f64;
    Ok(EvalResult { perplexity: nll.exp(), nll, n_predictions: total_n })
}

/// Clone an xla literal (pjrt builds only — `xla::Literal` has no reliable
/// `Clone`; round-trip through a shape-preserving reshape instead). Used
/// by the artifact integration suite.
#[cfg(feature = "pjrt")]
pub fn clone_literal_pub(l: &xla::Literal) -> Result<xla::Literal> {
    let shape = l.shape().map_err(|e| anyhow::anyhow!("shape: {e:?}"))?;
    match shape {
        xla::Shape::Array(a) => {
            let dims: Vec<i64> = a.dims().to_vec();
            match a.primitive_type() {
                xla::PrimitiveType::F32 => {
                    let v = l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
                    xla::Literal::vec1(&v).reshape(&dims).map_err(|e| anyhow::anyhow!("{e:?}"))
                }
                xla::PrimitiveType::S32 => {
                    let v = l.to_vec::<i32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
                    if dims.is_empty() {
                        Ok(xla::Literal::scalar(v[0]))
                    } else {
                        xla::Literal::vec1(&v)
                            .reshape(&dims)
                            .map_err(|e| anyhow::anyhow!("{e:?}"))
                    }
                }
                t => anyhow::bail!("unsupported literal type {t:?}"),
            }
        }
        s => anyhow::bail!("unsupported literal shape {s:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_logits_give_vocab_ppl() {
        let v = 32;
        let logits = Mat::zeros(10, v);
        let targets: Vec<u16> = (0..10).map(|i| (i % v) as u16).collect();
        let (nll, n) = perplexity_from_logits(&logits, &targets);
        assert_eq!(n, 10);
        let ppl = (nll / n as f64).exp();
        assert!((ppl - v as f64).abs() < 1e-6);
    }

    #[test]
    fn confident_correct_logits_give_low_ppl() {
        let v = 8;
        let mut logits = Mat::zeros(5, v);
        let targets: Vec<u16> = vec![1, 2, 3, 4, 5];
        for (i, &t) in targets.iter().enumerate() {
            *logits.at_mut(i, t as usize) = 20.0;
        }
        let (nll, n) = perplexity_from_logits(&logits, &targets);
        assert!((nll / n as f64).exp() < 1.001);
    }

    #[test]
    fn wrong_confident_logits_give_high_ppl() {
        let v = 8;
        let mut logits = Mat::zeros(3, v);
        for i in 0..3 {
            *logits.at_mut(i, 0) = 30.0;
        }
        let targets: Vec<u16> = vec![1, 1, 1];
        let (nll, n) = perplexity_from_logits(&logits, &targets);
        assert!((nll / n as f64).exp() > 1e8);
    }

    #[test]
    fn evaluate_with_streams_uniform_scorer() {
        // a fake backend producing uniform logits must give ppl = vocab
        let j = crate::util::json::parse(
            r#"{"config": {"name": "m", "n_layers": 1, "d_model": 8,
                "n_heads": 1, "d_ffn": 16, "vocab": 32, "seq_len": 16,
                "batch": 2, "block_sizes": [1]}}"#,
        )
        .unwrap();
        let cfg = ModelConfig::from_meta(&j).unwrap();
        let mut calls = 0usize;
        let mut score = |tokens: &[i32]| -> Result<Vec<f32>> {
            assert_eq!(tokens.len(), cfg.batch * cfg.seq_len);
            calls += 1;
            Ok(vec![0.0f32; cfg.batch * cfg.seq_len * cfg.vocab])
        };
        let r = evaluate_with(&mut score, &cfg, Source::Wiki, 256).unwrap();
        assert!(calls > 0);
        assert!((r.perplexity - 32.0).abs() < 1e-6);
        assert!(r.n_predictions >= 256 - cfg.seq_len);
    }
}
