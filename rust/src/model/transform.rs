//! The offline weight-transform engine — everything PeRQ merges into the
//! model before deployment (Fig 7 / Remark 4.2), leaving the compute graph
//! untouched:
//!
//! * `fold_norms`   — absorb RMSNorm scale vectors into adjacent linears
//!                    (prerequisite for rotation commutation);
//! * `merge_r1`     — residual-stream rotation: embed/pos/wo/wd outputs
//!                    right-multiplied, wq/wk/wv/wg/wu/wout inputs
//!                    left-multiplied by R1ᵀ;
//! * `merge_r2`     — per-head v→o rotation;
//! * `merge_p3`     — the PeRQ permutation through the SwiGLU
//!                    permutation-equivariant region (wg/wu out-cols,
//!                    wd in-rows);
//! * `merge_r3_inv` — fold R̃3ᵀ into wd so the graph's online rotation is
//!                    exactly cancelled at full precision.
//!
//! Python-side mirrors of these merges are validated in
//! python/tests/test_model.py (invariance of the fp forward).

use anyhow::Result;

use super::config::ModelConfig;
use super::weights::WeightSet;
use crate::hadamard::BlockRotator;
use crate::tensor::Mat;

/// Fold every RMSNorm scale into the adjacent linear weights and reset the
/// scales to 1 (rotation only commutes with scale-free RMSNorm).
pub fn fold_norms(ws: &mut WeightSet, cfg: &ModelConfig) {
    for l in 0..cfg.n_layers {
        let s1 = ws.get(&format!("l{l}.n1")).data.clone();
        for part in ["wq", "wk", "wv"] {
            let name = format!("l{l}.{part}");
            let folded = ws.get(&name).scale_rows(&s1);
            ws.set(&name, folded);
        }
        ws.set(&format!("l{l}.n1"), Mat::from_vec(1, cfg.d_model, vec![1.0; cfg.d_model]));
        let s2 = ws.get(&format!("l{l}.n2")).data.clone();
        for part in ["wg", "wu"] {
            let name = format!("l{l}.{part}");
            let folded = ws.get(&name).scale_rows(&s2);
            ws.set(&name, folded);
        }
        ws.set(&format!("l{l}.n2"), Mat::from_vec(1, cfg.d_model, vec![1.0; cfg.d_model]));
    }
    let sf = ws.get("nf").data.clone();
    let folded = ws.get("wout").scale_rows(&sf);
    ws.set("wout", folded);
    ws.set("nf", Mat::from_vec(1, cfg.d_model, vec![1.0; cfg.d_model]));
}

/// Merge the residual rotation R1 (d_model × d_model orthogonal).
/// Requires `fold_norms` first.
pub fn merge_r1(ws: &mut WeightSet, cfg: &ModelConfig, r1: &Mat) {
    assert_eq!(r1.rows, cfg.d_model);
    let r1t = r1.transpose();
    // residual producers: right-multiply by R1
    for name in ["embed", "pos"] {
        let m = ws.get(name).matmul(r1);
        ws.set(name, m);
    }
    for l in 0..cfg.n_layers {
        for part in ["wo", "wd"] {
            let name = format!("l{l}.{part}");
            let m = ws.get(&name).matmul(r1);
            ws.set(&name, m);
        }
        // residual consumers: left-multiply by R1ᵀ
        for part in ["wq", "wk", "wv", "wg", "wu"] {
            let name = format!("l{l}.{part}");
            let m = r1t.matmul(ws.get(&name));
            ws.set(&name, m);
        }
    }
    let m = r1t.matmul(ws.get("wout"));
    ws.set("wout", m);
}

/// Merge the per-head rotation R2 (head_dim × head_dim) into wv (out cols,
/// per head) and wo (in rows, per head).
pub fn merge_r2(ws: &mut WeightSet, cfg: &ModelConfig, r2: &Mat) {
    let hd = cfg.head_dim();
    assert_eq!(r2.rows, hd);
    // block-diagonal expansion of r2 over heads
    let mut blk = Mat::zeros(cfg.d_model, cfg.d_model);
    for h in 0..cfg.n_heads {
        for i in 0..hd {
            for j in 0..hd {
                *blk.at_mut(h * hd + i, h * hd + j) = r2.at(i, j);
            }
        }
    }
    let blk_t = blk.transpose();
    for l in 0..cfg.n_layers {
        let wv = ws.get(&format!("l{l}.wv")).matmul(&blk);
        ws.set(&format!("l{l}.wv"), wv);
        let wo = blk_t.matmul(ws.get(&format!("l{l}.wo")));
        ws.set(&format!("l{l}.wo"), wo);
    }
}

/// Merge the PeRQ permutation P3 for one layer through the SwiGLU region:
/// wg/wu out-columns gathered by `perm`, wd in-rows gathered by `perm`.
pub fn merge_p3_layer(ws: &mut WeightSet, layer: usize, perm: &[usize]) {
    for part in ["wg", "wu"] {
        let name = format!("l{layer}.{part}");
        let m = ws.get(&name).permute_cols(perm);
        ws.set(&name, m);
    }
    let name = format!("l{layer}.wd");
    let m = ws.get(&name).permute_rows(perm);
    ws.set(&name, m);
}

/// Fold the inverse online rotation R̃3ᵀ into wd's input rows, so that the
/// graph's online rotation of the activations cancels exactly at fmt=0.
pub fn merge_r3_inv(ws: &mut WeightSet, cfg: &ModelConfig, rot: &BlockRotator) -> Result<()> {
    for l in 0..cfg.n_layers {
        let name = format!("l{l}.wd");
        let merged = rot.merge_into_weight_rows(ws.get(&name))?;
        ws.set(&name, merged);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    //! Invariance is verified end-to-end against the AOT artifacts in
    //! tests/integration.rs; here we check the pure linear algebra.

    use super::*;
    use crate::model::config::ModelConfig;
    use crate::util::json;

    fn tiny_cfg() -> ModelConfig {
        let j = json::parse(
            r#"{"config": {"name": "t", "n_layers": 1, "d_model": 16,
                "n_heads": 2, "d_ffn": 32, "vocab": 8, "seq_len": 4,
                "batch": 1, "block_sizes": [1]}}"#,
        )
        .unwrap();
        ModelConfig::from_meta(&j).unwrap()
    }

    fn fake_ws(cfg: &ModelConfig, seed: u64) -> WeightSet {
        let mut rng = crate::data::rng::Rng::new(seed);
        let mut tensors = std::collections::BTreeMap::new();
        let mut shapes = std::collections::BTreeMap::new();
        let d = cfg.d_model;
        let f = cfg.d_ffn;
        let mut add = |name: &str, r: usize, c: usize, rank1: bool, rng: &mut crate::data::rng::Rng| {
            let m = Mat::from_fn(r, c, |_, _| rng.next_normal() as f32 * 0.3);
            shapes.insert(name.to_string(), if rank1 { vec![c] } else { vec![r, c] });
            tensors.insert(name.to_string(), m);
        };
        add("embed", cfg.vocab, d, false, &mut rng);
        add("pos", cfg.seq_len, d, false, &mut rng);
        add("l0.n1", 1, d, true, &mut rng);
        add("l0.wq", d, d, false, &mut rng);
        add("l0.wk", d, d, false, &mut rng);
        add("l0.wv", d, d, false, &mut rng);
        add("l0.wo", d, d, false, &mut rng);
        add("l0.n2", 1, d, true, &mut rng);
        add("l0.wg", d, f, false, &mut rng);
        add("l0.wu", d, f, false, &mut rng);
        add("l0.wd", f, d, false, &mut rng);
        add("nf", 1, d, true, &mut rng);
        add("wout", d, cfg.vocab, false, &mut rng);
        WeightSet { names: cfg.weight_names(), tensors, shapes, packed: Default::default() }
    }

    #[test]
    fn fold_norms_preserves_linear_response() {
        // rmsnorm(x, s) @ W == rmsnorm(x, 1) @ diag(s)W — check diag(s)W part
        let cfg = tiny_cfg();
        let mut ws = fake_ws(&cfg, 1);
        let s1 = ws.get("l0.n1").data.clone();
        let wq_before = ws.get("l0.wq").clone();
        fold_norms(&mut ws, &cfg);
        let wq_after = ws.get("l0.wq");
        for i in 0..cfg.d_model {
            for j in 0..cfg.d_model {
                let want = wq_before.at(i, j) * s1[i];
                assert!((wq_after.at(i, j) - want).abs() < 1e-6);
            }
        }
        assert!(ws.get("l0.n1").data.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn r1_merge_preserves_residual_algebra() {
        // (x R)(Rᵀ W) == x W at full precision
        let cfg = tiny_cfg();
        let mut ws = fake_ws(&cfg, 2);
        fold_norms(&mut ws, &cfg);
        let x = Mat::from_fn(3, cfg.d_model, |i, j| ((i + j) as f32).sin());
        let before = x.matmul(ws.get("l0.wq"));
        let r1 = crate::hadamard::normalized_hadamard(cfg.d_model).unwrap();
        merge_r1(&mut ws, &cfg, &r1);
        let xr = x.matmul(&r1);
        let after = xr.matmul(ws.get("l0.wq"));
        for (a, b) in after.data.iter().zip(&before.data) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn r2_merge_is_involution_for_symmetric_rotation() {
        // Sylvester H/√n is symmetric ⇒ merging twice restores wv·wo product
        let cfg = tiny_cfg();
        let mut ws = fake_ws(&cfg, 3);
        let prod_before = ws.get("l0.wv").matmul(ws.get("l0.wo"));
        let r2 = crate::hadamard::normalized_hadamard(cfg.head_dim()).unwrap();
        merge_r2(&mut ws, &cfg, &r2);
        let prod_after = ws.get("l0.wv").matmul(ws.get("l0.wo"));
        // wv·wo invariant because blk·blkᵀ = I
        for (a, b) in prod_after.data.iter().zip(&prod_before.data) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn p3_merge_preserves_swiglu_product_path() {
        // (elementwise(x wg P) ⊙ (x wu P)) (Pᵀ wd) == same without P
        let cfg = tiny_cfg();
        let mut ws = fake_ws(&cfg, 4);
        let x = Mat::from_fn(2, cfg.d_model, |i, j| ((i * 7 + j) as f32 * 0.1).cos());
        let fwd = |ws: &WeightSet| -> Mat {
            let g = x.matmul(ws.get("l0.wg"));
            let u = x.matmul(ws.get("l0.wu"));
            let mut prod = g.clone();
            for (p, (gv, uv)) in prod.data.iter_mut().zip(g.data.iter().zip(&u.data)) {
                *p = (gv / (1.0 + (-gv).exp())) * uv; // swish(g) * u
            }
            prod.matmul(ws.get("l0.wd"))
        };
        let before = fwd(&ws);
        let mut rng = crate::data::rng::Rng::new(9);
        let mut perm: Vec<usize> = (0..cfg.d_ffn).collect();
        for i in (1..perm.len()).rev() {
            let j = rng.next_below((i + 1) as u64) as usize;
            perm.swap(i, j);
        }
        merge_p3_layer(&mut ws, 0, &perm);
        let after = fwd(&ws);
        for (a, b) in after.data.iter().zip(&before.data) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn r3_merge_cancels_online_rotation() {
        let cfg = tiny_cfg();
        let mut ws = fake_ws(&cfg, 5);
        let g = Mat::from_fn(3, cfg.d_ffn, |i, j| ((i + 2 * j) as f32 * 0.05).sin());
        let before = g.matmul(ws.get("l0.wd"));
        let rot = BlockRotator::hadamard(16).unwrap();
        merge_r3_inv(&mut ws, &cfg, &rot).unwrap();
        let mut gr = g.clone();
        rot.apply_mat(&mut gr);
        let after = gr.matmul(ws.get("l0.wd"));
        for (a, b) in after.data.iter().zip(&before.data) {
            assert!((a - b).abs() < 1e-4);
        }
    }
}
