//! Weight store: named f32 tensors in the canonical artifact input order,
//! loaded from artifacts/weights/<model>/*.npy (written by train.py).
//! 1-D tensors (norm scales) are stored as 1×n Mats but remember their
//! original rank for literal construction.
//!
//! Quantized graphs additionally carry *packed* low-bit twins
//! (`tensor::qmat::QuantMat`, u4x2/i8 payloads) for the per-layer linear
//! sites, attached by the pipeline's rounding stage. The native backend
//! serves straight from the packed form and drops the dequantized f32
//! copies — the 4–8× weight-memory reduction of the paper's deployment
//! story.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::tensor::{npy, Mat, QuantMat};

#[derive(Clone)]
pub struct WeightSet {
    /// canonical order (the artifact input contract)
    pub names: Vec<String>,
    pub tensors: BTreeMap<String, Mat>,
    /// original npy shapes (for literal reshape)
    pub shapes: BTreeMap<String, Vec<usize>>,
    /// packed low-bit twins of quantized tensors (keyed like `tensors`)
    pub packed: BTreeMap<String, QuantMat>,
}

impl WeightSet {
    pub fn load(dir: &Path, names: &[String]) -> Result<WeightSet> {
        let mut tensors = BTreeMap::new();
        let mut shapes = BTreeMap::new();
        for n in names {
            let path = dir.join(format!("{n}.npy"));
            let raw = npy::read(&path)?;
            let mat = match raw.shape.len() {
                1 => Mat::from_vec(1, raw.shape[0], raw.data),
                2 => Mat::from_vec(raw.shape[0], raw.shape[1], raw.data),
                r => return Err(anyhow!("weight {n}: unexpected rank {r}")),
            };
            shapes.insert(n.clone(), raw.shape);
            tensors.insert(n.clone(), mat);
        }
        Ok(WeightSet { names: names.to_vec(), tensors, shapes, packed: BTreeMap::new() })
    }

    pub fn get(&self, name: &str) -> &Mat {
        self.tensors
            .get(name)
            .unwrap_or_else(|| panic!("missing weight {name}"))
    }

    pub fn get_mut(&mut self, name: &str) -> &mut Mat {
        self.tensors
            .get_mut(name)
            .unwrap_or_else(|| panic!("missing weight {name}"))
    }

    pub fn set(&mut self, name: &str, m: Mat) {
        assert!(self.tensors.contains_key(name), "unknown weight {name}");
        self.tensors.insert(name.to_string(), m);
    }

    pub fn shape(&self, name: &str) -> &[usize] {
        &self.shapes[name]
    }

    /// Attach a packed low-bit twin for a quantized tensor.
    pub fn set_packed(&mut self, name: &str, qm: QuantMat) {
        assert!(self.tensors.contains_key(name), "unknown weight {name}");
        self.packed.insert(name.to_string(), qm);
    }

    /// The packed twin of a tensor, if one was attached.
    pub fn packed(&self, name: &str) -> Option<&QuantMat> {
        self.packed.get(name)
    }

    /// Move a packed twin out (the native backend takes ownership and
    /// drops its dense copy).
    pub fn take_packed(&mut self, name: &str) -> Option<QuantMat> {
        self.packed.remove(name)
    }

    /// Drop the dense f32 copy of a tensor whose packed twin serves in its
    /// place — the memory-reduction half of the packed deployment path.
    /// `get` on a dropped name panics, so callers only drop tensors they
    /// will never read densely again.
    pub fn drop_dense(&mut self, name: &str) {
        self.tensors.remove(name);
    }

    /// Total parameter count (sanity/reporting).
    pub fn param_count(&self) -> usize {
        self.tensors.values().map(|m| m.data.len()).sum()
    }

    /// Approximate bytes held by weight storage: dense f32 tensors plus
    /// packed payloads (reporting/diagnostics).
    pub fn weight_bytes(&self) -> usize {
        let dense: usize = self.tensors.values().map(|m| m.data.len() * 4).sum();
        let packed: usize = self.packed.values().map(|q| q.packed_bytes()).sum();
        dense + packed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fake_weights(dir: &Path, names: &[(&str, Vec<usize>)]) {
        std::fs::create_dir_all(dir).unwrap();
        for (n, shape) in names {
            let count: usize = shape.iter().product();
            let data: Vec<f32> = (0..count).map(|i| i as f32 * 0.1).collect();
            npy::write(&dir.join(format!("{n}.npy")), shape, &data).unwrap();
        }
    }

    #[test]
    fn load_roundtrip() {
        let dir = std::env::temp_dir().join("perq_ws_test");
        write_fake_weights(&dir, &[("embed", vec![4, 8]), ("nf", vec![8])]);
        let names = vec!["embed".to_string(), "nf".to_string()];
        let ws = WeightSet::load(&dir, &names).unwrap();
        assert_eq!(ws.get("embed").rows, 4);
        assert_eq!(ws.get("embed").cols, 8);
        assert_eq!(ws.get("nf").rows, 1);
        assert_eq!(ws.shape("nf"), &[8]);
        assert_eq!(ws.param_count(), 40);
    }

    #[test]
    fn packed_twin_lifecycle() {
        let dir = std::env::temp_dir().join("perq_ws_test3");
        write_fake_weights(&dir, &[("w", vec![8, 4])]);
        let mut ws = WeightSet::load(&dir, &["w".to_string()]).unwrap();
        assert!(ws.packed("w").is_none());
        let w = ws.get("w").clone();
        let codec = crate::quant::WeightCodec::fit(crate::quant::Format::Int4, &w);
        let qm = crate::tensor::QuantMat::from_codec(&codec.quantize_mat(&w), &codec).unwrap();
        ws.set_packed("w", qm);
        assert!(ws.packed("w").is_some());
        assert!(ws.weight_bytes() > 8 * 4 * 4);
        let taken = ws.take_packed("w").unwrap();
        assert_eq!((taken.rows, taken.cols), (8, 4));
        ws.drop_dense("w");
        assert_eq!(ws.param_count(), 0);
    }

    #[test]
    fn missing_weight_errors() {
        let dir = std::env::temp_dir().join("perq_ws_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let names = vec!["nope".to_string()];
        assert!(WeightSet::load(&dir, &names).is_err());
    }
}
