//! Weight store: named f32 tensors in the canonical artifact input order,
//! loaded from artifacts/weights/<model>/*.npy (written by train.py).
//! 1-D tensors (norm scales) are stored as 1×n Mats but remember their
//! original rank for literal construction.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::tensor::{npy, Mat};

#[derive(Clone)]
pub struct WeightSet {
    /// canonical order (the artifact input contract)
    pub names: Vec<String>,
    pub tensors: BTreeMap<String, Mat>,
    /// original npy shapes (for literal reshape)
    pub shapes: BTreeMap<String, Vec<usize>>,
}

impl WeightSet {
    pub fn load(dir: &Path, names: &[String]) -> Result<WeightSet> {
        let mut tensors = BTreeMap::new();
        let mut shapes = BTreeMap::new();
        for n in names {
            let path = dir.join(format!("{n}.npy"));
            let raw = npy::read(&path)?;
            let mat = match raw.shape.len() {
                1 => Mat::from_vec(1, raw.shape[0], raw.data),
                2 => Mat::from_vec(raw.shape[0], raw.shape[1], raw.data),
                r => return Err(anyhow!("weight {n}: unexpected rank {r}")),
            };
            shapes.insert(n.clone(), raw.shape);
            tensors.insert(n.clone(), mat);
        }
        Ok(WeightSet { names: names.to_vec(), tensors, shapes })
    }

    pub fn get(&self, name: &str) -> &Mat {
        self.tensors
            .get(name)
            .unwrap_or_else(|| panic!("missing weight {name}"))
    }

    pub fn get_mut(&mut self, name: &str) -> &mut Mat {
        self.tensors
            .get_mut(name)
            .unwrap_or_else(|| panic!("missing weight {name}"))
    }

    pub fn set(&mut self, name: &str, m: Mat) {
        assert!(self.tensors.contains_key(name), "unknown weight {name}");
        self.tensors.insert(name.to_string(), m);
    }

    pub fn shape(&self, name: &str) -> &[usize] {
        &self.shapes[name]
    }

    /// Total parameter count (sanity/reporting).
    pub fn param_count(&self) -> usize {
        self.tensors.values().map(|m| m.data.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fake_weights(dir: &Path, names: &[(&str, Vec<usize>)]) {
        std::fs::create_dir_all(dir).unwrap();
        for (n, shape) in names {
            let count: usize = shape.iter().product();
            let data: Vec<f32> = (0..count).map(|i| i as f32 * 0.1).collect();
            npy::write(&dir.join(format!("{n}.npy")), shape, &data).unwrap();
        }
    }

    #[test]
    fn load_roundtrip() {
        let dir = std::env::temp_dir().join("perq_ws_test");
        write_fake_weights(&dir, &[("embed", vec![4, 8]), ("nf", vec![8])]);
        let names = vec!["embed".to_string(), "nf".to_string()];
        let ws = WeightSet::load(&dir, &names).unwrap();
        assert_eq!(ws.get("embed").rows, 4);
        assert_eq!(ws.get("embed").cols, 8);
        assert_eq!(ws.get("nf").rows, 1);
        assert_eq!(ws.shape("nf"), &[8]);
        assert_eq!(ws.param_count(), 40);
    }

    #[test]
    fn missing_weight_errors() {
        let dir = std::env::temp_dir().join("perq_ws_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let names = vec!["nope".to_string()];
        assert!(WeightSet::load(&dir, &names).is_err());
    }
}
