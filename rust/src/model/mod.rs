//! Model substrate: configuration (parsed from artifacts/<model>/meta.json),
//! the weight store, the offline transform engine (merging norm scales,
//! rotations R1/R2/R̃3 and permutations P3 into weights — Fig 7 / Remark
//! 4.2), and the `ModelBundle` tying them to a set of AOT artifacts.

pub mod bundle;
pub mod config;
pub mod transform;
pub mod weights;

pub use bundle::ModelBundle;
pub use config::ModelConfig;
pub use weights::WeightSet;
