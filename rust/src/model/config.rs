//! Model configuration — the rust view of python/compile/model.py's
//! ModelConfig, parsed from the meta.json the AOT exporter writes.

use anyhow::{anyhow, Result};

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ffn: usize,
    pub vocab: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub block_sizes: Vec<usize>,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn from_meta(meta: &Json) -> Result<ModelConfig> {
        let c = meta.get("config").ok_or_else(|| anyhow!("meta: no config"))?;
        let req = |k: &str| -> Result<usize> {
            c.get(k)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("meta.config missing {k}"))
        };
        Ok(ModelConfig {
            name: c
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("meta.config missing name"))?
                .to_string(),
            n_layers: req("n_layers")?,
            d_model: req("d_model")?,
            n_heads: req("n_heads")?,
            d_ffn: req("d_ffn")?,
            vocab: req("vocab")?,
            seq_len: req("seq_len")?,
            batch: req("batch")?,
            block_sizes: c
                .get("block_sizes")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_default(),
        })
    }

    /// Canonical weight ordering — must match python model.weight_names.
    pub fn weight_names(&self) -> Vec<String> {
        let mut names = vec!["embed".to_string(), "pos".to_string()];
        for i in 0..self.n_layers {
            for part in ["n1", "wq", "wk", "wv", "wo", "n2", "wg", "wu", "wd"] {
                names.push(format!("l{i}.{part}"));
            }
        }
        names.push("nf".to_string());
        names.push("wout".to_string());
        names
    }

    /// The per-layer linear sites PeRQ quantizes, with their calibration
    /// capture source. (embed/pos/unembed stay full precision, as in
    /// QuaRot-style pipelines.)
    pub fn linear_sites(&self) -> Vec<LinearSite> {
        let mut out = Vec::new();
        for l in 0..self.n_layers {
            for (part, cap) in [
                ("wq", CaptureKind::AttnIn),
                ("wk", CaptureKind::AttnIn),
                ("wv", CaptureKind::AttnIn),
                ("wo", CaptureKind::OIn),
                ("wg", CaptureKind::FfnIn),
                ("wu", CaptureKind::FfnIn),
                ("wd", CaptureKind::DownIn),
            ] {
                out.push(LinearSite { layer: l, name: format!("l{l}.{part}"), capture: cap });
            }
        }
        out
    }
}

/// Which calibration capture feeds a linear's Hessian.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CaptureKind {
    /// post-norm1 residual (input of wq/wk/wv)
    AttnIn,
    /// attention context (input of wo)
    OIn,
    /// post-norm2 residual (input of wg/wu)
    FfnIn,
    /// SwiGLU output (input of wd — the R̃3 site)
    DownIn,
}

#[derive(Clone, Debug)]
pub struct LinearSite {
    pub layer: usize,
    pub name: String,
    pub capture: CaptureKind,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn sample_meta() -> Json {
        json::parse(
            r#"{"config": {"name": "m", "n_layers": 2, "d_model": 128,
                "n_heads": 4, "d_ffn": 448, "vocab": 32, "seq_len": 128,
                "batch": 8, "block_sizes": [1, 16, 32]}}"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_config() {
        let c = ModelConfig::from_meta(&sample_meta()).unwrap();
        assert_eq!(c.name, "m");
        assert_eq!(c.d_ffn, 448);
        assert_eq!(c.head_dim(), 32);
        assert_eq!(c.block_sizes, vec![1, 16, 32]);
    }

    #[test]
    fn weight_names_match_python_layout() {
        let c = ModelConfig::from_meta(&sample_meta()).unwrap();
        let names = c.weight_names();
        assert_eq!(names.len(), 2 + 9 * 2 + 2);
        assert_eq!(names[0], "embed");
        assert_eq!(names[2], "l0.n1");
        assert_eq!(names[10], "l0.wd");
        assert_eq!(*names.last().unwrap(), "wout");
    }

    #[test]
    fn linear_sites_enumeration() {
        let c = ModelConfig::from_meta(&sample_meta()).unwrap();
        let sites = c.linear_sites();
        assert_eq!(sites.len(), 14);
        assert_eq!(sites[6].name, "l0.wd");
        assert_eq!(sites[6].capture, CaptureKind::DownIn);
    }
}
