//! `ModelBundle`: a model's config + pristine weights + artifact metadata,
//! loaded once and shared (read-only) across pipeline runs.
//!
//! Loading is plain file IO (meta.json + .npy weights) and never touches
//! PJRT; bundles therefore work on every backend. For artifact-free runs
//! (native backend, zero Python involvement) [`ModelBundle::synthetic`]
//! materializes one of the known model configs with deterministic
//! randomly-initialized weights.

use std::collections::BTreeMap;

use anyhow::{anyhow, Context, Result};

use super::config::ModelConfig;
use super::weights::WeightSet;
use crate::runtime::{Engine, RepoContext};
use crate::tensor::{npy, Mat};
use crate::util::json::{self, Json};

pub struct ModelBundle {
    pub name: String,
    pub cfg: ModelConfig,
    pub meta: Json,
    /// pristine full-precision weights (never mutated; pipelines clone)
    pub weights: WeightSet,
    /// learned full-vector R1 from rotopt.py, if present
    pub learned_r1: Option<Mat>,
    /// learned b×b block rotation from rotopt.py, if present
    pub learned_r1_block: Option<(usize, Mat)>,
    pub ctx: RepoContext,
}

impl ModelBundle {
    pub fn load(ctx: &RepoContext, name: &str) -> Result<ModelBundle> {
        let meta_path = ctx.model_dir(name).join("meta.json");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("loading meta for {name} ({meta_path:?})"))?;
        let meta = json::parse(&text)?;
        let cfg = ModelConfig::from_meta(&meta)?;
        let weights = WeightSet::load(&ctx.weights_dir(name), &cfg.weight_names())
            .with_context(|| format!("loading weights for {name}"))?;
        let wdir = ctx.weights_dir(name);
        let learned_r1 = npy::read_mat(&wdir.join("rotopt_r1.npy")).ok();
        let learned_r1_block = npy::read_mat(&wdir.join("rotopt_r1_b32.npy"))
            .ok()
            .map(|m| (m.rows, m));
        Ok(ModelBundle {
            name: name.to_string(),
            cfg,
            meta,
            weights,
            learned_r1,
            learned_r1_block,
            ctx: ctx.clone(),
        })
    }

    /// Load using an existing engine. Kept for API continuity — loading is
    /// pure file IO, so the engine is only a hint that one already exists.
    pub fn load_with_engine(ctx: &RepoContext, _engine: &Engine, name: &str) -> Result<ModelBundle> {
        Self::load(ctx, name)
    }

    /// An artifact-free bundle: one of the known model configs with
    /// deterministic random-init weights (the `model.init_weights` scheme:
    /// normal · 1/√fan_in linears, unit norms, zero positional). Serves
    /// the zero-dependency native path — no `make artifacts` required.
    pub fn synthetic(name: &str) -> Result<ModelBundle> {
        let cfg = synthetic_config(name)
            .ok_or_else(|| anyhow!("unknown synthetic model {name:?} (try llama_tiny, llama_np2, qwen_tiny)"))?;
        let seed = name.bytes().fold(0xBEEFu64, |a, b| a.wrapping_mul(31).wrapping_add(b as u64));
        let weights = synthetic_weights(&cfg, seed);
        Ok(ModelBundle {
            name: name.to_string(),
            cfg,
            meta: json::parse("{}")?,
            weights,
            learned_r1: None,
            learned_r1_block: None,
            ctx: RepoContext::ephemeral(),
        })
    }

    /// Tags of the quant-graph artifacts this bundle provides.
    pub fn quant_tag(&self, block: usize) -> String {
        format!("fwd_quant_b{block}")
    }

    pub fn has_artifact(&self, tag: &str) -> bool {
        self.ctx
            .model_dir(&self.name)
            .join(format!("{tag}.hlo.txt"))
            .exists()
    }
}

/// The rust mirror of python `model.CONFIGS` (DESIGN.md §6): Llama3-1B /
/// Llama3-8B(non-pow-2 FFN) / Qwen3 analogs.
pub fn synthetic_config(name: &str) -> Option<ModelConfig> {
    let (n_layers, d_model, n_heads, d_ffn, blocks): (usize, usize, usize, usize, &[usize]) =
        match name {
            "llama_tiny" => (4, 256, 8, 1024, &[1, 16, 32, 64, 128, 256, 512, 1024]),
            "llama_np2" => (2, 128, 4, 448, &[1, 16, 32, 64, 448]),
            "qwen_tiny" => (3, 192, 6, 768, &[1, 16, 32, 64, 128, 256, 768]),
            _ => return None,
        };
    Some(ModelConfig {
        name: name.to_string(),
        n_layers,
        d_model,
        n_heads,
        d_ffn,
        vocab: 32,
        seq_len: 128,
        batch: 8,
        block_sizes: blocks.to_vec(),
    })
}

/// Deterministic random-init weights for a config, mirroring
/// `model.init_weights`: norm scales = 1, positional = 0, linears ~
/// N(0, 1/fan_in).
pub fn synthetic_weights(cfg: &ModelConfig, seed: u64) -> WeightSet {
    let mut rng = crate::data::rng::Rng::new(seed);
    let names = cfg.weight_names();
    let mut tensors = BTreeMap::new();
    let mut shapes = BTreeMap::new();
    let (d, f, v, t) = (cfg.d_model, cfg.d_ffn, cfg.vocab, cfg.seq_len);
    for name in &names {
        let part = name.rsplit('.').next().unwrap_or(name);
        let (rows, cols, rank1) = match part {
            "embed" => (v, d, false),
            "pos" => (t, d, false),
            "n1" | "n2" | "nf" => (1, d, true),
            "wq" | "wk" | "wv" | "wo" => (d, d, false),
            "wg" | "wu" => (d, f, false),
            "wd" => (f, d, false),
            "wout" => (d, v, false),
            _ => unreachable!("unexpected weight {name}"),
        };
        let m = if rank1 {
            Mat::from_vec(1, cols, vec![1.0; cols])
        } else if part == "pos" {
            Mat::zeros(rows, cols)
        } else {
            let scale = 1.0 / (rows as f32).sqrt();
            Mat::from_fn(rows, cols, |_, _| rng.next_normal() as f32 * scale)
        };
        shapes.insert(name.clone(), if rank1 { vec![cols] } else { vec![rows, cols] });
        tensors.insert(name.clone(), m);
    }
    WeightSet { names, tensors, shapes, packed: BTreeMap::new() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_bundle_matches_python_configs() {
        let b = ModelBundle::synthetic("llama_np2").unwrap();
        assert_eq!(b.cfg.n_layers, 2);
        assert_eq!(b.cfg.d_model, 128);
        assert_eq!(b.cfg.d_ffn, 448);
        assert_eq!(b.cfg.head_dim(), 32);
        assert_eq!(b.weights.names, b.cfg.weight_names());
        assert!(!b.has_artifact("fwd"));
        assert!(ModelBundle::synthetic("gpt5").is_err());
    }

    #[test]
    fn synthetic_weights_deterministic_and_shaped() {
        let cfg = synthetic_config("qwen_tiny").unwrap();
        let a = synthetic_weights(&cfg, 7);
        let b = synthetic_weights(&cfg, 7);
        let c = synthetic_weights(&cfg, 8);
        assert_eq!(a.get("l0.wq").data, b.get("l0.wq").data);
        assert_ne!(a.get("l0.wq").data, c.get("l0.wq").data);
        assert_eq!(a.get("embed").rows, 32);
        assert_eq!(a.get("l0.wd").rows, cfg.d_ffn);
        assert_eq!(a.shape("nf"), &[cfg.d_model]);
        assert!(a.get("l0.n1").data.iter().all(|&x| x == 1.0));
        assert!(a.get("pos").data.iter().all(|&x| x == 0.0));
    }
}
