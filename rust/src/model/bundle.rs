//! `ModelBundle`: a model's config + pristine weights + artifact metadata,
//! loaded once and shared (read-only) across pipeline runs.

use anyhow::{Context, Result};

use super::config::ModelConfig;
use super::weights::WeightSet;
use crate::runtime::{Engine, RepoContext};
use crate::tensor::{npy, Mat};
use crate::util::json::Json;

pub struct ModelBundle {
    pub name: String,
    pub cfg: ModelConfig,
    pub meta: Json,
    /// pristine full-precision weights (never mutated; pipelines clone)
    pub weights: WeightSet,
    /// learned full-vector R1 from rotopt.py, if present
    pub learned_r1: Option<Mat>,
    /// learned b×b block rotation from rotopt.py, if present
    pub learned_r1_block: Option<(usize, Mat)>,
    pub ctx: RepoContext,
}

impl ModelBundle {
    pub fn load(ctx: &RepoContext, name: &str) -> Result<ModelBundle> {
        let engine = Engine::new(ctx)?;
        Self::load_with_engine(ctx, &engine, name)
    }

    /// Load using an existing engine (avoids spinning up extra PJRT clients).
    pub fn load_with_engine(ctx: &RepoContext, engine: &Engine, name: &str) -> Result<ModelBundle> {
        let meta = engine
            .load_meta(name)
            .with_context(|| format!("loading meta for {name}"))?;
        let cfg = ModelConfig::from_meta(&meta)?;
        let weights = WeightSet::load(&ctx.weights_dir(name), &cfg.weight_names())
            .with_context(|| format!("loading weights for {name}"))?;
        let wdir = ctx.weights_dir(name);
        let learned_r1 = npy::read_mat(&wdir.join("rotopt_r1.npy")).ok();
        let learned_r1_block = npy::read_mat(&wdir.join("rotopt_r1_b32.npy"))
            .ok()
            .map(|m| (m.rows, m));
        Ok(ModelBundle {
            name: name.to_string(),
            cfg,
            meta,
            weights,
            learned_r1,
            learned_r1_block,
            ctx: ctx.clone(),
        })
    }

    /// Tags of the quant-graph artifacts this bundle provides.
    pub fn quant_tag(&self, block: usize) -> String {
        format!("fwd_quant_b{block}")
    }

    pub fn has_artifact(&self, tag: &str) -> bool {
        self.ctx
            .model_dir(&self.name)
            .join(format!("{tag}.hlo.txt"))
            .exists()
    }
}
