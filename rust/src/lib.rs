//! # PeRQ — Permute, Rotate, then Quantize
//!
//! Production reproduction of *"Pushing the Limits of Block Rotations in
//! Post-Training Quantization"* (ICML 2026) as a three-layer rust + JAX +
//! Pallas stack:
//!
//! * **L3 (this crate)** — the quantization-pipeline coordinator: corpus +
//!   calibration management, the MassDiff permutation calibrator, the
//!   offline weight-transform engine (merging permutations and rotations
//!   into weights, Remark 4.2 / Fig 7), RTN/GPTQ/Qronos rounding, the PJRT
//!   runtime that executes AOT artifacts, evaluation (perplexity +
//!   zero-shot probes), and the bench harness that regenerates every table
//!   and figure in the paper.
//! * **L2 (python/compile, build time)** — the jax transformer compute
//!   graph and its quantization-graph variants, lowered to HLO text.
//! * **L1 (python/compile/kernels, build time)** — pallas kernels for the
//!   online block-Hadamard rotation and fake-quantization hot paths.
//!
//! Python never runs at inference/evaluation time: `make artifacts` lowers
//! everything once, and the rust binary is self-contained afterwards.
//! Since the `backend` subsystem landed, even the lowering is optional:
//! the default (no-feature) build executes the full quantized forward pass
//! through the pure-Rust `NativeBackend`, and the PJRT/artifact path is an
//! opt-in `pjrt` cargo feature — see ARCHITECTURE.md.
//!
//! Quick start (see examples/quickstart.rs):
//! ```no_run
//! use perq::prelude::*;
//!
//! let ctx = RepoContext::discover().unwrap();
//! let bundle = ModelBundle::load(&ctx, "llama_tiny").unwrap();
//! let spec = perq::coordinator::presets::perq_star(32, Format::Int4);
//! let report = Pipeline::new(spec).run(&bundle).unwrap();
//! println!("ppl = {:.2}", report.perplexity);
//! ```

pub mod backend;
pub mod calib;
pub mod coordinator;
pub mod data;
pub mod deploy;
pub mod eval;
pub mod hadamard;
pub mod model;
pub mod obs;
pub mod permute;
pub mod quant;
pub mod rounding;
pub mod runtime;
pub mod stats;
pub mod tensor;
pub mod util;

/// Convenience re-exports for examples and benches.
pub mod prelude {
    pub use crate::backend::{BackendKind, ExecBackend, ForwardGraph, NativeBackend};
    pub use crate::coordinator::pipeline::{baseline_eval, Pipeline, PipelineReport, QuantizedModel};
    pub use crate::coordinator::presets;
    pub use crate::deploy::DeployedModel;
    pub use crate::coordinator::spec::{GraphKind, PipelineSpec, RotKind, RotationSpec};
    pub use crate::data::corpus::Source;
    pub use crate::model::bundle::ModelBundle;
    pub use crate::permute::PermKind;
    pub use crate::quant::Format;
    pub use crate::rounding::Rounding;
    pub use crate::runtime::{Engine, RepoContext};
    pub use crate::tensor::Mat;
}
