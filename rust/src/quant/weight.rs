//! Weight codecs (Appendix B): symmetric per-out-channel INT-q and FP4 with
//! MSE-searched scales, and MXFP4 with power-of-2 scales per group of 32
//! input rows. Weights are (d_in, d_out); channel = output column.

use super::e2m1;
use super::Format;
use crate::tensor::Mat;

const MSE_GRID: usize = 48; // linear search resolution, Brevitas-style
const EPS: f32 = 1e-8;

/// A fitted weight quantizer: holds per-channel (or per-group) scales so the
/// rounding solvers can quantize entry-by-entry consistently.
pub enum WeightCodec {
    None,
    Int {
        bits: u32,
        /// per output-channel scale
        scales: Vec<f32>,
    },
    Fp4 {
        scales: Vec<f32>,
    },
    Mx {
        /// (d_in/32) x d_out power-of-2 scales
        scales: Mat,
        group: usize,
    },
}

fn int_quant_val(v: f32, s: f32, bits: u32) -> f32 {
    let qmax = ((1i64 << (bits - 1)) - 1) as f32;
    let q = (v / s).round().clamp(-qmax - 1.0, qmax);
    s * q
}

/// Symmetric per-channel INT-q fit with the Brevitas-style MSE linear
/// search (shared by the INT4 and INT8 formats).
fn fit_int(w: &Mat, bits: u32) -> WeightCodec {
    let qmax = ((1i64 << (bits - 1)) - 1) as f32;
    let scales = (0..w.cols)
        .map(|j| {
            let absmax = (0..w.rows).fold(0.0f32, |m, i| m.max(w.at(i, j).abs()));
            let base = (absmax / qmax).max(EPS);
            let mut best = (f64::INFINITY, base);
            for g in 0..MSE_GRID {
                let frac = 0.35 + 0.65 * (g as f32 + 1.0) / MSE_GRID as f32;
                let s = (absmax * frac / qmax).max(EPS);
                let mse = col_mse_int(w, j, s, bits);
                if mse < best.0 {
                    best = (mse, s);
                }
            }
            best.1
        })
        .collect();
    WeightCodec::Int { bits, scales }
}

fn col_mse_int(w: &Mat, j: usize, s: f32, bits: u32) -> f64 {
    let mut acc = 0.0f64;
    for i in 0..w.rows {
        let v = w.at(i, j);
        let e = (v - int_quant_val(v, s, bits)) as f64;
        acc += e * e;
    }
    acc
}

fn col_mse_fp4(w: &Mat, j: usize, s: f32) -> f64 {
    let mut acc = 0.0f64;
    for i in 0..w.rows {
        let v = w.at(i, j);
        let e = (v - s * e2m1::quantize(v / s)) as f64;
        acc += e * e;
    }
    acc
}

impl WeightCodec {
    /// Fit scales to a weight matrix (MSE linear search per channel for
    /// INT/FP4; power-of-2 absmax-derived for MX — per the OCP spec).
    pub fn fit(format: Format, w: &Mat) -> WeightCodec {
        match format {
            Format::None => WeightCodec::None,
            Format::Int4 => fit_int(w, 4),
            Format::Int8 => fit_int(w, 8),
            Format::Fp4 => {
                let scales = (0..w.cols)
                    .map(|j| {
                        let absmax = (0..w.rows).fold(0.0f32, |m, i| m.max(w.at(i, j).abs()));
                        let base = (absmax / e2m1::FP4_MAX).max(EPS);
                        let mut best = (f64::INFINITY, base);
                        for g in 0..MSE_GRID {
                            let frac = 0.35 + 0.65 * (g as f32 + 1.0) / MSE_GRID as f32;
                            let s = (absmax * frac / e2m1::FP4_MAX).max(EPS);
                            let mse = col_mse_fp4(w, j, s);
                            if mse < best.0 {
                                best = (mse, s);
                            }
                        }
                        best.1
                    })
                    .collect();
                WeightCodec::Fp4 { scales }
            }
            Format::Mxfp4 => {
                let group = 32.min(w.rows);
                assert!(w.rows % group == 0, "MX group must divide d_in");
                let ng = w.rows / group;
                let mut scales = Mat::zeros(ng, w.cols);
                for g in 0..ng {
                    for j in 0..w.cols {
                        let mut mx = 0.0f32;
                        for i in g * group..(g + 1) * group {
                            mx = mx.max(w.at(i, j).abs());
                        }
                        let raw = (mx / e2m1::FP4_MAX).max(EPS);
                        *scales.at_mut(g, j) = (2.0f32).powi(raw.log2().floor() as i32);
                    }
                }
                WeightCodec::Mx { scales, group }
            }
        }
    }

    /// Quantize a single weight entry at (row i, channel j).
    #[inline]
    pub fn quantize_entry(&self, i: usize, j: usize, v: f32) -> f32 {
        match self {
            WeightCodec::None => v,
            WeightCodec::Int { bits, scales } => int_quant_val(v, scales[j], *bits),
            WeightCodec::Fp4 { scales } => scales[j] * e2m1::quantize(v / scales[j]),
            WeightCodec::Mx { scales, group } => {
                let s = scales.at(i / group, j);
                s * e2m1::quantize(v / s)
            }
        }
    }

    /// The (bits, per-channel scales) of an integer codec — the inputs the
    /// packed-kernel layer (`tensor::qmat::QuantMat`) needs to recover
    /// integer codes from codec-quantized weights. `None` for the float
    /// formats, which have no integer-GEMM representation.
    pub fn int_params(&self) -> Option<(u32, &[f32])> {
        match self {
            WeightCodec::Int { bits, scales } => Some((*bits, scales)),
            _ => None,
        }
    }

    /// Round-to-nearest the whole matrix through the codec.
    pub fn quantize_mat(&self, w: &Mat) -> Mat {
        let mut out = w.clone();
        for i in 0..w.rows {
            for j in 0..w.cols {
                *out.at_mut(i, j) = self.quantize_entry(i, j, w.at(i, j));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_w(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = crate::data::rng::Rng::new(seed);
        Mat::from_fn(r, c, |_, _| rng.next_normal() as f32 * 0.1)
    }

    #[test]
    fn int4_levels_bounded() {
        let w = rand_w(64, 8, 1);
        let codec = WeightCodec::fit(Format::Int4, &w);
        let q = codec.quantize_mat(&w);
        for j in 0..8 {
            let mut levels: Vec<i64> = (0..64)
                .map(|i| (q.at(i, j) * 1e5).round() as i64)
                .collect();
            levels.sort_unstable();
            levels.dedup();
            assert!(levels.len() <= 16, "col {j}: {} levels", levels.len());
        }
    }

    #[test]
    fn mse_search_beats_absmax() {
        // inject one outlier per channel: MSE search should clip it
        let mut w = rand_w(128, 4, 2);
        for j in 0..4 {
            *w.at_mut(0, j) = 3.0;
        }
        let codec = WeightCodec::fit(Format::Int4, &w);
        let q = codec.quantize_mat(&w);
        let mse_search = q.sub(&w).frob_norm();
        // absmax baseline
        let qmax = 7.0;
        let absmax_codec = WeightCodec::Int {
            bits: 4,
            scales: (0..4)
                .map(|j| (0..128).fold(0.0f32, |m, i| m.max(w.at(i, j).abs())) / qmax)
                .collect(),
        };
        let q2 = absmax_codec.quantize_mat(&w);
        let mse_absmax = q2.sub(&w).frob_norm();
        assert!(mse_search <= mse_absmax * 1.0001);
    }

    #[test]
    fn int8_levels_bounded_and_tighter_than_int4() {
        let w = rand_w(128, 6, 9);
        let c8 = WeightCodec::fit(Format::Int8, &w);
        let (bits, scales) = c8.int_params().unwrap();
        assert_eq!(bits, 8);
        assert_eq!(scales.len(), 6);
        let e8 = c8.quantize_mat(&w).sub(&w).frob_norm();
        let c4 = WeightCodec::fit(Format::Int4, &w);
        let e4 = c4.quantize_mat(&w).sub(&w).frob_norm();
        assert!(e8 < e4, "int8 ({e8}) must beat int4 ({e4})");
        assert!(WeightCodec::fit(Format::Fp4, &w).int_params().is_none());
    }

    #[test]
    fn quantize_idempotent() {
        for f in [Format::Int4, Format::Int8, Format::Fp4, Format::Mxfp4] {
            let w = rand_w(64, 6, 3);
            let codec = WeightCodec::fit(f, &w);
            let q1 = codec.quantize_mat(&w);
            let q2 = codec.quantize_mat(&q1);
            for (a, b) in q1.data.iter().zip(&q2.data) {
                assert!((a - b).abs() < 1e-5, "{f:?}");
            }
        }
    }

    #[test]
    fn none_codec_identity() {
        let w = rand_w(16, 3, 4);
        let codec = WeightCodec::fit(Format::None, &w);
        assert_eq!(codec.quantize_mat(&w).data, w.data);
    }

    #[test]
    fn entry_matches_mat() {
        let w = rand_w(64, 5, 5);
        let codec = WeightCodec::fit(Format::Mxfp4, &w);
        let q = codec.quantize_mat(&w);
        for i in 0..64 {
            for j in 0..5 {
                assert_eq!(q.at(i, j), codec.quantize_entry(i, j, w.at(i, j)));
            }
        }
    }

    #[test]
    fn error_decreases_with_bits() {
        let w = rand_w(128, 4, 6);
        let c4 = WeightCodec::Int {
            bits: 4,
            scales: (0..4).map(|j| {
                (0..128).fold(0.0f32, |m, i| m.max(w.at(i, j).abs())) / 7.0
            }).collect(),
        };
        let c8 = WeightCodec::Int {
            bits: 8,
            scales: (0..4).map(|j| {
                (0..128).fold(0.0f32, |m, i| m.max(w.at(i, j).abs())) / 127.0
            }).collect(),
        };
        let e4 = c4.quantize_mat(&w).sub(&w).frob_norm();
        let e8 = c8.quantize_mat(&w).sub(&w).frob_norm();
        assert!(e8 < e4 / 4.0);
    }
}
