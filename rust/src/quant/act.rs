//! Dynamic per-token activation fake-quantization — the rust mirror of
//! ref.py / the L1 pallas kernels (Appendix B, Eqs. 4-5). Used offline to
//! build the rotated-and-quantized activations X̃ whose Gram matrix feeds
//! GPTQ/Qronos, and by the stats module for Figure 5.

use super::e2m1;
use super::Format;
use crate::tensor::simd;
use crate::tensor::Mat;

pub const EPS: f32 = 1e-8;

/// Per-row (scale, zero) of the Eq. 4 asymmetric quantizer — the single
/// definition shared by the fake-quant and code-emit paths, so the packed
/// kernel's bit-exactness contract holds by construction. The min/max
/// scan runs through the SIMD layer; min/max selection is exact, so the
/// parameters are identical across dispatch levels.
fn int_asym_params(row: &[f32], bits: u32) -> (f32, f32) {
    let levels = ((1u32 << bits) - 1) as f32;
    let (mn, mx) = simd::row_minmax(row);
    let s = ((mx - mn) / levels).max(EPS);
    (s, (mn / s).round())
}

/// INT-q asymmetric per-row fake-quant (Eq. 4). The quantize loop runs
/// through the SIMD layer; the vector rounding reproduces `f32::round`
/// exactly, so the fake-quant floats are bit-identical across levels.
pub fn int_asym_row(row: &mut [f32], bits: u32) {
    let levels = ((1u32 << bits) - 1) as f32;
    let (s, z) = int_asym_params(row, bits);
    simd::fake_quant_int(row, s, z, levels);
}

/// FP4 symmetric per-row fake-quant, s = ‖row‖_∞ / 6 (Eq. 5).
pub fn fp4_row(row: &mut [f32]) {
    let mx = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let s = (mx / e2m1::FP4_MAX).max(EPS);
    for v in row.iter_mut() {
        *v = s * e2m1::quantize(*v / s);
    }
}

/// MXFP4: per-group-of-32 power-of-2 scales rounded down.
pub fn mxfp4_row(row: &mut [f32], group: usize) {
    debug_assert!(row.len() % group == 0);
    for blk in row.chunks_exact_mut(group) {
        let mx = blk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let raw = (mx / e2m1::FP4_MAX).max(EPS);
        let s = (2.0f32).powi(raw.log2().floor() as i32);
        for v in blk.iter_mut() {
            *v = s * e2m1::quantize(*v / s);
        }
    }
}

/// Quantize one row to integer codes (Eq. 4) *without* materializing the
/// fake-quant floats — the emit half of the packed-kernel path. Appends
/// `row.len()` codes in `[0, 2^bits - 1]` to `codes` and returns the
/// per-row `(scale, zero)` pair, with dequantization `s · (code + z)`.
///
/// Bit-matches [`int_asym_row`]: `(s, z)` come from the shared
/// [`int_asym_params`] and the rounding expression is identical, so
/// `s * (code + z)` reproduces the fake-quant value exactly.
pub fn int_asym_emit(row: &[f32], bits: u32, codes: &mut Vec<u8>) -> (f32, f32) {
    let start = codes.len();
    codes.resize(start + row.len(), 0);
    int_asym_emit_into(row, bits, &mut codes[start..])
}

/// [`int_asym_emit`] into a preallocated slice — the allocation-free form
/// the KV cache writes through (`tensor::kvcache`): steady-state decode
/// must not touch the heap, so codes land in an arena indexed by
/// (slot, position) instead of growing a staging vector.
pub fn int_asym_emit_into(row: &[f32], bits: u32, codes: &mut [u8]) -> (f32, f32) {
    debug_assert!(bits <= 8, "codes are u8");
    debug_assert_eq!(codes.len(), row.len());
    let levels = ((1u32 << bits) - 1) as f32;
    let (s, z) = int_asym_params(row, bits);
    simd::emit_codes(row, s, z, levels, codes);
    (s, z)
}

/// Fake-quantize one activation row in place in the given format.
pub fn act_quant_row(row: &mut [f32], format: Format) {
    match format {
        Format::None => {}
        Format::Int4 => int_asym_row(row, 4),
        Format::Int8 => int_asym_row(row, 8),
        Format::Fp4 => fp4_row(row),
        Format::Mxfp4 => mxfp4_row(row, 32),
    }
}

/// Fake-quantize every row (token) of an activation matrix in place.
pub fn act_quant_mat(m: &mut Mat, format: Format) {
    if format == Format::None {
        return;
    }
    let cols = m.cols;
    for r in 0..m.rows {
        act_quant_row(&mut m.data[r * cols..(r + 1) * cols], format);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_row(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut rng = crate::data::rng::Rng::new(seed);
        (0..n).map(|_| rng.next_normal() as f32 * scale).collect()
    }

    #[test]
    fn int4_alphabet_at_most_16_levels() {
        let mut row = rand_row(64, 1, 3.0);
        int_asym_row(&mut row, 4);
        let mut vals: Vec<i64> = row.iter().map(|&v| (v * 1e4).round() as i64).collect();
        vals.sort_unstable();
        vals.dedup();
        assert!(vals.len() <= 16);
    }

    #[test]
    fn int4_endpoints_representable() {
        let mut row = vec![-2.0f32, -1.0, 0.0, 1.0, 5.5];
        int_asym_row(&mut row, 4);
        // min and max must be (nearly) exactly representable
        assert!((row[0] + 2.0).abs() < 1e-3);
        assert!((row[4] - 5.5).abs() < 1e-3);
    }

    #[test]
    fn int4_idempotent() {
        let mut row = rand_row(128, 2, 1.0);
        int_asym_row(&mut row, 4);
        let once = row.clone();
        int_asym_row(&mut row, 4);
        for (a, b) in row.iter().zip(&once) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn fp4_error_bounded_relative_to_linf() {
        let mut row = rand_row(256, 3, 10.0);
        let orig = row.clone();
        fp4_row(&mut row);
        let linf = orig.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        for (q, o) in row.iter().zip(&orig) {
            // e2m1 relative step ≤ 1/3 of value, absolute ≤ linf/24 near 0
            assert!((q - o).abs() <= linf / 6.0 + 1e-5);
        }
    }

    #[test]
    fn mxfp4_group_scales_pow2() {
        let mut row = rand_row(96, 4, 23.0);
        let orig = row.clone();
        mxfp4_row(&mut row, 32);
        for (qb, ob) in row.chunks(32).zip(orig.chunks(32)) {
            let m = qb.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
            if m == 0.0 {
                continue;
            }
            // max level is 6 or 4 times a power of two
            let e6 = (m / 6.0).log2();
            let e4 = (m / 4.0).log2();
            assert!(
                (e6 - e6.round()).abs() < 1e-4 || (e4 - e4.round()).abs() < 1e-4,
                "m={m} block {ob:?}"
            );
        }
    }

    #[test]
    fn emit_matches_fake_quant_bitwise() {
        // s·(code + z) must reproduce int_asym_row exactly — the packed
        // GEMM's correctness rests on this identity
        for bits in [4u32, 8] {
            for seed in 0..8u64 {
                let row = rand_row(96, 10 + seed, 2.5);
                let mut fake = row.clone();
                int_asym_row(&mut fake, bits);
                let mut codes = Vec::new();
                let (s, z) = int_asym_emit(&row, bits, &mut codes);
                assert_eq!(codes.len(), row.len());
                for (c, f) in codes.iter().zip(&fake) {
                    let deq = s * (*c as f32 + z);
                    assert_eq!(deq, *f, "bits={bits} seed={seed}");
                }
            }
        }
    }

    #[test]
    fn emit_codes_in_range() {
        let row = rand_row(64, 77, 50.0);
        let mut codes = Vec::new();
        int_asym_emit(&row, 4, &mut codes);
        assert!(codes.iter().all(|&c| c <= 15));
        codes.clear();
        int_asym_emit(&row, 8, &mut codes);
        // u8 range is enforced by construction; clamp keeps ≤ 255
        assert_eq!(codes.len(), 64);
    }

    #[test]
    fn zero_rows_stay_zero_and_finite() {
        for f in [Format::Int4, Format::Int8, Format::Fp4, Format::Mxfp4] {
            let mut row = vec![0.0f32; 64];
            act_quant_row(&mut row, f);
            assert!(row.iter().all(|v| v.is_finite() && v.abs() < 1e-6));
        }
    }

    #[test]
    fn none_format_is_identity() {
        let mut m = Mat::from_fn(3, 8, |i, j| (i * 8 + j) as f32);
        let orig = m.clone();
        act_quant_mat(&mut m, Format::None);
        assert_eq!(m.data, orig.data);
    }

    #[test]
    fn mx_tighter_than_fp4_on_outlier_rows() {
        // a row with one huge outlier: per-token FP4 scale destroys the
        // small values; MX group scaling preserves them (the paper's
        // "MX formats inherently mitigate outliers").
        let mut base = rand_row(128, 7, 0.5);
        base[5] = 100.0;
        let mut a = base.clone();
        let mut b = base.clone();
        fp4_row(&mut a);
        mxfp4_row(&mut b, 32);
        let err = |q: &[f32]| -> f32 {
            q.iter().zip(&base).map(|(x, y)| (x - y) * (x - y)).sum()
        };
        assert!(err(&b) < err(&a));
    }
}
