//! e2m1 (FP4 per the OCP MX spec): signed grid {0, .5, 1, 1.5, 2, 3, 4, 6}.
//! Threshold logic bit-matches ref.quant_e2m1 / the pallas kernels.

pub const FP4_MAX: f32 = 6.0;
pub const GRID: [f32; 8] = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0];

/// Round-to-nearest onto the signed e2m1 grid (pre-scaled input).
#[inline]
pub fn quantize(y: f32) -> f32 {
    let a = y.abs();
    let q = if a < 0.25 {
        0.0
    } else if a < 0.75 {
        0.5
    } else if a < 1.25 {
        1.0
    } else if a < 1.75 {
        1.5
    } else if a < 2.5 {
        2.0
    } else if a < 3.5 {
        3.0
    } else if a < 5.0 {
        4.0
    } else {
        6.0
    };
    if y < 0.0 {
        -q
    } else {
        q
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_fixed_points() {
        for &g in &GRID {
            assert_eq!(quantize(g), g);
            assert_eq!(quantize(-g), -g);
        }
    }

    #[test]
    fn midpoints_round_down_as_ref() {
        // thresholds chosen with strict `<` so midpoints round UP, matching
        // the jnp.where ladder in ref.py
        assert_eq!(quantize(0.25), 0.5);
        assert_eq!(quantize(0.7499), 0.5);
        assert_eq!(quantize(2.5), 3.0);
        assert_eq!(quantize(5.0), 6.0);
        assert_eq!(quantize(100.0), 6.0);
    }

    #[test]
    fn monotone() {
        let mut prev = quantize(-10.0);
        let mut x = -10.0f32;
        while x < 10.0 {
            let q = quantize(x);
            assert!(q >= prev);
            prev = q;
            x += 0.01;
        }
    }
}
