//! Quantization substrate: data formats (INT4 / FP4 / MXFP4 per Appendix B),
//! dynamic per-token activation fake-quant (bit-matching the L1 pallas
//! kernels / ref.py), per-channel weight codecs with MSE scale search, and
//! the worst-case error bound of Section 3.

pub mod act;
pub mod e2m1;
pub mod weight;

pub use act::act_quant_mat;
pub use weight::WeightCodec;


/// Target data format for weights and activations (paper Table 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Format {
    /// No quantization (BF16-analog baseline).
    None,
    /// INT4: asymmetric dynamic per-token activations, symmetric per-channel
    /// weights (Eq. 4).
    Int4,
    /// INT8: the same Eq. 4 scheme at 8 bits — the W8A8 deployment point.
    /// Native-backend only: no AOT artifact variant is lowered for it.
    Int8,
    /// FP4 (e2m1, OCP): symmetric per-token / per-channel scales (Eq. 5).
    Fp4,
    /// MXFP4: e2m1 with power-of-2 scales per group of 32.
    Mxfp4,
}

impl Format {
    /// The runtime `fmt` scalar fed to the AOT artifacts
    /// (0 none, 1 INT4, 2 FP4, 3 MXFP4 — the L2 `lax.switch` contract;
    /// 4 INT8 is a native-backend extension with no lowered artifact).
    pub fn fmt_id(&self) -> i32 {
        match self {
            Format::None => 0,
            Format::Int4 => 1,
            Format::Fp4 => 2,
            Format::Mxfp4 => 3,
            Format::Int8 => 4,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Format::None => "bf16",
            Format::Int4 => "int4",
            Format::Int8 => "int8",
            Format::Fp4 => "fp4",
            Format::Mxfp4 => "mxfp4",
        }
    }

    pub fn parse(s: &str) -> Option<Format> {
        match s {
            "none" | "bf16" => Some(Format::None),
            "int4" => Some(Format::Int4),
            "int8" => Some(Format::Int8),
            "fp4" => Some(Format::Fp4),
            "mxfp4" => Some(Format::Mxfp4),
            _ => None,
        }
    }

    /// Integer bit width for the INT formats (the packed-kernel cases).
    pub fn int_bits(&self) -> Option<u32> {
        match self {
            Format::Int4 => Some(4),
            Format::Int8 => Some(8),
            _ => None,
        }
    }
}

/// Worst-case ℓ2 quantization error bound (Section 3):
/// ‖X − Q(X)‖₂ ≤ √d/(2^q − 2) · ‖X‖_∞.
pub fn worst_case_error_bound(d: usize, q_bits: u32, linf: f64) -> f64 {
    (d as f64).sqrt() / ((1u64 << q_bits) as f64 - 2.0) * linf
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_ids_match_l2_contract() {
        assert_eq!(Format::None.fmt_id(), 0);
        assert_eq!(Format::Int4.fmt_id(), 1);
        assert_eq!(Format::Fp4.fmt_id(), 2);
        assert_eq!(Format::Mxfp4.fmt_id(), 3);
        // native-only extension; must stay outside the artifact range 0..=3
        assert_eq!(Format::Int8.fmt_id(), 4);
    }

    #[test]
    fn parse_roundtrip() {
        for f in [Format::Int4, Format::Int8, Format::Fp4, Format::Mxfp4] {
            assert_eq!(Format::parse(f.name()), Some(f));
        }
        assert_eq!(Format::parse("int16"), None);
    }

    #[test]
    fn int_bits_only_for_int_formats() {
        assert_eq!(Format::Int4.int_bits(), Some(4));
        assert_eq!(Format::Int8.int_bits(), Some(8));
        assert_eq!(Format::Fp4.int_bits(), None);
        assert_eq!(Format::Mxfp4.int_bits(), None);
        assert_eq!(Format::None.int_bits(), None);
    }

    #[test]
    fn bound_scales_linearly_with_linf() {
        let a = worst_case_error_bound(1024, 4, 1.0);
        let b = worst_case_error_bound(1024, 4, 2.0);
        assert!((b / a - 2.0).abs() < 1e-12);
        // √1024 / 14
        assert!((a - 32.0 / 14.0).abs() < 1e-12);
    }
}
