//! `NativeBackend` — the pure-Rust execution engine for the PeRQ forward
//! graphs. Executes the same math as the L2 jax graphs (model.py), against
//! the same transformed/quantized `WeightSet`, with zero PJRT/XLA or
//! Python-artifact dependency:
//!
//! * merged permutations and rotations are already folded into the weights
//!   (the Fig 7 deployment story), so the graph only performs what must be
//!   online: dynamic per-token activation quantization (`quant::act`) and
//!   the fused R̃3 block rotation (FWHT via `hadamard::fwht`, or the
//!   optimized non-power-of-2 plan) followed by per-token quant — the rust
//!   mirror of the pallas `fused.block_rotate_quant` kernel;
//! * INT4/INT8 merged graphs whose `WeightSet` carries packed twins run
//!   the *packed* path: activations are emitted as u8 codes straight into
//!   a staging buffer (for the R̃3 site, fused right after the in-place
//!   block rotation) and multiplied through the integer GEMM in
//!   `tensor::qmat` — i32 accumulation, per-channel dequant fused into the
//!   store, dense f32 weight copies dropped at load. Float formats (or
//!   weight sets without packed twins, e.g. the parity-test references)
//!   keep the fake-quant f32 path through `tensor::Mat`;
//! * matmuls fan out across the persistent `util::pool` worker pool;
//! * per-layer activation buffers are recycled through a bounded
//!   `util::pool::BufPool`, so steady-state scoring does no allocation;
//! * every inner loop — integer GEMM, f32 matmul, FWHT, activation
//!   staging, rmsnorm/swish — runs through the runtime-dispatched
//!   `tensor::simd` kernel layer (AVX2 / NEON / scalar, `PERQ_SIMD`
//!   override; see ARCHITECTURE.md "Kernel dispatch").
//!
//! Numerics note: rmsnorm/softmax accumulate in f32 like the XLA CPU
//! lowering; parity with the artifact path is asserted to 1e-4 by the
//! backend-parity property tests (rust/tests/backend_parity.rs). The
//! packed path shares the fake-quant rounding bit-for-bit (same scales,
//! zeros, and codes); only the accumulation order differs, which the
//! qgemm property suite (rust/tests/qgemm_props.rs) bounds.

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Result};

use super::{graph_op_counts, ExecBackend, ForwardGraph, OpCounts};
use crate::calib::capture::Captures;
use crate::hadamard::BlockRotator;
use crate::model::config::ModelConfig;
use crate::model::weights::WeightSet;
use crate::quant::{act, Format};
use crate::tensor::{qmat, simd, Mat, QuantActs, QuantMat};
use crate::util::pool::BufPool;

/// The packed per-layer linear weights of an INT4/INT8 merged graph.
struct PackedWeights {
    bits: u32,
    mats: BTreeMap<String, QuantMat>,
}

pub struct NativeBackend {
    cfg: ModelConfig,
    ws: WeightSet,
    graph: ForwardGraph,
    rot3: Option<BlockRotator>,
    format: Format,
    pool: BufPool,
    /// Some → low-bit serving path (integer GEMM over packed weights)
    packed: Option<PackedWeights>,
    /// staging buffer for emitted activation codes (packed path only)
    qa: QuantActs,
}

/// `PERQ_PACKED=0` (or `off`) forces the f32 fake-quant path even when
/// packed weights are available — an escape hatch for debugging parity.
/// Consulted both here and by the pipeline (which keeps the dense f32
/// copies alive when the hatch is set, so the fallback can actually run).
pub fn packed_serving_enabled() -> bool {
    match std::env::var("PERQ_PACKED") {
        Ok(v) => !(v == "0" || v.eq_ignore_ascii_case("off")),
        Err(_) => true,
    }
}

impl NativeBackend {
    pub fn new(cfg: ModelConfig, ws: WeightSet, graph: ForwardGraph) -> Result<NativeBackend> {
        let mut ws = ws;
        let (rot3, format) = match &graph {
            ForwardGraph::Fp => (None, Format::None),
            ForwardGraph::Merged { r3_block, format } => {
                ensure!(*r3_block >= 1 && cfg.d_ffn % r3_block == 0,
                        "R3 block {} must divide d_ffn {}", r3_block, cfg.d_ffn);
                (Some(BlockRotator::hadamard(*r3_block)?), *format)
            }
            ForwardGraph::Online { .. } => {
                bail!("the fully-online graph (Fig 9) is only lowered for the pjrt backend")
            }
        };
        // Engage the packed path when every per-layer linear site carries a
        // packed twin of the graph's integer width; the dense f32 copies of
        // those sites are dropped (the weight-memory reduction — embed/pos/
        // norms/unembed stay dense, matching the full-precision sites).
        let packed = match (&graph, format.int_bits()) {
            (ForwardGraph::Merged { .. }, Some(bits)) => {
                let sites = cfg.linear_sites();
                let complete = !sites.is_empty()
                    && sites
                        .iter()
                        .all(|s| ws.packed(&s.name).map_or(false, |q| q.bits == bits));
                // The pipeline may have already dropped the dense copies
                // (native engines do, process-wide); then packed serving
                // is the only option and the PERQ_PACKED escape hatch
                // cannot apply.
                let dense_missing =
                    sites.iter().any(|s| !ws.tensors.contains_key(&s.name));
                if complete && (packed_serving_enabled() || dense_missing) {
                    let mut mats = BTreeMap::new();
                    for s in &sites {
                        let qm = ws.take_packed(&s.name).expect("checked above");
                        if let Some(dense) = ws.tensors.get(&s.name) {
                            ensure!(
                                qm.rows == dense.rows && qm.cols == dense.cols,
                                "packed weight {} shape mismatch", s.name
                            );
                        }
                        ws.drop_dense(&s.name);
                        mats.insert(s.name.clone(), qm);
                    }
                    Some(PackedWeights { bits, mats })
                } else {
                    ensure!(
                        !dense_missing,
                        "weight set lacks dense f32 copies but its packed twins are \
                         incomplete — cannot serve this graph"
                    );
                    None
                }
            }
            _ => None,
        };
        let qa = QuantActs::new(packed.as_ref().map_or(8, |p| p.bits));
        Ok(NativeBackend { cfg, ws, graph, rot3, format, pool: BufPool::new(), packed, qa })
    }

    /// Build a backend straight from a loaded `.perq` deployment artifact
    /// — the serving entry point that never touches calibration code.
    pub fn from_deployed(dm: &crate::deploy::DeployedModel) -> Result<NativeBackend> {
        NativeBackend::new(dm.cfg.clone(), dm.ws.clone(), dm.graph.clone())
    }

    /// Whether this backend serves from packed low-bit weights.
    pub fn is_packed(&self) -> bool {
        self.packed.is_some()
    }

    /// Run the forward pass over `nt = n_seqs * seq_len` token rows,
    /// returning flat (nt, vocab) logits. `caps` collects the four
    /// per-layer linear-input captures (fp graphs only — the calibrator's
    /// `fwd_capture` contract).
    pub fn forward(&mut self, tokens: &[i32], caps: Option<&mut Captures>) -> Result<Vec<f32>> {
        let (t, d, f, heads) = (
            self.cfg.seq_len,
            self.cfg.d_model,
            self.cfg.d_ffn,
            self.cfg.n_heads,
        );
        let (n_layers, vocab) = (self.cfg.n_layers, self.cfg.vocab);
        ensure!(!tokens.is_empty() && tokens.len() % t == 0,
                "token count {} must be a multiple of seq_len {}", tokens.len(), t);
        let n_seqs = tokens.len() / t;
        let nt = tokens.len();
        let mut caps = caps;

        let mut x = self.take_mat(nt, d);
        let mut h = self.take_mat(nt, d);
        let mut q = self.take_mat(nt, d);
        let mut k = self.take_mat(nt, d);
        let mut v = self.take_mat(nt, d);
        let mut ctx = self.take_mat(nt, d);
        let mut proj = self.take_mat(nt, d);
        let mut g = self.take_mat(nt, f);
        let mut u = self.take_mat(nt, f);
        let mut down = self.take_mat(nt, d);
        let mut rot_scratch: Vec<f32> = Vec::new();

        // embedding gather + learned positional: x = embed[tok] + pos[j]
        let embed = self.ws.get("embed");
        let pos = self.ws.get("pos");
        for (r, &tok) in tokens.iter().enumerate() {
            ensure!(tok >= 0 && (tok as usize) < vocab, "token {tok} out of vocab");
            let xr = x.row_mut(r);
            let er = embed.row(tok as usize);
            let pr = pos.row(r % t);
            for c in 0..d {
                xr[c] = er[c] + pr[c];
            }
        }

        for l in 0..n_layers {
            let lname = |part: &str| format!("l{l}.{part}");
            // -- attention half ------------------------------------------
            rmsnorm_rows(&x, &self.ws.get(&lname("n1")).data, &mut h);
            if let Some(c) = caps.as_deref_mut() {
                c.attn_in[l] = h.clone();
            }
            if let Some(pw) = &self.packed {
                // emit codes once, run three integer GEMMs against them
                self.qa.fill_from_mat(&h);
                qmat::qgemm_into(&self.qa, &pw.mats[&lname("wq")], &mut q);
                qmat::qgemm_into(&self.qa, &pw.mats[&lname("wk")], &mut k);
                qmat::qgemm_into(&self.qa, &pw.mats[&lname("wv")], &mut v);
            } else {
                act::act_quant_mat(&mut h, self.format);
                h.par_matmul_into(self.ws.get(&lname("wq")), &mut q);
                h.par_matmul_into(self.ws.get(&lname("wk")), &mut k);
                h.par_matmul_into(self.ws.get(&lname("wv")), &mut v);
            }
            causal_attention(&q, &k, &v, &mut ctx, n_seqs, t, heads);
            if let Some(c) = caps.as_deref_mut() {
                c.o_in[l] = ctx.clone();
            }
            if let Some(pw) = &self.packed {
                self.qa.fill_from_mat(&ctx);
                qmat::qgemm_into(&self.qa, &pw.mats[&lname("wo")], &mut proj);
            } else {
                act::act_quant_mat(&mut ctx, self.format);
                ctx.par_matmul_into(self.ws.get(&lname("wo")), &mut proj);
            }
            add_assign(&mut x.data, &proj.data);
            // -- SwiGLU half ---------------------------------------------
            rmsnorm_rows(&x, &self.ws.get(&lname("n2")).data, &mut h);
            if let Some(c) = caps.as_deref_mut() {
                c.ffn_in[l] = h.clone();
            }
            if let Some(pw) = &self.packed {
                self.qa.fill_from_mat(&h);
                qmat::qgemm_into(&self.qa, &pw.mats[&lname("wg")], &mut g);
                qmat::qgemm_into(&self.qa, &pw.mats[&lname("wu")], &mut u);
            } else {
                act::act_quant_mat(&mut h, self.format);
                h.par_matmul_into(self.ws.get(&lname("wg")), &mut g);
                h.par_matmul_into(self.ws.get(&lname("wu")), &mut u);
            }
            // SwiGLU gate through the SIMD layer (vector arms use a
            // polynomial exp — ≈2 ulp of libm, deterministic per level)
            simd::swish_mul(&mut g.data, &u.data);
            if let Some(c) = caps.as_deref_mut() {
                c.down_in[l] = g.clone();
            }
            // fused R̃3 hot path: blockwise rotate, then per-token quant —
            // the rust twin of the pallas block_rotate_quant kernel. On the
            // packed path the rotated row is quantized straight into the
            // u8 staging buffer and fed to the integer GEMM.
            if let Some(pw) = &self.packed {
                // packed ⇒ merged graph ⇒ rot3 is always Some (b=1 is the
                // identity rotator, not None)
                let rot = self.rot3.as_ref().expect("merged graphs carry a rotator");
                self.qa.reset(f);
                for r in 0..nt {
                    let row = g.row_mut(r);
                    rot.apply_row(row, &mut rot_scratch);
                    self.qa.push_row(row);
                }
                qmat::qgemm_into(&self.qa, &pw.mats[&lname("wd")], &mut down);
            } else {
                if let Some(rot) = &self.rot3 {
                    for r in 0..nt {
                        let row = g.row_mut(r);
                        rot.apply_row(row, &mut rot_scratch);
                        act::act_quant_row(row, self.format);
                    }
                }
                g.par_matmul_into(self.ws.get(&lname("wd")), &mut down);
            }
            add_assign(&mut x.data, &down.data);
        }

        // final norm + unembed (full precision, as in the L2 graph)
        rmsnorm_rows(&x, &self.ws.get("nf").data, &mut h);
        let mut logits = Mat::zeros(nt, vocab);
        h.par_matmul_into(self.ws.get("wout"), &mut logits);
        if let Some(c) = caps.as_deref_mut() {
            c.n_tokens += nt;
        }

        for m in [x, h, q, k, v, ctx, proj, g, u, down] {
            self.put_mat(m);
        }
        Ok(logits.data)
    }

    fn take_mat(&mut self, rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: self.pool.take(rows * cols) }
    }

    fn put_mat(&mut self, m: Mat) {
        self.pool.put(m.data);
    }
}

impl ExecBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn cfg(&self) -> &ModelConfig {
        &self.cfg
    }

    fn score(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        let want = self.cfg.batch * self.cfg.seq_len;
        ensure!(tokens.len() == want,
                "score takes batch*seq_len = {} tokens, got {}", want, tokens.len());
        self.forward(tokens, None)
    }

    fn op_counts(&self) -> OpCounts {
        graph_op_counts(&self.cfg, &self.graph)
    }
}

/// Row-wise RMSNorm: out[r] = x[r] * rsqrt(mean(x[r]²) + 1e-6) * scale.
/// Matches `model.rmsnorm` (f32 accumulation, eps inside the sqrt). The
/// power sum and the normalize-store run through the SIMD layer; the
/// lane-parallel sum reassociates the reduction (deterministic per
/// dispatch level, within the 1e-4 parity budget), while the store is
/// elementwise and bit-identical.
pub fn rmsnorm_rows(x: &Mat, scale: &[f32], out: &mut Mat) {
    debug_assert_eq!((x.rows, x.cols), (out.rows, out.cols));
    debug_assert_eq!(scale.len(), x.cols);
    let d = x.cols;
    for r in 0..x.rows {
        let xr = x.row(r);
        let ss = simd::sum_squares(xr);
        let inv = 1.0 / (ss / d as f32 + 1e-6).sqrt();
        simd::mul_scale_store(xr, inv, scale, out.row_mut(r));
    }
}

fn add_assign(x: &mut [f32], y: &[f32]) {
    debug_assert_eq!(x.len(), y.len());
    simd::add_assign_f32(x, y);
}

/// Multi-head causal SDPA over `n_seqs` independent windows of length `t`:
/// q/k/v/out are (n_seqs*t, d) with heads laid out contiguously along d.
/// Matches `model.causal_attention` (f32, softmax = exp(s-max)/sum).
pub fn causal_attention(q: &Mat, k: &Mat, v: &Mat, out: &mut Mat,
                        n_seqs: usize, t: usize, heads: usize) {
    let d = q.cols;
    let hd = d / heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut scores = vec![0.0f32; t];
    for s in 0..n_seqs {
        for h in 0..heads {
            let off = h * hd;
            for i in 0..t {
                let qrow = &q.data[(s * t + i) * d + off..(s * t + i) * d + off + hd];
                let mut mx = f32::NEG_INFINITY;
                for j in 0..=i {
                    let krow = &k.data[(s * t + j) * d + off..(s * t + j) * d + off + hd];
                    let mut acc = 0.0f32;
                    for c in 0..hd {
                        acc += qrow[c] * krow[c];
                    }
                    let sc = acc * scale;
                    scores[j] = sc;
                    if sc > mx {
                        mx = sc;
                    }
                }
                let mut denom = 0.0f32;
                for sc in scores[..=i].iter_mut() {
                    *sc = (*sc - mx).exp();
                    denom += *sc;
                }
                let inv = 1.0 / denom;
                let orow = &mut out.data[(s * t + i) * d + off..(s * t + i) * d + off + hd];
                orow.fill(0.0);
                for j in 0..=i {
                    let w = scores[j] * inv;
                    let vrow = &v.data[(s * t + j) * d + off..(s * t + j) * d + off + hd];
                    for c in 0..hd {
                        orow[c] += w * vrow[c];
                    }
                }
            }
        }
    }
}

/// Native calibration capture: run the full-precision forward over the
/// calibration sequences with the given (already transformed) weights and
/// collect the four per-layer linear-input activations — the backend-free
/// twin of the `fwd_capture` artifact path.
pub fn capture_native(cfg: &ModelConfig, ws: &WeightSet, seqs: &[Vec<i32>]) -> Result<Captures> {
    ensure!(!seqs.is_empty(), "no calibration sequences");
    let (l, b, t) = (cfg.n_layers, cfg.batch, cfg.seq_len);
    let mut caps = Captures::empty(cfg);
    let mut be = NativeBackend::new(cfg.clone(), ws.clone(), ForwardGraph::Fp)?;
    for chunk in seqs.chunks(b) {
        let mut tokens: Vec<i32> = Vec::with_capacity(chunk.len() * t);
        for seq in chunk {
            ensure!(seq.len() == t, "calibration sequence length mismatch");
            tokens.extend_from_slice(seq);
        }
        let mut batch_caps = Captures::empty(cfg);
        be.forward(&tokens, Some(&mut batch_caps))?;
        for layer in 0..l {
            append_rows(&mut caps.attn_in[layer], &batch_caps.attn_in[layer]);
            append_rows(&mut caps.o_in[layer], &batch_caps.o_in[layer]);
            append_rows(&mut caps.ffn_in[layer], &batch_caps.ffn_in[layer]);
            append_rows(&mut caps.down_in[layer], &batch_caps.down_in[layer]);
        }
        caps.n_tokens += batch_caps.n_tokens;
    }
    Ok(caps)
}

fn append_rows(dst: &mut Mat, src: &Mat) {
    debug_assert_eq!(dst.cols, src.cols);
    dst.data.extend_from_slice(&src.data);
    dst.rows += src.rows;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn tiny_cfg() -> ModelConfig {
        let j = json::parse(
            r#"{"config": {"name": "t", "n_layers": 2, "d_model": 16,
                "n_heads": 2, "d_ffn": 32, "vocab": 8, "seq_len": 8,
                "batch": 2, "block_sizes": [1, 8]}}"#,
        )
        .unwrap();
        ModelConfig::from_meta(&j).unwrap()
    }

    fn tiny_ws(cfg: &ModelConfig, seed: u64) -> WeightSet {
        crate::model::bundle::synthetic_weights(cfg, seed)
    }

    #[test]
    fn score_shape_and_determinism() {
        let cfg = tiny_cfg();
        let ws = tiny_ws(&cfg, 1);
        let graph = ForwardGraph::Merged { r3_block: 8, format: Format::Int4 };
        let mut be = NativeBackend::new(cfg.clone(), ws, graph).unwrap();
        let tokens: Vec<i32> = (0..cfg.batch * cfg.seq_len).map(|i| (i % cfg.vocab) as i32).collect();
        let a = be.score(&tokens).unwrap();
        let b = be.score(&tokens).unwrap();
        assert_eq!(a.len(), cfg.batch * cfg.seq_len * cfg.vocab);
        assert_eq!(a, b, "scoring must be deterministic");
        assert!(a.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn score_rejects_bad_length() {
        let cfg = tiny_cfg();
        let ws = tiny_ws(&cfg, 2);
        let mut be = NativeBackend::new(cfg, ws, ForwardGraph::Fp).unwrap();
        assert!(be.score(&[0i32; 3]).is_err());
    }

    #[test]
    fn online_graph_rejected() {
        let cfg = tiny_cfg();
        let ws = tiny_ws(&cfg, 3);
        assert!(NativeBackend::new(cfg, ws, ForwardGraph::Online { format: Format::Int4 }).is_err());
    }

    #[test]
    fn capture_shapes_match_contract() {
        let cfg = tiny_cfg();
        let ws = tiny_ws(&cfg, 4);
        let seqs: Vec<Vec<i32>> = (0..3)
            .map(|s| (0..cfg.seq_len).map(|i| ((s + i) % cfg.vocab) as i32).collect())
            .collect();
        let caps = capture_native(&cfg, &ws, &seqs).unwrap();
        assert_eq!(caps.n_tokens, 3 * cfg.seq_len);
        for l in 0..cfg.n_layers {
            assert_eq!(caps.attn_in[l].rows, 3 * cfg.seq_len);
            assert_eq!(caps.attn_in[l].cols, cfg.d_model);
            assert_eq!(caps.down_in[l].cols, cfg.d_ffn);
        }
    }

    /// Quantize every linear site through a fitted codec and attach packed
    /// twins — the shape `Pipeline::round_all` produces for merged graphs.
    fn quantize_and_pack(cfg: &ModelConfig, ws: &WeightSet, format: Format) -> WeightSet {
        let mut out = ws.clone();
        for site in cfg.linear_sites() {
            let w = out.get(&site.name).clone();
            let codec = crate::quant::WeightCodec::fit(format, &w);
            let q = codec.quantize_mat(&w);
            let packed = QuantMat::from_codec(&q, &codec).unwrap();
            out.set(&site.name, q);
            out.set_packed(&site.name, packed);
        }
        out
    }

    #[test]
    fn packed_path_engages_and_tracks_fake_quant() {
        let cfg = tiny_cfg();
        let ws = tiny_ws(&cfg, 6);
        for format in [Format::Int4, Format::Int8] {
            let graph = ForwardGraph::Merged { r3_block: 8, format };
            let wsq = quantize_and_pack(&cfg, &ws, format);
            let mut pb = NativeBackend::new(cfg.clone(), wsq.clone(), graph.clone()).unwrap();
            assert!(pb.is_packed(), "{format:?}: packed path must engage");
            // dense copies of packed sites are dropped; fp sites stay
            assert!(pb.ws.tensors.get("l0.wq").is_none());
            assert!(pb.ws.tensors.get("embed").is_some());
            assert!(pb.ws.tensors.get("wout").is_some());
            // stripping the twins falls back to the fake-quant f32 path
            let mut plain = wsq.clone();
            plain.packed.clear();
            let mut fb = NativeBackend::new(cfg.clone(), plain, graph).unwrap();
            assert!(!fb.is_packed());
            let tokens: Vec<i32> = (0..cfg.batch * cfg.seq_len)
                .map(|i| ((i * 5 + 1) % cfg.vocab) as i32)
                .collect();
            let a = pb.score(&tokens).unwrap();
            let a2 = pb.score(&tokens).unwrap();
            assert_eq!(a, a2, "packed scoring must be deterministic");
            assert!(a.iter().all(|v| v.is_finite()));
            // both paths share the quantizer rounding bit-for-bit; the
            // difference is f32 accumulation order (cliffs can amplify a
            // single element, so the bound is aggregate)
            let b = fb.score(&tokens).unwrap();
            let mad: f64 =
                a.iter().zip(&b).map(|(x, y)| (x - y).abs() as f64).sum::<f64>() / a.len() as f64;
            assert!(mad < 5e-2, "{format:?}: packed drifts from fake-quant (mad {mad})");
        }
    }

    #[test]
    fn partial_packing_falls_back_to_dense() {
        let cfg = tiny_cfg();
        let ws = tiny_ws(&cfg, 7);
        let format = Format::Int4;
        let mut wsq = quantize_and_pack(&cfg, &ws, format);
        wsq.take_packed("l0.wk"); // one missing twin → no packed serving
        let graph = ForwardGraph::Merged { r3_block: 8, format };
        let be = NativeBackend::new(cfg, wsq, graph).unwrap();
        assert!(!be.is_packed());
        assert!(be.ws.tensors.get("l0.wq").is_some(), "dense copies must survive");
    }

    #[test]
    fn fp_graph_is_rotation_free() {
        // Fp scoring must equal Merged{b=1, None} scoring on the same
        // weights (identity rotation, no quantization).
        let cfg = tiny_cfg();
        let ws = tiny_ws(&cfg, 5);
        let tokens: Vec<i32> = (0..cfg.batch * cfg.seq_len).map(|i| (i * 3 % cfg.vocab) as i32).collect();
        let mut fp = NativeBackend::new(cfg.clone(), ws.clone(), ForwardGraph::Fp).unwrap();
        let mut id = NativeBackend::new(
            cfg.clone(), ws, ForwardGraph::Merged { r3_block: 1, format: Format::None },
        )
        .unwrap();
        let a = fp.score(&tokens).unwrap();
        let b = id.score(&tokens).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6);
        }
    }
}
