//! `NativeBackend` — the pure-Rust execution engine for the PeRQ forward
//! graphs. Executes the same math as the L2 jax graphs (model.py), against
//! the same transformed/quantized `WeightSet`, with zero PJRT/XLA or
//! Python-artifact dependency.
//!
//! Execution is **stateful and stepwise** (see `backend::ExecBackend`):
//! a session owns `batch` independent attention-state slots backed by a
//! `tensor::kvcache::KvCache` — per-layer K/V rows stored as packed u8
//! int8 codes (per-row scale/zero via `quant::act::int_asym_emit_into`,
//! `PERQ_KV={int8,f32}` escape hatch). Prompt windows prefill a slot;
//! each `decode_step` advances the active slots by one token, re-running
//! only the new rows — the decode-time workload the paper's App A
//! rotation-cost argument is about. Slots join/leave a live session at
//! step granularity, which is what the coordinator's continuous batching
//! drives.
//!
//! The forward math per row is unchanged from the stateless engine:
//!
//! * merged permutations and rotations are already folded into the weights
//!   (the Fig 7 deployment story), so the graph only performs what must be
//!   online: dynamic per-token activation quantization (`quant::act`) and
//!   the fused R̃3 block rotation (FWHT via `hadamard::fwht`, or the
//!   optimized non-power-of-2 plan) followed by per-token quant;
//! * INT4/INT8 merged graphs whose `WeightSet` carries packed twins run
//!   the *packed* path: activation codes staged straight into `QuantActs`
//!   and multiplied through the integer GEMM in `tensor::qmat`;
//! * every inner loop runs through the runtime-dispatched `tensor::simd`
//!   kernel layer (AVX2 / NEON / scalar, `PERQ_SIMD` override).
//!
//! Allocation discipline: session arenas are allocated once at `begin`;
//! activation buffers, KV gather scratch, and decode logits cycle through
//! the backend's `BufPool`; per-layer weight names and packed matrices are
//! resolved at construction (no `format!` on the hot path). Steady-state
//! `decode_step_into` therefore performs **zero heap allocation** —
//! asserted with a counting allocator in rust/tests/decode_parity.rs and
//! (with instrumentation enabled) rust/tests/obs_props.rs. Engine counters
//! ([`EngineObs`]) are `Arc` handles resolved once at construction from
//! the process-wide `obs::metrics::global()` registry; recording them is a
//! relaxed atomic add, so the zero-alloc contract holds with metrics on.
//!
//! Numerics: `score` (the stateless full-window contract) runs its
//! internal session in `KvMode::F32`, so it is bit-identical to the
//! pre-session engine regardless of `PERQ_KV` — the parity suites and
//! eval streamers observe no behavior change. Sessions opened through
//! `begin` use the configured KV mode; prefill attention reads *through*
//! the cache (quantize-on-write, dequantize-on-read), so a full-window
//! prefill and any prefill+decode split of the same tokens observe
//! bit-identical cache contents — the decode-parity contract of
//! rust/tests/decode_parity.rs.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{anyhow, bail, ensure, Result};

use super::{graph_op_counts, ExecBackend, ForwardGraph, OpCounts, SessionId};
use crate::calib::capture::Captures;
use crate::hadamard::BlockRotator;
use crate::model::config::ModelConfig;
use crate::model::weights::WeightSet;
use crate::obs::metrics::{Counter, Gauge};
use crate::quant::{act, Format};
use crate::tensor::{qmat, simd, KvCache, KvMode, KvSwap, Mat, PagedConfig, QuantActs, QuantMat};
use crate::util::pool::BufPool;

/// Engine-level counters in the process-wide metrics registry, resolved
/// once at backend construction so the hot path never touches the
/// registry's name map. Recording is a single relaxed atomic add.
struct EngineObs {
    decode_steps: Arc<Counter>,
    decode_rows: Arc<Counter>,
    prefill_tokens: Arc<Counter>,
    kv_pages_in_use: Arc<Gauge>,
    kv_pages_total: Arc<Gauge>,
    kv_prefix_hits: Arc<Counter>,
    kv_cow_copies: Arc<Counter>,
}

impl EngineObs {
    fn resolve() -> EngineObs {
        let reg = crate::obs::metrics::global();
        EngineObs {
            decode_steps: reg.counter(
                "perq_native_decode_steps_total",
                "decode_step_into calls executed by native backends",
            ),
            decode_rows: reg.counter(
                "perq_native_decode_rows_total",
                "active slot-rows advanced across all native decode steps",
            ),
            prefill_tokens: reg.counter(
                "perq_native_prefill_tokens_total",
                "prompt tokens prefilled through native sessions",
            ),
            kv_pages_in_use: reg.gauge(
                "perq_kv_pages_in_use",
                "KV pages off the free list (live slots + prefix cache)",
            ),
            kv_pages_total: reg.gauge(
                "perq_kv_pages_total",
                "KV page pool size of the most recent paged session",
            ),
            kv_prefix_hits: reg.counter(
                "perq_kv_prefix_hits_total",
                "prompt tokens served from the shared KV prefix cache",
            ),
            kv_cow_copies: reg.counter(
                "perq_kv_cow_copies_total",
                "private page copies triggered by writes into shared KV pages",
            ),
        }
    }

    /// Drain a cache's local event counters and refresh the page gauges —
    /// relaxed atomic ops on pre-resolved handles, zero-alloc safe.
    fn sync_kv(&self, kv: &mut KvCache) {
        let st = kv.take_stats();
        if st.prefix_hit_tokens > 0 {
            self.kv_prefix_hits.add(st.prefix_hit_tokens);
        }
        if st.cow_copies > 0 {
            self.kv_cow_copies.add(st.cow_copies);
        }
        if let Some((used, total)) = kv.page_usage() {
            self.kv_pages_in_use.set(used as i64);
            self.kv_pages_total.set(total as i64);
        }
    }
}

/// The packed linear weights of one layer (INT4/INT8 merged graphs),
/// resolved out of the `WeightSet` maps at construction so the serving
/// loop never does a string lookup.
struct LayerPacked {
    wq: QuantMat,
    wk: QuantMat,
    wv: QuantMat,
    wo: QuantMat,
    wg: QuantMat,
    wu: QuantMat,
    wd: QuantMat,
}

struct PackedWeights {
    bits: u32,
    layers: Vec<LayerPacked>,
}

/// Per-layer weight-name strings for the dense (fake-quant f32) path,
/// precomputed so the hot path never calls `format!`.
struct LayerNames {
    n1: String,
    wq: String,
    wk: String,
    wv: String,
    wo: String,
    n2: String,
    wg: String,
    wu: String,
    wd: String,
}

/// One live execution session: `batch` attention-state slots.
struct Session {
    kv: KvCache,
}

pub struct NativeBackend {
    cfg: ModelConfig,
    ws: WeightSet,
    graph: ForwardGraph,
    rot3: Option<BlockRotator>,
    format: Format,
    pool: BufPool,
    /// Some → low-bit serving path (integer GEMM over packed weights)
    packed: Option<PackedWeights>,
    /// staging buffer for emitted activation codes (packed path only)
    qa: QuantActs,
    /// KV storage mode for sessions opened via `begin` (`PERQ_KV`)
    kv_mode: KvMode,
    /// KV paging layout for sessions opened via `begin`/`begin_with_mode`
    /// (`PERQ_KV_PAGE`/`PERQ_KV_PAGES`; dense by default). Scoring and
    /// capture sessions always stay dense — exact stateless numerics
    /// never route through the page pool or the prefix trie.
    paged: PagedConfig,
    names: Vec<LayerNames>,
    sessions: Vec<Option<Session>>,
    /// persistent F32-mode session backing the stateless `score` contract
    score_sid: Option<SessionId>,
    /// persistent F32-mode session (with its slot count) backing the
    /// capture `forward` path — calibration loops over many batches and
    /// must not reallocate KV arenas per batch
    capture_sid: Option<(SessionId, usize)>,
    // -- reusable hot-path scratch (steady-state decode: zero alloc) ----
    rot_scratch: Vec<f32>,
    attn_scores: Vec<f32>,
    active_scratch: Vec<usize>,
    tok_scratch: Vec<i32>,
    slot_seen: Vec<bool>,
    obs: EngineObs,
    /// cooperative step-interrupt probe (`ExecBackend::set_step_interrupt`):
    /// checked once per layer in `run_rows` with a relaxed load, so the
    /// zero-alloc decode contract holds with cancellation enabled
    interrupt: Option<Arc<AtomicBool>>,
}

/// Deterministic fault injection for the engine step path — the test-only
/// harness behind `PERQ_FAULT` that the fail-safe serving suite
/// (rust/tests/failsafe.rs) and the CI fault leg drive to prove the
/// completion contract.
///
/// Spec grammar (comma-separated clauses, unknown clauses are warned and
/// ignored):
///   * `panic_step:N`    — panic at exactly the N-th engine step
///   * `fail_step:N`     — return an error at exactly the N-th step
///   * `slow_step:N:MS`  — sleep MS milliseconds on every step ≥ N
///
/// Steps are counted process-wide across all backends from the moment the
/// plan is armed ([`arm`] resets the counter), which keeps injection
/// deterministic for single-replica tests and merely *eventual* for
/// multi-replica ones (some step hits N). When disarmed — the normal
/// state — [`on_step`] is a single relaxed atomic load.
pub mod fault {
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::sync::{Mutex, Once};

    use anyhow::{bail, Result};

    /// One armed injection plan (see the module docs for the grammar).
    #[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
    pub struct FaultPlan {
        /// panic at exactly this (1-based) engine step
        pub panic_step: Option<u64>,
        /// return an engine error at exactly this step
        pub fail_step: Option<u64>,
        /// (from, ms): sleep `ms` on every step ≥ `from`
        pub slow_step: Option<(u64, u64)>,
    }

    impl FaultPlan {
        pub fn is_empty(&self) -> bool {
            *self == FaultPlan::default()
        }
    }

    static ACTIVE: AtomicBool = AtomicBool::new(false);
    static STEP: AtomicU64 = AtomicU64::new(0);
    static PLAN: Mutex<FaultPlan> =
        Mutex::new(FaultPlan { panic_step: None, fail_step: None, slow_step: None });
    static ENV_ONCE: Once = Once::new();

    /// Parse a `PERQ_FAULT` spec. Returns the plan plus every clause that
    /// failed to parse (callers log those — a typo must not silently
    /// disable an intended fault).
    pub fn parse(spec: &str) -> (FaultPlan, Vec<String>) {
        let mut plan = FaultPlan::default();
        let mut rejected = Vec::new();
        for clause in spec.split(',').map(str::trim).filter(|c| !c.is_empty()) {
            let mut parts = clause.split(':');
            let parsed = match parts.next() {
                Some("panic_step") => {
                    match (parts.next().and_then(|n| n.parse::<u64>().ok()), parts.next()) {
                        (Some(n), None) if n >= 1 => {
                            plan.panic_step = Some(n);
                            true
                        }
                        _ => false,
                    }
                }
                Some("fail_step") => {
                    match (parts.next().and_then(|n| n.parse::<u64>().ok()), parts.next()) {
                        (Some(n), None) if n >= 1 => {
                            plan.fail_step = Some(n);
                            true
                        }
                        _ => false,
                    }
                }
                Some("slow_step") => {
                    let from = parts.next().and_then(|n| n.parse::<u64>().ok());
                    let ms = parts.next().and_then(|n| n.parse::<u64>().ok());
                    match (from, ms, parts.next()) {
                        (Some(from), Some(ms), None) if from >= 1 => {
                            plan.slow_step = Some((from, ms));
                            true
                        }
                        _ => false,
                    }
                }
                _ => false,
            };
            if !parsed {
                rejected.push(clause.to_string());
            }
        }
        (plan, rejected)
    }

    /// Arm `plan`, resetting the step counter. Process-global: tests that
    /// arm faults must serialize against each other.
    pub fn arm(plan: FaultPlan) {
        *PLAN.lock().unwrap() = plan;
        STEP.store(0, Ordering::SeqCst);
        ACTIVE.store(!plan.is_empty(), Ordering::SeqCst);
    }

    /// Disarm injection (the hot path returns to one relaxed load).
    pub fn disarm() {
        ACTIVE.store(false, Ordering::SeqCst);
        *PLAN.lock().unwrap() = FaultPlan::default();
    }

    /// Arm from `PERQ_FAULT` once per process (backend construction calls
    /// this; explicit [`arm`] in tests takes precedence afterwards).
    pub fn load_env_once() {
        ENV_ONCE.call_once(|| {
            if let Ok(spec) = std::env::var("PERQ_FAULT") {
                let (plan, rejected) = parse(&spec);
                for clause in rejected {
                    crate::log_warn!(
                        "PERQ_FAULT: ignoring unparsable clause {clause:?} \
                         (grammar: panic_step:N, fail_step:N, slow_step:N:MS)"
                    );
                }
                if !plan.is_empty() {
                    crate::log_warn!("PERQ_FAULT armed: {plan:?}");
                    arm(plan);
                }
            }
        });
    }

    /// The engine-step hook: called once per `run_rows` invocation.
    #[inline]
    pub fn on_step() -> Result<()> {
        if !ACTIVE.load(Ordering::Relaxed) {
            return Ok(());
        }
        step_armed()
    }

    #[cold]
    fn step_armed() -> Result<()> {
        let plan = *PLAN.lock().unwrap();
        let n = STEP.fetch_add(1, Ordering::SeqCst) + 1;
        if let Some((from, ms)) = plan.slow_step {
            if n >= from {
                std::thread::sleep(std::time::Duration::from_millis(ms));
            }
        }
        if plan.fail_step == Some(n) {
            bail!("PERQ_FAULT: injected engine failure at step {n}");
        }
        if plan.panic_step == Some(n) {
            panic!("PERQ_FAULT: injected panic at engine step {n}");
        }
        Ok(())
    }
}

/// `PERQ_PACKED=0` (or `off`) forces the f32 fake-quant path even when
/// packed weights are available — an escape hatch for debugging parity.
/// Consulted both here and by the pipeline (which keeps the dense f32
/// copies alive when the hatch is set, so the fallback can actually run).
pub fn packed_serving_enabled() -> bool {
    match std::env::var("PERQ_PACKED") {
        Ok(v) => !(v == "0" || v.eq_ignore_ascii_case("off")),
        Err(_) => true,
    }
}

impl NativeBackend {
    pub fn new(cfg: ModelConfig, ws: WeightSet, graph: ForwardGraph) -> Result<NativeBackend> {
        fault::load_env_once();
        let mut ws = ws;
        let (rot3, format) = match &graph {
            ForwardGraph::Fp => (None, Format::None),
            ForwardGraph::Merged { r3_block, format } => {
                ensure!(*r3_block >= 1 && cfg.d_ffn % r3_block == 0,
                        "R3 block {} must divide d_ffn {}", r3_block, cfg.d_ffn);
                (Some(BlockRotator::hadamard(*r3_block)?), *format)
            }
            ForwardGraph::Online { .. } => {
                bail!("the fully-online graph (Fig 9) is only lowered for the pjrt backend")
            }
        };
        // Engage the packed path when every per-layer linear site carries a
        // packed twin of the graph's integer width; the dense f32 copies of
        // those sites are dropped (the weight-memory reduction — embed/pos/
        // norms/unembed stay dense, matching the full-precision sites).
        let packed = match (&graph, format.int_bits()) {
            (ForwardGraph::Merged { .. }, Some(bits)) => {
                let sites = cfg.linear_sites();
                let complete = !sites.is_empty()
                    && sites
                        .iter()
                        .all(|s| ws.packed(&s.name).map_or(false, |q| q.bits == bits));
                // The pipeline may have already dropped the dense copies
                // (native engines do, process-wide); then packed serving
                // is the only option and the PERQ_PACKED escape hatch
                // cannot apply.
                let dense_missing =
                    sites.iter().any(|s| !ws.tensors.contains_key(&s.name));
                if complete && (packed_serving_enabled() || dense_missing) {
                    let mut take = |name: &str| -> Result<QuantMat> {
                        let qm = ws.take_packed(name).expect("completeness checked above");
                        if let Some(dense) = ws.tensors.get(name) {
                            ensure!(
                                qm.rows == dense.rows && qm.cols == dense.cols,
                                "packed weight {name} shape mismatch"
                            );
                        }
                        ws.drop_dense(name);
                        Ok(qm)
                    };
                    let mut layers = Vec::with_capacity(cfg.n_layers);
                    for l in 0..cfg.n_layers {
                        layers.push(LayerPacked {
                            wq: take(&format!("l{l}.wq"))?,
                            wk: take(&format!("l{l}.wk"))?,
                            wv: take(&format!("l{l}.wv"))?,
                            wo: take(&format!("l{l}.wo"))?,
                            wg: take(&format!("l{l}.wg"))?,
                            wu: take(&format!("l{l}.wu"))?,
                            wd: take(&format!("l{l}.wd"))?,
                        });
                    }
                    Some(PackedWeights { bits, layers })
                } else {
                    ensure!(
                        !dense_missing,
                        "weight set lacks dense f32 copies but its packed twins are \
                         incomplete — cannot serve this graph"
                    );
                    None
                }
            }
            _ => None,
        };
        let qa = QuantActs::new(packed.as_ref().map_or(8, |p| p.bits));
        let names = (0..cfg.n_layers)
            .map(|l| LayerNames {
                n1: format!("l{l}.n1"),
                wq: format!("l{l}.wq"),
                wk: format!("l{l}.wk"),
                wv: format!("l{l}.wv"),
                wo: format!("l{l}.wo"),
                n2: format!("l{l}.n2"),
                wg: format!("l{l}.wg"),
                wu: format!("l{l}.wu"),
                wd: format!("l{l}.wd"),
            })
            .collect();
        Ok(NativeBackend {
            cfg,
            ws,
            graph,
            rot3,
            format,
            pool: BufPool::new(),
            packed,
            qa,
            kv_mode: KvMode::from_env(),
            paged: PagedConfig::from_env(),
            names,
            sessions: Vec::new(),
            score_sid: None,
            capture_sid: None,
            rot_scratch: Vec::new(),
            attn_scores: Vec::new(),
            active_scratch: Vec::new(),
            tok_scratch: Vec::new(),
            slot_seen: Vec::new(),
            obs: EngineObs::resolve(),
            interrupt: None,
        })
    }

    /// Build a backend straight from a loaded `.perq` deployment artifact
    /// — the serving entry point that never touches calibration code.
    pub fn from_deployed(dm: &crate::deploy::DeployedModel) -> Result<NativeBackend> {
        NativeBackend::new(dm.cfg.clone(), dm.ws.clone(), dm.graph.clone())
    }

    /// Whether this backend serves from packed low-bit weights.
    pub fn is_packed(&self) -> bool {
        self.packed.is_some()
    }

    /// KV storage mode of sessions opened via `begin`.
    pub fn kv_mode(&self) -> KvMode {
        self.kv_mode
    }

    /// KV paging layout of sessions opened via `begin`/`begin_with_mode`.
    pub fn kv_paging(&self) -> PagedConfig {
        self.paged
    }

    /// Override the KV paging layout for sessions opened *after* this call
    /// (live sessions keep their layout). Tests and benches use this to
    /// run dense and paged sessions on one backend without env races.
    pub fn set_kv_paging(&mut self, pcfg: PagedConfig) {
        self.paged = pcfg;
    }

    /// Open a session with an explicit KV mode (tests and the stateless
    /// `score` path pin `F32`; `begin` uses the `PERQ_KV` default).
    pub fn begin_with_mode(&mut self, batch: usize, mode: KvMode) -> Result<SessionId> {
        self.begin_session(batch, mode, self.paged)
    }

    fn begin_session(&mut self, batch: usize, mode: KvMode, pcfg: PagedConfig)
                     -> Result<SessionId> {
        ensure!(batch >= 1, "a session needs at least one slot");
        let sess = Session {
            kv: KvCache::new_paged(
                mode, self.cfg.n_layers, batch, self.cfg.seq_len, self.cfg.d_model, pcfg,
            ),
        };
        match self.sessions.iter().position(|s| s.is_none()) {
            Some(i) => {
                self.sessions[i] = Some(sess);
                Ok(i as SessionId)
            }
            None => {
                self.sessions.push(Some(sess));
                Ok((self.sessions.len() - 1) as SessionId)
            }
        }
    }

    /// Bytes resident in a session's KV arenas (diagnostics/serving stats).
    pub fn session_kv_bytes(&self, sid: SessionId) -> Result<usize> {
        Ok(self.session_ref(sid)?.kv.bytes())
    }

    fn session_ref(&self, sid: SessionId) -> Result<&Session> {
        self.sessions
            .get(sid as usize)
            .and_then(|s| s.as_ref())
            .ok_or_else(|| anyhow!("unknown session {sid}"))
    }

    fn take_session(&mut self, sid: SessionId) -> Result<Session> {
        self.sessions
            .get_mut(sid as usize)
            .and_then(|s| s.take())
            .ok_or_else(|| anyhow!("unknown session {sid}"))
    }

    /// Run the full-precision forward over `nt = n_seqs * seq_len` token
    /// rows with calibration capture — the calibrator's `fwd_capture`
    /// contract. Runs in a persistent F32-KV session (exact numerics),
    /// recreated only when the batch size changes, so calibration loops
    /// reuse the KV arenas instead of reallocating per batch.
    pub fn forward(&mut self, tokens: &[i32], caps: Option<&mut Captures>) -> Result<Vec<f32>> {
        let t = self.cfg.seq_len;
        ensure!(!tokens.is_empty() && tokens.len() % t == 0,
                "token count {} must be a multiple of seq_len {}", tokens.len(), t);
        let n_seqs = tokens.len() / t;
        let sid = match self.capture_sid {
            Some((sid, batch)) if batch == n_seqs => sid,
            stale => {
                if let Some((old, _)) = stale {
                    if (old as usize) < self.sessions.len() {
                        self.sessions[old as usize] = None;
                    }
                }
                let sid = self.begin_session(n_seqs, KvMode::F32, PagedConfig::dense())?;
                self.capture_sid = Some((sid, n_seqs));
                sid
            }
        };
        let mut sess = self.take_session(sid)?;
        sess.kv.reset_all();
        let slots: Vec<usize> = (0..n_seqs).collect();
        let result = self.run_rows(&mut sess, &slots, t, tokens, caps);
        self.sessions[sid as usize] = Some(sess);
        result.map(|m| m.data)
    }

    /// The session engine core: append `n_new` tokens to each listed slot
    /// (slot-major `tokens`), running the full graph over the new rows
    /// with attention against each slot's KV cache, and return the
    /// `(slots.len() * n_new, vocab)` logits. The returned `Mat`'s buffer
    /// came from the pool; decode gives it back, scoring moves it out.
    fn run_rows(&mut self, sess: &mut Session, slots: &[usize], n_new: usize,
                tokens: &[i32], mut caps: Option<&mut Captures>) -> Result<Mat> {
        // fault-injection hook (one relaxed load when disarmed) — every
        // engine step (prefill, decode, score) passes through here
        fault::on_step()?;
        let (d, f, heads) = (self.cfg.d_model, self.cfg.d_ffn, self.cfg.n_heads);
        let (n_layers, vocab) = (self.cfg.n_layers, self.cfg.vocab);
        let hd = d / heads;
        ensure!(n_new >= 1, "no tokens to run");
        ensure!(!slots.is_empty() && tokens.len() == slots.len() * n_new,
                "token count {} must equal slots*n_new = {}", tokens.len(),
                slots.len() * n_new);
        // validate slots (in range, distinct) and reserve cache room:
        // `prepare_append` checks logical capacity and, when paged, maps
        // fresh pages / CoWs a shared tail page — all failures (including
        // a typed OutOfPages) happen here, before any row is written, so
        // the step is retryable after the scheduler preempts a slot
        self.slot_seen.iter_mut().for_each(|s| *s = false);
        if self.slot_seen.len() < sess.kv.slots {
            self.slot_seen.resize(sess.kv.slots, false);
        }
        for &slot in slots {
            ensure!(slot < sess.kv.slots, "slot {slot} out of range ({} slots)", sess.kv.slots);
            ensure!(!self.slot_seen[slot], "slot {slot} listed twice");
            self.slot_seen[slot] = true;
            sess.kv.prepare_append(slot, n_new)?;
        }
        let nt = slots.len() * n_new;

        let mut x = self.take_mat(nt, d);
        let mut h = self.take_mat(nt, d);
        let mut q = self.take_mat(nt, d);
        let mut k = self.take_mat(nt, d);
        let mut v = self.take_mat(nt, d);
        let mut ctx = self.take_mat(nt, d);
        let mut proj = self.take_mat(nt, d);
        let mut g = self.take_mat(nt, f);
        let mut u = self.take_mat(nt, f);
        let mut down = self.take_mat(nt, d);

        // embedding gather + learned positional: x = embed[tok] + pos[p]
        // where p is the slot's absolute position (cache length + offset)
        let embed = self.ws.get("embed");
        let pos = self.ws.get("pos");
        for (si, &slot) in slots.iter().enumerate() {
            let base = sess.kv.len(slot);
            for j in 0..n_new {
                let r = si * n_new + j;
                let tok = tokens[r];
                ensure!(tok >= 0 && (tok as usize) < vocab, "token {tok} out of vocab");
                let xr = x.row_mut(r);
                let er = embed.row(tok as usize);
                let pr = pos.row(base + j);
                for c in 0..d {
                    xr[c] = er[c] + pr[c];
                }
            }
        }

        if self.attn_scores.len() < sess.kv.cap {
            self.attn_scores.resize(sess.kv.cap, 0.0);
        }

        // KV gather scratch, taken once per call at full session capacity:
        // a constant size keeps the pool recycling one buffer across the
        // whole decode, and taking outside the layer/slot loops avoids
        // re-zeroing cap*d floats per (layer, slot) — each slot's gather
        // overwrites the prefix before its attention reads it
        let mut kbuf = self.pool.take(sess.kv.cap * d);
        let mut vbuf = self.pool.take(sess.kv.cap * d);

        for l in 0..n_layers {
            // cooperative cancellation point: a relaxed load per layer —
            // cheap enough for the zero-alloc decode contract, frequent
            // enough that a drain abort never waits on a full forward pass
            if let Some(flag) = &self.interrupt {
                if flag.load(Ordering::Relaxed) {
                    bail!("engine step interrupted");
                }
            }
            // -- attention half ------------------------------------------
            rmsnorm_rows(&x, &self.ws.get(&self.names[l].n1).data, &mut h);
            if let Some(c) = caps.as_deref_mut() {
                c.attn_in[l] = h.clone();
            }
            if let Some(pw) = &self.packed {
                // emit codes once, run three integer GEMMs against them
                self.qa.fill_from_mat(&h);
                qmat::qgemm_into(&self.qa, &pw.layers[l].wq, &mut q);
                qmat::qgemm_into(&self.qa, &pw.layers[l].wk, &mut k);
                qmat::qgemm_into(&self.qa, &pw.layers[l].wv, &mut v);
            } else {
                act::act_quant_mat(&mut h, self.format);
                h.par_matmul_into(self.ws.get(&self.names[l].wq), &mut q);
                h.par_matmul_into(self.ws.get(&self.names[l].wk), &mut k);
                h.par_matmul_into(self.ws.get(&self.names[l].wv), &mut v);
            }
            // write the new K/V rows into the cache (quantize-on-write in
            // int8 mode), then attend against the cache — prefill and
            // decode read identical cache contents by construction
            for (si, &slot) in slots.iter().enumerate() {
                let base = sess.kv.len(slot);
                for j in 0..n_new {
                    let r = si * n_new + j;
                    sess.kv.write_k(l, slot, base + j, k.row(r));
                    sess.kv.write_v(l, slot, base + j, v.row(r));
                }
            }
            for (si, &slot) in slots.iter().enumerate() {
                let base = sess.kv.len(slot);
                let total = base + n_new;
                sess.kv.gather_k(l, slot, total, &mut kbuf[..total * d]);
                sess.kv.gather_v(l, slot, total, &mut vbuf[..total * d]);
                for j in 0..n_new {
                    let r = si * n_new + j;
                    attend_rows(
                        q.row(r), &kbuf, &vbuf, base + j + 1, d, heads, hd,
                        &mut self.attn_scores, ctx.row_mut(r),
                    );
                }
            }
            if let Some(c) = caps.as_deref_mut() {
                c.o_in[l] = ctx.clone();
            }
            if let Some(pw) = &self.packed {
                self.qa.fill_from_mat(&ctx);
                qmat::qgemm_into(&self.qa, &pw.layers[l].wo, &mut proj);
            } else {
                act::act_quant_mat(&mut ctx, self.format);
                ctx.par_matmul_into(self.ws.get(&self.names[l].wo), &mut proj);
            }
            add_assign(&mut x.data, &proj.data);
            // -- SwiGLU half ---------------------------------------------
            rmsnorm_rows(&x, &self.ws.get(&self.names[l].n2).data, &mut h);
            if let Some(c) = caps.as_deref_mut() {
                c.ffn_in[l] = h.clone();
            }
            if let Some(pw) = &self.packed {
                self.qa.fill_from_mat(&h);
                qmat::qgemm_into(&self.qa, &pw.layers[l].wg, &mut g);
                qmat::qgemm_into(&self.qa, &pw.layers[l].wu, &mut u);
            } else {
                act::act_quant_mat(&mut h, self.format);
                h.par_matmul_into(self.ws.get(&self.names[l].wg), &mut g);
                h.par_matmul_into(self.ws.get(&self.names[l].wu), &mut u);
            }
            // SwiGLU gate through the SIMD layer (vector arms use a
            // polynomial exp — ≈2 ulp of libm, deterministic per level)
            simd::swish_mul(&mut g.data, &u.data);
            if let Some(c) = caps.as_deref_mut() {
                c.down_in[l] = g.clone();
            }
            // fused R̃3 hot path: blockwise rotate, then per-token quant —
            // the rust twin of the pallas block_rotate_quant kernel. On the
            // packed path the rotated row is quantized straight into the
            // u8 staging buffer and fed to the integer GEMM.
            if let Some(pw) = &self.packed {
                // packed ⇒ merged graph ⇒ rot3 is always Some (b=1 is the
                // identity rotator, not None)
                let rot = self.rot3.as_ref().expect("merged graphs carry a rotator");
                self.qa.reset(f);
                for r in 0..nt {
                    let row = g.row_mut(r);
                    rot.apply_row(row, &mut self.rot_scratch);
                    self.qa.push_row(row);
                }
                qmat::qgemm_into(&self.qa, &pw.layers[l].wd, &mut down);
            } else {
                if let Some(rot) = &self.rot3 {
                    for r in 0..nt {
                        let row = g.row_mut(r);
                        rot.apply_row(row, &mut self.rot_scratch);
                        act::act_quant_row(row, self.format);
                    }
                }
                g.par_matmul_into(self.ws.get(&self.names[l].wd), &mut down);
            }
            add_assign(&mut x.data, &down.data);
        }

        // commit the freshly written positions (validated up front)
        for &slot in slots {
            sess.kv.advance(slot, n_new)?;
        }
        // drain prefix/CoW event counters + refresh the page gauges
        // (relaxed atomics on pre-resolved handles — zero-alloc)
        self.obs.sync_kv(&mut sess.kv);

        // final norm + unembed (full precision, as in the L2 graph)
        rmsnorm_rows(&x, &self.ws.get("nf").data, &mut h);
        let mut logits = self.take_mat(nt, vocab);
        h.par_matmul_into(self.ws.get("wout"), &mut logits);
        if let Some(c) = caps.as_deref_mut() {
            c.n_tokens += nt;
        }

        self.pool.put(kbuf);
        self.pool.put(vbuf);
        for m in [x, h, q, k, v, ctx, proj, g, u, down] {
            self.put_mat(m);
        }
        Ok(logits)
    }

    fn take_mat(&mut self, rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: self.pool.take(rows * cols) }
    }

    fn put_mat(&mut self, m: Mat) {
        self.pool.put(m.data);
    }
}

impl ExecBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn cfg(&self) -> &ModelConfig {
        &self.cfg
    }

    fn op_counts(&self) -> OpCounts {
        graph_op_counts(&self.cfg, &self.graph)
    }

    fn begin(&mut self, batch: usize) -> Result<SessionId> {
        self.begin_with_mode(batch, self.kv_mode)
    }

    /// Scoring sessions are pinned to the exact f32 cache regardless of
    /// `PERQ_KV` — and to the dense layout regardless of paging — so
    /// served NLLs match `score`/eval bit-for-bit.
    fn begin_scoring(&mut self, batch: usize) -> Result<SessionId> {
        self.begin_session(batch, KvMode::F32, PagedConfig::dense())
    }

    fn set_step_interrupt(&mut self, interrupt: Option<Arc<AtomicBool>>) {
        self.interrupt = interrupt;
    }

    fn session_batch(&self, sid: SessionId) -> Result<usize> {
        Ok(self.session_ref(sid)?.kv.slots)
    }

    fn slot_len(&self, sid: SessionId, slot: usize) -> Result<usize> {
        let sess = self.session_ref(sid)?;
        ensure!(slot < sess.kv.slots, "slot {slot} out of range");
        Ok(sess.kv.len(slot))
    }

    fn prefill_slots(&mut self, sid: SessionId, slots: &[usize], tokens: &[i32])
                     -> Result<Vec<f32>> {
        ensure!(!slots.is_empty(), "prefill needs at least one slot");
        ensure!(tokens.len() % slots.len() == 0,
                "token count {} must split evenly across {} slots",
                tokens.len(), slots.len());
        let n_new = tokens.len() / slots.len();
        let mut sess = self.take_session(sid)?;
        let result = self.run_rows(&mut sess, slots, n_new, tokens, None);
        self.sessions[sid as usize] = Some(sess);
        if result.is_ok() {
            self.obs.prefill_tokens.add(tokens.len() as u64);
        }
        result.map(|m| m.data)
    }

    fn decode_step_into(&mut self, sid: SessionId, last_tokens: &[i32], out: &mut Vec<f32>)
                        -> Result<()> {
        let vocab = self.cfg.vocab;
        let mut sess = self.take_session(sid)?;
        let batch = sess.kv.slots;
        if last_tokens.len() != batch {
            self.sessions[sid as usize] = Some(sess);
            bail!("decode_step takes one token per slot ({batch}), got {}", last_tokens.len());
        }
        // compact the active slots (negative token = idle, skipped)
        let mut active = std::mem::take(&mut self.active_scratch);
        let mut toks = std::mem::take(&mut self.tok_scratch);
        active.clear();
        toks.clear();
        for (slot, &tok) in last_tokens.iter().enumerate() {
            if tok >= 0 {
                active.push(slot);
                toks.push(tok);
            }
        }
        out.clear();
        out.resize(batch * vocab, 0.0);
        let result = if active.is_empty() {
            Ok(())
        } else {
            match self.run_rows(&mut sess, &active, 1, &toks, None) {
                Ok(logits) => {
                    for (i, &slot) in active.iter().enumerate() {
                        out[slot * vocab..(slot + 1) * vocab]
                            .copy_from_slice(logits.row(i));
                    }
                    self.put_mat(logits);
                    // relaxed atomic adds on pre-resolved handles — the
                    // zero-alloc decode contract holds with metrics on
                    self.obs.decode_steps.inc();
                    self.obs.decode_rows.add(active.len() as u64);
                    Ok(())
                }
                Err(e) => Err(e),
            }
        };
        self.active_scratch = active;
        self.tok_scratch = toks;
        self.sessions[sid as usize] = Some(sess);
        result
    }

    fn reset_slot(&mut self, sid: SessionId, slot: usize) -> Result<()> {
        let sess = self
            .sessions
            .get_mut(sid as usize)
            .and_then(|s| s.as_mut())
            .ok_or_else(|| anyhow!("unknown session {sid}"))?;
        ensure!(slot < sess.kv.slots, "slot {slot} out of range");
        sess.kv.reset_slot(slot);
        self.obs.sync_kv(&mut sess.kv);
        Ok(())
    }

    /// Prefix-aware generation prefill: serve the longest cached prefix of
    /// `prompt` from the shared page trie, run the forward pass over the
    /// remaining suffix only, and register the prompt for future sharers.
    fn prefill_prefixed(&mut self, sid: SessionId, slot: usize, prompt: &[i32])
                        -> Result<(Vec<f32>, usize)> {
        ensure!(!prompt.is_empty(), "prefill needs at least one token");
        let mut sess = self.take_session(sid)?;
        if slot >= sess.kv.slots {
            let n = sess.kv.slots;
            self.sessions[sid as usize] = Some(sess);
            bail!("slot {slot} out of range ({n} slots)");
        }
        // attach caps at prompt.len()-1, so the suffix is never empty and
        // the caller always gets freshly computed last-position logits
        let matched = sess.kv.attach_prefix(slot, prompt);
        let suffix = &prompt[matched..];
        let result = self.run_rows(&mut sess, &[slot], suffix.len(), suffix, None);
        match &result {
            Ok(_) => sess.kv.register_prefix(slot, prompt),
            // failed before any write (e.g. OutOfPages): release the
            // attached shared pages so refcounts don't leak, leaving the
            // slot empty for a clean retry
            Err(_) if matched > 0 => sess.kv.reset_slot(slot),
            Err(_) => {}
        }
        self.obs.sync_kv(&mut sess.kv);
        self.sessions[sid as usize] = Some(sess);
        if result.is_ok() {
            self.obs.prefill_tokens.add(suffix.len() as u64);
        }
        result.map(|m| (m.data, matched))
    }

    fn kv_free_pages(&self, sid: SessionId) -> Option<usize> {
        self.session_ref(sid).ok().and_then(|s| s.kv.free_pages())
    }

    fn swap_out_slot(&mut self, sid: SessionId, slot: usize) -> Result<Option<KvSwap>> {
        let sess = self
            .sessions
            .get_mut(sid as usize)
            .and_then(|s| s.as_mut())
            .ok_or_else(|| anyhow!("unknown session {sid}"))?;
        ensure!(slot < sess.kv.slots, "slot {slot} out of range");
        if !sess.kv.is_paged() {
            return Ok(None);
        }
        let swap = sess.kv.swap_out(slot);
        self.obs.sync_kv(&mut sess.kv);
        Ok(Some(swap))
    }

    fn swap_in_slot(&mut self, sid: SessionId, slot: usize, swap: &KvSwap) -> Result<()> {
        let sess = self
            .sessions
            .get_mut(sid as usize)
            .and_then(|s| s.as_mut())
            .ok_or_else(|| anyhow!("unknown session {sid}"))?;
        ensure!(slot < sess.kv.slots, "slot {slot} out of range");
        let result = sess.kv.swap_in(slot, swap);
        self.obs.sync_kv(&mut sess.kv);
        result
    }

    fn end(&mut self, sid: SessionId) -> Result<()> {
        let i = sid as usize;
        ensure!(
            self.sessions.get(i).map_or(false, |s| s.is_some()),
            "unknown session {sid}"
        );
        self.sessions[i] = None;
        if self.score_sid == Some(sid) {
            self.score_sid = None;
        }
        if self.capture_sid.map(|(s, _)| s) == Some(sid) {
            self.capture_sid = None;
        }
        Ok(())
    }

    /// The stateless contract, re-expressed as prefill-then-read over a
    /// *persistent F32-KV session* — bit-identical to the pre-session
    /// engine (f32 cache reads are exact copies), so eval streamers and
    /// the parity suites observe no behavior change, and repeat scoring
    /// reuses the session arenas instead of reallocating.
    fn score(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        let (b, t) = (self.cfg.batch, self.cfg.seq_len);
        ensure!(tokens.len() == b * t,
                "score takes batch*seq_len = {} tokens, got {}", b * t, tokens.len());
        let sid = match self.score_sid {
            Some(sid) => sid,
            None => {
                let sid = self.begin_session(b, KvMode::F32, PagedConfig::dense())?;
                self.score_sid = Some(sid);
                sid
            }
        };
        let mut sess = self.take_session(sid)?;
        sess.kv.reset_all();
        let slots: Vec<usize> = (0..b).collect();
        let result = self.run_rows(&mut sess, &slots, t, tokens, None);
        self.sessions[sid as usize] = Some(sess);
        result.map(|m| m.data)
    }
}

/// Row-wise RMSNorm: out[r] = x[r] * rsqrt(mean(x[r]²) + 1e-6) * scale.
/// Matches `model.rmsnorm` (f32 accumulation, eps inside the sqrt). The
/// power sum and the normalize-store run through the SIMD layer; the
/// lane-parallel sum reassociates the reduction (deterministic per
/// dispatch level, within the 1e-4 parity budget), while the store is
/// elementwise and bit-identical.
pub fn rmsnorm_rows(x: &Mat, scale: &[f32], out: &mut Mat) {
    debug_assert_eq!((x.rows, x.cols), (out.rows, out.cols));
    debug_assert_eq!(scale.len(), x.cols);
    let d = x.cols;
    for r in 0..x.rows {
        let xr = x.row(r);
        let ss = simd::sum_squares(xr);
        let inv = 1.0 / (ss / d as f32 + 1e-6).sqrt();
        simd::mul_scale_store(xr, inv, scale, out.row_mut(r));
    }
}

fn add_assign(x: &mut [f32], y: &[f32]) {
    debug_assert_eq!(x.len(), y.len());
    simd::add_assign_f32(x, y);
}

/// Multi-head causal SDPA for **one query row** against `len` cached K/V
/// rows (`kbuf`/`vbuf` are `len × d`, heads contiguous along d) — the
/// incremental form the prefill loop and `decode_step` share, so a
/// full-window prefill and any prefill+decode split are bit-identical.
/// Per (head, position) the arithmetic is exactly the pre-session
/// `causal_attention` (f32, softmax = exp(s-max)/sum, running max inside
/// the score loop).
#[allow(clippy::too_many_arguments)]
fn attend_rows(qrow: &[f32], kbuf: &[f32], vbuf: &[f32], len: usize, d: usize,
               heads: usize, hd: usize, scores: &mut [f32], out: &mut [f32]) {
    let scale = 1.0 / (hd as f32).sqrt();
    for h in 0..heads {
        let off = h * hd;
        let qh = &qrow[off..off + hd];
        let mut mx = f32::NEG_INFINITY;
        for j in 0..len {
            let krow = &kbuf[j * d + off..j * d + off + hd];
            let mut acc = 0.0f32;
            for c in 0..hd {
                acc += qh[c] * krow[c];
            }
            let sc = acc * scale;
            scores[j] = sc;
            if sc > mx {
                mx = sc;
            }
        }
        let mut denom = 0.0f32;
        for sc in scores[..len].iter_mut() {
            *sc = (*sc - mx).exp();
            denom += *sc;
        }
        let inv = 1.0 / denom;
        let oh = &mut out[off..off + hd];
        oh.fill(0.0);
        for j in 0..len {
            let w = scores[j] * inv;
            let vrow = &vbuf[j * d + off..j * d + off + hd];
            for c in 0..hd {
                oh[c] += w * vrow[c];
            }
        }
    }
}

/// Native calibration capture: run the full-precision forward over the
/// calibration sequences with the given (already transformed) weights and
/// collect the four per-layer linear-input activations — the backend-free
/// twin of the `fwd_capture` artifact path.
pub fn capture_native(cfg: &ModelConfig, ws: &WeightSet, seqs: &[Vec<i32>]) -> Result<Captures> {
    ensure!(!seqs.is_empty(), "no calibration sequences");
    let (l, b, t) = (cfg.n_layers, cfg.batch, cfg.seq_len);
    let mut caps = Captures::empty(cfg);
    let mut be = NativeBackend::new(cfg.clone(), ws.clone(), ForwardGraph::Fp)?;
    for chunk in seqs.chunks(b) {
        let mut tokens: Vec<i32> = Vec::with_capacity(chunk.len() * t);
        for seq in chunk {
            ensure!(seq.len() == t, "calibration sequence length mismatch");
            tokens.extend_from_slice(seq);
        }
        let mut batch_caps = Captures::empty(cfg);
        be.forward(&tokens, Some(&mut batch_caps))?;
        for layer in 0..l {
            append_rows(&mut caps.attn_in[layer], &batch_caps.attn_in[layer]);
            append_rows(&mut caps.o_in[layer], &batch_caps.o_in[layer]);
            append_rows(&mut caps.ffn_in[layer], &batch_caps.ffn_in[layer]);
            append_rows(&mut caps.down_in[layer], &batch_caps.down_in[layer]);
        }
        caps.n_tokens += batch_caps.n_tokens;
    }
    Ok(caps)
}

fn append_rows(dst: &mut Mat, src: &Mat) {
    debug_assert_eq!(dst.cols, src.cols);
    dst.data.extend_from_slice(&src.data);
    dst.rows += src.rows;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn tiny_cfg() -> ModelConfig {
        let j = json::parse(
            r#"{"config": {"name": "t", "n_layers": 2, "d_model": 16,
                "n_heads": 2, "d_ffn": 32, "vocab": 8, "seq_len": 8,
                "batch": 2, "block_sizes": [1, 8]}}"#,
        )
        .unwrap();
        ModelConfig::from_meta(&j).unwrap()
    }

    fn tiny_ws(cfg: &ModelConfig, seed: u64) -> WeightSet {
        crate::model::bundle::synthetic_weights(cfg, seed)
    }

    #[test]
    fn score_shape_and_determinism() {
        let cfg = tiny_cfg();
        let ws = tiny_ws(&cfg, 1);
        let graph = ForwardGraph::Merged { r3_block: 8, format: Format::Int4 };
        let mut be = NativeBackend::new(cfg.clone(), ws, graph).unwrap();
        let tokens: Vec<i32> = (0..cfg.batch * cfg.seq_len).map(|i| (i % cfg.vocab) as i32).collect();
        let a = be.score(&tokens).unwrap();
        let b = be.score(&tokens).unwrap();
        assert_eq!(a.len(), cfg.batch * cfg.seq_len * cfg.vocab);
        assert_eq!(a, b, "scoring must be deterministic");
        assert!(a.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn score_rejects_bad_length() {
        let cfg = tiny_cfg();
        let ws = tiny_ws(&cfg, 2);
        let mut be = NativeBackend::new(cfg, ws, ForwardGraph::Fp).unwrap();
        assert!(be.score(&[0i32; 3]).is_err());
    }

    #[test]
    fn online_graph_rejected() {
        let cfg = tiny_cfg();
        let ws = tiny_ws(&cfg, 3);
        assert!(NativeBackend::new(cfg, ws, ForwardGraph::Online { format: Format::Int4 }).is_err());
    }

    #[test]
    fn capture_shapes_match_contract() {
        let cfg = tiny_cfg();
        let ws = tiny_ws(&cfg, 4);
        let seqs: Vec<Vec<i32>> = (0..3)
            .map(|s| (0..cfg.seq_len).map(|i| ((s + i) % cfg.vocab) as i32).collect())
            .collect();
        let caps = capture_native(&cfg, &ws, &seqs).unwrap();
        assert_eq!(caps.n_tokens, 3 * cfg.seq_len);
        for l in 0..cfg.n_layers {
            assert_eq!(caps.attn_in[l].rows, 3 * cfg.seq_len);
            assert_eq!(caps.attn_in[l].cols, cfg.d_model);
            assert_eq!(caps.down_in[l].cols, cfg.d_ffn);
        }
    }

    /// Quantize every linear site through a fitted codec and attach packed
    /// twins — the shape `Pipeline::round_all` produces for merged graphs.
    fn quantize_and_pack(cfg: &ModelConfig, ws: &WeightSet, format: Format) -> WeightSet {
        let mut out = ws.clone();
        for site in cfg.linear_sites() {
            let w = out.get(&site.name).clone();
            let codec = crate::quant::WeightCodec::fit(format, &w);
            let q = codec.quantize_mat(&w);
            let packed = QuantMat::from_codec(&q, &codec).unwrap();
            out.set(&site.name, q);
            out.set_packed(&site.name, packed);
        }
        out
    }

    #[test]
    fn packed_path_engages_and_tracks_fake_quant() {
        let cfg = tiny_cfg();
        let ws = tiny_ws(&cfg, 6);
        for format in [Format::Int4, Format::Int8] {
            let graph = ForwardGraph::Merged { r3_block: 8, format };
            let wsq = quantize_and_pack(&cfg, &ws, format);
            let mut pb = NativeBackend::new(cfg.clone(), wsq.clone(), graph.clone()).unwrap();
            assert!(pb.is_packed(), "{format:?}: packed path must engage");
            // dense copies of packed sites are dropped; fp sites stay
            assert!(pb.ws.tensors.get("l0.wq").is_none());
            assert!(pb.ws.tensors.get("embed").is_some());
            assert!(pb.ws.tensors.get("wout").is_some());
            // stripping the twins falls back to the fake-quant f32 path
            let mut plain = wsq.clone();
            plain.packed.clear();
            let mut fb = NativeBackend::new(cfg.clone(), plain, graph).unwrap();
            assert!(!fb.is_packed());
            let tokens: Vec<i32> = (0..cfg.batch * cfg.seq_len)
                .map(|i| ((i * 5 + 1) % cfg.vocab) as i32)
                .collect();
            let a = pb.score(&tokens).unwrap();
            let a2 = pb.score(&tokens).unwrap();
            assert_eq!(a, a2, "packed scoring must be deterministic");
            assert!(a.iter().all(|v| v.is_finite()));
            // both paths share the quantizer rounding bit-for-bit; the
            // difference is f32 accumulation order (cliffs can amplify a
            // single element, so the bound is aggregate)
            let b = fb.score(&tokens).unwrap();
            let mad: f64 =
                a.iter().zip(&b).map(|(x, y)| (x - y).abs() as f64).sum::<f64>() / a.len() as f64;
            assert!(mad < 5e-2, "{format:?}: packed drifts from fake-quant (mad {mad})");
        }
    }

    #[test]
    fn partial_packing_falls_back_to_dense() {
        let cfg = tiny_cfg();
        let ws = tiny_ws(&cfg, 7);
        let format = Format::Int4;
        let mut wsq = quantize_and_pack(&cfg, &ws, format);
        wsq.take_packed("l0.wk"); // one missing twin → no packed serving
        let graph = ForwardGraph::Merged { r3_block: 8, format };
        let be = NativeBackend::new(cfg, wsq, graph).unwrap();
        assert!(!be.is_packed());
        assert!(be.ws.tensors.get("l0.wq").is_some(), "dense copies must survive");
    }

    #[test]
    fn fp_graph_is_rotation_free() {
        // Fp scoring must equal Merged{b=1, None} scoring on the same
        // weights (identity rotation, no quantization).
        let cfg = tiny_cfg();
        let ws = tiny_ws(&cfg, 5);
        let tokens: Vec<i32> = (0..cfg.batch * cfg.seq_len).map(|i| (i * 3 % cfg.vocab) as i32).collect();
        let mut fp = NativeBackend::new(cfg.clone(), ws.clone(), ForwardGraph::Fp).unwrap();
        let mut id = NativeBackend::new(
            cfg.clone(), ws, ForwardGraph::Merged { r3_block: 1, format: Format::None },
        )
        .unwrap();
        let a = fp.score(&tokens).unwrap();
        let b = id.score(&tokens).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn session_lifecycle_and_slot_bookkeeping() {
        let cfg = tiny_cfg();
        let ws = tiny_ws(&cfg, 9);
        let mut be = NativeBackend::new(cfg.clone(), ws, ForwardGraph::Fp).unwrap();
        let sid = be.begin(3).unwrap();
        assert_eq!(be.session_batch(sid).unwrap(), 3);
        assert_eq!(be.slot_len(sid, 0).unwrap(), 0);
        // prefill two of the three slots with 4-token prompts
        let prompts: Vec<i32> = vec![1, 2, 3, 4, 5, 6, 7, 0];
        let logits = be.prefill_slots(sid, &[0, 2], &prompts).unwrap();
        assert_eq!(logits.len(), 2 * 4 * cfg.vocab);
        assert_eq!(be.slot_len(sid, 0).unwrap(), 4);
        assert_eq!(be.slot_len(sid, 1).unwrap(), 0);
        assert_eq!(be.slot_len(sid, 2).unwrap(), 4);
        // decode advances only the active slots (slot 1 idle)
        let step = be.decode_step(sid, &[2, -1, 3]).unwrap();
        assert_eq!(step.len(), 3 * cfg.vocab);
        assert!(step[cfg.vocab..2 * cfg.vocab].iter().all(|&v| v == 0.0), "idle row zeroed");
        assert!(step[..cfg.vocab].iter().any(|&v| v != 0.0));
        assert_eq!(be.slot_len(sid, 0).unwrap(), 5);
        assert_eq!(be.slot_len(sid, 1).unwrap(), 0);
        // releasing a slot frees its positions
        be.reset_slot(sid, 0).unwrap();
        assert_eq!(be.slot_len(sid, 0).unwrap(), 0);
        // capacity overflow is an error, not a wrap
        let full: Vec<i32> = (0..cfg.seq_len as i32).collect();
        be.prefill_slots(sid, &[0], &full).unwrap();
        assert!(be.decode_step(sid, &[1, -1, -1]).is_err(), "slot 0 is full");
        be.end(sid).unwrap();
        assert!(be.slot_len(sid, 0).is_err(), "ended session is gone");
        assert!(be.end(sid).is_err());
    }

    #[test]
    fn fault_spec_parses_and_rejects_junk() {
        let (plan, bad) = fault::parse("panic_step:3, slow_step:2:15");
        assert_eq!(plan.panic_step, Some(3));
        assert_eq!(plan.slow_step, Some((2, 15)));
        assert_eq!(plan.fail_step, None);
        assert!(bad.is_empty(), "{bad:?}");
        let (plan, bad) = fault::parse("fail_step:1,panic_step:zero,bogus:4,slow_step:1");
        assert_eq!(plan.fail_step, Some(1));
        assert_eq!(plan.panic_step, None, "unparsable clause must not arm");
        assert_eq!(bad, vec!["panic_step:zero", "bogus:4", "slow_step:1"]);
        let (plan, bad) = fault::parse("");
        assert!(plan.is_empty() && bad.is_empty());
        // step 0 never fires (steps are 1-based) — reject it at parse time
        let (plan, bad) = fault::parse("panic_step:0");
        assert!(plan.is_empty());
        assert_eq!(bad.len(), 1);
    }

    #[test]
    fn prefix_prefill_shares_pages_bit_identically() {
        let cfg = tiny_cfg();
        let ws = tiny_ws(&cfg, 11);
        let mut be = NativeBackend::new(cfg.clone(), ws, ForwardGraph::Fp).unwrap();
        be.set_kv_paging(PagedConfig { page: 2, pages: 0 });
        let sid = be.begin_with_mode(2, KvMode::F32).unwrap();
        let prompt: Vec<i32> = vec![1, 2, 3, 4, 5, 6];
        let (full, m0) = be.prefill_prefixed(sid, 0, &prompt).unwrap();
        assert_eq!(m0, 0, "empty trie: nothing cached yet");
        assert_eq!(full.len(), prompt.len() * cfg.vocab);
        let (suffix, m1) = be.prefill_prefixed(sid, 1, &prompt).unwrap();
        assert_eq!(m1, prompt.len() - 1, "identical prompt shares all but the last token");
        assert_eq!(suffix.len(), (prompt.len() - m1) * cfg.vocab);
        // last-position logits agree bitwise with the full prefill (f32
        // cache + shared rows → identical attention inputs)
        let a = &full[(prompt.len() - 1) * cfg.vocab..];
        let b = &suffix[(prompt.len() - m1 - 1) * cfg.vocab..];
        assert_eq!(a, b, "shared-prefix last-position logits must be bit-identical");
        // one decode step: both slots hold identical state, rows match
        let step = be.decode_step(sid, &[3, 3]).unwrap();
        assert_eq!(
            &step[..cfg.vocab],
            &step[cfg.vocab..2 * cfg.vocab],
            "divergence after CoW must still start from identical state"
        );
        be.end(sid).unwrap();
    }

    #[test]
    fn swap_out_and_in_preserves_decode_state() {
        let cfg = tiny_cfg();
        let ws = tiny_ws(&cfg, 12);
        let mut be = NativeBackend::new(cfg.clone(), ws, ForwardGraph::Fp).unwrap();
        be.set_kv_paging(PagedConfig { page: 2, pages: 0 });
        let sid = be.begin_with_mode(2, KvMode::F32).unwrap();
        be.prefill_slots(sid, &[0], &[1, 2, 3, 4]).unwrap();
        let reference = be.decode_step(sid, &[5, -1]).unwrap();
        // rebuild the same state, preempt it, restore it, decode again
        be.reset_slot(sid, 0).unwrap();
        be.prefill_slots(sid, &[0], &[1, 2, 3, 4]).unwrap();
        let swap = be.swap_out_slot(sid, 0).unwrap().expect("paged session can spill");
        assert_eq!(swap.len(), 4);
        assert_eq!(be.slot_len(sid, 0).unwrap(), 0);
        be.swap_in_slot(sid, 0, &swap).unwrap();
        assert_eq!(be.slot_len(sid, 0).unwrap(), 4);
        let restored = be.decode_step(sid, &[5, -1]).unwrap();
        assert_eq!(reference, restored, "preempt→resume must be bit-identical");
        // dense sessions report themselves unspillable
        be.set_kv_paging(PagedConfig::dense());
        let dense = be.begin_with_mode(1, KvMode::F32).unwrap();
        be.prefill_slots(dense, &[0], &[1, 2]).unwrap();
        assert!(be.swap_out_slot(dense, 0).unwrap().is_none());
        assert!(be.kv_free_pages(dense).is_none());
        be.end(dense).unwrap();
        be.end(sid).unwrap();
    }

    #[test]
    fn duplicate_or_oob_slots_rejected() {
        let cfg = tiny_cfg();
        let ws = tiny_ws(&cfg, 10);
        let mut be = NativeBackend::new(cfg, ws, ForwardGraph::Fp).unwrap();
        let sid = be.begin(2).unwrap();
        assert!(be.prefill_slots(sid, &[0, 0], &[1, 2, 3, 4]).is_err());
        assert!(be.prefill_slots(sid, &[5], &[1, 2]).is_err());
        // after a rejected call the session must still be usable
        assert!(be.prefill_slots(sid, &[0], &[1, 2]).is_ok());
    }
}
