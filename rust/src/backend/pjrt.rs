//! `PjrtBackend` — the AOT-artifact execution path behind [`ExecBackend`]
//! (feature `pjrt`). Wraps the PJRT CPU client with *device-resident*
//! weights: the weight + rotation/format inputs are uploaded once via
//! `buffer_from_host_literal`, so the per-call path copies only tokens —
//! the §Perf win the batching server was built around.
//!
//! PJRT handles are `Rc`-based and thread-confined, so a `PjrtBackend` is
//! NOT `Send`; construct it on the thread that scores with it (the server
//! does this through its backend factory).

use std::path::Path;

use anyhow::{anyhow, Result};
use xla::{PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use super::{graph_op_counts, ExecBackend, ExtraInput, ForwardGraph, OpCounts};
use crate::model::config::ModelConfig;
use crate::model::weights::WeightSet;
use crate::runtime::engine;

pub struct PjrtBackend {
    exe: PjRtLoadedExecutable,
    weight_bufs: Vec<PjRtBuffer>,
    extra_bufs: Vec<PjRtBuffer>,
    /// Host literals backing the device buffers. `buffer_from_host_literal`
    /// copies asynchronously on the CPU client, so the source literals must
    /// outlive the buffers (dropping them early is a use-after-free that
    /// manifests as a fatal size-check in abstract_tfrt_cpu_buffer.cc).
    _host_literals: Vec<xla::Literal>,
    cfg: ModelConfig,
    graph: ForwardGraph,
}

impl PjrtBackend {
    /// Compile the artifact at `artifact` (an .hlo.txt path) and upload
    /// weights + graph extras to the device once.
    pub fn load(artifact: &Path, cfg: &ModelConfig, ws: &WeightSet,
                graph: &ForwardGraph) -> Result<PjrtBackend> {
        let client = PjRtClient::cpu().map_err(|e| anyhow!("pjrt client: {e:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(
            artifact.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("loading {artifact:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(|e| anyhow!("compile: {e:?}"))?;
        let devices = client.addressable_devices();
        let device = &devices[0];
        // one-time weight upload (the §Perf point of this backend)
        let mut host_literals = engine::weight_literals(ws)?;
        for e in &graph.extras()? {
            host_literals.push(match e {
                ExtraInput::Matrix(m) => engine::mat_literal(m)?,
                ExtraInput::ScalarI32(v) => engine::scalar_i32(*v),
            });
        }
        let n_weights = ws.names.len();
        let mut weight_bufs = Vec::new();
        let mut extra_bufs = Vec::new();
        for (i, lit) in host_literals.iter().enumerate() {
            let buf = client
                .buffer_from_host_literal(Some(device), lit)
                .map_err(|e| anyhow!("uploading input {i}: {e:?}"))?;
            if i < n_weights {
                weight_bufs.push(buf);
            } else {
                extra_bufs.push(buf);
            }
        }
        Ok(PjrtBackend {
            exe,
            weight_bufs,
            extra_bufs,
            _host_literals: host_literals,
            cfg: cfg.clone(),
            graph: graph.clone(),
        })
    }
}

impl ExecBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn cfg(&self) -> &ModelConfig {
        &self.cfg
    }

    fn score(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        let cfg = &self.cfg;
        let tok_lit = engine::tokens_literal(tokens, cfg.batch, cfg.seq_len)?;
        let client = self.exe.client();
        let devices = client.addressable_devices();
        let device = &devices[0];
        let tok_buf = client
            .buffer_from_host_literal(Some(device), &tok_lit)
            .map_err(|e| anyhow!("uploading tokens: {e:?}"))?;
        let mut inputs: Vec<&PjRtBuffer> = self.weight_bufs.iter().collect();
        inputs.push(&tok_buf);
        for b in &self.extra_bufs {
            inputs.push(b);
        }
        let out = self
            .exe
            .execute_b(&inputs)
            .map_err(|e| anyhow!("execute_b: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e:?}"))?;
        let tuple = lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        engine::literal_to_vec_f32(&tuple[0])
    }

    fn op_counts(&self) -> OpCounts {
        graph_op_counts(&self.cfg, &self.graph)
    }
}
