//! `PjrtBackend` — the AOT-artifact execution path behind [`ExecBackend`]
//! (feature `pjrt`). Wraps the PJRT CPU client with *device-resident*
//! weights: the weight + rotation/format inputs are uploaded once via
//! `buffer_from_host_literal`, so the per-call path copies only tokens —
//! the §Perf win the batching server was built around.
//!
//! PJRT handles are `Rc`-based and thread-confined, so a `PjrtBackend` is
//! NOT `Send`; construct it on the thread that scores with it (the server
//! does this through its backend factory).

use std::path::Path;

use anyhow::{anyhow, bail, ensure, Result};
use xla::{PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use super::{graph_op_counts, ExecBackend, ExtraInput, ForwardGraph, OpCounts, SessionId};
use crate::model::config::ModelConfig;
use crate::model::weights::WeightSet;
use crate::runtime::engine;

/// Minimal session bookkeeping for the AOT path: the lowered HLO graphs
/// are fixed-shape `(batch, seq_len)` forwards with no KV state, so a
/// pjrt session only supports one full-window prefill per slot set (the
/// `score` contract); incremental decode requires the native backend.
struct PjrtSession {
    lens: Vec<usize>,
}

pub struct PjrtBackend {
    exe: PjRtLoadedExecutable,
    weight_bufs: Vec<PjRtBuffer>,
    extra_bufs: Vec<PjRtBuffer>,
    /// Host literals backing the device buffers. `buffer_from_host_literal`
    /// copies asynchronously on the CPU client, so the source literals must
    /// outlive the buffers (dropping them early is a use-after-free that
    /// manifests as a fatal size-check in abstract_tfrt_cpu_buffer.cc).
    _host_literals: Vec<xla::Literal>,
    cfg: ModelConfig,
    graph: ForwardGraph,
    sessions: Vec<Option<PjrtSession>>,
}

impl PjrtBackend {
    /// Compile the artifact at `artifact` (an .hlo.txt path) and upload
    /// weights + graph extras to the device once.
    pub fn load(artifact: &Path, cfg: &ModelConfig, ws: &WeightSet,
                graph: &ForwardGraph) -> Result<PjrtBackend> {
        let client = PjRtClient::cpu().map_err(|e| anyhow!("pjrt client: {e:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(
            artifact.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("loading {artifact:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(|e| anyhow!("compile: {e:?}"))?;
        let devices = client.addressable_devices();
        let device = &devices[0];
        // one-time weight upload (the §Perf point of this backend)
        let mut host_literals = engine::weight_literals(ws)?;
        for e in &graph.extras()? {
            host_literals.push(match e {
                ExtraInput::Matrix(m) => engine::mat_literal(m)?,
                ExtraInput::ScalarI32(v) => engine::scalar_i32(*v),
            });
        }
        let n_weights = ws.names.len();
        let mut weight_bufs = Vec::new();
        let mut extra_bufs = Vec::new();
        for (i, lit) in host_literals.iter().enumerate() {
            let buf = client
                .buffer_from_host_literal(Some(device), lit)
                .map_err(|e| anyhow!("uploading input {i}: {e:?}"))?;
            if i < n_weights {
                weight_bufs.push(buf);
            } else {
                extra_bufs.push(buf);
            }
        }
        Ok(PjrtBackend {
            exe,
            weight_bufs,
            extra_bufs,
            _host_literals: host_literals,
            cfg: cfg.clone(),
            graph: graph.clone(),
            sessions: Vec::new(),
        })
    }

    fn session_ref(&self, sid: SessionId) -> Result<&PjrtSession> {
        self.sessions
            .get(sid as usize)
            .and_then(|s| s.as_ref())
            .ok_or_else(|| anyhow!("unknown session {sid}"))
    }

    /// The raw fixed-shape artifact execution (the pre-session `score`).
    fn score_full(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        let cfg = &self.cfg;
        let tok_lit = engine::tokens_literal(tokens, cfg.batch, cfg.seq_len)?;
        let client = self.exe.client();
        let devices = client.addressable_devices();
        let device = &devices[0];
        let tok_buf = client
            .buffer_from_host_literal(Some(device), &tok_lit)
            .map_err(|e| anyhow!("uploading tokens: {e:?}"))?;
        let mut inputs: Vec<&PjRtBuffer> = self.weight_bufs.iter().collect();
        inputs.push(&tok_buf);
        for b in &self.extra_bufs {
            inputs.push(b);
        }
        let out = self
            .exe
            .execute_b(&inputs)
            .map_err(|e| anyhow!("execute_b: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e:?}"))?;
        let tuple = lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        engine::literal_to_vec_f32(&tuple[0])
    }
}

impl ExecBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn cfg(&self) -> &ModelConfig {
        &self.cfg
    }

    fn op_counts(&self) -> OpCounts {
        graph_op_counts(&self.cfg, &self.graph)
    }

    fn begin(&mut self, batch: usize) -> Result<SessionId> {
        ensure!(
            batch == self.cfg.batch,
            "the pjrt backend executes fixed-shape AOT graphs — sessions carry \
             exactly cfg.batch = {} slots (got {batch})",
            self.cfg.batch
        );
        let sess = PjrtSession { lens: vec![0; batch] };
        match self.sessions.iter().position(|s| s.is_none()) {
            Some(i) => {
                self.sessions[i] = Some(sess);
                Ok(i as SessionId)
            }
            None => {
                self.sessions.push(Some(sess));
                Ok((self.sessions.len() - 1) as SessionId)
            }
        }
    }

    fn session_batch(&self, sid: SessionId) -> Result<usize> {
        Ok(self.session_ref(sid)?.lens.len())
    }

    fn slot_len(&self, sid: SessionId, slot: usize) -> Result<usize> {
        let sess = self.session_ref(sid)?;
        sess.lens
            .get(slot)
            .copied()
            .ok_or_else(|| anyhow!("slot {slot} out of range"))
    }

    /// Full-window prefill over any subset of slots. The lowered graph has
    /// a static `(batch, seq_len)` shape, so a partial batch is padded *by
    /// this adapter* (last window replicated into the unused rows — rows
    /// are scored independently, so filler never leaks into real logits);
    /// only the requested slots' logits are returned. The scheduler above
    /// carries no padding concept — fixed shapes are a pjrt artifact
    /// detail, handled here.
    fn prefill_slots(&mut self, sid: SessionId, slots: &[usize], tokens: &[i32])
                     -> Result<Vec<f32>> {
        let (b, t, v) = (self.cfg.batch, self.cfg.seq_len, self.cfg.vocab);
        {
            let sess = self.session_ref(sid)?;
            ensure!(!slots.is_empty() && slots.len() <= b, "bad slot count {}", slots.len());
            ensure!(tokens.len() == slots.len() * t,
                    "pjrt prefill takes seq_len = {t} tokens per slot, got {} for {} slots",
                    tokens.len(), slots.len());
            for (i, &s) in slots.iter().enumerate() {
                ensure!(s < b, "slot {s} out of range ({b} slots)");
                ensure!(!slots[..i].contains(&s), "slot {s} listed twice");
                ensure!(
                    sess.lens[s] == 0,
                    "pjrt slots score one full window each (no incremental append) — \
                     reset slot {s} first"
                );
            }
        }
        let k = slots.len();
        let mut full = Vec::with_capacity(b * t);
        for i in 0..b {
            let src = i.min(k - 1) * t;
            full.extend_from_slice(&tokens[src..src + t]);
        }
        let logits = self.score_full(&full)?;
        ensure!(logits.len() == b * t * v, "artifact returned a bad logit shape");
        if let Some(Some(sess)) = self.sessions.get_mut(sid as usize) {
            for &s in slots {
                sess.lens[s] = t;
            }
        }
        Ok(logits[..k * t * v].to_vec())
    }

    fn supports_decode(&self) -> bool {
        false
    }

    fn decode_step_into(&mut self, _sid: SessionId, _last_tokens: &[i32],
                        _out: &mut Vec<f32>) -> Result<()> {
        bail!(
            "incremental decode requires the native backend — the AOT HLO graphs \
             are fixed-shape full-window forwards (use --backend native)"
        )
    }

    fn reset_slot(&mut self, sid: SessionId, slot: usize) -> Result<()> {
        let sess = self
            .sessions
            .get_mut(sid as usize)
            .and_then(|s| s.as_mut())
            .ok_or_else(|| anyhow!("unknown session {sid}"))?;
        let len = sess
            .lens
            .get_mut(slot)
            .ok_or_else(|| anyhow!("slot {slot} out of range"))?;
        *len = 0;
        Ok(())
    }

    fn end(&mut self, sid: SessionId) -> Result<()> {
        let i = sid as usize;
        ensure!(
            self.sessions.get(i).map_or(false, |s| s.is_some()),
            "unknown session {sid}"
        );
        self.sessions[i] = None;
        Ok(())
    }

    /// Direct fixed-shape execution (identical to the provided
    /// prefill-then-read default, minus the session bookkeeping).
    fn score(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        let want = self.cfg.batch * self.cfg.seq_len;
        ensure!(tokens.len() == want,
                "score takes batch*seq_len = {want} tokens, got {}", tokens.len());
        self.score_full(tokens)
    }
}
