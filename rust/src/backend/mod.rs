//! Execution backends — the serving-side abstraction that decouples the L3
//! coordinator from *how* a forward pass is executed.
//!
//! The paper's case for block rotations is a serving argument (App A:
//! online rotation cost, end-to-end latency), so the rotate+quantize+matmul
//! chain must be runnable anywhere — not only where the XLA toolchain and
//! Python-lowered HLO artifacts exist. Two implementations sit behind the
//! [`ExecBackend`] trait:
//!
//! * [`native::NativeBackend`] — the full quantized forward pass in pure
//!   Rust: merged-permutation gather (already folded into the weights),
//!   blockwise FWHT (`hadamard::fwht`, including the non-power-of-2 plan),
//!   activation fake-quant from `quant::act`, and the cache-blocked f32
//!   matmul in `tensor`. Always available; zero external dependencies.
//! * `pjrt::PjrtBackend` — the device-resident PJRT adapter over the AOT
//!   HLO artifacts (feature `pjrt`; requires the vendored xla-rs bindings).
//!
//! Selection is explicit (`--backend {native,pjrt}`) or automatic
//! ([`BackendKind::auto`]: pjrt when HLO artifacts are present and the
//! feature is compiled, native otherwise; `PERQ_BACKEND` overrides).

pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use crate::hadamard::{self, opcount, BlockRotator};
use crate::model::config::ModelConfig;
use crate::model::weights::WeightSet;
use crate::quant::Format;
use crate::runtime::RepoContext;
use crate::tensor::{KvSwap, Mat};

pub use native::NativeBackend;

/// Extra forward-graph inputs after (weights, tokens), in host (`Send`)
/// form: the (b, b) rotation matrix and the runtime `fmt` scalar. PJRT
/// literal conversion happens inside the pjrt paths only.
#[derive(Clone)]
pub enum ExtraInput {
    Matrix(Mat),
    ScalarI32(i32),
}

/// Which forward graph a backend executes, in backend-neutral terms.
/// Mirrors the L2 artifact variants (`fwd`, `fwd_quant_b{b}`,
/// `fwd_online_b32`) without referencing artifacts.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ForwardGraph {
    /// Full-precision forward (BF16-analog baseline) — artifact tag `fwd`.
    Fp,
    /// The Fig 7 merged graph: online act-quant everywhere plus the fused
    /// R̃3 block rotate+quant before the down projection.
    Merged { r3_block: usize, format: Format },
    /// The Fig 9 fully-online graph (b = 32 at every site). PJRT only.
    Online { format: Format },
}

impl ForwardGraph {
    /// The matching AOT artifact tag.
    pub fn tag(&self) -> String {
        match self {
            ForwardGraph::Fp => "fwd".to_string(),
            ForwardGraph::Merged { r3_block, .. } => format!("fwd_quant_b{r3_block}"),
            ForwardGraph::Online { .. } => "fwd_online_b32".to_string(),
        }
    }

    pub fn format(&self) -> Format {
        match self {
            ForwardGraph::Fp => Format::None,
            ForwardGraph::Merged { format, .. } | ForwardGraph::Online { format } => *format,
        }
    }

    /// The extra graph inputs after (weights, tokens), in host form.
    pub fn extras(&self) -> Result<Vec<ExtraInput>> {
        Ok(match self {
            ForwardGraph::Fp => vec![],
            ForwardGraph::Merged { r3_block, format } => vec![
                ExtraInput::Matrix(BlockRotator::hadamard(*r3_block)?.matrix()?),
                ExtraInput::ScalarI32(format.fmt_id()),
            ],
            ForwardGraph::Online { format } => {
                let h32 = hadamard::normalized_hadamard(32)?;
                vec![
                    ExtraInput::Matrix(h32.clone()),
                    ExtraInput::Matrix(h32),
                    ExtraInput::ScalarI32(format.fmt_id()),
                ]
            }
        })
    }
}

/// Per-token analytic op counts a backend reports for its graph — the
/// serving-side view of the paper's Tables 3/4 accounting.
#[derive(Clone, Copy, Debug, Default)]
pub struct OpCounts {
    /// online rotation add/sub ops per token (the Appendix A quantity)
    pub rotation_ops: usize,
    /// linear-layer multiply-accumulate flops per token (2 per MAC)
    pub matmul_flops: usize,
    /// activation values fake-quantized per token
    pub quantized_values: usize,
}

/// Handle to one live execution session (a batch of attention states).
pub type SessionId = u64;

/// A compiled/loaded forward executor for one (model spec, graph) pair.
///
/// Execution is **stateful and stepwise**: a session ([`ExecBackend::begin`])
/// owns `batch` independent attention-state slots (per-layer K/V caches);
/// slots prefill prompt windows ([`ExecBackend::prefill_slots`]), then
/// advance one token per [`ExecBackend::decode_step`] — the workload the
/// paper's App A decode-time argument is about. Slots join and leave a
/// live session independently ([`ExecBackend::reset_slot`]), which is the
/// substrate the coordinator's continuous batching runs on.
///
/// The legacy stateless contract survives as the provided
/// [`ExecBackend::score`]: exactly `cfg.batch * cfg.seq_len` i32 tokens →
/// `(batch * seq_len * vocab)` f32 logits, re-expressed as
/// prefill-then-read over a throwaway session, so the eval streamers, the
/// parity suites, and the scoring server are unchanged callers.
///
/// Token layout is slot-major everywhere: `prefill_slots(sid, &[s0, s1],
/// toks)` splits `toks` into `slots.len()` equal consecutive prompt
/// windows. Implementations may keep internal scratch (hence `&mut`);
/// they are single-threaded objects owned by their caller.
pub trait ExecBackend {
    fn name(&self) -> &'static str;
    fn cfg(&self) -> &ModelConfig;
    fn op_counts(&self) -> OpCounts;

    /// Open a session with `batch` empty attention-state slots.
    fn begin(&mut self, batch: usize) -> Result<SessionId>;

    /// Open a session for *exact* stateless scoring. Backends with a
    /// lossy KV-cache mode (the native int8 cache) pin this session to
    /// exact storage so served NLLs match the eval/`score` path
    /// bit-for-bit; the default is an ordinary session.
    fn begin_scoring(&mut self, batch: usize) -> Result<SessionId> {
        self.begin(batch)
    }

    /// Whether this backend can advance sessions incrementally
    /// (`decode_step`). False for fixed-shape AOT executors — the server
    /// uses this to reject generation requests up front instead of
    /// failing them one by one on the worker thread.
    fn supports_decode(&self) -> bool {
        true
    }

    /// Install (or clear) a cooperative step-interrupt probe. When the
    /// flag reads `true` mid-step, the backend abandons the step with an
    /// error at its next cancellation point instead of finishing the full
    /// forward pass — the server's drain-timeout abort uses this so a
    /// slow or wedged engine step cannot stall shutdown. The check must
    /// be cheap (a relaxed atomic load on the hot path); backends whose
    /// steps are short may ignore it entirely (the default is a no-op).
    fn set_step_interrupt(&mut self, _interrupt: Option<Arc<AtomicBool>>) {}

    /// Slot count of a live session.
    fn session_batch(&self, sid: SessionId) -> Result<usize>;

    /// Current position count of one slot.
    fn slot_len(&self, sid: SessionId, slot: usize) -> Result<usize>;

    /// Append `tokens.len() / slots.len()` prompt tokens to each listed
    /// slot and return the full prompt logits, flat
    /// `(slots.len() * n_new, vocab)` in slot-major order.
    fn prefill_slots(&mut self, sid: SessionId, slots: &[usize], tokens: &[i32])
                     -> Result<Vec<f32>>;

    /// Advance every *active* slot by one token. `last_tokens` carries one
    /// entry per session slot; a negative entry marks the slot idle — it
    /// is skipped entirely (no compute) and its logits row comes back
    /// zeroed. `out` is resized to `batch * vocab`; reusing one buffer
    /// across steps keeps steady-state decode allocation-free.
    fn decode_step_into(&mut self, sid: SessionId, last_tokens: &[i32], out: &mut Vec<f32>)
                        -> Result<()>;

    /// Release one slot of a live session for reuse (a request left the
    /// continuous batch).
    fn reset_slot(&mut self, sid: SessionId, slot: usize) -> Result<()>;

    /// Close a session, releasing its attention state.
    fn end(&mut self, sid: SessionId) -> Result<()>;

    /// Prefill every slot of the session uniformly (`tokens` =
    /// `batch * n_new`, slot-major).
    fn prefill(&mut self, sid: SessionId, tokens: &[i32]) -> Result<Vec<f32>> {
        let batch = self.session_batch(sid)?;
        let slots: Vec<usize> = (0..batch).collect();
        self.prefill_slots(sid, &slots, tokens)
    }

    /// Allocating convenience over [`ExecBackend::decode_step_into`].
    fn decode_step(&mut self, sid: SessionId, last_tokens: &[i32]) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.decode_step_into(sid, last_tokens, &mut out)?;
        Ok(out)
    }

    /// Prefix-aware generation prefill for one slot: serve the longest
    /// cached prefix of `prompt` from the shared KV prefix cache, run the
    /// forward pass only over the remaining suffix, and register the
    /// prompt for future sharers. Returns `(suffix_logits, matched)` where
    /// `matched` is the position count served from the cache (always <
    /// `prompt.len()`, so the last prompt position is computed and its
    /// logits are the tail row of `suffix_logits`). The default has no
    /// prefix cache: a plain prefill with `matched == 0`.
    fn prefill_prefixed(&mut self, sid: SessionId, slot: usize, prompt: &[i32])
                        -> Result<(Vec<f32>, usize)> {
        Ok((self.prefill_slots(sid, &[slot], prompt)?, 0))
    }

    /// Pages immediately allocatable in the session's KV page pool, or
    /// `None` when the backend's cache is dense (no paging).
    fn kv_free_pages(&self, _sid: SessionId) -> Option<usize> {
        None
    }

    /// Spill one slot's KV state for scheduler-driven preemption, leaving
    /// the slot empty. `Ok(None)` = this backend cannot spill (dense
    /// cache) — the scheduler falls back to failing the request.
    fn swap_out_slot(&mut self, _sid: SessionId, _slot: usize) -> Result<Option<KvSwap>> {
        Ok(None)
    }

    /// Restore a spilled slot bit-identically. Fails with `OutOfPages` in
    /// the error chain when the pool cannot hold the pages yet.
    fn swap_in_slot(&mut self, _sid: SessionId, _slot: usize, _swap: &KvSwap) -> Result<()> {
        bail!("this backend does not support KV swap-in")
    }

    /// The stateless full-window contract, re-expressed as
    /// prefill-then-read: `cfg.batch * cfg.seq_len` tokens → flat logits.
    fn score(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        let (b, t) = (self.cfg().batch, self.cfg().seq_len);
        ensure!(tokens.len() == b * t,
                "score takes batch*seq_len = {} tokens, got {}", b * t, tokens.len());
        let sid = self.begin(b)?;
        let result = self.prefill(sid, tokens);
        let _ = self.end(sid);
        result
    }
}

/// Greedy sampling: the index of the maximum logit (ties resolve to the
/// lowest index, so sampling is deterministic). Shared by the serving
/// loop, `DeployedModel::generate`, and the decode benches so every
/// generation path samples identically.
pub fn greedy_argmax(row: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in row.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as i32
}

/// Backend selector. `Pjrt` requires both the `pjrt` cargo feature and the
/// AOT HLO artifacts on disk; `Native` has no requirements.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    Native,
    Pjrt,
}

impl BackendKind {
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }

    pub fn parse(s: &str) -> Option<BackendKind> {
        match s {
            "native" | "rust" => Some(BackendKind::Native),
            "pjrt" | "xla" => Some(BackendKind::Pjrt),
            _ => None,
        }
    }

    /// Default selection: pjrt when compiled in *and* HLO artifacts exist
    /// under `ctx.artifacts`, native otherwise. `PERQ_BACKEND` overrides.
    pub fn auto(ctx: &RepoContext) -> BackendKind {
        if let Ok(v) = std::env::var("PERQ_BACKEND") {
            if let Some(k) = BackendKind::parse(&v) {
                return k;
            }
        }
        if cfg!(feature = "pjrt") && has_hlo_artifacts(ctx) {
            BackendKind::Pjrt
        } else {
            BackendKind::Native
        }
    }

    /// Resolve an optional `--backend` CLI value (None/"auto" → [`auto`]).
    pub fn resolve(arg: Option<&str>, ctx: &RepoContext) -> Result<BackendKind> {
        match arg {
            None | Some("auto") => Ok(BackendKind::auto(ctx)),
            Some(s) => match BackendKind::parse(s) {
                Some(k) => Ok(k),
                None => bail!("unknown backend {s:?} (expected native|pjrt|auto)"),
            },
        }
    }
}

/// The AOT artifacts only lower `fmt` ids 0..=3 (the L2 `lax.switch`
/// branches); `Format::Int8` (id 4) is a native-backend extension. The
/// pjrt dispatch points (and the pipeline, for an early error) must
/// reject it — an out-of-range id would be clamped by the switch to the
/// wrong quantizer and score silently wrong.
pub fn ensure_artifact_format(graph: &ForwardGraph) -> Result<()> {
    let f = graph.format();
    ensure!(
        (0..=3).contains(&f.fmt_id()),
        "format {} is native-backend only (no AOT artifact lowering) — use --backend native",
        f.name()
    );
    Ok(())
}

/// Does any model directory under `artifacts/` hold a lowered HLO graph?
pub fn has_hlo_artifacts(ctx: &RepoContext) -> bool {
    let Ok(entries) = std::fs::read_dir(&ctx.artifacts) else {
        return false;
    };
    for entry in entries.flatten() {
        let dir = entry.path();
        if !dir.is_dir() {
            continue;
        }
        if let Ok(files) = std::fs::read_dir(&dir) {
            for f in files.flatten() {
                if f.file_name().to_string_lossy().ends_with(".hlo.txt") {
                    return true;
                }
            }
        }
    }
    false
}

/// Instantiate a backend for (model, graph). `ctx`/`model` are only needed
/// by the pjrt arm (artifact lookup); native ignores them.
pub fn make_backend(kind: BackendKind, ctx: Option<&RepoContext>, model: &str,
                    cfg: &ModelConfig, ws: &WeightSet, graph: &ForwardGraph)
                    -> Result<Box<dyn ExecBackend>> {
    match kind {
        BackendKind::Native => Ok(Box::new(NativeBackend::new(
            cfg.clone(),
            ws.clone(),
            graph.clone(),
        )?)),
        BackendKind::Pjrt => {
            ensure_artifact_format(graph)?;
            make_pjrt_backend(ctx, model, cfg, ws, graph)
        }
    }
}

#[cfg(feature = "pjrt")]
fn make_pjrt_backend(ctx: Option<&RepoContext>, model: &str, cfg: &ModelConfig,
                     ws: &WeightSet, graph: &ForwardGraph)
                     -> Result<Box<dyn ExecBackend>> {
    let ctx = ctx.ok_or_else(|| anyhow::anyhow!("pjrt backend needs a RepoContext"))?;
    let artifact = ctx.model_dir(model).join(format!("{}.hlo.txt", graph.tag()));
    anyhow::ensure!(artifact.exists(), "missing artifact {artifact:?} — run `make artifacts`");
    Ok(Box::new(pjrt::PjrtBackend::load(&artifact, cfg, ws, graph)?))
}

#[cfg(not(feature = "pjrt"))]
fn make_pjrt_backend(_ctx: Option<&RepoContext>, _model: &str, _cfg: &ModelConfig,
                     _ws: &WeightSet, _graph: &ForwardGraph)
                     -> Result<Box<dyn ExecBackend>> {
    bail!("the pjrt backend is not compiled in (rebuild with `--features pjrt`)")
}

/// Analytic per-token op counts for a graph on a model config — shared by
/// both backends so native-vs-pjrt comparisons report identical accounting.
pub fn graph_op_counts(cfg: &ModelConfig, graph: &ForwardGraph) -> OpCounts {
    let (l, d, f, v, t) = (cfg.n_layers, cfg.d_model, cfg.d_ffn, cfg.vocab, cfg.seq_len);
    // linear sites per layer: wq/wk/wv/wo (d×d), wg/wu (d×f), wd (f×d);
    // plus attention (scores + context ≈ 2·2·t·d) and the unembed d×v.
    let matmul_flops = l * (2 * (4 * d * d + 3 * d * f) + 4 * t * d) + 2 * d * v;
    let (rotation_ops, quantized_values) = match graph {
        ForwardGraph::Fp => (0, 0),
        ForwardGraph::Merged { r3_block, format } => {
            let rot = l * opcount::block_ops(f, *r3_block);
            let q = if *format == Format::None { 0 } else { l * (3 * d + f) };
            (rot, q)
        }
        ForwardGraph::Online { format } => {
            let rot = l * (3 * opcount::block_ops(d, 32.min(d)) + opcount::block_ops(f, 32));
            let q = if *format == Format::None { 0 } else { l * (3 * d + f) };
            (rot, q)
        }
    };
    OpCounts { rotation_ops, matmul_flops, quantized_values }
}

/// Build a scoring closure for (model, graph) on the engine's backend —
/// the shared entry point of the perplexity/zero-shot streamers. The
/// closure takes `cfg.batch * cfg.seq_len` tokens and yields flat logits.
pub fn scorer<'a>(engine: &'a crate::runtime::Engine, model: &str, cfg: &ModelConfig,
                  ws: &WeightSet, graph: &ForwardGraph)
                  -> Result<Box<dyn FnMut(&[i32]) -> Result<Vec<f32>> + 'a>> {
    match engine.backend() {
        BackendKind::Native => {
            let mut be = NativeBackend::new(cfg.clone(), ws.clone(), graph.clone())?;
            Ok(Box::new(move |tokens: &[i32]| be.score(tokens)))
        }
        BackendKind::Pjrt => {
            ensure_artifact_format(graph)?;
            pjrt_scorer(engine, model, cfg, ws, graph)
        }
    }
}

#[cfg(feature = "pjrt")]
fn pjrt_scorer<'a>(engine: &'a crate::runtime::Engine, model: &str, cfg: &ModelConfig,
                   ws: &WeightSet, graph: &ForwardGraph)
                   -> Result<Box<dyn FnMut(&[i32]) -> Result<Vec<f32>> + 'a>> {
    use crate::runtime::engine as raw;
    let raw_engine = engine.pjrt()?;
    let w_lits = raw::weight_literals(ws)?;
    let extras = graph.extras()?;
    let model = model.to_string();
    let tag = graph.tag();
    let (b, t) = (cfg.batch, cfg.seq_len);
    Ok(Box::new(move |tokens: &[i32]| {
        let mut inputs = w_lits.clone();
        inputs.push(raw::tokens_literal(tokens, b, t)?);
        for e in &extras {
            inputs.push(match e {
                ExtraInput::Matrix(m) => raw::mat_literal(m)?,
                ExtraInput::ScalarI32(v) => raw::scalar_i32(*v),
            });
        }
        let outs = raw_engine.run(&model, &tag, &inputs)?;
        anyhow::ensure!(!outs.is_empty(), "artifact returned no outputs");
        raw::literal_to_vec_f32(&outs[0])
    }))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_scorer<'a>(_engine: &'a crate::runtime::Engine, _model: &str, _cfg: &ModelConfig,
                   _ws: &WeightSet, _graph: &ForwardGraph)
                   -> Result<Box<dyn FnMut(&[i32]) -> Result<Vec<f32>> + 'a>> {
    bail!("the pjrt backend is not compiled in (rebuild with `--features pjrt`)")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn graph_tags_match_artifact_contract() {
        assert_eq!(ForwardGraph::Fp.tag(), "fwd");
        let g = ForwardGraph::Merged { r3_block: 32, format: Format::Int4 };
        assert_eq!(g.tag(), "fwd_quant_b32");
        assert_eq!(ForwardGraph::Online { format: Format::Fp4 }.tag(), "fwd_online_b32");
    }

    #[test]
    fn graph_extras_shapes() {
        let g = ForwardGraph::Merged { r3_block: 16, format: Format::Int4 };
        let ex = g.extras().unwrap();
        assert_eq!(ex.len(), 2);
        match &ex[0] {
            ExtraInput::Matrix(m) => assert_eq!((m.rows, m.cols), (16, 16)),
            _ => panic!("expected matrix"),
        }
        match &ex[1] {
            ExtraInput::ScalarI32(v) => assert_eq!(*v, 1),
            _ => panic!("expected scalar"),
        }
        assert!(ForwardGraph::Fp.extras().unwrap().is_empty());
    }

    #[test]
    fn artifact_formats_exclude_native_only_int8() {
        let ok = ForwardGraph::Merged { r3_block: 8, format: Format::Int4 };
        assert!(ensure_artifact_format(&ok).is_ok());
        assert!(ensure_artifact_format(&ForwardGraph::Fp).is_ok());
        let bad = ForwardGraph::Merged { r3_block: 8, format: Format::Int8 };
        let err = ensure_artifact_format(&bad).unwrap_err().to_string();
        assert!(err.contains("native-backend only"), "{err}");
    }

    #[test]
    fn backend_kind_parse_roundtrip() {
        assert_eq!(BackendKind::parse("native"), Some(BackendKind::Native));
        assert_eq!(BackendKind::parse("pjrt"), Some(BackendKind::Pjrt));
        assert_eq!(BackendKind::parse("tpu"), None);
        assert_eq!(BackendKind::Native.name(), "native");
    }

    #[test]
    fn op_counts_scale_with_layers() {
        let j = crate::util::json::parse(
            r#"{"config": {"name": "m", "n_layers": 2, "d_model": 128,
                "n_heads": 4, "d_ffn": 448, "vocab": 32, "seq_len": 128,
                "batch": 8, "block_sizes": [1]}}"#,
        )
        .unwrap();
        let cfg = ModelConfig::from_meta(&j).unwrap();
        let g = ForwardGraph::Merged { r3_block: 32, format: Format::Int4 };
        let oc = graph_op_counts(&cfg, &g);
        assert!(oc.matmul_flops > 0);
        assert_eq!(oc.rotation_ops, 2 * opcount::block_ops(448, 32));
        assert_eq!(oc.quantized_values, 2 * (3 * 128 + 448));
        assert_eq!(graph_op_counts(&cfg, &ForwardGraph::Fp).rotation_ops, 0);
    }
}
