//! Repository context: locates the artifacts directory (built by
//! `make artifacts`) from the current directory, an ancestor, or $PERQ_ROOT.

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

#[derive(Clone, Debug)]
pub struct RepoContext {
    pub root: PathBuf,
    pub artifacts: PathBuf,
}

impl RepoContext {
    pub fn discover() -> Result<RepoContext> {
        if let Ok(root) = std::env::var("PERQ_ROOT") {
            return RepoContext::at(Path::new(&root));
        }
        let mut dir = std::env::current_dir()?;
        loop {
            if dir.join("artifacts").join(".stamp").exists()
                || dir.join("artifacts").join("corpus_golden.bin").exists()
            {
                return RepoContext::at(&dir);
            }
            if !dir.pop() {
                bail!(
                    "no artifacts/ directory found from cwd upward — run `make artifacts` \
                     or set PERQ_ROOT"
                );
            }
        }
    }

    /// A context that points nowhere — for synthetic, artifact-free runs
    /// (native backend only). Every artifact lookup will simply miss.
    pub fn ephemeral() -> RepoContext {
        let root = std::env::temp_dir().join("perq-ephemeral");
        RepoContext { artifacts: root.join("artifacts"), root }
    }

    pub fn at(root: &Path) -> Result<RepoContext> {
        let artifacts = root.join("artifacts");
        if !artifacts.exists() {
            bail!("{artifacts:?} does not exist — run `make artifacts`");
        }
        Ok(RepoContext { root: root.to_path_buf(), artifacts })
    }

    pub fn model_dir(&self, model: &str) -> PathBuf {
        self.artifacts.join(model)
    }

    pub fn weights_dir(&self, model: &str) -> PathBuf {
        self.artifacts.join("weights").join(model)
    }

    pub fn golden_path(&self) -> PathBuf {
        self.artifacts.join("corpus_golden.bin")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn at_rejects_missing() {
        assert!(RepoContext::at(Path::new("/definitely/not/here")).is_err());
    }
}
