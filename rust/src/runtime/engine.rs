//! The PJRT execution engine: artifact registry + compiled-executable
//! cache + typed input builders for the L2 artifact input contract
//! (weights..., tokens, [hb...], [fmt]).

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::context::RepoContext;
use crate::model::weights::WeightSet;
use crate::tensor::Mat;
use crate::util::json::{self, Json};

pub struct Engine {
    pub client: PjRtClient,
    /// compiled executables keyed by "<model>/<tag>"
    cache: Mutex<HashMap<String, std::sync::Arc<PjRtLoadedExecutable>>>,
    ctx: RepoContext,
}

impl Engine {
    pub fn new(ctx: &RepoContext) -> Result<Engine> {
        Ok(Engine {
            client: PjRtClient::cpu().context("creating PJRT CPU client")?,
            cache: Mutex::new(HashMap::new()),
            ctx: ctx.clone(),
        })
    }

    pub fn load_meta(&self, model: &str) -> Result<Json> {
        let path = self.ctx.model_dir(model).join("meta.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}"))?;
        json::parse(&text)
    }

    fn artifact_path(&self, model: &str, tag: &str) -> PathBuf {
        self.ctx.model_dir(model).join(format!("{tag}.hlo.txt"))
    }

    /// Compile (or fetch from cache) an artifact executable.
    pub fn executable(&self, model: &str, tag: &str) -> Result<std::sync::Arc<PjRtLoadedExecutable>> {
        let key = format!("{model}/{tag}");
        if let Some(exe) = self.cache.lock().unwrap().get(&key) {
            return Ok(exe.clone());
        }
        let path = self.artifact_path(model, tag);
        let proto = HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("loading HLO {path:?}: {e:?}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {key}: {e:?}"))?;
        let exe = std::sync::Arc::new(exe);
        self.cache.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    /// Execute and return the output tuple as literals.
    pub fn run(&self, model: &str, tag: &str, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let exe = self.executable(model, tag)?;
        let result = exe
            .execute::<Literal>(inputs)
            .map_err(|e| anyhow!("executing {model}/{tag}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untupling result: {e:?}"))
    }
}

/// Build the weight literals in canonical artifact order (f32, original
/// npy shapes).
pub fn weight_literals(ws: &WeightSet) -> Result<Vec<Literal>> {
    let mut out = Vec::with_capacity(ws.names.len());
    for name in &ws.names {
        let m = ws.get(name);
        let shape = ws.shape(name);
        let dims: Vec<i64> = shape.iter().map(|&s| s as i64).collect();
        let lit = Literal::vec1(&m.data)
            .reshape(&dims)
            .map_err(|e| anyhow!("reshaping weight {name}: {e:?}"))?;
        out.push(lit);
    }
    Ok(out)
}

/// Tokens literal: (batch, seq) i32.
pub fn tokens_literal(tokens: &[i32], batch: usize, seq: usize) -> Result<Literal> {
    anyhow::ensure!(tokens.len() == batch * seq, "token shape mismatch");
    Literal::vec1(tokens)
        .reshape(&[batch as i64, seq as i64])
        .map_err(|e| anyhow!("reshaping tokens: {e:?}"))
}

/// (b, b) f32 rotation matrix literal.
pub fn mat_literal(m: &Mat) -> Result<Literal> {
    Literal::vec1(&m.data)
        .reshape(&[m.rows as i64, m.cols as i64])
        .map_err(|e| anyhow!("reshaping matrix literal: {e:?}"))
}

/// i32 scalar literal (the artifact `fmt` input).
pub fn scalar_i32(v: i32) -> Literal {
    Literal::scalar(v)
}

/// Read an f32 literal back into a flat vector.
pub fn literal_to_vec_f32(lit: &Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("literal to f32 vec: {e:?}"))
}
