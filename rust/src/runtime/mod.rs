//! PJRT runtime: loads the HLO-text artifacts the python build path emits
//! and executes them on the CPU PJRT client (xla crate / xla_extension
//! 0.5.1). HLO *text* is the interchange format — see python/compile/aot.py.

pub mod context;
pub mod engine;

pub use context::RepoContext;
pub use engine::Engine;
