//! Runtime layer: repository/artifact discovery plus the backend-dispatch
//! `Engine` facade. The raw PJRT engine (HLO-text artifacts executed on the
//! CPU PJRT client, xla crate / xla_extension 0.5.1) lives in `engine` and
//! only exists behind the `pjrt` feature; the facade lets the coordinator,
//! eval streamers, and benches stay backend-agnostic — they ask the facade
//! which [`BackendKind`] is active and never touch PJRT types directly.

pub mod context;
#[cfg(feature = "pjrt")]
pub mod engine;

use anyhow::{Context, Result};

use crate::backend::BackendKind;
use crate::util::json::{self, Json};

pub use context::RepoContext;

/// Backend-dispatch execution facade. `Engine::new` auto-selects
/// ([`BackendKind::auto`]): pjrt when compiled in and HLO artifacts exist,
/// native otherwise. All meta/weight loading is plain file IO and works on
/// every backend; artifact execution goes through [`Engine::pjrt`] (pjrt
/// builds only) or through `backend::NativeBackend` (always).
pub struct Engine {
    ctx: RepoContext,
    kind: BackendKind,
    #[cfg(feature = "pjrt")]
    pjrt: Option<engine::Engine>,
}

impl Engine {
    pub fn new(ctx: &RepoContext) -> Result<Engine> {
        let kind = BackendKind::auto(ctx);
        Engine::with_backend(ctx, kind)
    }

    pub fn with_backend(ctx: &RepoContext, kind: BackendKind) -> Result<Engine> {
        match kind {
            BackendKind::Native => Ok(Engine {
                ctx: ctx.clone(),
                kind,
                #[cfg(feature = "pjrt")]
                pjrt: None,
            }),
            BackendKind::Pjrt => Engine::new_pjrt(ctx),
        }
    }

    #[cfg(feature = "pjrt")]
    fn new_pjrt(ctx: &RepoContext) -> Result<Engine> {
        Ok(Engine {
            ctx: ctx.clone(),
            kind: BackendKind::Pjrt,
            pjrt: Some(engine::Engine::new(ctx)?),
        })
    }

    #[cfg(not(feature = "pjrt"))]
    fn new_pjrt(_ctx: &RepoContext) -> Result<Engine> {
        anyhow::bail!("the pjrt backend is not compiled in (rebuild with `--features pjrt`)")
    }

    /// A native-only engine with no artifact directory — for synthetic
    /// models and artifact-free serving (`--backend native` from scratch).
    pub fn native_ephemeral() -> Engine {
        Engine {
            ctx: RepoContext::ephemeral(),
            kind: BackendKind::Native,
            #[cfg(feature = "pjrt")]
            pjrt: None,
        }
    }

    pub fn backend(&self) -> BackendKind {
        self.kind
    }

    pub fn ctx(&self) -> &RepoContext {
        &self.ctx
    }

    /// Read a model's meta.json (plain file IO — no PJRT involved).
    pub fn load_meta(&self, model: &str) -> Result<Json> {
        let path = self.ctx.model_dir(model).join("meta.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}"))?;
        json::parse(&text)
    }

    /// The raw PJRT engine (pjrt builds, pjrt backend selected).
    #[cfg(feature = "pjrt")]
    pub fn pjrt(&self) -> Result<&engine::Engine> {
        self.pjrt
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("engine is running the native backend"))
    }

    /// Execute an artifact through the raw PJRT engine (pjrt builds only;
    /// kept for the artifact integration suite).
    #[cfg(feature = "pjrt")]
    pub fn run(&self, model: &str, tag: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.pjrt()?.run(model, tag, inputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_ephemeral_reports_backend() {
        let e = Engine::native_ephemeral();
        assert_eq!(e.backend(), BackendKind::Native);
        assert!(e.load_meta("nope").is_err());
    }
}
