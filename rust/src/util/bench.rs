//! Micro bench harness (criterion is unavailable offline): warmup + timed
//! iterations with mean/min/max, plus fixed-width table printing and the
//! shared `BENCH_*.json` trajectory-row writer used by every table/figure
//! bench binary and the CLI bench paths.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::OnceLock;
use std::time::Instant;

use crate::util::json::{self, Json};

#[derive(Debug, Clone)]
pub struct Timing {
    pub label: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl Timing {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

/// Time `f` for at least `min_iters` iterations and ~`min_ms` total.
pub fn time<T>(label: &str, min_iters: usize, min_ms: u64, mut f: impl FnMut() -> T) -> Timing {
    // warmup
    std::hint::black_box(f());
    let mut times = Vec::new();
    let start = Instant::now();
    while times.len() < min_iters || start.elapsed().as_millis() < min_ms as u128 {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_nanos() as f64);
        if times.len() > 100_000 {
            break;
        }
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    Timing {
        label: label.to_string(),
        iters: times.len(),
        mean_ns: mean,
        min_ns: times.iter().cloned().fold(f64::INFINITY, f64::min),
        max_ns: times.iter().cloned().fold(0.0, f64::max),
    }
}

/// Print a fixed-width table; `rows` are (label, cells).
pub fn print_table(title: &str, header: &[&str], rows: &[(String, Vec<String>)]) {
    println!("\n=== {title} ===");
    let label_w = rows
        .iter()
        .map(|(l, _)| l.len())
        .chain(std::iter::once(12))
        .max()
        .unwrap_or(12)
        + 2;
    let cell_w = 11usize;
    let mut head = format!("{:label_w$}", "");
    for h in header {
        head.push_str(&format!("{h:>cell_w$}"));
    }
    println!("{head}");
    for (label, cells) in rows {
        let mut line = format!("{label:label_w$}");
        for c in cells {
            line.push_str(&format!("{c:>cell_w$}"));
        }
        println!("{line}");
    }
}

/// Append one JSON object to a `BENCH_*.json` trajectory file — a JSON
/// array with one entry per bench run, so successive runs accumulate a
/// perf history. Hand-rolled read-modify-write (no serde offline); an
/// unrecognized file is restarted rather than corrupted.
pub fn append_trajectory(path: &std::path::Path, obj: &str) -> std::io::Result<()> {
    let existing = std::fs::read_to_string(path).unwrap_or_default();
    let trimmed = existing.trim();
    let out = match trimmed.strip_suffix(']') {
        Some(body) if trimmed.starts_with('[') => {
            let body = body.trim_end();
            if body == "[" {
                format!("[\n{obj}\n]\n")
            } else {
                format!("{body},\n{obj}\n]\n")
            }
        }
        _ => format!("[\n{obj}\n]\n"),
    };
    std::fs::write(path, out)
}

/// `git describe --always --dirty` of the working tree, resolved once per
/// process. `None` outside a git checkout (or without a git binary) — the
/// provenance key is simply omitted then.
pub fn git_describe() -> Option<String> {
    static GIT: OnceLock<Option<String>> = OnceLock::new();
    GIT.get_or_init(|| {
        let out = std::process::Command::new("git")
            .args(["describe", "--always", "--dirty"])
            .output()
            .ok()?;
        if !out.status.success() {
            return None;
        }
        let s = String::from_utf8_lossy(&out.stdout).trim().to_string();
        if s.is_empty() { None } else { Some(s) }
    })
    .clone()
}

/// One `BENCH_*.json` record under construction. Every row stamps shared
/// provenance — the bench name, a unix-epoch `ts`, and the working tree's
/// `git describe` — so trajectory entries are comparable across runs. The
/// single append path keeps all bench writers (CLI + bench binaries) on
/// the same serializer, so labels with quotes stay valid JSON.
pub struct TrajectoryRow {
    obj: BTreeMap<String, Json>,
}

impl TrajectoryRow {
    pub fn new(bench: &str) -> TrajectoryRow {
        let mut obj = BTreeMap::new();
        obj.insert("bench".to_string(), Json::Str(bench.to_string()));
        let ts = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        obj.insert("ts".to_string(), Json::Num(ts as f64));
        if let Some(desc) = git_describe() {
            obj.insert("git".to_string(), Json::Str(desc));
        }
        TrajectoryRow { obj }
    }

    pub fn str_field(mut self, k: &str, v: &str) -> TrajectoryRow {
        self.obj.insert(k.to_string(), Json::Str(v.to_string()));
        self
    }

    pub fn num_field(mut self, k: &str, v: f64) -> TrajectoryRow {
        self.obj.insert(k.to_string(), Json::Num(v));
        self
    }

    pub fn to_json_string(&self) -> String {
        json::dump(&Json::Obj(self.obj.clone()))
    }

    pub fn append_to(&self, path: &Path) -> std::io::Result<()> {
        append_trajectory(path, &self.to_json_string())
    }
}

/// Format a count the way the paper does (e.g. 205.51M, 516.10K).
pub fn fmt_count(n: usize) -> String {
    let x = n as f64;
    if x >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2}K", x / 1e3)
    } else {
        format!("{n}")
    }
}

/// Format perplexity the way the paper's tables do: one decimal below 100,
/// scientific (e.g. 2e3) above.
pub fn fmt_ppl(p: f64) -> String {
    if !p.is_finite() {
        "inf".to_string()
    } else if p >= 100.0 {
        let exp = p.log10().floor();
        let mant = (p / 10f64.powf(exp)).round();
        format!("{mant:.0}e{exp:.0}")
    } else {
        format!("{p:.1}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_runs() {
        let t = time("noop", 10, 1, || 1 + 1);
        assert!(t.iters >= 10);
        assert!(t.mean_ns > 0.0);
        assert!(t.min_ns <= t.mean_ns && t.mean_ns <= t.max_ns);
    }

    #[test]
    fn count_formatting_matches_paper_style() {
        assert_eq!(fmt_count(205_520_896), "205.52M");
        assert_eq!(fmt_count(258_048), "258.05K");
        assert_eq!(fmt_count(512), "512");
    }

    #[test]
    fn trajectory_accumulates_valid_json() {
        let path = std::env::temp_dir().join("perq_bench_traj_test.json");
        let _ = std::fs::remove_file(&path);
        append_trajectory(&path, r#"{"run": 1}"#).unwrap();
        append_trajectory(&path, r#"{"run": 2}"#).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = crate::util::json::parse(&text).unwrap();
        let arr = parsed.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[1].get("run").and_then(|v| v.as_usize()), Some(2));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn trajectory_row_stamps_provenance_and_appends() {
        let path = std::env::temp_dir().join("perq_bench_row_test.json");
        let _ = std::fs::remove_file(&path);
        TrajectoryRow::new("unit")
            .str_field("label", "a\"b")
            .num_field("value", 2.5)
            .append_to(&path)
            .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = crate::util::json::parse(&text).unwrap();
        let row = &parsed.as_arr().unwrap()[0];
        assert_eq!(row.get("bench").and_then(|v| v.as_str()), Some("unit"));
        assert_eq!(row.get("label").and_then(|v| v.as_str()), Some("a\"b"));
        assert_eq!(row.get("value").and_then(|v| v.as_f64()), Some(2.5));
        assert!(row.get("ts").and_then(|v| v.as_f64()).unwrap_or(0.0) > 0.0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn ppl_formatting() {
        assert_eq!(fmt_ppl(16.94), "16.9");
        assert_eq!(fmt_ppl(2345.0), "2e3");
        assert_eq!(fmt_ppl(934.0), "9e2");
    }
}
