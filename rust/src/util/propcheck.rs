//! Seed-sweep property-test helpers (proptest is unavailable offline).
//! `check(cases, |g| ...)` runs a property across many deterministic seeds
//! with a simple value generator; failures report the seed for replay.

use crate::data::rng::Rng;

pub struct Gen {
    pub seed: u64,
    rng: Rng,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { seed, rng: Rng::new(seed) }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.next_below((hi - lo + 1) as u64) as usize
    }

    pub fn f32_normal(&mut self, scale: f32) -> f32 {
        self.rng.next_normal() as f32 * scale
    }

    pub fn vec_normal(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_normal(scale)).collect()
    }

    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.rng.next_below(items.len() as u64) as usize]
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_f64() < 0.5
    }
}

/// Run `prop` for `cases` deterministic seeds; panic with the seed on the
/// first failure so it can be replayed directly.
pub fn check(cases: u64, prop: impl Fn(&mut Gen)) {
    for seed in 0..cases {
        let mut g = Gen::new(0xC0DE_0000 + seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(e) = result {
            eprintln!("property failed at seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_ranges() {
        check(50, |g| {
            let n = g.usize_in(3, 17);
            assert!((3..=17).contains(&n));
            let v = g.vec_normal(n, 2.0);
            assert_eq!(v.len(), n);
        });
    }

    #[test]
    #[should_panic]
    fn failures_propagate() {
        check(10, |g| {
            assert!(g.usize_in(0, 100) > 1000);
        });
    }
}
