//! Minimal JSON parser — just enough for artifacts/<model>/meta.json
//! (objects, arrays, strings, numbers, booleans, null; no escapes beyond
//! \" \\ \/ \n \t, which is all python's json.dump emits for our meta).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

/// Serialize a [`Json`] value to compact JSON text. The inverse of
/// [`parse`] for everything this module can represent: object keys keep
/// `BTreeMap` order (deterministic output), numbers that hold integral
/// values print without a fractional part, and non-finite numbers (which
/// JSON cannot express) degrade to `null`. Used by the `.perq` deployment
/// artifact headers, which must round-trip through `parse`.
pub fn dump(j: &Json) -> String {
    let mut out = String::new();
    dump_value(j, &mut out);
    out
}

fn dump_value(j: &Json, out: &mut String) {
    match j {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if !n.is_finite() {
                out.push_str("null");
            } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                // f64 Display is shortest-round-trip, so parse(dump(x)) == x
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => dump_string(s, out),
        Json::Arr(v) => {
            out.push('[');
            for (i, x) in v.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                dump_value(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, v)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                dump_string(k, out);
                out.push(':');
                dump_value(v, out);
            }
            out.push('}');
        }
    }
}

fn dump_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c => out.push(c),
        }
    }
    out.push('"');
}

pub fn parse(text: &str) -> Result<Json> {
    let bytes = text.as_bytes();
    let mut pos = 0usize;
    let v = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        bail!("trailing garbage at byte {pos}");
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        bail!("unexpected end of input");
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => {
            expect(b, pos, "true")?;
            Ok(Json::Bool(true))
        }
        b'f' => {
            expect(b, pos, "false")?;
            Ok(Json::Bool(false))
        }
        b'n' => {
            expect(b, pos, "null")?;
            Ok(Json::Null)
        }
        _ => parse_num(b, pos),
    }
}

fn expect(b: &[u8], pos: &mut usize, lit: &str) -> Result<()> {
    if b.len() - *pos < lit.len() || &b[*pos..*pos + lit.len()] != lit.as_bytes() {
        bail!("expected `{lit}` at byte {}", *pos);
    }
    *pos += lit.len();
    Ok(())
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    bail!("dangling escape");
                }
                out.push(match b[*pos] {
                    b'n' => '\n',
                    b't' => '\t',
                    b'r' => '\r',
                    c => c as char,
                });
                *pos += 1;
            }
            c => {
                out.push(c as char);
                *pos += 1;
            }
        }
    }
    bail!("unterminated string");
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos])?;
    Ok(Json::Num(s.parse::<f64>()?))
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // [
    let mut out = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(out));
    }
    loop {
        out.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(out));
            }
            _ => bail!("expected , or ] at byte {}", *pos),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // {
    let mut out = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(out));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            bail!("expected : at byte {}", *pos);
        }
        *pos += 1;
        let val = parse_value(b, pos)?;
        out.insert(key, val);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(out));
            }
            _ => bail!("expected , or }} at byte {}", *pos),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_meta_like_structure() {
        let j = parse(
            r#"{"config": {"name": "m", "d_model": 256, "block_sizes": [1, 16]},
               "weights": [{"name": "embed", "shape": [32, 256]}],
               "flag": true, "nothing": null}"#,
        )
        .unwrap();
        assert_eq!(j.get("config").unwrap().get("name").unwrap().as_str(), Some("m"));
        assert_eq!(j.get("config").unwrap().get("d_model").unwrap().as_usize(), Some(256));
        let bs = j.get("config").unwrap().get("block_sizes").unwrap().as_arr().unwrap();
        assert_eq!(bs.len(), 2);
        assert_eq!(j.get("flag"), Some(&Json::Bool(true)));
    }

    #[test]
    fn parses_numbers() {
        assert_eq!(parse("3.25").unwrap().as_f64(), Some(3.25));
        assert_eq!(parse("-7").unwrap().as_f64(), Some(-7.0));
        assert_eq!(parse("1e3").unwrap().as_f64(), Some(1000.0));
    }

    #[test]
    fn parses_escapes() {
        assert_eq!(parse(r#""a\nb""#).unwrap().as_str(), Some("a\nb"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1, 2,]").is_err());
        assert!(parse("{} extra").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn dump_round_trips_through_parse() {
        let text = r#"{"config": {"name": "m\n\"x\"", "d_model": 256,
            "scale": 0.125, "blocks": [1, 16], "flag": true, "none": null}}"#;
        let j = parse(text).unwrap();
        let dumped = dump(&j);
        assert_eq!(parse(&dumped).unwrap(), j);
        // integral numbers print without a fractional part
        assert!(dumped.contains("\"d_model\":256"), "{dumped}");
        assert!(dumped.contains("\"scale\":0.125"), "{dumped}");
    }

    #[test]
    fn dump_nonfinite_degrades_to_null() {
        assert_eq!(dump(&Json::Num(f64::NAN)), "null");
        assert_eq!(dump(&Json::Num(f64::INFINITY)), "null");
    }
}
