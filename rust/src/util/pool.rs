//! Thread-pool and buffer-pool substrate for the hot paths.
//!
//! * [`parallel_map`] — scoped fan-out/fan-in for the coarse per-layer
//!   rounding jobs (caller picks the worker count per call).
//! * [`WorkerPool`] / [`global`] — a *persistent* worker pool for the
//!   fine-grained serving kernels (`Mat::par_matmul_into`,
//!   `tensor::qmat::qgemm_into`). Spawning OS threads per matmul costs
//!   tens of microseconds — comparable to the kernel itself at serving
//!   shapes — so the serving path keeps one set of workers parked on a
//!   condvar for the lifetime of the process.
//! * [`BufPool`] — bounded f32 scratch-buffer recycling for per-layer
//!   activation buffers.
//!
//! (tokio/rayon are unavailable offline; the needs here are CPU-bound
//! fan-out/fan-in, which condvar-parked threads express directly.)

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Run `f(i)` for every i in 0..n across `workers` threads; results are
/// returned in index order. Panics in jobs propagate.
pub fn parallel_map<T: Send>(n: usize, workers: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    let workers = workers.max(1).min(n.max(1));
    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = f(i);
                *results[i].lock().unwrap() = Some(out);
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("job did not complete"))
        .collect()
}

// ---------------------------------------------------------------------
// Persistent worker pool
// ---------------------------------------------------------------------

/// One submitted batch of indexed tasks. `f` is a raw pointer to the
/// caller's closure (no faked `'static` lifetime); the barrier in
/// [`WorkerPool::run`] is what keeps every dereference inside the
/// closure's real lifetime — see the SAFETY comments on the `Send`/`Sync`
/// impls and at the dereference site in [`run_tasks`].
struct Batch {
    f: *const (dyn Fn(usize) + Sync),
    next: AtomicUsize,
    total: usize,
    panicked: AtomicBool,
    done: Mutex<usize>,
    done_cv: Condvar,
}

// SAFETY: `Batch` is shared across threads only through the `Arc` that
// `WorkerPool::run` publishes in the slot. The raw `f` pointer is valid
// for the whole sharing window: `run` borrows the closure from its caller
// and does not return until `done == total`, and a worker only
// dereferences `f` for an index it claimed *before* contributing the
// increment that lets `done` reach `total` (the `done` mutex orders the
// claim/deref before the submitter's wake-up). A worker that arrives after
// the batch completed sees `next >= total` and never touches `f`. All
// other fields are atomics or lock-protected.
unsafe impl Send for Batch {}
// SAFETY: see the `Send` impl above — the same barrier argument covers
// shared (`&Batch`) access; `f` itself is `dyn Fn + Sync`, so calling it
// concurrently from several workers is sound.
unsafe impl Sync for Batch {}

struct Slot {
    epoch: u64,
    batch: Option<Arc<Batch>>,
    /// set by `Drop` — workers exit their loop instead of re-parking
    shutdown: bool,
}

struct Shared {
    slot: Mutex<Slot>,
    work_cv: Condvar,
}

thread_local! {
    /// True on pool worker threads — nested `run` calls execute inline
    /// instead of deadlocking on the (busy) pool.
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

/// Persistent work-stealing-free worker pool: one batch at a time, indexed
/// tasks claimed via an atomic counter, submitter participates. Used by
/// the serving kernels through [`global`].
pub struct WorkerPool {
    shared: Arc<Shared>,
    /// threads parked in the pool (the submitter adds one more at run time)
    spawned: usize,
    /// serializes batches; `try_lock` failure → run inline (never blocks)
    submit: Mutex<()>,
    /// joined on drop so non-global pools release their threads
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// A pool with `parallelism` total lanes (spawns `parallelism - 1`
    /// threads; the submitting thread is the final lane).
    pub fn new(parallelism: usize) -> WorkerPool {
        let spawned = parallelism.max(1) - 1;
        let shared = Arc::new(Shared {
            slot: Mutex::new(Slot { epoch: 0, batch: None, shutdown: false }),
            work_cv: Condvar::new(),
        });
        let handles = (0..spawned)
            .map(|_| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name("perq-worker".into())
                    .spawn(move || worker_loop(&sh))
                    .expect("spawning pool worker")
            })
            .collect();
        WorkerPool { shared, spawned, submit: Mutex::new(()), handles }
    }

    /// Total parallel lanes (spawned workers + the submitting thread).
    pub fn parallelism(&self) -> usize {
        self.spawned + 1
    }

    /// Run `f(0..total)` across the pool. Blocks until every task has
    /// completed. Reentrant calls (from inside a task) and contended calls
    /// (another batch in flight) degrade to inline serial execution, so
    /// `run` can never deadlock.
    pub fn run(&self, total: usize, f: &(dyn Fn(usize) + Sync)) {
        if total == 0 {
            return;
        }
        if total == 1 || self.spawned == 0 || IN_POOL.with(|c| c.get()) {
            for i in 0..total {
                f(i);
            }
            return;
        }
        let guard = match self.submit.try_lock() {
            Ok(g) => g,
            // a previous batch panicked during submission — the pool
            // itself is intact, so recover the lock rather than silently
            // degrading every future call to serial execution
            Err(std::sync::TryLockError::Poisoned(p)) => p.into_inner(),
            Err(std::sync::TryLockError::WouldBlock) => {
                for i in 0..total {
                    f(i);
                }
                return;
            }
        };
        // Store the closure as a raw pointer (a safe cast — the unsafe
        // dereference lives in `run_tasks`, guarded by the `done == total`
        // barrier below: this frame cannot return, and so `f` cannot die,
        // while any worker still holds an index to run).
        let batch = Arc::new(Batch {
            f: f as *const (dyn Fn(usize) + Sync),
            next: AtomicUsize::new(0),
            total,
            panicked: AtomicBool::new(false),
            done: Mutex::new(0),
            done_cv: Condvar::new(),
        });
        {
            let mut slot = self.shared.slot.lock().unwrap();
            slot.epoch += 1;
            slot.batch = Some(Arc::clone(&batch));
            self.shared.work_cv.notify_all();
        }
        // participate, then wait for the stragglers
        run_tasks(&batch);
        let mut done = batch.done.lock().unwrap();
        while *done < total {
            done = batch.done_cv.wait(done).unwrap();
        }
        drop(done);
        {
            let mut slot = self.shared.slot.lock().unwrap();
            slot.batch = None;
        }
        // release the submit lock *before* propagating a task panic so the
        // mutex is never poisoned and later batches still run in parallel
        drop(guard);
        if batch.panicked.load(Ordering::SeqCst) {
            panic!("worker pool task panicked");
        }
    }
}

impl Drop for WorkerPool {
    /// Signal workers to exit so dropping a non-global pool does not leak
    /// its threads. (The [`global`] pool lives in a static and is never
    /// dropped.)
    fn drop(&mut self) {
        {
            let mut slot = match self.shared.slot.lock() {
                Ok(s) => s,
                Err(p) => p.into_inner(),
            };
            slot.shutdown = true;
            slot.epoch += 1;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    IN_POOL.with(|c| c.set(true));
    let mut seen = 0u64;
    loop {
        let batch = {
            let mut slot = shared.slot.lock().unwrap();
            loop {
                if slot.shutdown {
                    return;
                }
                if slot.epoch != seen {
                    seen = slot.epoch;
                    if let Some(b) = slot.batch.clone() {
                        break b;
                    }
                }
                slot = shared.work_cv.wait(slot).unwrap();
            }
        };
        run_tasks(&batch);
    }
}

fn run_tasks(batch: &Batch) {
    loop {
        let i = batch.next.fetch_add(1, Ordering::Relaxed);
        if i >= batch.total {
            break;
        }
        // SAFETY: `i < total` here, so the submitter is still blocked on
        // the `done == total` barrier in `WorkerPool::run` — our matching
        // `done` increment happens only after this call returns — which
        // keeps the caller's frame (and the closure it borrows) alive for
        // the whole dereference.
        let f = unsafe { &*batch.f };
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i)));
        if r.is_err() {
            batch.panicked.store(true, Ordering::SeqCst);
        }
        let mut done = batch.done.lock().unwrap();
        *done += 1;
        if *done >= batch.total {
            batch.done_cv.notify_all();
        }
    }
}

/// The process-wide serving pool, spawned lazily on first use with
/// [`default_workers`] lanes (`--threads` CLI flag via
/// [`set_default_parallelism`], else `PERQ_THREADS`, else core count).
pub fn global() -> &'static WorkerPool {
    static POOL: OnceLock<WorkerPool> = OnceLock::new();
    POOL.get_or_init(|| WorkerPool::new(default_workers()))
}

/// A raw pointer that may cross thread boundaries — used by the kernels to
/// hand each pool task its disjoint output slice. Callers must guarantee
/// disjointness.
#[derive(Clone, Copy)]
pub struct SendPtr<T>(pub *mut T);
// SAFETY: SendPtr is a plain address; sending it to another thread moves
// no data. The construction sites (mat.rs / qmat.rs row fan-out) promise
// that concurrent tasks write through it only at disjoint offsets, and the
// pool's completion barrier sequences all writes before the submitter
// reads the buffer again.
unsafe impl<T> Send for SendPtr<T> {}
// SAFETY: `&SendPtr` only yields copies of the address (`get`); the
// disjoint-offsets contract above is what makes the resulting concurrent
// writes sound.
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    #[inline]
    pub fn get(self) -> *mut T {
        self.0
    }
}

// ---------------------------------------------------------------------
// Bounded buffer pool
// ---------------------------------------------------------------------

/// Reusable f32 scratch-buffer pool — the native execution backend's
/// per-layer activation buffers cycle through here so steady-state scoring
/// performs no heap allocation. Single-owner (no locking): each backend
/// instance keeps its own pool.
///
/// Retention is bounded on two axes (buffer count and total pooled
/// elements), so serving a stream of varying batch shapes cannot grow the
/// pool without limit: once full, the smallest parked buffers are evicted
/// first (large buffers are the ones worth keeping).
pub struct BufPool {
    free: Vec<Vec<f32>>,
    /// total parked capacity, in f32 elements
    held: usize,
    max_buffers: usize,
    max_elems: usize,
}

impl Default for BufPool {
    fn default() -> Self {
        BufPool::new()
    }
}

impl BufPool {
    /// Default bounds: 64 buffers / 32 Mi elements (128 MiB of f32).
    pub fn new() -> BufPool {
        BufPool::with_limits(64, 32 << 20)
    }

    /// A pool retaining at most `max_buffers` buffers and `max_elems`
    /// total f32 elements.
    pub fn with_limits(max_buffers: usize, max_elems: usize) -> BufPool {
        BufPool { free: Vec::new(), held: 0, max_buffers, max_elems }
    }

    /// Take a buffer of exactly `len` elements, zero-filled. Reuses the
    /// smallest free buffer whose capacity fits, else allocates.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let mut best: Option<usize> = None;
        for (i, b) in self.free.iter().enumerate() {
            if b.capacity() >= len && best.map_or(true, |j| b.capacity() < self.free[j].capacity()) {
                best = Some(i);
            }
        }
        match best {
            Some(i) => {
                let mut b = self.free.swap_remove(i);
                self.held -= b.capacity();
                b.clear();
                b.resize(len, 0.0);
                b
            }
            None => vec![0.0; len],
        }
    }

    /// Return a buffer for reuse. Buffers that would push the pool past
    /// its bounds evict smaller parked buffers; a buffer larger than the
    /// whole element budget is dropped outright.
    pub fn put(&mut self, v: Vec<f32>) {
        let cap = v.capacity();
        if cap == 0 || cap > self.max_elems {
            return;
        }
        // evict smallest-first until the newcomer fits both bounds
        while !self.free.is_empty()
            && (self.free.len() >= self.max_buffers || self.held + cap > self.max_elems)
        {
            let smallest = self
                .free
                .iter()
                .enumerate()
                .min_by_key(|(_, b)| b.capacity())
                .map(|(i, _)| i)
                .unwrap();
            if self.free[smallest].capacity() >= cap {
                // everything parked is at least as useful as the newcomer
                return;
            }
            self.held -= self.free.swap_remove(smallest).capacity();
        }
        if self.free.len() >= self.max_buffers || self.held + cap > self.max_elems {
            return;
        }
        self.held += cap;
        self.free.push(v);
    }

    /// Number of parked buffers (diagnostics/tests).
    pub fn idle(&self) -> usize {
        self.free.len()
    }

    /// Total parked capacity in f32 elements (diagnostics/tests).
    pub fn held_elems(&self) -> usize {
        self.held
    }
}

/// Process-wide parallelism override (`--threads N`): 0 = unset. Must be
/// stored before the first kernel touches [`global`] — `main` applies it
/// during argument parsing, ahead of any model work.
static PARALLELISM_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Set the worker-count override (the `--threads N` CLI flag). Takes
/// precedence over `PERQ_THREADS` and hardware detection. Has no effect
/// on a global pool that already spawned — call before first use.
pub fn set_default_parallelism(n: usize) {
    PARALLELISM_OVERRIDE.store(n, Ordering::Relaxed);
}

/// Pure resolution of the worker count from (CLI override, `PERQ_THREADS`
/// env value, detected hardware parallelism) — split out so the
/// precedence is unit-testable without touching process state.
pub fn resolve_workers(override_n: usize, env: Option<&str>, hw: usize) -> usize {
    if override_n > 0 {
        return override_n.clamp(1, 64);
    }
    if let Some(raw) = env {
        match raw.trim().parse::<usize>() {
            Ok(n) if n > 0 => return n.clamp(1, 64),
            // a mistyped PERQ_THREADS silently falling back to hardware
            // detection hides sizing mistakes — name the bad value and
            // what is used instead
            _ => crate::log_warn!(
                "PERQ_THREADS={raw:?} is not a positive lane count — \
                 using detected parallelism ({})",
                hw.clamp(1, 16)
            ),
        }
    }
    hw.clamp(1, 16)
}

/// Default worker count: `--threads` override, else `PERQ_THREADS`, else
/// physical parallelism capped at 16.
pub fn default_workers() -> usize {
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    resolve_workers(
        PARALLELISM_OVERRIDE.load(Ordering::Relaxed),
        std::env::var("PERQ_THREADS").ok().as_deref(),
        hw,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = parallel_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn handles_zero_jobs() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn single_worker_equivalent() {
        let a = parallel_map(37, 1, |i| i + 1);
        let b = parallel_map(37, 7, |i| i + 1);
        assert_eq!(a, b);
    }

    #[test]
    fn buf_pool_recycles() {
        let mut pool = BufPool::new();
        let a = pool.take(128);
        let ptr = a.as_ptr();
        pool.put(a);
        assert_eq!(pool.idle(), 1);
        let b = pool.take(64); // fits in the recycled 128-cap buffer
        assert_eq!(b.as_ptr(), ptr, "expected buffer reuse");
        assert_eq!(b.len(), 64);
        assert!(b.iter().all(|&v| v == 0.0));
        pool.put(b);
        let c = pool.take(256); // too big for the parked buffer
        assert_eq!(c.len(), 256);
        assert_eq!(pool.idle(), 1);
    }

    #[test]
    fn buf_pool_bounds_buffer_count() {
        let mut pool = BufPool::with_limits(4, 1 << 20);
        for len in [16usize, 32, 64, 128, 256, 512] {
            pool.put(vec![0.0; len]);
        }
        assert!(pool.idle() <= 4);
        // smallest-first eviction keeps the big (most reusable) buffers
        let caps: Vec<usize> = pool.free.iter().map(|b| b.capacity()).collect();
        assert!(caps.iter().all(|&c| c >= 64), "small buffers evicted first: {caps:?}");
    }

    #[test]
    fn buf_pool_bounds_total_elems() {
        let mut pool = BufPool::with_limits(64, 1000);
        for _ in 0..10 {
            pool.put(vec![0.0; 400]);
        }
        assert!(pool.held_elems() <= 1000, "held {}", pool.held_elems());
        // an over-budget buffer is never parked
        pool.put(vec![0.0; 4000]);
        assert!(pool.held_elems() <= 1000);
    }

    #[test]
    fn buf_pool_varying_shapes_stay_bounded() {
        // the regression this bound exists for: a stream of distinct batch
        // shapes must not grow the pool monotonically
        let mut pool = BufPool::new();
        for i in 1..200usize {
            let b = pool.take(i * 1024);
            pool.put(b);
        }
        assert!(pool.idle() <= 64);
        assert!(pool.held_elems() <= 32 << 20);
    }

    #[test]
    fn heavy_jobs_all_complete() {
        let out = parallel_map(32, 4, |i| {
            let mut acc = 0u64;
            for k in 0..10_000 {
                acc = acc.wrapping_add((i as u64).wrapping_mul(k));
            }
            acc
        });
        assert_eq!(out.len(), 32);
    }

    #[test]
    fn worker_pool_runs_all_tasks() {
        let pool = WorkerPool::new(4);
        let hits: Vec<AtomicUsize> = (0..100).map(|_| AtomicUsize::new(0)).collect();
        pool.run(100, &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn worker_pool_nested_runs_inline() {
        let pool = WorkerPool::new(4);
        let count = AtomicUsize::new(0);
        pool.run(8, &|_| {
            // nested submission from a task must not deadlock
            super::global().run(4, &|_| {
                count.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(count.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn worker_pool_reusable_across_batches() {
        let pool = WorkerPool::new(3);
        for round in 1..20usize {
            let sum = AtomicUsize::new(0);
            pool.run(round, &|i| {
                sum.fetch_add(i + 1, Ordering::Relaxed);
            });
            assert_eq!(sum.load(Ordering::Relaxed), round * (round + 1) / 2);
        }
    }

    #[test]
    fn worker_pool_drop_joins_workers() {
        // drop must signal shutdown and join — no hang, no leaked threads
        let pool = WorkerPool::new(3);
        let n = AtomicUsize::new(0);
        pool.run(10, &|_| {
            n.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 10);
        drop(pool); // joins; a hang here fails the test via timeout
    }

    #[test]
    fn worker_pool_send_ptr_disjoint_writes() {
        // Miri regression target for the Batch raw-pointer design and the
        // SendPtr contract: concurrent tasks write disjoint rows of one
        // buffer through a shared base pointer, the barrier in `run`
        // sequences the writes before the submitter reads them back, and
        // the borrowed closure state (`out`, `rows`) must never be
        // touched after `run` returns. Any dangling `f` dereference or
        // overlapping write is UB that `cargo miri test` flags here.
        const ROWS: usize = 16;
        const COLS: usize = 8;
        let pool = WorkerPool::new(4);
        let mut out = vec![0u32; ROWS * COLS];
        let base = SendPtr(out.as_mut_ptr());
        pool.run(ROWS, &|r| {
            // SAFETY: task r exclusively owns rows r*COLS..(r+1)*COLS of
            // `out`, which outlives `run` (the submitter blocks in `run`
            // until every task finished).
            let row = unsafe { std::slice::from_raw_parts_mut(base.get().add(r * COLS), COLS) };
            for (c, v) in row.iter_mut().enumerate() {
                *v = (r * COLS + c) as u32;
            }
        });
        assert_eq!(out, (0..(ROWS * COLS) as u32).collect::<Vec<_>>());
    }

    #[test]
    fn resolve_workers_precedence() {
        // CLI override wins over env and hardware
        assert_eq!(resolve_workers(3, Some("7"), 12), 3);
        // env wins over hardware
        assert_eq!(resolve_workers(0, Some("7"), 12), 7);
        assert_eq!(resolve_workers(0, Some(" 5 "), 12), 5);
        // bad/zero env falls through to hardware (capped at 16)
        assert_eq!(resolve_workers(0, Some("junk"), 12), 12);
        assert_eq!(resolve_workers(0, Some("0"), 12), 12);
        assert_eq!(resolve_workers(0, None, 64), 16);
        // explicit requests clamp into [1, 64]
        assert_eq!(resolve_workers(1000, None, 4), 64);
        assert_eq!(resolve_workers(0, Some("1000"), 4), 64);
    }

    #[test]
    fn global_pool_singleton() {
        let a = global() as *const WorkerPool;
        let b = global() as *const WorkerPool;
        assert_eq!(a, b);
        assert!(global().parallelism() >= 1);
    }
}
