//! Scoped work-queue thread pool for the per-layer rounding jobs.
//! (tokio is unavailable offline; the coordinator's parallelism needs are
//! CPU-bound fan-out/fan-in, which scoped threads express directly.)

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run `f(i)` for every i in 0..n across `workers` threads; results are
/// returned in index order. Panics in jobs propagate.
pub fn parallel_map<T: Send>(n: usize, workers: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    let workers = workers.max(1).min(n.max(1));
    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = f(i);
                *results[i].lock().unwrap() = Some(out);
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("job did not complete"))
        .collect()
}

/// Reusable f32 scratch-buffer pool — the native execution backend's
/// per-layer activation buffers cycle through here so steady-state scoring
/// performs no heap allocation. Single-owner (no locking): each backend
/// instance keeps its own pool.
#[derive(Default)]
pub struct BufPool {
    free: Vec<Vec<f32>>,
}

impl BufPool {
    pub fn new() -> BufPool {
        BufPool::default()
    }

    /// Take a buffer of exactly `len` elements, zero-filled. Reuses the
    /// smallest free buffer whose capacity fits, else allocates.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let mut best: Option<usize> = None;
        for (i, b) in self.free.iter().enumerate() {
            if b.capacity() >= len && best.map_or(true, |j| b.capacity() < self.free[j].capacity()) {
                best = Some(i);
            }
        }
        match best {
            Some(i) => {
                let mut b = self.free.swap_remove(i);
                b.clear();
                b.resize(len, 0.0);
                b
            }
            None => vec![0.0; len],
        }
    }

    /// Return a buffer for reuse.
    pub fn put(&mut self, v: Vec<f32>) {
        if v.capacity() > 0 && self.free.len() < 64 {
            self.free.push(v);
        }
    }

    /// Number of parked buffers (diagnostics/tests).
    pub fn idle(&self) -> usize {
        self.free.len()
    }
}

/// Default worker count: physical parallelism, capped.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = parallel_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn handles_zero_jobs() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn single_worker_equivalent() {
        let a = parallel_map(37, 1, |i| i + 1);
        let b = parallel_map(37, 7, |i| i + 1);
        assert_eq!(a, b);
    }

    #[test]
    fn buf_pool_recycles() {
        let mut pool = BufPool::new();
        let a = pool.take(128);
        let ptr = a.as_ptr();
        pool.put(a);
        assert_eq!(pool.idle(), 1);
        let b = pool.take(64); // fits in the recycled 128-cap buffer
        assert_eq!(b.as_ptr(), ptr, "expected buffer reuse");
        assert_eq!(b.len(), 64);
        assert!(b.iter().all(|&v| v == 0.0));
        pool.put(b);
        let c = pool.take(256); // too big for the parked buffer
        assert_eq!(c.len(), 256);
        assert_eq!(pool.idle(), 1);
    }

    #[test]
    fn heavy_jobs_all_complete() {
        let out = parallel_map(32, 4, |i| {
            let mut acc = 0u64;
            for k in 0..10_000 {
                acc = acc.wrapping_add((i as u64).wrapping_mul(k));
            }
            acc
        });
        assert_eq!(out.len(), 32);
    }
}
