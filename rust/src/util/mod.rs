//! Offline-environment utilities: this build environment has no network
//! access and only the `xla` crate's dependency tree vendored, so the
//! conveniences usually pulled from crates.io are implemented here —
//! a minimal JSON parser (`json`), a micro bench harness (`bench`), a CLI
//! argument helper (`cli`), a scoped work-queue thread pool (`pool`), and
//! seed-sweep property-test helpers (`propcheck`).

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod propcheck;
