//! Tiny CLI argument helper (clap is unavailable offline): supports
//! `--key value`, `--key=value`, and bare flags.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

pub fn parse(argv: &[String]) -> Args {
    let mut out = Args::default();
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(stripped) = a.strip_prefix("--") {
            if let Some((k, v)) = stripped.split_once('=') {
                out.options.insert(k.to_string(), v.to_string());
            } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                out.options.insert(stripped.to_string(), argv[i + 1].clone());
                i += 1;
            } else {
                out.flags.push(stripped.to_string());
            }
        } else {
            out.positional.push(a.clone());
        }
        i += 1;
    }
    out
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// The shared `--backend {native,pjrt,auto}` selection, if present.
    /// Resolution (auto-detect, validation) lives in
    /// `backend::BackendKind::resolve`.
    pub fn backend(&self) -> Option<&str> {
        self.get("backend")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = parse(&v(&["quantize", "--model", "llama_tiny", "--block=32", "--verbose"]));
        assert_eq!(a.positional, vec!["quantize"]);
        assert_eq!(a.get("model"), Some("llama_tiny"));
        assert_eq!(a.get_usize("block", 0), 32);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn defaults() {
        let a = parse(&v(&[]));
        assert_eq!(a.get_or("x", "y"), "y");
        assert_eq!(a.get_usize("n", 7), 7);
        assert_eq!(a.backend(), None);
    }

    #[test]
    fn backend_selection() {
        let a = parse(&v(&["serve", "--backend", "native"]));
        assert_eq!(a.backend(), Some("native"));
        let b = parse(&v(&["--backend=pjrt"]));
        assert_eq!(b.backend(), Some("pjrt"));
    }
}
