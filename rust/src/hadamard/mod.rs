//! Hadamard rotation substrate: matrix constructions (Sylvester, Paley I/II),
//! the in-place fast Walsh-Hadamard transform, the optimized non-power-of-2
//! transform of Appendix A.1, and the analytic op-count model behind the
//! paper's Tables 3 and 4.

pub mod construct;
pub mod fwht;
pub mod nonpow2;
pub mod opcount;
pub mod rotator;

pub use construct::{hadamard, normalized_hadamard, pow2_split};
pub use rotator::BlockRotator;
