//! Hadamard matrix constructions — rust twin of python/compile/hadamard_np.py.
//!
//! Orders: powers of two (Sylvester); q+1 for prime q ≡ 3 mod 4 (Paley I:
//! 12, 20, 44, ...); 2(q+1) for prime q ≡ 1 mod 4 (Paley II: 28, 76); and
//! any 2^j multiple of those bases via Sylvester doubling (448 = 2^4·28,
//! 768 = 2^6·12, ...). Matrices are ±1; `normalized_hadamard` divides by
//! √n to give the rotation used throughout the paper.

use anyhow::{bail, ensure, Result};

use crate::tensor::Mat;

pub fn is_prime(n: u64) -> bool {
    if n < 2 {
        return false;
    }
    let mut i = 2;
    while i * i <= n {
        if n % i == 0 {
            return false;
        }
        i += 1;
    }
    true
}

/// (k, t) with d = k·t, k the power-of-2 part, t odd.
pub fn pow2_split(d: usize) -> (usize, usize) {
    let mut k = 1;
    let mut t = d;
    while t % 2 == 0 {
        t /= 2;
        k *= 2;
    }
    (k, t)
}

fn jacobsthal(q: usize) -> Vec<i8> {
    // chi[a] for a in 0..q: quadratic residue character
    let mut chi = vec![0i8; q];
    let mut residues = vec![false; q];
    for x in 1..q {
        residues[(x * x) % q] = true;
    }
    for a in 1..q {
        chi[a] = if residues[a] { 1 } else { -1 };
    }
    chi
}

/// Paley I: order q+1 for prime q ≡ 3 (mod 4). Entries ±1 as i8 grid.
pub fn paley1(q: usize) -> Vec<Vec<i8>> {
    assert!(is_prime(q as u64) && q % 4 == 3, "paley1 needs prime q ≡ 3 mod 4");
    let n = q + 1;
    let chi = jacobsthal(q);
    let mut h = vec![vec![0i8; n]; n];
    h[0][0] = 1;
    for j in 1..n {
        h[0][j] = 1;
        h[j][0] = -1;
    }
    for i in 0..q {
        for j in 0..q {
            let s = chi[(i + q - j) % q];
            h[i + 1][j + 1] = if i == j { 1 } else { s };
        }
    }
    h
}

/// Paley II: order 2(q+1) for prime q ≡ 1 (mod 4).
pub fn paley2(q: usize) -> Vec<Vec<i8>> {
    assert!(is_prime(q as u64) && q % 4 == 1, "paley2 needs prime q ≡ 1 mod 4");
    let m = q + 1;
    let chi = jacobsthal(q);
    // S: symmetric conference-type matrix with zero diagonal
    let mut s = vec![vec![0i8; m]; m];
    for j in 1..m {
        s[0][j] = 1;
        s[j][0] = 1;
    }
    for i in 0..q {
        for j in 0..q {
            if i != j {
                s[i + 1][j + 1] = chi[(i + q - j) % q];
            }
        }
    }
    // H = kron(S, A) + kron(I, B); A = [[1,1],[1,-1]], B = [[1,-1],[-1,-1]]
    let a = [[1i8, 1], [1, -1]];
    let b = [[1i8, -1], [-1, -1]];
    let n = 2 * m;
    let mut h = vec![vec![0i8; n]; n];
    for i in 0..m {
        for j in 0..m {
            for u in 0..2 {
                for v in 0..2 {
                    let mut val = s[i][j] * a[u][v];
                    if i == j {
                        val += b[u][v];
                    }
                    h[2 * i + u][2 * j + v] = val;
                }
            }
        }
    }
    h
}

fn sylvester_double(h: Vec<Vec<i8>>) -> Vec<Vec<i8>> {
    let n = h.len();
    let mut out = vec![vec![0i8; 2 * n]; 2 * n];
    for i in 0..n {
        for j in 0..n {
            out[i][j] = h[i][j];
            out[i][j + n] = h[i][j];
            out[i + n][j] = h[i][j];
            out[i + n][j + n] = -h[i][j];
        }
    }
    out
}

/// Unnormalized ±1 Hadamard matrix of order n.
pub fn hadamard_signs(n: usize) -> Result<Vec<Vec<i8>>> {
    if n == 1 {
        return Ok(vec![vec![1]]);
    }
    let (k, t) = pow2_split(n);
    if t == 1 {
        let mut h = vec![vec![1i8]];
        for _ in 0..k.trailing_zeros() {
            h = sylvester_double(h);
        }
        return Ok(h);
    }
    let base = 4 * t;
    if n % base != 0 || !(n / base).is_power_of_two() {
        bail!("no Hadamard construction for order {n}");
    }
    let doublings = (n / base).trailing_zeros();
    let mut h = if is_prime((base - 1) as u64) && (base - 1) % 4 == 3 {
        paley1(base - 1)
    } else if base % 2 == 0 && is_prime((base / 2 - 1) as u64) && (base / 2 - 1) % 4 == 1 {
        paley2(base / 2 - 1)
    } else {
        bail!("no Paley construction for base order {base}");
    };
    for _ in 0..doublings {
        h = sylvester_double(h);
    }
    Ok(h)
}

/// Unnormalized Hadamard matrix as a Mat of ±1.0.
pub fn hadamard(n: usize) -> Result<Mat> {
    let h = hadamard_signs(n)?;
    Ok(Mat::from_fn(n, n, |i, j| h[i][j] as f32))
}

/// Normalized Hadamard rotation H/√n (columns unit-norm, ‖col‖_∞ = 1/√n).
pub fn normalized_hadamard(n: usize) -> Result<Mat> {
    let mut m = hadamard(n)?;
    m.scale(1.0 / (n as f32).sqrt());
    Ok(m)
}

/// Orders for which a construction exists (used by config validation).
pub fn constructible(n: usize) -> bool {
    hadamard_signs(n).is_ok()
}

/// Dense block-diagonal rotation I_{d/b} ⊗ (H_b/√b) — test/reference use.
pub fn block_hadamard_dense(d: usize, b: usize) -> Result<Mat> {
    ensure!(d % b == 0, "block {b} must divide {d}");
    let hb = normalized_hadamard(b)?;
    let mut out = Mat::zeros(d, d);
    for g in 0..d / b {
        for i in 0..b {
            for j in 0..b {
                *out.at_mut(g * b + i, g * b + j) = hb.at(i, j);
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_hadamard(h: &[Vec<i8>]) {
        let n = h.len();
        for i in 0..n {
            for j in 0..n {
                let dot: i64 = (0..n).map(|k| h[i][k] as i64 * h[j][k] as i64).sum();
                let want = if i == j { n as i64 } else { 0 };
                assert_eq!(dot, want, "rows {i},{j} of order {n}");
            }
        }
    }

    #[test]
    fn sylvester_orders() {
        for n in [1usize, 2, 4, 8, 16, 64, 256, 1024] {
            assert_hadamard(&hadamard_signs(n).unwrap());
        }
    }

    #[test]
    fn paley1_orders() {
        for q in [11usize, 19, 43, 59] {
            assert_hadamard(&paley1(q));
        }
    }

    #[test]
    fn paley2_orders() {
        for q in [13usize, 37] {
            assert_hadamard(&paley2(q));
        }
    }

    #[test]
    fn composite_orders() {
        for n in [12usize, 24, 28, 48, 56, 76, 96, 112, 448, 768] {
            assert_hadamard(&hadamard_signs(n).unwrap());
        }
    }

    #[test]
    fn unsupported_order() {
        assert!(hadamard_signs(92).is_err());
        assert!(hadamard_signs(6).is_err());
    }

    #[test]
    fn pow2_split_cases() {
        assert_eq!(pow2_split(14336), (2048, 7));
        assert_eq!(pow2_split(8192), (8192, 1));
        assert_eq!(pow2_split(9728), (512, 19));
        assert_eq!(pow2_split(448), (64, 7));
        assert_eq!(pow2_split(1), (1, 1));
    }

    #[test]
    fn normalized_is_orthonormal() {
        let h = normalized_hadamard(28).unwrap();
        let g = h.matmul(&h.transpose());
        for i in 0..28 {
            for j in 0..28 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((g.at(i, j) - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn normalized_linf_is_inv_sqrt_n() {
        let h = normalized_hadamard(64).unwrap();
        assert!((h.abs_max() - 0.125).abs() < 1e-6);
    }
}
