//! `BlockRotator` — the unified online-rotation engine the L3 hot path
//! uses: identity (b=1), FWHT (power-of-2 b), the optimized non-power-of-2
//! plan, or an arbitrary dense orthogonal matrix (learned rotations).

use anyhow::{ensure, Result};

use super::construct::normalized_hadamard;
use super::fwht::block_fwht_normalized;
use super::nonpow2::NonPow2Plan;
use crate::tensor::Mat;

pub enum RotatorKind {
    Identity,
    Fwht,
    Fast(NonPow2Plan),
    /// Arbitrary dense b×b orthogonal rotation (e.g. Givens-refined).
    Dense(Mat),
}

pub struct BlockRotator {
    pub b: usize,
    kind: RotatorKind,
}

impl BlockRotator {
    /// Hadamard rotation with block size b (b=1 → identity, b=d → full).
    pub fn hadamard(b: usize) -> Result<Self> {
        let kind = if b == 1 {
            RotatorKind::Identity
        } else if b.is_power_of_two() {
            RotatorKind::Fwht
        } else {
            RotatorKind::Fast(NonPow2Plan::new(b)?)
        };
        Ok(BlockRotator { b, kind })
    }

    /// Rotation by an explicit orthogonal matrix (learned-rotation arms).
    pub fn dense(m: Mat) -> Result<Self> {
        ensure!(m.rows == m.cols, "rotation must be square");
        Ok(BlockRotator { b: m.rows, kind: RotatorKind::Dense(m) })
    }

    /// Transposed (inverse) rotator — used to fold R̃ᵀ into weights.
    pub fn transposed(&self) -> Result<Self> {
        match &self.kind {
            // Hadamard/Sylvester normalized matrices here are symmetric only
            // for Sylvester; Paley ones are not, so go through the dense
            // matrix for correctness.
            RotatorKind::Identity => BlockRotator::hadamard(1),
            RotatorKind::Fwht => {
                // Sylvester H/√b is symmetric ⇒ self-transpose
                BlockRotator::hadamard(self.b)
            }
            RotatorKind::Fast(_) => {
                let h = normalized_hadamard(self.b)?;
                BlockRotator::dense(h.transpose())
            }
            RotatorKind::Dense(m) => BlockRotator::dense(m.transpose()),
        }
    }

    /// The dense (b, b) matrix of this rotator — fed to the AOT artifact as
    /// its `hb` input so the in-graph rotation matches the offline merges.
    pub fn matrix(&self) -> Result<Mat> {
        match &self.kind {
            RotatorKind::Identity => Ok(Mat::eye(1)),
            RotatorKind::Fwht | RotatorKind::Fast(_) => normalized_hadamard(self.b),
            RotatorKind::Dense(m) => Ok(m.clone()),
        }
    }

    /// Rotate one row in place (each contiguous b-block independently).
    pub fn apply_row(&self, row: &mut [f32], scratch: &mut Vec<f32>) {
        debug_assert!(row.len() % self.b == 0, "row {} not divisible by b {}", row.len(), self.b);
        match &self.kind {
            RotatorKind::Identity => {}
            RotatorKind::Fwht => block_fwht_normalized(row, self.b),
            RotatorKind::Fast(plan) => {
                for blk in row.chunks_exact_mut(self.b) {
                    plan.apply(blk, scratch);
                }
            }
            RotatorKind::Dense(m) => {
                let b = self.b;
                scratch.clear();
                scratch.resize(b, 0.0);
                for blk in row.chunks_exact_mut(b) {
                    for v in scratch.iter_mut() {
                        *v = 0.0;
                    }
                    for (i, &xi) in blk.iter().enumerate() {
                        if xi == 0.0 {
                            continue;
                        }
                        let hrow = m.row(i);
                        for (j, acc) in scratch.iter_mut().enumerate() {
                            *acc += xi * hrow[j];
                        }
                    }
                    blk.copy_from_slice(scratch);
                }
            }
        }
    }

    /// Rotate every row of a (tokens × d) activation matrix in place.
    pub fn apply_mat(&self, m: &mut Mat) {
        let mut scratch = Vec::new();
        let cols = m.cols;
        for r in 0..m.rows {
            let row = &mut m.data[r * cols..(r + 1) * cols];
            self.apply_row(row, &mut scratch);
        }
    }

    /// Rotate the *rows* of a weight matrix by R̃ᵀ, i.e. w ← R̃ᵀ w.
    /// This is the offline merge that undoes an online activation rotation:
    /// (x R̃)(R̃ᵀ w) = x w.
    ///
    /// Implementation: R̃ᵀw = (wᵀ·R̃)ᵀ, i.e. apply the rotator itself to
    /// the rows of wᵀ. (Applying the *transposed* rotator here would give
    /// R̃w, which only coincides for symmetric bases — Sylvester/Paley II —
    /// and silently breaks Paley I bases like b = 12.)
    pub fn merge_into_weight_rows(&self, w: &Mat) -> Result<Mat> {
        let mut wt = w.transpose();
        self.apply_mat(&mut wt);
        Ok(wt.transpose())
    }

    /// Rotate weight rows by R̃ (the forward direction): w ← R̃ w = (wᵀR̃ᵀ)ᵀ.
    /// Used by the fully-online graph to pre-compensate the in-graph weight
    /// rotation (see coordinator::pipeline).
    pub fn rotate_weight_rows_fwd(&self, w: &Mat) -> Result<Mat> {
        let inv = self.transposed()?;
        let mut wt = w.transpose();
        inv.apply_mat(&mut wt);
        Ok(wt.transpose())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hadamard::construct::normalized_hadamard;

    fn rand_mat(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = crate::data::rng::Rng::new(seed);
        Mat::from_fn(r, c, |_, _| rng.next_normal() as f32)
    }

    #[test]
    fn fwht_rotator_matches_dense() {
        let x = rand_mat(5, 64, 1);
        let rot = BlockRotator::hadamard(16).unwrap();
        let mut got = x.clone();
        rot.apply_mat(&mut got);
        let h = crate::hadamard::construct::block_hadamard_dense(64, 16).unwrap();
        let want = x.matmul(&h);
        for (g, w) in got.data.iter().zip(&want.data) {
            assert!((g - w).abs() < 1e-4);
        }
    }

    #[test]
    fn nonpow2_rotator_matches_dense() {
        let x = rand_mat(4, 56, 2);
        let rot = BlockRotator::hadamard(28).unwrap();
        let mut got = x.clone();
        rot.apply_mat(&mut got);
        let h = crate::hadamard::construct::block_hadamard_dense(56, 28).unwrap();
        let want = x.matmul(&h);
        for (g, w) in got.data.iter().zip(&want.data) {
            assert!((g - w).abs() < 1e-3);
        }
    }

    #[test]
    fn identity_rotator_noop() {
        let x = rand_mat(3, 10, 3);
        let rot = BlockRotator::hadamard(1).unwrap();
        let mut got = x.clone();
        rot.apply_mat(&mut got);
        assert_eq!(got.data, x.data);
    }

    #[test]
    fn merge_undoes_online_rotation() {
        // (x R̃) @ (R̃ᵀ w) == x @ w for every rotator kind, including the
        // *asymmetric* Paley-I base b = 12 (regression: a transposed-side
        // bug is invisible on symmetric bases).
        for b in [1usize, 4, 12, 16, 28] {
            let d = if b == 28 { 56 } else { 48 };
            let x = rand_mat(6, d, b as u64);
            let w = rand_mat(d, 9, b as u64 + 100);
            let rot = BlockRotator::hadamard(b).unwrap();
            let mut xr = x.clone();
            rot.apply_mat(&mut xr);
            let wm = rot.merge_into_weight_rows(&w).unwrap();
            let got = xr.matmul(&wm);
            let want = x.matmul(&w);
            for (g, ww) in got.data.iter().zip(&want.data) {
                assert!((g - ww).abs() < 1e-3, "b={b}");
            }
        }
    }

    #[test]
    fn dense_rotator_matches_matmul() {
        let h = normalized_hadamard(12).unwrap();
        let rot = BlockRotator::dense(h.clone()).unwrap();
        let x = rand_mat(4, 24, 7);
        let mut got = x.clone();
        rot.apply_mat(&mut got);
        let hd = crate::hadamard::construct::block_hadamard_dense(24, 12).unwrap();
        let want = x.matmul(&hd);
        for (g, w) in got.data.iter().zip(&want.data) {
            assert!((g - w).abs() < 1e-4);
        }
    }
}
