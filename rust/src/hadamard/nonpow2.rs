//! Optimized non-power-of-2 Hadamard transform (paper Appendix A.1).
//!
//! For d = 2^{k'} · 4t (t odd > 1) the Sylvester-from-Paley matrix factors
//! as H_d = H_{2^{k'}} ⊗ H_{4t}, giving:
//!
//!   1. k' radix-2 butterfly stages across 4t-element blocks (exact);
//!   2. per 4t block, stage 1+2 compute sums/differences over every group of
//!      four adjacent inputs (the H_4 sub-transforms plus their pair
//!      intermediates), and a final stage combines one or two pool entries
//!      per group according to the sign pattern of the base matrix.
//!
//! The paper's Figure 8 final stage uses exactly t entries per output; that
//! requires the base matrix to factor as B·(I_t ⊗ H_4) with B 1-sparse per
//! group, which we *prove impossible* for order-12 matrices (all H_12 are
//! equivalent, and the required GF(2) quadruple partition does not exist —
//! see DESIGN.md §Hardware-Adaptation). Our generalized final stage uses
//! one pool entry for even-parity column groups and two for odd-parity
//! ones, landing within ~15% of the paper's modeled d(k'+t+2) count; the
//! analytic model in `opcount.rs` reproduces the paper's tables exactly.

use anyhow::{ensure, Result};

use super::construct::{hadamard_signs, pow2_split};
use crate::tensor::simd;

/// One term of a final-stage output: (pool index, +1/-1 sign).
type Term = (u32, f32);

/// Precomputed plan for a d-dimensional non-power-of-2 Hadamard transform.
pub struct NonPow2Plan {
    pub d: usize,
    pub base: usize,      // 4t
    pub t: usize,
    pub k_stages: usize,  // k' butterfly stages
    /// Which of the 8 pool slots per group are actually used.
    pool_used: Vec<bool>, // len 8*t
    /// Per output coordinate of the base transform: signed pool terms.
    programs: Vec<Vec<Term>>, // len 4t
    norm: f32,
}

impl NonPow2Plan {
    pub fn new(d: usize) -> Result<Self> {
        let (k, t) = pow2_split(d);
        ensure!(t > 1, "dimension {d} is a power of two; use fwht");
        ensure!(k >= 4, "need d = 2^k'·4t with k' >= 0 (k = {k})");
        let base = 4 * t;
        let k_stages = (k / 4).trailing_zeros() as usize;
        let h = hadamard_signs(base)?;

        // Pool layout per group g: [a, b, c, d, y0, y1, y2, y3] at 8g..8g+8.
        let mut pool_used = vec![false; 8 * t];
        let mut programs = Vec::with_capacity(base);
        for j in 0..base {
            let mut terms: Vec<Term> = Vec::new();
            for g in 0..t {
                let p: [i8; 4] = [h[4 * g][j], h[4 * g + 1][j], h[4 * g + 2][j], h[4 * g + 3][j]];
                let minus = p.iter().filter(|&&v| v < 0).count();
                if minus % 2 == 0 {
                    // ± a row of H4: identify row r with p = s * H4[r]
                    let h4: [[i8; 4]; 4] =
                        [[1, 1, 1, 1], [1, -1, 1, -1], [1, 1, -1, -1], [1, -1, -1, 1]];
                    let mut matched = false;
                    for (r, row) in h4.iter().enumerate() {
                        for s in [1i8, -1] {
                            if (0..4).all(|c| p[c] == s * row[c]) {
                                terms.push(((8 * g + 4 + r) as u32, s as f32));
                                pool_used[8 * g + 4 + r] = true;
                                matched = true;
                                break;
                            }
                        }
                        if matched {
                            break;
                        }
                    }
                    debug_assert!(matched);
                } else {
                    // odd parity: u from {a=x0+x1, b=x0-x1}, v from {c, d}
                    let (ui, us) = match (p[0], p[1]) {
                        (1, 1) => (0usize, 1.0f32),
                        (1, -1) => (1, 1.0),
                        (-1, -1) => (0, -1.0),
                        (-1, 1) => (1, -1.0),
                        _ => unreachable!(),
                    };
                    let (vi, vs) = match (p[2], p[3]) {
                        (1, 1) => (2usize, 1.0f32),
                        (1, -1) => (3, 1.0),
                        (-1, -1) => (2, -1.0),
                        (-1, 1) => (3, -1.0),
                        _ => unreachable!(),
                    };
                    terms.push(((8 * g + ui) as u32, us));
                    terms.push(((8 * g + vi) as u32, vs));
                    pool_used[8 * g + ui] = true;
                    pool_used[8 * g + vi] = true;
                }
            }
            programs.push(terms);
        }
        Ok(NonPow2Plan {
            d,
            base,
            t,
            k_stages,
            pool_used,
            programs,
            norm: 1.0 / (d as f32).sqrt(),
        })
    }

    /// Measured add/sub op count per transformed vector (honest accounting;
    /// compare with `opcount::ours_ops`).
    pub fn measured_ops(&self) -> usize {
        let butterflies = self.k_stages * self.d;
        let nblocks = self.d / self.base;
        // stage 1 always computes a,b,c,d (4 ops/group); stage 2 computes
        // only the H4 outputs that some program references.
        let stage1 = 4 * self.t;
        let stage2: usize = self
            .pool_used
            .iter()
            .enumerate()
            .filter(|(i, &u)| u && i % 8 >= 4)
            .count();
        let fin: usize = self.programs.iter().map(|p| p.len() - 1).sum();
        butterflies + nblocks * (stage1 + stage2 + fin)
    }

    /// Transform x (length d) in place: x ← x · (H_d / √d).
    pub fn apply(&self, x: &mut [f32], scratch: &mut Vec<f32>) {
        debug_assert_eq!(x.len(), self.d);
        let base = self.base;
        let nblocks = self.d / base;
        // --- k' butterfly stages across blocks (H_{2^{k'}} ⊗ I_base) ---
        // Each stage is an elementwise add/sub over base-length runs, so
        // the SIMD butterfly is bit-identical to the scalar loop.
        let mut h = 1;
        while h < nblocks {
            let mut i = 0;
            while i < nblocks {
                for j in i..i + h {
                    let (lo, hi) = x.split_at_mut((j + h) * base);
                    let a = &mut lo[j * base..j * base + base];
                    let b = &mut hi[..base];
                    simd::butterfly(a, b);
                }
                i += 2 * h;
            }
            h *= 2;
        }
        // --- per-block base transform via the pool program ---
        scratch.clear();
        scratch.resize(8 * self.t, 0.0);
        let mut out = vec![0.0f32; base];
        for blk in x.chunks_exact_mut(base) {
            for g in 0..self.t {
                let x0 = blk[4 * g];
                let x1 = blk[4 * g + 1];
                let x2 = blk[4 * g + 2];
                let x3 = blk[4 * g + 3];
                let a = x0 + x1;
                let b = x0 - x1;
                let c = x2 + x3;
                let d = x2 - x3;
                let p = &mut scratch[8 * g..8 * g + 8];
                p[0] = a;
                p[1] = b;
                p[2] = c;
                p[3] = d;
                if self.pool_used[8 * g + 4] {
                    p[4] = a + c;
                }
                if self.pool_used[8 * g + 5] {
                    p[5] = b + d;
                }
                if self.pool_used[8 * g + 6] {
                    p[6] = a - c;
                }
                if self.pool_used[8 * g + 7] {
                    p[7] = b - d;
                }
            }
            for (j, prog) in self.programs.iter().enumerate() {
                let mut acc = 0.0f32;
                for &(idx, sign) in prog {
                    acc += sign * scratch[idx as usize];
                }
                out[j] = acc;
            }
            blk.copy_from_slice(&out);
        }
        // --- normalization (elementwise — bit-identical across levels) ---
        simd::scale_inplace(x, self.norm);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hadamard::construct::normalized_hadamard;
    use crate::tensor::Mat;

    fn check_dim(d: usize) {
        let plan = NonPow2Plan::new(d).unwrap();
        let mut rng = crate::data::rng::Rng::new(d as u64);
        let x0: Vec<f32> = (0..d).map(|_| rng.next_normal() as f32).collect();
        let h = normalized_hadamard(d).unwrap();
        let want = Mat::from_vec(1, d, x0.clone()).matmul(&h);
        let mut got = x0;
        let mut scratch = Vec::new();
        plan.apply(&mut got, &mut scratch);
        for (g, w) in got.iter().zip(&want.data) {
            assert!((g - w).abs() < 1e-3, "d={d}: {g} vs {w}");
        }
    }

    #[test]
    fn matches_dense_small() {
        for d in [12usize, 28, 76] {
            check_dim(d);
        }
    }

    #[test]
    fn matches_dense_composite() {
        for d in [24usize, 48, 56, 112, 448] {
            check_dim(d);
        }
    }

    #[test]
    fn rejects_pow2() {
        assert!(NonPow2Plan::new(64).is_err());
    }

    #[test]
    fn measured_ops_near_model() {
        // paper model: d(k' + t + 2); our generalized final stage lands close
        for d in [448usize, 1792, 14336] {
            let plan = NonPow2Plan::new(d).unwrap();
            let model = crate::hadamard::opcount::ours_ops(d);
            let meas = plan.measured_ops();
            let ratio = meas as f64 / model as f64;
            assert!(
                (0.7..1.6).contains(&ratio),
                "d={d}: measured {meas} vs model {model}"
            );
        }
    }

    #[test]
    fn preserves_l2() {
        let plan = NonPow2Plan::new(56).unwrap();
        let mut rng = crate::data::rng::Rng::new(1);
        let x0: Vec<f32> = (0..56).map(|_| rng.next_normal() as f32).collect();
        let n0: f32 = x0.iter().map(|v| v * v).sum();
        let mut x = x0;
        plan.apply(&mut x, &mut Vec::new());
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() / n0 < 1e-3);
    }
}
