//! In-place fast Walsh-Hadamard transform — the O(d log d) butterfly the
//! paper's op-count model assumes for power-of-2 dimensions (Fino & Algazi
//! 1976). `fwht` computes x ← x·H_d (unnormalized Sylvester H); callers
//! scale by 1/√d for the rotation.

use crate::tensor::simd;

/// In-place unnormalized FWHT over a power-of-2-length slice.
/// Matches `x @ hadamard(d)` for the Sylvester construction.
///
/// §Perf: sizes ≥ 8 first try the runtime-dispatched SIMD kernels
/// (`tensor::simd::fwht_pow2` — AVX2/NEON in-register butterflies for the
/// sub-vector stages, wide vector butterflies above). Every path —
/// SIMD, the fully-unrolled fixed-size kernels for sizes ≤ 32, and the
/// general radix-4-fused tree — evaluates the identical butterfly
/// addition DAG, so results are bit-identical across size cutovers *and*
/// dispatch levels (each butterfly output is one IEEE add/sub of two
/// fully-determined operands).
pub fn fwht(x: &mut [f32]) {
    if x.len() >= 8 && simd::fwht_pow2(x, 1.0) {
        return;
    }
    match x.len() {
        0 | 1 => {}
        2 => fwht_fixed::<2>(x, 1.0),
        4 => fwht_fixed::<4>(x, 1.0),
        8 => fwht_fixed::<8>(x, 1.0),
        16 => fwht_fixed::<16>(x, 1.0),
        32 => fwht_fixed::<32>(x, 1.0),
        _ => fwht_general(x),
    }
}

/// Fixed-size FWHT: all stages over a stack array with constant trip
/// counts (LLVM fully unrolls), the final store fused with `scale`.
/// Same butterfly tree as [`fwht_general`] — bit-identical results
/// (`v * 1.0` is exact, and the trailing normalization multiply matches
/// the separate scaling loop the general path pairs with).
#[inline]
fn fwht_fixed<const N: usize>(x: &mut [f32], scale: f32) {
    debug_assert_eq!(x.len(), N);
    debug_assert!(N.is_power_of_two());
    let mut t = [0.0f32; N];
    t.copy_from_slice(x);
    let mut h = 1;
    while h < N {
        let mut i = 0;
        while i < N {
            let mut j = i;
            while j < i + h {
                let a = t[j];
                let b = t[j + h];
                t[j] = a + b;
                t[j + h] = a - b;
                j += 1;
            }
            i += 2 * h;
        }
        h *= 2;
    }
    for (o, v) in x.iter_mut().zip(t.iter()) {
        *o = v * scale;
    }
}

fn fwht_general(x: &mut [f32]) {
    let n = x.len();
    debug_assert!(n.is_power_of_two(), "fwht needs power-of-2 length");
    let mut h = 1;
    if n >= 4 {
        // fused radix-4 first pass (stages h=1 and h=2)
        for q in x.chunks_exact_mut(4) {
            let (x0, x1, x2, x3) = (q[0], q[1], q[2], q[3]);
            let a = x0 + x1;
            let b = x0 - x1;
            let c = x2 + x3;
            let d = x2 - x3;
            q[0] = a + c;
            q[1] = b + d;
            q[2] = a - c;
            q[3] = b - d;
        }
        h = 4;
    }
    while h < n {
        let mut i = 0;
        while i < n {
            let (lo, hi) = x[i..i + 2 * h].split_at_mut(h);
            for (a, b) in lo.iter_mut().zip(hi.iter_mut()) {
                let av = *a;
                let bv = *b;
                *a = av + bv;
                *b = av - bv;
            }
            i += 2 * h;
        }
        h *= 2;
    }
}

/// Normalized in-place FWHT: x ← x·(H_d/√d).
pub fn fwht_normalized(x: &mut [f32]) {
    fwht(x);
    let s = 1.0 / (x.len() as f32).sqrt();
    for v in x.iter_mut() {
        *v *= s;
    }
}

/// Apply the normalized *block* FWHT to a d-length row: each contiguous
/// b-block rotated by H_b/√b. Requires b power of two.
///
/// Block sizes ≥ 8 first try the SIMD block path (dispatch hoisted out of
/// the block loop); otherwise sizes ≤ 32 run the fixed-size kernels. Both
/// fuse the 1/√b scale into the final store — one pass over the row
/// instead of two — and stay bit-identical to the general tree.
pub fn block_fwht_normalized(x: &mut [f32], b: usize) {
    debug_assert!(x.len() % b == 0);
    if b <= 1 {
        return;
    }
    let s = 1.0 / (b as f32).sqrt();
    if simd::fwht_blocks(x, b, s) {
        return;
    }
    match b {
        2 => {
            for blk in x.chunks_exact_mut(2) {
                fwht_fixed::<2>(blk, s);
            }
        }
        4 => {
            for blk in x.chunks_exact_mut(4) {
                fwht_fixed::<4>(blk, s);
            }
        }
        8 => {
            for blk in x.chunks_exact_mut(8) {
                fwht_fixed::<8>(blk, s);
            }
        }
        16 => {
            for blk in x.chunks_exact_mut(16) {
                fwht_fixed::<16>(blk, s);
            }
        }
        32 => {
            for blk in x.chunks_exact_mut(32) {
                fwht_fixed::<32>(blk, s);
            }
        }
        _ => {
            for blk in x.chunks_exact_mut(b) {
                fwht(blk);
                for v in blk {
                    *v *= s;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hadamard::construct::normalized_hadamard;
    use crate::tensor::Mat;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::data::rng::Rng::new(seed);
        (0..n).map(|_| rng.next_normal() as f32).collect()
    }

    #[test]
    fn fwht_matches_matmul() {
        for n in [2usize, 4, 16, 64, 256] {
            let x = rand_vec(n, n as u64);
            let h = normalized_hadamard(n).unwrap();
            let xm = Mat::from_vec(1, n, x.clone());
            let want = xm.matmul(&h);
            let mut got = x;
            fwht_normalized(&mut got);
            for (g, w) in got.iter().zip(&want.data) {
                assert!((g - w).abs() < 1e-4, "n={n}");
            }
        }
    }

    #[test]
    fn fwht_involution() {
        // H/√d is symmetric for Sylvester ⇒ applying twice restores input
        let x0 = rand_vec(128, 3);
        let mut x = x0.clone();
        fwht_normalized(&mut x);
        fwht_normalized(&mut x);
        for (a, b) in x.iter().zip(&x0) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn block_fwht_matches_per_block() {
        let x0 = rand_vec(96, 5);
        let mut got = x0.clone();
        block_fwht_normalized(&mut got, 16);
        let h = normalized_hadamard(16).unwrap();
        for (blk, want_blk) in got.chunks(16).zip(x0.chunks(16)) {
            let w = Mat::from_vec(1, 16, want_blk.to_vec()).matmul(&h);
            for (g, ww) in blk.iter().zip(&w.data) {
                assert!((g - ww).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn fixed_small_kernels_match_general_bitwise() {
        // the ≤32 fast path must be bit-identical to the generic butterfly
        // (same addition tree), so block-size dispatch can never change
        // results
        for n in [2usize, 4, 8, 16, 32] {
            let x0 = rand_vec(n, 100 + n as u64);
            let mut fast = x0.clone();
            fwht(&mut fast);
            let mut slow = x0.clone();
            if n >= 4 {
                fwht_general(&mut slow);
            } else {
                let (a, b) = (slow[0], slow[1]);
                slow[0] = a + b;
                slow[1] = a - b;
            }
            assert_eq!(fast, slow, "n={n}");
        }
    }

    #[test]
    fn block_fwht_small_blocks_match_dense() {
        for b in [2usize, 4, 8, 16, 32] {
            let d = b * 3;
            let x0 = rand_vec(d, 200 + b as u64);
            let mut got = x0.clone();
            block_fwht_normalized(&mut got, b);
            let h = normalized_hadamard(b).unwrap();
            for (blk, want_blk) in got.chunks(b).zip(x0.chunks(b)) {
                let w = Mat::from_vec(1, b, want_blk.to_vec()).matmul(&h);
                for (g, ww) in blk.iter().zip(&w.data) {
                    assert!((g - ww).abs() < 1e-4, "b={b}");
                }
            }
        }
    }

    #[test]
    fn fwht_preserves_l2() {
        let x0 = rand_vec(64, 9);
        let n0: f32 = x0.iter().map(|v| v * v).sum();
        let mut x = x0;
        fwht_normalized(&mut x);
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() / n0 < 1e-4);
    }
}
