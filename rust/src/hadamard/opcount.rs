//! Analytic op-count model for online Hadamard rotations — Remark A.1 and
//! Appendix A.1; regenerates the paper's Tables 3 and 4 exactly.
//!
//! Conventions (matching the paper's numbers):
//!   * d = k·t with k the power-of-2 part, t the largest odd factor;
//!   * for t > 1, d = 2^{k'}·4t with k' = log2(k) − 2;
//!   * dense matmul: d² MACs;
//!   * butterfly + matmul (Dao 2023-style): d(k' + 4t − 1);
//!   * ours (App A.1): d(k' + t + 2);
//!   * power-of-2 d: all butterfly methods cost d·log2(d);
//!   * block rotation, power-of-2 b: d·log2(b).

use super::construct::pow2_split;

/// log2 for exact powers of two.
fn log2(n: usize) -> usize {
    debug_assert!(n.is_power_of_two());
    n.trailing_zeros() as usize
}

/// Decompose d = 2^{k'} · 4t; returns (k', t). Requires t > 1.
pub fn nonpow2_decomp(d: usize) -> (usize, usize) {
    let (k, t) = pow2_split(d);
    assert!(t > 1 && k >= 4, "d = {d} is not 2^k'·4t with t odd > 1");
    (log2(k) - 2, t)
}

/// Ops for a dense d×d rotation matmul.
pub fn dense_matmul_ops(d: usize) -> usize {
    d * d
}

/// Ops for the butterfly + dense-base decomposition (existing approach).
pub fn butterfly_matmul_ops(d: usize) -> usize {
    let (_, t) = pow2_split(d);
    if t == 1 {
        d * log2(d)
    } else {
        let (kp, t) = nonpow2_decomp(d);
        d * (kp + 4 * t - 1)
    }
}

/// Ops for the paper's optimized non-power-of-2 rotation (Appendix A.1).
pub fn ours_ops(d: usize) -> usize {
    let (_, t) = pow2_split(d);
    if t == 1 {
        d * log2(d)
    } else {
        let (kp, t) = nonpow2_decomp(d);
        d * (kp + t + 2)
    }
}

/// Ops for a full-vector online rotation (paper's "Full" column): the
/// butterfly for powers of two, ours otherwise.
pub fn full_ops(d: usize) -> usize {
    ours_ops(d)
}

/// Ops for a block Hadamard rotation with power-of-2 block size b.
pub fn block_ops(d: usize, b: usize) -> usize {
    assert!(d % b == 0, "block {b} must divide {d}");
    if b == 1 {
        return 0;
    }
    let (_, tb) = pow2_split(b);
    if tb == 1 {
        d * log2(b)
    } else {
        // non-pow-2 block: per-block ours cost
        (d / b) * ours_ops(b)
    }
}

/// One row of the paper's Table 3.
#[derive(Debug, Clone)]
pub struct Table3Row {
    pub model: &'static str,
    pub size: &'static str,
    pub d: usize,
    pub k: usize,
    pub t: usize,
    pub b32: usize,
    pub b128: usize,
    pub b512: usize,
    pub full: usize,
}

/// The exact workloads of the paper's Table 3 (down-projection input dims).
pub fn table3() -> Vec<Table3Row> {
    let rows = [
        ("Llama3", "1B/3B", 8192usize),
        ("Llama3", "8B", 14336),
        ("Qwen3", "1.7B", 6144),
        ("Qwen3", "4B", 9728),
        ("Qwen3", "8B", 12288),
    ];
    rows.iter()
        .map(|&(model, size, d)| {
            let (k, t) = pow2_split(d);
            Table3Row {
                model,
                size,
                d,
                k,
                t,
                b32: block_ops(d, 32),
                b128: block_ops(d, 128),
                b512: block_ops(d, 512),
                full: full_ops(d),
            }
        })
        .collect()
}

/// One row of the paper's Table 4 (non-power-of-2 methods comparison).
#[derive(Debug, Clone)]
pub struct Table4Row {
    pub model: &'static str,
    pub d: usize,
    pub kp: usize,
    pub base: usize,
    pub matmul: usize,
    pub butterfly_matmul: usize,
    pub ours: usize,
}

pub fn table4() -> Vec<Table4Row> {
    let rows = [
        ("Llama3-8B", 14336usize),
        ("Qwen3-0.6B", 3072),
        ("Qwen3-1.7B", 6144),
        ("Qwen3-4B", 9728),
        ("Qwen3-8B", 12288),
    ];
    rows.iter()
        .map(|&(model, d)| {
            let (_, t) = pow2_split(d);
            let (kp, base) = if t == 1 {
                // the paper still reports 2^{k'} x 4t with t from the
                // greatest odd factor; for pow2 dims it uses t=3 forms of
                // the Qwen sizes (3072 = 2^8 * 12, 12288 = 2^10 * 12)
                (log2(d) - 2, 4)
            } else {
                let (kp, t) = nonpow2_decomp(d);
                (kp, 4 * t)
            };
            Table4Row {
                model,
                d,
                kp,
                base,
                matmul: dense_matmul_ops(d),
                butterfly_matmul: butterfly_matmul_ops(d),
                ours: ours_ops(d),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    // Every assertion below is a number printed in the paper.

    #[test]
    fn table3_llama3_1b() {
        // d=8192: 40960 (38%), 57344 (54%), 73728 (69%), full 106496
        assert_eq!(block_ops(8192, 32), 40960);
        assert_eq!(block_ops(8192, 128), 57344);
        assert_eq!(block_ops(8192, 512), 73728);
        assert_eq!(full_ops(8192), 106496);
    }

    #[test]
    fn table3_llama3_8b() {
        // d=14336 = 2^11 * 7: 71680, 100352, 129024, full 258048
        assert_eq!(block_ops(14336, 32), 71680);
        assert_eq!(block_ops(14336, 128), 100352);
        assert_eq!(block_ops(14336, 512), 129024);
        assert_eq!(full_ops(14336), 258048);
    }

    #[test]
    fn table3_qwen() {
        assert_eq!(block_ops(6144, 32), 30720);
        assert_eq!(block_ops(6144, 128), 43008);
        assert_eq!(block_ops(6144, 512), 55296);
        assert_eq!(full_ops(6144), 86016);
        assert_eq!(block_ops(9728, 32), 48640);
        assert_eq!(block_ops(9728, 128), 68096);
        assert_eq!(block_ops(9728, 512), 87552);
        assert_eq!(full_ops(9728), 272384);
        assert_eq!(block_ops(12288, 32), 61440);
        assert_eq!(block_ops(12288, 128), 86016);
        assert_eq!(block_ops(12288, 512), 110592);
        assert_eq!(full_ops(12288), 184320);
    }

    #[test]
    fn table4_rows() {
        // Llama3-8B: matmul 205.51M, butterfly+matmul 516.10K, ours 258.05K
        assert_eq!(dense_matmul_ops(14336), 205_520_896);
        assert_eq!(butterfly_matmul_ops(14336), 516_096);
        assert_eq!(ours_ops(14336), 258_048);
        // Qwen3-4B: 94.62M / 797.70K / 272.38K
        assert_eq!(dense_matmul_ops(9728), 94_633_984);
        assert_eq!(butterfly_matmul_ops(9728), 797_696);
        assert_eq!(ours_ops(9728), 272_384);
        // Qwen3-1.7B: 37.74M / 122.88K / 86.02K
        assert_eq!(dense_matmul_ops(6144), 37_748_736);
        assert_eq!(butterfly_matmul_ops(6144), 122_880);
        assert_eq!(ours_ops(6144), 86_016);
    }

    #[test]
    fn table4_ratios() {
        // "1.4-2.9x reduction vs butterfly decomposition"
        for d in [14336usize, 6144, 9728, 12288] {
            let r = butterfly_matmul_ops(d) as f64 / ours_ops(d) as f64;
            assert!((1.3..3.0).contains(&r), "d={d}: ratio {r}");
        }
    }

    #[test]
    fn asymptotic_4x() {
        // for fixed k', t -> inf approaches 4x
        let r = butterfly_matmul_ops(4 * 1019) as f64 / ours_ops(4 * 1019) as f64;
        assert!(r > 3.5);
    }

    #[test]
    fn block_ops_monotone_in_b() {
        for d in [8192usize, 14336] {
            let mut prev = 0;
            for b in [2usize, 4, 8, 16, 32, 64, 128, 256, 512] {
                let ops = block_ops(d, b);
                assert!(ops > prev);
                prev = ops;
            }
        }
    }
}
