//! Activation capture: executes the `fwd_capture` artifact over
//! calibration batches and accumulates per-layer linear-input activations.
//!
//! Captures are taken from the *transformed* weights (rotations/norm folds
//! already merged), so they live in exactly the space the quantized graph
//! sees — which is what both MassDiff (Fig 2) and the GPTQ/Qronos Hessians
//! need (Appendix B: X̃ is rotated and quantized).

use anyhow::{ensure, Result};

use crate::data::corpus::{self, Source, Split};
use crate::model::config::{CaptureKind, ModelConfig};
use crate::model::weights::WeightSet;
use crate::runtime::engine::{self, Engine};
use crate::tensor::Mat;

/// Per-layer activation captures: rows = calibration tokens.
pub struct Captures {
    pub attn_in: Vec<Mat>,
    pub o_in: Vec<Mat>,
    pub ffn_in: Vec<Mat>,
    pub down_in: Vec<Mat>,
    pub n_tokens: usize,
}

impl Captures {
    pub fn site(&self, kind: CaptureKind, layer: usize) -> &Mat {
        match kind {
            CaptureKind::AttnIn => &self.attn_in[layer],
            CaptureKind::OIn => &self.o_in[layer],
            CaptureKind::FfnIn => &self.ffn_in[layer],
            CaptureKind::DownIn => &self.down_in[layer],
        }
    }

    pub fn site_mut(&mut self, kind: CaptureKind, layer: usize) -> &mut Mat {
        match kind {
            CaptureKind::AttnIn => &mut self.attn_in[layer],
            CaptureKind::OIn => &mut self.o_in[layer],
            CaptureKind::FfnIn => &mut self.ffn_in[layer],
            CaptureKind::DownIn => &mut self.down_in[layer],
        }
    }
}

/// Calibration token batches: `n_seqs` sequences of seq_len tokens drawn
/// from the train split (the paper uses random 2048-token sequences; our
/// deterministic equivalent strides a seeded offset pattern).
pub fn calibration_batches(cfg: &ModelConfig, source: Source, n_seqs: usize,
                           seed: u64) -> Vec<Vec<i32>> {
    let need = n_seqs * cfg.seq_len * 4; // pool to stride over
    let toks = corpus::token_stream(source, Split::Train, need.max(1 << 16));
    let mut rng = crate::data::rng::Rng::new(seed ^ 0x5eed_ca1b);
    let max_start = toks.len() - cfg.seq_len - 1;
    (0..n_seqs)
        .map(|_| {
            let s = rng.next_below(max_start as u64) as usize;
            toks[s..s + cfg.seq_len].iter().map(|&t| t as i32).collect()
        })
        .collect()
}

/// Run `fwd_capture` over the calibration sequences with the given
/// (already transformed) weights, returning per-layer activations.
pub fn run_capture(engine: &Engine, model: &str, cfg: &ModelConfig,
                   ws: &WeightSet, seqs: &[Vec<i32>]) -> Result<Captures> {
    ensure!(!seqs.is_empty(), "no calibration sequences");
    let (l, d, f, b, t) = (cfg.n_layers, cfg.d_model, cfg.d_ffn, cfg.batch, cfg.seq_len);
    let mut caps = Captures {
        attn_in: (0..l).map(|_| Mat::zeros(0, d)).collect(),
        o_in: (0..l).map(|_| Mat::zeros(0, d)).collect(),
        ffn_in: (0..l).map(|_| Mat::zeros(0, d)).collect(),
        down_in: (0..l).map(|_| Mat::zeros(0, f)).collect(),
        n_tokens: 0,
    };
    let w_lits = engine::weight_literals(ws)?;
    for chunk in seqs.chunks(b) {
        // pad the final partial batch by repeating the first sequence
        let mut tokens: Vec<i32> = Vec::with_capacity(b * t);
        for i in 0..b {
            let seq = chunk.get(i).unwrap_or(&chunk[0]);
            tokens.extend_from_slice(seq);
        }
        let mut inputs = w_lits.clone();
        inputs.push(engine::tokens_literal(&tokens, b, t)?);
        let outs = engine.run(model, "fwd_capture", &inputs)?;
        ensure!(outs.len() == 5, "capture artifact must return 5 outputs");
        let real = chunk.len(); // ignore padded sequences
        for (idx, (kind, dim)) in [
            (CaptureKind::AttnIn, d),
            (CaptureKind::OIn, d),
            (CaptureKind::FfnIn, d),
            (CaptureKind::DownIn, f),
        ]
        .iter()
        .enumerate()
        {
            let data = engine::literal_to_vec_f32(&outs[idx + 1])?;
            ensure!(data.len() == l * b * t * dim, "capture size mismatch");
            for layer in 0..l {
                let site = caps.site_mut(*kind, layer);
                let mut rows = std::mem::replace(site, Mat::zeros(0, *dim));
                let base = layer * b * t * dim;
                let mut new_data = rows.data;
                new_data.extend_from_slice(&data[base..base + real * t * dim]);
                rows = Mat::from_vec(new_data.len() / dim, *dim, new_data);
                *site = rows;
            }
        }
        caps.n_tokens += real * t;
    }
    Ok(caps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn cfg() -> ModelConfig {
        let j = json::parse(
            r#"{"config": {"name": "m", "n_layers": 2, "d_model": 128,
                "n_heads": 4, "d_ffn": 448, "vocab": 32, "seq_len": 128,
                "batch": 8, "block_sizes": [1]}}"#,
        )
        .unwrap();
        ModelConfig::from_meta(&j).unwrap()
    }

    #[test]
    fn batches_deterministic_and_shaped() {
        let c = cfg();
        let a = calibration_batches(&c, Source::Wiki, 4, 1);
        let b = calibration_batches(&c, Source::Wiki, 4, 1);
        assert_eq!(a, b);
        assert_eq!(a.len(), 4);
        assert!(a.iter().all(|s| s.len() == c.seq_len));
        let c2 = calibration_batches(&c, Source::Wiki, 4, 2);
        assert_ne!(a, c2);
    }

    #[test]
    fn batches_tokens_in_vocab() {
        let c = cfg();
        for seq in calibration_batches(&c, Source::C4, 3, 7) {
            assert!(seq.iter().all(|&t| (0..32).contains(&t)));
        }
    }
}
