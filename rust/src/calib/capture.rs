//! Activation capture: executes the `fwd_capture` artifact over
//! calibration batches and accumulates per-layer linear-input activations.
//!
//! Captures are taken from the *transformed* weights (rotations/norm folds
//! already merged), so they live in exactly the space the quantized graph
//! sees — which is what both MassDiff (Fig 2) and the GPTQ/Qronos Hessians
//! need (Appendix B: X̃ is rotated and quantized).

use anyhow::Result;

use crate::backend::BackendKind;
use crate::data::corpus::{self, Source, Split};
use crate::model::config::{CaptureKind, ModelConfig};
use crate::model::weights::WeightSet;
use crate::runtime::Engine;
use crate::tensor::Mat;

/// Per-layer activation captures: rows = calibration tokens.
pub struct Captures {
    pub attn_in: Vec<Mat>,
    pub o_in: Vec<Mat>,
    pub ffn_in: Vec<Mat>,
    pub down_in: Vec<Mat>,
    pub n_tokens: usize,
}

impl Captures {
    /// Empty per-layer capture matrices shaped for `cfg` (0 token rows).
    pub fn empty(cfg: &ModelConfig) -> Captures {
        let (l, d, f) = (cfg.n_layers, cfg.d_model, cfg.d_ffn);
        Captures {
            attn_in: (0..l).map(|_| Mat::zeros(0, d)).collect(),
            o_in: (0..l).map(|_| Mat::zeros(0, d)).collect(),
            ffn_in: (0..l).map(|_| Mat::zeros(0, d)).collect(),
            down_in: (0..l).map(|_| Mat::zeros(0, f)).collect(),
            n_tokens: 0,
        }
    }

    pub fn site(&self, kind: CaptureKind, layer: usize) -> &Mat {
        match kind {
            CaptureKind::AttnIn => &self.attn_in[layer],
            CaptureKind::OIn => &self.o_in[layer],
            CaptureKind::FfnIn => &self.ffn_in[layer],
            CaptureKind::DownIn => &self.down_in[layer],
        }
    }

    pub fn site_mut(&mut self, kind: CaptureKind, layer: usize) -> &mut Mat {
        match kind {
            CaptureKind::AttnIn => &mut self.attn_in[layer],
            CaptureKind::OIn => &mut self.o_in[layer],
            CaptureKind::FfnIn => &mut self.ffn_in[layer],
            CaptureKind::DownIn => &mut self.down_in[layer],
        }
    }
}

/// Calibration token batches: `n_seqs` sequences of seq_len tokens drawn
/// from the train split (the paper uses random 2048-token sequences; our
/// deterministic equivalent strides a seeded offset pattern).
pub fn calibration_batches(cfg: &ModelConfig, source: Source, n_seqs: usize,
                           seed: u64) -> Vec<Vec<i32>> {
    let need = n_seqs * cfg.seq_len * 4; // pool to stride over
    let toks = corpus::token_stream(source, Split::Train, need.max(1 << 16));
    let mut rng = crate::data::rng::Rng::new(seed ^ 0x5eed_ca1b);
    let max_start = toks.len() - cfg.seq_len - 1;
    (0..n_seqs)
        .map(|_| {
            let s = rng.next_below(max_start as u64) as usize;
            toks[s..s + cfg.seq_len].iter().map(|&t| t as i32).collect()
        })
        .collect()
}

/// Run the capture forward over the calibration sequences with the given
/// (already transformed) weights, returning per-layer activations.
/// Dispatches on the engine's backend: the `fwd_capture` AOT artifact on
/// pjrt, the pure-Rust forward (`backend::native::capture_native`) on
/// native — both produce identical per-layer capture layouts.
pub fn run_capture(engine: &Engine, model: &str, cfg: &ModelConfig,
                   ws: &WeightSet, seqs: &[Vec<i32>]) -> Result<Captures> {
    match engine.backend() {
        BackendKind::Native => {
            let _ = model;
            crate::backend::native::capture_native(cfg, ws, seqs)
        }
        BackendKind::Pjrt => run_capture_pjrt(engine, model, cfg, ws, seqs),
    }
}

#[cfg(not(feature = "pjrt"))]
fn run_capture_pjrt(_engine: &Engine, _model: &str, _cfg: &ModelConfig,
                    _ws: &WeightSet, _seqs: &[Vec<i32>]) -> Result<Captures> {
    anyhow::bail!("the pjrt backend is not compiled in (rebuild with `--features pjrt`)")
}

/// Execute the `fwd_capture` artifact over calibration batches.
#[cfg(feature = "pjrt")]
fn run_capture_pjrt(engine: &Engine, model: &str, cfg: &ModelConfig,
                    ws: &WeightSet, seqs: &[Vec<i32>]) -> Result<Captures> {
    use crate::runtime::engine as raw;
    anyhow::ensure!(!seqs.is_empty(), "no calibration sequences");
    let engine = engine.pjrt()?;
    let (l, d, f, b, t) = (cfg.n_layers, cfg.d_model, cfg.d_ffn, cfg.batch, cfg.seq_len);
    let mut caps = Captures::empty(cfg);
    let w_lits = raw::weight_literals(ws)?;
    for chunk in seqs.chunks(b) {
        // pad the final partial batch by repeating the first sequence
        let mut tokens: Vec<i32> = Vec::with_capacity(b * t);
        for i in 0..b {
            let seq = chunk.get(i).unwrap_or(&chunk[0]);
            tokens.extend_from_slice(seq);
        }
        let mut inputs = w_lits.clone();
        inputs.push(raw::tokens_literal(&tokens, b, t)?);
        let outs = engine.run(model, "fwd_capture", &inputs)?;
        anyhow::ensure!(outs.len() == 5, "capture artifact must return 5 outputs");
        let real = chunk.len(); // ignore padded sequences
        for (idx, (kind, dim)) in [
            (CaptureKind::AttnIn, d),
            (CaptureKind::OIn, d),
            (CaptureKind::FfnIn, d),
            (CaptureKind::DownIn, f),
        ]
        .iter()
        .enumerate()
        {
            let data = raw::literal_to_vec_f32(&outs[idx + 1])?;
            anyhow::ensure!(data.len() == l * b * t * dim, "capture size mismatch");
            for layer in 0..l {
                let site = caps.site_mut(*kind, layer);
                let mut rows = std::mem::replace(site, Mat::zeros(0, *dim));
                let base = layer * b * t * dim;
                let mut new_data = rows.data;
                new_data.extend_from_slice(&data[base..base + real * t * dim]);
                rows = Mat::from_vec(new_data.len() / dim, *dim, new_data);
                *site = rows;
            }
        }
        caps.n_tokens += real * t;
    }
    Ok(caps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn cfg() -> ModelConfig {
        let j = json::parse(
            r#"{"config": {"name": "m", "n_layers": 2, "d_model": 128,
                "n_heads": 4, "d_ffn": 448, "vocab": 32, "seq_len": 128,
                "batch": 8, "block_sizes": [1]}}"#,
        )
        .unwrap();
        ModelConfig::from_meta(&j).unwrap()
    }

    #[test]
    fn batches_deterministic_and_shaped() {
        let c = cfg();
        let a = calibration_batches(&c, Source::Wiki, 4, 1);
        let b = calibration_batches(&c, Source::Wiki, 4, 1);
        assert_eq!(a, b);
        assert_eq!(a.len(), 4);
        assert!(a.iter().all(|s| s.len() == c.seq_len));
        let c2 = calibration_batches(&c, Source::Wiki, 4, 2);
        assert_ne!(a, c2);
    }

    #[test]
    fn batches_tokens_in_vocab() {
        let c = cfg();
        for seq in calibration_batches(&c, Source::C4, 3, 7) {
            assert!(seq.iter().all(|&t| (0..32).contains(&t)));
        }
    }
}
