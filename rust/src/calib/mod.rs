//! Calibration substrate: runs the capture artifact over calibration
//! batches and exposes per-layer, per-site activation matrices to the
//! permutation calibrators (MassDiff & co.) and the rounding Hessians.

pub mod capture;

pub use capture::Captures;
