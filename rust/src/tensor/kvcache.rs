//! Per-layer K/V caches for stateful (prefill/decode) execution.
//!
//! The paper's serving argument (App A) is a *decode-time* argument: the
//! online R̃3 rotation is paid per generated token, so the workload that
//! matters is incremental token generation over a persistent attention
//! state — not stateless full-window rescoring. This module holds that
//! state. Following the SpinQuant/QuaRot deployment story (rotations
//! placed so caches stay low-bit at decode), K/V rows are stored as
//! **packed u8 codes** with per-row (scale, zero) from the same Eq. 4
//! asymmetric quantizer the activation path uses (`quant::act`), so the
//! cache costs 1 byte/value instead of 4 — the dominant per-session memory
//! at serving batch sizes.
//!
//! Layout: one [`KvStore`] per layer for K and one for V, each a flat
//! `slots × cap × d` arena indexed `(slot, pos, channel)`. All buffers are
//! allocated once at session creation (`KvCache::new`) and written in
//! place, so steady-state decode performs **zero heap allocation**; reads
//! dequantize a slot's prefix into caller-provided scratch (the backend
//! recycles that scratch through its `BufPool`).
//!
//! Modes ([`KvMode`], `PERQ_KV={int8,f32}` escape hatch):
//! * `Int8` (default) — packed u8 codes + per-row (scale, zero); reads
//!   reproduce the fake-quant value `s·(code + z)` exactly, so prefill and
//!   decode observe bit-identical cache contents.
//! * `F32` — raw f32 rows; `gather` is a copy, making the session path
//!   bit-identical to the stateless full-precision forward (the parity
//!   baseline, and the mode `ExecBackend::score` runs in).

use anyhow::{ensure, Result};

use crate::quant::act;

/// How cached K/V rows are stored. Parsed from `PERQ_KV` (default int8).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvMode {
    /// packed u8 codes, per-row (scale, zero) — 1 byte/value
    Int8,
    /// raw f32 rows — the exact-cache escape hatch
    F32,
}

impl KvMode {
    pub fn name(&self) -> &'static str {
        match self {
            KvMode::Int8 => "int8",
            KvMode::F32 => "f32",
        }
    }

    pub fn parse(s: &str) -> Option<KvMode> {
        match s.to_ascii_lowercase().as_str() {
            "int8" | "i8" | "u8" => Some(KvMode::Int8),
            "f32" | "fp32" | "float" => Some(KvMode::F32),
            _ => None,
        }
    }

    /// `PERQ_KV` override, else the int8 default (the paper's low-bit
    /// decode story).
    pub fn from_env() -> KvMode {
        std::env::var("PERQ_KV")
            .ok()
            .and_then(|v| KvMode::parse(&v))
            .unwrap_or(KvMode::Int8)
    }
}

/// One `slots × cap × d` arena of cached rows (one per layer per K/V).
enum KvStore {
    /// u8 codes + per-(slot,pos) scale/zero, dequant `s · (code + z)`
    Int8 { codes: Vec<u8>, scales: Vec<f32>, zeros: Vec<f32> },
    F32(Vec<f32>),
}

impl KvStore {
    fn new(mode: KvMode, slots: usize, cap: usize, d: usize) -> KvStore {
        let n = slots * cap * d;
        match mode {
            KvMode::Int8 => KvStore::Int8 {
                codes: vec![0u8; n],
                scales: vec![0.0; slots * cap],
                zeros: vec![0.0; slots * cap],
            },
            KvMode::F32 => KvStore::F32(vec![0.0; n]),
        }
    }

    /// Bytes resident in this store's buffers.
    fn bytes(&self) -> usize {
        match self {
            KvStore::Int8 { codes, scales, zeros } => {
                codes.len() + 4 * (scales.len() + zeros.len())
            }
            KvStore::F32(data) => 4 * data.len(),
        }
    }

    #[inline]
    fn write(&mut self, row_idx: usize, d: usize, row: &[f32]) {
        debug_assert_eq!(row.len(), d);
        match self {
            KvStore::Int8 { codes, scales, zeros } => {
                let (s, z) = act::int_asym_emit_into(row, 8, &mut codes[row_idx * d..(row_idx + 1) * d]);
                scales[row_idx] = s;
                zeros[row_idx] = z;
            }
            KvStore::F32(data) => {
                data[row_idx * d..(row_idx + 1) * d].copy_from_slice(row);
            }
        }
    }

    /// Dequantize rows `row0 .. row0 + n` into `out` (n·d f32s).
    #[inline]
    fn gather(&self, row0: usize, n: usize, d: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), n * d);
        match self {
            KvStore::Int8 { codes, scales, zeros } => {
                for r in 0..n {
                    let (s, z) = (scales[row0 + r], zeros[row0 + r]);
                    let src = &codes[(row0 + r) * d..(row0 + r + 1) * d];
                    let dst = &mut out[r * d..(r + 1) * d];
                    for c in 0..d {
                        dst[c] = s * (src[c] as f32 + z);
                    }
                }
            }
            KvStore::F32(data) => {
                out.copy_from_slice(&data[row0 * d..(row0 + n) * d]);
            }
        }
    }
}

/// The full per-session attention state: `n_layers` K stores + V stores
/// over `slots` independent sequences of up to `cap` positions each.
/// Slot lengths advance via [`KvCache::advance`] and reset independently
/// ([`KvCache::reset_slot`]) — the substrate of continuous batching, where
/// requests join and leave a live batch at step granularity.
pub struct KvCache {
    mode: KvMode,
    pub slots: usize,
    /// maximum positions per slot (the model's seq_len)
    pub cap: usize,
    /// row width (d_model)
    pub d: usize,
    k: Vec<KvStore>,
    v: Vec<KvStore>,
    lens: Vec<usize>,
}

impl KvCache {
    /// Allocate the full arena up front — the only allocation this cache
    /// ever performs.
    pub fn new(mode: KvMode, n_layers: usize, slots: usize, cap: usize, d: usize) -> KvCache {
        KvCache {
            mode,
            slots,
            cap,
            d,
            k: (0..n_layers).map(|_| KvStore::new(mode, slots, cap, d)).collect(),
            v: (0..n_layers).map(|_| KvStore::new(mode, slots, cap, d)).collect(),
            lens: vec![0; slots],
        }
    }

    pub fn mode(&self) -> KvMode {
        self.mode
    }

    /// Current position count of a slot.
    pub fn len(&self, slot: usize) -> usize {
        self.lens[slot]
    }

    pub fn is_empty(&self, slot: usize) -> bool {
        self.lens[slot] == 0
    }

    /// Free positions left in a slot.
    pub fn remaining(&self, slot: usize) -> usize {
        self.cap - self.lens[slot]
    }

    /// Write the K row of `(slot, pos)` at `layer` (quantizing in int8
    /// mode). Positions at or past the slot's length are staging writes;
    /// they become visible via [`KvCache::advance`].
    #[inline]
    pub fn write_k(&mut self, layer: usize, slot: usize, pos: usize, row: &[f32]) {
        debug_assert!(pos < self.cap, "position {pos} past cache capacity {}", self.cap);
        self.k[layer].write(slot * self.cap + pos, self.d, row);
    }

    /// Write the V row of `(slot, pos)` at `layer`.
    #[inline]
    pub fn write_v(&mut self, layer: usize, slot: usize, pos: usize, row: &[f32]) {
        debug_assert!(pos < self.cap, "position {pos} past cache capacity {}", self.cap);
        self.v[layer].write(slot * self.cap + pos, self.d, row);
    }

    /// Dequantize the first `n` K rows of `slot` at `layer` into `out`.
    pub fn gather_k(&self, layer: usize, slot: usize, n: usize, out: &mut [f32]) {
        self.k[layer].gather(slot * self.cap, n, self.d, out);
    }

    /// Dequantize the first `n` V rows of `slot` at `layer` into `out`.
    pub fn gather_v(&self, layer: usize, slot: usize, n: usize, out: &mut [f32]) {
        self.v[layer].gather(slot * self.cap, n, self.d, out);
    }

    /// Commit `n` freshly written positions to a slot (after every layer
    /// has written them).
    pub fn advance(&mut self, slot: usize, n: usize) -> Result<()> {
        ensure!(
            self.lens[slot] + n <= self.cap,
            "slot {slot} overflows cache capacity {} ({} + {n})",
            self.cap,
            self.lens[slot]
        );
        self.lens[slot] += n;
        Ok(())
    }

    /// Release a slot for reuse (continuous batching: a request left the
    /// batch). O(1): codes are overwritten in place by the next occupant.
    pub fn reset_slot(&mut self, slot: usize) {
        self.lens[slot] = 0;
    }

    /// Reset every slot (the persistent scoring session reuses its cache
    /// across `score` calls).
    pub fn reset_all(&mut self) {
        self.lens.iter_mut().for_each(|l| *l = 0);
    }

    /// Bytes resident in the cache arenas — the number the int8 mode
    /// exists to shrink.
    pub fn bytes(&self) -> usize {
        self.k.iter().chain(self.v.iter()).map(|s| s.bytes()).sum::<usize>()
            + 8 * self.lens.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::act;

    fn rand_row(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut rng = crate::data::rng::Rng::new(seed);
        (0..n).map(|_| rng.next_normal() as f32 * scale).collect()
    }

    #[test]
    fn mode_parse_and_env_default() {
        assert_eq!(KvMode::parse("int8"), Some(KvMode::Int8));
        assert_eq!(KvMode::parse("F32"), Some(KvMode::F32));
        assert_eq!(KvMode::parse("fp32"), Some(KvMode::F32));
        assert_eq!(KvMode::parse("nope"), None);
        assert_eq!(KvMode::Int8.name(), "int8");
    }

    #[test]
    fn f32_mode_round_trips_exactly() {
        let (layers, slots, cap, d) = (2, 3, 8, 16);
        let mut kv = KvCache::new(KvMode::F32, layers, slots, cap, d);
        let rows: Vec<Vec<f32>> = (0..4).map(|i| rand_row(d, 100 + i, 2.0)).collect();
        for (p, row) in rows.iter().enumerate() {
            kv.write_k(1, 2, p, row);
            kv.write_v(1, 2, p, row);
        }
        kv.advance(2, 4).unwrap();
        assert_eq!(kv.len(2), 4);
        assert_eq!(kv.len(0), 0);
        let mut out = vec![0.0f32; 4 * d];
        kv.gather_k(1, 2, 4, &mut out);
        let want: Vec<f32> = rows.concat();
        assert_eq!(out, want, "f32 mode must be an exact copy");
        kv.gather_v(1, 2, 4, &mut out);
        assert_eq!(out, want);
    }

    #[test]
    fn int8_mode_matches_fake_quant_bitwise() {
        // the cache's read value must equal the Eq. 4 int8 fake-quant of
        // the written row, bit for bit — the same identity the packed
        // GEMM rests on
        let (layers, slots, cap, d) = (1, 2, 4, 32);
        let mut kv = KvCache::new(KvMode::Int8, layers, slots, cap, d);
        for p in 0..3 {
            let row = rand_row(d, 7 + p as u64, 1.5);
            kv.write_k(0, 1, p, &row);
            kv.advance(1, 1).unwrap();
            let mut fake = row.clone();
            act::int_asym_row(&mut fake, 8);
            let mut out = vec![0.0f32; (p + 1) * d];
            kv.gather_k(0, 1, p + 1, &mut out);
            assert_eq!(&out[p * d..], fake.as_slice(), "pos {p}");
        }
    }

    #[test]
    fn slots_are_independent_and_resettable() {
        let d = 8;
        let mut kv = KvCache::new(KvMode::Int8, 1, 2, 4, d);
        let a = rand_row(d, 1, 1.0);
        let b = rand_row(d, 2, 1.0);
        kv.write_k(0, 0, 0, &a);
        kv.write_k(0, 1, 0, &b);
        kv.advance(0, 1).unwrap();
        kv.advance(1, 1).unwrap();
        let (mut oa, mut ob) = (vec![0.0; d], vec![0.0; d]);
        kv.gather_k(0, 0, 1, &mut oa);
        kv.gather_k(0, 1, 1, &mut ob);
        assert_ne!(oa, ob, "slots must not alias");
        kv.reset_slot(0);
        assert_eq!(kv.len(0), 0);
        assert_eq!(kv.len(1), 1, "resetting one slot must not touch others");
        assert_eq!(kv.remaining(0), 4);
        // overflow is an error, not a wrap
        assert!(kv.advance(1, 4).is_err());
    }

    #[test]
    fn int8_arena_is_quarter_sized() {
        let f = KvCache::new(KvMode::F32, 2, 4, 16, 64);
        let q = KvCache::new(KvMode::Int8, 2, 4, 16, 64);
        // codes are 1 byte/value vs 4; per-row metadata is amortized by d
        assert!(q.bytes() * 3 < f.bytes(), "int8 {} vs f32 {}", q.bytes(), f.bytes());
    }
}
