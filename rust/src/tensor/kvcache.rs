//! Per-layer K/V caches for stateful (prefill/decode) execution — dense or
//! **paged**, with radix-trie prefix sharing.
//!
//! The paper's serving argument (App A) is a *decode-time* argument: the
//! online R̃3 rotation is paid per generated token, so the workload that
//! matters is incremental token generation over a persistent attention
//! state — not stateless full-window rescoring. This module holds that
//! state. Following the SpinQuant/QuaRot deployment story (rotations
//! placed so caches stay low-bit at decode), K/V rows are stored as
//! **packed u8 codes** with per-row (scale, zero) from the same Eq. 4
//! asymmetric quantizer the activation path uses (`quant::act`), so the
//! cache costs 1 byte/value instead of 4 — the dominant per-session memory
//! at serving batch sizes.
//!
//! ## Layout
//!
//! One [`KvStore`] per layer for K and one for V. Dense (the default,
//! `PERQ_KV_PAGE` unset/0): each arena is a flat `slots × cap × d` buffer
//! indexed `(slot, pos, channel)` — bit-for-bit the pre-paging cache.
//!
//! Paged ([`PagedConfig`], `PERQ_KV_PAGE` > 0): the arenas become a pool
//! of fixed-size **pages** (`page` positions each):
//!
//! * every slot owns a **page table** (`Vec<u32>` of page ids with
//!   capacity preallocated to `ceil(cap/page)`); logical position `p`
//!   lives at physical row `table[p/page]·page + p%page`. One page id
//!   indexes every per-layer K and V arena at the same offset
//!   (vLLM-style), so there is a single table per slot, not one per layer.
//! * pages come from a preallocated **free list**; steady-state decode
//!   stays zero-heap-allocation — one free-list pop every `page` tokens,
//!   nothing else.
//! * a **trie prefix cache** keyed on token prefixes lets identical prompt
//!   prefixes share pages copy-on-write with refcounts: [`KvCache::attach_prefix`]
//!   maps a new slot onto already-cached pages, and the first write into a
//!   shared partial page triggers a private copy of only that split page.
//!   Unreferenced trie leaves are evicted on demand when the pool runs dry.
//! * [`KvCache::swap_out`]/[`KvCache::swap_in`] spill a slot's raw rows to
//!   a [`KvSwap`] buffer and restore them bit-identically — the
//!   scheduler-driven preemption path in `coordinator::server`.
//!
//! ## Numerics contract
//!
//! Paged reads are **bit-identical** to the dense cache: the same
//! `int_asym_emit_into` rows are written and the same per-row dequant is
//! read back — only the addressing changes. Prefix-shared rows are exactly
//! the rows the donor prompt wrote, and attention is per-row independent,
//! so every existing ≤1e-4 / bit-exact parity bound holds unchanged
//! (rust/tests/decode_parity.rs). The int8 dequant inner loop runs through
//! the dispatched `tensor::simd::dequant_codes` primitive, which is in the
//! bit-identical class (u8→f32 conversion is exact; one mul + one add per
//! element in scalar expression order).
//!
//! Modes ([`KvMode`], `PERQ_KV={int8,f32}` escape hatch):
//! * `Int8` (default) — packed u8 codes + per-row (scale, zero); reads
//!   reproduce the fake-quant value `s·(code + z)` exactly, so prefill and
//!   decode observe bit-identical cache contents.
//! * `F32` — raw f32 rows; `gather` is a copy, making the session path
//!   bit-identical to the stateless full-precision forward (the parity
//!   baseline, and the mode `ExecBackend::score` runs in).

use std::fmt;

use anyhow::{ensure, Result};

use crate::quant::act;
use crate::tensor::simd;

/// How cached K/V rows are stored. Parsed from `PERQ_KV` (default int8).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvMode {
    /// packed u8 codes, per-row (scale, zero) — 1 byte/value
    Int8,
    /// raw f32 rows — the exact-cache escape hatch
    F32,
}

impl KvMode {
    pub fn name(self) -> &'static str {
        match self {
            KvMode::Int8 => "int8",
            KvMode::F32 => "f32",
        }
    }

    pub fn parse(s: &str) -> Option<KvMode> {
        match s.to_ascii_lowercase().as_str() {
            "int8" | "i8" | "u8" => Some(KvMode::Int8),
            "f32" | "fp32" | "float" => Some(KvMode::F32),
            _ => None,
        }
    }

    /// `PERQ_KV` with the int8 default (unset or unparsable → Int8).
    pub fn from_env() -> KvMode {
        std::env::var("PERQ_KV")
            .ok()
            .and_then(|v| KvMode::parse(&v))
            .unwrap_or(KvMode::Int8)
    }
}

/// Paged-arena knobs. `page == 0` keeps the dense `slots × cap` layout
/// (bit-for-bit today's behavior); `page > 0` carves the arenas into a
/// pool of `pages` fixed-size pages.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PagedConfig {
    /// Positions per page; 0 disables paging.
    pub page: usize,
    /// Pool size in pages per session; 0 = dense-equivalent
    /// (`slots × ceil(cap/page)` — paging with no oversubscription).
    pub pages: usize,
}

impl PagedConfig {
    /// The dense layout (paging off).
    pub fn dense() -> PagedConfig {
        PagedConfig { page: 0, pages: 0 }
    }

    pub fn is_paged(&self) -> bool {
        self.page > 0
    }

    /// `PERQ_KV_PAGE` (positions per page, 0/unset = dense) and
    /// `PERQ_KV_PAGES` (pool pages per session, 0/unset = dense-equivalent;
    /// also settable as `perq serve --kv-pages N`).
    pub fn from_env() -> PagedConfig {
        let parse = |k: &str| {
            std::env::var(k)
                .ok()
                .and_then(|v| v.trim().parse::<usize>().ok())
                .unwrap_or(0)
        };
        PagedConfig { page: parse("PERQ_KV_PAGE"), pages: parse("PERQ_KV_PAGES") }
    }
}

/// Typed allocation failure: the page pool is exhausted and the prefix
/// cache holds no evictable (unreferenced) pages. The serving scheduler
/// downcasts to this to trigger preemption instead of failing the step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OutOfPages;

impl fmt::Display for OutOfPages {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "KV page pool exhausted (all pages pinned by live slots or the prefix cache)")
    }
}

impl std::error::Error for OutOfPages {}

/// Local (per-cache) event counters, drained by the engine into the
/// process-wide obs registry ([`KvCache::take_stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KvStats {
    /// Prompt tokens served from the shared prefix cache.
    pub prefix_hit_tokens: u64,
    /// Private page copies triggered by writes into shared pages.
    pub cow_copies: u64,
}

/// One storage arena (one layer's K or one layer's V).
enum KvStore {
    Int8 { codes: Vec<u8>, scales: Vec<f32>, zeros: Vec<f32> },
    F32(Vec<f32>),
}

impl KvStore {
    fn new(mode: KvMode, rows: usize, d: usize) -> KvStore {
        match mode {
            KvMode::Int8 => KvStore::Int8 {
                codes: vec![0u8; rows * d],
                scales: vec![0.0; rows],
                zeros: vec![0.0; rows],
            },
            KvMode::F32 => KvStore::F32(vec![0.0; rows * d]),
        }
    }

    fn bytes(&self) -> usize {
        match self {
            KvStore::Int8 { codes, scales, zeros } => {
                codes.len() + 4 * (scales.len() + zeros.len())
            }
            KvStore::F32(data) => 4 * data.len(),
        }
    }

    /// Quantize-on-write one row at physical row index `row_idx`.
    fn write(&mut self, row_idx: usize, d: usize, row: &[f32]) {
        debug_assert_eq!(row.len(), d);
        match self {
            KvStore::Int8 { codes, scales, zeros } => {
                let (s, z) =
                    act::int_asym_emit_into(row, 8, &mut codes[row_idx * d..(row_idx + 1) * d]);
                scales[row_idx] = s;
                zeros[row_idx] = z;
            }
            KvStore::F32(data) => {
                data[row_idx * d..(row_idx + 1) * d].copy_from_slice(row);
            }
        }
    }

    /// Dequantize-on-read `n` physically-contiguous rows starting at
    /// `row0` into `out` (`n * d` floats).
    fn gather(&self, row0: usize, n: usize, d: usize, out: &mut [f32]) {
        debug_assert!(out.len() >= n * d);
        match self {
            KvStore::Int8 { codes, scales, zeros } => {
                for r in 0..n {
                    let (s, z) = (scales[row0 + r], zeros[row0 + r]);
                    let src = &codes[(row0 + r) * d..(row0 + r + 1) * d];
                    let dst = &mut out[r * d..(r + 1) * d];
                    // fused dequant through the dispatched SIMD layer —
                    // bit-identical class (u8→f32 is exact; one mul + one
                    // add per element in scalar expression order)
                    simd::dequant_codes(s, z, src, dst);
                }
            }
            KvStore::F32(data) => {
                out[..n * d].copy_from_slice(&data[row0 * d..(row0 + n) * d]);
            }
        }
    }

    /// Copy one whole page within the arena (the CoW split copy) —
    /// `copy_within` on the owned buffers, no heap allocation.
    fn copy_page(&mut self, src_page: usize, dst_page: usize, page: usize, d: usize) {
        match self {
            KvStore::Int8 { codes, scales, zeros } => {
                codes.copy_within(
                    src_page * page * d..(src_page + 1) * page * d,
                    dst_page * page * d,
                );
                scales.copy_within(src_page * page..(src_page + 1) * page, dst_page * page);
                zeros.copy_within(src_page * page..(src_page + 1) * page, dst_page * page);
            }
            KvStore::F32(data) => {
                data.copy_within(
                    src_page * page * d..(src_page + 1) * page * d,
                    dst_page * page * d,
                );
            }
        }
    }

    /// Raw-copy `n` rows starting at physical `src_row0` into swap rows
    /// starting at `dst_row0` — the stored representation, not a dequant,
    /// so restore is bit-identical.
    fn export_rows(
        &self,
        src_row0: usize,
        dst_row0: usize,
        n: usize,
        d: usize,
        out: &mut SwapStore,
    ) {
        match (self, out) {
            (
                KvStore::Int8 { codes, scales, zeros },
                SwapStore::Int8 { codes: oc, scales: os, zeros: oz },
            ) => {
                oc[dst_row0 * d..(dst_row0 + n) * d]
                    .copy_from_slice(&codes[src_row0 * d..(src_row0 + n) * d]);
                os[dst_row0..dst_row0 + n].copy_from_slice(&scales[src_row0..src_row0 + n]);
                oz[dst_row0..dst_row0 + n].copy_from_slice(&zeros[src_row0..src_row0 + n]);
            }
            (KvStore::F32(data), SwapStore::F32(o)) => {
                o[dst_row0 * d..(dst_row0 + n) * d]
                    .copy_from_slice(&data[src_row0 * d..(src_row0 + n) * d]);
            }
            _ => unreachable!("swap buffers are built for this cache's mode"),
        }
    }

    /// Inverse of [`KvStore::export_rows`].
    fn import_rows(
        &mut self,
        dst_row0: usize,
        src: &SwapStore,
        src_row0: usize,
        n: usize,
        d: usize,
    ) {
        match (self, src) {
            (
                KvStore::Int8 { codes, scales, zeros },
                SwapStore::Int8 { codes: sc, scales: ss, zeros: sz },
            ) => {
                codes[dst_row0 * d..(dst_row0 + n) * d]
                    .copy_from_slice(&sc[src_row0 * d..(src_row0 + n) * d]);
                scales[dst_row0..dst_row0 + n].copy_from_slice(&ss[src_row0..src_row0 + n]);
                zeros[dst_row0..dst_row0 + n].copy_from_slice(&sz[src_row0..src_row0 + n]);
            }
            (KvStore::F32(data), SwapStore::F32(s)) => {
                data[dst_row0 * d..(dst_row0 + n) * d]
                    .copy_from_slice(&s[src_row0 * d..(src_row0 + n) * d]);
            }
            _ => unreachable!("swap buffers are built for this cache's mode"),
        }
    }
}

/// One spilled arena: a slot's rows in their stored representation.
enum SwapStore {
    Int8 { codes: Vec<u8>, scales: Vec<f32>, zeros: Vec<f32> },
    F32(Vec<f32>),
}

impl SwapStore {
    fn new(mode: KvMode, len: usize, d: usize) -> SwapStore {
        match mode {
            KvMode::Int8 => SwapStore::Int8 {
                codes: vec![0u8; len * d],
                scales: vec![0.0; len],
                zeros: vec![0.0; len],
            },
            KvMode::F32 => SwapStore::F32(vec![0.0; len * d]),
        }
    }

    fn bytes(&self) -> usize {
        match self {
            SwapStore::Int8 { codes, scales, zeros } => {
                codes.len() + 4 * (scales.len() + zeros.len())
            }
            SwapStore::F32(data) => 4 * data.len(),
        }
    }
}

/// A preempted slot's spilled KV state ([`KvCache::swap_out`]), restored
/// bit-identically by [`KvCache::swap_in`]. Per-layer K and V rows in
/// their stored representation.
pub struct KvSwap {
    len: usize,
    k: Vec<SwapStore>,
    v: Vec<SwapStore>,
}

impl KvSwap {
    /// Cached positions held by the spilled slot.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Spill-buffer footprint in bytes.
    pub fn bytes(&self) -> usize {
        self.k.iter().chain(self.v.iter()).map(SwapStore::bytes).sum()
    }
}

/// One node of the prefix trie: a single page's token run. The root level
/// and every `children` list branch on the chunk's first token; runs are
/// page-aligned (only the last node of an inserted prefix may be shorter
/// than a page).
struct TrieNode {
    tokens: Vec<i32>,
    page: u32,
    /// `u32::MAX` = root level.
    parent: u32,
    children: Vec<u32>,
}

/// Radix-trie prefix cache over page-sized token chunks. The trie itself
/// holds one refcount on each node's page; eviction (unreferenced leaves
/// only) releases pages back to the pool on demand.
struct Trie {
    nodes: Vec<Option<TrieNode>>,
    roots: Vec<u32>,
    spare: Vec<u32>,
}

impl Trie {
    fn new() -> Trie {
        Trie { nodes: Vec::new(), roots: Vec::new(), spare: Vec::new() }
    }

    fn level_ids(&self, parent: u32) -> &[u32] {
        if parent == u32::MAX {
            &self.roots
        } else {
            &self.nodes[parent as usize].as_ref().expect("live parent").children
        }
    }

    /// Walk the longest shared prefix of `tokens[..limit]`, pushing each
    /// shared page id onto `table` and bumping its refcount. A node whose
    /// run only partially matches still shares its page for the matched
    /// positions (the slot's first append into it will CoW). Returns the
    /// matched token count.
    fn attach(
        &self,
        tokens: &[i32],
        limit: usize,
        page: usize,
        table: &mut Vec<u32>,
        refs: &mut [u32],
    ) -> usize {
        let mut matched = 0usize;
        let mut parent = u32::MAX;
        loop {
            let rem = &tokens[matched..limit];
            if rem.is_empty() {
                return matched;
            }
            let mut descend = None;
            for &ni in self.level_ids(parent) {
                let node = self.nodes[ni as usize].as_ref().expect("live child");
                let common =
                    node.tokens.iter().zip(rem.iter()).take_while(|(a, b)| a == b).count();
                if common == 0 {
                    continue;
                }
                table.push(node.page);
                refs[node.page as usize] += 1;
                matched += common;
                // descend only through exactly-matched full pages; a
                // partial match ends the walk on its split page
                if common == node.tokens.len() && common == page {
                    descend = Some(ni);
                }
                break;
            }
            match descend {
                Some(ni) => parent = ni,
                None => return matched,
            }
        }
    }

    /// Record a freshly prefilled prompt's pages. Inserts nodes only along
    /// fresh branches — when a chunk partially overlaps an existing node,
    /// the walk stops and the existing structure wins (first-writer-wins
    /// per branch; the divergent suffix stays private to its slot).
    fn register(&mut self, tokens: &[i32], page: usize, table: &[u32], refs: &mut [u32]) {
        let mut off = 0usize;
        let mut parent = u32::MAX;
        while off < tokens.len() {
            let chunk = &tokens[off..(off + page).min(tokens.len())];
            let mut found = None;
            let mut overlaps = false;
            for &ni in self.level_ids(parent) {
                let node = self.nodes[ni as usize].as_ref().expect("live child");
                let common =
                    node.tokens.iter().zip(chunk.iter()).take_while(|(a, b)| a == b).count();
                if common == 0 {
                    continue;
                }
                overlaps = true;
                if common == node.tokens.len() && common == chunk.len() && common == page {
                    found = Some(ni);
                }
                break;
            }
            match found {
                Some(ni) => {
                    parent = ni;
                    off += page;
                }
                None => {
                    if overlaps {
                        return; // divergent branch — not re-registered
                    }
                    let pid = table[off / page];
                    let ni = self.insert(TrieNode {
                        tokens: chunk.to_vec(),
                        page: pid,
                        parent,
                        children: Vec::new(),
                    });
                    refs[pid as usize] += 1;
                    if chunk.len() < page {
                        return; // partial tail node is always a leaf
                    }
                    parent = ni;
                    off += page;
                }
            }
        }
    }

    fn insert(&mut self, node: TrieNode) -> u32 {
        let parent = node.parent;
        let ni = match self.spare.pop() {
            Some(i) => {
                self.nodes[i as usize] = Some(node);
                i
            }
            None => {
                self.nodes.push(Some(node));
                (self.nodes.len() - 1) as u32
            }
        };
        if parent == u32::MAX {
            self.roots.push(ni);
        } else {
            self.nodes[parent as usize].as_mut().expect("live parent").children.push(ni);
        }
        ni
    }

    /// Evict one unreferenced leaf (page held only by the trie), returning
    /// its reclaimed page. `None` when every cached page is still shared.
    fn evict_one(&mut self, refs: &mut [u32]) -> Option<u32> {
        let victim = self.nodes.iter().enumerate().find_map(|(i, n)| {
            n.as_ref().and_then(|node| {
                (node.children.is_empty() && refs[node.page as usize] == 1).then_some(i as u32)
            })
        })?;
        let node = self.nodes[victim as usize].take().expect("found above");
        if node.parent == u32::MAX {
            self.roots.retain(|&r| r != victim);
        } else {
            self.nodes[node.parent as usize]
                .as_mut()
                .expect("live parent")
                .children
                .retain(|&c| c != victim);
        }
        self.spare.push(victim);
        refs[node.page as usize] = 0;
        Some(node.page)
    }
}

/// The paged-layout state: page tables, free list, refcounts, prefix trie.
struct PageMap {
    page: usize,
    pages: usize,
    tables: Vec<Vec<u32>>,
    free: Vec<u32>,
    refs: Vec<u32>,
    trie: Trie,
}

/// Pop a free page (evicting an unreferenced prefix-cache leaf if the
/// free list is dry) and claim it with refcount 1.
fn alloc_page(pm: &mut PageMap) -> Result<u32, OutOfPages> {
    let p = match pm.free.pop() {
        Some(p) => p,
        None => pm.trie.evict_one(&mut pm.refs).ok_or(OutOfPages)?,
    };
    pm.refs[p as usize] = 1;
    Ok(p)
}

/// Per-layer K/V cache over `slots` independent attention-state slots,
/// dense (`slots × cap` rows per arena) or paged (see the module docs).
pub struct KvCache {
    mode: KvMode,
    pub slots: usize,
    pub cap: usize,
    pub d: usize,
    k: Vec<KvStore>,
    v: Vec<KvStore>,
    lens: Vec<usize>,
    paged: Option<PageMap>,
    stats: KvStats,
}

impl KvCache {
    /// Dense layout — bit-for-bit the pre-paging cache.
    pub fn new(mode: KvMode, n_layers: usize, slots: usize, cap: usize, d: usize) -> KvCache {
        KvCache::new_paged(mode, n_layers, slots, cap, d, PagedConfig::dense())
    }

    /// Dense or paged layout per `pcfg` (`PagedConfig::from_env()` reads
    /// the `PERQ_KV_PAGE`/`PERQ_KV_PAGES` knobs).
    pub fn new_paged(
        mode: KvMode,
        n_layers: usize,
        slots: usize,
        cap: usize,
        d: usize,
        pcfg: PagedConfig,
    ) -> KvCache {
        let paged = if pcfg.is_paged() {
            let page = pcfg.page.clamp(1, cap.max(1));
            let per_slot = cap.div_ceil(page);
            let pages = if pcfg.pages > 0 { pcfg.pages } else { slots * per_slot };
            Some(PageMap {
                page,
                pages,
                tables: (0..slots).map(|_| Vec::with_capacity(per_slot)).collect(),
                free: (0..pages as u32).rev().collect(),
                refs: vec![0; pages],
                trie: Trie::new(),
            })
        } else {
            None
        };
        let rows = paged.as_ref().map_or(slots * cap, |pm| pm.pages * pm.page);
        let k = (0..n_layers).map(|_| KvStore::new(mode, rows, d)).collect();
        let v = (0..n_layers).map(|_| KvStore::new(mode, rows, d)).collect();
        KvCache {
            mode,
            slots,
            cap,
            d,
            k,
            v,
            lens: vec![0; slots],
            paged,
            stats: KvStats::default(),
        }
    }

    pub fn mode(&self) -> KvMode {
        self.mode
    }

    pub fn is_paged(&self) -> bool {
        self.paged.is_some()
    }

    /// Positions per page when paged.
    pub fn page_size(&self) -> Option<usize> {
        self.paged.as_ref().map(|pm| pm.page)
    }

    /// `(pages_in_use, pages_total)` when paged — in-use counts every page
    /// off the free list, including prefix-cache-pinned ones.
    pub fn page_usage(&self) -> Option<(usize, usize)> {
        self.paged.as_ref().map(|pm| (pm.pages - pm.free.len(), pm.pages))
    }

    /// Pages immediately allocatable without eviction.
    pub fn free_pages(&self) -> Option<usize> {
        self.paged.as_ref().map(|pm| pm.free.len())
    }

    /// The most positions a single slot can ever hold: `cap` dense, also
    /// capped by the whole pool when paged — the submit-time admission
    /// bound for `prompt_len + max_new`.
    pub fn max_request_positions(&self) -> usize {
        match &self.paged {
            None => self.cap,
            Some(pm) => self.cap.min(pm.pages * pm.page),
        }
    }

    /// Drain the local event counters (the engine syncs them into obs).
    pub fn take_stats(&mut self) -> KvStats {
        std::mem::take(&mut self.stats)
    }

    pub fn len(&self, slot: usize) -> usize {
        self.lens[slot]
    }

    pub fn is_empty(&self, slot: usize) -> bool {
        self.lens[slot] == 0
    }

    pub fn remaining(&self, slot: usize) -> usize {
        self.cap - self.lens[slot]
    }

    /// Physical row of logical position `pos` in `slot`.
    #[inline]
    fn phys(&self, slot: usize, pos: usize) -> usize {
        match &self.paged {
            None => slot * self.cap + pos,
            Some(pm) => {
                let pi = pos / pm.page;
                debug_assert!(pi < pm.tables[slot].len(), "position {pos} has no mapped page");
                pm.tables[slot][pi] as usize * pm.page + pos % pm.page
            }
        }
    }

    /// Make room for `n_new` appended positions: checks logical capacity,
    /// CoWs a shared partial tail page, and maps fresh pages from the free
    /// list (evicting unreferenced prefix-cache leaves under pressure).
    /// Fails with [`OutOfPages`] in the error chain when the pool is truly
    /// dry — before any row is written, so the step can be retried after
    /// the scheduler preempts a slot. Steady-state cost: one free-list pop
    /// every `page` tokens; zero heap allocation.
    pub fn prepare_append(&mut self, slot: usize, n_new: usize) -> Result<()> {
        ensure!(
            self.lens[slot] + n_new <= self.cap,
            "slot {slot} holds {} of {} positions — no room for {n_new} more",
            self.lens[slot],
            self.cap
        );
        let Some(pm) = self.paged.as_mut() else { return Ok(()) };
        if n_new == 0 {
            return Ok(());
        }
        let len = self.lens[slot];
        // copy-on-write: the partial tail page is about to be written; if
        // it is shared (prefix cache or a sibling slot), this slot gets a
        // private copy of just that split page
        if len % pm.page != 0 {
            let pi = len / pm.page;
            let old = pm.tables[slot][pi];
            if pm.refs[old as usize] > 1 {
                let fresh = alloc_page(pm).map_err(anyhow::Error::new)?;
                for store in self.k.iter_mut().chain(self.v.iter_mut()) {
                    store.copy_page(old as usize, fresh as usize, pm.page, self.d);
                }
                pm.refs[old as usize] -= 1;
                pm.tables[slot][pi] = fresh;
                self.stats.cow_copies += 1;
            }
        }
        let total_pages = (len + n_new).div_ceil(pm.page);
        while pm.tables[slot].len() < total_pages {
            let fresh = alloc_page(pm).map_err(anyhow::Error::new)?;
            pm.tables[slot].push(fresh);
        }
        Ok(())
    }

    /// Map `slot` (must be empty) onto the longest cached prefix of
    /// `tokens`, sharing pages with bumped refcounts. At most
    /// `tokens.len() - 1` positions attach, so the caller always prefills
    /// at least one row and gets last-position logits. Returns the number
    /// of positions served from the cache. Dense caches never match.
    pub fn attach_prefix(&mut self, slot: usize, tokens: &[i32]) -> usize {
        let Some(pm) = self.paged.as_mut() else { return 0 };
        if self.lens[slot] != 0 || tokens.len() < 2 {
            return 0;
        }
        debug_assert!(pm.tables[slot].is_empty());
        let limit = (tokens.len() - 1).min(self.cap);
        let matched = pm.trie.attach(tokens, limit, pm.page, &mut pm.tables[slot], &mut pm.refs);
        self.lens[slot] = matched;
        self.stats.prefix_hit_tokens += matched as u64;
        matched
    }

    /// Record a freshly prefilled prompt in the prefix cache so later
    /// identical prefixes share its pages. No-op on dense caches.
    pub fn register_prefix(&mut self, slot: usize, tokens: &[i32]) {
        let Some(pm) = self.paged.as_mut() else { return };
        let n = tokens.len().min(self.lens[slot]);
        if n == 0 {
            return;
        }
        pm.trie.register(&tokens[..n], pm.page, &pm.tables[slot], &mut pm.refs);
    }

    /// Evict every currently-unreferenced prefix-cache page back to the
    /// free list; returns the number of pages reclaimed.
    pub fn evict_prefix_cache(&mut self) -> usize {
        let Some(pm) = self.paged.as_mut() else { return 0 };
        let mut n = 0;
        while let Some(p) = pm.trie.evict_one(&mut pm.refs) {
            pm.free.push(p);
            n += 1;
        }
        n
    }

    /// Spill `slot`'s rows (stored representation — restore is
    /// bit-identical) and release its pages. The slot is left empty.
    pub fn swap_out(&mut self, slot: usize) -> KvSwap {
        let len = self.lens[slot];
        let k = self.k.iter().map(|s| self.export_store(s, slot, len)).collect();
        let v = self.v.iter().map(|s| self.export_store(s, slot, len)).collect();
        self.reset_slot(slot);
        KvSwap { len, k, v }
    }

    fn export_store(&self, store: &KvStore, slot: usize, len: usize) -> SwapStore {
        let mut out = SwapStore::new(self.mode, len, self.d);
        match &self.paged {
            None => store.export_rows(slot * self.cap, 0, len, self.d, &mut out),
            Some(pm) => {
                let mut off = 0;
                while off < len {
                    let take = pm.page.min(len - off);
                    let phys0 = pm.tables[slot][off / pm.page] as usize * pm.page;
                    store.export_rows(phys0, off, take, self.d, &mut out);
                    off += take;
                }
            }
        }
        out
    }

    /// Restore a spilled slot: allocate pages for `swap.len()` positions
    /// (failing with [`OutOfPages`] in the chain when the pool cannot hold
    /// them yet) and copy the rows back bit-identically.
    pub fn swap_in(&mut self, slot: usize, swap: &KvSwap) -> Result<()> {
        ensure!(self.lens[slot] == 0, "swap_in requires an empty slot {slot}");
        ensure!(
            swap.k.len() == self.k.len() && swap.v.len() == self.v.len(),
            "swap layer count mismatch"
        );
        self.prepare_append(slot, swap.len)?;
        let mut off = 0;
        while off < swap.len {
            let (phys0, take) = match &self.paged {
                None => (slot * self.cap + off, swap.len - off),
                Some(pm) => (
                    pm.tables[slot][off / pm.page] as usize * pm.page,
                    pm.page.min(swap.len - off),
                ),
            };
            for (store, sw) in self.k.iter_mut().zip(&swap.k) {
                store.import_rows(phys0, sw, off, take, self.d);
            }
            for (store, sw) in self.v.iter_mut().zip(&swap.v) {
                store.import_rows(phys0, sw, off, take, self.d);
            }
            off += take;
        }
        self.lens[slot] = swap.len;
        Ok(())
    }

    /// Write the K row for (`layer`, `slot`, position `pos`). Paged caches
    /// require `prepare_append` to have mapped the position's page.
    pub fn write_k(&mut self, layer: usize, slot: usize, pos: usize, row: &[f32]) {
        debug_assert!(pos < self.cap);
        let r = self.phys(slot, pos);
        self.k[layer].write(r, self.d, row);
    }

    pub fn write_v(&mut self, layer: usize, slot: usize, pos: usize, row: &[f32]) {
        debug_assert!(pos < self.cap);
        let r = self.phys(slot, pos);
        self.v[layer].write(r, self.d, row);
    }

    /// Dequantize the first `n` cached positions of (`layer`, `slot`) into
    /// `out` (`n * d` floats) — page-chunked when paged, one contiguous
    /// copy when dense; identical rows either way.
    pub fn gather_k(&self, layer: usize, slot: usize, n: usize, out: &mut [f32]) {
        self.gather_store(&self.k[layer], slot, n, out);
    }

    pub fn gather_v(&self, layer: usize, slot: usize, n: usize, out: &mut [f32]) {
        self.gather_store(&self.v[layer], slot, n, out);
    }

    fn gather_store(&self, store: &KvStore, slot: usize, n: usize, out: &mut [f32]) {
        debug_assert!(out.len() >= n * self.d);
        match &self.paged {
            None => store.gather(slot * self.cap, n, self.d, out),
            Some(pm) => {
                let mut off = 0;
                while off < n {
                    let take = pm.page.min(n - off);
                    let phys0 = pm.tables[slot][off / pm.page] as usize * pm.page;
                    store.gather(
                        phys0,
                        take,
                        self.d,
                        &mut out[off * self.d..(off + take) * self.d],
                    );
                    off += take;
                }
            }
        }
    }

    /// Commit `n` freshly written positions to `slot`.
    pub fn advance(&mut self, slot: usize, n: usize) -> Result<()> {
        ensure!(
            self.lens[slot] + n <= self.cap,
            "slot {slot} overflow: {} + {n} > {}",
            self.lens[slot],
            self.cap
        );
        self.lens[slot] += n;
        Ok(())
    }

    /// Release a slot: O(1) dense; paged, every table page drops one ref
    /// and unreferenced pages return to the free list (prefix-cache pages
    /// stay resident for future hits).
    pub fn reset_slot(&mut self, slot: usize) {
        self.lens[slot] = 0;
        if let Some(pm) = self.paged.as_mut() {
            for pid in pm.tables[slot].drain(..) {
                let r = &mut pm.refs[pid as usize];
                *r -= 1;
                if *r == 0 {
                    pm.free.push(pid);
                }
            }
        }
    }

    pub fn reset_all(&mut self) {
        for slot in 0..self.slots {
            self.reset_slot(slot);
        }
    }

    /// Resident bytes across all arenas — the paged pool is sized by
    /// `pages × page`, so an oversubscribed pool is proportionally smaller
    /// than the dense `slots × cap` arena.
    pub fn bytes(&self) -> usize {
        let stores: usize = self.k.iter().chain(self.v.iter()).map(KvStore::bytes).sum();
        stores + 8 * self.lens.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_row(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut rng = crate::data::rng::Rng::new(seed);
        (0..n).map(|_| (rng.next_f32() - 0.5) * scale).collect()
    }

    #[test]
    fn mode_parse_and_env_default() {
        assert_eq!(KvMode::parse("int8"), Some(KvMode::Int8));
        assert_eq!(KvMode::parse("I8"), Some(KvMode::Int8));
        assert_eq!(KvMode::parse("f32"), Some(KvMode::F32));
        assert_eq!(KvMode::parse("FP32"), Some(KvMode::F32));
        assert_eq!(KvMode::parse("bogus"), None);
        assert_eq!(KvMode::Int8.name(), "int8");
        assert_eq!(KvMode::F32.name(), "f32");
    }

    #[test]
    fn f32_mode_round_trips_exactly() {
        let (layers, slots, cap, d) = (2, 2, 6, 8);
        let mut kv = KvCache::new(KvMode::F32, layers, slots, cap, d);
        let rows: Vec<Vec<f32>> = (0..4).map(|i| rand_row(d, 40 + i as u64, 3.0)).collect();
        for (pos, row) in rows.iter().enumerate() {
            kv.write_k(1, 1, pos, row);
            kv.write_v(1, 1, pos, row);
        }
        kv.advance(1, rows.len()).unwrap();
        let mut out = vec![0.0; rows.len() * d];
        kv.gather_k(1, 1, rows.len(), &mut out);
        for (pos, row) in rows.iter().enumerate() {
            assert_eq!(&out[pos * d..(pos + 1) * d], &row[..], "f32 cache must be exact");
        }
    }

    #[test]
    fn int8_mode_matches_fake_quant_bitwise() {
        let (layers, slots, cap, d) = (1, 1, 4, 16);
        let mut kv = KvCache::new(KvMode::Int8, layers, slots, cap, d);
        for pos in 0..3 {
            let row = rand_row(d, 7 + pos as u64, 4.0);
            kv.write_k(0, 0, pos, &row);
            kv.write_v(0, 0, pos, &row);
            let mut fake = row.clone();
            act::int_asym_row(&mut fake, 8);
            let mut out = vec![0.0; (pos + 1) * d];
            kv.advance(0, 1).unwrap();
            kv.gather_k(0, 0, pos + 1, &mut out);
            assert_eq!(
                &out[pos * d..(pos + 1) * d],
                &fake[..],
                "int8 cache row must match the reference fake-quant bitwise"
            );
        }
    }

    #[test]
    fn slots_are_independent_and_resettable() {
        let (layers, slots, cap, d) = (1, 3, 4, 8);
        let mut kv = KvCache::new(KvMode::Int8, layers, slots, cap, d);
        let a = rand_row(d, 1, 2.0);
        let b = rand_row(d, 2, 2.0);
        kv.write_k(0, 0, 0, &a);
        kv.advance(0, 1).unwrap();
        kv.write_k(0, 2, 0, &b);
        kv.advance(2, 1).unwrap();
        assert_eq!(kv.len(0), 1);
        assert_eq!(kv.len(1), 0);
        assert_eq!(kv.len(2), 1);
        let mut oa = vec![0.0; d];
        let mut ob = vec![0.0; d];
        kv.gather_k(0, 0, 1, &mut oa);
        kv.gather_k(0, 2, 1, &mut ob);
        assert_ne!(oa, ob, "distinct rows must stay distinct across slots");
        kv.reset_slot(0);
        assert_eq!(kv.len(0), 0);
        assert_eq!(kv.len(2), 1, "resetting one slot must not touch others");
        assert_eq!(kv.remaining(0), cap);
        assert!(kv.advance(0, cap + 1).is_err(), "overflow must error");
    }

    #[test]
    fn int8_arena_is_quarter_sized() {
        let q = KvCache::new(KvMode::Int8, 2, 2, 8, 64);
        let f = KvCache::new(KvMode::F32, 2, 2, 8, 64);
        assert!(
            q.bytes() * 3 < f.bytes(),
            "int8 arenas must be ~4× smaller ({} vs {})",
            q.bytes(),
            f.bytes()
        );
    }

    // -- paged layout ----------------------------------------------------

    fn paged(
        mode: KvMode,
        slots: usize,
        cap: usize,
        d: usize,
        page: usize,
        pages: usize,
    ) -> KvCache {
        KvCache::new_paged(mode, 1, slots, cap, d, PagedConfig { page, pages })
    }

    #[test]
    fn paged_config_dense_default() {
        assert!(!PagedConfig::dense().is_paged());
        assert!(PagedConfig { page: 4, pages: 0 }.is_paged());
    }

    #[test]
    fn paged_rows_match_dense_bitwise() {
        for mode in [KvMode::Int8, KvMode::F32] {
            let (cap, d, page) = (12, 16, 4);
            let mut dense = KvCache::new(mode, 1, 2, cap, d);
            let mut pg = paged(mode, 2, cap, d, page, 0);
            for pos in 0..10 {
                let row = rand_row(d, 100 + pos as u64, 3.0);
                for kv in [&mut dense, &mut pg] {
                    kv.prepare_append(1, 1).unwrap();
                    kv.write_k(0, 1, pos, &row);
                    kv.write_v(0, 1, pos, &row);
                    kv.advance(1, 1).unwrap();
                }
            }
            let mut a = vec![0.0; 10 * d];
            let mut b = vec![0.0; 10 * d];
            dense.gather_k(0, 1, 10, &mut a);
            pg.gather_k(0, 1, 10, &mut b);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.to_bits(), y.to_bits(), "{mode:?}: paged read must be bit-identical");
            }
            // the dense-equivalent pool is the same arena volume; data
            // reads identical through completely different addressing
        }
    }

    #[test]
    fn page_pool_accounting_and_exhaustion() {
        let mut kv = paged(KvMode::Int8, 2, 16, 8, 4, 3); // 3-page pool
        assert_eq!(kv.page_usage(), Some((0, 3)));
        assert_eq!(kv.max_request_positions(), 12, "pool caps a single request");
        kv.prepare_append(0, 9).unwrap(); // 3 pages
        assert_eq!(kv.page_usage(), Some((3, 3)));
        assert_eq!(kv.free_pages(), Some(0));
        let err = kv.prepare_append(1, 1).unwrap_err();
        assert!(err.downcast_ref::<OutOfPages>().is_some(), "typed exhaustion: {err}");
        kv.advance(0, 9).unwrap();
        kv.reset_slot(0);
        assert_eq!(kv.page_usage(), Some((0, 3)), "reset returns pages to the pool");
        kv.prepare_append(1, 1).unwrap();
        assert_eq!(kv.page_usage(), Some((1, 3)));
    }

    #[test]
    fn prefix_attach_shares_pages_and_cow_splits() {
        let d = 8;
        let mut kv = paged(KvMode::F32, 2, 16, d, 4, 8);
        // slot 0 prefills a 6-token prompt and registers it
        let prompt: Vec<i32> = vec![5, 6, 7, 8, 9, 10];
        kv.prepare_append(0, prompt.len()).unwrap();
        let rows: Vec<Vec<f32>> =
            (0..prompt.len()).map(|i| rand_row(d, 60 + i as u64, 2.0)).collect();
        for (pos, row) in rows.iter().enumerate() {
            kv.write_k(0, 0, pos, row);
            kv.write_v(0, 0, pos, row);
        }
        kv.advance(0, prompt.len()).unwrap();
        kv.register_prefix(0, &prompt);
        let used_before = kv.page_usage().unwrap().0;
        // slot 1 submits the same prompt: all but the last token attach
        let matched = kv.attach_prefix(1, &prompt);
        assert_eq!(matched, prompt.len() - 1);
        assert_eq!(kv.len(1), matched);
        assert_eq!(
            kv.page_usage().unwrap().0,
            used_before,
            "attach shares pages, allocating none"
        );
        // shared rows read back exactly what slot 0 wrote
        let mut out = vec![0.0; matched * d];
        kv.gather_k(0, 1, matched, &mut out);
        for (pos, row) in rows[..matched].iter().enumerate() {
            assert_eq!(&out[pos * d..(pos + 1) * d], &row[..]);
        }
        // appending into the shared split page forces a private copy
        let stats0 = kv.take_stats();
        assert_eq!(stats0.prefix_hit_tokens, matched as u64);
        kv.prepare_append(1, 1).unwrap();
        let stats1 = kv.take_stats();
        assert_eq!(stats1.cow_copies, 1, "divergence copies exactly the split page");
        // the divergent write is private: slot 0's row is untouched
        let newrow = rand_row(d, 99, 2.0);
        kv.write_k(0, 1, matched, &newrow);
        kv.advance(1, 1).unwrap();
        let mut a = vec![0.0; prompt.len() * d];
        kv.gather_k(0, 0, prompt.len(), &mut a);
        assert_eq!(&a[matched * d..], &rows[matched][..], "CoW must not clobber the donor");
    }

    #[test]
    fn trie_eviction_reclaims_unreferenced_pages() {
        let d = 8;
        let mut kv = paged(KvMode::Int8, 1, 16, d, 4, 4);
        let prompt: Vec<i32> = (0..8).collect();
        kv.prepare_append(0, prompt.len()).unwrap();
        for pos in 0..prompt.len() {
            let row = rand_row(d, pos as u64, 1.0);
            kv.write_k(0, 0, pos, &row);
            kv.write_v(0, 0, pos, &row);
        }
        kv.advance(0, prompt.len()).unwrap();
        kv.register_prefix(0, &prompt);
        kv.reset_slot(0);
        // the trie pins both prompt pages: 2 in use, 2 free
        assert_eq!(kv.page_usage(), Some((2, 4)));
        // a 4-page demand must evict the cache rather than fail
        kv.prepare_append(0, 16).unwrap();
        assert_eq!(kv.page_usage(), Some((4, 4)));
        kv.reset_slot(0);
        assert_eq!(kv.evict_prefix_cache(), 0, "eviction already consumed the cache");
    }

    #[test]
    fn swap_round_trip_is_bit_identical() {
        for mode in [KvMode::Int8, KvMode::F32] {
            let d = 8;
            let mut kv = paged(mode, 2, 16, d, 4, 8);
            kv.prepare_append(0, 6).unwrap();
            for pos in 0..6 {
                let row = rand_row(d, 300 + pos as u64, 2.0);
                kv.write_k(0, 0, pos, &row);
                kv.write_v(0, 0, pos, &row);
            }
            kv.advance(0, 6).unwrap();
            let mut before = vec![0.0; 6 * d];
            kv.gather_v(0, 0, 6, &mut before);
            let swap = kv.swap_out(0);
            assert_eq!(swap.len(), 6);
            assert!(!swap.is_empty());
            assert!(swap.bytes() > 0);
            assert_eq!(kv.len(0), 0);
            assert_eq!(kv.page_usage(), Some((0, 8)), "swap-out releases all pages");
            kv.swap_in(0, &swap).unwrap();
            assert_eq!(kv.len(0), 6);
            let mut after = vec![0.0; 6 * d];
            kv.gather_v(0, 0, 6, &mut after);
            for (x, y) in before.iter().zip(&after) {
                assert_eq!(x.to_bits(), y.to_bits(), "{mode:?}: restore must be bit-identical");
            }
        }
    }
}
