//! Packed low-bit weight matrices + the integer GEMM serving kernel.
//!
//! The fake-quant execution path materializes dequantized f32 weights and
//! pays full f32 memory bandwidth per matmul — the INT4 deployment story
//! (merged permutations + block rotations, paper §Fig 7) only wins if the
//! weights *stay* low-bit. This module is that path:
//!
//! * [`QuantMat`] — a (d_in, d_out) weight packed once at load from a
//!   fitted [`WeightCodec`]: u4x2 nibbles (INT4) or i8 bytes (INT8),
//!   row-major, plus per-output-channel scales and integer column sums.
//! * [`QuantActs`] — per-token activation rows quantized to u8 codes with
//!   per-row (scale, zero) by `quant::act::int_asym_emit`, emitted
//!   straight from the (already rotated) f32 row — no fake-quant floats.
//! * [`qgemm_into`] — the integer GEMM: i32 accumulation over u8×i8
//!   products, per-channel dequantization fused into the store. For the
//!   asymmetric activation scheme `a = s·(u + z)` and symmetric weights
//!   `w = t_j·q`, the dot product factors as
//!   `Σ a·w = s·t_j·(Σ u·q + z·Σ q)` — the `Σ q` column sums are
//!   precomputed at pack time, so the zero-point correction is one fused
//!   multiply-add per output.
//!
//! The kernel is cache-blocked over token rows (MB at a time) so each
//! unpacked weight chunk is reused MB times, tiled over NB output columns
//! so the accumulator tile stays L1-resident, and row blocks are fanned
//! out across the persistent `util::pool` workers. Every inner loop runs
//! through the runtime-dispatched `tensor::simd` layer: the INT4×INT4
//! case accumulates in i16 lanes (16-wide on AVX2, 8-wide on NEON, scalar
//! fallback) over KC-length k-chunks widened into i32 between chunks.
//! Overflow: INT4 products are ≤ 120 so a 256-chunk stays within i16 (see
//! `KC`); the generic i32 path is exact for d_in < 2^16 (|u|≤255 · |q|≤128
//! products) — far above any model dimension here. Integer accumulation
//! is exact, so results are bit-identical across dispatch levels and
//! tilings (rust/tests/simd_props.rs).

use std::cell::RefCell;

use anyhow::{bail, ensure, Result};

use crate::quant::act;
use crate::quant::WeightCodec;
use crate::tensor::simd;
use crate::tensor::Mat;
use crate::util::pool::{self, SendPtr};

/// A packed integer weight matrix: (rows = d_in, cols = d_out), row-major
/// payload, per-output-channel symmetric scales.
#[derive(Clone)]
pub struct QuantMat {
    pub rows: usize,
    pub cols: usize,
    /// 4 (u4x2 nibble pairs, code stored offset-by-8) or 8 (i8 bytes)
    pub bits: u32,
    payload: Vec<u8>,
    /// per output-channel scale t_j (dequant: w = t_j · q)
    pub scales: Vec<f32>,
    /// per output-channel Σ_k q — the zero-point correction term
    colsum: Vec<i32>,
}

impl std::fmt::Debug for QuantMat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "QuantMat({}x{}, int{})", self.rows, self.cols, self.bits)
    }
}

impl QuantMat {
    /// Pack a (codec-quantized or raw) f32 weight with the given
    /// per-channel scales. Codes are `round(v / t_j)` clamped to the
    /// signed `bits`-wide range — the same rounding as
    /// `WeightCodec::quantize_entry`, so packing codec output is lossless.
    pub fn pack_int(w: &Mat, scales: &[f32], bits: u32) -> QuantMat {
        assert!(bits == 4 || bits == 8, "packed kernels support int4/int8");
        assert_eq!(scales.len(), w.cols, "one scale per output channel");
        let (k, n) = (w.rows, w.cols);
        let qmax = ((1i32 << (bits - 1)) - 1) as f32;
        let qmin = -qmax - 1.0;
        let mut colsum = vec![0i32; n];
        let payload = if bits == 4 {
            let stride = (n + 1) / 2;
            let mut p = vec![0u8; k * stride];
            // row staging buffer so the nibble layout lives in exactly one
            // place: simd::scalar::pack_row4, the proved inverse of the
            // kernel-side unpack_row4 (rust/verify/kernels.rs)
            let mut codes = vec![0i16; n];
            for i in 0..k {
                for j in 0..n {
                    let q = (w.at(i, j) / scales[j]).round().clamp(qmin, qmax) as i32;
                    colsum[j] += q;
                    codes[j] = q as i16;
                }
                simd::scalar::pack_row4(&codes, n, &mut p[i * stride..(i + 1) * stride]);
            }
            p
        } else {
            let mut p = vec![0u8; k * n];
            for i in 0..k {
                for j in 0..n {
                    let q = (w.at(i, j) / scales[j]).round().clamp(qmin, qmax) as i32;
                    colsum[j] += q;
                    p[i * n + j] = (q as i8) as u8;
                }
            }
            p
        };
        QuantMat { rows: k, cols: n, bits, payload, scales: scales.to_vec(), colsum }
    }

    /// Pack through a fitted codec. `None` for codecs with no integer-GEMM
    /// representation (FP4 / MXFP4 / no-op).
    pub fn from_codec(w: &Mat, codec: &WeightCodec) -> Option<QuantMat> {
        let (bits, scales) = codec.int_params()?;
        if bits != 4 && bits != 8 {
            return None;
        }
        Some(QuantMat::pack_int(w, scales, bits))
    }

    /// The signed integer code at (i, j) (tests/diagnostics).
    pub fn code(&self, i: usize, j: usize) -> i32 {
        debug_assert!(i < self.rows && j < self.cols);
        if self.bits == 4 {
            let stride = (self.cols + 1) / 2;
            let byte = self.payload[i * stride + j / 2];
            let nib = if j % 2 == 0 { byte & 0x0F } else { byte >> 4 };
            nib as i32 - 8
        } else {
            (self.payload[i * self.cols + j] as i8) as i32
        }
    }

    /// Materialize the dequantized f32 matrix — bit-identical to
    /// `WeightCodec::quantize_mat` output for the packing codec (both
    /// compute the f32 product `t_j · q`).
    pub fn dequantize(&self) -> Mat {
        Mat::from_fn(self.rows, self.cols, |i, j| self.scales[j] * self.code(i, j) as f32)
    }

    /// The raw packed payload (u4x2 nibble pairs or i8 bit patterns),
    /// row-major — the bytes the `.perq` deployment artifact persists.
    pub fn payload_bytes(&self) -> &[u8] {
        &self.payload
    }

    /// Per output-channel integer column sums (the zero-point correction
    /// term), exposed for artifact serialization.
    pub fn colsums(&self) -> &[i32] {
        &self.colsum
    }

    /// Payload byte length of a (rows × cols) matrix packed at `bits` —
    /// the artifact reader uses this to split a section into payload /
    /// scales / colsums without trusting stored lengths. Checked: header
    /// shapes are untrusted input, so an overflowing product is an error,
    /// never a wrap or a debug panic.
    pub fn payload_len(rows: usize, cols: usize, bits: u32) -> Result<usize> {
        let per_row = match bits {
            4 => cols / 2 + cols % 2,
            8 => cols,
            _ => bail!("unsupported packed width int{bits} (expected 4 or 8)"),
        };
        rows.checked_mul(per_row)
            .ok_or_else(|| anyhow::anyhow!("packed {rows}x{cols} int{bits} size overflows"))
    }

    /// Reassemble a packed matrix from serialized parts (the inverse of
    /// reading [`QuantMat::payload_bytes`]/`scales`/[`QuantMat::colsums`]),
    /// validating every length against the declared shape. Round-trips
    /// bit-exactly: the payload is stored verbatim.
    pub fn from_parts(rows: usize, cols: usize, bits: u32, payload: Vec<u8>,
                      scales: Vec<f32>, colsum: Vec<i32>) -> Result<QuantMat> {
        let want = QuantMat::payload_len(rows, cols, bits)?;
        ensure!(
            payload.len() == want,
            "packed payload holds {} bytes, {}x{} int{} needs {}",
            payload.len(), rows, cols, bits, want
        );
        ensure!(
            scales.len() == cols && colsum.len() == cols,
            "per-channel metadata must carry one entry per output column ({} scales, {} colsums, {} cols)",
            scales.len(), colsum.len(), cols
        );
        Ok(QuantMat { rows, cols, bits, payload, scales, colsum })
    }

    /// Payload bytes actually held (the weight-memory footprint).
    pub fn packed_bytes(&self) -> usize {
        self.payload.len() + 4 * (self.scales.len() + self.colsum.len())
    }

    /// Bytes the dequantized f32 copy would occupy.
    pub fn dense_bytes(&self) -> usize {
        self.rows * self.cols * 4
    }
}

/// Per-token activation rows quantized to integer codes: `rows × cols` u8
/// codes plus per-row (scale, zero). Buffers persist across `reset` calls,
/// so steady-state serving emits with zero allocation.
pub struct QuantActs {
    pub rows: usize,
    pub cols: usize,
    pub bits: u32,
    pub codes: Vec<u8>,
    pub scales: Vec<f32>,
    pub zeros: Vec<f32>,
}

impl QuantActs {
    pub fn new(bits: u32) -> QuantActs {
        assert!(bits == 4 || bits == 8, "activation codes are u8, 4- or 8-bit");
        QuantActs { rows: 0, cols: 0, bits, codes: Vec::new(), scales: Vec::new(), zeros: Vec::new() }
    }

    /// Clear for a new batch of `cols`-wide rows (capacity retained).
    pub fn reset(&mut self, cols: usize) {
        self.rows = 0;
        self.cols = cols;
        self.codes.clear();
        self.scales.clear();
        self.zeros.clear();
    }

    /// Quantize one (already rotated) activation row straight into the
    /// staging buffer — the emit half of the fused rotate→quant→qgemm
    /// sequence.
    pub fn push_row(&mut self, row: &[f32]) {
        debug_assert_eq!(row.len(), self.cols, "row width mismatch");
        let (s, z) = act::int_asym_emit(row, self.bits, &mut self.codes);
        self.scales.push(s);
        self.zeros.push(z);
        self.rows += 1;
    }

    /// Reset and emit every row of an activation matrix — the staging
    /// counterpart of `act::act_quant_mat` on the packed path.
    pub fn fill_from_mat(&mut self, m: &Mat) {
        self.reset(m.cols);
        for r in 0..m.rows {
            self.push_row(m.row(r));
        }
    }

    /// Quantize a whole activation matrix (convenience for tests/benches).
    pub fn from_mat(m: &Mat, bits: u32) -> QuantActs {
        let mut qa = QuantActs::new(bits);
        qa.fill_from_mat(m);
        qa
    }
}

/// Token rows per cache block: each unpacked weight row is reused this
/// many times, amortizing nibble decode to <10% of the MAC work.
const MB: usize = 16;

/// Columns per cache tile. The inner loops run over an (MB × NB)
/// accumulator tile (4 KiB in i16, 8 KiB in i32) plus an NB-wide unpacked
/// weight chunk, all L1-resident across a whole k-chunk — without the
/// tile split the MB × d_out accumulator streams from L2 on every k step
/// and the kernel goes memory-bound, flattening the SIMD win. Tiling only
/// reorders the j-iteration; integer accumulation is exact, so results
/// are bit-identical to the untiled loop.
const NB: usize = 128;

/// k-chunk length for the INT4 i16 fast path. With |u| ≤ 15 and |q| ≤ 8
/// every product is ≤ 120 in magnitude, so 256 accumulations stay below
/// the i16 limit (256 · 120 = 30 720 < 32 767); the i16 tile is widened
/// into the i32 accumulator between chunks. i16 lanes are the reason the
/// packed kernel beats f32: `pmullw`/`paddw` are 8-wide even on baseline
/// SSE2 (16-wide on AVX2), where 32-bit integer multiplies are not.
const KC: usize = 256;

thread_local! {
    /// Per-worker kernel scratch (i32 accumulator tile, i16 chunk
    /// accumulator, unpacked i16 weight row) — reused across calls so
    /// steady-state scoring does not allocate.
    static QG_SCRATCH: RefCell<(Vec<i32>, Vec<i16>, Vec<i16>)> =
        const { RefCell::new((Vec::new(), Vec::new(), Vec::new())) };
}

/// `acts @ w` into a preallocated (acts.rows, w.cols) f32 output: integer
/// GEMM with i32 accumulation and per-channel dequantization fused into
/// the store. Row blocks are distributed across the persistent worker
/// pool; each block owns a disjoint slice of `out`, so the result is
/// deterministic.
pub fn qgemm_into(acts: &QuantActs, w: &QuantMat, out: &mut Mat) {
    assert_eq!(acts.cols, w.rows, "qgemm shape mismatch");
    assert_eq!((out.rows, out.cols), (acts.rows, w.cols), "qgemm output shape");
    let m = acts.rows;
    if m == 0 {
        return;
    }
    let (k, n) = (w.rows, w.cols);
    let blocks = (m + MB - 1) / MB;
    let out_ptr = SendPtr(out.data.as_mut_ptr());
    let task = move |bi: usize| {
        let r0 = bi * MB;
        let mb = MB.min(m - r0);
        QG_SCRATCH.with(|cell| {
            let mut guard = cell.borrow_mut();
            let scratch = &mut *guard;
            let (acc32, acc16, wbuf) = (&mut scratch.0, &mut scratch.1, &mut scratch.2);
            // SAFETY: block bi exclusively owns output rows r0..r0+mb.
            let o = unsafe { std::slice::from_raw_parts_mut(out_ptr.get().add(r0 * n), mb * n) };
            qgemm_block(acts, w, r0, mb, acc32, acc16, wbuf, o);
        });
    };
    // same threshold as par_matmul_into: below ~2 M MACs the fan-out
    // costs more than it saves
    if blocks == 1 || m * k * n < (1 << 21) {
        for bi in 0..blocks {
            task(bi);
        }
    } else {
        pool::global().run(blocks, &task);
    }
}

/// Allocating convenience wrapper over [`qgemm_into`].
pub fn qgemm(acts: &QuantActs, w: &QuantMat) -> Mat {
    let mut out = Mat::zeros(acts.rows, w.cols);
    qgemm_into(acts, w, &mut out);
    out
}

/// One MB-row block: accumulate `acc[mi][j] += u[mi][kk] · q[kk][j]` over
/// (MB × NB) L1-resident column tiles with the weight chunk unpacked once
/// per (kk, tile), then store with fused dequant
/// `out = s·t_j·(acc + z·colsum_j)`. All inner loops go through the
/// runtime-dispatched `tensor::simd` primitives (AVX2/NEON/scalar) —
/// integer lanes are exact, so every dispatch level and tiling produces
/// bit-identical results.
///
/// Three accumulation strategies, chosen by payload/code width:
/// * INT4 × INT4 codes — i16 lanes in KC-length k-chunks, widened into
///   i32 between chunks (provably overflow-free; see [`KC`]), two
///   activation rows per weight load (`axpy2_i16`);
/// * INT4 weights with wider activation codes — straight i32 lanes;
/// * INT8 weights — straight i32 lanes over the raw i8 payload row.
fn qgemm_block(acts: &QuantActs, w: &QuantMat, r0: usize, mb: usize,
               acc32: &mut Vec<i32>, acc16: &mut Vec<i16>, wbuf: &mut Vec<i16>,
               out: &mut [f32]) {
    let (k, n) = (w.rows, w.cols);
    acc32.clear();
    acc32.resize(mb * n, 0);
    if w.bits == 4 && acts.bits == 4 {
        let stride = (n + 1) / 2;
        wbuf.resize(NB.min(n), 0);
        acc16.clear();
        acc16.resize(mb * n, 0);
        let mut j0 = 0;
        while j0 < n {
            let j1 = (j0 + NB).min(n);
            let nb = j1 - j0;
            let mut c0 = 0;
            while c0 < k {
                let cend = (c0 + KC).min(k);
                for kk in c0..cend {
                    // NB is even, so the tile starts on a whole byte
                    let prow = &w.payload[kk * stride + j0 / 2..(kk + 1) * stride];
                    simd::unpack_row4(prow, nb, &mut wbuf[..nb]);
                    let mut mi = 0;
                    while mi + 2 <= mb {
                        let u0 = acts.codes[(r0 + mi) * k + kk] as i16;
                        let u1 = acts.codes[(r0 + mi + 1) * k + kk] as i16;
                        if u0 != 0 || u1 != 0 {
                            let (head, tail) = acc16.split_at_mut((mi + 1) * n);
                            simd::axpy2_i16(
                                u0,
                                u1,
                                &wbuf[..nb],
                                &mut head[mi * n + j0..mi * n + j1],
                                &mut tail[j0..j1],
                            );
                        }
                        mi += 2;
                    }
                    if mi < mb {
                        let u = acts.codes[(r0 + mi) * k + kk] as i16;
                        if u != 0 {
                            simd::axpy_i16(u, &wbuf[..nb], &mut acc16[mi * n + j0..mi * n + j1]);
                        }
                    }
                }
                // widen the chunk's column tile into i32 and reset
                for mi in 0..mb {
                    simd::widen_reset_i16(
                        &mut acc16[mi * n + j0..mi * n + j1],
                        &mut acc32[mi * n + j0..mi * n + j1],
                    );
                }
                c0 = cend;
            }
            j0 = j1;
        }
    } else if w.bits == 4 {
        let stride = (n + 1) / 2;
        wbuf.resize(NB.min(n), 0);
        let mut j0 = 0;
        while j0 < n {
            let j1 = (j0 + NB).min(n);
            let nb = j1 - j0;
            for kk in 0..k {
                let prow = &w.payload[kk * stride + j0 / 2..(kk + 1) * stride];
                simd::unpack_row4(prow, nb, &mut wbuf[..nb]);
                for mi in 0..mb {
                    let u = acts.codes[(r0 + mi) * k + kk] as i32;
                    if u == 0 {
                        continue;
                    }
                    simd::axpy_i32_i16w(u, &wbuf[..nb], &mut acc32[mi * n + j0..mi * n + j1]);
                }
            }
            j0 = j1;
        }
    } else {
        let mut j0 = 0;
        while j0 < n {
            let j1 = (j0 + NB).min(n);
            for kk in 0..k {
                let prow = &w.payload[kk * n + j0..kk * n + j1];
                // SAFETY: i8 and u8 have identical layout; codes were stored
                // as i8 bit patterns.
                let wrow =
                    unsafe { std::slice::from_raw_parts(prow.as_ptr() as *const i8, j1 - j0) };
                for mi in 0..mb {
                    let u = acts.codes[(r0 + mi) * k + kk] as i32;
                    if u == 0 {
                        continue;
                    }
                    simd::axpy_i32_i8w(u, wrow, &mut acc32[mi * n + j0..mi * n + j1]);
                }
            }
            j0 = j1;
        }
    }
    for mi in 0..mb {
        let r = r0 + mi;
        let (sx, z) = (acts.scales[r], acts.zeros[r]);
        simd::dequant_store(
            sx,
            z,
            &w.scales,
            &w.colsum,
            &acc32[mi * n..(mi + 1) * n],
            &mut out[mi * n..(mi + 1) * n],
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{act as actq, Format};

    fn rand_mat(r: usize, c: usize, seed: u64, scale: f32) -> Mat {
        let mut rng = crate::data::rng::Rng::new(seed);
        Mat::from_fn(r, c, |_, _| rng.next_normal() as f32 * scale)
    }

    /// The fake-quant f32 reference: quantized activations (fake) × codec-
    /// quantized weights through a naive f32 matmul.
    fn reference(x: &Mat, qw: &Mat, bits: u32) -> Mat {
        let mut xq = x.clone();
        for r in 0..xq.rows {
            actq::int_asym_row(xq.row_mut(r), bits);
        }
        let mut out = Mat::zeros(x.rows, qw.cols);
        for i in 0..x.rows {
            for j in 0..qw.cols {
                let mut acc = 0.0f32;
                for kk in 0..x.cols {
                    acc += xq.at(i, kk) * qw.at(kk, j);
                }
                *out.at_mut(i, j) = acc;
            }
        }
        out
    }

    #[test]
    fn pack_roundtrip_bit_exact() {
        for (fmt, bits) in [(Format::Int4, 4u32), (Format::Int8, 8)] {
            let w = rand_mat(48, 9, 1, 0.2); // odd cols exercise the nibble tail
            let codec = WeightCodec::fit(fmt, &w);
            let qw = codec.quantize_mat(&w);
            let packed = QuantMat::from_codec(&qw, &codec).unwrap();
            assert_eq!(packed.bits, bits);
            assert_eq!(packed.dequantize().data, qw.data, "{fmt:?}");
        }
    }

    #[test]
    fn from_parts_round_trips_bit_exact() {
        for (fmt, bits) in [(Format::Int4, 4u32), (Format::Int8, 8)] {
            let w = rand_mat(24, 7, 3, 0.2); // odd cols: nibble-tail coverage
            let codec = WeightCodec::fit(fmt, &w);
            let qm = QuantMat::from_codec(&codec.quantize_mat(&w), &codec).unwrap();
            let back = QuantMat::from_parts(
                qm.rows, qm.cols, qm.bits,
                qm.payload_bytes().to_vec(),
                qm.scales.clone(),
                qm.colsums().to_vec(),
            )
            .unwrap();
            assert_eq!(back.bits, bits);
            assert_eq!(back.payload_bytes(), qm.payload_bytes());
            assert_eq!(back.dequantize().data, qm.dequantize().data);
        }
    }

    #[test]
    fn from_parts_rejects_bad_lengths() {
        assert!(QuantMat::from_parts(4, 4, 4, vec![0u8; 3], vec![1.0; 4], vec![0; 4]).is_err());
        assert!(QuantMat::from_parts(4, 4, 8, vec![0u8; 16], vec![1.0; 3], vec![0; 4]).is_err());
        assert!(QuantMat::from_parts(4, 4, 2, vec![0u8; 16], vec![1.0; 4], vec![0; 4]).is_err());
        assert_eq!(QuantMat::payload_len(4, 5, 4).unwrap(), 4 * 3);
        assert_eq!(QuantMat::payload_len(4, 5, 8).unwrap(), 20);
    }

    #[test]
    fn packed_bytes_shrink() {
        let w = rand_mat(128, 64, 2, 0.1);
        let codec = WeightCodec::fit(Format::Int4, &w);
        let packed = QuantMat::from_codec(&w, &codec).unwrap();
        // ~8× for int4 (plus per-channel metadata)
        assert!(packed.packed_bytes() * 6 < packed.dense_bytes());
    }

    #[test]
    fn qgemm_matches_fake_quant_reference() {
        for (fmt, bits) in [(Format::Int4, 4u32), (Format::Int8, 8)] {
            for seed in 0..4u64 {
                let (m, k, n) = (33, 64, 17);
                let x = rand_mat(m, k, 10 + seed, 1.0);
                let w = rand_mat(k, n, 20 + seed, 0.3);
                let codec = WeightCodec::fit(fmt, &w);
                let qw = codec.quantize_mat(&w);
                let packed = QuantMat::from_codec(&qw, &codec).unwrap();
                let acts = QuantActs::from_mat(&x, bits);
                let got = qgemm(&acts, &packed);
                let want = reference(&x, &qw, bits);
                // same rounding; only the accumulation order differs
                let tol = 1e-4 * (1.0 + want.abs_max());
                for (g, ww) in got.data.iter().zip(&want.data) {
                    assert!((g - ww).abs() <= tol, "{fmt:?} seed={seed}: {g} vs {ww}");
                }
            }
        }
    }

    #[test]
    fn qgemm_into_deterministic_across_block_counts() {
        // large enough to cross the parallel threshold: pool fan-out must
        // not change results
        let (m, k, n) = (70, 256, 160); // m·k·n > 2^21 → pool fan-out
        let x = rand_mat(m, k, 5, 1.0);
        let w = rand_mat(k, n, 6, 0.2);
        let codec = WeightCodec::fit(Format::Int4, &w);
        let packed = QuantMat::from_codec(&codec.quantize_mat(&w), &codec).unwrap();
        let acts = QuantActs::from_mat(&x, 4);
        let a = qgemm(&acts, &packed);
        let b = qgemm(&acts, &packed);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn i16_chunk_widening_exact_at_extremes() {
        // k > KC with extremal codes: every INT4 product is -120 and each
        // 256-chunk sums to -30720 — the documented i16 bound. The result
        // must be the exact integer answer.
        let (m, k, n) = (2usize, 600, 3);
        let w = Mat::from_fn(k, n, |_, _| -8.0);
        let packed = QuantMat::pack_int(&w, &vec![1.0; n], 4);
        let mut acts = QuantActs::new(4);
        acts.rows = m;
        acts.cols = k;
        acts.codes = vec![15u8; m * k];
        acts.scales = vec![1.0; m];
        acts.zeros = vec![0.0; m];
        let got = qgemm(&acts, &packed);
        for v in &got.data {
            assert_eq!(*v, (15 * -8 * 600) as f32);
        }
    }

    #[test]
    fn mixed_width_codes_use_exact_i32_path() {
        // int8 activation codes against int4 weights must route around the
        // i16 fast path (its overflow bound assumes 4-bit codes)
        let (m, k, n) = (3usize, 300, 4);
        let w = Mat::from_fn(k, n, |_, _| -8.0);
        let packed = QuantMat::pack_int(&w, &vec![1.0; n], 4);
        let mut acts = QuantActs::new(8);
        acts.rows = m;
        acts.cols = k;
        acts.codes = vec![255u8; m * k];
        acts.scales = vec![1.0; m];
        acts.zeros = vec![0.0; m];
        let got = qgemm(&acts, &packed);
        for v in &got.data {
            assert_eq!(*v, (255 * -8 * 300) as f32);
        }
    }

    #[test]
    fn quant_acts_reset_reuses_buffers() {
        let x = rand_mat(8, 32, 7, 1.0);
        let mut qa = QuantActs::new(4);
        qa.reset(32);
        for r in 0..8 {
            qa.push_row(x.row(r));
        }
        assert_eq!((qa.rows, qa.codes.len()), (8, 256));
        let cap = qa.codes.capacity();
        qa.reset(32);
        for r in 0..8 {
            qa.push_row(x.row(r));
        }
        assert_eq!(qa.codes.capacity(), cap, "reset must retain capacity");
    }

    #[test]
    fn zero_point_correction_handles_shifted_rows() {
        // rows with a large positive offset stress the z·colsum term
        let (m, k, n) = (5, 32, 7);
        let mut x = rand_mat(m, k, 8, 0.5);
        for v in &mut x.data {
            *v += 40.0;
        }
        let w = rand_mat(k, n, 9, 0.3);
        let codec = WeightCodec::fit(Format::Int8, &w);
        let qw = codec.quantize_mat(&w);
        let packed = QuantMat::from_codec(&qw, &codec).unwrap();
        let got = qgemm(&QuantActs::from_mat(&x, 8), &packed);
        let want = reference(&x, &qw, 8);
        let tol = 1e-4 * (1.0 + want.abs_max());
        for (g, ww) in got.data.iter().zip(&want.data) {
            assert!((g - ww).abs() <= tol, "{g} vs {ww}");
        }
    }
}
