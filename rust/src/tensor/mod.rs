//! Minimal dense-tensor substrate: row-major `Mat` (f32), f64 linear
//! algebra for rounding solvers, and NPY v1.0 interchange with the python
//! build path. Built from scratch — no external linear-algebra crates.

pub mod linalg;
pub mod mat;
pub mod npy;

pub use mat::Mat;
