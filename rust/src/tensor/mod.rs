//! Minimal dense-tensor substrate: row-major `Mat` (f32), packed low-bit
//! `QuantMat` + integer GEMM for the serving path, the runtime-dispatched
//! SIMD kernel layer (`simd`: AVX2/NEON/scalar), f64 linear algebra for
//! rounding solvers, and NPY v1.0 interchange with the python build path.
//! Built from scratch — no external linear-algebra crates.

pub mod kvcache;
pub mod linalg;
pub mod mat;
pub mod npy;
pub mod qmat;
pub mod simd;

pub use kvcache::{KvCache, KvMode, KvStats, KvSwap, OutOfPages, PagedConfig};
pub use mat::Mat;
pub use qmat::{qgemm_into, QuantActs, QuantMat};
