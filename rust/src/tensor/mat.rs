//! Row-major dense f32 matrix. Activations are (tokens, features);
//! weights are (in_features, out_features) — matching the L2 jax layout
//! where `y = x @ w`.

use std::fmt;

#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mat({}x{})", self.rows, self.cols)
    }
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        Mat::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols;
        &mut self.data[i * c..(i + 1) * c]
    }

    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self.at(i, j)).collect()
    }

    pub fn set_col(&mut self, j: usize, v: &[f32]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            *self.at_mut(i, j) = v[i];
        }
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                *out.at_mut(j, i) = self.at(i, j);
            }
        }
        out
    }

    /// `self @ other` — cache-blocked ikj loop; the workhorse of the
    /// offline transform engine.
    pub fn matmul(&self, other: &Mat) -> Mat {
        let mut out = Mat::zeros(self.rows, other.cols);
        self.matmul_into(other, &mut out);
        out
    }

    /// `self @ other` into a preallocated (rows, other.cols) output —
    /// the allocation-free form the native execution backend uses with
    /// pooled scratch buffers. Overwrites `out`.
    pub fn matmul_into(&self, other: &Mat, out: &mut Mat) {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        assert_eq!((out.rows, out.cols), (self.rows, other.cols), "matmul output shape");
        out.data.fill(0.0);
        matmul_kernel(&self.data, self.rows, self.cols, &other.data, other.cols, &mut out.data);
    }

    /// `self @ other` into `out`, with the rows of `self` partitioned
    /// across the persistent `util::pool` workers (deterministic: each
    /// task owns a disjoint slice of `out`, so the result is bit-identical
    /// to `matmul_into`). Falls back to the single-threaded kernel for
    /// small problems — per-call thread spawning is gone entirely, so the
    /// parallel threshold no longer has to amortize OS thread creation.
    pub fn par_matmul_into(&self, other: &Mat, out: &mut Mat) {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        assert_eq!((out.rows, out.cols), (self.rows, other.cols), "matmul output shape");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let workers = crate::util::pool::global().parallelism();
        // ~2 MFLOP minimum, or the fan-out costs more than it saves
        if workers <= 1 || m * k * n < 1 << 21 || m < 2 * workers {
            self.matmul_into(other, out);
            return;
        }
        out.data.fill(0.0);
        let chunk_rows = (m + workers - 1) / workers;
        let n_chunks = (m + chunk_rows - 1) / chunk_rows;
        let a = &self.data;
        let b = &other.data;
        let out_ptr = crate::util::pool::SendPtr(out.data.as_mut_ptr());
        crate::util::pool::global().run(n_chunks, &move |ci: usize| {
            let r0 = ci * chunk_rows;
            let rows = chunk_rows.min(m - r0);
            // SAFETY: chunk ci exclusively owns output rows r0..r0+rows.
            let o = unsafe { std::slice::from_raw_parts_mut(out_ptr.get().add(r0 * n), rows * n) };
            matmul_kernel(&a[r0 * k..(r0 + rows) * k], rows, k, b, n, o);
        });
    }

    /// `self^T @ other` without materializing the transpose.
    pub fn t_matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "t_matmul shape mismatch");
        let (k, m, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        for kk in 0..k {
            let arow = &self.data[kk * m..(kk + 1) * m];
            let brow = &other.data[kk * n..(kk + 1) * n];
            for i in 0..m {
                let a = arow[i];
                if a == 0.0 {
                    continue;
                }
                let orow = &mut out.data[i * n..(i + 1) * n];
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        out
    }

    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        }
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    /// Permute columns: out[:, j] = self[:, perm[j]].
    pub fn permute_cols(&self, perm: &[usize]) -> Mat {
        assert_eq!(perm.len(), self.cols);
        let mut out = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let src = self.row(i);
            let dst = out.row_mut(i);
            for (j, &p) in perm.iter().enumerate() {
                dst[j] = src[p];
            }
        }
        out
    }

    /// Permute rows: out[i, :] = self[perm[i], :].
    pub fn permute_rows(&self, perm: &[usize]) -> Mat {
        assert_eq!(perm.len(), self.rows);
        let mut out = Mat::zeros(self.rows, self.cols);
        for (i, &p) in perm.iter().enumerate() {
            out.row_mut(i).copy_from_slice(self.row(p));
        }
        out
    }

    /// Scale row i by s[i] (used to fold RMSNorm scales into weights).
    pub fn scale_rows(&self, s: &[f32]) -> Mat {
        assert_eq!(s.len(), self.rows);
        let mut out = self.clone();
        for i in 0..self.rows {
            let si = s[i];
            for v in out.row_mut(i) {
                *v *= si;
            }
        }
        out
    }
}

/// The shared cache-blocked ikj kernel: `out += a @ b` for a row-major
/// (m, k) slice against (k, n). `out` must be zeroed by the caller. The
/// rank-1 update runs through the SIMD layer (`tensor::simd::axpy_f32`);
/// each output element still accumulates in kk order with mul-then-add,
/// so results are bit-identical across dispatch levels.
fn matmul_kernel(a: &[f32], m: usize, k: usize, b: &[f32], n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    const BK: usize = 64;
    for kb in (0..k).step_by(BK) {
        let kend = (kb + BK).min(k);
        for i in 0..m {
            let orow = &mut out[i * n..(i + 1) * n];
            for kk in kb..kend {
                let av = a[i * k + kk];
                if av == 0.0 {
                    continue;
                }
                let brow = &b[kk * n..(kk + 1) * n];
                crate::tensor::simd::axpy_f32(av, brow, orow);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_fn(3, 4, |i, j| (i * 4 + j) as f32);
        let id = Mat::eye(4);
        assert_eq!(a.matmul(&id).data, a.data);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Mat::from_vec(2, 2, vec![5., 6., 7., 8.]);
        assert_eq!(a.matmul(&b).data, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn t_matmul_matches_explicit_transpose() {
        let a = Mat::from_fn(5, 3, |i, j| (i + 2 * j) as f32 * 0.5);
        let b = Mat::from_fn(5, 4, |i, j| (i * j) as f32 - 1.0);
        let got = a.t_matmul(&b);
        let want = a.transpose().matmul(&b);
        for (g, w) in got.data.iter().zip(&want.data) {
            assert!((g - w).abs() < 1e-5);
        }
    }

    #[test]
    fn matmul_into_matches_matmul() {
        let a = Mat::from_fn(7, 5, |i, j| (i * 5 + j) as f32 * 0.25 - 3.0);
        let b = Mat::from_fn(5, 9, |i, j| ((i + 1) * (j + 2)) as f32 * 0.1);
        let want = a.matmul(&b);
        let mut out = Mat::from_fn(7, 9, |_, _| 42.0); // stale contents overwritten
        a.matmul_into(&b, &mut out);
        assert_eq!(out.data, want.data);
    }

    #[test]
    fn par_matmul_bit_identical_to_serial() {
        // large enough to cross the parallel threshold
        let a = Mat::from_fn(256, 96, |i, j| ((i * 31 + j * 7) % 13) as f32 - 6.0);
        let b = Mat::from_fn(96, 128, |i, j| ((i * 17 + j * 3) % 11) as f32 * 0.5);
        let want = a.matmul(&b);
        let mut out = Mat::zeros(256, 128);
        a.par_matmul_into(&b, &mut out);
        assert_eq!(out.data, want.data, "row partitioning must not change results");
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Mat::from_fn(3, 7, |i, j| (i * 7 + j) as f32);
        assert_eq!(a.transpose().transpose().data, a.data);
    }

    #[test]
    fn permute_cols_inverse() {
        let a = Mat::from_fn(2, 5, |i, j| (i * 5 + j) as f32);
        let perm = vec![3, 1, 4, 0, 2];
        let mut inv = vec![0usize; 5];
        for (j, &p) in perm.iter().enumerate() {
            inv[p] = j;
        }
        assert_eq!(a.permute_cols(&perm).permute_cols(&inv).data, a.data);
    }

    #[test]
    fn permute_rows_then_cols_commute_on_square() {
        let a = Mat::from_fn(4, 4, |i, j| (i * 4 + j) as f32);
        let perm = vec![2, 0, 3, 1];
        let rc = a.permute_rows(&perm).permute_cols(&perm);
        let cr = a.permute_cols(&perm).permute_rows(&perm);
        assert_eq!(rc.data, cr.data);
    }

    #[test]
    fn scale_rows_folds_norm() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let s = vec![2.0, 0.5];
        assert_eq!(a.scale_rows(&s).data, vec![2., 4., 1.5, 2.]);
    }

    #[test]
    fn frob_norm_known() {
        let a = Mat::from_vec(1, 2, vec![3., 4.]);
        assert!((a.frob_norm() - 5.0).abs() < 1e-9);
    }
}
